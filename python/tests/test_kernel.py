"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

Shape/seed sweeps stand in for hypothesis (not installed in this image):
every test iterates a parameter grid with seeded random data and asserts
allclose against ref.py.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import ref
from compile.kernels.cost_batch import cost_batch
from compile.kernels.matmul import matmul


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


# ---------------------------------------------------------------- matmul


@pytest.mark.parametrize("m", [1, 3, 16, 32, 33, 64, 128])
@pytest.mark.parametrize("k", [1, 16, 17, 64])
@pytest.mark.parametrize("n", [1, 8, 64])
def test_matmul_matches_ref(m, k, n):
    rng = np.random.default_rng(m * 10007 + k * 101 + n)
    x, w = rand(rng, m, k), rand(rng, k, n)
    got = matmul(x, w)
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bm,bn", [(8, 8), (16, 32), (64, 64), (128, 128)])
def test_matmul_block_shape_invariance(bm, bn):
    rng = np.random.default_rng(7)
    x, w = rand(rng, 64, 32), rand(rng, 32, 48)
    base = matmul(x, w)
    got = matmul(x, w, bm=bm, bn=bn)
    # Different block shapes reorder the f32 accumulation.
    np.testing.assert_allclose(got, base, rtol=1e-3, atol=1e-4)


def test_matmul_rejects_mismatched_contraction():
    rng = np.random.default_rng(8)
    with pytest.raises(AssertionError):
        matmul(rand(rng, 4, 5), rand(rng, 6, 4))


# ------------------------------------------------------------- cost batch


def random_feats(rng, b):
    """Feature rows shaped like real candidates (positive, large range)."""
    f = np.zeros((b, ref.NUM_FEATURES), np.float32)
    f[:, 0] = rng.uniform(1e6, 1e10, b)  # macs
    f[:, 1] = rng.uniform(1e3, 1e7, b)  # ifm
    f[:, 2] = rng.uniform(1e3, 1e7, b)  # ofm
    f[:, 3] = rng.uniform(1e2, 1e7, b)  # wgt
    f[:, 4] = rng.integers(1, 257, b)  # nodes
    f[:, 5] = 2.0 ** rng.integers(0, 7, b)  # rounds
    f[:, 6] = rng.integers(0, 2, b)  # ifm_on_chip
    f[:, 7] = rng.integers(0, 2, b)  # ofm_on_chip
    f[:, 8] = rng.uniform(1.0, 8.0, b)  # hops
    f[:, 9] = 64.0  # pes per node
    f[:, 10] = 3.4  # gbuf pj
    f[:, 11] = 0.35  # regf pj
    return jnp.asarray(f)


PARAMS = jnp.asarray([1.0, 200.0, 9.76, 2.0, 25.6], jnp.float32)


@pytest.mark.parametrize("b", [1, 2, 63, 64, 128, 256])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_cost_batch_matches_ref(b, seed):
    rng = np.random.default_rng(seed)
    feats = random_feats(rng, b)
    got = cost_batch(feats, PARAMS)
    want = ref.cost_batch_ref(feats, PARAMS)
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_cost_batch_block_invariance():
    rng = np.random.default_rng(3)
    feats = random_feats(rng, 128)
    a = cost_batch(feats, PARAMS, bb=16)
    b = cost_batch(feats, PARAMS, bb=128)
    np.testing.assert_allclose(a, b, rtol=1e-6)


def test_cost_monotone_in_macs():
    rng = np.random.default_rng(4)
    feats = np.array(random_feats(rng, 8))
    hi = feats.copy()
    hi[:, 0] *= 2.0
    lo = np.asarray(cost_batch(jnp.asarray(feats), PARAMS))
    up = np.asarray(cost_batch(jnp.asarray(hi), PARAMS))
    assert (up[:, 0] > lo[:, 0]).all()
    assert (up[:, 1] >= lo[:, 1]).all()


def test_on_chip_forwarding_cheaper():
    rng = np.random.default_rng(5)
    feats = np.array(random_feats(rng, 16))
    feats[:, 6] = 0.0
    off = np.asarray(cost_batch(jnp.asarray(feats), PARAMS))
    feats[:, 6] = 1.0
    on = np.asarray(cost_batch(jnp.asarray(feats), PARAMS))
    assert (on[:, 0] <= off[:, 0]).all()
