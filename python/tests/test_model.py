"""Layer-2 correctness: the surrogate MLP (forward + custom_vjp train step
over the Pallas matmul) against the explicit-gradient reference."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref


def params_and_data(seed, batch):
    rng = np.random.default_rng(seed)
    w1 = jnp.asarray(rng.standard_normal((model.SCHEME_FEATURES, model.HIDDEN)) * 0.3, jnp.float32)
    b1 = jnp.asarray(rng.standard_normal(model.HIDDEN) * 0.1, jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((model.HIDDEN, 1)) * 0.3, jnp.float32)
    b2 = jnp.asarray(rng.standard_normal(1) * 0.1, jnp.float32)
    x = jnp.asarray(rng.standard_normal((batch, model.SCHEME_FEATURES)), jnp.float32)
    y = jnp.asarray(rng.standard_normal(batch), jnp.float32)
    return (w1, b1, w2, b2), x, y


@pytest.mark.parametrize("batch", [4, 32, 64, 128])
@pytest.mark.parametrize("seed", [0, 1])
def test_forward_matches_ref(batch, seed):
    (w1, b1, w2, b2), x, _ = params_and_data(seed, batch)
    got = model.mlp_forward(w1, b1, w2, b2, x)
    want = ref.mlp_forward_ref(w1, b1, w2, b2, x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("batch", [8, 64])
@pytest.mark.parametrize("seed", [0, 3])
def test_train_step_matches_explicit_gradients(batch, seed):
    """jax.grad through the Pallas custom_vjp == hand-derived gradients."""
    (w1, b1, w2, b2), x, y = params_and_data(seed, batch)
    got = model.mlp_train_step(w1, b1, w2, b2, x, y)
    want = ref.mlp_train_step_ref(w1, b1, w2, b2, x, y, model.LEARNING_RATE)
    for g, w, name in zip(got, want, ["w1", "b1", "w2", "b2", "loss"]):
        np.testing.assert_allclose(g, w, rtol=2e-4, atol=2e-5, err_msg=name)


def test_training_reduces_loss():
    (w1, b1, w2, b2), x, _ = params_and_data(7, model.TRAIN_BATCH)
    # Learnable target: a fixed linear function of the features.
    y = 0.7 * x[:, 0] - 0.2 * x[:, 5] + 0.1
    losses = []
    for _ in range(60):
        w1, b1, w2, b2, loss = model.mlp_train_step(w1, b1, w2, b2, x, y)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.2, losses[::10]


def test_artifact_shapes_lower():
    """Every AOT artifact lowers to non-trivial HLO text."""
    from compile import aot

    for name, lower in aot.ARTIFACTS.items():
        text = aot.to_hlo_text(lower())
        assert "HloModule" in text, name
        assert len(text) > 1000, name


def test_constants_in_sync_with_rust():
    """Guard the cross-language contract (values also asserted in rust)."""
    assert model.SCHEME_FEATURES == 16
    assert model.HIDDEN == 64
    assert model.LEARNING_RATE == 1e-2
    assert ref.NUM_FEATURES == 12
    assert ref.NUM_PARAMS == 5
    assert model.COST_BATCH == 256
    assert model.INFER_BATCH == 128
    assert model.TRAIN_BATCH == 64
