"""Layer-1 Pallas kernel: blocked matrix multiply.

The surrogate MLP's forward and backward passes are built entirely from
this kernel (through a custom_vjp in model.py), so the whole L2 graph
lowers into Pallas-generated HLO.

TPU mapping notes (DESIGN.md §Hardware-Adaptation): the grid tiles M x N
output blocks for VMEM residency with the full K panel streamed per tile —
the natural MXU-feeding schedule for the small (<=128) dimensions used
here. `interpret=True` is mandatory on this CPU-PJRT image; real-TPU
lowering would emit a Mosaic custom-call the CPU plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref):
    # x_ref: [bm, K], w_ref: [K, bn] -> o_ref: [bm, bn]
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)


def _pick_block(dim, want):
    """Largest divisor of `dim` not exceeding `want` (grid must tile)."""
    b = min(dim, want)
    while dim % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def matmul(x, w, bm=32, bn=32):
    """Blocked Pallas matmul: x [M, K] @ w [K, N] -> [M, N]."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(x, w)
