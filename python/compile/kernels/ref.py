"""Pure-jnp oracles for the Pallas kernels (Layer-1 correctness signal).

Every Pallas kernel in this package has a reference implementation here;
pytest sweeps shapes and asserts allclose between kernel and reference.
The formulas mirror `rust/src/cost/mod.rs::cost_from_features` and
`rust/src/solvers/ml.rs::NativeMlp` exactly (the Rust side is the third
implementation of the same arithmetic, cross-checked in rust tests).
"""

import jax.numpy as jnp

# Feature vector layout, keep in sync with rust cost::features():
#  0 macs, 1 ifm, 2 ofm, 3 wgt, 4 nodes, 5 rounds, 6 ifm_on_chip,
#  7 ofm_on_chip, 8 dram_hops, 9 pes_per_node, 10 gbuf_pj, 11 regf_pj
NUM_FEATURES = 12

# Arch param vector layout, keep in sync with rust runtime::cost_params():
#  0 mac_pj, 1 dram_pj_per_word, 2 noc_pj_per_word_hop, 3 bus_pj_per_word,
#  4 dram_words_per_cycle
NUM_PARAMS = 5


def cost_batch_ref(feats, params):
    """Batched KAPLA lower-bound cost model.

    feats: [B, NUM_FEATURES]; params: [NUM_PARAMS].
    Returns [B, 2]: (energy_pj, latency_cycles_per_round).
    """
    macs = feats[:, 0]
    ifm = feats[:, 1]
    ofm = feats[:, 2]
    wgt = feats[:, 3]
    nodes = feats[:, 4]
    rounds = feats[:, 5]
    ifm_on = feats[:, 6]
    ofm_on = feats[:, 7]
    hops = feats[:, 8]
    pes = feats[:, 9]
    gbuf_pj = feats[:, 10]
    regf_pj = feats[:, 11]

    mac_pj, dram_pj, noc_pj, bus_pj, dram_wpc = (
        params[0],
        params[1],
        params[2],
        params[3],
        params[4],
    )

    rounds_c = jnp.maximum(rounds, 1.0)
    alu = macs * mac_pj
    regf = 4.0 * macs * regf_pj
    gbuf = 2.0 * (ifm + ofm + wgt / rounds_c) * gbuf_pj
    dram_words = ifm * (1.0 - ifm_on) + ofm * (1.0 - ofm_on) + wgt / rounds_c
    dram = dram_words * dram_pj
    noc_hops = dram_words * hops + (ifm * ifm_on + ofm * ofm_on)
    noc = noc_hops * noc_pj
    bus = (ifm + ofm + wgt / rounds_c) * bus_pj
    energy = (alu + regf + gbuf + dram + noc + bus) * rounds

    compute = macs / (jnp.maximum(nodes, 1.0) * pes)
    mem = dram_words / dram_wpc
    latency = jnp.maximum(compute, mem)

    return jnp.stack([energy, latency], axis=-1)


def matmul_ref(x, w):
    """Plain matmul oracle for the Pallas blocked-matmul kernel."""
    return jnp.matmul(x, w)


def mlp_forward_ref(w1, b1, w2, b2, x):
    """Surrogate MLP forward: x [B,F] -> predictions [B]."""
    h = jnp.maximum(jnp.matmul(x, w1) + b1, 0.0)
    y = jnp.matmul(h, w2) + b2
    return y[:, 0]


def mlp_train_step_ref(w1, b1, w2, b2, x, y, lr):
    """One explicit SGD step on MSE; mirrors rust NativeMlp::train_step."""
    h_lin = jnp.matmul(x, w1) + b1
    h = jnp.maximum(h_lin, 0.0)
    pred = (jnp.matmul(h, w2) + b2)[:, 0]
    err = pred - y
    n = x.shape[0]
    loss = jnp.mean(err * err)

    g = (2.0 * err / n)[:, None]  # [B,1]
    gb2 = jnp.sum(g)
    gw2 = jnp.matmul(h.T, g)  # [H,1]
    gh = jnp.matmul(g, w2.T) * (h_lin > 0.0)  # [B,H]
    gb1 = jnp.sum(gh, axis=0)
    gw1 = jnp.matmul(x.T, gh)  # [F,H]

    return (
        w1 - lr * gw1,
        b1 - lr * gb1,
        w2 - lr * gw2,
        b2 - lr * gb2,
        loss,
    )
