"""Layer-1 Pallas kernel: batched KAPLA cost-model evaluation.

The KAPLA solver's inter-layer phase scores hundreds of candidate segment
schemes per layer; this kernel evaluates the lower-bound cost model over a
whole candidate batch in one shot. The arithmetic is identical to
`ref.cost_batch_ref` and to `rust/src/cost/mod.rs::cost_from_features`.

TPU mapping: the grid tiles the batch dimension; each program instance
holds a [bb, F] feature block and the broadcast [P] param vector in VMEM
and emits a [bb, 2] result block — a pure VPU elementwise schedule with no
cross-instance communication.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _cost_kernel(f_ref, p_ref, o_ref):
    f = f_ref[...]
    p = p_ref[...]
    o_ref[...] = ref.cost_batch_ref(f, p)


def _pick_block(dim, want):
    b = min(dim, want)
    while dim % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bb",))
def cost_batch(feats, params, bb=64):
    """feats [B, NUM_FEATURES] f32, params [NUM_PARAMS] f32 -> [B, 2]."""
    b, f = feats.shape
    assert f == ref.NUM_FEATURES, f"expected {ref.NUM_FEATURES} features, got {f}"
    (p,) = params.shape
    assert p == ref.NUM_PARAMS
    bb = _pick_block(b, bb)
    return pl.pallas_call(
        _cost_kernel,
        out_shape=jax.ShapeDtypeStruct((b, 2), jnp.float32),
        grid=(b // bb,),
        in_specs=[
            pl.BlockSpec((bb, f), lambda i: (i, 0)),
            pl.BlockSpec((p,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bb, 2), lambda i: (i, 0)),
        interpret=True,
    )(feats, params)
