"""AOT lowering: JAX/Pallas Layer-2 graphs -> HLO text artifacts.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published `xla` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Artifacts (consumed by rust/src/runtime/):
  cost_batch.hlo.txt       fn(feats [256,12], params [5]) -> [256,2]
  surrogate_infer.hlo.txt  fn(w1 [16,64], b1 [64], w2 [64,1], b2 [1],
                              x [128,16]) -> [128]
  surrogate_train.hlo.txt  fn(w1, b1, w2, b2, x [64,16], y [64])
                              -> (w1', b1', w2', b2', loss)

Run once at build time (`make artifacts`); never on the solve path.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_cost_batch():
    feats = spec((model.COST_BATCH, ref.NUM_FEATURES))
    params = spec((ref.NUM_PARAMS,))
    return jax.jit(lambda f, p: (model.cost_batch_eval(f, p),)).lower(feats, params)


def param_specs():
    return (
        spec((model.SCHEME_FEATURES, model.HIDDEN)),
        spec((model.HIDDEN,)),
        spec((model.HIDDEN, 1)),
        spec((1,)),
    )


def lower_surrogate_infer():
    x = spec((model.INFER_BATCH, model.SCHEME_FEATURES))
    return jax.jit(lambda w1, b1, w2, b2, x: (model.mlp_forward(w1, b1, w2, b2, x),)).lower(
        *param_specs(), x
    )


def lower_surrogate_train():
    x = spec((model.TRAIN_BATCH, model.SCHEME_FEATURES))
    y = spec((model.TRAIN_BATCH,))
    return jax.jit(model.mlp_train_step).lower(*param_specs(), x, y)


ARTIFACTS = {
    "cost_batch.hlo.txt": lower_cost_batch,
    "surrogate_infer.hlo.txt": lower_surrogate_infer,
    "surrogate_train.hlo.txt": lower_surrogate_train,
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    for name, lower in ARTIFACTS.items():
        text = to_hlo_text(lower())
        path = os.path.join(args.out, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text):>9} chars to {path}")


if __name__ == "__main__":
    main()
