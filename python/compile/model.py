"""Layer-2 JAX model: the cost-surrogate MLP (forward + SGD train step) and
the batched cost evaluator, built on the Layer-1 Pallas kernels.

The MLP (SCHEME_FEATURES -> HIDDEN ReLU -> 1) is the learned surrogate of
the ML-based scheduling baseline (paper §V, AutoTVM-style). Its matmuls —
forward *and* backward — run through the Pallas blocked-matmul kernel via a
custom_vjp, so `jax.grad` of the training loss lowers entirely into
Pallas-generated HLO. Hyperparameters mirror
`rust/src/solvers/ml.rs` (HIDDEN, LEARNING_RATE) and
`rust/src/cost/mod.rs::SCHEME_FEATURES`; the Rust runtime cross-checks
numeric parity against its native implementation.
"""

import jax
import jax.numpy as jnp

from .kernels.matmul import matmul as _pallas_matmul

# Keep in sync with rust/src/cost/mod.rs and rust/src/solvers/ml.rs.
SCHEME_FEATURES = 16
HIDDEN = 64
LEARNING_RATE = 1e-2

# AOT artifact shapes (static for HLO export; the Rust runtime pads).
INFER_BATCH = 128
TRAIN_BATCH = 64
COST_BATCH = 256


@jax.custom_vjp
def mm(x, w):
    """Matmul as a differentiable primitive backed by the Pallas kernel."""
    return _pallas_matmul(x, w)


def _mm_fwd(x, w):
    return _pallas_matmul(x, w), (x, w)


def _mm_bwd(res, g):
    x, w = res
    # Both cotangents are themselves Pallas matmuls.
    dx = _pallas_matmul(g, w.T)
    dw = _pallas_matmul(x.T, g)
    return dx, dw


mm.defvjp(_mm_fwd, _mm_bwd)


def mlp_forward(w1, b1, w2, b2, x):
    """Surrogate forward: x [B, F] -> predictions [B]."""
    h = jnp.maximum(mm(x, w1) + b1, 0.0)
    y = mm(h, w2) + b2
    return y[:, 0]


def mlp_loss(params, x, y):
    w1, b1, w2, b2 = params
    pred = mlp_forward(w1, b1, w2, b2, x)
    err = pred - y
    return jnp.mean(err * err)


def mlp_train_step(w1, b1, w2, b2, x, y):
    """One SGD step; returns (w1', b1', w2', b2', loss).

    The gradient flows through the Pallas matmul custom_vjp.
    """
    loss, grads = jax.value_and_grad(mlp_loss)((w1, b1, w2, b2), x, y)
    gw1, gb1, gw2, gb2 = grads
    lr = LEARNING_RATE
    return (
        w1 - lr * gw1,
        b1 - lr * gb1,
        w2 - lr * gw2,
        b2 - lr * gb2,
        loss,
    )


def cost_batch_eval(feats, params):
    """Batched KAPLA lower-bound cost model (Layer-1 kernel pass-through)."""
    from .kernels.cost_batch import cost_batch

    return cost_batch(feats, params)


def init_params(seed=0):
    """He-normal init, used by pytest only (the Rust runtime owns the real
    parameter buffers and initializes them with its own PRNG)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w1 = jax.random.normal(k1, (SCHEME_FEATURES, HIDDEN), jnp.float32) * (
        2.0 / SCHEME_FEATURES
    ) ** 0.5
    b1 = jnp.zeros((HIDDEN,), jnp.float32)
    w2 = jax.random.normal(k2, (HIDDEN, 1), jnp.float32) * (2.0 / HIDDEN) ** 0.5
    b2 = jnp.zeros((1,), jnp.float32)
    return w1, b1, w2, b2
