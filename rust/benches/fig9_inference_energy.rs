//! Paper Fig. 9: dataflow energy for *inference* on multi-node
//! Eyeriss-like accelerators (batch 64), all five solvers normalized to B.
//! Inference DAGs are simpler than training DAGs and have fewer
//! constraints, so the scheduling space is relatively richer — the paper
//! reports K at 7.7% average overhead here (vs 2.2% for training), with
//! R and M degrading much further (59% / 36.1%).
//!
//! Run: `cargo bench --bench fig9_inference_energy`

use kapla::report::benchkit as bk;
use kapla::report::Table;
use kapla::solvers::Objective;
use kapla::util::stats::{fmt_duration, geomean};

fn main() {
    let arch = bk::bench_arch();
    let batch = bk::bench_batch();
    let nets = bk::bench_nets(&["alexnet", "mlp"]);
    let solvers = bk::paper_solvers(0.1);

    let mut t = Table::new(
        &format!("Fig.9 — inference energy normalized to B (batch {batch}, {})", arch.name),
        &["network", "B", "S", "R", "M", "K", "K solve", "B solve"],
    );
    let mut per_solver: Vec<Vec<f64>> = vec![Vec::new(); solvers.len()];
    for net in &nets {
        eprintln!("[fig9] {} ({} layers)...", net.name, net.len());
        let results: Vec<_> = solvers
            .iter()
            .map(|&s| bk::run_cell(&arch, net, batch, Objective::Energy, s))
            .collect();
        let base = results[0].eval.energy.total();
        let mut row = vec![net.name.clone()];
        for (i, r) in results.iter().enumerate() {
            let norm = r.eval.energy.total() / base;
            per_solver[i].push(norm);
            row.push(format!("{norm:.3}"));
        }
        row.push(fmt_duration(results[4].solve_s));
        row.push(fmt_duration(results[0].solve_s));
        t.row(row);
    }
    let mut gm = vec!["geomean".to_string()];
    for s in &per_solver {
        gm.push(format!("{:.3}", geomean(s)));
    }
    gm.push(String::new());
    gm.push(String::new());
    t.row(gm);

    let out = t.save_and_render("fig9_inference_energy");
    println!("{out}");
    bk::log_section("fig9_inference_energy", &out);
    println!("paper shape: K ~7.7% over B on average; R worst (esp. MLP), M between.");
}
