//! Paper Table VI: effectiveness of the inter-layer conservative pruning —
//! number of candidate inter-layer schemes of one representative segment
//! per network, before and after validity + Pareto pruning. Runs at the
//! paper's full 16x16-node scale (pruning statistics are cheap: no
//! intra-layer solving happens here — that is the whole point).
//!
//! Run: `cargo bench --bench table6_pruning`

use kapla::arch::presets;
use kapla::cost::TieredCost;
use kapla::interlayer::enumerate_segment_schemes;
use kapla::interlayer::prune::prune_and_rank;
use kapla::report::benchkit as bk;
use kapla::report::Table;
use kapla::workloads::{all_networks, training_graph, LayerKind};

/// Pick a representative multi-layer segment: the first span of 3
/// consecutive weighted layers in the training graph (falls back to 2).
fn representative_span(net: &kapla::workloads::Network) -> Vec<usize> {
    let weighted: Vec<usize> = net
        .layers
        .iter()
        .enumerate()
        .filter(|(_, l)| l.kind != LayerKind::Eltwise)
        .map(|(i, _)| i)
        .collect();
    for w in weighted.windows(3) {
        if w[2] - w[0] == 2 {
            return w.to_vec();
        }
    }
    vec![0, 1]
}

fn main() {
    let arch = presets::multi_node_eyeriss(); // full scale, like the paper
    let batch = bk::bench_batch();

    let mut t = Table::new(
        "Table VI — inter-layer conservative pruning (one representative segment per NN)",
        &["network", "segment", "total schemes", "after validity", "after Pareto", "% pruned"],
    );
    for fwd in all_networks() {
        let net = training_graph(&fwd);
        let span = representative_span(&net);
        let cands = enumerate_segment_schemes(&net, &arch, batch, &span, 64);
        let total = cands.len();
        let (_, stats) = prune_and_rank(&arch, &net, batch, cands, &TieredCost::fresh());
        let seg_name: Vec<&str> = span.iter().map(|&i| net.layers[i].name.as_str()).collect();
        t.row(vec![
            fwd.name.clone(),
            seg_name.join("+"),
            total.to_string(),
            stats.after_validity.to_string(),
            stats.after_pareto.to_string(),
            format!("{:.1}%", 100.0 * (1.0 - stats.after_pareto as f64 / total.max(1) as f64)),
        ]);
    }
    let out = t.save_and_render("table6_pruning");
    println!("{out}");
    bk::log_section("table6_pruning", &out);
    println!("paper shape: 85.7%..99.8% of candidate inter-layer schemes pruned per segment.");

    // Companion table: *intra-layer* subtree pruning — the staged
    // branch-and-bound enumeration behind the exhaustive baselines (B/S).
    // Reported at the scaled bench config (the full per-layer scans are
    // what the admissible bound makes tractable in the first place).
    use kapla::cost::TieredCost as Tiered;
    use kapla::solvers::exhaustive::ExhaustiveIntra;
    use kapla::solvers::space::BnbCounters;
    use kapla::solvers::{IntraCtx, IntraSolver as _, Objective};

    let barch = kapla::arch::presets::bench_multi_node();
    let mut bt = Table::new(
        "Table VI-b — intra-layer branch-and-bound pruning (staged exhaustive scan, S)",
        &[
            "layer",
            "prefixes visited",
            "prefixes pruned",
            "schemes evaluated",
            "schemes skipped",
            "prune rate",
            "bound tightness",
        ],
    );
    let anet = kapla::workloads::nets::alexnet();
    let mnet = kapla::workloads::nets::mlp();
    let mlp_name = format!("mlp/{}", mnet.layers[0].name);
    for (name, layer) in [("alexnet/conv2", &anet.layers[2]), (mlp_name.as_str(), &mnet.layers[0])] {
        let ctx = IntraCtx { region: (2, 2), rb: 4, ifm_on_chip: false, objective: Objective::Energy };
        let counters = BnbCounters::new();
        let solver =
            ExhaustiveIntra { with_sharing: true, stats: Some(&counters), ..Default::default() };
        let s = solver.solve(&barch, layer, &ctx, &Tiered::fresh()).expect("solvable layer");
        std::hint::black_box(s);
        let st = counters.snapshot();
        bt.row(vec![
            name.to_string(),
            st.prefixes_visited.to_string(),
            st.prefixes_pruned.to_string(),
            st.schemes_visited.to_string(),
            st.schemes_skipped.to_string(),
            format!("{:.1}%", 100.0 * st.prune_rate()),
            format!("{:.2}", st.avg_bound_tightness()),
        ]);
    }
    let bout = bt.save_and_render("table6_bnb_pruning");
    println!("{bout}");
    bk::log_section("table6_bnb_pruning", &bout);
}
