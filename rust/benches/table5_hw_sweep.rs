//! Paper Table V: energy overhead of KAPLA's schedules vs the exhaustive
//! optimum across hardware configurations (node mesh, PE array, REGF size,
//! batch). The paper sweeps GoogLeNet; the default here is AlexNet so the
//! exhaustive reference completes at CI scale (KAPLA_NETS=googlenet for
//! the paper workload).
//!
//! Run: `cargo bench --bench table5_hw_sweep`

use kapla::arch::presets::table5_configs;
use kapla::coordinator::SolverKind;
use kapla::report::benchkit as bk;
use kapla::report::Table;
use kapla::solvers::Objective;
use kapla::util::stats::fmt_duration;

fn main() {
    let nets = bk::bench_nets(&["alexnet"]);
    let net = &nets[0];

    let mut t = Table::new(
        &format!("Table V — KAPLA energy overhead vs B across HW configs ({})", net.name),
        &["batch", "nodes", "PEs", "GBUF", "REGF", "overhead", "K solve"],
    );
    for (batch, arch) in table5_configs() {
        eprintln!(
            "[table5] batch={batch} nodes={}x{} pes={}x{} regf={}B ...",
            arch.nodes.0, arch.nodes.1, arch.pes.0, arch.pes.1, arch.regf.bytes
        );
        let b = bk::run_cell(&arch, net, batch, Objective::Energy, SolverKind::Baseline);
        let k = bk::run_cell(&arch, net, batch, Objective::Energy, SolverKind::Kapla);
        let overhead = k.eval.energy.total() / b.eval.energy.total() - 1.0;
        t.row(vec![
            batch.to_string(),
            format!("{}x{}", arch.nodes.0, arch.nodes.1),
            format!("{}x{}", arch.pes.0, arch.pes.1),
            format!("{} kB", arch.gbuf.bytes / 1024),
            format!("{} B", arch.regf.bytes),
            format!("{:+.1}%", overhead * 100.0),
            fmt_duration(k.solve_s),
        ]);
    }
    let out = t.save_and_render("table5_hw_sweep");
    println!("{out}");
    bk::log_section("table5_hw_sweep", &out);
    println!("paper shape: overheads stay small (1.5%..8.3%) across all configs — robustness.");
}
