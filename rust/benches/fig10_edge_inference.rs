//! Paper Fig. 10: inference energy on the single-node TPU-like edge device
//! (batch 1), all five solvers normalized to B. The paper notes the random
//! baseline needs p = 0.85 to find valid schemes under the rigid 256 kB
//! buffer constraints — a generality failure of hyperparameter-driven
//! methods; we use the same setting.
//!
//! Run: `cargo bench --bench fig10_edge_inference`

use kapla::arch::presets;
use kapla::report::benchkit as bk;
use kapla::report::Table;
use kapla::solvers::Objective;
use kapla::util::stats::{fmt_duration, geomean};

fn main() {
    let arch = presets::edge_tpu(); // fixed: the paper's edge config
    let batch = 1;
    let nets = bk::bench_nets(&["alexnet", "mobilenet", "mlp", "lstm"]);
    let solvers = bk::paper_solvers(0.85); // paper: p must be 0.85 here

    let mut t = Table::new(
        "Fig.10 — edge inference energy normalized to B (batch 1, TPU-like 16x16 systolic)",
        &["network", "B", "S", "R", "M", "K", "K solve"],
    );
    let mut per_solver: Vec<Vec<f64>> = vec![Vec::new(); solvers.len()];
    for net in &nets {
        eprintln!("[fig10] {} ({} layers)...", net.name, net.len());
        let results: Vec<_> = solvers
            .iter()
            .map(|&s| bk::run_cell(&arch, net, batch, Objective::Energy, s))
            .collect();
        let base = results[0].eval.energy.total();
        let mut row = vec![net.name.clone()];
        for (i, r) in results.iter().enumerate() {
            let norm = r.eval.energy.total() / base;
            per_solver[i].push(norm);
            row.push(format!("{norm:.3}"));
        }
        row.push(fmt_duration(results[4].solve_s));
        t.row(row);
    }
    let mut gm = vec!["geomean".to_string()];
    for s in &per_solver {
        gm.push(format!("{:.3}", geomean(s)));
    }
    gm.push(String::new());
    t.row(gm);

    let out = t.save_and_render("fig10_edge_inference");
    println!("{out}");
    bk::log_section("fig10_edge_inference", &out);
    println!(
        "paper shape: small design space, all methods near-optimal; K ~1.9% avg (worst 10%),\n\
         R ~3.8% only with p=0.85, M up to 16%."
    );
}
