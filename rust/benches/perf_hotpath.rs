//! Hot-path micro-benchmarks backing EXPERIMENTS.md §Perf: throughput of
//! the solver inner loops at each layer of the stack.
//!
//!   L3a  directive access-count calculus (the innermost arithmetic)
//!   L3b  KAPLA bottom-up intra-layer solve (per layer-context), then the
//!        batched context sweep: sequential/uncached vs the scoped worker
//!        pool sharing one CostCache (identical results, measured speedup)
//!   L3c  exhaustive enumeration rate (schemes/s) — baseline B's inner
//!        loop — cold vs warm through the evaluation memo
//!   L3d  inter-layer DP (per network)
//!   L4   warm scheduling sessions: a sweep of near-identical jobs against
//!        one shared (optionally budgeted) cost::SessionCache — the
//!        cross-job reuse the coordinator's session subsystem provides
//!   L1   AOT batched cost kernel via PJRT vs native Rust loop
//!        (the batch-size amortization curve; PJRT needs `--features pjrt`)
//!
//! Run: `cargo bench --bench perf_hotpath`

use kapla::arch::presets;
use kapla::cost::{cost_from_features, features, CostCache, LayerCtx, TieredCost};
use kapla::directives::{Grp, LevelBlock, LoopOrder, Qty};
use kapla::interlayer::dp::{best_chains, DpConfig};
use kapla::mapping::UnitMap;
use kapla::partition::PartitionScheme;
use kapla::report::benchkit as bk;
use kapla::solvers::kapla::{solve_intra, solve_intra_cached};
use kapla::solvers::space::{
    visit_schemes, visit_schemes_staged, BnbCounters, PartOrder, StagedQuery,
};
use kapla::solvers::{IntraCtx, Objective};
use kapla::util::json::Json;
use kapla::util::{available_threads, par_map, Timer};
use kapla::workloads::{nets, Layer};

fn main() {
    let arch = presets::multi_node_eyeriss();
    let net = nets::alexnet();
    let conv2 = &net.layers[2];
    let mut lines = Vec::new();

    // Satellite guard: the memoized divisors must be exactly the trial
    // division results (the enumeration counts below all hang off this).
    for n in [1u64, 12, 96, 256, 1024, 4095, 4096, 4097, 14336] {
        assert_eq!(
            kapla::util::divisors(n),
            kapla::util::divisors_uncached(n),
            "divisors memo diverged at {n}"
        );
    }

    // L3a: access-count calculus throughput.
    {
        let part = PartitionScheme { region: (4, 4), pk: 4, pn: 4, ..PartitionScheme::single() };
        let unit = UnitMap::build(&arch, part.node_shape(conv2, 16));
        let s = kapla::directives::LayerScheme {
            part,
            unit,
            regf: LevelBlock { qty: Qty::new(1, 2, 2), order: LoopOrder([Grp::B, Grp::K, Grp::C]) },
            gbuf: LevelBlock {
                qty: unit.align_block(Qty::new(2, 16, 16)),
                order: LoopOrder([Grp::B, Grp::C, Grp::K]),
            },
        };
        let n = 2_000_000u64;
        let t = Timer::start();
        let mut acc = 0u64;
        for _ in 0..n {
            acc = acc.wrapping_add(s.access_counts(false).dram_total());
        }
        let rate = n as f64 / t.elapsed_s();
        lines.push(format!("L3a access_counts: {:.1} M evals/s (checksum {acc})", rate / 1e6));
    }

    // L3b: KAPLA intra-layer solve.
    {
        let ctx =
            IntraCtx { region: (16, 16), rb: 64, ifm_on_chip: false, objective: Objective::Energy };
        let n = 200;
        let t = Timer::start();
        for _ in 0..n {
            let s = solve_intra(&arch, conv2, &ctx).unwrap();
            std::hint::black_box(s);
        }
        let per = t.elapsed_ms() / n as f64;
        lines.push(format!("L3b kapla solve_intra(conv2 @16x16,b64): {per:.2} ms/layer"));
    }

    // L3b-par: the batched intra-layer context sweep — the sequential
    // uncached path vs the scoped worker pool sharing one CostCache. The
    // context list mimics the DP re-solving overlapping spans: each
    // (layer, region) context recurs, as it does across top-k_S chains.
    {
        let layer_ids = [0usize, 2, 4, 5, 6]; // the alexnet convs
        let regions = [(16u64, 16u64), (8, 16)];
        let mut ctxs: Vec<(usize, IntraCtx)> = Vec::new();
        for _rep in 0..3 {
            for &li in &layer_ids {
                for &region in &regions {
                    let c = IntraCtx {
                        region,
                        rb: 16,
                        ifm_on_chip: false,
                        objective: Objective::Energy,
                    };
                    ctxs.push((li, c));
                }
            }
        }
        let t = Timer::start();
        let seq: Vec<_> =
            ctxs.iter().map(|(li, c)| solve_intra(&arch, &net.layers[*li], c)).collect();
        let t_seq = t.elapsed_s();

        let cache = CostCache::new();
        let model = TieredCost::over(&cache);
        let threads = available_threads();
        let t = Timer::start();
        let par = par_map(&ctxs, threads, |(li, c)| {
            solve_intra_cached(&arch, &net.layers[*li], c, &model)
        });
        let t_par = t.elapsed_s();
        // Determinism invariant: the parallel/cached sweep returns the
        // exact schemes of the sequential path.
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(format!("{a:?}"), format!("{b:?}"), "parallel sweep diverged");
        }
        lines.push(format!(
            "L3b parallel+cached sweep ({} ctxs, {threads} threads): {:.2} s -> {:.2} s \
             ({:.1}x, cache hit rate {:.0}%)",
            ctxs.len(),
            t_seq,
            t_par,
            t_seq / t_par.max(1e-9),
            100.0 * cache.hit_rate()
        ));
    }

    // L3c: exhaustive enumeration rate.
    {
        let t = Timer::start();
        let mut count = 0u64;
        visit_schemes(&arch, conv2, (4, 4), 16, true, |s| {
            std::hint::black_box(s);
            count += 1;
            count < 2_000_000
        });
        let rate = count as f64 / t.elapsed_s();
        lines.push(format!("L3c exhaustive enumeration: {:.2} M schemes/s ({count} visited)", rate / 1e6));
    }

    // L3c-cache: the evaluation memo on the exhaustive inner loop —
    // identical scheme stream scored cold (computing) then warm (memo).
    {
        let cache = CostCache::new();
        let run = || {
            let t = Timer::start();
            let mut n = 0u64;
            visit_schemes(&arch, conv2, (4, 4), 16, true, |s| {
                std::hint::black_box(cache.evaluate_layer(&arch, s, false));
                n += 1;
                n < 100_000
            });
            (n, t.elapsed_s())
        };
        let (n1, cold) = run();
        let (_, warm) = run();
        lines.push(format!(
            "L3c cached evaluation ({n1} schemes): cold {:.2} M evals/s, warm {:.2} M evals/s ({:.1}x)",
            n1 as f64 / cold.max(1e-9) / 1e6,
            n1 as f64 / warm.max(1e-9) / 1e6,
            cold / warm.max(1e-9)
        ));
    }

    // L3c-staged: the full evaluated argmin — baseline B's actual inner
    // loop — run naively (every candidate one-shot evaluated through the
    // memo) vs the staged branch-and-bound enumeration. Same space, and
    // the chosen optimum must be byte-identical: the checksums gate the CI
    // bench smoke against any staged/naive divergence.
    {
        let layer = Layer::conv("bench_l3c", 32, 64, 28, 3, 1);
        let ctx =
            IntraCtx { region: (2, 2), rb: 4, ifm_on_chip: false, objective: Objective::Energy };

        let cache = CostCache::new();
        let t = Timer::start();
        let mut naive_best: Option<(f64, String)> = None;
        let mut naive_n = 0u64;
        visit_schemes(&arch, &layer, ctx.region, ctx.rb, true, |s| {
            let e = cache.evaluate_layer(&arch, s, ctx.ifm_on_chip).energy.total();
            if naive_best.as_ref().map(|(b, _)| e < *b).unwrap_or(true) {
                naive_best = Some((e, format!("{s:?}")));
            }
            naive_n += 1;
            true
        });
        let t_naive = t.elapsed_s();
        let (naive_cost, naive_scheme) = naive_best.expect("non-empty space");

        let model = TieredCost::fresh();
        let counters = BnbCounters::new();
        let q = StagedQuery::for_ctx(&arch, &layer, &ctx, true, &model).counters(&counters);
        let t = Timer::start();
        let mut staged_best: Option<(f64, String)> = None;
        visit_schemes_staged(&q, |s, est| {
            let c = est.energy_pj;
            if staged_best.as_ref().map(|(b, _)| c < *b).unwrap_or(true) {
                staged_best = Some((c, format!("{s:?}")));
            }
            Some(staged_best.as_ref().map(|(b, _)| *b).unwrap_or(f64::INFINITY))
        });
        let t_staged = t.elapsed_s();
        let (staged_cost, staged_scheme) = staged_best.expect("non-empty space");

        // The CI divergence gate: byte-identical optimum, or the bench
        // (and the smoke step running it) fails.
        let naive_checksum = kapla::util::fnv1a(
            naive_scheme.bytes().map(u64::from).chain([naive_cost.to_bits()]),
        );
        let staged_checksum = kapla::util::fnv1a(
            staged_scheme.bytes().map(u64::from).chain([staged_cost.to_bits()]),
        );
        assert_eq!(
            naive_checksum, staged_checksum,
            "staged search diverged from the naive scan: {naive_cost} ({naive_scheme}) vs \
             {staged_cost} ({staged_scheme})"
        );

        let st = counters.snapshot();
        // Effective rate: the staged search covers the same `naive_n`
        // candidates (visited + proven-unimprovable) in `t_staged`.
        let naive_rate = naive_n as f64 / t_naive.max(1e-9);
        let staged_rate = naive_n as f64 / t_staged.max(1e-9);
        lines.push(format!(
            "L3c naive evaluated argmin: {naive_n} schemes in {t_naive:.2} s \
             ({:.2} M schemes/s, checksum {naive_checksum:x})",
            naive_rate / 1e6
        ));
        lines.push(format!(
            "L3c staged+B&B evaluated argmin: {} evaluated / {} skipped in {t_staged:.2} s \
             ({:.2} M effective schemes/s, {:.1}x naive, prune rate {:.0}%, bound tightness {:.2}, \
             checksum {staged_checksum:x})",
            st.schemes_visited,
            st.schemes_skipped,
            staged_rate / 1e6,
            staged_rate / naive_rate.max(1e-9),
            100.0 * st.prune_rate(),
            st.avg_bound_tightness()
        ));

        let mut row = Json::obj();
        row.set("layer", "conv 32x64x28 r3 @(2,2) rb4 sharing".into())
            .set("naive_schemes", naive_n.into())
            .set("naive_s", t_naive.into())
            .set("naive_schemes_per_s", naive_rate.into())
            .set("staged_s", t_staged.into())
            .set("staged_effective_schemes_per_s", staged_rate.into())
            .set("speedup", (staged_rate / naive_rate.max(1e-9)).into())
            .set("best_energy_pj", staged_cost.into())
            .set("checksum", format!("{staged_checksum:x}").into())
            .set("bnb", st.to_json());
        bk::save_json("perf_hotpath_l3c", &row);
    }

    // L3c-part: the partition-level admissible floor — the same staged
    // B&B argmin with the partition check on vs off. The floor equals the
    // prefix bound at gq == totals, so the visited stream and the argmin
    // are provably unchanged; the saving is skipping capacity probes,
    // blocking enumeration and per-prefix bound evaluations of partitions
    // no blocking of which can beat the incumbent. The checksum equality
    // is a CI divergence gate like the L3c staged/naive one.
    {
        let layer = Layer::conv("bench_l3cp", 64, 64, 28, 3, 1);
        let ctx =
            IntraCtx { region: (2, 2), rb: 8, ifm_on_chip: false, objective: Objective::Energy };
        let model = TieredCost::fresh();
        let run = |part_floor: bool| {
            let counters = BnbCounters::new();
            let q = StagedQuery::for_ctx(&arch, &layer, &ctx, true, &model)
                .counters(&counters)
                .part_floor(part_floor);
            let t = Timer::start();
            let mut best: Option<(f64, String)> = None;
            visit_schemes_staged(&q, |s, est| {
                let c = est.energy_pj;
                if best.as_ref().map(|(b, _)| c < *b).unwrap_or(true) {
                    best = Some((c, format!("{s:?}")));
                }
                Some(best.as_ref().map(|(b, _)| *b).unwrap_or(f64::INFINITY))
            });
            let secs = t.elapsed_s();
            let (cost, scheme) = best.expect("non-empty space");
            let checksum =
                kapla::util::fnv1a(scheme.bytes().map(u64::from).chain([cost.to_bits()]));
            let mut st = counters.snapshot();
            st.part_floor = part_floor;
            (secs, checksum, st)
        };
        let (t_on, sum_on, st_on) = run(true);
        let (t_off, sum_off, st_off) = run(false);
        assert_eq!(
            sum_on, sum_off,
            "partition floor changed the argmin: {sum_on:x} vs {sum_off:x}"
        );
        assert!(st_on.parts_pruned > 0, "partition floor never fired: {st_on:?}");
        assert_eq!(st_off.parts_pruned, 0, "disabled floor still pruned: {st_off:?}");
        lines.push(format!(
            "L3c partition floor on/off: {:.2} s -> {:.2} s ({:.1}x; {} of {} partitions \
             pruned, checksum {sum_on:x})",
            t_off,
            t_on,
            t_off / t_on.max(1e-9),
            st_on.parts_pruned,
            st_on.parts_visited + st_on.parts_pruned,
        ));
        let mut row = Json::obj();
        row.set("layer", "conv 64x64x28 r3 @(2,2) rb8 sharing".into())
            .set("floor_on_s", t_on.into())
            .set("floor_off_s", t_off.into())
            .set("speedup", (t_off / t_on.max(1e-9)).into())
            .set("checksum", format!("{sum_on:x}").into())
            .set("bnb_on", st_on.to_json())
            .set("bnb_off", st_off.to_json());
        bk::save_json("perf_hotpath_l3c_part", &row);
    }

    // L3c-ord: partition visit ordering — ascending admissible floor vs
    // raw enumeration order. Sorting is a heuristic on top of the exact
    // search: the argmin *value* is invariant (gated below), but the
    // first-minimum identity may move between cost ties, so the gate is on
    // cost, not scheme bytes. Visiting cheap-floor partitions first
    // tightens the incumbent sooner, so later partitions prune harder.
    {
        let layer = Layer::conv("bench_l3co", 64, 64, 28, 3, 1);
        let ctx =
            IntraCtx { region: (2, 2), rb: 8, ifm_on_chip: false, objective: Objective::Energy };
        let model = TieredCost::fresh();
        let run = |order: PartOrder| {
            let counters = BnbCounters::new();
            let q = StagedQuery::for_ctx(&arch, &layer, &ctx, true, &model)
                .counters(&counters)
                .part_floor(true)
                .part_order(order);
            let t = Timer::start();
            let mut best = f64::INFINITY;
            visit_schemes_staged(&q, |_, est| {
                if est.energy_pj < best {
                    best = est.energy_pj;
                }
                Some(best)
            });
            (t.elapsed_s(), best, counters.snapshot())
        };
        let (t_floor, best_floor, st_floor) = run(PartOrder::Floor);
        let (t_enum, best_enum, st_enum) = run(PartOrder::Enum);
        assert_eq!(
            best_floor.to_bits(),
            best_enum.to_bits(),
            "partition ordering changed the argmin value: {best_floor} vs {best_enum}"
        );
        lines.push(format!(
            "L3c partition order enum -> floor: {:.2} s -> {:.2} s ({:.2}x; partitions pruned \
             {} -> {}, schemes skipped {} -> {})",
            t_enum,
            t_floor,
            t_enum / t_floor.max(1e-9),
            st_enum.parts_pruned,
            st_floor.parts_pruned,
            st_enum.schemes_skipped,
            st_floor.schemes_skipped,
        ));
        let mut row = Json::obj();
        row.set("layer", "conv 64x64x28 r3 @(2,2) rb8 sharing".into())
            .set("enum_s", t_enum.into())
            .set("floor_s", t_floor.into())
            .set("speedup", (t_enum / t_floor.max(1e-9)).into())
            .set("best_energy_pj", best_floor.into())
            .set("bnb_floor_order", st_floor.to_json())
            .set("bnb_enum_order", st_enum.to_json());
        bk::save_json("perf_hotpath_l3c_order", &row);
    }

    // L3d: inter-layer DP (estimate tier of the cost model only).
    {
        let cfg = DpConfig::default();
        let model = TieredCost::fresh();
        let t = Timer::start();
        let n = 20;
        for _ in 0..n {
            let (c, _) = best_chains(&arch, &net, 64, &cfg, &model).expect("chains");
            std::hint::black_box(c);
        }
        lines.push(format!("L3d inter-layer DP (alexnet, 16x16): {:.1} ms/net", t.elapsed_ms() / n as f64));
    }

    // L3d-spec: the speculative span pipeline — the sequential planner
    // (1 thread, tables built inline at stream time) vs the speculative
    // one (4 threads: main thread streams against the live incumbent,
    // workers prebuild the tables of the next `spec_window` spans).
    // Chains and counters must be byte-identical; only wall-clock moves.
    {
        let model = TieredCost::fresh();
        let reps = 10u32;
        let run = |threads: usize| {
            let cfg = DpConfig { solve_threads: threads, ..DpConfig::default() };
            let t = Timer::start();
            let mut last = None;
            for _ in 0..reps {
                last = Some(best_chains(&arch, &net, 64, &cfg, &model).expect("chains"));
            }
            (t.elapsed_s() / reps as f64, last.unwrap())
        };
        let (t_seq, (seq_chains, seq_stats)) = run(1);
        let (t_spec, (spec_chains, spec_stats)) = run(4);
        assert_eq!(
            format!("{seq_chains:?}"),
            format!("{spec_chains:?}"),
            "speculative planner changed the chains"
        );
        assert_eq!(
            format!("{seq_stats:?}"),
            format!("{spec_stats:?}"),
            "speculative planner changed the prune counters"
        );
        lines.push(format!(
            "L3d speculative planner (alexnet, 1 -> 4 threads, window {}): \
             {:.1} -> {:.1} ms/net ({:.2}x; {} tables, {} of {} spans pruned)",
            DpConfig::default().spec_window,
            t_seq * 1e3,
            t_spec * 1e3,
            t_seq / t_spec.max(1e-9),
            seq_stats.tables_built,
            seq_stats.spans_pruned,
            seq_stats.spans_total,
        ));
        let mut row = Json::obj();
        row.set("net", "alexnet".into())
            .set("batch", 64u64.into())
            .set("spec_window", DpConfig::default().spec_window.into())
            .set("sequential_ms", (t_seq * 1e3).into())
            .set("speculative_ms_4t", (t_spec * 1e3).into())
            .set("speedup", (t_seq / t_spec.max(1e-9)).into())
            .set("prune", seq_stats.to_json());
        bk::save_json("perf_hotpath_l3d_spec", &row);
    }

    // L3d2: the lazy inter-layer span machinery — the iterative
    // composition generator (one reused buffer) and the scratch-segment
    // scheme streaming vs the eager materialized Vec<Segment>. The counts
    // double as correctness micro-asserts: C(15,3) compositions of a
    // 16-wide mesh into 4 strips, and stream == eager candidate counts.
    {
        use kapla::interlayer::{enumerate_segment_schemes, visit_segment_schemes, Compositions};
        let reps = 2000u64;
        let t = Timer::start();
        let mut comps = 0u64;
        for _ in 0..reps {
            let mut comp_gen = Compositions::new(16, 4);
            while let Some(ws) = comp_gen.next_slice() {
                std::hint::black_box(ws);
                comps += 1;
            }
        }
        let comp_rate = comps as f64 / t.elapsed_s();
        assert_eq!(comps, 455 * reps, "C(15,3) compositions expected");

        let span = [2usize, 3, 4];
        let t = Timer::start();
        let mut streamed = 0u64;
        for _ in 0..200 {
            visit_segment_schemes(&net, &arch, 64, &span, 64, |s| {
                std::hint::black_box(s.rounds);
                streamed += 1;
                true
            });
        }
        let t_stream = t.elapsed_s();
        let t = Timer::start();
        let mut eager = 0u64;
        for _ in 0..200 {
            eager += enumerate_segment_schemes(&net, &arch, 64, &span, 64).len() as u64;
        }
        let t_eager = t.elapsed_s();
        assert_eq!(streamed, eager, "lazy stream diverged from eager enumeration");
        lines.push(format!(
            "L3d2 span streaming: compositions {:.1} M/s; {} schemes/span streamed \
             {:.2} M/s vs eager {:.2} M/s ({:.1}x)",
            comp_rate / 1e6,
            streamed / 200,
            streamed as f64 / t_stream.max(1e-9) / 1e6,
            eager as f64 / t_eager.max(1e-9) / 1e6,
            t_eager / t_stream.max(1e-9)
        ));
    }

    // L4: warm scheduling sessions — cross-job evaluation reuse. A sweep
    // of near-identical jobs (NAS/service-style traffic) solved against
    // one shared SessionCache: the first job warms the memo, the rest
    // answer their detailed-model evaluations from it. Schedules must stay
    // byte-identical to the cold (private-cache) runs.
    {
        use kapla::coordinator::{run_job, run_jobs_with, Job, SolverKind};
        use kapla::cost::{CacheBudget, EvalCache as _, SessionCache};
        use kapla::util::json::Json;

        let sarch = presets::bench_multi_node();
        let jobs: Vec<Job> = (0..4)
            .map(|i| Job {
                net: nets::mlp(),
                batch: 16,
                objective: if i % 2 == 0 { Objective::Energy } else { Objective::Latency },
                solver: SolverKind::Kapla,
                dp: DpConfig { max_rounds: 8, solve_threads: 1, ..DpConfig::default() },
                deadline_ms: None,
            })
            .collect();

        let t = Timer::start();
        let cold: Vec<_> = jobs.iter().map(|j| run_job(&sarch, j).expect("cold solve")).collect();
        let t_cold = t.elapsed_s();

        let session = SessionCache::unbounded();
        let t = Timer::start();
        let warm: Vec<_> = run_jobs_with(&sarch, &jobs, 1, &session)
            .into_iter()
            .map(|r| r.expect("warm solve"))
            .collect();
        let t_warm = t.elapsed_s();
        for (a, b) in cold.iter().zip(&warm) {
            assert_eq!(
                format!("{:?}", a.schedule),
                format!("{:?}", b.schedule),
                "shared session diverged from cold runs"
            );
        }
        let st = session.stats();
        lines.push(format!(
            "L4a warm session ({} jobs): cold {:.2} s -> shared {:.2} s \
             ({:.1}x, hit rate {:.0}%, {} entries, intra-argmin {}/{} replays)",
            jobs.len(),
            t_cold,
            t_warm,
            t_cold / t_warm.max(1e-9),
            100.0 * st.hit_rate(),
            st.entries,
            st.intra_hits,
            st.intra_lookups
        ));

        // A tiny budget forces clock-eviction churn; schedules must not
        // change (purity makes eviction a perf knob, never a results one).
        let bounded = SessionCache::new(CacheBudget::entries(256));
        let t = Timer::start();
        let bres: Vec<_> = run_jobs_with(&sarch, &jobs, 1, &bounded)
            .into_iter()
            .map(|r| r.expect("bounded solve"))
            .collect();
        let t_bounded = t.elapsed_s();
        for (a, b) in cold.iter().zip(&bres) {
            assert_eq!(
                format!("{:?}", a.schedule),
                format!("{:?}", b.schedule),
                "bounded session diverged from cold runs"
            );
        }
        let bst = bounded.stats();
        lines.push(format!(
            "L4b bounded session (256 entries): {:.2} s, hit rate {:.0}%, {} evictions",
            t_bounded,
            100.0 * bst.hit_rate(),
            bst.evictions
        ));

        let rows: Vec<Json> = jobs
            .iter()
            .zip(&warm)
            .map(|(j, r)| bk::result_json(&j.net.name, j.solver, r))
            .collect();
        bk::save_json("perf_hotpath_session", &Json::Arr(rows));
    }

    // L4c: eviction policy — the sharded clock vs the protected-segment
    // (segmented-LRU) variant under a NAS-style sweep: repeated
    // near-identical jobs whose working set exceeds the entry budget.
    // Scan-heavy solver traffic touches most entries exactly once, so the
    // protected segment only pays off if re-referenced entries dominate;
    // clock stays the default unless this row shows an SLRU win. Purity
    // gate: schedules must be byte-identical under either policy.
    {
        use kapla::coordinator::{run_jobs_with, Job, SolverKind};
        use kapla::cost::{CacheBudget, CacheStats, EvalCache as _, EvictPolicy, SessionCache};

        let sarch = presets::bench_multi_node();
        let mut jobs: Vec<Job> = Vec::new();
        for _rep in 0..2 {
            for batch in [4u64, 8, 16] {
                for objective in [Objective::Energy, Objective::Latency] {
                    jobs.push(Job {
                        net: nets::mlp(),
                        batch,
                        objective,
                        solver: SolverKind::Kapla,
                        dp: DpConfig { max_rounds: 8, solve_threads: 1, ..DpConfig::default() },
                        deadline_ms: None,
                    });
                }
            }
        }
        let run = |policy: EvictPolicy| {
            let cache = SessionCache::with_policy(CacheBudget::entries(512), policy);
            let t = Timer::start();
            let rs: Vec<_> = run_jobs_with(&sarch, &jobs, 1, &cache)
                .into_iter()
                .map(|r| r.expect("sweep solve"))
                .collect();
            (t.elapsed_s(), rs, cache.stats())
        };
        let (t_clock, r_clock, st_clock) = run(EvictPolicy::Clock);
        let (t_slru, r_slru, st_slru) = run(EvictPolicy::SegmentedLru);
        for (a, b) in r_clock.iter().zip(&r_slru) {
            assert_eq!(
                format!("{:?}", a.schedule),
                format!("{:?}", b.schedule),
                "eviction policy changed a schedule"
            );
        }
        lines.push(format!(
            "L4c eviction policy (NAS sweep, {} jobs, 512 entries): clock hit rate {:.1}% \
             ({} evictions, {:.2} s) vs slru {:.1}% ({} evictions, {:.2} s)",
            jobs.len(),
            100.0 * st_clock.hit_rate(),
            st_clock.evictions,
            t_clock,
            100.0 * st_slru.hit_rate(),
            st_slru.evictions,
            t_slru,
        ));
        let policy_row = |name: &str, t: f64, st: &CacheStats| {
            let mut r = Json::obj();
            r.set("policy", name.into())
                .set("seconds", t.into())
                .set("hit_rate", st.hit_rate().into())
                .set("lookups", st.lookups.into())
                .set("hits", st.hits.into())
                .set("evictions", st.evictions.into());
            r
        };
        bk::save_json(
            "perf_hotpath_l4_evict",
            &Json::Arr(vec![
                policy_row("clock", t_clock, &st_clock),
                policy_row("slru", t_slru, &st_slru),
            ]),
        );
    }

    // L5: concurrent service connections — end-to-end request throughput
    // of the network front end. C clients stream warm requests at an
    // in-process TCP service sharing one tenant session; the row compares
    // single-connection against fan-out to show the bounded worker pool
    // multiplexing (solves are pure per session, so concurrency changes
    // throughput, never responses).
    {
        use kapla::coordinator::transport::{self, ServiceConfig};
        use std::io::{BufRead, BufReader, Write};
        use std::net::TcpStream;

        let sarch = presets::bench_multi_node();
        let reqs_per_conn = 8usize;
        let mut l5_rows: Vec<Json> = Vec::new();
        for conns in [1usize, 4] {
            let cfg = ServiceConfig {
                queue_depth: 64,
                workers: available_threads(),
                ..Default::default()
            };
            let handle = transport::spawn(&sarch, cfg, "127.0.0.1:0").expect("bind service");
            let addr = handle.tcp_addr().expect("tcp addr");
            let ids: Vec<usize> = (0..conns).collect();
            let t = Timer::start();
            let served: Vec<usize> = par_map(&ids, conns, |_| {
                let conn = TcpStream::connect(addr).expect("connect");
                let mut writer = conn.try_clone().expect("clone");
                let mut reader = BufReader::new(conn);
                let mut ok = 0usize;
                for _ in 0..reqs_per_conn {
                    writer
                        .write_all(
                            b"schedule mlp 8 kapla threads=1 max_rounds=8 tenant=bench\n",
                        )
                        .expect("send");
                    let mut resp = String::new();
                    reader.read_line(&mut resp).expect("recv");
                    assert!(resp.contains("\"ok\":true"), "service error: {resp}");
                    ok += 1;
                }
                ok
            });
            let total: usize = served.iter().sum();
            let secs = t.elapsed_s();
            handle.shutdown();
            lines.push(format!(
                "L5 service transport {conns} conns x {reqs_per_conn} reqs: \
                 {:.1} req/s ({:.2} s end-to-end)",
                total as f64 / secs.max(1e-9),
                secs
            ));
            let mut row = Json::obj();
            row.set("conns", conns.into())
                .set("reqs_per_conn", reqs_per_conn.into())
                .set("requests", total.into())
                .set("seconds", secs.into())
                .set("req_per_s", (total as f64 / secs.max(1e-9)).into());
            l5_rows.push(row);
        }
        bk::save_json("perf_hotpath_transport", &Json::Arr(l5_rows));
    }

    // L1: PJRT batched cost kernel vs native formula.
    {
        let ctx = LayerCtx {
            nodes: 64,
            round_batch: 8,
            rounds: 4,
            ifm_on_chip: false,
            ofm_on_chip: false,
            dram_hops: 2.0,
        };
        let feats: Vec<_> = (0..4096).map(|_| features(&arch, conv2, &ctx)).collect();
        let t = Timer::start();
        let reps = 100;
        for _ in 0..reps {
            for f in &feats {
                std::hint::black_box(cost_from_features(&arch, f));
            }
        }
        let native_rate = (reps * feats.len()) as f64 / t.elapsed_s();
        lines.push(format!("L1 native cost formula: {:.1} M evals/s", native_rate / 1e6));

        #[cfg(feature = "pjrt")]
        {
            if kapla::runtime::artifacts_available() {
                let rt = kapla::runtime::Runtime::cpu().expect("pjrt client");
                let eval = rt.cost_evaluator().expect("cost artifact");
                let params = kapla::runtime::cost_params(&arch);
                for chunk in [256usize, 1024, 4096] {
                    let t = Timer::start();
                    let out = eval.eval(&feats[..chunk], params).unwrap();
                    std::hint::black_box(out);
                    let per_call = t.elapsed_ms();
                    let rate = chunk as f64 / t.elapsed_s();
                    lines.push(format!(
                        "L1 PJRT cost kernel batch={chunk}: {per_call:.2} ms/call, {:.2} M evals/s",
                        rate / 1e6
                    ));
                }
            } else {
                lines.push("L1 PJRT cost kernel: skipped (run `make artifacts`)".into());
            }
        }
        #[cfg(not(feature = "pjrt"))]
        {
            lines.push(
                "L1 PJRT cost kernel: skipped (build with --features pjrt + vendored xla)".into(),
            );
        }
    }

    let body = lines.join("\n");
    println!("{body}");
    bk::log_section("perf_hotpath", &body);
}
