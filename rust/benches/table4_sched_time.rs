//! Paper Table IV: scheduling (wall-clock) time per solver per network for
//! NN training on multi-node accelerators. The paper measured an Intel
//! Xeon Gold 5120 with 8 parallel processes; absolute times differ here,
//! the claim is the *ratios*: K is orders of magnitude faster than B/S/M
//! and faster than R while matching B's quality.
//!
//! Run: `cargo bench --bench table4_sched_time`

use kapla::report::benchkit as bk;
use kapla::report::Table;
use kapla::solvers::Objective;
use kapla::util::json::Json;
use kapla::util::stats::fmt_duration;
use kapla::workloads::training_graph;

fn main() {
    let arch = bk::bench_arch();
    let batch = bk::bench_batch();
    let nets = bk::bench_nets(&["alexnet", "mlp"]);
    let solvers = bk::paper_solvers(0.1);

    let mut t = Table::new(
        &format!("Table IV — scheduling time, training (batch {batch}, {})", arch.name),
        &["network", "B", "S", "R", "M", "K", "B/K speedup"],
    );
    let mut speedups = Vec::new();
    let mut json_rows: Vec<Json> = Vec::new();
    for fwd in &nets {
        let net = training_graph(fwd);
        eprintln!("[table4] {} ({} layers)...", net.name, net.len());
        let mut row = vec![fwd.name.clone()];
        let mut times = Vec::new();
        for &s in &solvers {
            let r = bk::run_cell(&arch, &net, batch, Objective::Energy, s);
            times.push(r.solve_s);
            row.push(fmt_duration(r.solve_s));
            // Planner rows for the K cells: spans visited/skipped and the
            // session memo hit rate ride into the uploaded bench JSON via
            // `result_json`'s prune/cache objects.
            if let Some(p) = &r.prune {
                eprintln!(
                    "[table4] {} K planner: {}/{} spans pruned, {} schemes bound-pruned, \
                     intra-memo {}/{} hits",
                    net.name,
                    p.spans_pruned,
                    p.spans_total,
                    p.schemes_bound_pruned,
                    r.cache.intra_hits,
                    r.cache.intra_lookups
                );
            }
            json_rows.push(bk::result_json(&net.name, s, &r));
        }
        let speedup = times[0] / times[4].max(1e-9);
        speedups.push(speedup);
        row.push(format!("{speedup:.0}x"));
        t.row(row);
    }
    bk::save_json("table4_sched_time", &Json::Arr(json_rows));
    let out = t.save_and_render("table4_sched_time");
    println!("{out}");
    bk::log_section("table4_sched_time", &out);
    println!(
        "geomean B/K speedup: {:.0}x (paper: 518x avg at 16x16-node scale — the gap grows\n\
         with the mesh because B's space explodes while K's pruning holds)",
        kapla::util::stats::geomean(&speedups)
    );
}
