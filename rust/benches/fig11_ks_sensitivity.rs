//! Paper Fig. 11: impact of the segment-candidate count k_S on the
//! energy-overhead / scheduling-time tradeoff. The paper finds overheads
//! barely grow as k_S shrinks (cost-estimation errors are small) while
//! search speed improves substantially; default k_S = 4.
//!
//! Run: `cargo bench --bench fig11_ks_sensitivity`

use kapla::coordinator::SolverKind;
use kapla::interlayer::dp::DpConfig;
use kapla::report::benchkit as bk;
use kapla::report::Table;
use kapla::solvers::{Objective, SolveCtx};
use kapla::util::stats::fmt_duration;
use kapla::workloads::training_graph;

fn main() {
    let arch = bk::bench_arch();
    let batch = bk::bench_batch();
    let nets = bk::bench_nets(&["alexnet", "mlp"]);

    let mut t = Table::new(
        &format!("Fig.11 — k_S sensitivity (training, batch {batch}, {})", arch.name),
        &["network", "k_S", "energy vs B", "solve time"],
    );
    for fwd in &nets {
        let net = training_graph(fwd);
        eprintln!("[fig11] reference B for {}...", net.name);
        let b = bk::run_cell(&arch, &net, batch, Objective::Energy, SolverKind::Baseline);
        let be = b.eval.energy.total();
        for ks in [1usize, 2, 4, 8] {
            let dp = DpConfig { ks, ..bk::bench_dp() };
            let r = SolveCtx::new(&arch)
                .dp(dp)
                .run(&net, batch, SolverKind::Kapla)
                .expect("kapla solve");
            t.row(vec![
                fwd.name.clone(),
                ks.to_string(),
                format!("{:.3}", r.eval.energy.total() / be),
                fmt_duration(r.solve_s),
            ]);
        }
    }
    let out = t.save_and_render("fig11_ks_sensitivity");
    println!("{out}");
    bk::log_section("fig11_ks_sensitivity", &out);
    println!("paper shape: energy ~flat in k_S (estimation errors small); time grows with k_S.");
}
