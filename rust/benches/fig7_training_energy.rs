//! Paper Fig. 7: dataflow energy for *training* on multi-node accelerators
//! (batch 64), all five solvers (B S R M K), normalized to B, with the
//! per-component energy breakdown for B and K — swept under BOTH PE-array
//! mapping templates (row-stationary and systolic) over full training
//! graphs (fwd + dX + dW + wu).
//!
//! Run: `cargo bench --bench fig7_training_energy`
//! Scale: 4x4-node config + CI net subset by default; KAPLA_FULL=1 /
//! KAPLA_NETS=... for the paper-scale run (hours, as in the paper).

use kapla::report::benchkit as bk;
use kapla::report::{eng, Table};
use kapla::solvers::Objective;
use kapla::util::json::Json;
use kapla::util::stats::{fmt_duration, geomean};
use kapla::workloads::training_graph;

fn main() {
    let base = bk::bench_arch();
    let batch = bk::bench_batch();
    let nets = bk::bench_nets(&["alexnet", "mlp"]);
    let solvers = bk::paper_solvers(0.1);

    let mut t = Table::new(
        &format!("Fig.7 — training energy normalized to B (batch {batch}, {})", base.name),
        &["network", "array", "B", "S", "R", "M", "K", "K solve", "B solve"],
    );
    let mut per_solver: Vec<Vec<f64>> = vec![Vec::new(); solvers.len()];
    let mut rows: Vec<Json> = Vec::new();
    for fwd in &nets {
        let net = training_graph(fwd);
        // Structural pin: bd + bw + wu present, MACs conserved.
        bk::check_training_graph(fwd, &net, batch);
        for df in bk::array_mappings() {
            let arch = bk::with_mapping(&base, df);
            let mapping = bk::mapping_label(&arch);
            eprintln!("[fig7] {} / {} ({} layers)...", net.name, mapping, net.len());
            let results: Vec<_> = solvers
                .iter()
                .map(|&s| bk::run_cell(&arch, &net, batch, Objective::Energy, s))
                .collect();
            let base_e = results[0].eval.energy.total();
            let mut row = vec![fwd.name.clone(), mapping.to_string()];
            for (i, r) in results.iter().enumerate() {
                let norm = r.eval.energy.total() / base_e;
                per_solver[i].push(norm);
                row.push(format!("{norm:.3}"));
                let mut j = bk::result_json(&net.name, solvers[i], r);
                j.set("array", mapping.into());
                rows.push(j);
            }
            row.push(fmt_duration(results[4].solve_s));
            row.push(fmt_duration(results[0].solve_s));
            t.row(row);

            // Component breakdown match (paper: "energy breakdowns across
            // major hardware components also match well").
            let bb = &results[0].eval.energy;
            let kb = &results[4].eval.energy;
            eprintln!(
                "  breakdown B: dram {} gbuf {} | K: dram {} gbuf {}",
                eng(bb.dram_pj, "pJ"),
                eng(bb.gbuf_pj, "pJ"),
                eng(kb.dram_pj, "pJ"),
                eng(kb.gbuf_pj, "pJ"),
            );
        }
    }
    let mut gm = vec!["geomean".to_string(), String::new()];
    for s in &per_solver {
        gm.push(format!("{:.3}", geomean(s)));
    }
    gm.push(String::new());
    gm.push(String::new());
    t.row(gm);

    let out = t.save_and_render("fig7_training_energy");
    println!("{out}");
    bk::save_json("fig7_training_energy", &Json::Arr(rows));
    bk::log_section("fig7_training_energy", &out);
    println!(
        "paper shape: K within a few % of B (2.2% avg in paper); R worst/erratic; M between.\n\
         K may dip below 1.0: the directive space (sharing, partial regions) exceeds B's."
    );
}
