//! Paper Fig. 8: dataflow *performance* (latency) for training on
//! multi-node accelerators (batch 64), all five solvers normalized to B —
//! demonstrating that optimizing for performance follows the same trends
//! as energy ("validates our conjecture of co-optimizing energy and
//! performance") — swept under BOTH PE-array mapping templates over full
//! training graphs (fwd + dX + dW + wu).
//!
//! Run: `cargo bench --bench fig8_training_perf`

use kapla::report::benchkit as bk;
use kapla::report::Table;
use kapla::solvers::Objective;
use kapla::util::json::Json;
use kapla::util::stats::geomean;
use kapla::workloads::training_graph;

fn main() {
    let base = bk::bench_arch();
    let batch = bk::bench_batch();
    let nets = bk::bench_nets(&["alexnet", "mlp"]);
    let solvers = bk::paper_solvers(0.1);

    let mut t = Table::new(
        &format!("Fig.8 — training latency normalized to B (batch {batch}, {})", base.name),
        &["network", "array", "B", "S", "R", "M", "K"],
    );
    let mut per_solver: Vec<Vec<f64>> = vec![Vec::new(); solvers.len()];
    let mut rows: Vec<Json> = Vec::new();
    for fwd in &nets {
        let net = training_graph(fwd);
        // Structural pin: bd + bw + wu present, MACs conserved.
        bk::check_training_graph(fwd, &net, batch);
        for df in bk::array_mappings() {
            let arch = bk::with_mapping(&base, df);
            let mapping = bk::mapping_label(&arch);
            eprintln!("[fig8] {} / {} ({} layers)...", net.name, mapping, net.len());
            let results: Vec<_> = solvers
                .iter()
                .map(|&s| bk::run_cell(&arch, &net, batch, Objective::Latency, s))
                .collect();
            let base_l = results[0].eval.latency_cycles;
            let mut row = vec![fwd.name.clone(), mapping.to_string()];
            for (i, r) in results.iter().enumerate() {
                let norm = r.eval.latency_cycles / base_l;
                per_solver[i].push(norm);
                row.push(format!("{norm:.3}"));
                let mut j = bk::result_json(&net.name, solvers[i], r);
                j.set("array", mapping.into());
                rows.push(j);
            }
            t.row(row);
        }
    }
    let mut gm = vec!["geomean".to_string(), String::new()];
    for s in &per_solver {
        gm.push(format!("{:.3}", geomean(s)));
    }
    t.row(gm);

    let out = t.save_and_render("fig8_training_perf");
    println!("{out}");
    bk::save_json("fig8_training_perf", &Json::Arr(rows));
    bk::log_section("fig8_training_perf", &out);
    println!("paper shape: same ordering as Fig.7 — performance co-optimizes with energy.");
}
