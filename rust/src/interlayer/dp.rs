//! Dynamic-programming segment-chain search (paper §IV-B).
//!
//! Layers are processed in DAG topological order; step `i` finds the best
//! segment chains *ending at* layer `i` by combining each candidate segment
//! `[j..=i]` with the best chains ending at `j-1`. To tolerate estimation
//! error, the top `k_S` candidate chains are kept per layer (default 4,
//! studied in the paper's Fig. 11).

use super::prune::{prune_and_rank, prune_and_rank_threaded, PruneStats, RankedSegment};
use super::{candidate_spans, enumerate_segment_schemes, Segment};
use crate::arch::ArchConfig;
use crate::cost::CostModel;
use crate::workloads::Network;

/// Tuning knobs of the inter-layer search.
#[derive(Debug, Clone, Copy)]
pub struct DpConfig {
    /// Chains kept per layer (k_S).
    pub ks: usize,
    /// Maximum layers per pipelined segment.
    pub max_seg_len: usize,
    /// Cap on pipelining rounds explored.
    pub max_rounds: u64,
    /// Ranked inter-layer schemes retained per span after pruning.
    pub top_per_span: usize,
    /// Worker threads for the independent intra-layer solves (the paper
    /// measured 8 parallel processes, Table IV). Every solver is pure per
    /// context, so the resulting schedule is byte-identical for any value;
    /// 1 runs fully inline. Use `util::available_threads()` to saturate
    /// the host.
    pub solve_threads: usize,
}

impl Default for DpConfig {
    fn default() -> Self {
        DpConfig { ks: 4, max_seg_len: 4, max_rounds: 64, top_per_span: 2, solve_threads: 1 }
    }
}

/// A complete segment chain (covers layers 0..=end) with its estimated
/// cost.
#[derive(Debug, Clone)]
pub struct ChainCand {
    pub cost: f64,
    pub segments: Vec<Segment>,
}

#[derive(Clone)]
struct Node {
    cost: f64,
    seg: Segment,
    /// (previous layer index, rank within its candidate list)
    parent: Option<(usize, usize)>,
}

/// Run the DP and return the top `ks` complete chains, plus aggregate
/// pruning statistics (for Table VI-style reporting).
///
/// The per-span work — enumerating a span's inter-layer schemes, validity
/// pruning, lower-bound scoring, Pareto filtering — depends only on the
/// span, never on DP state, so with `cfg.solve_threads > 1` every
/// `(end layer, span)` candidate is scored up front across the scoped
/// worker pool (each span ranking inline so pools don't nest); the
/// sequential chain combination afterwards is pure table assembly.
/// `par_map` preserves item order and the scoring is pure, so the chains
/// are byte-identical for any thread count.
pub fn best_chains(
    arch: &ArchConfig,
    net: &Network,
    batch: u64,
    cfg: &DpConfig,
    model: &dyn CostModel,
) -> (Vec<ChainCand>, PruneStats) {
    let n = net.len();
    let mut table: Vec<Vec<Node>> = Vec::with_capacity(n);
    let mut stats = PruneStats::default();

    let span_jobs: Vec<(usize, Vec<usize>)> = (0..n)
        .flat_map(|i| candidate_spans(i, cfg.max_seg_len).into_iter().map(move |s| (i, s)))
        .collect();
    let outer = cfg.solve_threads.max(1);
    let ranked_jobs: Vec<(Vec<RankedSegment>, PruneStats)> =
        crate::util::par_map(&span_jobs, outer, |(_, span)| {
            let schemes = enumerate_segment_schemes(net, arch, batch, span, cfg.max_rounds);
            let (mut ranked, st) = if outer > 1 {
                prune_and_rank_threaded(arch, net, batch, schemes, 1, model)
            } else {
                prune_and_rank(arch, net, batch, schemes, model)
            };
            // Only the best `top_per_span` survivors are ever read; drop
            // the rest here so holding all spans' results at once costs
            // O(spans * top_per_span), not O(spans * survivors).
            ranked.truncate(cfg.top_per_span);
            (ranked, st)
        });

    let mut job = 0;
    for i in 0..n {
        let mut cands: Vec<Node> = Vec::new();
        while job < span_jobs.len() && span_jobs[job].0 == i {
            let start = span_jobs[job].1[0];
            let (ranked, st) = &ranked_jobs[job];
            job += 1;
            stats.total += st.total;
            stats.after_validity += st.after_validity;
            stats.after_pareto += st.after_pareto;
            for RankedSegment { seg, est } in ranked.iter() {
                if start == 0 {
                    cands.push(Node { cost: est.score(), seg: seg.clone(), parent: None });
                } else {
                    for (rank, prev) in table[start - 1].iter().enumerate() {
                        cands.push(Node {
                            cost: est.score() + prev.cost,
                            seg: seg.clone(),
                            parent: Some((start - 1, rank)),
                        });
                    }
                }
            }
        }
        cands.sort_by(|a, b| a.cost.partial_cmp(&b.cost).unwrap());
        cands.truncate(cfg.ks.max(1));
        assert!(!cands.is_empty(), "no valid segment chain ends at layer {i}");
        table.push(cands);
    }

    // Reconstruct the top-ks chains ending at the last layer.
    let last = n - 1;
    let mut out = Vec::new();
    for rank in 0..table[last].len() {
        let mut segments = Vec::new();
        let mut cur = Some((last, rank));
        while let Some((li, r)) = cur {
            let node = &table[li][r];
            segments.push(node.seg.clone());
            cur = node.parent;
        }
        segments.reverse();
        out.push(ChainCand { cost: table[last][rank].cost, segments });
    }
    (out, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::cost::TieredCost;
    use crate::workloads::nets;

    fn check_chain_covers(net_len: usize, chain: &ChainCand) {
        let mut covered = Vec::new();
        for seg in &chain.segments {
            covered.extend(seg.layers.iter().copied());
        }
        let expect: Vec<usize> = (0..net_len).collect();
        assert_eq!(covered, expect, "chain must cover each layer exactly once, in order");
    }

    #[test]
    fn chains_cover_alexnet() {
        let arch = presets::multi_node_eyeriss();
        let net = nets::alexnet();
        let (chains, stats) = best_chains(&arch, &net, 64, &DpConfig::default(), &TieredCost::fresh());
        assert!(!chains.is_empty() && chains.len() <= 4);
        for ch in &chains {
            check_chain_covers(net.len(), ch);
        }
        assert!(stats.total > 0);
        assert!(stats.after_pareto <= stats.total);
        // chains sorted by cost
        for w in chains.windows(2) {
            assert!(w[0].cost <= w[1].cost);
        }
    }

    #[test]
    fn ks1_returns_single_chain() {
        let arch = presets::multi_node_eyeriss();
        let net = nets::mlp();
        let cfg = DpConfig { ks: 1, ..DpConfig::default() };
        let (chains, _) = best_chains(&arch, &net, 64, &cfg, &TieredCost::fresh());
        assert_eq!(chains.len(), 1);
        check_chain_covers(net.len(), &chains[0]);
    }

    #[test]
    fn bigger_ks_never_worse() {
        let arch = presets::multi_node_eyeriss();
        let net = nets::mlp();
        let c1 = best_chains(&arch, &net, 64, &DpConfig { ks: 1, ..DpConfig::default() }, &TieredCost::fresh()).0;
        let c8 = best_chains(&arch, &net, 64, &DpConfig { ks: 8, ..DpConfig::default() }, &TieredCost::fresh()).0;
        assert!(c8[0].cost <= c1[0].cost + 1e-9);
    }

    #[test]
    fn edge_arch_gets_singleton_segments() {
        let arch = presets::edge_tpu();
        let net = nets::alexnet();
        let (chains, _) = best_chains(&arch, &net, 1, &DpConfig::default(), &TieredCost::fresh());
        for seg in &chains[0].segments {
            assert_eq!(seg.len(), 1);
        }
    }

    #[test]
    fn parallel_span_scoring_is_byte_identical() {
        let arch = presets::multi_node_eyeriss();
        let net = nets::alexnet();
        let seq =
            best_chains(&arch, &net, 64, &DpConfig { solve_threads: 1, ..DpConfig::default() }, &TieredCost::fresh());
        let par =
            best_chains(&arch, &net, 64, &DpConfig { solve_threads: 4, ..DpConfig::default() }, &TieredCost::fresh());
        assert_eq!(seq.0.len(), par.0.len());
        for (a, b) in seq.0.iter().zip(&par.0) {
            assert_eq!(a.cost, b.cost);
            assert_eq!(format!("{:?}", a.segments), format!("{:?}", b.segments));
        }
        assert_eq!(format!("{:?}", seq.1), format!("{:?}", par.1));
    }

    #[test]
    fn multilayer_segments_chosen_when_beneficial() {
        // On the big mesh with pipelining enabled, at least one chain
        // should use a multi-layer segment for conv-heavy nets.
        let arch = presets::multi_node_eyeriss();
        let net = nets::alexnet();
        let (chains, _) = best_chains(&arch, &net, 64, &DpConfig::default(), &TieredCost::fresh());
        let any_multi =
            chains.iter().any(|ch| ch.segments.iter().any(|s| s.len() > 1));
        assert!(any_multi, "expected some pipelined segment in top chains");
    }
}
