//! Dynamic-programming segment-chain search (paper §IV-B).
//!
//! Layers are processed in DAG topological order; step `i` finds the best
//! segment chains *ending at* layer `i` by combining each candidate segment
//! `[j..=i]` with the best chains ending at `j-1`. To tolerate estimation
//! error, the top `k_S` candidate chains are kept per layer (default 4,
//! studied in the paper's Fig. 11).
//!
//! The search itself lives in the staged [`super::planner::Planner`]
//! (lazy span enumeration, admissible chain-level branch-and-bound,
//! memo-assembled estimates); [`best_chains`] is the conventional entry
//! point the solver engine calls.

use super::planner::Planner;
use super::prune::PruneStats;
use super::Segment;
use crate::arch::ArchConfig;
use crate::cost::CostModel;
use crate::solvers::SolveError;
use crate::workloads::Network;

/// Tuning knobs of the inter-layer search.
#[derive(Debug, Clone, Copy)]
pub struct DpConfig {
    /// Chains kept per layer (k_S).
    pub ks: usize,
    /// Maximum layers per pipelined segment.
    pub max_seg_len: usize,
    /// Cap on pipelining rounds explored.
    pub max_rounds: u64,
    /// Ranked inter-layer schemes retained per span after pruning.
    pub top_per_span: usize,
    /// Worker threads for the independent intra-layer solves (the paper
    /// measured 8 parallel processes, Table IV). Every solver is pure per
    /// context, so the resulting schedule is byte-identical for any value;
    /// 1 runs fully inline. Use `util::available_threads()` to saturate
    /// the host.
    pub solve_threads: usize,
    /// Minimum context-table key count before a span's table build shards
    /// across the worker pool — below it the per-solve work doesn't amortise
    /// thread startup (previously a hardcoded planner constant).
    pub parallel_table_min: usize,
    /// Speculation window: while span `i` streams its schemes against the
    /// live incumbent, context tables and admissible span floors for spans
    /// `i+1..i+W` are prebuilt on the worker pool. Tables and floors depend
    /// only on the span shape and the cost model — never on the incumbent —
    /// so speculation changes wall-clock only, not the visited stream or
    /// the chains. `0` disables speculation; it is also inert when
    /// `solve_threads <= 1`.
    pub spec_window: usize,
    /// Check the partition-level admissible floor in the staged intra-layer
    /// scans before enumerating a partition's blockings (`off` for triage;
    /// the argmin is provably identical either way).
    pub part_floor: bool,
    /// Partition visiting order in the staged intra-layer scans:
    /// `Floor` (default) sorts partitions by ascending admissible floor so
    /// the incumbent tightens sooner and `part_floor` prunes more; `Enum`
    /// keeps raw enumeration order. Both are exact on the optimum *value*;
    /// ties may resolve to a different equal-cost scheme, which is why the
    /// exhaustive solvers fold the order into their memo fingerprint.
    pub part_order: crate::solvers::space::PartOrder,
}

impl Default for DpConfig {
    fn default() -> Self {
        DpConfig {
            ks: 4,
            max_seg_len: 4,
            max_rounds: 64,
            top_per_span: 2,
            solve_threads: 1,
            parallel_table_min: 1024,
            spec_window: 8,
            part_floor: true,
            part_order: crate::solvers::space::PartOrder::Floor,
        }
    }
}

/// A complete segment chain (covers layers 0..=end) with its estimated
/// cost.
#[derive(Debug, Clone)]
pub struct ChainCand {
    pub cost: f64,
    pub segments: Vec<Segment>,
}

/// Run the staged inter-layer planner and return the top `ks` complete
/// chains, plus aggregate pruning statistics (for Table VI-style
/// reporting). A degenerate net/arch combination with no valid chain
/// returns a structured [`SolveError`] instead of panicking.
///
/// Chain-level branch-and-bound and the staged context tables never change
/// the result — chains are byte-identical to a full enumeration (pinned by
/// `tests/planner_equivalence.rs`) and to any `solve_threads` value.
pub fn best_chains(
    arch: &ArchConfig,
    net: &Network,
    batch: u64,
    cfg: &DpConfig,
    model: &dyn CostModel,
) -> Result<(Vec<ChainCand>, PruneStats), SolveError> {
    best_chains_cancellable(arch, net, batch, cfg, model, None)
}

/// [`best_chains`] with a cooperative cancellation token threaded into the
/// planner's span stream and speculative workers. A trip mid-DP returns
/// `SolveError::Deadline` — the partial table is not a complete chain, so
/// the caller (the engine's KAPLA path) degrades to its all-singleton
/// fallback instead. `None` (or an untripped token) is byte-identical to
/// [`best_chains`].
pub fn best_chains_cancellable(
    arch: &ArchConfig,
    net: &Network,
    batch: u64,
    cfg: &DpConfig,
    model: &dyn CostModel,
    cancel: Option<&crate::util::cancel::CancelToken>,
) -> Result<(Vec<ChainCand>, PruneStats), SolveError> {
    Planner::new(arch, net, batch, cfg, model).cancel(cancel).chains()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::cost::TieredCost;
    use crate::workloads::nets;

    fn check_chain_covers(net_len: usize, chain: &ChainCand) {
        let mut covered = Vec::new();
        for seg in &chain.segments {
            covered.extend(seg.layers.iter().copied());
        }
        let expect: Vec<usize> = (0..net_len).collect();
        assert_eq!(covered, expect, "chain must cover each layer exactly once, in order");
    }

    #[test]
    fn chains_cover_alexnet() {
        let arch = presets::multi_node_eyeriss();
        let net = nets::alexnet();
        let (chains, stats) =
            best_chains(&arch, &net, 64, &DpConfig::default(), &TieredCost::fresh()).unwrap();
        assert!(!chains.is_empty() && chains.len() <= 4);
        for ch in &chains {
            check_chain_covers(net.len(), ch);
        }
        assert!(stats.total > 0);
        assert!(stats.after_pareto <= stats.total);
        assert!(stats.spans_total > 0);
        assert!(stats.spans_pruned <= stats.spans_total);
        // chains sorted by cost
        for w in chains.windows(2) {
            assert!(w[0].cost <= w[1].cost);
        }
    }

    #[test]
    fn ks1_returns_single_chain() {
        let arch = presets::multi_node_eyeriss();
        let net = nets::mlp();
        let cfg = DpConfig { ks: 1, ..DpConfig::default() };
        let (chains, _) = best_chains(&arch, &net, 64, &cfg, &TieredCost::fresh()).unwrap();
        assert_eq!(chains.len(), 1);
        check_chain_covers(net.len(), &chains[0]);
    }

    #[test]
    fn bigger_ks_never_worse() {
        let arch = presets::multi_node_eyeriss();
        let net = nets::mlp();
        let cfg1 = DpConfig { ks: 1, ..DpConfig::default() };
        let c1 = best_chains(&arch, &net, 64, &cfg1, &TieredCost::fresh()).unwrap().0;
        let cfg8 = DpConfig { ks: 8, ..DpConfig::default() };
        let c8 = best_chains(&arch, &net, 64, &cfg8, &TieredCost::fresh()).unwrap().0;
        assert!(c8[0].cost <= c1[0].cost + 1e-9);
    }

    #[test]
    fn edge_arch_gets_singleton_segments() {
        let arch = presets::edge_tpu();
        let net = nets::alexnet();
        let (chains, _) =
            best_chains(&arch, &net, 1, &DpConfig::default(), &TieredCost::fresh()).unwrap();
        for seg in &chains[0].segments {
            assert_eq!(seg.len(), 1);
        }
    }

    #[test]
    fn parallel_span_scoring_is_byte_identical() {
        let arch = presets::multi_node_eyeriss();
        let net = nets::alexnet();
        let seq = best_chains(
            &arch,
            &net,
            64,
            &DpConfig { solve_threads: 1, ..DpConfig::default() },
            &TieredCost::fresh(),
        )
        .unwrap();
        let par = best_chains(
            &arch,
            &net,
            64,
            &DpConfig { solve_threads: 4, ..DpConfig::default() },
            &TieredCost::fresh(),
        )
        .unwrap();
        assert_eq!(seq.0.len(), par.0.len());
        for (a, b) in seq.0.iter().zip(&par.0) {
            assert_eq!(a.cost, b.cost);
            assert_eq!(format!("{:?}", a.segments), format!("{:?}", b.segments));
        }
        assert_eq!(format!("{:?}", seq.1), format!("{:?}", par.1));
    }

    #[test]
    fn multilayer_segments_chosen_when_beneficial() {
        // On the big mesh with pipelining enabled, at least one chain
        // should use a multi-layer segment for conv-heavy nets.
        let arch = presets::multi_node_eyeriss();
        let net = nets::alexnet();
        let (chains, _) =
            best_chains(&arch, &net, 64, &DpConfig::default(), &TieredCost::fresh()).unwrap();
        let any_multi =
            chains.iter().any(|ch| ch.segments.iter().any(|s| s.len() > 1));
        assert!(any_multi, "expected some pipelined segment in top chains");
    }
}
