//! Inter-layer matching rules (paper §III-B): adjacent pipelined layers
//! must agree on the shared intermediate tensor — equal tensor sizes at
//! the top (GBUF) level and matched top-level `update` steps — so the
//! consumer can consume data "as soon as produced" (fine-grained
//! forwarding, Listing 1's `update(K+=24)` vs `update(C+=24)` example).
//!
//! The solvers construct schemes that satisfy these rules by construction
//! (segments share the per-round batch, and forwarding granularity is the
//! round); this module makes the rules *checkable* so externally-authored
//! or mutated schedules can be audited, and tests can assert the property
//! on every solver's output.

use crate::directives::LayerScheme;
use crate::interlayer::Segment;
use crate::workloads::{Network, PrevRef};

/// A single matching violation between a producer/consumer pair.
#[derive(Debug, Clone, PartialEq)]
pub struct Mismatch {
    pub producer: usize,
    pub consumer: usize,
    pub what: String,
}

/// Check the forwarding-compatibility of all in-segment producer/consumer
/// pairs of a scheduled segment. Returns all violations (empty = valid).
pub fn check_segment(
    net: &Network,
    seg: &Segment,
    schemes: &[LayerScheme],
) -> Vec<Mismatch> {
    let mut out = Vec::new();
    if !seg.spatial {
        return out;
    }
    let pos_of = |li: usize| seg.layers.iter().position(|&x| x == li);
    for (cpos, &ci) in seg.layers.iter().enumerate() {
        for p in &net.prevs[ci] {
            let PrevRef::Layer(pi) = p else { continue };
            let Some(ppos) = pos_of(*pi) else { continue };
            let prod = &schemes[ppos];
            let cons = &schemes[cpos];

            // Rule 1: equal per-round batch quantities at the top level —
            // the producer emits and the consumer ingests the same number
            // of images per pipeline round. Batch-independent layers
            // (weight updates) legitimately consume a reduced tensor.
            let batch_free = net.layers[ci].no_batch || net.layers[*pi].no_batch;
            if !batch_free && prod.unit.shape.n != cons.unit.shape.n {
                out.push(Mismatch {
                    producer: *pi,
                    consumer: ci,
                    what: format!(
                        "round batch {} vs {}",
                        prod.unit.shape.n, cons.unit.shape.n
                    ),
                });
            }

            // Rule 2: the produced channel extent covers what the consumer
            // reads (concat producers each cover a slice; their sum is
            // checked by the DAG validator, so each must not exceed it).
            let prod_k = prod.unit.shape.k * prod.part.pk;
            let cons_c = cons.unit.shape.c * cons.part.pc.max(1);
            if net.prevs[ci].len() == 1 && prod_k < cons_c {
                out.push(Mismatch {
                    producer: *pi,
                    consumer: ci,
                    what: format!("channel extent {prod_k} < consumer C {cons_c}"),
                });
            }

            // Rule 3: matched top-level update steps for the shared tensor:
            // the producer's K-group step (what it finishes per top
            // iteration) must be a multiple of the consumer's C-group step
            // (what it can start with), or vice versa — otherwise the
            // intermediate stalls in neither buffer.
            let ps = prod.gbuf.qty.k.max(1);
            let cs = cons.gbuf.qty.c.max(1);
            if ps % cs != 0 && cs % ps != 0 {
                out.push(Mismatch {
                    producer: *pi,
                    consumer: ci,
                    what: format!("update steps K+={ps} vs C+={cs} incompatible"),
                });
            }
        }
    }
    out
}

/// Check a whole schedule; returns violations per segment index.
pub fn check_schedule(
    net: &Network,
    sched: &crate::interlayer::Schedule,
) -> Vec<(usize, Mismatch)> {
    let mut out = Vec::new();
    for (si, (seg, schemes)) in sched.segments.iter().enumerate() {
        for m in check_segment(net, seg, schemes) {
            out.push((si, m));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::coordinator::{run_job, Job, SolverKind};
    use crate::interlayer::dp::DpConfig;
    use crate::solvers::Objective;
    use crate::workloads::{nets, training_graph};

    #[test]
    fn kapla_schedules_satisfy_matching_rules() {
        let arch = presets::multi_node_eyeriss();
        for net in [nets::alexnet(), nets::mobilenet(), training_graph(&nets::mlp())] {
            let j = Job {
                net: net.clone(),
                batch: 64,
                objective: Objective::Energy,
                solver: SolverKind::Kapla,
                dp: DpConfig::default(),
                deadline_ms: None,
            };
            let r = run_job(&arch, &j).expect("schedulable");
            let violations = check_schedule(&net, &r.schedule);
            // Batch-round agreement (rule 1) must hold exactly; step
            // compatibility (rule 3) may legitimately round on ceil splits.
            let hard: Vec<_> = violations
                .iter()
                .filter(|(_, m)| m.what.starts_with("round batch"))
                .collect();
            assert!(hard.is_empty(), "{}: {hard:?}", net.name);
        }
    }

    #[test]
    fn mismatched_round_batch_detected() {
        let arch = presets::bench_multi_node();
        let net = nets::alexnet();
        let seg = crate::interlayer::Segment {
            layers: vec![2, 3],
            regions: vec![(2, 4), (2, 4)],
            spatial: true,
            rounds: 4,
        };
        // Build one scheme at the right round batch and one wrong.
        let mk = |li: usize, rb: u64| {
            crate::solvers::space::minimal_scheme(&arch, &net.layers[li], (2, 4), rb).unwrap()
        };
        let ok = check_segment(&net, &seg, &[mk(2, 4), mk(3, 4)]);
        assert!(ok.iter().all(|m| !m.what.starts_with("round batch")), "{ok:?}");
        let bad = check_segment(&net, &seg, &[mk(2, 4), mk(3, 8)]);
        assert!(bad.iter().any(|m| m.what.starts_with("round batch")), "{bad:?}");
    }

    #[test]
    fn non_spatial_segments_trivially_match() {
        let arch = presets::bench_multi_node();
        let net = nets::alexnet();
        let seg = crate::interlayer::Segment::single(0, &arch);
        let s = crate::solvers::space::minimal_scheme(&arch, &net.layers[0], arch.nodes, 8).unwrap();
        assert!(check_segment(&net, &seg, &[s]).is_empty());
    }
}
