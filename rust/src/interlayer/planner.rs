//! The staged inter-layer planner (paper §IV-B, lifted to the upper
//! level): lazy span enumeration, admissible chain-level branch-and-bound,
//! and memo-assembled estimate scoring — the inter-layer mirror of the
//! staged intra-layer enumeration (`solvers::space::visit_schemes_staged`).
//!
//! [`Planner`] owns the segment-chain search `dp::best_chains` wraps. The
//! eager pipeline it replaces materialized every `(end layer, span)`
//! candidate set up front — a `Vec` of hundreds of [`Segment`]s per span,
//! each cloning its `regions` per rounds option — and fully ranked all of
//! them before the DP ever looked at a cost. The planner instead processes
//! spans in DP order and stages each one:
//!
//! 1. **Context table** — the span's distinct `(layer, LayerCtx)` estimate
//!    keys are generated directly from the span shape (layer positions x
//!    strip widths x rounds options) and scored once each through the
//!    model's estimate tier ([`CostModel::estimate_layer`]); this is the
//!    same memo the eager path staged inside `prune_and_rank_threaded`,
//!    built *before* any scheme exists.
//! 2. **Span floor** — an admissible lower bound on
//!    [`CostEstimate::score`] over *every* scheme of the span, derived
//!    from the table alone (per-layer minima over widths and rounds; the
//!    admissibility argument lives on `Planner::span_table`). When
//!    `floor + best_prev >= incumbent` — the k_S-th best chain cost
//!    already accumulated at the span's end layer — the whole span is
//!    skipped without streaming a single scheme (`PruneStats::spans_pruned`).
//! 3. **Bounded streaming** — surviving spans stream their schemes lazily
//!    ([`visit_segment_schemes`]: one scratch segment, no per-candidate
//!    allocation), assemble each estimate from the context table (the
//!    exact `segment_lower_bound_with` accumulation, so totals are
//!    bit-identical to one-shot scoring), and drop every scheme whose
//!    `score + best_prev >= incumbent`
//!    (`PruneStats::schemes_bound_pruned`). Only the survivors are cloned,
//!    Pareto-filtered and ranked.
//!
//! Both prunes are **exact**: a skipped candidate chain would cost at
//! least `incumbent`, and the incumbent is the k_S-th smallest cost of
//! candidates *already inserted* — all of which precede the skipped one in
//! insertion order, so under the DP's stable ordering (insertion order
//! breaks cost ties) the skipped candidate could never enter the final
//! top-k_S. The bound criterion is monotone in score, so the bound-filtered
//! scheme set is a suffix-drop of the span's score-sorted ranking; since
//! domination implies a score no smaller than the dominator's, Pareto
//! filtering commutes with the drop and the surviving ranked prefix equals
//! the eager path's. `tests/planner_equivalence.rs` pins chains and final
//! schedules byte-identical against a reference copy of the eager
//! pipeline.
//!
//! Threading: the incumbent flows span to span, so the *stream* is
//! inherently sequential — but a span's context table and admissible floor
//! depend only on the span shape and the cost model, never on the
//! incumbent. With `solve_threads > 1` the planner therefore runs a
//! **speculative pipeline**: while span `i` streams its schemes against
//! the live incumbent on the main thread, scoped workers prebuild the
//! tables of spans `i+1..i+spec_window` (`DpConfig::spec_window`, in DP
//! order, bounded so speculation never races arbitrarily far ahead). The
//! floor *check* — the only incumbent-dependent step — still happens at
//! stream time on the main thread, and every multi-layer span's table is
//! built in both modes, so the visited stream, the pruning decisions, the
//! chains and even the `PruneStats` counters are byte-identical for any
//! thread count (pinned by `dp::tests::parallel_span_scoring_is_byte_identical`).
//! Sequentially (`solve_threads <= 1` or `spec_window == 0`) a large
//! table's estimate stage instead shards across the pool
//! (`DpConfig::parallel_table_min`); speculative workers build tables
//! inline so the pools never nest.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

use super::dp::{ChainCand, DpConfig};
use super::prune::{conservative_valid, pareto_rank, CtxKey, PruneStats, RankedSegment};
use super::{candidate_spans, visit_segment_schemes, Segment};
use crate::arch::ArchConfig;
use crate::cost::{segment_lower_bound_with, CostEstimate, CostModel, LayerCtx};
use crate::solvers::SolveError;
use crate::workloads::Network;

/// One chain-candidate node of the DP table.
struct Node {
    cost: f64,
    seg: Segment,
    /// (previous layer index, rank within its candidate list)
    parent: Option<(usize, usize)>,
}

/// The per-span staged state: the distinct `(layer, ctx)` estimate table
/// and the admissible score floor derived from it.
struct SpanTable {
    index: HashMap<CtxKey, usize>,
    ests: Vec<CostEstimate>,
    floor: f64,
}

/// One slot of the speculative pipeline: a worker parks the span's table
/// (`None` when the span shape has no scheme at all), the main thread
/// blocks on [`SpecSlot::take`] until it lands. Outer `Option` = "has the
/// worker filled this slot yet", inner = `span_table`'s own result.
struct SpecSlot {
    filled: Mutex<Option<Option<SpanTable>>>,
    ready: Condvar,
}

impl SpecSlot {
    fn new() -> SpecSlot {
        SpecSlot { filled: Mutex::new(None), ready: Condvar::new() }
    }

    fn fill(&self, tbl: Option<SpanTable>) {
        *self.filled.lock().unwrap() = Some(tbl);
        self.ready.notify_one();
    }

    fn take(&self) -> Option<SpanTable> {
        let mut g = self.filled.lock().unwrap();
        loop {
            if let Some(t) = g.take() {
                return t;
            }
            g = self.ready.wait(g).unwrap();
        }
    }
}

/// The staged inter-layer segment-chain planner. Build with
/// [`Planner::new`], optionally disable the chain-level bound with
/// [`Planner::bound_prune`] (the reference full-enumeration mode the
/// equivalence battery compares against), then call [`Planner::chains`].
pub struct Planner<'a> {
    arch: &'a ArchConfig,
    net: &'a Network,
    batch: u64,
    cfg: &'a DpConfig,
    model: &'a dyn CostModel,
    bound_prune: bool,
    cancel: Option<&'a crate::util::cancel::CancelToken>,
}

impl<'a> Planner<'a> {
    pub fn new(
        arch: &'a ArchConfig,
        net: &'a Network,
        batch: u64,
        cfg: &'a DpConfig,
        model: &'a dyn CostModel,
    ) -> Planner<'a> {
        Planner { arch, net, batch, cfg, model, bound_prune: true, cancel: None }
    }

    /// Enable/disable the chain-level branch-and-bound (default on).
    /// Disabling streams and ranks every span in full — the argmin is
    /// identical by construction; only the work differs.
    pub fn bound_prune(mut self, on: bool) -> Planner<'a> {
        self.bound_prune = on;
        self
    }

    /// Cooperative cancellation for the span stream and the speculative
    /// table workers. A trip makes [`Planner::chains`] return
    /// `SolveError::Deadline` — the DP's partial table is not a complete
    /// chain, so the *caller* (the KAPLA engine path) supplies the anytime
    /// fallback. Untripped tokens never change the stream, the chains or
    /// the counters.
    pub fn cancel(mut self, tok: Option<&'a crate::util::cancel::CancelToken>) -> Planner<'a> {
        self.cancel = tok;
        self
    }

    /// Run the DP and return the top `k_S` complete chains plus pruning
    /// statistics, or a structured error when no valid chain covers the
    /// network (a degenerate net/arch combination must not panic a
    /// long-running service).
    ///
    /// With `solve_threads > 1` and a nonzero `spec_window`, span context
    /// tables are built speculatively ahead of the stream (module docs);
    /// the DP itself and every pruning decision run on this thread either
    /// way, so the result is byte-identical for any configuration.
    pub fn chains(&self) -> Result<(Vec<ChainCand>, PruneStats), SolveError> {
        // The flat span worklist in DP order — the stream the main thread
        // consumes and the speculation slots line up with, one entry per
        // (end layer, span) pair.
        let mut flat: Vec<Vec<usize>> = Vec::new();
        for i in 0..self.net.len() {
            flat.extend(candidate_spans(i, self.cfg.max_seg_len));
        }

        let window = self.cfg.spec_window;
        if self.cfg.solve_threads <= 1 || window == 0 || flat.is_empty() {
            // Sequential: tables built inline at stream time; a large
            // table's estimate stage may itself shard across the pool.
            return self.run_dp(&flat, |_, span| {
                self.span_table(span, self.cfg.solve_threads)
            });
        }

        // Speculative pipeline. Workers claim flat indices in order via an
        // atomic cursor, build each span's table inline (threads=1 — the
        // pipeline is the parallelism; nesting pools would oversubscribe),
        // and park it in the span's slot. The `consumed` counter + condvar
        // bound claims to `window` ahead of the stream so speculation
        // cannot run arbitrarily far past the incumbent.
        let slots: Vec<SpecSlot> = flat.iter().map(|_| SpecSlot::new()).collect();
        let cursor = AtomicUsize::new(0);
        let consumed = Mutex::new(0usize);
        let advanced = Condvar::new();
        let workers = (self.cfg.solve_threads - 1).clamp(1, flat.len());
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    // Cancellation check BEFORE claiming a slot: a claimed
                    // slot is always filled (the main thread may already
                    // have passed its own cancellation check for that span
                    // and would block on `take` forever otherwise), so a
                    // tripped worker simply stops claiming and exits; the
                    // post-`run_dp` drain below releases any worker still
                    // parked on the speculation window.
                    if self.cancel.is_some_and(|c| c.is_cancelled()) {
                        break;
                    }
                    let j = cursor.fetch_add(1, Ordering::Relaxed);
                    if j >= flat.len() {
                        break;
                    }
                    {
                        let mut c = consumed.lock().unwrap();
                        while j >= *c + window {
                            c = advanced.wait(c).unwrap();
                        }
                    }
                    slots[j].fill(self.span_table(&flat[j], 1));
                });
            }
            let result = self.run_dp(&flat, |j, _| {
                let tbl = slots[j].take();
                *consumed.lock().unwrap() = j + 1;
                advanced.notify_all();
                tbl
            });
            // On an early error return some slots were never consumed;
            // release any worker parked on the window so the scope joins
            // (it drains the remaining cheap table builds and exits).
            *consumed.lock().unwrap() = flat.len();
            advanced.notify_all();
            result
        })
    }

    /// The sequential DP over the flat span worklist. `get_table` supplies
    /// each span's context table (inline build or speculative slot) and is
    /// called exactly once per span, in stream order — single-layer spans
    /// included, so the speculation window's consumed counter advances
    /// uniformly.
    fn run_dp(
        &self,
        flat: &[Vec<usize>],
        mut get_table: impl FnMut(usize, &[usize]) -> Option<SpanTable>,
    ) -> Result<(Vec<ChainCand>, PruneStats), SolveError> {
        let n = self.net.len();
        let ks = self.cfg.ks.max(1);
        let mut table: Vec<Vec<Node>> = Vec::with_capacity(n);
        let mut stats = PruneStats::default();

        let mut cands: Vec<Node> = Vec::new();
        for (j, span) in flat.iter().enumerate() {
            // Cancellation yield point, checked BEFORE `get_table` claims
            // this span's speculative slot: on a trip the function returns
            // without ever taking another slot, so workers that observed
            // the same trip and stopped filling cannot strand this thread
            // on a Condvar. Purely an early exit — untripped runs stream
            // the byte-identical span sequence.
            if self.cancel.is_some_and(|c| c.is_cancelled()) {
                let tok = self.cancel.unwrap();
                return Err(SolveError::Deadline { elapsed_ms: tok.elapsed_ms() as u64 });
            }
            let (start, end) = (span[0], *span.last().unwrap());
            stats.spans_total += 1;
            // The cheapest chain this span's candidates can extend
            // anchors both bounds; a missing prefix row cannot happen
            // (every processed layer has at least one chain or the DP
            // already returned an error).
            let prev_best = if start == 0 { 0.0 } else { table[start - 1][0].cost };
            let incumbent = if cands.len() >= ks { cands[ks - 1].cost } else { f64::INFINITY };
            let tbl = get_table(j, span);
            if span.len() > 1 && tbl.is_some() {
                stats.tables_built += 1;
            }
            let ranked = self.rank_span(span, tbl, prev_best, incumbent, &mut stats);
            for RankedSegment { seg, est } in ranked {
                if start == 0 {
                    insert_top(&mut cands, ks, Node { cost: est.score(), seg, parent: None });
                } else {
                    for rank in 0..table[start - 1].len() {
                        insert_top(&mut cands, ks, Node {
                            cost: est.score() + table[start - 1][rank].cost,
                            seg: seg.clone(),
                            parent: Some((start - 1, rank)),
                        });
                    }
                }
            }
            // Last span ending at this layer: commit the layer's top-k_S.
            let layer_done = flat.get(j + 1).map(|next| *next.last().unwrap() != end).unwrap_or(true);
            if layer_done {
                if cands.is_empty() {
                    return Err(SolveError::NoChain {
                        layer: end,
                        layer_name: self.net.layers[end].name.clone(),
                    });
                }
                table.push(std::mem::take(&mut cands));
            }
        }

        // Reconstruct the top-ks chains ending at the last layer.
        let last = n - 1;
        let mut out = Vec::new();
        for rank in 0..table[last].len() {
            let mut segments = Vec::new();
            let mut cur = Some((last, rank));
            while let Some((li, r)) = cur {
                let node = &table[li][r];
                segments.push(node.seg.clone());
                cur = node.parent;
            }
            segments.reverse();
            out.push(ChainCand { cost: table[last][rank].cost, segments });
        }
        Ok((out, stats))
    }

    /// Rank one span: admissible floor check against the live incumbent,
    /// bounded streaming, Pareto + sort + top-per-span truncation. Returns
    /// the ranked survivors (empty when the span floor pruned everything).
    /// `tbl` is the span's prebuilt context table — `None` means no scheme
    /// exists for the span shape; single-layer spans ignore it (their one
    /// scheme is scored exactly, no table needed).
    fn rank_span(
        &self,
        span: &[usize],
        tbl: Option<SpanTable>,
        prev_best: f64,
        incumbent: f64,
        stats: &mut PruneStats,
    ) -> Vec<RankedSegment> {
        // Single-layer spans have exactly one scheme, so the "floor" is
        // the scheme's exact estimate and the span-level check subsumes
        // the per-scheme one.
        if span.len() == 1 {
            let seg = Segment::single(span[0], self.arch);
            let est = segment_lower_bound_with(self.net, self.batch, &seg, &mut |li, ctx| {
                self.model.estimate_layer(self.arch, &self.net.layers[li], ctx)
            });
            if self.prunes(est.score(), prev_best, incumbent) {
                stats.spans_pruned += 1;
                return Vec::new();
            }
            stats.total += 1;
            stats.after_validity += 1;
            stats.after_pareto += 1;
            return vec![RankedSegment { seg, est }];
        }

        let Some(tbl) = tbl else {
            return Vec::new(); // no scheme exists for this span shape
        };
        if self.prunes(tbl.floor, prev_best, incumbent) {
            stats.spans_pruned += 1;
            return Vec::new();
        }

        // Bounded streaming: validity, memo-assembled estimate, chain
        // bound — survivors cloned, everything else allocation-free.
        let mut ranked: Vec<RankedSegment> = Vec::new();
        let (mut total, mut valid) = (0usize, 0usize);
        visit_segment_schemes(self.net, self.arch, self.batch, span, self.cfg.max_rounds, |seg| {
            total += 1;
            if !conservative_valid(self.arch, self.net, self.batch, seg) {
                return true;
            }
            valid += 1;
            let est = segment_lower_bound_with(self.net, self.batch, seg, &mut |li, ctx| {
                match tbl.index.get(&CtxKey::of(li, ctx)) {
                    Some(&k) => tbl.ests[k],
                    // Defensive: the table generation mirrors the assembly's
                    // context construction; an unseen context still scores
                    // correctly, it just wasn't pre-staged.
                    None => self.model.estimate_layer(self.arch, &self.net.layers[li], ctx),
                }
            });
            if self.prunes(est.score(), prev_best, incumbent) {
                stats.schemes_bound_pruned += 1;
                return true;
            }
            ranked.push(RankedSegment { seg: seg.clone(), est });
            true
        });
        stats.total += total;
        stats.after_validity += valid;
        let mut ranked = pareto_rank(ranked);
        stats.after_pareto += ranked.len();
        // Only the best `top_per_span` survivors ever reach the DP.
        ranked.truncate(self.cfg.top_per_span);
        ranked
    }

    /// The one pruning predicate: admissible `floor_or_score` plus the
    /// cheapest extendable prefix cannot strictly beat the k_S-th
    /// incumbent. Never fires on an infinite incumbent (fewer than k_S
    /// candidates so far) and never fires on a NaN score (`>=` is false),
    /// so a broken estimate tier degrades to no pruning, not to a wrong
    /// argmin.
    fn prunes(&self, floor_or_score: f64, prev_best: f64, incumbent: f64) -> bool {
        self.bound_prune && incumbent.is_finite() && floor_or_score + prev_best >= incumbent
    }

    /// Build the context table and admissible floor of a multi-layer span.
    ///
    /// The distinct contexts of a span are exactly the cartesian product
    /// (layer position) x (strip width) x (rounds option): every scheme's
    /// per-layer context is determined by its layer's width and the
    /// scheme's rounds, and the on-chip flags depend only on span
    /// membership. The keys are therefore collected by *dry assembly runs*
    /// of `segment_lower_bound_with` itself over one scratch segment per
    /// (width, rounds) with uniform strips — exactly how
    /// `prune_and_rank_threaded` stages its scoring — so the table is
    /// derived from the real accumulation and can never drift from the
    /// assembly's context construction.
    ///
    /// Floor admissibility: for any scheme, its energy is a sum of
    /// per-layer estimates, each bounded below by that layer's minimum
    /// over all (width, rounds); its latency is
    /// `max_layer(latency) * (rounds + len - 1)`, bounded below by
    /// `min_rounds [ max_layer( min_width latency ) * (rounds + len - 1) ]`;
    /// and `CostEstimate::score` is monotone in both, so the floor score
    /// never exceeds any scheme's score.
    ///
    /// `max_threads` caps the estimate stage's sharding: the sequential
    /// planner passes `cfg.solve_threads`, speculative workers pass 1 so
    /// worker pools never nest. The table's contents are identical either
    /// way (`util::par_map` preserves order).
    fn span_table(&self, span: &[usize], max_threads: usize) -> Option<SpanTable> {
        let len = span.len();
        if len <= 1 {
            return None; // single-layer spans are scored exactly, no table
        }
        if !self.arch.spatial_layer_pipe {
            return None;
        }
        let (mesh_w, mesh_h) = self.arch.nodes;
        if (len as u64) > mesh_w {
            return None;
        }
        let widths: Vec<u64> = (1..=(mesh_w - (len as u64 - 1))).collect();
        let rounds_opts: Vec<u64> = crate::util::divisors(self.batch)
            .into_iter()
            .filter(|&r| r <= self.cfg.max_rounds)
            .collect();

        // Stage 1: dry assembly runs record the distinct keys. Spans hold
        // distinct layers, so (width, rounds) passes can never collide in
        // `CtxKey` and the key layout is (width-major, rounds, position).
        let mut keys: Vec<(usize, LayerCtx)> =
            Vec::with_capacity(widths.len() * rounds_opts.len() * len);
        let mut index = HashMap::with_capacity(keys.capacity());
        let mut scratch = Segment {
            layers: span.to_vec(),
            regions: vec![(0, mesh_h); len],
            spatial: true,
            rounds: 1,
        };
        for &w in &widths {
            for slot in scratch.regions.iter_mut() {
                *slot = (w, mesh_h);
            }
            for &r in &rounds_opts {
                scratch.rounds = r;
                segment_lower_bound_with(self.net, self.batch, &scratch, &mut |li, ctx| {
                    index.entry(CtxKey::of(li, ctx)).or_insert_with(|| {
                        keys.push((li, *ctx));
                        keys.len() - 1
                    });
                    CostEstimate { energy_pj: 0.0, latency_cycles: 0.0 }
                });
            }
        }

        // Stage 2: score each distinct context once (sharded only when
        // the table is large enough to amortize the pool spawn).
        let threads = if max_threads > 1 && keys.len() >= self.cfg.parallel_table_min {
            max_threads
        } else {
            1
        };
        let ests = crate::util::par_map(&keys, threads, |(li, ctx)| {
            self.model.estimate_layer(self.arch, &self.net.layers[*li], ctx)
        });

        // Stage 3: the floor, reduced by index arithmetic over the
        // (width, rounds, position) layout. Should the assembly ever
        // produce an unexpected key count, the floor degrades to "never
        // prune this span" — the per-scheme bounds (computed from real
        // estimates) stay fully sound either way.
        let (nw, nr) = (widths.len(), rounds_opts.len());
        let floor = if !keys.is_empty() && keys.len() == nw * nr * len {
            let at = |pos: usize, wi: usize, ri: usize| &ests[(wi * nr + ri) * len + pos];
            let mut floor_e = 0.0;
            for pos in 0..len {
                let mut min_e = f64::INFINITY;
                for wi in 0..nw {
                    for ri in 0..nr {
                        min_e = min_e.min(at(pos, wi, ri).energy_pj);
                    }
                }
                floor_e += min_e;
            }
            let mut floor_l = f64::INFINITY;
            for (ri, &r) in rounds_opts.iter().enumerate() {
                let mut round_lat: f64 = 0.0;
                for pos in 0..len {
                    let mut min_l = f64::INFINITY;
                    for wi in 0..nw {
                        min_l = min_l.min(at(pos, wi, ri).latency_cycles);
                    }
                    round_lat = round_lat.max(min_l);
                }
                floor_l = floor_l.min(round_lat * (r as f64 + len as f64 - 1.0));
            }
            CostEstimate { energy_pj: floor_e, latency_cycles: floor_l }.score()
        } else {
            f64::NEG_INFINITY
        };
        Some(SpanTable { index, ests, floor })
    }
}

/// Insert a candidate into the running top-k_S list, keeping it sorted by
/// cost with ties resolved by insertion order — exactly the stable
/// sort-then-truncate the eager DP ran, maintained incrementally.
/// `total_cmp` ordering makes a NaN cost sort last instead of panicking.
fn insert_top(cands: &mut Vec<Node>, ks: usize, node: Node) {
    let pos = cands
        .partition_point(|n| n.cost.total_cmp(&node.cost) != std::cmp::Ordering::Greater);
    if pos >= ks {
        return; // provably outside the top-k_S, never materialized
    }
    cands.insert(pos, node);
    cands.truncate(ks);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::cost::TieredCost;
    use crate::interlayer::enumerate_segment_schemes;
    use crate::workloads::nets;

    fn chains_snapshot(chains: &[ChainCand]) -> String {
        chains
            .iter()
            .map(|c| format!("{:?} {:?}\n", c.cost, c.segments))
            .collect::<String>()
    }

    #[test]
    fn bound_pruning_never_changes_the_chains() {
        let arch = presets::multi_node_eyeriss();
        let model = TieredCost::fresh();
        for net in [nets::mlp(), nets::alexnet()] {
            for ks in [1usize, 4] {
                let cfg = DpConfig { ks, ..DpConfig::default() };
                let full = Planner::new(&arch, &net, 64, &cfg, &model)
                    .bound_prune(false)
                    .chains()
                    .unwrap();
                let pruned =
                    Planner::new(&arch, &net, 64, &cfg, &model).chains().unwrap();
                assert_eq!(
                    chains_snapshot(&full.0),
                    chains_snapshot(&pruned.0),
                    "{} ks={ks}: pruning changed the chains",
                    net.name
                );
                assert_eq!(full.1.spans_pruned + full.1.schemes_bound_pruned, 0);
            }
        }
    }

    #[test]
    fn tight_ks_makes_the_bound_fire() {
        let arch = presets::multi_node_eyeriss();
        let net = nets::alexnet();
        let cfg = DpConfig { ks: 1, ..DpConfig::default() };
        let (_, stats) =
            Planner::new(&arch, &net, 64, &cfg, &TieredCost::fresh()).chains().unwrap();
        assert!(stats.spans_total > 0);
        assert!(
            stats.spans_pruned + stats.schemes_bound_pruned > 0,
            "k_S=1 should prune at least some spans/schemes: {stats:?}"
        );
    }

    #[test]
    fn staged_table_matches_per_candidate_estimates() {
        // Every streamed scheme's memo-assembled estimate must equal the
        // model's one-shot `estimate_segment` bit for bit — the floor,
        // ranking and DP scores all hang off this.
        let arch = presets::multi_node_eyeriss();
        let net = nets::alexnet();
        let model = TieredCost::fresh();
        let cfg = DpConfig::default();
        let planner = Planner::new(&arch, &net, 64, &cfg, &model);
        for span in [vec![2usize, 3], vec![2, 3, 4]] {
            let tbl = planner.span_table(&span, 1).expect("pipelinable span");
            for seg in enumerate_segment_schemes(&net, &arch, 64, &span, cfg.max_rounds) {
                let staged =
                    segment_lower_bound_with(&net, 64, &seg, &mut |li, ctx| {
                        tbl.ests[tbl.index[&CtxKey::of(li, ctx)]]
                    });
                let direct = model.estimate_segment(&arch, &net, 64, &seg);
                assert_eq!(staged, direct, "span {span:?}, seg {seg:?}");
                // Floor admissibility over the whole span.
                assert!(
                    tbl.floor <= staged.score() + 1e-9,
                    "floor {} above scheme score {}",
                    tbl.floor,
                    staged.score()
                );
            }
        }
    }

    #[test]
    fn speculation_never_changes_chains_or_counters() {
        // Tables and floors depend only on span shape + model, never the
        // incumbent, so the speculative pipeline must reproduce the
        // sequential planner exactly — chains AND PruneStats (tables_built
        // is counted at consume time, so it too is identical) — for every
        // window size and thread count.
        let arch = presets::multi_node_eyeriss();
        let net = nets::alexnet();
        let model = TieredCost::fresh();
        let base = DpConfig::default();
        let seq_cfg = DpConfig { solve_threads: 1, ..base };
        let (seq_chains, seq_stats) =
            Planner::new(&arch, &net, 64, &seq_cfg, &model).chains().unwrap();
        assert!(seq_stats.tables_built > 0, "alexnet must build some span tables");
        for (threads, window) in [(4usize, 0usize), (2, 1), (4, 3), (4, 8), (4, 1024)] {
            let cfg = DpConfig { solve_threads: threads, spec_window: window, ..base };
            let (chains, stats) =
                Planner::new(&arch, &net, 64, &cfg, &model).chains().unwrap();
            assert_eq!(
                chains_snapshot(&seq_chains),
                chains_snapshot(&chains),
                "threads={threads} window={window}: speculation changed the chains"
            );
            assert_eq!(
                format!("{seq_stats:?}"),
                format!("{stats:?}"),
                "threads={threads} window={window}: counters diverged"
            );
        }
    }

    #[test]
    fn insert_top_matches_stable_sort_truncate() {
        let arch = presets::bench_multi_node();
        let seg = |r: u64| {
            let mut s = Segment::single(0, &arch);
            s.rounds = r; // tag so ties are distinguishable
            s
        };
        let costs = [3.0, 1.0, 2.0, 1.0, f64::NAN, 0.5, 2.0, 1.0];
        let mut top: Vec<Node> = Vec::new();
        for (i, &c) in costs.iter().enumerate() {
            insert_top(&mut top, 3, Node { cost: c, seg: seg(i as u64), parent: None });
        }
        // Reference: stable sort by total order, truncate.
        let mut all: Vec<(f64, u64)> =
            costs.iter().enumerate().map(|(i, &c)| (c, i as u64)).collect();
        all.sort_by(|a, b| a.0.total_cmp(&b.0));
        all.truncate(3);
        let got: Vec<(f64, u64)> = top.iter().map(|n| (n.cost, n.seg.rounds)).collect();
        assert_eq!(format!("{got:?}"), format!("{all:?}"));
    }
}
