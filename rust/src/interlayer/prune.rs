//! Inter-layer conservative validity pruning and Pareto filtering
//! (paper §IV-B, Table VI).
//!
//! Validity is checked *without* exploring intra-layer schemes: a layer is
//! guaranteed infeasible if even its raw per-round data cannot fit the
//! aggregated GBUF capacity of the nodes allocated to it. The check is
//! conservative (never rejects a segment some intra-layer scheme could
//! realize), so pruning preserves optimality while removing most
//! candidates in practice. Candidate prioritization draws from the
//! *estimate* tier of the shared [`CostModel`], so pruning, DP scoring and
//! the intra-layer descent all score against one model object.

use std::collections::HashMap;

use super::Segment;
use crate::arch::ArchConfig;
use crate::cost::{segment_lower_bound_with, CostEstimate, CostModel, LayerCtx};
use crate::workloads::Network;

/// Conservative validity: for every pipelined layer, the per-round working
/// set (input slice + output slice + resident weights) must fit in the
/// aggregated GBUF capacity of its node region. Single-layer segments
/// stream from DRAM and are always valid.
pub fn conservative_valid(arch: &ArchConfig, net: &Network, batch: u64, seg: &Segment) -> bool {
    if !seg.spatial {
        return true;
    }
    let rb = seg.round_batch(batch);
    for (pos, &li) in seg.layers.iter().enumerate() {
        let l = &net.layers[li];
        let nodes = seg.regions[pos].0 * seg.regions[pos].1;
        let agg_words = nodes * arch.gbuf_words();
        let (inp, out, wgt) = l.role_volumes(rb);
        let need = inp + out + wgt;
        if need > agg_words {
            return false;
        }
    }
    true
}

/// A pruned, prioritized inter-layer candidate.
#[derive(Debug, Clone)]
pub struct RankedSegment {
    pub seg: Segment,
    pub est: CostEstimate,
}

/// Statistics for Table VI, plus the span-level counters of the staged
/// inter-layer planner's chain-level branch-and-bound
/// (`interlayer::planner`). The scheme-level counters (`total`,
/// `after_validity`, `after_pareto`) only cover spans that were actually
/// enumerated: a span skipped by the admissible floor contributes to
/// `spans_pruned` and nothing else — its schemes were never streamed.
#[derive(Debug, Clone, Copy, Default)]
pub struct PruneStats {
    pub total: usize,
    pub after_validity: usize,
    pub after_pareto: usize,
    /// Candidate `(end layer, span)` pairs the planner examined. Zero for
    /// direct `prune_and_rank` calls, which rank one span's schemes.
    pub spans_total: usize,
    /// Spans skipped outright: the admissible span floor (computed from
    /// `CostModel::estimate_layer` before any scheme enumeration) already
    /// met the k_S-th incumbent chain cost at the span's end layer.
    pub spans_pruned: usize,
    /// Individual streamed schemes dropped by the chain-level bound
    /// (`score + best_prev >= incumbent`) before Pareto ranking.
    pub schemes_bound_pruned: usize,
    /// Multi-layer spans whose context table was built. Counted when the
    /// planner *consumes* a table (never when a speculative worker produces
    /// one), so the value is identical for any thread count / speculation
    /// window — `tests` assert PruneStats equality across 1-vs-N threads.
    pub tables_built: usize,
}

impl PruneStats {
    /// JSON object shared by bench reports and service responses.
    pub fn to_json(&self) -> crate::util::json::Json {
        let mut o = crate::util::json::Json::obj();
        o.set("total", self.total.into())
            .set("after_validity", self.after_validity.into())
            .set("after_pareto", self.after_pareto.into())
            .set("spans_total", self.spans_total.into())
            .set("spans_pruned", self.spans_pruned.into())
            .set("schemes_bound_pruned", self.schemes_bound_pruned.into())
            .set("tables_built", self.tables_built.into());
        o
    }
}

/// Apply conservative validity pruning then Pareto filtering on the
/// model's (energy, latency) estimates, returning survivors sorted by
/// score.
///
/// The estimates are pure per-candidate arithmetic, so large candidate
/// sets are scored across the scoped worker pool; results keep candidate
/// order, making the output independent of the thread count.
pub fn prune_and_rank(
    arch: &ArchConfig,
    net: &Network,
    batch: u64,
    candidates: Vec<Segment>,
    model: &dyn CostModel,
) -> (Vec<RankedSegment>, PruneStats) {
    prune_and_rank_threaded(arch, net, batch, candidates, 0, model)
}

/// Hashable identity of one per-layer estimate context (`LayerCtx` holds
/// an f64, so the key carries its bits). Shared with the staged planner's
/// per-span context tables (`interlayer::planner`).
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub(crate) struct CtxKey {
    li: usize,
    nodes: u64,
    round_batch: u64,
    rounds: u64,
    ifm_on_chip: bool,
    ofm_on_chip: bool,
    dram_hops_bits: u64,
}

impl CtxKey {
    pub(crate) fn of(li: usize, ctx: &LayerCtx) -> CtxKey {
        CtxKey {
            li,
            nodes: ctx.nodes,
            round_batch: ctx.round_batch,
            rounds: ctx.rounds,
            ifm_on_chip: ctx.ifm_on_chip,
            ofm_on_chip: ctx.ofm_on_chip,
            dram_hops_bits: ctx.dram_hops.to_bits(),
        }
    }
}

/// [`prune_and_rank`] with an explicit estimation thread count: `0` keeps
/// the size-based auto heuristic, `1` forces inline scoring. Callers that
/// already run on the scoped worker pool (the parallel inter-layer DP)
/// pass `1` so the pools don't nest and multiply.
///
/// Segment estimation is *staged*: a span's candidates are a cartesian
/// product of per-layer regions and round counts, so the same
/// `(layer, context)` lower bound recurs across most of them. The distinct
/// contexts are collected first (deterministic first-seen order), scored
/// once each through the model's estimate tier — across the worker pool
/// for large sets — and every candidate's estimate is then assembled from
/// the memo by the exact accumulation `cost::segment_lower_bound` runs, so
/// the totals are bit-identical to per-candidate scoring.
pub fn prune_and_rank_threaded(
    arch: &ArchConfig,
    net: &Network,
    batch: u64,
    candidates: Vec<Segment>,
    threads: usize,
    model: &dyn CostModel,
) -> (Vec<RankedSegment>, PruneStats) {
    let mut stats = PruneStats { total: candidates.len(), ..Default::default() };
    let valid: Vec<Segment> =
        candidates.into_iter().filter(|seg| conservative_valid(arch, net, batch, seg)).collect();
    stats.after_validity = valid.len();

    // Stage 1: the distinct (layer, context) estimate keys, in first-seen
    // order (a dry assembly run records which contexts each candidate
    // reads).
    let mut keys: Vec<(usize, LayerCtx)> = Vec::new();
    let mut index: HashMap<CtxKey, usize> = HashMap::new();
    for seg in &valid {
        segment_lower_bound_with(net, batch, seg, &mut |li, ctx| {
            index.entry(CtxKey::of(li, ctx)).or_insert_with(|| {
                keys.push((li, *ctx));
                keys.len() - 1
            });
            CostEstimate { energy_pj: 0.0, latency_cycles: 0.0 }
        });
    }

    // Stage 2: score each distinct context once. An estimate costs ~1us;
    // spawning the scoped pool costs ~100us — only shard genuinely large
    // context sets (full-scale meshes with long spans).
    let threads = if threads == 0 {
        if keys.len() >= 1024 {
            crate::util::available_threads()
        } else {
            1
        }
    } else {
        threads
    };
    let layer_ests = crate::util::par_map(&keys, threads, |(li, ctx)| {
        model.estimate_layer(arch, &net.layers[*li], ctx)
    });

    // Stage 3: assemble every candidate's estimate from the memo.
    let ests: Vec<CostEstimate> = valid
        .iter()
        .map(|seg| {
            segment_lower_bound_with(net, batch, seg, &mut |li, ctx| {
                layer_ests[index[&CtxKey::of(li, ctx)]]
            })
        })
        .collect();
    let ranked: Vec<RankedSegment> =
        valid.into_iter().zip(ests).map(|(seg, est)| RankedSegment { seg, est }).collect();
    let ranked = pareto_rank(ranked);
    stats.after_pareto = ranked.len();
    (ranked, stats)
}

/// Pareto prune on (energy, latency) — drop candidates dominated by *any*
/// other candidate in both objectives (paper §IV-B: "skipping the schemes
/// with non-Pareto-optimal access counts") — then sort the survivors by
/// score. The sort is stable and `total_cmp`-ordered, so equal scores keep
/// candidate order and a NaN score (a broken external estimate tier) sinks
/// to the end instead of panicking the solver. Shared by the eager
/// [`prune_and_rank`] path and the streamed `interlayer::planner` pipeline.
pub(crate) fn pareto_rank(mut ranked: Vec<RankedSegment>) -> Vec<RankedSegment> {
    let mut keep = vec![true; ranked.len()];
    for i in 0..ranked.len() {
        for j in 0..ranked.len() {
            if i == j {
                continue;
            }
            if dominates(&ranked[j].est, &ranked[i].est) {
                keep[i] = false;
                break;
            }
        }
    }
    let mut it = keep.iter();
    ranked.retain(|_| *it.next().unwrap());
    ranked.sort_by(|a, b| a.est.score().total_cmp(&b.est.score()));
    ranked
}

fn dominates(a: &CostEstimate, b: &CostEstimate) -> bool {
    (a.energy_pj < b.energy_pj && a.latency_cycles <= b.latency_cycles)
        || (a.energy_pj <= b.energy_pj && a.latency_cycles < b.latency_cycles)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::cost::TieredCost;
    use crate::interlayer::enumerate_segment_schemes;
    use crate::workloads::nets;

    #[test]
    fn single_layer_always_valid() {
        let arch = presets::multi_node_eyeriss();
        let net = nets::vggnet();
        for i in 0..net.len() {
            let seg = Segment::single(i, &arch);
            assert!(conservative_valid(&arch, &net, 64, &seg), "layer {i}");
        }
    }

    #[test]
    fn oversized_pipeline_round_rejected() {
        let arch = presets::multi_node_eyeriss();
        let net = nets::vggnet();
        // conv1_1/conv1_2 at 224x224 x 64ch x batch-64 rounds=1 cannot fit
        // on-chip: 64*224*224*64 words >> 8MB.
        let seg = Segment {
            layers: vec![0, 1],
            regions: vec![(8, 16), (8, 16)],
            spatial: true,
            rounds: 1,
        };
        assert!(!conservative_valid(&arch, &net, 64, &seg));
        // Finer granularity (one image per round) can fit... or at least
        // prunes strictly less.
        let seg64 = Segment { rounds: 64, ..seg.clone() };
        let v64 = conservative_valid(&arch, &net, 64, &seg64);
        let v1 = conservative_valid(&arch, &net, 64, &seg);
        assert!(v64 as u8 >= v1 as u8);
    }

    #[test]
    fn pruning_reduces_candidates() {
        let arch = presets::multi_node_eyeriss();
        let net = nets::alexnet();
        let cands = enumerate_segment_schemes(&net, &arch, 64, &[2, 3, 4], 64);
        let total = cands.len();
        let (ranked, stats) = prune_and_rank(&arch, &net, 64, cands, &TieredCost::fresh());
        assert_eq!(stats.total, total);
        assert!(stats.after_validity <= stats.total);
        assert!(stats.after_pareto <= stats.after_validity);
        assert!(!ranked.is_empty());
        // sorted by score
        for w in ranked.windows(2) {
            assert!(w[0].est.score() <= w[1].est.score());
        }
    }

    #[test]
    fn survivors_form_a_pareto_front() {
        // No survivor may be dominated by any other — including by
        // candidates enumerated *after* it (the seed's dominance loop
        // stopped at j == i and only ever compared against earlier ones).
        let arch = presets::multi_node_eyeriss();
        let net = nets::alexnet();
        let cands = enumerate_segment_schemes(&net, &arch, 64, &[2, 3], 64);
        let (ranked, _) = prune_and_rank(&arch, &net, 64, cands, &TieredCost::fresh());
        for (i, a) in ranked.iter().enumerate() {
            for (j, b) in ranked.iter().enumerate() {
                if i != j {
                    assert!(
                        !dominates(&a.est, &b.est),
                        "survivor {j} is dominated by survivor {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn staged_estimates_match_per_candidate_scoring() {
        // The staged (distinct-context memo + shared assembly) estimates
        // must equal per-candidate `estimate_segment` bit for bit — the
        // ranking, Pareto front and DP scores all hang off this.
        let arch = presets::multi_node_eyeriss();
        let net = nets::alexnet();
        let model = TieredCost::fresh();
        let cands = enumerate_segment_schemes(&net, &arch, 64, &[2, 3, 4], 64);
        let (ranked, _) = prune_and_rank(&arch, &net, 64, cands, &model);
        assert!(!ranked.is_empty());
        for r in &ranked {
            let direct = model.estimate_segment(&arch, &net, 64, &r.seg);
            assert_eq!(r.est, direct, "staged estimate diverged for {:?}", r.seg);
        }
    }

    #[test]
    fn pareto_drops_dominated() {
        let a = CostEstimate { energy_pj: 1.0, latency_cycles: 1.0 };
        let b = CostEstimate { energy_pj: 2.0, latency_cycles: 2.0 };
        assert!(dominates(&a, &b));
        assert!(!dominates(&b, &a));
        let c = CostEstimate { energy_pj: 0.5, latency_cycles: 3.0 };
        assert!(!dominates(&a, &c) && !dominates(&c, &a));
    }

    #[test]
    fn validity_never_rejects_what_finer_rounds_accept_more_of() {
        // Monotonicity property: increasing rounds (finer slices) never
        // turns a valid segment invalid.
        let arch = presets::multi_node_eyeriss();
        let net = nets::alexnet();
        for span in [vec![2usize, 3], vec![4, 5, 6]] {
            let mk = |rounds: u64| Segment {
                layers: span.clone(),
                regions: span.iter().map(|_| (4u64, 16u64)).collect(),
                spatial: true,
                rounds,
            };
            let mut prev = false;
            for rounds in [1u64, 2, 4, 8, 16, 32, 64] {
                let v = conservative_valid(&arch, &net, 64, &mk(rounds));
                assert!(v || !prev, "validity regressed at rounds={rounds}");
                prev = v;
            }
        }
    }
}
