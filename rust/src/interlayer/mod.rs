//! Inter-layer scheduling structures (paper §III-A, §IV-B): segment
//! slicing (temporal) and layer pipelining (spatial), plus the enumeration
//! of inter-layer schemes for a segment.
//!
//! A *segment* is a group of consecutive layers (in DAG topological order)
//! that execute together: single-layer segments time-share the whole
//! accelerator; multi-layer segments pipeline spatially across disjoint
//! node regions, forwarding intermediate fmaps on-chip at a per-round
//! granularity (`rounds` batch slices).

pub mod dp;
pub mod matching;
pub mod planner;
pub mod prune;

use crate::arch::ArchConfig;
use crate::directives::LayerScheme;
use crate::util::divisors;
use crate::workloads::{Network, PrevRef};

/// One segment with its inter-layer scheme decided: layer span, per-layer
/// node regions, and the pipelining granularity.
#[derive(Debug, Clone, PartialEq)]
pub struct Segment {
    /// Layer indices, contiguous in topological order.
    pub layers: Vec<usize>,
    /// Node region (w, h) per layer. For single-layer segments this is the
    /// whole mesh.
    pub regions: Vec<(u64, u64)>,
    /// Spatial pipelining on (multi-layer segments only).
    pub spatial: bool,
    /// Number of batch rounds forwarded through the pipeline (the
    /// granularity/timing choice of Fig. 2 (2)).
    pub rounds: u64,
}

impl Segment {
    /// Single layer occupying the full mesh, no pipelining.
    pub fn single(layer: usize, arch: &ArchConfig) -> Segment {
        Segment { layers: vec![layer], regions: vec![arch.nodes], spatial: false, rounds: 1 }
    }

    pub fn len(&self) -> usize {
        self.layers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    /// Per-round batch for intra-layer scheduling within this segment.
    pub fn round_batch(&self, batch: u64) -> u64 {
        crate::util::ceil_div(batch, self.rounds)
    }

    /// Is layer `li`'s input fmap produced inside this segment (and thus
    /// forwarded on-chip when pipelining)?
    pub fn ifm_on_chip(&self, net: &Network, li: usize) -> bool {
        if !self.spatial {
            return false;
        }
        net.prevs[li].iter().all(|p| match p {
            PrevRef::Input => false,
            PrevRef::Layer(j) => self.layers.contains(j),
        })
    }

    /// Is layer `li`'s output consumed entirely inside the segment (so its
    /// ofm never goes to DRAM)? The network's final layers always spill.
    pub fn ofm_on_chip(&self, net: &Network, li: usize) -> bool {
        if !self.spatial {
            return false;
        }
        let nexts = net.nexts();
        !nexts[li].is_empty() && nexts[li].iter().all(|j| self.layers.contains(j))
    }
}

/// A complete network schedule: an ordered chain of segments covering every
/// layer exactly once, with the chosen intra-layer scheme per layer.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub segments: Vec<(Segment, Vec<LayerScheme>)>,
}

impl Schedule {
    pub fn num_layers(&self) -> usize {
        self.segments.iter().map(|(s, _)| s.len()).sum()
    }
}

/// Stream the candidate inter-layer schemes of the segment spanning
/// `layers` (already known to be a contiguous topo range) to `visit`:
/// every column split of the mesh into one strip per layer (the
/// spatial-allocation axis) x every pipelining-rounds divisor of the batch
/// (the granularity/timing axis). On the paper's 16x16 mesh this is
/// *hundreds* of schemes per segment (Table VI: AlexNet 700), which is
/// exactly what makes the inter-layer space expensive for exhaustive
/// solvers and cheap for KAPLA's conservative pruning.
///
/// The enumeration is lazy: one scratch [`Segment`] is reused for the
/// whole span — the composition generator rewrites its `regions` in place
/// and each rounds option rewrites only `rounds` — so a caller that
/// rejects most candidates (validity pruning, the planner's chain-level
/// bound) allocates nothing per rejected scheme; survivors are cloned by
/// the visitor. Candidates arrive in exactly the order
/// [`enumerate_segment_schemes`] materializes them. The visitor returns
/// `true` to continue.
pub fn visit_segment_schemes(
    net: &Network,
    arch: &ArchConfig,
    batch: u64,
    layers: &[usize],
    max_rounds: u64,
    mut visit: impl FnMut(&Segment) -> bool,
) {
    let _ = net;
    if layers.len() == 1 {
        visit(&Segment::single(layers[0], arch));
        return;
    }
    if !arch.spatial_layer_pipe {
        return; // multi-layer segments need spatial pipelining support
    }
    let (mesh_w, mesh_h) = arch.nodes;
    if (layers.len() as u64) > mesh_w {
        return; // cannot give each layer a column strip
    }
    let rounds_opts: Vec<u64> =
        divisors(batch).into_iter().filter(|&r| r <= max_rounds).collect();
    let mut seg = Segment {
        layers: layers.to_vec(),
        regions: vec![(0, mesh_h); layers.len()],
        spatial: true,
        rounds: 1,
    };
    let mut widths = Compositions::new(mesh_w, layers.len());
    while let Some(ws) = widths.next_slice() {
        for (slot, &w) in seg.regions.iter_mut().zip(ws) {
            *slot = (w, mesh_h);
        }
        for &rounds in &rounds_opts {
            seg.rounds = rounds;
            if !visit(&seg) {
                return;
            }
        }
    }
}

/// Materialized form of [`visit_segment_schemes`], for callers that want
/// the whole candidate set at once (the exact-DP baselines, Table VI).
pub fn enumerate_segment_schemes(
    net: &Network,
    arch: &ArchConfig,
    batch: u64,
    layers: &[usize],
    max_rounds: u64,
) -> Vec<Segment> {
    let mut out = Vec::new();
    visit_segment_schemes(net, arch, batch, layers, max_rounds, |seg| {
        out.push(seg.clone());
        true
    });
    out
}

/// Iterative generator of all ordered compositions of `total` into
/// `parts` positive integers, in the lexicographic order the recursive
/// enumeration it replaced produced: `(1, 1, .., rest)` first,
/// `(total-parts+1, 1, .., 1)` last. The successor is computed in place,
/// so streaming all C(total-1, parts-1) compositions allocates one buffer
/// instead of one `Vec` per composition — the allocation blow-up the old
/// `compositions()` paid per span (micro-benchmarked in `perf_hotpath`).
pub struct Compositions {
    buf: Vec<u64>,
    total: u64,
    started: bool,
    done: bool,
}

impl Compositions {
    /// Generator over compositions of `total` into `parts` parts
    /// (`parts >= 1`). A `total` smaller than `parts` yields none.
    pub fn new(total: u64, parts: usize) -> Compositions {
        assert!(parts >= 1);
        let done = (parts as u64) > total;
        Compositions { buf: vec![1; parts], total, started: false, done }
    }

    /// The next composition, borrowed until the following call (lending
    /// iteration: no per-item allocation), or `None` when exhausted.
    pub fn next_slice(&mut self) -> Option<&[u64]> {
        if self.done {
            return None;
        }
        let p = self.buf.len();
        if !self.started {
            self.started = true;
            // Lexicographically smallest: all ones, remainder at the end.
            for v in self.buf.iter_mut() {
                *v = 1;
            }
            self.buf[p - 1] = self.total - (p as u64 - 1);
            return Some(&self.buf);
        }
        // Successor: bump the rightmost position whose suffix still has a
        // unit of slack to give, then reset that suffix to its smallest
        // shape (ones, remainder at the end).
        let mut suffix = self.buf[p - 1];
        let mut bump = None;
        for j in (0..p.saturating_sub(1)).rev() {
            if suffix > (p - 1 - j) as u64 {
                bump = Some(j);
                break;
            }
            suffix += self.buf[j];
        }
        let Some(j) = bump else {
            self.done = true;
            return None;
        };
        self.buf[j] += 1;
        for v in &mut self.buf[j + 1..] {
            *v = 1;
        }
        self.buf[p - 1] = suffix - 1 - (p - 2 - j) as u64;
        Some(&self.buf)
    }
}

/// Enumerate contiguous candidate segment spans ending at layer `end`
/// (inclusive), up to `max_len` layers.
pub fn candidate_spans(end: usize, max_len: usize) -> Vec<Vec<usize>> {
    let mut out = Vec::new();
    for len in 1..=max_len.min(end + 1) {
        let start = end + 1 - len;
        out.push((start..=end).collect());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::workloads::nets;

    #[test]
    fn single_segment_basics() {
        let arch = presets::multi_node_eyeriss();
        let s = Segment::single(3, &arch);
        assert_eq!(s.len(), 1);
        assert_eq!(s.regions[0], (16, 16));
        assert!(!s.spatial);
        assert_eq!(s.round_batch(64), 64);
    }

    #[test]
    fn candidate_spans_contiguous() {
        let spans = candidate_spans(4, 3);
        assert_eq!(spans, vec![vec![4], vec![3, 4], vec![2, 3, 4]]);
        assert_eq!(candidate_spans(0, 8), vec![vec![0]]);
    }

    #[test]
    fn enumerate_generates_policy_x_rounds() {
        let net = nets::alexnet();
        let arch = presets::multi_node_eyeriss();
        let schemes = enumerate_segment_schemes(&net, &arch, 64, &[2, 3, 4], 64);
        assert!(!schemes.is_empty());
        // rounds are divisors of 64
        for s in &schemes {
            assert!(64 % s.rounds == 0);
            assert!(s.spatial);
            assert_eq!(s.regions.len(), 3);
            let total_w: u64 = s.regions.iter().map(|r| r.0).sum();
            assert_eq!(total_w, 16);
        }
    }

    #[test]
    fn allocation_axis_enumerates_all_splits() {
        let net = nets::alexnet();
        let arch = presets::multi_node_eyeriss();
        let schemes = enumerate_segment_schemes(&net, &arch, 64, &[1, 2], 64);
        // 15 column splits of a 16-wide mesh into 2 strips x 7 round
        // divisors of 64 = 105 candidate schemes ("hundreds" per paper).
        assert_eq!(schemes.len(), 15 * 7);
        assert!(schemes.iter().any(|s| s.regions[1].0 > s.regions[0].0));
        assert!(schemes.iter().any(|s| s.regions[1].0 < s.regions[0].0));
    }

    /// Reference recursive enumeration (the seed implementation) — the
    /// iterative generator must reproduce its output order exactly.
    fn compositions_recursive(total: u64, parts: usize) -> Vec<Vec<u64>> {
        assert!(parts >= 1);
        if parts == 1 {
            return vec![vec![total]];
        }
        let mut out = Vec::new();
        for first in 1..=(total - (parts as u64 - 1)) {
            for mut rest in compositions_recursive(total - first, parts - 1) {
                let mut v = Vec::with_capacity(parts);
                v.push(first);
                v.append(&mut rest);
                out.push(v);
            }
        }
        out
    }

    fn collect_compositions(total: u64, parts: usize) -> Vec<Vec<u64>> {
        let mut comp_gen = Compositions::new(total, parts);
        let mut out = Vec::new();
        while let Some(c) = comp_gen.next_slice() {
            out.push(c.to_vec());
        }
        out
    }

    #[test]
    fn compositions_count_and_sum() {
        let cs = collect_compositions(6, 3);
        assert_eq!(cs.len(), 10); // C(5,2)
        for c in &cs {
            assert_eq!(c.iter().sum::<u64>(), 6);
            assert!(c.iter().all(|&x| x >= 1));
        }
    }

    #[test]
    fn compositions_iterative_matches_recursive_order() {
        for (total, parts) in [(1u64, 1usize), (4, 1), (4, 4), (6, 3), (8, 2), (16, 4), (9, 5)] {
            assert_eq!(
                collect_compositions(total, parts),
                compositions_recursive(total, parts),
                "({total}, {parts})"
            );
        }
        // total < parts has no composition into positive integers.
        assert!(collect_compositions(2, 3).is_empty());
    }

    #[test]
    fn streaming_matches_materialized_enumeration() {
        let net = nets::alexnet();
        let arch = presets::multi_node_eyeriss();
        for span in [vec![3usize], vec![2, 3], vec![2, 3, 4]] {
            let eager = enumerate_segment_schemes(&net, &arch, 64, &span, 64);
            let mut streamed = Vec::new();
            visit_segment_schemes(&net, &arch, 64, &span, 64, |s| {
                streamed.push(s.clone());
                true
            });
            assert_eq!(eager, streamed, "span {span:?}");
        }
        // Early stop is respected.
        let mut n = 0;
        visit_segment_schemes(&net, &arch, 64, &[2, 3, 4], 64, |_| {
            n += 1;
            n < 5
        });
        assert_eq!(n, 5);
    }

    #[test]
    fn edge_arch_refuses_multilayer() {
        let net = nets::alexnet();
        let arch = presets::edge_tpu();
        assert!(enumerate_segment_schemes(&net, &arch, 1, &[1, 2], 8).is_empty());
        assert_eq!(enumerate_segment_schemes(&net, &arch, 1, &[1], 8).len(), 1);
    }

    #[test]
    fn ifm_on_chip_requires_in_segment_producer() {
        let net = nets::alexnet();
        let arch = presets::multi_node_eyeriss();
        let schemes = enumerate_segment_schemes(&net, &arch, 64, &[2, 3], 64);
        let seg = &schemes[0];
        // layer 2's producer (1) is outside; layer 3's producer (2) inside.
        assert!(!seg.ifm_on_chip(&net, 2));
        assert!(seg.ifm_on_chip(&net, 3));
        // layer 2's output feeds 3 (inside): stays on chip.
        assert!(seg.ofm_on_chip(&net, 2));
        assert!(!seg.ofm_on_chip(&net, 3));
    }

    #[test]
    fn round_batch_ceils() {
        let arch = presets::multi_node_eyeriss();
        let mut s = Segment::single(0, &arch);
        s.rounds = 8;
        assert_eq!(s.round_batch(64), 8);
        s.rounds = 3;
        assert_eq!(s.round_batch(64), 22);
    }

    #[test]
    fn too_many_layers_for_mesh_rejected() {
        let net = nets::vggnet();
        let arch = presets::bench_multi_node(); // 4x4 mesh
        let span: Vec<usize> = (0..6).collect();
        assert!(enumerate_segment_schemes(&net, &arch, 64, &span, 64).is_empty());
    }
}
