//! Inter-layer scheduling structures (paper §III-A, §IV-B): segment
//! slicing (temporal) and layer pipelining (spatial), plus the enumeration
//! of inter-layer schemes for a segment.
//!
//! A *segment* is a group of consecutive layers (in DAG topological order)
//! that execute together: single-layer segments time-share the whole
//! accelerator; multi-layer segments pipeline spatially across disjoint
//! node regions, forwarding intermediate fmaps on-chip at a per-round
//! granularity (`rounds` batch slices).

pub mod dp;
pub mod matching;
pub mod prune;

use crate::arch::ArchConfig;
use crate::directives::LayerScheme;
use crate::util::divisors;
use crate::workloads::{Network, PrevRef};

/// One segment with its inter-layer scheme decided: layer span, per-layer
/// node regions, and the pipelining granularity.
#[derive(Debug, Clone, PartialEq)]
pub struct Segment {
    /// Layer indices, contiguous in topological order.
    pub layers: Vec<usize>,
    /// Node region (w, h) per layer. For single-layer segments this is the
    /// whole mesh.
    pub regions: Vec<(u64, u64)>,
    /// Spatial pipelining on (multi-layer segments only).
    pub spatial: bool,
    /// Number of batch rounds forwarded through the pipeline (the
    /// granularity/timing choice of Fig. 2 (2)).
    pub rounds: u64,
}

impl Segment {
    /// Single layer occupying the full mesh, no pipelining.
    pub fn single(layer: usize, arch: &ArchConfig) -> Segment {
        Segment { layers: vec![layer], regions: vec![arch.nodes], spatial: false, rounds: 1 }
    }

    pub fn len(&self) -> usize {
        self.layers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    /// Per-round batch for intra-layer scheduling within this segment.
    pub fn round_batch(&self, batch: u64) -> u64 {
        crate::util::ceil_div(batch, self.rounds)
    }

    /// Is layer `li`'s input fmap produced inside this segment (and thus
    /// forwarded on-chip when pipelining)?
    pub fn ifm_on_chip(&self, net: &Network, li: usize) -> bool {
        if !self.spatial {
            return false;
        }
        net.prevs[li].iter().all(|p| match p {
            PrevRef::Input => false,
            PrevRef::Layer(j) => self.layers.contains(j),
        })
    }

    /// Is layer `li`'s output consumed entirely inside the segment (so its
    /// ofm never goes to DRAM)? The network's final layers always spill.
    pub fn ofm_on_chip(&self, net: &Network, li: usize) -> bool {
        if !self.spatial {
            return false;
        }
        let nexts = net.nexts();
        !nexts[li].is_empty() && nexts[li].iter().all(|j| self.layers.contains(j))
    }
}

/// A complete network schedule: an ordered chain of segments covering every
/// layer exactly once, with the chosen intra-layer scheme per layer.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub segments: Vec<(Segment, Vec<LayerScheme>)>,
}

impl Schedule {
    pub fn num_layers(&self) -> usize {
        self.segments.iter().map(|(s, _)| s.len()).sum()
    }
}

/// Enumerate the candidate inter-layer schemes of the segment spanning
/// `layers` (already known to be a contiguous topo range): every column
/// split of the mesh into one strip per layer (the spatial-allocation
/// axis) x every pipelining-rounds divisor of the batch (the
/// granularity/timing axis). On the paper's 16x16 mesh this yields
/// *hundreds* of schemes per segment (Table VI: AlexNet 700), which is
/// exactly what makes the inter-layer space expensive for exhaustive
/// solvers and cheap for KAPLA's conservative pruning.
pub fn enumerate_segment_schemes(
    net: &Network,
    arch: &ArchConfig,
    batch: u64,
    layers: &[usize],
    max_rounds: u64,
) -> Vec<Segment> {
    let _ = net;
    let mut out = Vec::new();
    if layers.len() == 1 {
        out.push(Segment::single(layers[0], arch));
        return out;
    }
    if !arch.spatial_layer_pipe {
        return out; // multi-layer segments need spatial pipelining support
    }
    let (mesh_w, mesh_h) = arch.nodes;
    if (layers.len() as u64) > mesh_w {
        return out; // cannot give each layer a column strip
    }
    let rounds_opts: Vec<u64> =
        divisors(batch).into_iter().filter(|&r| r <= max_rounds).collect();
    for widths in compositions(mesh_w, layers.len()) {
        let regions: Vec<(u64, u64)> = widths.iter().map(|&w| (w, mesh_h)).collect();
        for &rounds in &rounds_opts {
            out.push(Segment {
                layers: layers.to_vec(),
                regions: regions.clone(),
                spatial: true,
                rounds,
            });
        }
    }
    out
}

/// All ordered compositions of `total` into `parts` positive integers.
fn compositions(total: u64, parts: usize) -> Vec<Vec<u64>> {
    assert!(parts >= 1);
    if parts == 1 {
        return vec![vec![total]];
    }
    let mut out = Vec::new();
    for first in 1..=(total - (parts as u64 - 1)) {
        for mut rest in compositions(total - first, parts - 1) {
            let mut v = Vec::with_capacity(parts);
            v.push(first);
            v.append(&mut rest);
            out.push(v);
        }
    }
    out
}

/// Enumerate contiguous candidate segment spans ending at layer `end`
/// (inclusive), up to `max_len` layers.
pub fn candidate_spans(end: usize, max_len: usize) -> Vec<Vec<usize>> {
    let mut out = Vec::new();
    for len in 1..=max_len.min(end + 1) {
        let start = end + 1 - len;
        out.push((start..=end).collect());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::workloads::nets;

    #[test]
    fn single_segment_basics() {
        let arch = presets::multi_node_eyeriss();
        let s = Segment::single(3, &arch);
        assert_eq!(s.len(), 1);
        assert_eq!(s.regions[0], (16, 16));
        assert!(!s.spatial);
        assert_eq!(s.round_batch(64), 64);
    }

    #[test]
    fn candidate_spans_contiguous() {
        let spans = candidate_spans(4, 3);
        assert_eq!(spans, vec![vec![4], vec![3, 4], vec![2, 3, 4]]);
        assert_eq!(candidate_spans(0, 8), vec![vec![0]]);
    }

    #[test]
    fn enumerate_generates_policy_x_rounds() {
        let net = nets::alexnet();
        let arch = presets::multi_node_eyeriss();
        let schemes = enumerate_segment_schemes(&net, &arch, 64, &[2, 3, 4], 64);
        assert!(!schemes.is_empty());
        // rounds are divisors of 64
        for s in &schemes {
            assert!(64 % s.rounds == 0);
            assert!(s.spatial);
            assert_eq!(s.regions.len(), 3);
            let total_w: u64 = s.regions.iter().map(|r| r.0).sum();
            assert_eq!(total_w, 16);
        }
    }

    #[test]
    fn allocation_axis_enumerates_all_splits() {
        let net = nets::alexnet();
        let arch = presets::multi_node_eyeriss();
        let schemes = enumerate_segment_schemes(&net, &arch, 64, &[1, 2], 64);
        // 15 column splits of a 16-wide mesh into 2 strips x 7 round
        // divisors of 64 = 105 candidate schemes ("hundreds" per paper).
        assert_eq!(schemes.len(), 15 * 7);
        assert!(schemes.iter().any(|s| s.regions[1].0 > s.regions[0].0));
        assert!(schemes.iter().any(|s| s.regions[1].0 < s.regions[0].0));
    }

    #[test]
    fn compositions_count_and_sum() {
        let cs = compositions(6, 3);
        assert_eq!(cs.len(), 10); // C(5,2)
        for c in &cs {
            assert_eq!(c.iter().sum::<u64>(), 6);
            assert!(c.iter().all(|&x| x >= 1));
        }
    }

    #[test]
    fn edge_arch_refuses_multilayer() {
        let net = nets::alexnet();
        let arch = presets::edge_tpu();
        assert!(enumerate_segment_schemes(&net, &arch, 1, &[1, 2], 8).is_empty());
        assert_eq!(enumerate_segment_schemes(&net, &arch, 1, &[1], 8).len(), 1);
    }

    #[test]
    fn ifm_on_chip_requires_in_segment_producer() {
        let net = nets::alexnet();
        let arch = presets::multi_node_eyeriss();
        let schemes = enumerate_segment_schemes(&net, &arch, 64, &[2, 3], 64);
        let seg = &schemes[0];
        // layer 2's producer (1) is outside; layer 3's producer (2) inside.
        assert!(!seg.ifm_on_chip(&net, 2));
        assert!(seg.ifm_on_chip(&net, 3));
        // layer 2's output feeds 3 (inside): stays on chip.
        assert!(seg.ofm_on_chip(&net, 2));
        assert!(!seg.ofm_on_chip(&net, 3));
    }

    #[test]
    fn round_batch_ceils() {
        let arch = presets::multi_node_eyeriss();
        let mut s = Segment::single(0, &arch);
        s.rounds = 8;
        assert_eq!(s.round_batch(64), 8);
        s.rounds = 3;
        assert_eq!(s.round_batch(64), 22);
    }

    #[test]
    fn too_many_layers_for_mesh_rejected() {
        let net = nets::vggnet();
        let arch = presets::bench_multi_node(); // 4x4 mesh
        let span: Vec<usize> = (0..6).collect();
        assert!(enumerate_segment_schemes(&net, &arch, 64, &span, 64).is_empty());
    }
}
