//! KAPLA — pragmatic representation and fast solving of scalable NN
//! accelerator dataflow (Li & Gao, 2023).
//!
//! This crate reproduces the paper's full system as a three-layer stack:
//!
//! * **Layer 3 (this crate)** — the scheduling coordinator: tensor-centric
//!   dataflow directives, hardware templates, the KAPLA solver (inter-layer
//!   pruning + DP prioritization, intra-layer bottom-up cost descending),
//!   baseline solvers (exhaustive, random, ML/simulated annealing), and an
//!   nn-dataflow-style detailed simulator used as the evaluation oracle.
//! * **Layer 2 (python/compile/model.py)** — a JAX surrogate cost model
//!   (MLP fwd/bwd training step) and a batched analytical cost evaluator,
//!   AOT-lowered to HLO text at build time.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels (blocked matmul
//!   and batched cost evaluation) called from the Layer-2 graphs.
//!
//! Python never runs on the scheduling path: the Rust binary is
//! self-contained and dependency-free by default. The PJRT execution of
//! the AOT artifacts lives behind the `pjrt` cargo feature (`runtime`
//! module) and needs vendored `xla`/`anyhow` crates; without it the
//! native Rust implementations (bit-compatible by construction) serve
//! every code path.

// Lint policy: the solver plumbing deliberately threads its context as
// explicit parameters (arch/net/batch/objective/memo caches) instead of a
// grab-bag struct, and the DP tables index several parallel vectors.
#![allow(clippy::too_many_arguments)]
#![allow(clippy::needless_range_loop)]

pub mod arch;
pub mod coordinator;
pub mod cost;
pub mod directives;
pub mod interlayer;
pub mod mapping;
pub mod partition;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod solvers;
pub mod util;
pub mod workloads;
