//! The network zoo evaluated by the paper (§V): AlexNet, MobileNet-v1,
//! VGG-16, GoogLeNet-v1, ResNet-50, a PRIME-style MLP, and a seq2seq-style
//! LSTM. Dimensions follow the original publications; grouped convolutions
//! (AlexNet) are modeled dense, as nn-dataflow does.

use super::dag::{Network, PrevRef};
use super::layer::Layer;

/// AlexNet [27]: 5 convs + 3 pools + 3 FCs on 3x224(227)x224 input.
pub fn alexnet() -> Network {
    let mut n = Network::new("alexnet", 3, 227, 227);
    n.chain(Layer::conv("conv1", 3, 96, 55, 11, 4));
    n.chain(Layer::pool("pool1", 96, 27, 3, 2));
    n.chain(Layer::conv("conv2", 96, 256, 27, 5, 1));
    n.chain(Layer::pool("pool2", 256, 13, 3, 2));
    n.chain(Layer::conv("conv3", 256, 384, 13, 3, 1));
    n.chain(Layer::conv("conv4", 384, 384, 13, 3, 1));
    n.chain(Layer::conv("conv5", 384, 256, 13, 3, 1));
    n.chain(Layer::pool("pool5", 256, 6, 3, 2));
    n.chain(Layer::fc("fc6", 256 * 6 * 6, 4096));
    n.chain(Layer::fc("fc7", 4096, 4096));
    n.chain(Layer::fc("fc8", 4096, 1000));
    n
}

/// VGG-16 [45]: 13 convs (all 3x3) + 5 pools + 3 FCs.
pub fn vggnet() -> Network {
    let mut n = Network::new("vggnet", 3, 224, 224);
    let cfg: &[(&str, u64, u64, u64)] = &[
        // (name, c, k, xo)
        ("conv1_1", 3, 64, 224),
        ("conv1_2", 64, 64, 224),
    ];
    for &(name, c, k, xo) in cfg {
        n.chain(Layer::conv(name, c, k, xo, 3, 1));
    }
    n.chain(Layer::pool("pool1", 64, 112, 2, 2));
    n.chain(Layer::conv("conv2_1", 64, 128, 112, 3, 1));
    n.chain(Layer::conv("conv2_2", 128, 128, 112, 3, 1));
    n.chain(Layer::pool("pool2", 128, 56, 2, 2));
    n.chain(Layer::conv("conv3_1", 128, 256, 56, 3, 1));
    n.chain(Layer::conv("conv3_2", 256, 256, 56, 3, 1));
    n.chain(Layer::conv("conv3_3", 256, 256, 56, 3, 1));
    n.chain(Layer::pool("pool3", 256, 28, 2, 2));
    n.chain(Layer::conv("conv4_1", 256, 512, 28, 3, 1));
    n.chain(Layer::conv("conv4_2", 512, 512, 28, 3, 1));
    n.chain(Layer::conv("conv4_3", 512, 512, 28, 3, 1));
    n.chain(Layer::pool("pool4", 512, 14, 2, 2));
    n.chain(Layer::conv("conv5_1", 512, 512, 14, 3, 1));
    n.chain(Layer::conv("conv5_2", 512, 512, 14, 3, 1));
    n.chain(Layer::conv("conv5_3", 512, 512, 14, 3, 1));
    n.chain(Layer::pool("pool5", 512, 7, 2, 2));
    n.chain(Layer::fc("fc6", 512 * 7 * 7, 4096));
    n.chain(Layer::fc("fc7", 4096, 4096));
    n.chain(Layer::fc("fc8", 4096, 1000));
    n
}

/// One GoogLeNet inception module: 4 branches concatenated along C.
/// Returns the indices of the four branch-output layers.
#[allow(clippy::too_many_arguments)]
fn inception(
    n: &mut Network,
    name: &str,
    prevs: &[PrevRef],
    c_in: u64,
    xo: u64,
    k1: u64,
    k3r: u64,
    k3: u64,
    k5r: u64,
    k5: u64,
    kp: u64,
) -> Vec<PrevRef> {
    let b1 = n.add(Layer::conv(&format!("{name}_1x1"), c_in, k1, xo, 1, 1), prevs);
    let r3 = n.add(Layer::conv(&format!("{name}_3x3r"), c_in, k3r, xo, 1, 1), prevs);
    let b3 = n.add(Layer::conv(&format!("{name}_3x3"), k3r, k3, xo, 3, 1), &[PrevRef::Layer(r3)]);
    let r5 = n.add(Layer::conv(&format!("{name}_5x5r"), c_in, k5r, xo, 1, 1), prevs);
    let b5 = n.add(Layer::conv(&format!("{name}_5x5"), k5r, k5, xo, 5, 1), &[PrevRef::Layer(r5)]);
    let pp = n.add(Layer::pool(&format!("{name}_pool"), c_in, xo, 3, 1), prevs);
    let bp = n.add(Layer::conv(&format!("{name}_poolproj"), c_in, kp, xo, 1, 1), &[PrevRef::Layer(pp)]);
    vec![PrevRef::Layer(b1), PrevRef::Layer(b3), PrevRef::Layer(b5), PrevRef::Layer(bp)]
}

/// GoogLeNet-v1 [50]: stem + 9 inception modules + FC.
pub fn googlenet() -> Network {
    let mut n = Network::new("googlenet", 3, 224, 224);
    n.chain(Layer::conv("conv1", 3, 64, 112, 7, 2));
    n.chain(Layer::pool("pool1", 64, 56, 3, 2));
    n.chain(Layer::conv("conv2r", 64, 64, 56, 1, 1));
    n.chain(Layer::conv("conv2", 64, 192, 56, 3, 1));
    let p2 = n.chain(Layer::pool("pool2", 192, 28, 3, 2));

    let mut prevs = vec![PrevRef::Layer(p2)];
    // (name, k1, k3r, k3, k5r, k5, kp) per the GoogLeNet table.
    let m3a = inception(&mut n, "inc3a", &prevs, 192, 28, 64, 96, 128, 16, 32, 32);
    prevs = m3a;
    let m3b = inception(&mut n, "inc3b", &prevs, 256, 28, 128, 128, 192, 32, 96, 64);
    // pool between 3b and 4a; concat first via a pool over the concat:
    // model the pool as consuming the concatenated 480 channels.
    let p3 = n.add(Layer::pool("pool3", 480, 14, 3, 2), &m3b);
    prevs = vec![PrevRef::Layer(p3)];
    let m4a = inception(&mut n, "inc4a", &prevs, 480, 14, 192, 96, 208, 16, 48, 64);
    let m4b = inception(&mut n, "inc4b", &m4a, 512, 14, 160, 112, 224, 24, 64, 64);
    let m4c = inception(&mut n, "inc4c", &m4b, 512, 14, 128, 128, 256, 24, 64, 64);
    let m4d = inception(&mut n, "inc4d", &m4c, 512, 14, 112, 144, 288, 32, 64, 64);
    let m4e = inception(&mut n, "inc4e", &m4d, 528, 14, 256, 160, 320, 32, 128, 128);
    let p4 = n.add(Layer::pool("pool4", 832, 7, 3, 2), &m4e);
    let m5a = inception(&mut n, "inc5a", &[PrevRef::Layer(p4)], 832, 7, 256, 160, 320, 32, 128, 128);
    let m5b = inception(&mut n, "inc5b", &m5a, 832, 7, 384, 192, 384, 48, 128, 128);
    let p5 = n.add(Layer::pool("pool5", 1024, 1, 7, 7), &m5b);
    n.add(Layer::fc("fc", 1024, 1000), &[PrevRef::Layer(p5)]);
    n
}

/// One ResNet bottleneck: 1x1 down, 3x3, 1x1 up, eltwise add with shortcut.
fn bottleneck(
    n: &mut Network,
    name: &str,
    prev: PrevRef,
    c_in: u64,
    mid: u64,
    out: u64,
    xo: u64,
    stride: u64,
    project: bool,
) -> PrevRef {
    let a = n.add(Layer::conv(&format!("{name}_a"), c_in, mid, xo, 1, stride), &[prev]);
    let b = n.add(Layer::conv(&format!("{name}_b"), mid, mid, xo, 3, 1), &[PrevRef::Layer(a)]);
    let c = n.add(Layer::conv(&format!("{name}_c"), mid, out, xo, 1, 1), &[PrevRef::Layer(b)]);
    let sc = if project {
        PrevRef::Layer(n.add(Layer::conv(&format!("{name}_sc"), c_in, out, xo, 1, stride), &[prev]))
    } else {
        prev
    };
    PrevRef::Layer(n.add(Layer::eltwise(&format!("{name}_add"), out, xo), &[PrevRef::Layer(c), sc]))
}

/// ResNet-50 [19]: conv1 + 4 stages of [3,4,6,3] bottlenecks + FC.
pub fn resnet() -> Network {
    let mut n = Network::new("resnet50", 3, 224, 224);
    n.chain(Layer::conv("conv1", 3, 64, 112, 7, 2));
    let p1 = n.chain(Layer::pool("pool1", 64, 56, 3, 2));
    let mut prev = PrevRef::Layer(p1);
    let stages: [(u64, u64, u64, u64, usize); 4] = [
        // (mid, out, xo, first-stride, blocks)
        (64, 256, 56, 1, 3),
        (128, 512, 28, 2, 4),
        (256, 1024, 14, 2, 6),
        (512, 2048, 7, 2, 3),
    ];
    let mut c_in = 64u64;
    for (si, &(mid, out, xo, stride0, blocks)) in stages.iter().enumerate() {
        for b in 0..blocks {
            let stride = if b == 0 { stride0 } else { 1 };
            let name = format!("res{}{}", si + 2, (b'a' + b as u8) as char);
            prev = bottleneck(&mut n, &name, prev, c_in, mid, out, xo, stride, b == 0);
            c_in = out;
        }
    }
    let pf = n.add(Layer::pool("pool5", 2048, 1, 7, 7), &[prev]);
    n.add(Layer::fc("fc", 2048, 1000), &[PrevRef::Layer(pf)]);
    n
}

/// MobileNet-v1 [22]: 3x3 conv + 13 depthwise-separable blocks + FC.
pub fn mobilenet() -> Network {
    let mut n = Network::new("mobilenet", 3, 224, 224);
    n.chain(Layer::conv("conv1", 3, 32, 112, 3, 2));
    // (c_in, k_out, xo_out, dw stride)
    let blocks: [(u64, u64, u64, u64); 13] = [
        (32, 64, 112, 1),
        (64, 128, 56, 2),
        (128, 128, 56, 1),
        (128, 256, 28, 2),
        (256, 256, 28, 1),
        (256, 512, 14, 2),
        (512, 512, 14, 1),
        (512, 512, 14, 1),
        (512, 512, 14, 1),
        (512, 512, 14, 1),
        (512, 512, 14, 1),
        (512, 1024, 7, 2),
        (1024, 1024, 7, 1),
    ];
    for (i, &(c, k, xo, stride)) in blocks.iter().enumerate() {
        n.chain(Layer::dwconv(&format!("dw{}", i + 1), c, xo, 3, stride));
        n.chain(Layer::conv(&format!("pw{}", i + 1), c, k, xo, 1, 1));
    }
    n.chain(Layer::pool("avgpool", 1024, 1, 7, 7));
    n.chain(Layer::fc("fc", 1024, 1000));
    n
}

/// PRIME-style MLP [12]: 784-1500-1000-500-10.
pub fn mlp() -> Network {
    let mut n = Network::new("mlp", 784, 1, 1);
    n.chain(Layer::fc("fc1", 784, 1500));
    n.chain(Layer::fc("fc2", 1500, 1000));
    n.chain(Layer::fc("fc3", 1000, 500));
    n.chain(Layer::fc("fc4", 500, 10));
    n
}

/// Seq2seq-style LSTM [49]: 2 stacked cells, hidden 512, unrolled 8 steps.
/// Each cell step is four gate FCs (2H -> H for i/f/g/o over [x; h]) plus
/// the eltwise state-update chain c' = f*c + i*g, h' = o*tanh(c').
pub fn lstm() -> Network {
    let hidden = 512u64;
    let steps = 8usize;
    let cells = 2usize;
    let mut n = Network::new("lstm", hidden, 1, 1);
    // Step-0 h/c states stream from DRAM: use the network input as their
    // stand-in producer, matching nn-dataflow's treatment of initial state.
    let mut h_prev: Vec<PrevRef> = vec![PrevRef::Input; cells];
    let mut c_prev: Vec<PrevRef> = vec![PrevRef::Input; cells];
    for t in 0..steps {
        // Input to cell 0 at step t comes from the embedding (external).
        let mut x: PrevRef = PrevRef::Input;
        for cell in 0..cells {
            let tag = format!("t{t}c{cell}");
            let xh = [x, h_prev[cell]];
            let gi = n.add(Layer::fc(&format!("{tag}_i"), 2 * hidden, hidden), &xh);
            let gf = n.add(Layer::fc(&format!("{tag}_f"), 2 * hidden, hidden), &xh);
            let gg = n.add(Layer::fc(&format!("{tag}_g"), 2 * hidden, hidden), &xh);
            let go = n.add(Layer::fc(&format!("{tag}_o"), 2 * hidden, hidden), &xh);
            let ig = n.add(
                Layer::eltwise(&format!("{tag}_ig"), hidden, 1),
                &[PrevRef::Layer(gi), PrevRef::Layer(gg)],
            );
            let fc_ = n.add(
                Layer::eltwise(&format!("{tag}_fc"), hidden, 1),
                &[PrevRef::Layer(gf), c_prev[cell]],
            );
            let cn = n.add(
                Layer::eltwise(&format!("{tag}_cell"), hidden, 1),
                &[PrevRef::Layer(ig), PrevRef::Layer(fc_)],
            );
            let hn = n.add(
                Layer::eltwise(&format!("{tag}_hid"), hidden, 1),
                &[PrevRef::Layer(go), PrevRef::Layer(cn)],
            );
            c_prev[cell] = PrevRef::Layer(cn);
            h_prev[cell] = PrevRef::Layer(hn);
            x = PrevRef::Layer(hn);
        }
    }
    n
}

/// The full zoo in the paper's presentation order.
pub fn all_networks() -> Vec<Network> {
    vec![alexnet(), mobilenet(), vggnet(), googlenet(), resnet(), mlp(), lstm()]
}

/// Look a network up by name (CLI entry point). A `-train` suffix returns
/// the full training graph of the base net (fwd + dX + dW + wu layers),
/// e.g. `alexnet-train`.
pub fn by_name(name: &str) -> Option<Network> {
    if let Some(base) = name.strip_suffix("-train") {
        return by_name(base).map(|n| super::training::training_graph(&n));
    }
    match name {
        "alexnet" => Some(alexnet()),
        "mobilenet" => Some(mobilenet()),
        "vggnet" | "vgg" | "vgg16" => Some(vggnet()),
        "googlenet" => Some(googlenet()),
        "resnet" | "resnet50" => Some(resnet()),
        "mlp" => Some(mlp()),
        "lstm" => Some(lstm()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_validate() {
        for net in all_networks() {
            net.validate().unwrap_or_else(|e| panic!("{}: {e}", net.name));
        }
    }

    #[test]
    fn by_name_train_suffix_builds_training_graph() {
        let t = by_name("mlp-train").unwrap();
        assert_eq!(t.name, "mlp-train");
        assert!(t.len() > by_name("mlp").unwrap().len());
        assert!(t.layers.iter().any(|l| l.name.ends_with("@bd")));
        assert!(by_name("nonesuch-train").is_none());
    }

    #[test]
    fn alexnet_macs_match_literature() {
        // AlexNet (dense, no groups) forward MACs ~ 1.1-1.2 G for batch 1
        // (grouped conv halves conv2/4/5; we model dense like nn-dataflow).
        let m = alexnet().total_macs(1) as f64;
        assert!(m > 0.9e9 && m < 1.6e9, "alexnet macs {m}");
    }

    #[test]
    fn vgg_macs_match_literature() {
        // VGG-16: ~15.5 GMACs per image.
        let m = vggnet().total_macs(1) as f64;
        assert!(m > 15.0e9 && m < 16.5e9, "vgg macs {m}");
    }

    #[test]
    fn resnet_macs_match_literature() {
        // ResNet-50: ~3.8-4.1 GMACs.
        let m = resnet().total_macs(1) as f64;
        assert!(m > 3.4e9 && m < 4.6e9, "resnet macs {m}");
    }

    #[test]
    fn mobilenet_macs_match_literature() {
        // MobileNet-v1: ~0.57 GMACs.
        let m = mobilenet().total_macs(1) as f64;
        assert!(m > 0.45e9 && m < 0.75e9, "mobilenet macs {m}");
    }

    #[test]
    fn googlenet_macs_match_literature() {
        // GoogLeNet-v1: ~1.4-1.6 GMACs.
        let m = googlenet().total_macs(1) as f64;
        assert!(m > 1.2e9 && m < 1.9e9, "googlenet macs {m}");
    }

    #[test]
    fn googlenet_concat_channels() {
        let net = googlenet();
        // inc3a output concat = 64+128+32+32 = 256 -> consumed by inc3b 1x1.
        let l = net.layers.iter().find(|l| l.name == "inc3b_1x1").unwrap();
        assert_eq!(l.c, 256);
        // final concat 384+384+128+128 = 1024 into the classifier.
        let fc = net.layers.iter().find(|l| l.name == "fc").unwrap();
        assert_eq!(fc.c, 1024);
    }

    #[test]
    fn resnet_block_count() {
        let net = resnet();
        let adds = net.layers.iter().filter(|l| l.name.ends_with("_add")).count();
        assert_eq!(adds, 16); // 3+4+6+3
        let convs =
            net.layers.iter().filter(|l| l.kind == super::super::layer::LayerKind::Conv).count();
        assert_eq!(convs, 53); // 1 + 3*16 + 4 shortcuts
    }

    #[test]
    fn mobilenet_alternates_dw_pw() {
        let net = mobilenet();
        let dw = net.layers.iter().filter(|l| l.kind == super::super::layer::LayerKind::DWConv).count();
        assert_eq!(dw, 13);
    }

    #[test]
    fn lstm_structure() {
        let net = lstm();
        let gates = net
            .layers
            .iter()
            .filter(|l| l.kind == super::super::layer::LayerKind::Fc)
            .count();
        assert_eq!(gates, 64); // 8 steps x 2 cells x 4 gates
        net.validate().unwrap();
    }

    #[test]
    fn by_name_roundtrip() {
        for name in ["alexnet", "mobilenet", "vggnet", "googlenet", "resnet", "mlp", "lstm"] {
            assert!(by_name(name).is_some(), "{name}");
        }
        assert!(by_name("nope").is_none());
    }
}
