//! Workload substrate: layer specifications, layer DAGs, the evaluated
//! network zoo, and the training-graph extension (paper §II-A, §V).

pub mod dag;
pub mod layer;
pub mod nets;
pub mod training;

pub use dag::{Network, PrevRef};
pub use layer::{Layer, LayerKind};
pub use nets::{all_networks, by_name};
pub use training::training_graph;
