//! Layer DAG (paper §II-A: an NN is a DAG of layers; training extends the
//! DAG with error-propagation and weight-update layers).
//!
//! Each layer's output is a named fmap tensor. A layer's input is the
//! concatenation (along C) of its predecessors' outputs — this models
//! GoogLeNet inception concat without a dedicated concat op. Eltwise layers
//! instead require all predecessors to produce identically-shaped tensors.

use super::layer::{Layer, LayerKind};

/// Reference to a producer of a layer's input fmap.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PrevRef {
    /// The network's external input image/features.
    Input,
    /// Output of layer `i` in `Network::layers`.
    Layer(usize),
}

/// A whole network: layers in topological order plus predecessor edges.
#[derive(Debug)]
pub struct Network {
    pub name: String,
    /// External input: (channels, width, height).
    pub input: (u64, u64, u64),
    pub layers: Vec<Layer>,
    /// `prevs[i]` lists the producers of layer i's input fmap(s).
    pub prevs: Vec<Vec<PrevRef>>,
    /// Lazily-built successor lists (perf: the schedulers query
    /// `ofm_on_chip` in their inner loops; rebuilding adjacency per query
    /// dominated the inter-layer DP before this cache — see
    /// EXPERIMENTS.md §Perf).
    nexts_cache: std::sync::OnceLock<Vec<Vec<usize>>>,
}

impl Clone for Network {
    fn clone(&self) -> Network {
        Network {
            name: self.name.clone(),
            input: self.input,
            layers: self.layers.clone(),
            prevs: self.prevs.clone(),
            nexts_cache: std::sync::OnceLock::new(),
        }
    }
}

impl Network {
    pub fn new(name: &str, in_c: u64, in_x: u64, in_y: u64) -> Network {
        Network {
            name: name.into(),
            input: (in_c, in_x, in_y),
            layers: Vec::new(),
            prevs: Vec::new(),
            nexts_cache: std::sync::OnceLock::new(),
        }
    }

    /// True if this network is already a training graph: it contains
    /// backward layer kinds, or carries `training_graph`'s `-train` name
    /// suffix (the suffix alone covers degenerate weightless graphs whose
    /// backward passes are all pool/eltwise). Front ends can reach the
    /// training graph two ways — a `-train` net name or a `train` flag —
    /// and this predicate is what keeps applying both idempotent.
    pub fn is_training(&self) -> bool {
        self.name.ends_with("-train") || self.layers.iter().any(|l| l.kind.is_backward())
    }

    /// Append a layer whose input comes from the given producers. Returns
    /// the layer index. Panics on structural inconsistency (wrong channel
    /// sum) — networks are static, so this is a programming error.
    pub fn add(&mut self, layer: Layer, prevs: &[PrevRef]) -> usize {
        layer.validate().unwrap_or_else(|e| panic!("{e}"));
        assert!(!prevs.is_empty(), "layer {} has no inputs", layer.name);
        for p in prevs {
            if let PrevRef::Layer(i) = p {
                assert!(*i < self.layers.len(), "layer {} references future layer {i}", layer.name);
            }
        }
        if layer.kind == LayerKind::Eltwise {
            for p in prevs {
                let (k, xo, yo) = self.out_shape(*p);
                assert_eq!(
                    (k, xo, yo),
                    (layer.c, layer.xo, layer.yo),
                    "eltwise {} operand shape mismatch",
                    layer.name
                );
            }
        } else {
            // FC consumers flatten the producer fmap: channels x Xo x Yo.
            let flat = layer.kind == LayerKind::Fc;
            let c_sum: u64 = prevs
                .iter()
                .map(|p| {
                    let (k, xo, yo) = self.out_shape(*p);
                    if flat {
                        k * xo * yo
                    } else {
                        k
                    }
                })
                .sum();
            assert_eq!(
                c_sum, layer.c,
                "layer {}: input channels {} != sum of producer channels {}",
                layer.name, layer.c, c_sum
            );
        }
        self.layers.push(layer);
        self.prevs.push(prevs.to_vec());
        self.nexts_cache = std::sync::OnceLock::new(); // invalidate
        self.layers.len() - 1
    }

    /// Convenience: append a layer consuming the single previous layer
    /// (or the network input if this is the first layer).
    pub fn chain(&mut self, layer: Layer) -> usize {
        let prev =
            if self.layers.is_empty() { PrevRef::Input } else { PrevRef::Layer(self.layers.len() - 1) };
        self.add(layer, &[prev])
    }

    /// Output shape (channels, x, y) of a producer.
    pub fn out_shape(&self, p: PrevRef) -> (u64, u64, u64) {
        match p {
            PrevRef::Input => self.input,
            PrevRef::Layer(i) => {
                let l = &self.layers[i];
                (l.k, l.xo, l.yo)
            }
        }
    }

    pub fn len(&self) -> usize {
        self.layers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    /// Successor lists (derived from `prevs`), cached after first use.
    /// Mutating builders (`add`/`chain`) invalidate by construction: they
    /// are only used before scheduling starts.
    pub fn nexts(&self) -> &[Vec<usize>] {
        self.nexts_cache.get_or_init(|| {
            let mut out = vec![Vec::new(); self.layers.len()];
            for (i, ps) in self.prevs.iter().enumerate() {
                for p in ps {
                    if let PrevRef::Layer(j) = p {
                        out[*j].push(i);
                    }
                }
            }
            out
        })
    }

    /// Drop the cached successor lists (builders call this on mutation).
    pub(crate) fn invalidate_nexts(&mut self) {
        self.nexts_cache = std::sync::OnceLock::new();
    }

    /// Total MACs over all layers at batch `n`.
    pub fn total_macs(&self, n: u64) -> u64 {
        self.layers.iter().map(|l| l.macs(n)).sum()
    }

    /// Total weight elements.
    pub fn total_weights(&self) -> u64 {
        self.layers.iter().map(|l| l.weight_elems()).sum()
    }

    /// Structural validation of the whole DAG (used by tests over every
    /// network in the zoo).
    pub fn validate(&self) -> Result<(), String> {
        if self.layers.len() != self.prevs.len() {
            return Err("layers/prevs length mismatch".into());
        }
        for (i, l) in self.layers.iter().enumerate() {
            l.validate()?;
            for p in &self.prevs[i] {
                if let PrevRef::Layer(j) = p {
                    if *j >= i {
                        return Err(format!("layer {} has non-topological edge {j}->{i}", l.name));
                    }
                }
            }
            // Spatial compatibility: every producer fmap must be at least as
            // large as the consumer's input window (crop/pad tolerated).
            for p in &self.prevs[i] {
                let (_, px, py) = self.out_shape(*p);
                // Allow modest padding: producer may be up to R-1 smaller.
                if px + l.r <= l.xi() - l.stride || py + l.s <= l.yi() - l.stride {
                    return Err(format!(
                        "layer {}: producer fmap {}x{} too small for input {}x{}",
                        l.name,
                        px,
                        py,
                        l.xi(),
                        l.yi()
                    ));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Network {
        let mut n = Network::new("tiny", 3, 32, 32);
        n.chain(Layer::conv("c1", 3, 8, 32, 3, 1));
        n.chain(Layer::pool("p1", 8, 16, 2, 2));
        n.chain(Layer::conv("c2", 8, 16, 16, 3, 1));
        n
    }

    #[test]
    fn chain_builds_linear_dag() {
        let n = tiny();
        assert_eq!(n.len(), 3);
        assert_eq!(n.prevs[0], vec![PrevRef::Input]);
        assert_eq!(n.prevs[2], vec![PrevRef::Layer(1)]);
        n.validate().unwrap();
    }

    #[test]
    fn nexts_inverts_prevs() {
        let n = tiny();
        let nx = n.nexts();
        assert_eq!(nx[0], vec![1]);
        assert_eq!(nx[1], vec![2]);
        assert!(nx[2].is_empty());
    }

    #[test]
    fn concat_channels_sum() {
        let mut n = Network::new("cat", 3, 16, 16);
        let a = n.chain(Layer::conv("a", 3, 8, 16, 1, 1));
        let b = n.add(Layer::conv("b", 3, 24, 16, 1, 1), &[PrevRef::Input]);
        // consumer of concat(a, b) => c = 32
        n.add(Layer::conv("c", 32, 16, 16, 3, 1), &[PrevRef::Layer(a), PrevRef::Layer(b)]);
        n.validate().unwrap();
    }

    #[test]
    #[should_panic(expected = "input channels")]
    fn concat_channel_mismatch_panics() {
        let mut n = Network::new("cat", 3, 16, 16);
        let a = n.chain(Layer::conv("a", 3, 8, 16, 1, 1));
        n.add(Layer::conv("c", 99, 16, 16, 3, 1), &[PrevRef::Layer(a)]);
    }

    #[test]
    fn eltwise_requires_matching_shapes() {
        let mut n = Network::new("res", 8, 16, 16);
        let a = n.chain(Layer::conv("a", 8, 8, 16, 3, 1));
        let b = n.add(Layer::conv("b", 8, 8, 16, 1, 1), &[PrevRef::Input]);
        n.add(Layer::eltwise("add", 8, 16), &[PrevRef::Layer(a), PrevRef::Layer(b)]);
        n.validate().unwrap();
    }

    #[test]
    fn totals_accumulate() {
        let n = tiny();
        assert_eq!(
            n.total_macs(2),
            n.layers[0].macs(2) + n.layers[1].macs(2) + n.layers[2].macs(2)
        );
        assert_eq!(n.total_weights(), 8 * 3 * 9 + 16 * 8 * 9);
    }
}
