//! NN layer specifications (paper Table I notation).
//!
//! All tensors are 4D: input fmaps (N, C, Xi, Yi), output fmaps
//! (N, K, Xo, Yo), filter weights (K, C, R, S). FC layers are CONVs with
//! Xo = Yo = R = S = 1. Backward (training) layers are modeled as CONVs
//! with transformed dimensions (paper §II-A, [46], [48]) — see
//! `workloads::training`.

/// Layer operator kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerKind {
    /// Dense convolution.
    Conv,
    /// Depthwise convolution: C == K, one filter per channel (paper
    /// Listing 1 DWCONV example).
    DWConv,
    /// Fully connected / matrix multiplication.
    Fc,
    /// Pooling (max/avg): no weights, K == C.
    Pool,
    /// Element-wise add (ResNet shortcut, LSTM cell ops): no weights,
    /// K == C, R == S == 1.
    Eltwise,
    /// Training back-weight pass dW = X (*) dY (paper §II-A, [46], [48]).
    /// Carries the *forward* layer's dimensions but reassigns the dataflow
    /// roles: the streamed "filter" is dY (N,K,Xo,Yo), the stationary
    /// output is dW (K,C,R,S) accumulated over the batch, and the input
    /// fmap is the stashed activation X (N,C,Xi,Yi).
    ConvBwWeight,
    /// Training back-activation pass dX = dY (*) W-transposed (paper
    /// §II-A): a transposed convolution whose C/K are the forward layer's
    /// K/C, whose output fmap is the forward *input* fmap, and whose
    /// stride is the forward stride acting as dY *upsampling*. The input
    /// fmap (dY) is therefore the forward output fmap: `xi()`/`yi()`
    /// invert the stride instead of multiplying by it, and MACs count one
    /// C*R*S reduction per dY pixel — exactly the forward MAC count.
    ConvBwAct,
    /// Depthwise back-activation pass: `ConvBwAct` with the depthwise
    /// single-filter-per-channel constraint (C == K, channels in the K
    /// group).
    DWConvBwAct,
}

impl LayerKind {
    /// True for the training-only backward kinds `workloads::training_graph`
    /// emits; forward (inference) networks never contain them, which is
    /// what makes "is this already a training graph?" decidable.
    pub fn is_backward(self) -> bool {
        matches!(self, LayerKind::ConvBwWeight | LayerKind::ConvBwAct | LayerKind::DWConvBwAct)
    }
}

/// A single layer. Batch size N is a property of the scheduling run, not
/// the layer (paper evaluates the same nets at batch 64 and batch 1).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    /// Input channels C.
    pub c: u64,
    /// Output channels K.
    pub k: u64,
    /// Output fmap width/height.
    pub xo: u64,
    pub yo: u64,
    /// Filter width/height.
    pub r: u64,
    pub s: u64,
    /// Convolution stride (same both axes).
    pub stride: u64,
    /// True for layers whose work does not scale with batch (weight-update
    /// layers in training graphs).
    pub no_batch: bool,
}

impl Layer {
    pub fn conv(name: &str, c: u64, k: u64, xo: u64, r: u64, stride: u64) -> Layer {
        Layer {
            name: name.into(),
            kind: LayerKind::Conv,
            c,
            k,
            xo,
            yo: xo,
            r,
            s: r,
            stride,
            no_batch: false,
        }
    }

    pub fn dwconv(name: &str, c: u64, xo: u64, r: u64, stride: u64) -> Layer {
        Layer {
            name: name.into(),
            kind: LayerKind::DWConv,
            c,
            k: c,
            xo,
            yo: xo,
            r,
            s: r,
            stride,
            no_batch: false,
        }
    }

    pub fn fc(name: &str, c: u64, k: u64) -> Layer {
        Layer {
            name: name.into(),
            kind: LayerKind::Fc,
            c,
            k,
            xo: 1,
            yo: 1,
            r: 1,
            s: 1,
            stride: 1,
            no_batch: false,
        }
    }

    pub fn pool(name: &str, c: u64, xo: u64, r: u64, stride: u64) -> Layer {
        Layer {
            name: name.into(),
            kind: LayerKind::Pool,
            c,
            k: c,
            xo,
            yo: xo,
            r,
            s: r,
            stride,
            no_batch: false,
        }
    }

    pub fn eltwise(name: &str, c: u64, xo: u64) -> Layer {
        Layer {
            name: name.into(),
            kind: LayerKind::Eltwise,
            c,
            k: c,
            xo,
            yo: xo,
            r: 1,
            s: 1,
            stride: 1,
            no_batch: false,
        }
    }

    /// Input fmap width Xi = (Xo - 1) * stride + R for forward layers.
    /// Back-activation layers invert the relation (their input is the
    /// forward output fmap): Xi = (Xo - R) / stride + 1, saturating so
    /// ragged per-node splits stay well-defined.
    pub fn xi(&self) -> u64 {
        match self.kind {
            LayerKind::ConvBwAct | LayerKind::DWConvBwAct => {
                self.xo.saturating_sub(self.r) / self.stride + 1
            }
            _ => (self.xo - 1) * self.stride + self.r,
        }
    }

    /// Input fmap height Yi (see `xi`).
    pub fn yi(&self) -> u64 {
        match self.kind {
            LayerKind::ConvBwAct | LayerKind::DWConvBwAct => {
                self.yo.saturating_sub(self.s) / self.stride + 1
            }
            _ => (self.yo - 1) * self.stride + self.s,
        }
    }

    /// Whether this layer owns a *persistent* weight tensor (resident
    /// across batch rounds). Back-weight layers stream dY instead;
    /// back-activation layers reread the forward filters (transposed).
    pub fn has_weights(&self) -> bool {
        matches!(
            self.kind,
            LayerKind::Conv
                | LayerKind::DWConv
                | LayerKind::Fc
                | LayerKind::ConvBwAct
                | LayerKind::DWConvBwAct
        )
    }

    /// Number of input operands (Eltwise takes two fmaps).
    pub fn num_inputs(&self) -> usize {
        if self.kind == LayerKind::Eltwise {
            2
        } else {
            1
        }
    }

    /// Weight tensor element count (0 for unweighted layers).
    pub fn weight_elems(&self) -> u64 {
        match self.kind {
            LayerKind::Conv | LayerKind::Fc | LayerKind::ConvBwAct => {
                self.k * self.c * self.r * self.s
            }
            LayerKind::DWConv | LayerKind::DWConvBwAct => self.c * self.r * self.s,
            LayerKind::Pool | LayerKind::Eltwise | LayerKind::ConvBwWeight => 0,
        }
    }

    /// Input fmap element count for batch `n` (a single operand).
    pub fn ifm_elems(&self, n: u64) -> u64 {
        self.batch(n) * self.c * self.xi() * self.yi()
    }

    /// Output fmap element count for batch `n`.
    pub fn ofm_elems(&self, n: u64) -> u64 {
        self.batch(n) * self.k * self.xo * self.yo
    }

    /// Effective batch (1 for batch-independent layers).
    pub fn batch(&self, n: u64) -> u64 {
        if self.no_batch {
            1
        } else {
            n
        }
    }

    /// MAC (or op) count for batch `n`.
    pub fn macs(&self, n: u64) -> u64 {
        let n = self.batch(n);
        match self.kind {
            LayerKind::Conv | LayerKind::Fc | LayerKind::ConvBwWeight => {
                n * self.k * self.c * self.xo * self.yo * self.r * self.s
            }
            // Transposed conv: one C*R*S reduction per dY pixel, so MACs
            // count over the *input* fmap and equal the forward layer's.
            LayerKind::ConvBwAct => n * self.k * self.c * self.xi() * self.yi() * self.r * self.s,
            LayerKind::DWConv => n * self.c * self.xo * self.yo * self.r * self.s,
            LayerKind::DWConvBwAct => n * self.c * self.xi() * self.yi() * self.r * self.s,
            LayerKind::Pool => n * self.c * self.xo * self.yo * self.r * self.s,
            LayerKind::Eltwise => n * self.c * self.xo * self.yo,
        }
    }

    /// The reduction size per output element (C*R*S for conv).
    pub fn reduction_per_output(&self) -> u64 {
        match self.kind {
            LayerKind::Conv | LayerKind::Fc | LayerKind::ConvBwAct => self.c * self.r * self.s,
            LayerKind::DWConv | LayerKind::DWConvBwAct | LayerKind::Pool => self.r * self.s,
            LayerKind::Eltwise => self.num_inputs() as u64,
            // dW accumulates over the batch and the output fmap.
            LayerKind::ConvBwWeight => self.xo * self.yo,
        }
    }

    /// Tensor volumes by *dataflow role*: (streamed input words incl. any
    /// per-batch second operand, output words, persistent weight words).
    /// For ordinary layers this is (ifm, ofm, weights); the back-weight
    /// pass streams X and dY and emits the batch-reduced dW.
    pub fn role_volumes(&self, n: u64) -> (u64, u64, u64) {
        match self.kind {
            LayerKind::ConvBwWeight => (
                self.ifm_elems(n) + self.batch(n) * self.k * self.xo * self.yo,
                self.k * self.c * self.r * self.s,
                0,
            ),
            _ => (self.ifm_elems(n), self.ofm_elems(n), self.weight_elems()),
        }
    }

    /// Validate internal consistency.
    pub fn validate(&self) -> Result<(), String> {
        for (what, v) in
            [("c", self.c), ("k", self.k), ("xo", self.xo), ("yo", self.yo), ("r", self.r), ("s", self.s), ("stride", self.stride)]
        {
            if v == 0 {
                return Err(format!("layer {}: {what} == 0", self.name));
            }
        }
        match self.kind {
            LayerKind::DWConv | LayerKind::DWConvBwAct | LayerKind::Pool | LayerKind::Eltwise
                if self.c != self.k =>
            {
                Err(format!("layer {}: {:?} requires C == K", self.name, self.kind))
            }
            LayerKind::Fc if self.xo != 1 || self.yo != 1 || self.r != 1 || self.s != 1 => {
                Err(format!("layer {}: FC requires Xo=Yo=R=S=1", self.name))
            }
            _ => Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_dims() {
        // AlexNet conv1: 3 -> 96, 55x55 out, 11x11 filter, stride 4.
        let l = Layer::conv("conv1", 3, 96, 55, 11, 4);
        assert_eq!(l.xi(), 227);
        assert_eq!(l.yi(), 227);
        assert_eq!(l.weight_elems(), 96 * 3 * 11 * 11);
        assert_eq!(l.macs(1), 96 * 3 * 55 * 55 * 11 * 11);
        assert_eq!(l.macs(64), 64 * 96 * 3 * 55 * 55 * 11 * 11);
        l.validate().unwrap();
    }

    #[test]
    fn fc_is_1x1_conv() {
        let l = Layer::fc("fc6", 9216, 4096);
        assert_eq!(l.xi(), 1);
        assert_eq!(l.macs(2), 2 * 9216 * 4096);
        assert_eq!(l.weight_elems(), 9216 * 4096);
        l.validate().unwrap();
    }

    #[test]
    fn dwconv_channels_match() {
        let l = Layer::dwconv("dw1", 32, 112, 3, 1);
        assert_eq!(l.k, 32);
        assert_eq!(l.macs(1), 32 * 112 * 112 * 9);
        assert_eq!(l.weight_elems(), 32 * 9);
        l.validate().unwrap();

        let mut bad = l.clone();
        bad.k = 64;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn pool_and_eltwise_have_no_weights() {
        let p = Layer::pool("p", 96, 27, 3, 2);
        assert_eq!(p.weight_elems(), 0);
        assert!(!p.has_weights());
        let e = Layer::eltwise("e", 256, 56);
        assert_eq!(e.num_inputs(), 2);
        assert_eq!(e.macs(4), 4 * 256 * 56 * 56);
    }

    #[test]
    fn no_batch_layer_ignores_n() {
        let mut l = Layer::fc("wu", 100, 100);
        l.no_batch = true;
        assert_eq!(l.macs(64), l.macs(1));
        assert_eq!(l.ifm_elems(64), l.ifm_elems(1));
    }

    #[test]
    fn conv_bw_act_inverts_stride_and_conserves_macs() {
        let fwd = Layer::conv("conv1", 3, 96, 55, 11, 4);
        let bd = Layer {
            name: "conv1@bd".into(),
            kind: LayerKind::ConvBwAct,
            c: fwd.k,
            k: fwd.c,
            xo: fwd.xi(),
            yo: fwd.yi(),
            r: fwd.r,
            s: fwd.s,
            stride: fwd.stride,
            no_batch: false,
        };
        bd.validate().unwrap();
        // dY is the backward input fmap: xi() inverts the stride exactly.
        assert_eq!(bd.xi(), fwd.xo);
        assert_eq!(bd.yi(), fwd.yo);
        assert_eq!(bd.macs(64), fwd.macs(64));
        // Same filter tensor, transposed roles; volumes swap with roles.
        assert_eq!(bd.weight_elems(), fwd.weight_elems());
        assert!(bd.has_weights());
        assert_eq!(bd.ifm_elems(16), fwd.ofm_elems(16));
        assert_eq!(bd.ofm_elems(16), fwd.ifm_elems(16));
    }

    #[test]
    fn dwconv_bw_act_is_depthwise() {
        let fwd = Layer::dwconv("dw1", 32, 112, 3, 2);
        let mut bd = Layer {
            name: "dw1@bd".into(),
            kind: LayerKind::DWConvBwAct,
            c: fwd.c,
            k: fwd.c,
            xo: fwd.xi(),
            yo: fwd.yi(),
            r: fwd.r,
            s: fwd.s,
            stride: fwd.stride,
            no_batch: false,
        };
        bd.validate().unwrap();
        assert_eq!(bd.macs(8), fwd.macs(8));
        assert_eq!(bd.weight_elems(), fwd.weight_elems());
        bd.k = 64;
        assert!(bd.validate().is_err()); // C == K enforced like DWConv
    }

    #[test]
    fn zero_dim_rejected() {
        let mut l = Layer::conv("c", 3, 8, 10, 3, 1);
        l.xo = 0;
        assert!(l.validate().is_err());
    }
}
