//! Training-graph extension (paper §II-A: "In training, the original DAGs
//! are extended with more layers for error propagation and weight updates.
//! The backward CONV/FC layers can be modeled similarly to the forward
//! layers with different data layouts and computations [46], [48]").
//!
//! For each forward layer L we append, in reverse topological order:
//!
//! * **back-data** `L@bd` — dX = dY (*) W-transposed: a first-class
//!   `ConvBwAct` (`DWConvBwAct` for depthwise) with C and K swapped, fmap
//!   dims equal to L's *input* fmap, and the forward stride acting as dY
//!   upsampling — so its MAC count equals the forward layer's exactly.
//! * **back-weight** `L@bw` — dW = X (*) dY: a CONV whose "output fmap" is
//!   the R x S filter grid and whose reduction runs over the batch and the
//!   output fmap (same MAC count as the forward layer).
//! * **weight-update** `L@wu` — dense eltwise over the weight tensor,
//!   batch-independent.
//!
//! Unweighted layers (pool/eltwise) get a single backward eltwise-style
//! layer propagating the error at the same fmap shape.

use super::dag::{Network, PrevRef};
use super::layer::{Layer, LayerKind};

/// Extend a forward (inference) network into its training graph.
///
/// Idempotent: `workloads::by_name` already resolves `-train` names to
/// training graphs, and a service request may redundantly stack a `train`
/// flag on top (`schedule mlp-train … train`). Re-extending would hit the
/// backward-kind arm below (formerly an `unreachable!` that panicked the
/// serve loop) and mint a nonsense `*-train-train` net, so an
/// already-training input is returned as-is.
pub fn training_graph(fwd: &Network) -> Network {
    if fwd.is_training() {
        return fwd.clone();
    }
    let mut net = fwd.clone();
    net.name = format!("{}-train", fwd.name);
    let n_fwd = fwd.len();
    let nexts = fwd.nexts();

    // grad_of[i] = index of the layer producing dY for forward layer i
    // (its back-data output feeds the predecessors). Built in reverse topo
    // order; layers with multiple consumers get an eltwise sum-join first.
    let mut grad_of: Vec<Option<usize>> = vec![None; n_fwd];

    for i in (0..n_fwd).rev() {
        let l = fwd.layers[i].clone();

        // Producers of dY for layer i: the back-data layers of each
        // consumer. The loss layer feeds the DAG tail externally (Input).
        let consumers = &nexts[i];
        let dy: Vec<PrevRef> = if consumers.is_empty() {
            vec![PrevRef::Input]
        } else {
            consumers
                .iter()
                .map(|&j| grad_of[j].map(PrevRef::Layer).unwrap_or(PrevRef::Input))
                .collect()
        };
        // Multiple consumers: eltwise-sum their back-propagated errors.
        // A single producer may also have the wrong channel count when the
        // consumer consumed a concat; the sum-join layer renormalizes to
        // this layer's K channels (data-layout move, eltwise cost).
        let dy_ref = if dy.len() == 1 && fwd.prevs[consumers.first().copied().unwrap_or(0)].len() <= 1
        {
            dy[0]
        } else {
            let mut join = Layer::eltwise(&format!("{}@dj", l.name), l.k, l.xo);
            join.yo = l.yo;
            let ji = push_raw(&mut net, join, &dy);
            PrevRef::Layer(ji)
        };

        match l.kind {
            LayerKind::Conv | LayerKind::Fc | LayerKind::DWConv => {
                // back-data: first-class transposed conv — C <-> K, output
                // fmap = forward input fmap, forward stride kept as the dY
                // upsampling stride (ConvBwAct::xi() inverts it back to the
                // forward output fmap).
                let mut bd = Layer {
                    name: format!("{}@bd", l.name),
                    kind: if l.kind == LayerKind::DWConv {
                        LayerKind::DWConvBwAct
                    } else {
                        LayerKind::ConvBwAct
                    },
                    c: l.k,
                    k: l.c,
                    xo: l.xi(),
                    yo: l.yi(),
                    r: l.r,
                    s: l.s,
                    stride: l.stride,
                    no_batch: false,
                };
                if l.kind == LayerKind::DWConv {
                    bd.k = l.c;
                    bd.c = l.c;
                }
                let bdi = push_raw(&mut net, bd, &[dy_ref]);
                grad_of[i] = Some(bdi);

                // back-weight: dW = X (*) dY, reduction over N * Xo * Yo.
                // The dedicated ConvBwWeight kind reuses the forward
                // layer's dimensions and reassigns tensor roles (streamed
                // dY as the "filter", batch-reduced dW as the output);
                // MACs match the forward layer exactly (asserted below).
                let bw = Layer {
                    name: format!("{}@bw", l.name),
                    kind: LayerKind::ConvBwWeight,
                    c: l.c,
                    k: l.k,
                    xo: l.xo,
                    yo: l.yo,
                    r: l.r,
                    s: l.s,
                    stride: l.stride,
                    no_batch: false,
                };
                let x_ref = fwd.prevs[i].clone(); // stashed activations
                let mut bw_prevs = x_ref;
                bw_prevs.push(dy_ref);
                let bwi = push_raw(&mut net, bw, &bw_prevs);

                // weight update: W -= eta * dW, batch-independent eltwise
                // over the weight tensor.
                let wsz = l.weight_elems();
                let mut wu = Layer::eltwise(&format!("{}@wu", l.name), wsz.max(1), 1);
                wu.no_batch = true;
                push_raw(&mut net, wu, &[PrevRef::Layer(bwi)]);
            }
            LayerKind::Pool => {
                // Error upsampling through the pool window.
                let bp = Layer {
                    name: format!("{}@bp", l.name),
                    kind: LayerKind::Pool,
                    c: l.c,
                    k: l.c,
                    xo: l.xi(),
                    yo: l.yi(),
                    r: l.r,
                    s: l.s,
                    stride: 1,
                    no_batch: false,
                };
                let bpi = push_raw(&mut net, bp, &[dy_ref]);
                grad_of[i] = Some(bpi);
            }
            LayerKind::ConvBwWeight | LayerKind::ConvBwAct | LayerKind::DWConvBwAct => {
                unreachable!("training graphs are built from forward networks")
            }
            LayerKind::Eltwise => {
                // d(add) passes through; keep an explicit layer so the
                // scheduler sees the traffic.
                let mut be = Layer::eltwise(&format!("{}@be", l.name), l.c, l.xo);
                be.yo = l.yo;
                let bei = push_raw(&mut net, be, &[dy_ref]);
                grad_of[i] = Some(bei);
            }
        }
    }
    net
}

/// Append without the concat-channel bookkeeping of `Network::add`:
/// backward layers legitimately mix operand shapes (e.g. back-weight reads
/// the stashed X and dY). We still validate the layer itself.
fn push_raw(net: &mut Network, layer: Layer, prevs: &[PrevRef]) -> usize {
    layer.validate().unwrap_or_else(|e| panic!("{e}"));
    net.layers.push(layer);
    net.prevs.push(prevs.to_vec());
    net.invalidate_nexts();
    net.layers.len() - 1
}

#[cfg(test)]
mod tests {
    use super::super::nets;
    use super::*;

    #[test]
    fn training_graph_is_larger() {
        for f in nets::all_networks() {
            let t = training_graph(&f);
            assert!(t.len() > 2 * f.len() - f.len() / 2, "{}: {} vs {}", f.name, t.len(), f.len());
            // Edges stay topological.
            for (i, ps) in t.prevs.iter().enumerate() {
                for p in ps {
                    if let PrevRef::Layer(j) = p {
                        assert!(*j < i, "{}: edge {j} -> {i}", t.name);
                    }
                }
            }
        }
    }

    #[test]
    fn training_graph_is_idempotent() {
        for f in nets::all_networks() {
            let once = training_graph(&f);
            assert!(once.is_training());
            assert!(!f.is_training(), "{} must stay a forward net", f.name);
            let twice = training_graph(&once);
            // Re-extending an already-training graph is the double-wrap
            // regression: it used to panic on the backward kinds and would
            // have produced a `*-train-train` net.
            assert_eq!(twice.name, once.name);
            assert_eq!(twice.len(), once.len());
            assert_eq!(twice.layers, once.layers);
            assert_eq!(twice.prevs, once.prevs);
        }
    }

    #[test]
    fn back_weight_macs_match_forward() {
        let f = nets::alexnet();
        let t = training_graph(&f);
        let fwd = t.layers.iter().find(|l| l.name == "conv3").unwrap();
        let bw = t.layers.iter().find(|l| l.name == "conv3@bw").unwrap();
        assert_eq!(fwd.macs(64), bw.macs(64));
    }

    #[test]
    fn back_weight_roles() {
        let f = nets::mobilenet();
        let t = training_graph(&f);
        let bw = t.layers.iter().find(|l| l.name == "pw1@bw").unwrap();
        assert_eq!(bw.kind, LayerKind::ConvBwWeight);
        // No persistent weights; output volume is dW; dY streams per batch.
        assert_eq!(bw.weight_elems(), 0);
        let (inp, out, wgt) = bw.role_volumes(4);
        assert_eq!(out, bw.k * bw.c * bw.r * bw.s);
        assert_eq!(wgt, 0);
        assert!(inp > bw.ifm_elems(4)); // X plus dY
    }

    #[test]
    fn back_data_dims_are_swapped() {
        let f = nets::alexnet();
        let t = training_graph(&f);
        let fwd = t.layers.iter().find(|l| l.name == "conv2").unwrap();
        let bd = t.layers.iter().find(|l| l.name == "conv2@bd").unwrap();
        assert_eq!(bd.kind, LayerKind::ConvBwAct);
        assert_eq!(bd.c, fwd.k);
        assert_eq!(bd.k, fwd.c);
        assert_eq!((bd.xo, bd.yo), (fwd.xi(), fwd.yi()));
        // The backward input fmap is exactly the forward output fmap.
        assert_eq!((bd.xi(), bd.yi()), (fwd.xo, fwd.yo));
        assert_eq!(bd.macs(64), fwd.macs(64));
    }

    #[test]
    fn depthwise_back_data_is_first_class() {
        let t = training_graph(&nets::mobilenet());
        let fwd = t.layers.iter().find(|l| l.kind == LayerKind::DWConv).unwrap().clone();
        let bd = t.layers.iter().find(|l| l.name == format!("{}@bd", fwd.name)).unwrap();
        assert_eq!(bd.kind, LayerKind::DWConvBwAct);
        assert_eq!(bd.c, bd.k);
        assert_eq!(bd.macs(16), fwd.macs(16));
    }

    #[test]
    fn weight_update_is_batch_independent() {
        let t = training_graph(&nets::mlp());
        let wu = t.layers.iter().find(|l| l.name == "fc2@wu").unwrap();
        assert!(wu.no_batch);
        assert_eq!(wu.c, 1500 * 1000);
        assert_eq!(wu.macs(64), wu.macs(1));
    }

    #[test]
    fn training_macs_roughly_3x_forward() {
        // fwd + back-data + back-weight ~= 3x forward compute for conv nets.
        let f = nets::vggnet();
        let t = training_graph(&f);
        let ratio = t.total_macs(64) as f64 / f.total_macs(64) as f64;
        assert!(ratio > 2.2 && ratio < 4.0, "ratio {ratio}");
    }

    #[test]
    fn every_forward_layer_has_gradient_path() {
        let f = nets::resnet();
        let t = training_graph(&f);
        for l in &f.layers {
            if l.has_weights() {
                assert!(
                    t.layers.iter().any(|x| x.name == format!("{}@bw", l.name)),
                    "missing bw for {}",
                    l.name
                );
                assert!(
                    t.layers.iter().any(|x| x.name == format!("{}@wu", l.name)),
                    "missing wu for {}",
                    l.name
                );
            }
        }
    }
}
