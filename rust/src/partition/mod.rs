//! Node-level parallelization (paper §III-A "Node parallelization").
//!
//! A layer assigned to a rectangular region of the node mesh is partitioned
//! in a hybrid way along batch (N), output channels (K), input channels (C)
//! and the 2D output fmap (Xo, Yo) [16], [24], [47]. Tensors containing a
//! partitioned dim shrink per node; the others are replicated — unless
//! *buffer sharing* [17] stores a single copy across the sibling buffers
//! and rotates shares (expressed by the `shr` parameter of the `tensor`
//! directive).

use crate::mapping::LayerShape;
use crate::util::{ceil_div, divisors};
use crate::workloads::{Layer, LayerKind};

/// A node-level partition scheme on a rectangular mesh region.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PartitionScheme {
    /// Node region (width, height) allocated to the layer.
    pub region: (u64, u64),
    /// Partition factors; their product must not exceed region nodes.
    pub pn: u64,
    pub pk: u64,
    pub pc: u64,
    pub px: u64,
    pub py: u64,
    /// Buffer-share the input fmap across the `pk` output-parallel nodes
    /// instead of replicating it (paper Listing 1 line 14, `shr=4`).
    pub share_ifm: bool,
    /// Buffer-share the weights across the `pn*px*py` batch/fmap-parallel
    /// nodes instead of replicating them.
    pub share_wgt: bool,
}

impl PartitionScheme {
    /// Trivial scheme: single node, no partitioning.
    pub fn single() -> PartitionScheme {
        PartitionScheme {
            region: (1, 1),
            pn: 1,
            pk: 1,
            pc: 1,
            px: 1,
            py: 1,
            share_ifm: false,
            share_wgt: false,
        }
    }

    pub fn nodes(&self) -> u64 {
        self.region.0 * self.region.1
    }

    pub fn used_nodes(&self) -> u64 {
        self.pn * self.pk * self.pc * self.px * self.py
    }

    /// Per-node layer shape after partitioning (ceiling split).
    pub fn node_shape(&self, layer: &Layer, batch: u64) -> LayerShape {
        let full = LayerShape::full(layer, batch);
        // DW/pool/eltwise carry channels in K: a pk-partition splits both
        // c and k (they are the same physical dim); pc must be 1.
        let chan_split = self.pk;
        let (c, k) = match layer.kind {
            LayerKind::DWConv | LayerKind::DWConvBwAct | LayerKind::Pool | LayerKind::Eltwise => {
                (ceil_div(full.c, chan_split), ceil_div(full.k, chan_split))
            }
            _ => (ceil_div(full.c, self.pc), ceil_div(full.k, self.pk)),
        };
        LayerShape {
            kind: full.kind,
            n: ceil_div(full.n, self.pn),
            c,
            k,
            xo: ceil_div(full.xo, self.px),
            yo: ceil_div(full.yo, self.py),
            r: full.r,
            s: full.s,
            stride: full.stride,
        }
    }

    /// Replication factor of the input fmap across nodes (how many nodes
    /// hold the same ifm data), and the sharing divisor when buffer
    /// sharing is on.
    pub fn ifm_replication(&self) -> u64 {
        // ifm does not contain K; K-parallel nodes need the same ifm.
        self.pk
    }

    pub fn ifm_shr(&self) -> u64 {
        if self.share_ifm {
            self.pk
        } else {
            1
        }
    }

    /// Replication of the weights (no N, Xo, Yo dims).
    pub fn wgt_replication(&self) -> u64 {
        self.pn * self.px * self.py
    }

    pub fn wgt_shr(&self) -> u64 {
        if self.share_wgt {
            self.wgt_replication()
        } else {
            1
        }
    }

    /// Number of nodes that accumulate partial sums of the same output
    /// (input-channel parallelism needs a cross-node reduction).
    pub fn ofm_reduction(&self) -> u64 {
        self.pc
    }

    /// Kind-aware reduction: the back-weight pass reduces its output (dW)
    /// over batch and fmap, so those parallel nodes must combine.
    pub fn ofm_reduction_for(&self, kind: LayerKind) -> u64 {
        match kind {
            LayerKind::ConvBwWeight => self.pn * self.px * self.py,
            _ => self.pc,
        }
    }

    /// Kind-aware weight-slot sharing: the back-weight "wgt" tensor is the
    /// streamed dY (replicated across C-parallel nodes, not shareable the
    /// same way); disable the static sharing divisor there.
    pub fn wgt_shr_for(&self, kind: LayerKind) -> u64 {
        match kind {
            LayerKind::ConvBwWeight => 1,
            _ => self.wgt_shr(),
        }
    }

    /// Average NoC hop count for DRAM<->node traffic: half the mesh
    /// perimeter distance from edge memory controllers (paper Fig. 1:
    /// off-chip memories on the mesh boundary).
    pub fn dram_hops(&self) -> f64 {
        ((self.region.0 + self.region.1) as f64 / 4.0).max(1.0)
    }

    /// Average hop count for neighbour rotation (buffer sharing) and
    /// cross-node reduction: ring neighbours.
    pub fn neighbor_hops(&self) -> f64 {
        1.0
    }

    /// Validity: factors fit the region and the layer dims.
    pub fn is_valid(&self, layer: &Layer, batch: u64) -> bool {
        if self.used_nodes() > self.nodes() {
            return false;
        }
        let full = LayerShape::full(layer, batch);
        if self.pn > full.n || self.pk > full.k || self.pc > full.c {
            return false;
        }
        if self.px > full.xo || self.py > full.yo {
            return false;
        }
        match layer.kind {
            // Channel-paired kinds cannot split C independently.
            LayerKind::DWConv | LayerKind::DWConvBwAct | LayerKind::Pool | LayerKind::Eltwise => {
                self.pc == 1
            }
            LayerKind::Fc => self.px == 1 && self.py == 1,
            LayerKind::Conv | LayerKind::ConvBwWeight | LayerKind::ConvBwAct => true,
        }
    }
}

/// Enumerate all partition schemes of `layer` over a `region`, optionally
/// with buffer-sharing variants. This is the node-level *stack* space the
/// solvers explore.
pub fn enumerate_partitions(
    layer: &Layer,
    batch: u64,
    region: (u64, u64),
    with_sharing: bool,
) -> Vec<PartitionScheme> {
    let area = region.0 * region.1;
    let mut out = Vec::new();
    // Factor the full region area into the five dims (ordered factorization
    // of every divisor chain). Under-filled regions waste nodes, so we only
    // use the full area; fragmented dims are handled by ceiling splits.
    for pn in divisors(area) {
        let a1 = area / pn;
        for pk in divisors(a1) {
            let a2 = a1 / pk;
            for pc in divisors(a2) {
                let a3 = a2 / pc;
                for px in divisors(a3) {
                    let py = a3 / px;
                    let base = PartitionScheme {
                        region,
                        pn,
                        pk,
                        pc,
                        px,
                        py,
                        share_ifm: false,
                        share_wgt: false,
                    };
                    if !base.is_valid(layer, batch) {
                        continue;
                    }
                    out.push(base);
                    if with_sharing {
                        if base.pk > 1 {
                            let mut s = base;
                            s.share_ifm = true;
                            out.push(s);
                        }
                        if base.wgt_replication() > 1 && layer.has_weights() {
                            let mut s = base;
                            s.share_wgt = true;
                            out.push(s);
                            if base.pk > 1 {
                                let mut s2 = s;
                                s2.share_ifm = true;
                                out.push(s2);
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::Layer;

    fn conv() -> Layer {
        Layer::conv("c", 64, 128, 28, 3, 1)
    }

    #[test]
    fn single_is_identity() {
        let p = PartitionScheme::single();
        let s = p.node_shape(&conv(), 16);
        assert_eq!((s.n, s.c, s.k, s.xo), (16, 64, 128, 28));
        assert!(p.is_valid(&conv(), 16));
    }

    #[test]
    fn node_shape_splits_ceiling() {
        let p = PartitionScheme { pn: 4, pk: 2, px: 2, ..PartitionScheme::single() };
        let p = PartitionScheme { region: (4, 4), ..p };
        assert!(p.is_valid(&conv(), 16));
        let s = p.node_shape(&conv(), 16);
        assert_eq!(s.n, 4);
        assert_eq!(s.k, 64);
        assert_eq!(s.xo, 14);
        assert_eq!(s.c, 64); // unsplit
    }

    #[test]
    fn enumerate_covers_area_exactly() {
        let ps = enumerate_partitions(&conv(), 16, (2, 2), false);
        assert!(!ps.is_empty());
        for p in &ps {
            assert_eq!(p.used_nodes(), 4, "{p:?}");
            assert!(p.is_valid(&conv(), 16));
        }
        // Hybrid schemes present: some partition two different dims.
        assert!(ps.iter().any(|p| p.pn > 1 && p.pk > 1));
    }

    #[test]
    fn sharing_variants_added() {
        let ps = enumerate_partitions(&conv(), 16, (2, 2), true);
        assert!(ps.iter().any(|p| p.share_ifm));
        assert!(ps.iter().any(|p| p.share_wgt));
        let ps0 = enumerate_partitions(&conv(), 16, (2, 2), false);
        assert!(ps.len() > ps0.len());
    }

    #[test]
    fn fc_never_partitions_fmap() {
        let fc = Layer::fc("f", 512, 512);
        for p in enumerate_partitions(&fc, 16, (4, 4), true) {
            assert_eq!((p.px, p.py), (1, 1));
        }
    }

    #[test]
    fn dwconv_never_partitions_c() {
        let dw = Layer::dwconv("d", 64, 28, 3, 1);
        let ps = enumerate_partitions(&dw, 16, (2, 2), false);
        assert!(!ps.is_empty());
        for p in &ps {
            assert_eq!(p.pc, 1);
        }
        // channel split halves both c and k
        let p = ps.iter().find(|p| p.pk == 4).unwrap();
        let s = p.node_shape(&dw, 16);
        assert_eq!((s.c, s.k), (16, 16));
    }

    #[test]
    fn batch1_limits_pn() {
        for p in enumerate_partitions(&conv(), 1, (4, 4), false) {
            assert_eq!(p.pn, 1);
        }
    }

    #[test]
    fn replication_and_sharing_factors() {
        let p = PartitionScheme {
            region: (4, 4),
            pn: 2,
            pk: 4,
            pc: 1,
            px: 2,
            py: 1,
            share_ifm: true,
            share_wgt: false,
        };
        assert_eq!(p.ifm_replication(), 4);
        assert_eq!(p.ifm_shr(), 4);
        assert_eq!(p.wgt_replication(), 4);
        assert_eq!(p.wgt_shr(), 1);
        assert_eq!(p.ofm_reduction(), 1);
    }

    #[test]
    fn invalid_when_overcommitted() {
        let p = PartitionScheme { region: (2, 2), pn: 8, ..PartitionScheme::single() };
        assert!(!p.is_valid(&conv(), 4)); // pn > batch and > nodes
    }

    #[test]
    fn dram_hops_grow_with_region() {
        let small = PartitionScheme { region: (2, 2), ..PartitionScheme::single() };
        let big = PartitionScheme { region: (16, 16), ..PartitionScheme::single() };
        assert!(big.dram_hops() > small.dram_hops());
    }
}
