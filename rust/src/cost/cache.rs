//! Memoized candidate evaluation: a thread-safe cache in front of the
//! detailed simulator (`sim::evaluate_layer`).
//!
//! Every solver family evaluates large candidate sets on the detailed
//! model, and the same (scheme, forwarding) pair recurs constantly: the
//! KAPLA stacking pass re-probes partitions along its hill-climbing paths
//! and the final solve re-scores the probe schemes; the inter-layer DP
//! re-enumerates overlapping spans whose segments share layer contexts;
//! the ML baseline proposes duplicate mutations. `evaluate_layer` is a
//! pure function of (arch, scheme, ifm_on_chip), so one `CostCache` is
//! shared per scheduling run — across `solvers::kapla::solve_intra`,
//! `solvers::exhaustive`, `solvers::random`, `solvers::ml` and the worker
//! threads of the parallel intra-layer sweep (MAESTRO-style analytical
//! models get their speed from exactly this kind of cheap repeated query).
//!
//! The map is sharded under independent mutexes so the scoped worker pool
//! (`util::par_map`) can hit it concurrently with little contention, and
//! the key is built from the scheme's integer fields only (the f64 members
//! of `UnitMap` are themselves pure functions of those fields), so lookups
//! are exact — no float hashing, no collisions by construction.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::arch::{ArchConfig, PeDataflow};
use crate::directives::{LayerScheme, LevelBlock};
use crate::mapping::LayerShape;
use crate::partition::PartitionScheme;
use crate::sim::LayerEval;

/// Exact identity of one detailed-model evaluation. `UnitMap`'s derived
/// f64 fields (utilization) and derived quantities (granule, totals) are
/// functions of (shape, array, dataflow, rs_chunk), so together with the
/// arch fingerprint this integer tuple uniquely determines the result.
/// Shared with the bounded cross-job [`super::SessionCache`], which reuses
/// the exact same key (including `arch_fp`) so session sharing can never
/// alias entries across hardware configs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub(crate) struct SchemeKey {
    pub(crate) arch_fp: u64,
    pub(crate) shape: LayerShape,
    pub(crate) array: (u64, u64),
    pub(crate) dataflow: PeDataflow,
    pub(crate) rs_chunk: u64,
    pub(crate) part: PartitionScheme,
    pub(crate) regf: LevelBlock,
    pub(crate) gbuf: LevelBlock,
    pub(crate) ifm_on_chip: bool,
}

impl SchemeKey {
    pub(crate) fn of(arch: &ArchConfig, s: &LayerScheme, ifm_on_chip: bool) -> SchemeKey {
        SchemeKey {
            arch_fp: arch_fingerprint(arch),
            shape: s.unit.shape,
            array: s.unit.array,
            // The unit map carries its template as a trait object; the
            // arch's dataflow selector is the same information in hashable
            // form (UnitMap::build derives one from the other).
            dataflow: arch.pe_dataflow,
            rs_chunk: s.unit.rs_chunk,
            part: s.part,
            regf: s.regf,
            gbuf: s.gbuf,
            ifm_on_chip,
        }
    }
}

/// FNV fingerprint of every `ArchConfig` field the detailed model reads, so
/// one cache shared across hardware configs (hw sweeps, a future cross-job
/// cache) can never return an evaluation computed for another arch.
///
/// Recomputed per lookup on purpose: ~17 u64 mixes are noise next to the
/// shard lock + map probe, and any memo keyed on `&ArchConfig` identity
/// (address) could alias a reallocated config — the exact bug this
/// fingerprint exists to prevent.
pub(crate) fn arch_fingerprint(arch: &ArchConfig) -> u64 {
    crate::util::fnv1a([
        arch.nodes.0,
        arch.nodes.1,
        arch.pes.0,
        arch.pes.1,
        arch.regf.bytes,
        arch.gbuf.bytes,
        arch.word_bytes,
        arch.mac_pj.to_bits(),
        arch.regf.pj_per_word.to_bits(),
        arch.gbuf.pj_per_word.to_bits(),
        arch.gbuf.words_per_cycle.to_bits(),
        arch.dram.pj_per_word.to_bits(),
        arch.noc_pj_per_bit_hop.to_bits(),
        arch.noc_words_per_cycle.to_bits(),
        arch.dram_bw_bytes_per_s.to_bits(),
        arch.freq_hz.to_bits(),
        matches!(arch.pe_dataflow, PeDataflow::Systolic) as u64,
    ])
}

pub(crate) const SHARDS: usize = 16;

/// Shard index of a key — one hash, shared by [`CostCache`] and the bounded
/// [`super::SessionCache`] so both spread identically.
pub(crate) fn shard_of(key: &SchemeKey) -> usize {
    let mut h = DefaultHasher::new();
    key.hash(&mut h);
    (h.finish() as usize) % SHARDS
}

/// Counter snapshot of an evaluation cache. `lookups`/`hits`/`evictions`
/// are cumulative since the cache was constructed (so for a shared
/// scheduling session they aggregate across jobs); `entries` is the number
/// of evaluations resident right now.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheStats {
    pub lookups: u64,
    pub hits: u64,
    pub evictions: u64,
    pub entries: usize,
    /// Lookups into the cross-job intra-layer *argmin* memo (whole
    /// enumeration optima keyed by [`super::IntraKey`], not individual
    /// evaluations). Zero for caches without one (the per-run `CostCache`).
    pub intra_lookups: u64,
    /// Argmin-memo lookups answered from a recorded scan — each hit skips
    /// an entire intra-layer search, not just one evaluation.
    pub intra_hits: u64,
    /// Lookups into the content-addressed on-disk schedule store
    /// (`cost::store`) — whole-request granularity, one per solve that
    /// consulted the store. Zero when no store is configured.
    pub store_lookups: u64,
    /// Store lookups answered by replaying a recorded `SolveResult` — each
    /// hit skips the entire search, every scan and every detailed
    /// evaluation.
    pub store_hits: u64,
    /// Snapshot/store entries rejected at load time (bad checksum, unknown
    /// version or tag, mismatched fingerprint). Skipped entries only cost
    /// warmth — they are never trusted — but a nonzero value on a freshly
    /// written snapshot indicates corruption.
    pub load_skipped: u64,
}

impl CacheStats {
    /// Saturating on purpose: a snapshot taken while other threads are
    /// mid-lookup can tear (the counters are independent relaxed atomics),
    /// so a torn `hits > lookups` reads as 0 misses, never an underflow.
    pub fn misses(&self) -> u64 {
        self.lookups.saturating_sub(self.hits)
    }

    /// Fraction of lookups answered from the memo (0.0 when unused,
    /// clamped to 1.0 against torn concurrent snapshots).
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            (self.hits as f64 / self.lookups as f64).min(1.0)
        }
    }

    /// Render the counters as a JSON object — the shape shared by service
    /// responses, bench reports and CLI consumers.
    pub fn to_json(&self) -> crate::util::json::Json {
        let mut o = crate::util::json::Json::obj();
        o.set("lookups", self.lookups.into())
            .set("hits", self.hits.into())
            .set("misses", self.misses().into())
            .set("evictions", self.evictions.into())
            .set("entries", self.entries.into())
            .set("hit_rate", self.hit_rate().into())
            .set("intra_lookups", self.intra_lookups.into())
            .set("intra_hits", self.intra_hits.into())
            .set("store_lookups", self.store_lookups.into())
            .set("store_hits", self.store_hits.into())
            .set("load_skipped", self.load_skipped.into());
        o
    }
}

/// A memoizing front end to `sim::evaluate_layer`. Implemented by the
/// unbounded per-run [`CostCache`] and the budgeted cross-job
/// [`super::SessionCache`]; every solver family evaluates candidates
/// through this trait so one shared session can serve a whole job stream.
///
/// Implementations must be pure with respect to results: `evaluate_layer`
/// always returns exactly what a fresh `sim::evaluate_layer` call would
/// (caching and eviction may change *when* the simulator runs, never what
/// the caller sees) — the determinism invariant the golden-schedule tests
/// pin.
pub trait EvalCache: Sync {
    fn evaluate_layer(&self, arch: &ArchConfig, s: &LayerScheme, ifm_on_chip: bool) -> LayerEval;

    /// Cross-job intra-layer *argmin* memo, consulted before running a
    /// full intra-layer scan: `Some(argmin)` replays a recorded
    /// enumeration optimum (the inner `Option` is the scan's result —
    /// `None` when the recorded answer was "no valid scheme exists"),
    /// outer `None` means not recorded. Because every intra-layer solver
    /// is pure per `(arch, layer, ctx, solver)` — the fields
    /// [`super::IntraKey`] fingerprints — replaying never changes a
    /// schedule, it only skips the search. Backends without a cross-job
    /// store (the per-run [`CostCache`]) keep the default no-op: solitary
    /// runs already dedup contexts in the engine's per-run memo.
    fn intra_argmin(&self, key: &super::IntraKey) -> Option<Option<LayerScheme>> {
        let _ = key;
        None
    }

    /// Record a finished scan's argmin for [`EvalCache::intra_argmin`].
    fn record_intra_argmin(&self, key: super::IntraKey, argmin: Option<LayerScheme>) {
        let _ = (key, argmin);
    }

    /// Current counter snapshot.
    fn stats(&self) -> CacheStats;
}

/// Sharded memo table for `sim::evaluate_layer` results.
pub struct CostCache {
    shards: Vec<Mutex<HashMap<SchemeKey, LayerEval>>>,
    lookups: AtomicU64,
    hits: AtomicU64,
}

impl Default for CostCache {
    fn default() -> Self {
        CostCache::new()
    }
}

impl CostCache {
    pub fn new() -> CostCache {
        CostCache {
            shards: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            lookups: AtomicU64::new(0),
            hits: AtomicU64::new(0),
        }
    }

    /// Evaluate `s` on the detailed model, memoized. Concurrent misses on
    /// the same key may both compute (the function is pure, so they agree);
    /// the lock is never held across the evaluation itself.
    pub fn evaluate_layer(&self, arch: &ArchConfig, s: &LayerScheme, ifm_on_chip: bool) -> LayerEval {
        let key = SchemeKey::of(arch, s, ifm_on_chip);
        let shard = &self.shards[shard_of(&key)];
        self.lookups.fetch_add(1, Ordering::Relaxed);
        if let Some(ev) = shard.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return *ev;
        }
        let ev = crate::sim::evaluate_layer(arch, s, ifm_on_chip);
        shard.lock().unwrap().insert(key, ev);
        ev
    }

    /// Total lookups served since construction.
    pub fn lookups(&self) -> u64 {
        self.lookups.load(Ordering::Relaxed)
    }

    /// Lookups answered from the memo table.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Fraction of lookups answered from the memo table (0.0 when unused).
    pub fn hit_rate(&self) -> f64 {
        let l = self.lookups();
        if l == 0 {
            0.0
        } else {
            self.hits() as f64 / l as f64
        }
    }

    /// Distinct evaluations currently memoized.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl EvalCache for CostCache {
    fn evaluate_layer(&self, arch: &ArchConfig, s: &LayerScheme, ifm_on_chip: bool) -> LayerEval {
        CostCache::evaluate_layer(self, arch, s, ifm_on_chip)
    }

    fn stats(&self) -> CacheStats {
        // Hits read before lookups (each hit bumps lookups first) to make
        // torn concurrent snapshots unlikely; relaxed atomics can still
        // reorder, so misses()/hit_rate() clamp rather than trust this.
        let hits = self.hits();
        CacheStats {
            lookups: self.lookups(),
            hits,
            evictions: 0,
            entries: self.len(),
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::directives::{Grp, LoopOrder, Qty};
    use crate::mapping::UnitMap;
    use crate::workloads::Layer;

    fn scheme(arch: &ArchConfig, k: u64) -> LayerScheme {
        let l = Layer::conv("c", 16, k, 14, 3, 1);
        let part = PartitionScheme::single();
        let unit = UnitMap::build(arch, part.node_shape(&l, 4));
        LayerScheme {
            part,
            unit,
            regf: LevelBlock { qty: Qty::new(1, 2, 2), order: LoopOrder([Grp::B, Grp::K, Grp::C]) },
            gbuf: LevelBlock { qty: Qty::new(1, 8, 8), order: LoopOrder([Grp::B, Grp::C, Grp::K]) },
        }
    }

    #[test]
    fn repeated_lookup_hits_and_matches_simulator() {
        let arch = presets::multi_node_eyeriss();
        let cache = CostCache::new();
        let s = scheme(&arch, 32);
        let a = cache.evaluate_layer(&arch, &s, false);
        let b = cache.evaluate_layer(&arch, &s, false);
        assert_eq!(cache.lookups(), 2);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.len(), 1);
        let direct = crate::sim::evaluate_layer(&arch, &s, false);
        assert_eq!(a.energy.total(), direct.energy.total());
        assert_eq!(b.latency_cycles, direct.latency_cycles);
        assert!((cache.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn forwarding_flag_is_part_of_the_key() {
        let arch = presets::multi_node_eyeriss();
        let cache = CostCache::new();
        let s = scheme(&arch, 32);
        let off = cache.evaluate_layer(&arch, &s, false);
        let on = cache.evaluate_layer(&arch, &s, true);
        assert_eq!(cache.hits(), 0, "distinct forwarding must not alias");
        assert_eq!(cache.len(), 2);
        assert!(on.energy.dram_pj < off.energy.dram_pj);
    }

    #[test]
    fn distinct_schemes_do_not_alias() {
        let arch = presets::multi_node_eyeriss();
        let cache = CostCache::new();
        let a = cache.evaluate_layer(&arch, &scheme(&arch, 32), false);
        let b = cache.evaluate_layer(&arch, &scheme(&arch, 64), false);
        assert_eq!(cache.hits(), 0);
        assert!(b.energy.total() > a.energy.total());
    }

    #[test]
    fn arch_is_part_of_the_key() {
        // Two configs with identical node internals except GBUF capacity:
        // the scheme structure (and thus the rest of the key) is identical,
        // so only the arch fingerprint separates the entries.
        let a1 = crate::arch::presets::eyeriss_like((4, 4), (8, 8), 64, 32 * 1024);
        let a2 = crate::arch::presets::eyeriss_like((4, 4), (8, 8), 64, 64 * 1024);
        let cache = CostCache::new();
        let s = scheme(&a1, 32);
        let e1 = cache.evaluate_layer(&a1, &s, false);
        let e2 = cache.evaluate_layer(&a2, &s, false);
        assert_eq!(cache.hits(), 0, "different arches must not alias");
        assert_eq!(cache.len(), 2);
        // Larger GBUF costs more per access (sqrt-capacity energy fit).
        assert!(e2.energy.gbuf_pj > e1.energy.gbuf_pj);
    }

    #[test]
    fn concurrent_access_is_consistent() {
        let arch = presets::multi_node_eyeriss();
        let cache = CostCache::new();
        let schemes: Vec<LayerScheme> =
            (0..16).map(|i| scheme(&arch, 16 + 16 * (i % 4))).collect();
        let evs = crate::util::par_map(&schemes, 4, |s| {
            cache.evaluate_layer(&arch, s, false).energy.total()
        });
        for (s, e) in schemes.iter().zip(&evs) {
            assert_eq!(*e, crate::sim::evaluate_layer(&arch, s, false).energy.total());
        }
        assert_eq!(cache.len(), 4, "four distinct K values");
        assert_eq!(cache.lookups(), 16);
    }
}
