//! The tiered cost model (paper §IV): one object that exposes *both*
//! fidelity tiers the KAPLA design decouples —
//!
//! * **estimate** — the pure-arithmetic optimistic lower bounds
//!   (`layer_lower_bound` / `segment_lower_bound`, §IV-B "Fast cost
//!   estimation") the inter-layer search uses to prune and prioritize
//!   cheaply, and
//! * **evaluate** — the detailed simulator (`sim::evaluate_layer`),
//!   reached through a memoizing [`EvalCache`], that scores the few
//!   candidates the search actually realizes.
//!
//! Threading one `&dyn CostModel` through pruning, DP scoring and the
//! intra-layer descent (instead of wiring free functions and caches into
//! each solver separately) keeps the two tiers coherent — the
//! admissibility invariant `estimate <= evaluate` becomes a property of
//! the model object (`tests/cost_model_admissibility.rs`) — and makes a
//! future backend (batched-PJRT kernel scoring, a persisted session) a
//! drop-in `CostModel` impl rather than another solver-surface fork.

use crate::arch::ArchConfig;
use crate::directives::{LayerScheme, Qty};
use crate::interlayer::Segment;
use crate::mapping::UnitMap;
use crate::partition::PartitionScheme;
use crate::sim::StagedEval;
use crate::workloads::{Layer, Network};

use super::cache::{CacheStats, CostCache, EvalCache};
use super::{layer_lower_bound, segment_lower_bound, CostEstimate, LayerCtx};

/// The two-tier cost model every solver stage draws from.
///
/// The estimate tier must be *admissible*: for any scheme realizable in
/// the given context, `estimate_*` never exceeds what `evaluate` reports
/// for it (the DP keeps top-k chains to absorb the remaining gap, paper
/// §IV-B). The evaluate tier must be *pure*: repeated calls — through any
/// cache, budget or eviction policy — return exactly what a fresh
/// detailed simulation would.
pub trait CostModel: Sync {
    /// Fast tier: optimistic lower bound for one layer in a segment
    /// context (pure arithmetic, no search state).
    fn estimate_layer(&self, arch: &ArchConfig, layer: &Layer, ctx: &LayerCtx) -> CostEstimate {
        layer_lower_bound(arch, layer, ctx)
    }

    /// Fast tier: optimistic lower bound for a whole segment scheme.
    fn estimate_segment(
        &self,
        arch: &ArchConfig,
        net: &Network,
        batch: u64,
        seg: &Segment,
    ) -> CostEstimate {
        segment_lower_bound(arch, net, batch, seg)
    }

    /// Detailed tier: evaluate one concrete intra-layer scheme on the
    /// detailed model (cache-backed).
    fn evaluate(&self, arch: &ArchConfig, s: &LayerScheme, ifm_on_chip: bool) -> CostEstimate;

    /// Detailed tier, staged: a [`StagedEval`] for one `(part, unit)`
    /// enumeration prefix, or `None` when this backend has no staged
    /// shortcut and callers must score every candidate through
    /// [`CostModel::evaluate`]. An implementation returning `Some` opts the
    /// enumeration hot path (`solvers::space::visit_schemes_staged`) into
    /// incremental scoring *and* branch-and-bound pruning, and therefore
    /// promises that the staged results — and the [`CostModel::bound_prefix`]
    /// lower bound — match its `evaluate` exactly; the default `None` keeps
    /// external backends on the one-candidate-at-a-time contract.
    fn staged<'a>(
        &self,
        arch: &'a ArchConfig,
        part: &PartitionScheme,
        unit: &UnitMap,
        ifm_on_chip: bool,
    ) -> Option<StagedEval<'a>> {
        let _ = (arch, part, unit, ifm_on_chip);
        None
    }

    /// Admissible lower bound on `evaluate` for *every* completion of a
    /// `(part, gbuf block)` enumeration prefix — any gbuf/regf loop order,
    /// any nested REGF block. Only consulted when [`CostModel::staged`]
    /// returned `Some`, so the default (the staged floor of the detailed
    /// simulator) is admissible exactly when the staged evaluator is the
    /// detailed simulator.
    fn bound_prefix(&self, staged: &StagedEval<'_>, gq: Qty) -> CostEstimate {
        staged.bound_prefix(gq)
    }

    /// Admissible lower bound on `evaluate` over *every* blocking of a
    /// `(part, unit)` enumeration prefix — the partition level of the
    /// bound hierarchy (partition → prefix → span), one level above
    /// [`CostModel::bound_prefix`]: gq/go-independent, so the scan can
    /// skip a whole partition before enumerating a single blocking. Like
    /// `bound_prefix`, only consulted when [`CostModel::staged`] returned
    /// `Some`, so the default (the staged partition floor of the detailed
    /// simulator) is admissible exactly when the staged evaluator is the
    /// detailed simulator.
    fn bound_partition(&self, staged: &StagedEval<'_>) -> CostEstimate {
        staged.bound_partition()
    }

    /// Cross-job intra-layer argmin memo, consulted by the solver engine
    /// before running a full intra-layer scan (see
    /// [`EvalCache::intra_argmin`] for the contract). The default `None`
    /// ("not recorded") keeps external backends — and per-run caches — on
    /// the always-scan path; [`TieredCost`] forwards to its cache, so a
    /// session-backed model replays recorded scans across jobs.
    fn intra_argmin(&self, key: &super::IntraKey) -> Option<Option<LayerScheme>> {
        let _ = key;
        None
    }

    /// Record a finished scan's argmin for [`CostModel::intra_argmin`].
    fn record_intra_argmin(&self, key: super::IntraKey, argmin: Option<LayerScheme>) {
        let _ = (key, argmin);
    }

    /// Counter snapshot of the detailed tier's evaluation cache (zeros for
    /// backends without one).
    fn stats(&self) -> CacheStats {
        CacheStats::default()
    }
}

enum Detail<'a> {
    /// A private, unbounded per-run memo.
    Owned(CostCache),
    /// A caller-supplied cache — typically a cross-job `SessionCache`.
    Shared(&'a dyn EvalCache),
}

/// The default [`CostModel`]: the in-tree lower-bound formulas for the
/// estimate tier, composed with any [`EvalCache`] implementation for the
/// detailed tier.
pub struct TieredCost<'a> {
    detail: Detail<'a>,
}

impl<'a> TieredCost<'a> {
    /// A model with a private, fresh evaluation memo (solitary runs).
    pub fn fresh() -> TieredCost<'static> {
        TieredCost { detail: Detail::Owned(CostCache::new()) }
    }

    /// A model whose detailed tier runs through a shared cache — the way
    /// scheduling sessions reuse evaluations across jobs.
    pub fn over(cache: &'a dyn EvalCache) -> TieredCost<'a> {
        TieredCost { detail: Detail::Shared(cache) }
    }

    fn cache(&self) -> &dyn EvalCache {
        match &self.detail {
            Detail::Owned(c) => c,
            Detail::Shared(c) => *c,
        }
    }
}

impl CostModel for TieredCost<'_> {
    fn evaluate(&self, arch: &ArchConfig, s: &LayerScheme, ifm_on_chip: bool) -> CostEstimate {
        let ev = self.cache().evaluate_layer(arch, s, ifm_on_chip);
        CostEstimate { energy_pj: ev.energy.total(), latency_cycles: ev.latency_cycles }
    }

    /// The detailed tier *is* `sim::evaluate_layer` (the cache is pure), so
    /// the staged evaluator scores enumeration-unique candidates directly —
    /// skipping the per-candidate `SchemeKey` hashing entirely — while
    /// staying bit-identical to `evaluate`. The memo keeps serving the
    /// revisit-heavy paths (KAPLA's descent probes, cross-job sessions) at
    /// the `SolveCtx` boundary.
    fn staged<'a>(
        &self,
        arch: &'a ArchConfig,
        part: &PartitionScheme,
        unit: &UnitMap,
        ifm_on_chip: bool,
    ) -> Option<StagedEval<'a>> {
        Some(StagedEval::new(arch, *part, *unit, ifm_on_chip))
    }

    fn intra_argmin(&self, key: &super::IntraKey) -> Option<Option<LayerScheme>> {
        self.cache().intra_argmin(key)
    }

    fn record_intra_argmin(&self, key: super::IntraKey, argmin: Option<LayerScheme>) {
        self.cache().record_intra_argmin(key, argmin)
    }

    fn stats(&self) -> CacheStats {
        self.cache().stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::cost::SessionCache;
    use crate::directives::{Grp, LevelBlock, LoopOrder, Qty};
    use crate::mapping::UnitMap;
    use crate::partition::PartitionScheme;
    use crate::workloads::nets;

    fn scheme(arch: &ArchConfig) -> LayerScheme {
        let l = crate::workloads::Layer::conv("c", 16, 32, 14, 3, 1);
        let part = PartitionScheme::single();
        let unit = UnitMap::build(arch, part.node_shape(&l, 4));
        LayerScheme {
            part,
            unit,
            regf: LevelBlock { qty: Qty::new(1, 2, 2), order: LoopOrder([Grp::B, Grp::K, Grp::C]) },
            gbuf: LevelBlock { qty: Qty::new(1, 8, 8), order: LoopOrder([Grp::B, Grp::C, Grp::K]) },
        }
    }

    #[test]
    fn evaluate_matches_detailed_simulator() {
        let arch = presets::multi_node_eyeriss();
        let model = TieredCost::fresh();
        let s = scheme(&arch);
        let got = model.evaluate(&arch, &s, false);
        let want = crate::sim::evaluate_layer(&arch, &s, false);
        assert_eq!(got.energy_pj, want.energy.total());
        assert_eq!(got.latency_cycles, want.latency_cycles);
        // Repeats hit the owned memo.
        model.evaluate(&arch, &s, false);
        assert_eq!(model.stats().hits, 1);
        assert_eq!(model.stats().lookups, 2);
    }

    #[test]
    fn estimate_tier_matches_free_functions() {
        let arch = presets::multi_node_eyeriss();
        let net = nets::alexnet();
        let model = TieredCost::fresh();
        let ctx = LayerCtx {
            nodes: 16,
            round_batch: 4,
            rounds: 1,
            ifm_on_chip: false,
            ofm_on_chip: false,
            dram_hops: 2.0,
        };
        let a = model.estimate_layer(&arch, &net.layers[0], &ctx);
        let b = layer_lower_bound(&arch, &net.layers[0], &ctx);
        assert_eq!(a, b);
        let seg = Segment::single(0, &arch);
        let a = model.estimate_segment(&arch, &net, 16, &seg);
        let b = segment_lower_bound(&arch, &net, 16, &seg);
        assert_eq!(a, b);
    }

    #[test]
    fn staged_tier_matches_evaluate_bit_for_bit() {
        let arch = presets::multi_node_eyeriss();
        let model = TieredCost::fresh();
        let s = scheme(&arch);
        for ifm_on_chip in [false, true] {
            let staged = model.staged(&arch, &s.part, &s.unit, ifm_on_chip).expect("tiered opts in");
            let via_staged = staged.gbuf(s.gbuf.qty, s.gbuf.order).cost(s.regf.qty, s.regf.order);
            assert_eq!(via_staged, model.evaluate(&arch, &s, ifm_on_chip));
            // The prefix bound never exceeds any completion's evaluation.
            let bound = model.bound_prefix(&staged, s.gbuf.qty);
            assert!(bound.energy_pj <= via_staged.energy_pj);
            assert!(bound.latency_cycles <= via_staged.latency_cycles);
            // And the partition bound never exceeds the prefix bound — the
            // full hierarchy: partition <= prefix <= evaluation.
            let pb = model.bound_partition(&staged);
            assert!(pb.energy_pj <= bound.energy_pj + 1e-9);
            assert!(pb.latency_cycles <= bound.latency_cycles + 1e-9);
        }
    }

    #[test]
    fn shared_model_reports_shared_stats() {
        let arch = presets::multi_node_eyeriss();
        let session = SessionCache::unbounded();
        let s = scheme(&arch);
        {
            let model = TieredCost::over(&session);
            model.evaluate(&arch, &s, false);
            model.evaluate(&arch, &s, false);
            assert_eq!(model.stats().hits, 1);
        }
        // The evaluations outlive the model: a second model over the same
        // session answers from the shared memo.
        let model = TieredCost::over(&session);
        model.evaluate(&arch, &s, false);
        assert_eq!(model.stats().hits, 2);
    }
}
