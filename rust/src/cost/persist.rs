//! On-disk session snapshots: the persistence layer of the warm tier
//! (ROADMAP item 2), serializing the [`SessionCache`] evaluation memo and
//! the `IntraKey -> argmin` memo so a service restart or a fresh CI shard
//! starts warm at the *scan* granularity.
//!
//! The format is hand-rolled length-prefixed binary (the crate is
//! zero-dependency): an 8-byte magic + u32 version header, then a stream
//! of self-delimiting records `[tag u8][len u32][payload][fnv1a u64]`.
//! Floats travel as `f64::to_bits`, enums as explicit u8 maps, so a
//! round-trip is bit-exact. Safety comes from never trusting the file:
//!
//! * every record carries an FNV-1a checksum of its payload — torn or
//!   flipped bytes fail it and the record is skipped;
//! * entries are self-describing via the same fingerprints the in-memory
//!   memos key on (`arch_fp` inside [`SchemeKey`] / [`IntraKey`]), so a
//!   snapshot written for different hardware warms nothing — mismatched
//!   entries are skipped, not aliased;
//! * an unknown magic, version, tag or enum byte skips (file, record,
//!   record respectively) rather than guessing — forward compatibility is
//!   "start cold", never "trust stale bytes";
//! * everything skipped is counted ([`SnapshotStats::skipped`], surfaced
//!   as `load_skipped` in [`super::CacheStats`]) so corruption is visible
//!   even though it is harmless.
//!
//! Writes are atomic: the snapshot is staged to a pid-suffixed temp file
//! in the same directory and `rename`d into place, so a killed process
//! leaves either the old snapshot or the new one, never a torn file.
//! Because the evaluator and every intra-layer solver are pure in exactly
//! the fingerprinted inputs, loading a snapshot can only change *when*
//! searches run, never their results — the same invariant the in-memory
//! session relies on.

use std::fs;
use std::io;
use std::path::Path;

use crate::arch::{ArchConfig, PeDataflow};
use crate::directives::scheme::AccessCounts;
use crate::directives::{Grp, LayerScheme, LevelBlock, LoopOrder, Qty};
use crate::mapping::{ArrayMapping, LayerShape, RowStationary, Systolic, UnitMap};
use crate::partition::PartitionScheme;
use crate::sim::{EnergyBreakdown, LayerEval};
use crate::workloads::LayerKind;

use super::cache::{arch_fingerprint, SchemeKey};
use super::session::{IntraKey, SessionCache};

const SNAPSHOT_MAGIC: &[u8; 8] = b"KAPLASNP";
/// Bumped on any encoding change; a mismatch loads nothing (cold start).
pub const SNAPSHOT_VERSION: u32 = 1;

const TAG_EVAL: u8 = 1;
const TAG_INTRA: u8 = 2;

/// What a snapshot load (or save) touched. `skipped` counts records
/// rejected rather than trusted: bad checksum, unknown tag/enum byte,
/// truncation remainder, or a fingerprint that doesn't match the session's
/// arch filter.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SnapshotStats {
    pub eval_entries: u64,
    pub intra_entries: u64,
    pub skipped: u64,
}

// ---------------------------------------------------------------------------
// Byte codec (shared with `cost::store`).

/// Little-endian byte sink for the snapshot/store payloads.
#[derive(Default)]
pub(crate) struct ByteWriter {
    pub(crate) buf: Vec<u8>,
}

impl ByteWriter {
    pub(crate) fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    pub(crate) fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub(crate) fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub(crate) fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    pub(crate) fn bool(&mut self, v: bool) {
        self.u8(v as u8);
    }
}

/// Bounds-checked little-endian reader: every accessor returns `None` on
/// truncation, and `bool` rejects anything but 0/1 so corrupted payloads
/// fail decoding instead of smuggling garbage in.
pub(crate) struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> ByteReader<'a> {
        ByteReader { buf, pos: 0 }
    }

    pub(crate) fn is_empty(&self) -> bool {
        self.pos >= self.buf.len()
    }

    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            return None;
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Some(s)
    }

    pub(crate) fn u8(&mut self) -> Option<u8> {
        self.take(1).map(|s| s[0])
    }
    pub(crate) fn u32(&mut self) -> Option<u32> {
        self.take(4).map(|s| u32::from_le_bytes(s.try_into().unwrap()))
    }
    pub(crate) fn u64(&mut self) -> Option<u64> {
        self.take(8).map(|s| u64::from_le_bytes(s.try_into().unwrap()))
    }
    pub(crate) fn f64(&mut self) -> Option<f64> {
        self.u64().map(f64::from_bits)
    }
    pub(crate) fn bool(&mut self) -> Option<bool> {
        match self.u8()? {
            0 => Some(false),
            1 => Some(true),
            _ => None,
        }
    }
}

/// FNV-1a over raw bytes — the per-record checksum.
pub(crate) fn bytes_fp(b: &[u8]) -> u64 {
    crate::util::fnv1a(b.iter().map(|&x| x as u64))
}

/// Append one framed record: `[tag][len u32][payload][fnv1a(payload) u64]`.
pub(crate) fn push_record(out: &mut Vec<u8>, tag: u8, payload: &[u8]) {
    out.push(tag);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    out.extend_from_slice(&bytes_fp(payload).to_le_bytes());
}

/// Stage `bytes` to a uniquely-named temp file beside `path` and rename
/// it into place — readers see the old file or the new one, never a torn
/// mix. The temp name carries the pid *and* a process-wide sequence
/// number so concurrent writers (other processes, or threads of this
/// one) each stage to their own file; last rename wins whole.
pub(crate) fn write_atomic(path: &Path, bytes: &[u8]) -> io::Result<()> {
    use std::sync::atomic::{AtomicU64, Ordering};
    static TMP_SEQ: AtomicU64 = AtomicU64::new(0);
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            fs::create_dir_all(dir)?;
        }
    }
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(format!(
        ".tmp.{}.{}",
        std::process::id(),
        TMP_SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let tmp = std::path::PathBuf::from(tmp);
    fs::write(&tmp, bytes)?;
    fs::rename(&tmp, path).inspect_err(|_| {
        let _ = fs::remove_file(&tmp);
    })
}

// ---------------------------------------------------------------------------
// Struct codecs. Enum byte maps are explicit (declaration order) so the
// on-disk values are stable against source reordering only if the maps
// here change with them — which is what SNAPSHOT_VERSION exists to gate.

fn write_grp(w: &mut ByteWriter, g: Grp) {
    w.u8(match g {
        Grp::B => 0,
        Grp::C => 1,
        Grp::K => 2,
    });
}

fn read_grp(r: &mut ByteReader) -> Option<Grp> {
    match r.u8()? {
        0 => Some(Grp::B),
        1 => Some(Grp::C),
        2 => Some(Grp::K),
        _ => None,
    }
}

fn write_kind(w: &mut ByteWriter, k: LayerKind) {
    w.u8(match k {
        LayerKind::Conv => 0,
        LayerKind::DWConv => 1,
        LayerKind::Fc => 2,
        LayerKind::Pool => 3,
        LayerKind::Eltwise => 4,
        LayerKind::ConvBwWeight => 5,
        LayerKind::ConvBwAct => 6,
        LayerKind::DWConvBwAct => 7,
    });
}

fn read_kind(r: &mut ByteReader) -> Option<LayerKind> {
    match r.u8()? {
        0 => Some(LayerKind::Conv),
        1 => Some(LayerKind::DWConv),
        2 => Some(LayerKind::Fc),
        3 => Some(LayerKind::Pool),
        4 => Some(LayerKind::Eltwise),
        5 => Some(LayerKind::ConvBwWeight),
        6 => Some(LayerKind::ConvBwAct),
        7 => Some(LayerKind::DWConvBwAct),
        _ => None,
    }
}

fn write_dataflow(w: &mut ByteWriter, d: PeDataflow) {
    w.u8(match d {
        PeDataflow::RowStationary => 0,
        PeDataflow::Systolic => 1,
    });
}

fn read_dataflow(r: &mut ByteReader) -> Option<PeDataflow> {
    match r.u8()? {
        0 => Some(PeDataflow::RowStationary),
        1 => Some(PeDataflow::Systolic),
        _ => None,
    }
}

/// The array-mapping trait object travels as a template tag; decode
/// resolves it back to the two statics (the same pair
/// `mapping::array_mapping` dispatches to).
fn mapping_tag(m: &'static dyn ArrayMapping) -> u8 {
    if m.name() == RowStationary.name() {
        0
    } else {
        1
    }
}

fn read_mapping(r: &mut ByteReader) -> Option<&'static dyn ArrayMapping> {
    match r.u8()? {
        0 => Some(&RowStationary),
        1 => Some(&Systolic),
        _ => None,
    }
}

fn write_qty(w: &mut ByteWriter, q: Qty) {
    w.u64(q.b);
    w.u64(q.c);
    w.u64(q.k);
}

fn read_qty(r: &mut ByteReader) -> Option<Qty> {
    Some(Qty { b: r.u64()?, c: r.u64()?, k: r.u64()? })
}

fn write_level(w: &mut ByteWriter, l: LevelBlock) {
    write_qty(w, l.qty);
    for g in l.order.0 {
        write_grp(w, g);
    }
}

fn read_level(r: &mut ByteReader) -> Option<LevelBlock> {
    let qty = read_qty(r)?;
    let order = LoopOrder([read_grp(r)?, read_grp(r)?, read_grp(r)?]);
    Some(LevelBlock { qty, order })
}

fn write_shape(w: &mut ByteWriter, s: LayerShape) {
    write_kind(w, s.kind);
    for v in [s.n, s.c, s.k, s.xo, s.yo, s.r, s.s, s.stride] {
        w.u64(v);
    }
}

fn read_shape(r: &mut ByteReader) -> Option<LayerShape> {
    Some(LayerShape {
        kind: read_kind(r)?,
        n: r.u64()?,
        c: r.u64()?,
        k: r.u64()?,
        xo: r.u64()?,
        yo: r.u64()?,
        r: r.u64()?,
        s: r.u64()?,
        stride: r.u64()?,
    })
}

fn write_part(w: &mut ByteWriter, p: PartitionScheme) {
    for v in [p.region.0, p.region.1, p.pn, p.pk, p.pc, p.px, p.py] {
        w.u64(v);
    }
    w.bool(p.share_ifm);
    w.bool(p.share_wgt);
}

fn read_part(r: &mut ByteReader) -> Option<PartitionScheme> {
    Some(PartitionScheme {
        region: (r.u64()?, r.u64()?),
        pn: r.u64()?,
        pk: r.u64()?,
        pc: r.u64()?,
        px: r.u64()?,
        py: r.u64()?,
        share_ifm: r.bool()?,
        share_wgt: r.bool()?,
    })
}

fn write_unit(w: &mut ByteWriter, u: &UnitMap) {
    w.u8(mapping_tag(u.mapping));
    write_shape(w, u.shape);
    w.u64(u.array.0);
    w.u64(u.array.1);
    write_qty(w, u.totals);
    write_qty(w, u.granule);
    w.f64(u.utilization);
    w.u64(u.rs_chunk);
}

fn read_unit(r: &mut ByteReader) -> Option<UnitMap> {
    Some(UnitMap {
        mapping: read_mapping(r)?,
        shape: read_shape(r)?,
        array: (r.u64()?, r.u64()?),
        totals: read_qty(r)?,
        granule: read_qty(r)?,
        utilization: r.f64()?,
        rs_chunk: r.u64()?,
    })
}

pub(crate) fn write_layer_scheme(w: &mut ByteWriter, s: &LayerScheme) {
    write_part(w, s.part);
    write_unit(w, &s.unit);
    write_level(w, s.regf);
    write_level(w, s.gbuf);
}

pub(crate) fn read_layer_scheme(r: &mut ByteReader) -> Option<LayerScheme> {
    Some(LayerScheme {
        part: read_part(r)?,
        unit: read_unit(r)?,
        regf: read_level(r)?,
        gbuf: read_level(r)?,
    })
}

fn write_scheme_key(w: &mut ByteWriter, k: &SchemeKey) {
    w.u64(k.arch_fp);
    write_shape(w, k.shape);
    w.u64(k.array.0);
    w.u64(k.array.1);
    write_dataflow(w, k.dataflow);
    w.u64(k.rs_chunk);
    write_part(w, k.part);
    write_level(w, k.regf);
    write_level(w, k.gbuf);
    w.bool(k.ifm_on_chip);
}

fn read_scheme_key(r: &mut ByteReader) -> Option<SchemeKey> {
    Some(SchemeKey {
        arch_fp: r.u64()?,
        shape: read_shape(r)?,
        array: (r.u64()?, r.u64()?),
        dataflow: read_dataflow(r)?,
        rs_chunk: r.u64()?,
        part: read_part(r)?,
        regf: read_level(r)?,
        gbuf: read_level(r)?,
        ifm_on_chip: r.bool()?,
    })
}

fn write_layer_eval(w: &mut ByteWriter, e: &LayerEval) {
    let en = &e.energy;
    for v in [en.alu_pj, en.regf_pj, en.bus_pj, en.gbuf_pj, en.noc_pj, en.dram_pj] {
        w.f64(v);
    }
    w.f64(e.latency_cycles);
    let a = &e.access;
    for v in a.dram {
        w.u64(v);
    }
    for v in a.gbuf {
        w.u64(v);
    }
    w.u64(a.gbuf_regf_side);
    w.u64(a.regf);
    w.f64(a.noc_word_hops);
    w.u64(a.macs);
    w.f64(e.compute_cycles);
    w.f64(e.dram_cycles);
}

fn read_layer_eval(r: &mut ByteReader) -> Option<LayerEval> {
    let energy = EnergyBreakdown {
        alu_pj: r.f64()?,
        regf_pj: r.f64()?,
        bus_pj: r.f64()?,
        gbuf_pj: r.f64()?,
        noc_pj: r.f64()?,
        dram_pj: r.f64()?,
    };
    let latency_cycles = r.f64()?;
    let access = AccessCounts {
        dram: [r.u64()?, r.u64()?, r.u64()?],
        gbuf: [r.u64()?, r.u64()?, r.u64()?],
        gbuf_regf_side: r.u64()?,
        regf: r.u64()?,
        noc_word_hops: r.f64()?,
        macs: r.u64()?,
    };
    Some(LayerEval {
        energy,
        latency_cycles,
        access,
        compute_cycles: r.f64()?,
        dram_cycles: r.f64()?,
    })
}

// ---------------------------------------------------------------------------
// Snapshot save / load.

fn encode_eval_record(key: &SchemeKey, eval: &LayerEval) -> Vec<u8> {
    let mut w = ByteWriter::default();
    write_scheme_key(&mut w, key);
    write_layer_eval(&mut w, eval);
    w.buf
}

fn encode_intra_record(key: &IntraKey, argmin: &Option<LayerScheme>) -> Vec<u8> {
    let mut w = ByteWriter::default();
    w.u64(key.arch_fp);
    w.u64(key.ctx_fp);
    w.u64(key.solver_fp);
    match argmin {
        None => w.u8(0),
        Some(s) => {
            w.u8(1);
            write_layer_scheme(&mut w, s);
        }
    }
    w.buf
}

/// Serialize every resident memo entry of `cache` to `path`, atomically.
/// Returns what was written (skipped is always 0 on save).
pub fn save_session(cache: &SessionCache, path: &Path) -> io::Result<SnapshotStats> {
    let mut out = Vec::with_capacity(64 * 1024);
    out.extend_from_slice(SNAPSHOT_MAGIC);
    out.extend_from_slice(&SNAPSHOT_VERSION.to_le_bytes());
    let mut stats = SnapshotStats::default();
    for (key, eval) in cache.export_eval() {
        push_record(&mut out, TAG_EVAL, &encode_eval_record(&key, &eval));
        stats.eval_entries += 1;
    }
    for (key, argmin) in cache.export_intra() {
        push_record(&mut out, TAG_INTRA, &encode_intra_record(&key, &argmin));
        stats.intra_entries += 1;
    }
    write_atomic(path, &out)?;
    Ok(stats)
}

/// Load a snapshot into `cache`, skipping (and counting) anything
/// unrecognized: bad header, bad checksum, unknown tag, bad enum byte,
/// truncation, or — when `arch` is given — entries fingerprinted for
/// different hardware. A missing file is a clean cold start. Skips are
/// also reported to the session's `load_skipped` counter; the cache is
/// never poisoned and this never panics on any byte sequence.
pub fn load_session(
    cache: &SessionCache,
    path: &Path,
    arch: Option<&ArchConfig>,
) -> io::Result<SnapshotStats> {
    let bytes = match fs::read(path) {
        Ok(b) => b,
        Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(SnapshotStats::default()),
        Err(e) => return Err(e),
    };
    let mut stats = SnapshotStats::default();
    let header_ok = bytes.len() >= 12
        && &bytes[..8] == SNAPSHOT_MAGIC
        && bytes[8..12] == SNAPSHOT_VERSION.to_le_bytes();
    if !header_ok {
        stats.skipped = 1;
        cache.note_load_skipped(stats.skipped);
        return Ok(stats);
    }
    let want_fp = arch.map(arch_fingerprint);
    let mut pos = 12;
    while pos < bytes.len() {
        // Frame: tag + len, payload, checksum. A truncated frame counts
        // once and stops — after a broken length there is no resync point.
        if bytes.len() - pos < 5 {
            stats.skipped += 1;
            break;
        }
        let tag = bytes[pos];
        let len = u32::from_le_bytes(bytes[pos + 1..pos + 5].try_into().unwrap()) as usize;
        pos += 5;
        if bytes.len() - pos < len + 8 {
            stats.skipped += 1;
            break;
        }
        let payload = &bytes[pos..pos + len];
        let ck = u64::from_le_bytes(bytes[pos + len..pos + len + 8].try_into().unwrap());
        pos += len + 8;
        if bytes_fp(payload) != ck {
            stats.skipped += 1;
            continue;
        }
        let mut r = ByteReader::new(payload);
        match tag {
            TAG_EVAL => match read_scheme_key(&mut r).zip(read_layer_eval(&mut r)) {
                Some((key, eval))
                    if r.is_empty() && want_fp.is_none_or(|fp| key.arch_fp == fp) =>
                {
                    cache.import_eval(key, eval);
                    stats.eval_entries += 1;
                }
                _ => stats.skipped += 1,
            },
            TAG_INTRA => {
                let decoded = (|| {
                    let key = IntraKey {
                        arch_fp: r.u64()?,
                        ctx_fp: r.u64()?,
                        solver_fp: r.u64()?,
                    };
                    let argmin = match r.u8()? {
                        0 => None,
                        1 => Some(read_layer_scheme(&mut r)?),
                        _ => return None,
                    };
                    Some((key, argmin))
                })();
                match decoded {
                    Some((key, argmin))
                        if r.is_empty() && want_fp.is_none_or(|fp| key.arch_fp == fp) =>
                    {
                        cache.import_intra(key, argmin);
                        stats.intra_entries += 1;
                    }
                    _ => stats.skipped += 1,
                }
            }
            _ => stats.skipped += 1,
        }
    }
    cache.note_load_skipped(stats.skipped);
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::cost::EvalCache;
    use crate::directives::{Grp, LoopOrder, Qty};
    use crate::workloads::Layer;

    fn scheme(arch: &ArchConfig, k: u64) -> LayerScheme {
        let l = Layer::conv("c", 16, k, 14, 3, 1);
        let part = PartitionScheme::single();
        let unit = UnitMap::build(arch, part.node_shape(&l, 4));
        LayerScheme {
            part,
            unit,
            regf: LevelBlock { qty: Qty::new(1, 2, 2), order: LoopOrder([Grp::B, Grp::K, Grp::C]) },
            gbuf: LevelBlock { qty: Qty::new(1, 8, 8), order: LoopOrder([Grp::B, Grp::C, Grp::K]) },
        }
    }

    fn tmp_path(name: &str) -> std::path::PathBuf {
        static SEQ: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);
        let n = SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        std::env::temp_dir().join(format!(
            "kapla-persist-unit-{}-{}-{}.snap",
            std::process::id(),
            name,
            n
        ))
    }

    #[test]
    fn codec_round_trips_scheme_and_eval_bit_exact() {
        let arch = presets::multi_node_eyeriss();
        let s = scheme(&arch, 32);
        let ev = crate::sim::evaluate_layer(&arch, &s, false);
        let key = SchemeKey::of(&arch, &s, false);
        let rec = encode_eval_record(&key, &ev);
        let mut r = ByteReader::new(&rec);
        let (k2, e2) = read_scheme_key(&mut r).zip(read_layer_eval(&mut r)).expect("decodes");
        assert!(r.is_empty(), "trailing bytes after decode");
        assert_eq!(k2, key);
        assert_eq!(format!("{e2:?}"), format!("{ev:?}"));
        // The scheme itself (trait object included) round-trips too.
        let mut w = ByteWriter::default();
        write_layer_scheme(&mut w, &s);
        let s2 = read_layer_scheme(&mut ByteReader::new(&w.buf)).expect("decodes");
        assert_eq!(format!("{s2:?}"), format!("{s:?}"));
        assert_eq!(s2.unit.mapping.name(), s.unit.mapping.name());
    }

    #[test]
    fn save_load_round_trip_restores_both_memos() {
        let arch = presets::multi_node_eyeriss();
        let sc = SessionCache::unbounded();
        let schemes: Vec<LayerScheme> = [16u64, 32, 64].iter().map(|&k| scheme(&arch, k)).collect();
        for s in &schemes {
            sc.evaluate_layer(&arch, s, false);
        }
        EvalCache::record_intra_argmin(&sc, IntraKey::of(&arch, 0xC0DE, 0xF00D), Some(schemes[0]));
        EvalCache::record_intra_argmin(&sc, IntraKey::of(&arch, 0xBEEF, 0xF00D), None);

        let path = tmp_path("roundtrip");
        let saved = save_session(&sc, &path).expect("save");
        assert_eq!((saved.eval_entries, saved.intra_entries, saved.skipped), (3, 2, 0));

        let warm = SessionCache::unbounded();
        let loaded = load_session(&warm, &path, Some(&arch)).expect("load");
        assert_eq!(loaded, saved);
        assert_eq!(warm.len(), 3);
        assert_eq!(warm.intra_len(), 2);
        assert_eq!(warm.load_skipped(), 0);
        // Every reloaded entry hits and matches the simulator bit-exactly.
        for s in &schemes {
            let got = warm.evaluate_layer(&arch, s, false);
            let want = crate::sim::evaluate_layer(&arch, s, false);
            assert_eq!(format!("{got:?}"), format!("{want:?}"));
        }
        assert_eq!(warm.hits(), 3, "reloaded evaluations must hit");
        assert!(matches!(
            EvalCache::intra_argmin(&warm, &IntraKey::of(&arch, 0xBEEF, 0xF00D)),
            Some(None)
        ));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn missing_file_is_a_clean_cold_start() {
        let sc = SessionCache::unbounded();
        let st = load_session(&sc, &tmp_path("missing"), None).expect("ok");
        assert_eq!(st, SnapshotStats::default());
        assert_eq!(sc.load_skipped(), 0);
    }

    #[test]
    fn arch_filter_skips_foreign_entries() {
        let a1 = presets::eyeriss_like((4, 4), (8, 8), 64, 32 * 1024);
        let a2 = presets::eyeriss_like((4, 4), (8, 8), 64, 64 * 1024);
        let sc = SessionCache::unbounded();
        sc.evaluate_layer(&a1, &scheme(&a1, 32), false);
        sc.evaluate_layer(&a2, &scheme(&a2, 32), false);
        let path = tmp_path("archfilter");
        save_session(&sc, &path).expect("save");
        let warm = SessionCache::unbounded();
        let st = load_session(&warm, &path, Some(&a1)).expect("load");
        assert_eq!((st.eval_entries, st.skipped), (1, 1));
        assert_eq!(warm.len(), 1);
        assert_eq!(warm.load_skipped(), 1);
        let _ = std::fs::remove_file(&path);
    }
}
