//! Chaos-testing cost-model wrapper: seeded probabilistic panics and
//! injected latency over any [`CostModel`].
//!
//! The service stack promises that every *admitted* request gets exactly
//! one answer — complete, degraded, or a structured error — no matter what
//! the cost model does underneath. [`FaultInjector`] is how the chaos
//! battery (`tests/service_chaos.rs`) exercises that promise: it wraps the
//! real model, forwards every call, and on a deterministic per-call
//! schedule panics out of `evaluate` or sleeps inside it. Panics unwind
//! through the solver into the transport worker's `catch_unwind` and come
//! back as `{"ok":false,"error":"internal error: ..."}`; injected latency
//! pushes solves past their `deadline_ms=` budgets and forces the anytime
//! degraded path.
//!
//! Determinism: faults fire on a pure function of `(seed, call counter)`
//! — a [`SplitMix64`]-mixed hash, no clocks, no global RNG — so a failing
//! chaos run replays exactly from its seed.
//!
//! [`CostModel::staged`] deliberately forwards as `None`: the staged
//! evaluator scores candidates *outside* the model (that is the point of
//! staging), which would let the hot path bypass the injection site. With
//! staging off every candidate scores through [`FaultInjector::evaluate`],
//! and since the staged path is pinned bit-identical to `evaluate`
//! (`tests/staged_eval_equivalence.rs`), disabling it changes wall-clock
//! only — a fault-free injector returns exactly the wrapped model's
//! results.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::arch::ArchConfig;
use crate::directives::LayerScheme;
use crate::interlayer::Segment;
use crate::workloads::{Layer, Network};

use super::model::CostModel;
use super::{CacheStats, CostEstimate, IntraKey, LayerCtx};

/// A [`CostModel`] wrapper that injects deterministic, seeded faults into
/// the detailed tier. Test-only by intent: the service refuses the
/// `chaos=` request knob unless `KAPLA_CHAOS=1` is set in the process
/// environment.
pub struct FaultInjector<'a> {
    inner: &'a dyn CostModel,
    seed: u64,
    /// Per-`evaluate` panic probability in permille (0..=1000).
    panic_permille: u64,
    /// Sleep injected into every `evaluate` call, in microseconds.
    latency_us: u64,
    calls: AtomicU64,
    injected: AtomicU64,
}

impl<'a> FaultInjector<'a> {
    pub fn new(
        inner: &'a dyn CostModel,
        seed: u64,
        panic_permille: u64,
        latency_us: u64,
    ) -> FaultInjector<'a> {
        FaultInjector {
            inner,
            seed,
            panic_permille: panic_permille.min(1000),
            latency_us,
            calls: AtomicU64::new(0),
            injected: AtomicU64::new(0),
        }
    }

    /// Total `evaluate` calls observed.
    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    /// Panics actually fired (counted just before unwinding).
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    /// Whether call number `n` (0-based) draws a panic. Pure in
    /// `(seed, n)`; exposed so tests can predict the fault schedule.
    pub fn fires_at(&self, n: u64) -> bool {
        if self.panic_permille == 0 {
            return false;
        }
        // One SplitMix64 scramble of seed^n: full-avalanche, so permille
        // thresholds hold even for sequential n.
        let mut rng = crate::util::SplitMix64::new(self.seed ^ n.wrapping_mul(0x9E3779B97F4A7C15));
        rng.below(1000) < self.panic_permille
    }
}

impl CostModel for FaultInjector<'_> {
    fn estimate_layer(&self, arch: &ArchConfig, layer: &Layer, ctx: &LayerCtx) -> CostEstimate {
        self.inner.estimate_layer(arch, layer, ctx)
    }

    fn estimate_segment(
        &self,
        arch: &ArchConfig,
        net: &Network,
        batch: u64,
        seg: &Segment,
    ) -> CostEstimate {
        self.inner.estimate_segment(arch, net, batch, seg)
    }

    fn evaluate(&self, arch: &ArchConfig, s: &LayerScheme, ifm_on_chip: bool) -> CostEstimate {
        let n = self.calls.fetch_add(1, Ordering::Relaxed);
        if self.latency_us > 0 {
            std::thread::sleep(Duration::from_micros(self.latency_us));
        }
        if self.fires_at(n) {
            self.injected.fetch_add(1, Ordering::Relaxed);
            panic!("chaos: injected cost-model fault #{n}");
        }
        self.inner.evaluate(arch, s, ifm_on_chip)
    }

    // No staged shortcut: force every candidate through `evaluate` so the
    // injection site sees the whole scoring stream (see module docs).

    fn intra_argmin(&self, key: &IntraKey) -> Option<Option<LayerScheme>> {
        self.inner.intra_argmin(key)
    }

    fn record_intra_argmin(&self, key: IntraKey, argmin: Option<LayerScheme>) {
        self.inner.record_intra_argmin(key, argmin)
    }

    fn stats(&self) -> CacheStats {
        self.inner.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::cost::TieredCost;
    use crate::coordinator::{run_job, Job, SolverKind};
    use crate::interlayer::dp::DpConfig;
    use crate::solvers::{Objective, SolveCtx, SolverKind as SK};
    use crate::workloads::nets;

    #[test]
    fn fault_free_injector_is_transparent() {
        // panic_permille=0, latency=0: schedules and costs are identical
        // to the unwrapped engine (staging off is a perf knob only).
        let arch = presets::bench_multi_node();
        let net = nets::mlp();
        let dp = DpConfig { max_rounds: 4, ..DpConfig::default() };
        let job = Job {
            net: net.clone(),
            batch: 4,
            objective: Objective::Energy,
            solver: SolverKind::Kapla,
            dp,
            deadline_ms: None,
        };
        let plain = run_job(&arch, &job).unwrap();
        let tiered = TieredCost::fresh();
        let inj = FaultInjector::new(&tiered, 7, 0, 0);
        let wrapped = SolveCtx::new(&arch)
            .objective(Objective::Energy)
            .dp(dp)
            .model(&inj)
            .run(&net, 4, SK::Kapla)
            .unwrap();
        assert_eq!(format!("{:?}", wrapped.schedule), format!("{:?}", plain.schedule));
        assert_eq!(wrapped.eval.energy.total(), plain.eval.energy.total());
        assert!(inj.calls() > 0, "evaluate must be consulted with staging off");
        assert_eq!(inj.injected(), 0);
    }

    #[test]
    fn fault_schedule_is_deterministic_and_roughly_calibrated() {
        let tiered = TieredCost::fresh();
        let a = FaultInjector::new(&tiered, 42, 100, 0);
        let b = FaultInjector::new(&tiered, 42, 100, 0);
        let hits: u64 = (0..10_000).filter(|&n| a.fires_at(n)).count() as u64;
        for n in 0..10_000 {
            assert_eq!(a.fires_at(n), b.fires_at(n), "schedule must be pure in (seed, n)");
        }
        // 100 permille over 10k draws: expect ~1000, allow wide slack.
        assert!((500..=1500).contains(&hits), "permille calibration off: {hits}/10000");
        // permille=0 never fires; different seeds differ somewhere.
        let z = FaultInjector::new(&tiered, 42, 0, 0);
        assert!((0..1000).all(|n| !z.fires_at(n)));
        let c = FaultInjector::new(&tiered, 43, 100, 0);
        assert!((0..1000).any(|n| a.fires_at(n) != c.fires_at(n)));
    }

    #[test]
    fn injected_panic_unwinds_with_chaos_message() {
        let arch = presets::bench_multi_node();
        let net = nets::mlp();
        let tiered = TieredCost::fresh();
        // permille=1000: the very first evaluate panics.
        let inj = FaultInjector::new(&tiered, 1, 1000, 0);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            SolveCtx::new(&arch)
                .dp(DpConfig { max_rounds: 4, ..DpConfig::default() })
                .model(&inj)
                .run(&net, 4, SK::Kapla)
        }));
        let err = res.expect_err("all-faults injector must panic the solve");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(msg.contains("chaos: injected cost-model fault"), "got: {msg}");
        assert!(inj.injected() >= 1);
    }
}
