//! KAPLA's fast cost model (paper §IV-A "Cost model", §IV-B "Fast cost
//! estimation").
//!
//! Energy and latency are simple functions of resource utilization and data
//! access counts. During inter-layer exploration the model "approximates to
//! the optimistic cases if there is insufficient information, so the
//! estimated cost [is] a (relatively tight) lower bound" — good enough to
//! *prioritize* candidates, with DP keeping top-k chains to absorb errors.
//!
//! The lower-bound discipline extends below the estimate tier into the
//! scan itself: `CostModel::bound_partition` (floor over every blocking
//! of a partition) and `CostModel::bound_prefix` (floor over every
//! completion of a `(part, gbuf)` prefix) are the bottom two levels of
//! the solvers' partition → prefix → span bound hierarchy, and the same
//! admissibility invariant — bound never exceeds the detailed evaluation
//! of anything it stands for — makes their pruning exact.
//!
//! The same per-candidate formula is exported as a feature vector
//! (`features()`), mirrored bit-for-bit by the AOT-compiled JAX/Pallas
//! batched cost kernel (`python/compile/kernels/cost_batch.py`) that the
//! runtime can invoke to score large candidate batches in one call.

pub mod cache;
pub mod fault;
pub mod model;
pub mod persist;
pub mod session;
pub mod store;

pub use cache::{CacheStats, CostCache, EvalCache};
pub use fault::FaultInjector;
pub use model::{CostModel, TieredCost};
pub use persist::{load_session, save_session, SnapshotStats};
pub use session::{CacheBudget, EvictPolicy, IntraKey, SessionCache};
pub use store::{net_fingerprint, ScheduleStore, StoreKey};

use crate::arch::{energy as earch, ArchConfig};
use crate::interlayer::Segment;
use crate::workloads::{Layer, Network};

/// Number of features per candidate in the batched-kernel interchange.
pub const NUM_FEATURES: usize = 12;

/// A fast (optimistic) cost estimate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostEstimate {
    pub energy_pj: f64,
    pub latency_cycles: f64,
}

impl CostEstimate {
    /// Scalar objective: energy-delay-ish weighting used for ranking. The
    /// paper co-optimizes energy and performance (Fig. 7/8 trends match);
    /// we rank by energy with a latency tie-breaker.
    pub fn score(&self) -> f64 {
        self.energy_pj * (1.0 + 1e-12 * self.latency_cycles)
    }
}

/// Per-layer lower-bound terms within a segment context.
#[derive(Debug, Clone, Copy)]
pub struct LayerCtx {
    /// Nodes allocated to the layer.
    pub nodes: u64,
    /// Per-round batch.
    pub round_batch: u64,
    /// Rounds in the segment.
    pub rounds: u64,
    /// Input forwarded on-chip (producer in segment).
    pub ifm_on_chip: bool,
    /// Output consumed on-chip (consumer in segment).
    pub ofm_on_chip: bool,
    /// Average DRAM-distribution hops for the region.
    pub dram_hops: f64,
}

/// The feature vector for one (layer, ctx) candidate — the interchange
/// format of the AOT batched cost kernel. Mirrored in python
/// `compile/kernels/cost_batch.py::FEATURES`.
pub fn features(arch: &ArchConfig, layer: &Layer, ctx: &LayerCtx) -> [f64; NUM_FEATURES] {
    let rb = ctx.round_batch;
    // Role volumes fold the back-weight pass's streamed dY into the input
    // slot and zero the (non-resident) weight slot, so the shared formula
    // stays correct for every layer kind.
    let (inp, out, wgt) = layer.role_volumes(rb);
    [
        layer.macs(rb) as f64,
        inp as f64,
        out as f64,
        wgt as f64,
        ctx.nodes as f64,
        ctx.rounds as f64,
        ctx.ifm_on_chip as u64 as f64,
        ctx.ofm_on_chip as u64 as f64,
        ctx.dram_hops,
        arch.pes_per_node() as f64,
        arch.gbuf.pj_per_word,
        arch.regf.pj_per_word,
    ]
}

/// Evaluate the lower-bound cost from a feature vector. This is the single
/// source of truth for the formula: the Rust hot path, the Pallas kernel
/// and its jnp reference all implement exactly this arithmetic.
pub fn cost_from_features(arch: &ArchConfig, f: &[f64; NUM_FEATURES]) -> CostEstimate {
    let [macs, ifm, ofm, wgt, nodes, rounds, ifm_on, ofm_on, hops, pes, gbuf_pj, regf_pj] = *f;

    // --- energy lower bound (per round) --------------------------------
    let alu = macs * arch.mac_pj;
    let regf = 4.0 * macs * regf_pj;
    // Compulsory single pass through GBUF both ways.
    let gbuf = 2.0 * (ifm + ofm + wgt / rounds.max(1.0)) * gbuf_pj;
    // DRAM: compulsory misses only; weights amortized over rounds
    // (resident across rounds).
    let dram_words = ifm * (1.0 - ifm_on) + ofm * (1.0 - ofm_on) + wgt / rounds.max(1.0);
    let dram = dram_words * arch.dram.pj_per_word;
    // NoC: DRAM distribution plus on-chip forwarding at one hop.
    let noc_hops = dram_words * hops + (ifm * ifm_on + ofm * ofm_on) * 1.0;
    let noc = noc_hops * arch.noc_pj_per_word(1.0);
    let bus = (ifm + ofm + wgt / rounds.max(1.0)) * earch::pe_bus_pj_per_word();
    let energy_round = alu + regf + gbuf + dram + noc + bus;

    // --- latency lower bound (per round) --------------------------------
    // Optimistically assume all PEs across all allocated nodes are busy
    // (paper §IV-B: "assume that the layer could use all the PEs").
    let compute = macs / (nodes.max(1.0) * pes);
    let mem = dram_words / arch.dram_words_per_cycle();
    let lat_round = compute.max(mem);

    CostEstimate { energy_pj: energy_round * rounds, latency_cycles: lat_round }
}

/// Lower-bound estimate for one layer in a segment context.
pub fn layer_lower_bound(arch: &ArchConfig, layer: &Layer, ctx: &LayerCtx) -> CostEstimate {
    let f = features(arch, layer, ctx);
    cost_from_features(arch, &f)
}

/// Structural feature count for intra-layer *scheme* candidates — the
/// input dimension of the learned cost surrogate used by the ML baseline
/// (mirrored by `python/compile/model.py::SCHEME_FEATURES`).
pub const SCHEME_FEATURES: usize = 16;

/// Cheap structural featurization of an intra-layer scheme (AutoTVM-style
/// "knob" features: no access counts, so the surrogate has something
/// non-trivial to learn). Log-scaled where dynamic range is large.
pub fn scheme_features(s: &crate::directives::LayerScheme) -> [f64; SCHEME_FEATURES] {
    fn lg(x: u64) -> f64 {
        ((x.max(1)) as f64).ln()
    }
    let p = &s.part;
    let order_id = |o: crate::directives::LoopOrder| -> f64 {
        crate::directives::LoopOrder::all().iter().position(|x| *x == o).unwrap() as f64
    };
    [
        lg(p.pn),
        lg(p.pk),
        lg(p.pc),
        lg(p.px * p.py),
        p.share_ifm as u64 as f64,
        p.share_wgt as u64 as f64,
        lg(s.gbuf.qty.b),
        lg(s.gbuf.qty.c),
        lg(s.gbuf.qty.k),
        lg(s.regf.qty.b),
        lg(s.regf.qty.c),
        lg(s.regf.qty.k),
        order_id(s.gbuf.order),
        order_id(s.regf.order),
        s.unit.utilization,
        lg(s.unit.node_macs()),
    ]
}

/// Lower-bound estimate for a whole segment scheme (paper §IV-B): per-layer
/// optimistic costs, fine-grained pipelining credited when granularities
/// match, fill/drain rounds included.
pub fn segment_lower_bound(
    arch: &ArchConfig,
    net: &Network,
    batch: u64,
    seg: &Segment,
) -> CostEstimate {
    segment_lower_bound_with(net, batch, seg, &mut |li, ctx| {
        layer_lower_bound(arch, &net.layers[li], ctx)
    })
}

/// The per-layer assembly behind [`segment_lower_bound`], parameterized
/// over the layer-estimate source. `interlayer::prune_and_rank` stages its
/// candidate scoring through this: the distinct `(layer, ctx)` estimates —
/// which recur across the whole candidate set — are computed once, and the
/// per-candidate assembly here is pure summation, so the staged totals are
/// bit-identical to the one-shot path (both run this exact accumulation).
pub fn segment_lower_bound_with(
    net: &Network,
    batch: u64,
    seg: &Segment,
    layer_est: &mut dyn FnMut(usize, &LayerCtx) -> CostEstimate,
) -> CostEstimate {
    let rb = seg.round_batch(batch);
    let mut energy = 0.0;
    let mut round_lat: f64 = 0.0;
    for (pos, &li) in seg.layers.iter().enumerate() {
        let nodes = seg.regions[pos].0 * seg.regions[pos].1;
        let ctx = LayerCtx {
            nodes,
            round_batch: rb,
            rounds: seg.rounds,
            ifm_on_chip: seg.ifm_on_chip(net, li),
            ofm_on_chip: seg.ofm_on_chip(net, li),
            dram_hops: ((seg.regions[pos].0 + seg.regions[pos].1) as f64 / 4.0).max(1.0),
        };
        let est = layer_est(li, &ctx);
        energy += est.energy_pj;
        round_lat = round_lat.max(est.latency_cycles);
    }
    let latency = if seg.spatial {
        round_lat * (seg.rounds as f64 + seg.len() as f64 - 1.0)
    } else {
        // time-multiplexed single layer(s)
        seg.layers
            .iter()
            .enumerate()
            .map(|(pos, &li)| {
                let nodes = seg.regions[pos].0 * seg.regions[pos].1;
                let ctx = LayerCtx {
                    nodes,
                    round_batch: rb,
                    rounds: seg.rounds,
                    ifm_on_chip: false,
                    ofm_on_chip: false,
                    dram_hops: ((seg.regions[pos].0 + seg.regions[pos].1) as f64 / 4.0).max(1.0),
                };
                layer_est(li, &ctx).latency_cycles
            })
            .sum::<f64>()
            * seg.rounds as f64
    };
    CostEstimate { energy_pj: energy, latency_cycles: latency }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::interlayer::Segment;
    use crate::workloads::nets;

    fn ctx(nodes: u64, rb: u64) -> LayerCtx {
        LayerCtx {
            nodes,
            round_batch: rb,
            rounds: 1,
            ifm_on_chip: false,
            ofm_on_chip: false,
            dram_hops: 2.0,
        }
    }

    #[test]
    fn estimate_positive_and_scales_with_batch() {
        let arch = presets::multi_node_eyeriss();
        let net = nets::alexnet();
        let l = &net.layers[0];
        let e1 = layer_lower_bound(&arch, l, &ctx(256, 1));
        let e4 = layer_lower_bound(&arch, l, &ctx(256, 4));
        assert!(e1.energy_pj > 0.0);
        assert!(e4.energy_pj > 3.0 * e1.energy_pj && e4.energy_pj < 5.0 * e1.energy_pj);
    }

    #[test]
    fn more_nodes_cut_latency_not_energy_floor() {
        let arch = presets::multi_node_eyeriss();
        let net = nets::alexnet();
        let l = &net.layers[2];
        let few = layer_lower_bound(&arch, l, &ctx(16, 4));
        let many = layer_lower_bound(&arch, l, &ctx(256, 4));
        assert!(many.latency_cycles < few.latency_cycles);
    }

    #[test]
    fn on_chip_forwarding_cheaper() {
        let arch = presets::multi_node_eyeriss();
        let net = nets::alexnet();
        let l = &net.layers[2];
        let mut c = ctx(64, 4);
        let off = layer_lower_bound(&arch, l, &c);
        c.ifm_on_chip = true;
        let on = layer_lower_bound(&arch, l, &c);
        assert!(on.energy_pj < off.energy_pj);
    }

    #[test]
    fn estimate_is_lower_bound_of_simulator() {
        // The fast model must never exceed the detailed simulator for the
        // same layer placement (it drops all refetch traffic).
        use crate::directives::{Grp, LevelBlock, LoopOrder, Qty};
        use crate::mapping::UnitMap;
        use crate::partition::PartitionScheme;

        let arch = presets::multi_node_eyeriss();
        let net = nets::alexnet();
        for li in [0usize, 2, 4] {
            let l = &net.layers[li];
            let part = PartitionScheme { region: (4, 4), pk: 4, pn: 4, ..PartitionScheme::single() };
            if !part.is_valid(l, 16) {
                continue;
            }
            let unit = UnitMap::build(&arch, part.node_shape(l, 16));
            let s = crate::directives::LayerScheme {
                part,
                unit,
                regf: LevelBlock {
                    qty: Qty::new(1, 1, 2),
                    order: LoopOrder([Grp::B, Grp::K, Grp::C]),
                },
                gbuf: LevelBlock {
                    qty: unit.align_block(Qty::new(1, 8, 8)),
                    order: LoopOrder([Grp::B, Grp::C, Grp::K]),
                },
            };
            let sim = crate::sim::evaluate_layer(&arch, &s, false);
            let est = layer_lower_bound(
                &arch,
                l,
                &LayerCtx {
                    nodes: 16,
                    round_batch: 16,
                    rounds: 1,
                    ifm_on_chip: false,
                    ofm_on_chip: false,
                    dram_hops: part.dram_hops(),
                },
            );
            assert!(
                est.energy_pj <= sim.energy.total() * 1.001,
                "layer {li}: est {} > sim {}",
                est.energy_pj,
                sim.energy.total()
            );
        }
    }

    #[test]
    fn segment_estimate_accumulates() {
        let arch = presets::multi_node_eyeriss();
        let net = nets::alexnet();
        let seg1 = Segment::single(0, &arch);
        let e1 = segment_lower_bound(&arch, &net, 64, &seg1);
        assert!(e1.energy_pj > 0.0 && e1.latency_cycles > 0.0);

        let seg2 = Segment {
            layers: vec![2, 3],
            regions: vec![(8, 16), (8, 16)],
            spatial: true,
            rounds: 8,
        };
        let e2 = segment_lower_bound(&arch, &net, 64, &seg2);
        assert!(e2.energy_pj > 0.0);
    }

    #[test]
    fn features_roundtrip_formula() {
        let arch = presets::multi_node_eyeriss();
        let net = nets::alexnet();
        let l = &net.layers[0];
        let c = ctx(64, 4);
        let f = features(&arch, l, &c);
        let via_features = cost_from_features(&arch, &f);
        let direct = layer_lower_bound(&arch, l, &c);
        assert_eq!(via_features, direct);
        assert_eq!(f.len(), NUM_FEATURES);
    }

    #[test]
    fn score_orders_by_energy() {
        let a = CostEstimate { energy_pj: 1.0, latency_cycles: 1e9 };
        let b = CostEstimate { energy_pj: 2.0, latency_cycles: 1.0 };
        assert!(a.score() < b.score());
    }
}
