//! Cross-job scheduling sessions: a *bounded* memo for detailed-model
//! evaluations, shared across `coordinator::run_jobs` sweeps and long-lived
//! `coordinator::service` connections.
//!
//! KAPLA's headline claim is search speed, and the traffic the coordinator
//! serves (NAS sweeps, repeated service requests) re-schedules
//! near-identical layers job after job. The per-run `CostCache` already
//! memoizes within one solve; `SessionCache` extends the same exact-key
//! memo (`SchemeKey`, arch fingerprint included, so sharing can never alias
//! across hardware configs) *across* jobs, under a configurable byte/entry
//! budget so a long-lived service cannot grow without bound.
//!
//! Eviction is sharded clock (second chance): each of the 16 shards keeps
//! its entries in a ring with a reference bit, and a full cache replaces
//! the first unreferenced entry past the shard's hand. The total entry
//! count is tracked globally, so the budget holds *exactly* — after any
//! operation sequence `len() <= budget` (property-tested) — while inserts
//! only ever lock their own shard. Because `sim::evaluate_layer` is pure,
//! eviction changes when the simulator runs, never what callers see:
//! schedules are byte-identical for any budget (golden-schedule tests).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::arch::ArchConfig;
use crate::directives::LayerScheme;
use crate::sim::LayerEval;

use super::cache::{arch_fingerprint, shard_of, CacheStats, EvalCache, SchemeKey, SHARDS};

/// Identity of one intra-layer *argmin*: which hardware (`arch_fp`, the
/// same fingerprint the evaluation memo keys on), which layer in which
/// solve context (`ctx_fp` — `solvers::ctx_fingerprint`, folding every
/// layer dimension plus region/round-batch/forwarding/objective), and
/// which solver policy and search space (`solver_fp` —
/// `IntraSolver::fingerprint`, folding the family name and every
/// stochastic knob). Every intra-layer solver is a pure function of
/// exactly these three, so a session may replay a recorded argmin — for
/// repeated `(layer, ctx)` solves across DP chains, KAPLA descent probes
/// and warm cross-job sessions — and skip the scan entirely without any
/// schedule changing.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct IntraKey {
    pub(crate) arch_fp: u64,
    pub(crate) ctx_fp: u64,
    pub(crate) solver_fp: u64,
}

impl IntraKey {
    pub fn of(arch: &ArchConfig, ctx_fp: u64, solver_fp: u64) -> IntraKey {
        IntraKey { arch_fp: arch_fingerprint(arch), ctx_fp, solver_fp }
    }
}

/// Capacity budget of a [`SessionCache`], in resident entries. Byte budgets
/// are converted via [`entry_bytes`] at construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheBudget {
    /// Maximum resident entries; `usize::MAX` means unbounded.
    pub max_entries: usize,
}

/// Estimated resident bytes per cached evaluation: key + value stored in
/// the clock ring, plus the key duplicated in the index map and amortized
/// map/ring overhead (the factor of 2).
pub fn entry_bytes() -> usize {
    (std::mem::size_of::<SchemeKey>() + std::mem::size_of::<LayerEval>()) * 2
}

/// Estimated resident bytes per recorded intra-layer argmin: the key in
/// the map and again in the FIFO ring, the recorded scheme, and amortized
/// map overhead (the factor of 2).
pub fn intra_entry_bytes() -> usize {
    (std::mem::size_of::<IntraKey>() * 2 + std::mem::size_of::<Option<LayerScheme>>()) * 2
}

impl CacheBudget {
    pub const UNBOUNDED: CacheBudget = CacheBudget { max_entries: usize::MAX };

    /// Budget of at most `n` resident evaluations.
    pub fn entries(n: usize) -> CacheBudget {
        CacheBudget { max_entries: n }
    }

    /// Budget of at most `bytes` estimated resident bytes (at least one
    /// entry, so a tiny byte budget degrades to a 1-entry cache rather
    /// than disabling caching outright).
    pub fn bytes(bytes: usize) -> CacheBudget {
        CacheBudget { max_entries: (bytes / entry_bytes()).max(1) }
    }

    pub fn is_unbounded(&self) -> bool {
        self.max_entries == usize::MAX
    }

    /// Parse a CLI/service budget spec: `"unbounded"`/`"none"`, a plain
    /// entry count (`"50000"`), or a byte size with a `kb`/`mb`/`gb`
    /// suffix (`"64mb"`; case-insensitive, optional `b`).
    pub fn parse(s: &str) -> Result<CacheBudget, String> {
        let t = s.trim().to_ascii_lowercase();
        if t.is_empty() {
            return Err("empty cache budget".to_string());
        }
        if t == "unbounded" || t == "none" {
            return Ok(CacheBudget::UNBOUNDED);
        }
        let (digits, suffix) = match t.find(|c: char| !c.is_ascii_digit()) {
            Some(pos) => t.split_at(pos),
            None => (t.as_str(), ""),
        };
        let n: usize = digits
            .parse()
            .map_err(|_| format!("bad cache budget {s:?}: expected a number"))?;
        match suffix {
            "" => Ok(CacheBudget::entries(n)),
            "k" | "kb" => Ok(CacheBudget::bytes(n.saturating_mul(1024))),
            "m" | "mb" => Ok(CacheBudget::bytes(n.saturating_mul(1024 * 1024))),
            "g" | "gb" => Ok(CacheBudget::bytes(n.saturating_mul(1024 * 1024 * 1024))),
            _ => Err(format!("bad cache budget {s:?}: unknown suffix {suffix:?}")),
        }
    }
}

/// Eviction policy of a [`SessionCache`].
///
/// `Clock` is the original one-bit second-chance sweep. `SegmentedLru`
/// approximates a protected/probationary segmented LRU with a second bit:
/// a hit on an already-referenced entry promotes it to *protected*, and
/// the victim sweep demotes (protected → referenced-clear → evict) instead
/// of evicting outright — so an entry must go un-touched for two full
/// sweeps before it leaves, holding multi-hit NAS layers longer under
/// churn. Either policy only changes *when* the simulator re-runs, never
/// what callers see (the evaluator is pure), so schedules stay
/// byte-identical across policies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictPolicy {
    Clock,
    SegmentedLru,
}

impl EvictPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            EvictPolicy::Clock => "clock",
            EvictPolicy::SegmentedLru => "slru",
        }
    }

    pub fn parse(s: &str) -> Result<EvictPolicy, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "clock" => Ok(EvictPolicy::Clock),
            "slru" | "segmented-lru" => Ok(EvictPolicy::SegmentedLru),
            other => Err(format!("bad evict policy {other:?}: expected clock|slru")),
        }
    }
}

/// One resident evaluation in a shard's clock ring.
struct ClockEntry {
    key: SchemeKey,
    eval: LayerEval,
    /// Second-chance bit: set on hit, cleared as the hand sweeps past.
    referenced: bool,
    /// Segmented-LRU protection bit: set when a *referenced* entry is hit
    /// again (promotion to the protected segment), cleared by the sweep
    /// before the entry becomes evictable. Never set under
    /// [`EvictPolicy::Clock`], so that policy's behavior is unchanged.
    protected: bool,
}

#[derive(Default)]
struct Shard {
    /// Key -> slot in `ring`.
    index: HashMap<SchemeKey, usize>,
    ring: Vec<ClockEntry>,
    /// Clock hand: next slot the eviction sweep examines.
    hand: usize,
}

impl Shard {
    /// Advance the hand to the first unreferenced, unprotected entry
    /// (clearing reference bits, then protection bits, on the way) and
    /// return its slot. Terminates: one full sweep clears every reference
    /// bit and a second clears every protection bit. Must only be called
    /// on a non-empty ring.
    fn clock_victim(&mut self) -> usize {
        loop {
            if self.hand >= self.ring.len() {
                self.hand = 0;
            }
            let e = &mut self.ring[self.hand];
            if e.referenced {
                e.referenced = false;
                self.hand += 1;
            } else if e.protected {
                e.protected = false;
                self.hand += 1;
            } else {
                let slot = self.hand;
                self.hand += 1;
                return slot;
            }
        }
    }
}

/// Budgeted, sharded, clock-evicting memo for `sim::evaluate_layer` —
/// the cross-job scheduling session cache. See the module docs for the
/// design; the unbounded per-run [`super::CostCache`] remains the default
/// for solitary jobs.
pub struct SessionCache {
    shards: Vec<Mutex<Shard>>,
    /// Entry budget (`usize::MAX` = unbounded).
    cap: usize,
    /// Total resident entries across shards (may transiently read high
    /// during a contended insert, never low — so the budget is a hard
    /// ceiling).
    count: AtomicUsize,
    lookups: AtomicU64,
    hits: AtomicU64,
    evictions: AtomicU64,
    /// Cross-job intra-layer argmin memo ([`IntraKey`] -> recorded scan
    /// result), FIFO-bounded by `intra_cap`. Eviction only changes when a
    /// scan re-runs, never its result.
    intra: Mutex<IntraMemo>,
    /// Entry cap of the argmin memo: a dedicated ~1/8 slice of the
    /// session budget, re-denominated from evaluation-entry bytes into
    /// (larger) argmin-entry bytes, so a byte-budgeted session's total
    /// resident footprint overshoots the requested ceiling by at most
    /// ~12.5% rather than doubling it.
    intra_cap: usize,
    intra_lookups: AtomicU64,
    intra_hits: AtomicU64,
    /// Eviction policy (one-bit clock vs. two-bit segmented LRU).
    policy: EvictPolicy,
    /// Snapshot/store entries rejected at load time (`cost::persist`,
    /// `cost::store`): bad checksum, unknown version/tag, mismatched
    /// fingerprint. Surfaced through [`CacheStats::load_skipped`].
    load_skipped: AtomicU64,
}

#[derive(Default)]
struct IntraMemo {
    map: HashMap<IntraKey, Option<LayerScheme>>,
    fifo: VecDeque<IntraKey>,
}

impl SessionCache {
    pub fn new(budget: CacheBudget) -> SessionCache {
        SessionCache::with_policy(budget, EvictPolicy::Clock)
    }

    /// A session under an explicit eviction policy. `new` keeps the clock
    /// default; the segmented-LRU variant exists for the perf_hotpath
    /// hit-rate comparison and stays opt-in unless that row shows a win.
    pub fn with_policy(budget: CacheBudget, policy: EvictPolicy) -> SessionCache {
        let intra_cap = if budget.is_unbounded() {
            usize::MAX
        } else if budget.max_entries == 0 {
            0
        } else {
            // One argmin entry replaces a whole scan but costs more bytes
            // than one evaluation entry; charge it at its true size
            // against a 1/8 slice of the budget (at least one entry, so a
            // tiny budget still short-circuits its hottest scan).
            (budget.max_entries * entry_bytes() / 8 / intra_entry_bytes()).max(1)
        };
        SessionCache {
            shards: (0..SHARDS).map(|_| Mutex::new(Shard::default())).collect(),
            cap: budget.max_entries,
            count: AtomicUsize::new(0),
            lookups: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            intra: Mutex::new(IntraMemo::default()),
            intra_cap,
            intra_lookups: AtomicU64::new(0),
            intra_hits: AtomicU64::new(0),
            policy,
            load_skipped: AtomicU64::new(0),
        }
    }

    pub fn unbounded() -> SessionCache {
        SessionCache::new(CacheBudget::UNBOUNDED)
    }

    /// The configured entry budget.
    pub fn budget_entries(&self) -> usize {
        self.cap
    }

    /// Distinct evaluations currently resident.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().ring.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn lookups(&self) -> u64 {
        self.lookups.load(Ordering::Relaxed)
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Recorded intra-layer argmins currently resident.
    pub fn intra_len(&self) -> usize {
        self.intra.lock().unwrap().map.len()
    }

    pub fn intra_hits(&self) -> u64 {
        self.intra_hits.load(Ordering::Relaxed)
    }

    pub fn hit_rate(&self) -> f64 {
        EvalCache::stats(self).hit_rate()
    }

    /// The eviction policy this session was built with.
    pub fn policy(&self) -> EvictPolicy {
        self.policy
    }

    /// Snapshot entries rejected at load time so far.
    pub fn load_skipped(&self) -> u64 {
        self.load_skipped.load(Ordering::Relaxed)
    }

    /// Count `n` snapshot/store entries that were rejected rather than
    /// trusted at load time (`cost::persist` / `cost::store` report here).
    pub(crate) fn note_load_skipped(&self, n: u64) {
        if n > 0 {
            self.load_skipped.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Every resident evaluation, for the session snapshot
    /// (`cost::persist::save_session`). Shard-by-shard ring order, so the
    /// output is deterministic for a deterministic insert history.
    pub(crate) fn export_eval(&self) -> Vec<(SchemeKey, LayerEval)> {
        let mut out = Vec::new();
        for sh in &self.shards {
            let sh = sh.lock().unwrap();
            out.extend(sh.ring.iter().map(|e| (e.key, e.eval)));
        }
        out
    }

    /// Every recorded intra-layer argmin, in FIFO (recording) order.
    pub(crate) fn export_intra(&self) -> Vec<(IntraKey, Option<LayerScheme>)> {
        let memo = self.intra.lock().unwrap();
        memo.fifo.iter().filter_map(|k| memo.map.get(k).map(|v| (*k, *v))).collect()
    }

    /// Insert a snapshot-loaded evaluation without counting a lookup. Goes
    /// through the normal budgeted insert path, so a snapshot larger than
    /// the budget warms up to the budget and no further.
    pub(crate) fn import_eval(&self, key: SchemeKey, eval: LayerEval) {
        self.insert(shard_of(&key), key, eval);
    }

    /// Insert a snapshot-loaded argmin (first-in wins, FIFO-bounded — the
    /// same rules as a live recording).
    pub(crate) fn import_intra(&self, key: IntraKey, argmin: Option<LayerScheme>) {
        EvalCache::record_intra_argmin(self, key, argmin);
    }

    /// Insert a freshly computed evaluation, staying within the budget: a
    /// full cache evicts a clock victim from the entry's own shard; if the
    /// own shard is empty (budgets smaller than the shard count), a victim
    /// is stolen from a non-empty peer shard — with no locks held across
    /// shards — so even a 1-entry budget keeps caching instead of going
    /// permanently cold for 15/16 of the keyspace.
    fn insert(&self, si: usize, key: SchemeKey, eval: LayerEval) {
        if self.cap == 0 {
            return;
        }
        {
            let mut sh = self.shards[si].lock().unwrap();
            if let Some(&slot) = sh.index.get(&key) {
                // Another thread computed the same key concurrently.
                sh.ring[slot].referenced = true;
                return;
            }
            if self.try_reserve_and_push(&mut sh, key, eval) {
                return;
            }
            if !sh.ring.is_empty() {
                let slot = sh.clock_victim();
                let old = sh.ring[slot].key;
                sh.index.remove(&old);
                sh.ring[slot] = ClockEntry { key, eval, referenced: false, protected: false };
                sh.index.insert(key, slot);
                self.evictions.fetch_add(1, Ordering::Relaxed);
                return;
            }
        }
        // Own shard empty but the cache is full: free a slot elsewhere,
        // then retry one reservation. The own-shard lock is dropped first,
        // so shard locks are only ever taken one at a time (no ordering
        // deadlock); if a racing thread grabs the freed slot we simply
        // skip caching this entry (still within budget).
        if !self.steal_slot(si) {
            return;
        }
        let mut sh = self.shards[si].lock().unwrap();
        if !sh.index.contains_key(&key) {
            self.try_reserve_and_push(&mut sh, key, eval);
        }
    }

    /// Reserve one slot in the global budget and, on success, append the
    /// entry to the shard's clock ring. fetch_add serializes reservations,
    /// so at most `cap` succeed; losers give the slot back (the transient
    /// overshoot makes peers conservative, never over-budget).
    fn try_reserve_and_push(&self, sh: &mut Shard, key: SchemeKey, eval: LayerEval) -> bool {
        let prev = self.count.fetch_add(1, Ordering::Relaxed);
        if prev < self.cap {
            let slot = sh.ring.len();
            sh.ring.push(ClockEntry { key, eval, referenced: false, protected: false });
            sh.index.insert(key, slot);
            true
        } else {
            self.count.fetch_sub(1, Ordering::Relaxed);
            false
        }
    }

    /// Clock-evict one entry from the first non-empty shard other than
    /// `except`, returning whether a slot was freed. Called with no shard
    /// lock held.
    fn steal_slot(&self, except: usize) -> bool {
        for sj in (0..self.shards.len()).filter(|&j| j != except) {
            let mut sh = self.shards[sj].lock().unwrap();
            if sh.ring.is_empty() {
                continue;
            }
            let slot = sh.clock_victim();
            let old = sh.ring[slot].key;
            sh.index.remove(&old);
            sh.ring.swap_remove(slot);
            // swap_remove moved the tail entry into `slot`: fix its index
            // and keep the hand in range.
            if slot < sh.ring.len() {
                let moved = sh.ring[slot].key;
                sh.index.insert(moved, slot);
            }
            if sh.hand > sh.ring.len() {
                sh.hand = 0;
            }
            self.count.fetch_sub(1, Ordering::Relaxed);
            self.evictions.fetch_add(1, Ordering::Relaxed);
            return true;
        }
        false
    }
}

impl EvalCache for SessionCache {
    /// Evaluate `s` on the detailed model, memoized under the budget.
    /// Concurrent misses on the same key may both compute (the simulator
    /// is pure, so they agree); no lock is held across the evaluation.
    fn evaluate_layer(&self, arch: &ArchConfig, s: &LayerScheme, ifm_on_chip: bool) -> LayerEval {
        let key = SchemeKey::of(arch, s, ifm_on_chip);
        let si = shard_of(&key);
        self.lookups.fetch_add(1, Ordering::Relaxed);
        {
            let mut sh = self.shards[si].lock().unwrap();
            if let Some(&slot) = sh.index.get(&key) {
                let e = &mut sh.ring[slot];
                // Segmented LRU: a second hit (entry already referenced)
                // promotes to the protected segment.
                if self.policy == EvictPolicy::SegmentedLru && e.referenced {
                    e.protected = true;
                }
                e.referenced = true;
                self.hits.fetch_add(1, Ordering::Relaxed);
                return e.eval;
            }
        }
        let ev = crate::sim::evaluate_layer(arch, s, ifm_on_chip);
        self.insert(si, key, ev);
        ev
    }

    /// Replay a recorded scan, or report "not recorded". Counted
    /// separately from evaluation lookups: one hit here stands in for a
    /// whole enumeration, not one candidate.
    fn intra_argmin(&self, key: &IntraKey) -> Option<Option<LayerScheme>> {
        self.intra_lookups.fetch_add(1, Ordering::Relaxed);
        let memo = self.intra.lock().unwrap();
        let hit = memo.map.get(key).copied();
        if hit.is_some() {
            self.intra_hits.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Record a scan's argmin, FIFO-evicting under the memo's budget
    /// slice. Concurrent recorders of the same key agree (solvers are
    /// pure), so first-in wins and duplicates are dropped.
    fn record_intra_argmin(&self, key: IntraKey, argmin: Option<LayerScheme>) {
        if self.intra_cap == 0 {
            return;
        }
        let mut memo = self.intra.lock().unwrap();
        if memo.map.contains_key(&key) {
            return;
        }
        while memo.map.len() >= self.intra_cap {
            let Some(old) = memo.fifo.pop_front() else { break };
            memo.map.remove(&old);
        }
        memo.map.insert(key, argmin);
        memo.fifo.push_back(key);
    }

    fn stats(&self) -> CacheStats {
        // Hits read before lookups (each hit bumps lookups first) to make
        // torn concurrent snapshots unlikely; relaxed atomics can still
        // reorder, so misses()/hit_rate() clamp rather than trust this.
        let hits = self.hits();
        let intra_hits = self.intra_hits();
        CacheStats {
            lookups: self.lookups(),
            hits,
            evictions: self.evictions(),
            entries: self.len(),
            intra_lookups: self.intra_lookups.load(Ordering::Relaxed),
            intra_hits,
            load_skipped: self.load_skipped(),
            // Store counters live on the `cost::store::ScheduleStore`
            // serving this session; the coordinator overlays them.
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::directives::{Grp, LevelBlock, LoopOrder, Qty};
    use crate::mapping::UnitMap;
    use crate::partition::PartitionScheme;
    use crate::workloads::Layer;

    fn scheme(arch: &ArchConfig, k: u64) -> LayerScheme {
        let l = Layer::conv("c", 16, k, 14, 3, 1);
        let part = PartitionScheme::single();
        let unit = UnitMap::build(arch, part.node_shape(&l, 4));
        LayerScheme {
            part,
            unit,
            regf: LevelBlock { qty: Qty::new(1, 2, 2), order: LoopOrder([Grp::B, Grp::K, Grp::C]) },
            gbuf: LevelBlock { qty: Qty::new(1, 8, 8), order: LoopOrder([Grp::B, Grp::C, Grp::K]) },
        }
    }

    #[test]
    fn budget_parse_forms() {
        assert_eq!(CacheBudget::parse("unbounded"), Ok(CacheBudget::UNBOUNDED));
        assert_eq!(CacheBudget::parse("none"), Ok(CacheBudget::UNBOUNDED));
        assert_eq!(CacheBudget::parse("5000"), Ok(CacheBudget::entries(5000)));
        assert_eq!(CacheBudget::parse("64MB"), Ok(CacheBudget::bytes(64 * 1024 * 1024)));
        assert_eq!(CacheBudget::parse("4kb"), Ok(CacheBudget::bytes(4 * 1024)));
        assert!(CacheBudget::parse("").is_err());
        assert!(CacheBudget::parse("12xb").is_err());
        assert!(CacheBudget::parse("lots").is_err());
        // Tiny byte budgets degrade to one entry, never zero.
        assert!(CacheBudget::parse("1kb").unwrap().max_entries >= 1);
    }

    #[test]
    fn warm_hits_match_simulator_and_count() {
        let arch = presets::multi_node_eyeriss();
        let sc = SessionCache::unbounded();
        let s = scheme(&arch, 32);
        let a = sc.evaluate_layer(&arch, &s, false);
        let b = sc.evaluate_layer(&arch, &s, false);
        let direct = crate::sim::evaluate_layer(&arch, &s, false);
        assert_eq!(format!("{a:?}"), format!("{direct:?}"));
        assert_eq!(format!("{b:?}"), format!("{direct:?}"));
        let st = EvalCache::stats(&sc);
        assert_eq!((st.lookups, st.hits, st.evictions, st.entries), (2, 1, 0, 1));
        assert_eq!(st.misses(), 1);
        assert!((st.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn budget_is_a_hard_ceiling() {
        let arch = presets::multi_node_eyeriss();
        let sc = SessionCache::new(CacheBudget::entries(3));
        for k in [8u64, 16, 24, 32, 40, 48, 56, 64] {
            sc.evaluate_layer(&arch, &scheme(&arch, k), false);
            assert!(sc.len() <= 3, "len {} exceeds budget", sc.len());
        }
        assert!(sc.evictions() > 0 || sc.len() < 3, "churn must evict once full");
        // Evicted or not, every lookup still returns the simulator's value.
        for k in [8u64, 32, 64] {
            let s = scheme(&arch, k);
            let got = sc.evaluate_layer(&arch, &s, false);
            let want = crate::sim::evaluate_layer(&arch, &s, false);
            assert_eq!(format!("{got:?}"), format!("{want:?}"));
            assert!(sc.len() <= 3);
        }
    }

    #[test]
    fn zero_budget_never_caches_but_stays_correct() {
        let arch = presets::multi_node_eyeriss();
        let sc = SessionCache::new(CacheBudget::entries(0));
        let s = scheme(&arch, 32);
        let a = sc.evaluate_layer(&arch, &s, false);
        let b = sc.evaluate_layer(&arch, &s, false);
        assert_eq!(sc.len(), 0);
        assert_eq!(sc.hits(), 0);
        let want = crate::sim::evaluate_layer(&arch, &s, false);
        assert_eq!(format!("{a:?}"), format!("{want:?}"));
        assert_eq!(format!("{b:?}"), format!("{want:?}"));
    }

    #[test]
    fn clock_gives_hot_entries_a_second_chance() {
        // Single-shard scenario is not guaranteed (keys hash across 16
        // shards), so assert the behavioral consequence instead: with a
        // budget of 2 and a hot key touched between insertions of cold
        // keys, the hot key keeps hitting.
        let arch = presets::multi_node_eyeriss();
        let sc = SessionCache::new(CacheBudget::entries(2));
        let hot = scheme(&arch, 32);
        sc.evaluate_layer(&arch, &hot, false);
        let mut hot_hits = 0;
        for k in [8u64, 16, 24, 40, 48] {
            sc.evaluate_layer(&arch, &scheme(&arch, k), false);
            let before = sc.hits();
            sc.evaluate_layer(&arch, &hot, false);
            hot_hits += (sc.hits() - before) as usize;
            assert!(sc.len() <= 2);
        }
        // The reference bit must have saved the hot entry at least once.
        assert!(hot_hits > 0, "hot key never survived eviction");
    }

    #[test]
    fn different_arch_fingerprints_never_alias() {
        let a1 = presets::eyeriss_like((4, 4), (8, 8), 64, 32 * 1024);
        let a2 = presets::eyeriss_like((4, 4), (8, 8), 64, 64 * 1024);
        let sc = SessionCache::unbounded();
        let s = scheme(&a1, 32);
        let e1 = sc.evaluate_layer(&a1, &s, false);
        let e2 = sc.evaluate_layer(&a2, &s, false);
        assert_eq!(sc.hits(), 0, "different arches must not alias");
        assert_eq!(sc.len(), 2);
        assert!(e2.energy.gbuf_pj > e1.energy.gbuf_pj);
        // Warm lookups stay arch-exact.
        let w1 = sc.evaluate_layer(&a1, &s, false);
        let w2 = sc.evaluate_layer(&a2, &s, false);
        assert_eq!(sc.hits(), 2);
        assert_eq!(format!("{w1:?}"), format!("{e1:?}"));
        assert_eq!(format!("{w2:?}"), format!("{e2:?}"));
    }

    #[test]
    fn intra_argmin_memo_records_and_replays() {
        let arch = presets::multi_node_eyeriss();
        let sc = SessionCache::unbounded();
        let key = IntraKey::of(&arch, 0xABCD, 0x1234);
        assert!(EvalCache::intra_argmin(&sc, &key).is_none());
        let s = scheme(&arch, 32);
        EvalCache::record_intra_argmin(&sc, key, Some(s));
        let hit = EvalCache::intra_argmin(&sc, &key).expect("recorded");
        assert_eq!(format!("{:?}", hit.unwrap()), format!("{s:?}"));
        // "No valid scheme" is memoizable too, and distinct keys never
        // alias (different solver, different arch).
        let none_key = IntraKey::of(&arch, 0xABCD, 0x9999);
        EvalCache::record_intra_argmin(&sc, none_key, None);
        assert!(matches!(EvalCache::intra_argmin(&sc, &none_key), Some(None)));
        let other_arch = presets::eyeriss_like((4, 4), (8, 8), 64, 64 * 1024);
        assert!(EvalCache::intra_argmin(&sc, &IntraKey::of(&other_arch, 0xABCD, 0x1234)).is_none());
        let st = EvalCache::stats(&sc);
        assert_eq!((st.intra_lookups, st.intra_hits), (4, 2));
        assert_eq!(sc.intra_len(), 2);
    }

    #[test]
    fn intra_argmin_memo_respects_the_entry_budget() {
        let arch = presets::multi_node_eyeriss();
        let sc = SessionCache::new(CacheBudget::entries(2));
        let s = scheme(&arch, 32);
        for fp in 0..8u64 {
            EvalCache::record_intra_argmin(&sc, IntraKey::of(&arch, fp, 0), Some(s));
            assert!(sc.intra_len() <= 2, "intra memo breached the budget");
        }
        // Zero budget never records, but lookups stay well-formed.
        let zero = SessionCache::new(CacheBudget::entries(0));
        EvalCache::record_intra_argmin(&zero, IntraKey::of(&arch, 1, 0), Some(s));
        assert_eq!(zero.intra_len(), 0);
        assert!(EvalCache::intra_argmin(&zero, &IntraKey::of(&arch, 1, 0)).is_none());
    }

    #[test]
    fn concurrent_bounded_access_is_consistent() {
        let arch = presets::multi_node_eyeriss();
        let sc = SessionCache::new(CacheBudget::entries(4));
        let schemes: Vec<LayerScheme> =
            (0..32).map(|i| scheme(&arch, 8 + 8 * (i % 8))).collect();
        let evs = crate::util::par_map(&schemes, 4, |s| {
            sc.evaluate_layer(&arch, s, false).energy.total()
        });
        for (s, e) in schemes.iter().zip(&evs) {
            assert_eq!(*e, crate::sim::evaluate_layer(&arch, s, false).energy.total());
        }
        assert!(sc.len() <= 4);
        assert_eq!(sc.lookups(), 32);
    }

    #[test]
    fn segmented_lru_protects_twice_hit_entries() {
        let arch = presets::multi_node_eyeriss();
        let sc = SessionCache::with_policy(CacheBudget::entries(2), EvictPolicy::SegmentedLru);
        assert_eq!(sc.policy().name(), "slru");
        let hot = scheme(&arch, 32);
        sc.evaluate_layer(&arch, &hot, false);
        sc.evaluate_layer(&arch, &hot, false); // sets the reference bit
        sc.evaluate_layer(&arch, &hot, false); // promotes to protected
        let mut hot_hits = 0;
        for k in [8u64, 16, 24, 40, 48, 56] {
            sc.evaluate_layer(&arch, &scheme(&arch, k), false);
            let before = sc.hits();
            let got = sc.evaluate_layer(&arch, &hot, false);
            hot_hits += (sc.hits() - before) as usize;
            // Evicted or resident, results always match the simulator.
            let want = crate::sim::evaluate_layer(&arch, &hot, false);
            assert_eq!(format!("{got:?}"), format!("{want:?}"));
            assert!(sc.len() <= 2, "len {} exceeds budget", sc.len());
        }
        assert!(hot_hits > 0, "protected hot key never survived eviction");
        assert_eq!(EvictPolicy::parse("segmented-lru"), Ok(EvictPolicy::SegmentedLru));
        assert_eq!(EvictPolicy::parse("clock"), Ok(EvictPolicy::Clock));
        assert!(EvictPolicy::parse("lfu").is_err());
    }
}
