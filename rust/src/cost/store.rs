//! Content-addressed on-disk schedule store — the top level of the
//! memoization hierarchy (evaluation memo → intra-argmin memo → whole
//! `SolveResult`s). Solving is fully deterministic given
//! `(net, arch, knobs)`, so a completed schedule can be stored under the
//! fingerprint triple and replayed verbatim: a repeated request after a
//! full process restart is answered with zero detailed evaluations and a
//! byte-identical schedule.
//!
//! Layout: one file per solve under the store directory,
//! `<net_fp>-<arch_fp>-<knobs_fp>.sched` (hex), so a plain shared
//! directory doubles as a fleet-wide warm tier — writers use the same
//! atomic temp-file+rename discipline as the session snapshot
//! (`persist::write_atomic`), so concurrent shards and killed processes
//! can never publish a torn file.
//!
//! Safety discipline matches [`super::persist`]: every file carries a
//! magic, a format version, the full key triple and a checksum over the
//! payload, and the payload must decode exactly (no trailing bytes).
//! Anything that fails any of these checks is *skipped and counted*
//! (`skipped()`), never trusted — the caller falls back to a cold solve,
//! which is always correct. Degraded (deadline-cancelled) results are the
//! caller's responsibility to keep out of the store: only full solves are
//! deterministic replays of the request.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::{fs, io};

use crate::interlayer::prune::PruneStats;
use crate::interlayer::{Schedule, Segment};
use crate::solvers::BnbStats;
use crate::util::fnv1a;
use crate::workloads::{Network, PrevRef};

use super::persist::{
    bytes_fp, read_layer_scheme, write_atomic, write_layer_scheme, ByteReader, ByteWriter,
};

/// Store format version. Bump on ANY layout change — a version mismatch is
/// a skip (cold solve), never a reinterpretation.
pub const STORE_VERSION: u32 = 1;

const MAGIC: [u8; 8] = *b"KAPLASTO";

/// The content address of one solve: fingerprints of everything the
/// (deterministic) solver output depends on.
///
/// * `net_fp` — [`net_fingerprint`]: topology + every layer dimension.
/// * `arch_fp` — `cache::arch_fingerprint`: the full resource/energy
///   description.
/// * `knobs_fp` — solver kind + every determinism-relevant DP/search knob
///   (assembled by the coordinator). Wall-clock-only knobs (threads,
///   deadline, speculation window) are deliberately excluded: they change
///   how fast the same schedule is found, not which one.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct StoreKey {
    pub net_fp: u64,
    pub arch_fp: u64,
    pub knobs_fp: u64,
}

impl StoreKey {
    fn file_name(&self) -> String {
        format!("{:016x}-{:016x}-{:016x}.sched", self.net_fp, self.arch_fp, self.knobs_fp)
    }
}

/// Deterministic fingerprint of a network: name, input dims, every layer's
/// kind and dimensions, and the DAG topology. Two nets with equal
/// fingerprints produce identical solver inputs.
pub fn net_fingerprint(net: &Network) -> u64 {
    let mut vals: Vec<u64> = Vec::with_capacity(16 + net.layers.len() * 12);
    vals.push(net.name.len() as u64);
    vals.extend(net.name.bytes().map(u64::from));
    vals.extend([net.input.0, net.input.1, net.input.2]);
    vals.push(net.layers.len() as u64);
    for (l, prevs) in net.layers.iter().zip(&net.prevs) {
        vals.push(l.name.len() as u64);
        vals.extend(l.name.bytes().map(u64::from));
        vals.extend([
            l.kind as u64,
            l.c,
            l.k,
            l.xo,
            l.yo,
            l.r,
            l.s,
            l.stride,
            l.no_batch as u64,
        ]);
        vals.push(prevs.len() as u64);
        vals.extend(prevs.iter().map(|p| match p {
            PrevRef::Input => u64::MAX,
            PrevRef::Layer(j) => *j as u64,
        }));
    }
    fnv1a(vals)
}

/// A stored solve: the schedule plus the solve-time statistics that
/// describe the search which produced it (replayed verbatim so a warm
/// response reports the same pruning table as the original).
#[derive(Debug, Clone)]
pub struct StoredResult {
    pub schedule: Schedule,
    pub prune: Option<PruneStats>,
    pub bnb: Option<BnbStats>,
}

/// Handle on one store directory. All counters are monotonic over the
/// handle's lifetime and surface through `CacheStats`
/// (`store_lookups`/`store_hits`) and the metrics endpoint.
#[derive(Debug)]
pub struct ScheduleStore {
    dir: PathBuf,
    lookups: AtomicU64,
    hits: AtomicU64,
    skipped: AtomicU64,
    writes: AtomicU64,
}

impl ScheduleStore {
    /// Open (creating if necessary) a store rooted at `dir`.
    pub fn open(dir: &Path) -> io::Result<ScheduleStore> {
        fs::create_dir_all(dir)?;
        Ok(ScheduleStore {
            dir: dir.to_path_buf(),
            lookups: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            skipped: AtomicU64::new(0),
            writes: AtomicU64::new(0),
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn lookups(&self) -> u64 {
        self.lookups.load(Ordering::Relaxed)
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Files that existed but failed a safety check (magic, version, key,
    /// checksum, exact decode) and were ignored.
    pub fn skipped(&self) -> u64 {
        self.skipped.load(Ordering::Relaxed)
    }

    pub fn writes(&self) -> u64 {
        self.writes.load(Ordering::Relaxed)
    }

    /// Look up a stored solve. A missing file is a plain miss; a present
    /// but unreadable/undecodable file is a miss *and* bumps `skipped()`.
    pub fn lookup(&self, key: &StoreKey) -> Option<StoredResult> {
        self.lookups.fetch_add(1, Ordering::Relaxed);
        let path = self.dir.join(key.file_name());
        let bytes = match fs::read(&path) {
            Ok(b) => b,
            Err(e) if e.kind() == io::ErrorKind::NotFound => return None,
            Err(_) => {
                self.skipped.fetch_add(1, Ordering::Relaxed);
                return None;
            }
        };
        match decode_stored(&bytes, key) {
            Some(r) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(r)
            }
            None => {
                self.skipped.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Record a completed (non-degraded) solve. Atomic: readers — in this
    /// process or any other shard sharing the directory — see either the
    /// old file or the complete new one, never a torn write.
    pub fn record(
        &self,
        key: &StoreKey,
        schedule: &Schedule,
        prune: Option<&PruneStats>,
        bnb: Option<&BnbStats>,
    ) -> io::Result<()> {
        let bytes = encode_stored(key, schedule, prune, bnb);
        write_atomic(&self.dir.join(key.file_name()), &bytes)?;
        self.writes.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }
}

// --- codec ---------------------------------------------------------------

fn encode_stored(
    key: &StoreKey,
    schedule: &Schedule,
    prune: Option<&PruneStats>,
    bnb: Option<&BnbStats>,
) -> Vec<u8> {
    let mut w = ByteWriter::default();
    w.u64(key.net_fp);
    w.u64(key.arch_fp);
    w.u64(key.knobs_fp);
    write_schedule(&mut w, schedule);
    match prune {
        Some(p) => {
            w.u8(1);
            write_prune(&mut w, p);
        }
        None => w.u8(0),
    }
    match bnb {
        Some(b) => {
            w.u8(1);
            write_bnb(&mut w, b);
        }
        None => w.u8(0),
    }
    let payload = w.buf;
    let mut out = Vec::with_capacity(payload.len() + 20);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&STORE_VERSION.to_le_bytes());
    out.extend_from_slice(&bytes_fp(&payload).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

fn decode_stored(bytes: &[u8], want: &StoreKey) -> Option<StoredResult> {
    if bytes.len() < 20 || bytes[..8] != MAGIC {
        return None;
    }
    let version = u32::from_le_bytes(bytes[8..12].try_into().ok()?);
    if version != STORE_VERSION {
        return None;
    }
    let sum = u64::from_le_bytes(bytes[12..20].try_into().ok()?);
    let payload = &bytes[20..];
    if bytes_fp(payload) != sum {
        return None;
    }
    let mut r = ByteReader::new(payload);
    // The embedded key must match the address the caller computed — a
    // renamed/cross-copied file answers under the wrong key otherwise.
    let key =
        StoreKey { net_fp: r.u64()?, arch_fp: r.u64()?, knobs_fp: r.u64()? };
    if key != *want {
        return None;
    }
    let schedule = read_schedule(&mut r)?;
    let prune = match r.u8()? {
        0 => None,
        1 => Some(read_prune(&mut r)?),
        _ => return None,
    };
    let bnb = match r.u8()? {
        0 => None,
        1 => Some(read_bnb(&mut r)?),
        _ => return None,
    };
    if !r.is_empty() {
        return None;
    }
    Some(StoredResult { schedule, prune, bnb })
}

fn write_schedule(w: &mut ByteWriter, s: &Schedule) {
    w.u32(s.segments.len() as u32);
    for (seg, schemes) in &s.segments {
        w.u32(seg.layers.len() as u32);
        for &li in &seg.layers {
            w.u64(li as u64);
        }
        w.u32(seg.regions.len() as u32);
        for &(a, b) in &seg.regions {
            w.u64(a);
            w.u64(b);
        }
        w.bool(seg.spatial);
        w.u64(seg.rounds);
        w.u32(schemes.len() as u32);
        for sc in schemes {
            write_layer_scheme(w, sc);
        }
    }
}

fn read_schedule(r: &mut ByteReader) -> Option<Schedule> {
    let nseg = r.u32()? as usize;
    let mut segments = Vec::with_capacity(nseg.min(1024));
    for _ in 0..nseg {
        let nl = r.u32()? as usize;
        let mut layers = Vec::with_capacity(nl.min(1024));
        for _ in 0..nl {
            layers.push(r.u64()? as usize);
        }
        let nr = r.u32()? as usize;
        let mut regions = Vec::with_capacity(nr.min(1024));
        for _ in 0..nr {
            regions.push((r.u64()?, r.u64()?));
        }
        let spatial = r.bool()?;
        let rounds = r.u64()?;
        let seg = Segment { layers, regions, spatial, rounds };
        let ns = r.u32()? as usize;
        let mut schemes = Vec::with_capacity(ns.min(1024));
        for _ in 0..ns {
            schemes.push(read_layer_scheme(r)?);
        }
        segments.push((seg, schemes));
    }
    Some(Schedule { segments })
}

fn write_prune(w: &mut ByteWriter, p: &PruneStats) {
    for v in [
        p.total,
        p.after_validity,
        p.after_pareto,
        p.spans_total,
        p.spans_pruned,
        p.schemes_bound_pruned,
        p.tables_built,
    ] {
        w.u64(v as u64);
    }
}

fn read_prune(r: &mut ByteReader) -> Option<PruneStats> {
    Some(PruneStats {
        total: r.u64()? as usize,
        after_validity: r.u64()? as usize,
        after_pareto: r.u64()? as usize,
        spans_total: r.u64()? as usize,
        spans_pruned: r.u64()? as usize,
        schemes_bound_pruned: r.u64()? as usize,
        tables_built: r.u64()? as usize,
    })
}

fn write_bnb(w: &mut ByteWriter, b: &BnbStats) {
    w.bool(b.part_floor);
    for v in [
        b.parts_visited,
        b.parts_pruned,
        b.prefixes_visited,
        b.prefixes_pruned,
        b.bound_evals,
        b.schemes_visited,
        b.schemes_skipped,
        b.tightness_permille,
    ] {
        w.u64(v);
    }
}

fn read_bnb(r: &mut ByteReader) -> Option<BnbStats> {
    Some(BnbStats {
        part_floor: r.bool()?,
        parts_visited: r.u64()?,
        parts_pruned: r.u64()?,
        prefixes_visited: r.u64()?,
        prefixes_pruned: r.u64()?,
        bound_evals: r.u64()?,
        schemes_visited: r.u64()?,
        schemes_skipped: r.u64()?,
        tightness_permille: r.u64()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::cost::cache::arch_fingerprint;
    use crate::solvers::{SolveCtx, SolverKind};
    use crate::workloads::nets;
    use std::sync::atomic::AtomicUsize;

    fn tmp_dir(name: &str) -> PathBuf {
        static SEQ: AtomicUsize = AtomicUsize::new(0);
        let d = std::env::temp_dir().join(format!(
            "kapla-store-unit-{}-{}-{}",
            std::process::id(),
            name,
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn sched_key(tag: u64) -> StoreKey {
        StoreKey { net_fp: tag, arch_fp: tag.wrapping_mul(3), knobs_fp: tag.wrapping_mul(7) }
    }

    #[test]
    fn net_fingerprint_separates_nets_and_is_stable() {
        let a = nets::mlp();
        let b = nets::alexnet();
        assert_eq!(net_fingerprint(&a), net_fingerprint(&a));
        assert_ne!(net_fingerprint(&a), net_fingerprint(&b));
        // A single dimension tweak must move the fingerprint.
        let mut c = nets::mlp();
        c.layers[0].k += 1;
        assert_ne!(net_fingerprint(&a), net_fingerprint(&c));
    }

    #[test]
    fn record_then_lookup_round_trips_schedule_bytes() {
        let arch = presets::bench_multi_node();
        let net = nets::mlp();
        let r = SolveCtx::new(&arch).run(&net, 4, SolverKind::Kapla).unwrap();
        let dir = tmp_dir("roundtrip");
        let store = ScheduleStore::open(&dir).unwrap();
        let key = StoreKey {
            net_fp: net_fingerprint(&net),
            arch_fp: arch_fingerprint(&arch),
            knobs_fp: 42,
        };
        assert!(store.lookup(&key).is_none(), "store starts cold");
        store.record(&key, &r.schedule, r.prune.as_ref(), r.bnb.as_ref()).unwrap();
        let got = store.lookup(&key).expect("recorded entry");
        assert_eq!(
            format!("{:?}", got.schedule),
            format!("{:?}", r.schedule),
            "schedule must replay byte-identical"
        );
        assert_eq!(store.lookups(), 2);
        assert_eq!(store.hits(), 1);
        assert_eq!(store.skipped(), 0);
        // A fresh handle on the same directory (a "restarted process")
        // still answers.
        let reopened = ScheduleStore::open(&dir).unwrap();
        assert!(reopened.lookup(&key).is_some());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupted_files_are_skipped_not_trusted() {
        let arch = presets::bench_multi_node();
        let net = nets::mlp();
        let r = SolveCtx::new(&arch).run(&net, 4, SolverKind::Kapla).unwrap();
        let dir = tmp_dir("corrupt");
        let store = ScheduleStore::open(&dir).unwrap();
        let key = sched_key(9);
        store.record(&key, &r.schedule, None, None).unwrap();
        let path = dir.join(key.file_name());
        let clean = fs::read(&path).unwrap();

        // Truncation, flipped version byte, flipped payload byte, and a
        // wrong-key rename each degrade to a miss with skipped bumped.
        let cases: Vec<Vec<u8>> = vec![
            clean[..clean.len() / 2].to_vec(),
            {
                let mut b = clean.clone();
                b[8] ^= 0xFF; // version
                b
            },
            {
                let mut b = clean.clone();
                let last = b.len() - 1;
                b[last] ^= 0x01; // payload (checksum mismatch)
                b
            },
        ];
        for (i, bad) in cases.iter().enumerate() {
            fs::write(&path, bad).unwrap();
            let before = store.skipped();
            assert!(store.lookup(&key).is_none(), "case {i} must miss");
            assert_eq!(store.skipped(), before + 1, "case {i} must be counted");
        }
        // Wrong key: intact bytes copied under another address.
        fs::write(&path, &clean).unwrap();
        let other = sched_key(10);
        fs::write(dir.join(other.file_name()), &clean).unwrap();
        assert!(store.lookup(&other).is_none(), "cross-copied file must not answer");
        assert!(store.lookup(&key).is_some(), "original stays valid");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn prune_and_bnb_stats_round_trip() {
        let p = PruneStats {
            total: 10,
            after_validity: 9,
            after_pareto: 5,
            spans_total: 4,
            spans_pruned: 2,
            schemes_bound_pruned: 3,
            tables_built: 2,
        };
        let b = BnbStats {
            part_floor: true,
            parts_visited: 7,
            parts_pruned: 6,
            prefixes_visited: 5,
            prefixes_pruned: 4,
            bound_evals: 3,
            schemes_visited: 2,
            schemes_skipped: 1,
            tightness_permille: 1234,
        };
        let sched = Schedule { segments: Vec::new() };
        let key = sched_key(1);
        let bytes = encode_stored(&key, &sched, Some(&p), Some(&b));
        let got = decode_stored(&bytes, &key).unwrap();
        assert_eq!(format!("{:?}", got.prune.unwrap()), format!("{p:?}"));
        assert_eq!(got.bnb.unwrap(), b);
    }
}
