//! `kapla` — CLI front end for the KAPLA dataflow scheduler.
//!
//! Subcommands:
//!   schedule   Solve one network and print the resulting schedule.
//!   compare    Run several solvers on one network, paper-style table.
//!   directives Emit the tensor-centric directive program of a schedule.
//!   validate   Parse + inspect an externally-authored directive file.
//!   serve      Request-loop service mode (stdin/stdout).
//!   info       Show hardware presets and network zoo.
//!
//! Argument parsing is hand-rolled (no clap in the offline registry);
//! flags are `--key value` pairs.

use kapla::arch::{presets, ArchConfig};
use kapla::coordinator::{self, service, transport, Job, SolverKind};
use kapla::cost::{
    load_session, save_session, CacheBudget, CacheStats, EvalCache as _, ScheduleStore,
    SessionCache,
};
use kapla::directives::emit::emit_layer;
use kapla::interlayer::dp::DpConfig;
use kapla::report::{eng, Table};
use kapla::solvers::Objective;
use kapla::util::stats::fmt_duration;
use kapla::workloads;

use std::collections::HashMap;
use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = args.split_first() else {
        usage();
        return ExitCode::FAILURE;
    };
    let flags = parse_flags(rest);
    match cmd.as_str() {
        "schedule" => cmd_schedule(&flags, false),
        "directives" => cmd_schedule(&flags, true),
        "compare" => cmd_compare(&flags),
        "validate" => cmd_validate(rest),
        "serve" => cmd_serve(&flags),
        "info" => cmd_info(),
        _ => {
            usage();
            ExitCode::FAILURE
        }
    }
}

fn usage() {
    eprintln!(
        "kapla <schedule|directives|compare|validate|serve|info> \
         [--net NAME] [--batch N] [--arch multi|edge|bench] \
         [--solver k|b|s|r[:p=P,seed=S]|m[:rounds=R,batch=B,seed=S]] \
         [--objective energy|latency] [--train] \
         [--threads N] [--cache-budget N|unbounded|64mb] \
         [--cache-dir DIR] [--deadline-ms MS]\n\
         serve only: [--listen HOST:PORT|unix:PATH] [--tenants N] \
         [--queue-depth N] [--workers N] [--max-connections N] \
         [--metrics-interval SECS] [--idle-timeout SECS]"
    );
}

/// serve: the stdin/stdout line loop by default, or — with `--listen` —
/// the concurrent TCP / unix-socket front end with per-tenant sessions,
/// bounded-queue admission control and the `metrics` surface. Either way
/// the session budget defaults to the bounded `run_jobs` default (a
/// long-running service must not grow memory monotonically);
/// `--cache-budget unbounded` restores the old behavior explicitly.
fn cmd_serve(flags: &HashMap<String, String>) -> ExitCode {
    let budget = match flags.get("cache-budget") {
        Some(s) => match CacheBudget::parse(s) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("{e}");
                return ExitCode::FAILURE;
            }
        },
        None => CacheBudget::bytes(coordinator::DEFAULT_SESSION_BYTES),
    };
    let arch = arch_of(flags);
    let cache_dir = flags.get("cache-dir").map(PathBuf::from);
    let Some(spec) = flags.get("listen") else {
        service::serve_persistent(&arch, budget, cache_dir.as_deref());
        return ExitCode::SUCCESS;
    };

    let mut cfg = transport::ServiceConfig { budget, cache_dir, ..Default::default() };
    for (key, slot) in [
        ("queue-depth", &mut cfg.queue_depth),
        ("tenants", &mut cfg.max_tenants),
        ("workers", &mut cfg.workers),
        ("max-connections", &mut cfg.max_connections),
    ] {
        if let Some(v) = flags.get(key) {
            match v.parse::<usize>() {
                Ok(n) if n >= 1 => *slot = n,
                _ => {
                    eprintln!("bad --{key} {v:?}: want a count >= 1");
                    return ExitCode::FAILURE;
                }
            }
        }
    }
    if let Some(v) = flags.get("metrics-interval") {
        match v.parse::<f64>() {
            Ok(s) if s > 0.0 && s.is_finite() => {
                cfg.metrics_interval = Some(std::time::Duration::from_secs_f64(s))
            }
            _ => {
                eprintln!("bad --metrics-interval {v:?}: want seconds > 0");
                return ExitCode::FAILURE;
            }
        }
    }
    if let Some(v) = flags.get("idle-timeout") {
        match v.parse::<f64>() {
            Ok(s) if s > 0.0 && s.is_finite() => {
                cfg.idle_timeout = Some(std::time::Duration::from_secs_f64(s))
            }
            _ => {
                eprintln!("bad --idle-timeout {v:?}: want seconds > 0");
                return ExitCode::FAILURE;
            }
        }
    }
    match transport::spawn(&arch, cfg, spec) {
        Ok(handle) => {
            eprintln!("kapla service listening on {}", handle.label());
            handle.join();
            ExitCode::SUCCESS
        }
        Err(e) => {
            eprintln!("cannot listen on {spec}: {e}");
            ExitCode::FAILURE
        }
    }
}

/// Session-cache budget from `--cache-budget` (entries, `kb/mb/gb` byte
/// sizes, or `unbounded`); the default is unbounded.
fn budget_of(flags: &HashMap<String, String>) -> Result<CacheBudget, String> {
    match flags.get("cache-budget") {
        Some(s) => CacheBudget::parse(s),
        None => Ok(CacheBudget::UNBOUNDED),
    }
}

fn print_cache_stats(prefix: &str, st: &CacheStats) {
    println!(
        "{prefix}: {} lookups, {} hits ({:.0}%), {} evictions, {} entries resident, \
         {}/{} intra-argmin replays",
        st.lookups,
        st.hits,
        100.0 * st.hit_rate(),
        st.evictions,
        st.entries,
        st.intra_hits,
        st.intra_lookups
    );
}

/// DP knobs for CLI jobs: intra-layer solves use all available workers
/// unless `--threads` overrides (results are identical either way).
fn dp_of(flags: &HashMap<String, String>) -> DpConfig {
    let threads = flags
        .get("threads")
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(coordinator::default_threads);
    DpConfig { solve_threads: threads, ..DpConfig::default() }
}

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        if let Some(key) = a.strip_prefix("--") {
            let val = match it.peek() {
                Some(v) if !v.starts_with("--") => it.next().unwrap().clone(),
                _ => "true".to_string(),
            };
            out.insert(key.to_string(), val);
        }
    }
    out
}

fn arch_of(flags: &HashMap<String, String>) -> ArchConfig {
    match flags.get("arch").map(|s| s.as_str()).unwrap_or("multi") {
        "edge" => presets::edge_tpu(),
        "bench" => presets::bench_multi_node(),
        _ => presets::multi_node_eyeriss(),
    }
}

fn net_of(flags: &HashMap<String, String>) -> Option<(kapla::workloads::Network, u64)> {
    let name = flags.get("net").map(|s| s.as_str()).unwrap_or("alexnet");
    let fwd = workloads::by_name(name)?;
    let train = flags.contains_key("train");
    let batch: u64 = flags.get("batch").and_then(|s| s.parse().ok()).unwrap_or(64);
    let net = if train { workloads::training_graph(&fwd) } else { fwd };
    Some((net, batch))
}

/// `--objective`, strict: a present-but-misspelled value is an error, not
/// a silent fall-back to energy.
fn objective_of(flags: &HashMap<String, String>) -> Result<Objective, String> {
    match flags.get("objective") {
        Some(s) => Objective::parse(s).ok_or_else(|| format!("unknown objective {s:?}")),
        None => Ok(Objective::Energy),
    }
}

fn cmd_schedule(flags: &HashMap<String, String>, emit: bool) -> ExitCode {
    let arch = arch_of(flags);
    let Some((net, batch)) = net_of(flags) else {
        eprintln!("unknown network");
        return ExitCode::FAILURE;
    };
    let solver = match flags.get("solver") {
        Some(s) => match SolverKind::parse(s) {
            Some(k) => k,
            None => {
                eprintln!("unknown solver {s:?}");
                return ExitCode::FAILURE;
            }
        },
        None => SolverKind::Kapla,
    };
    let budget = match budget_of(flags) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("{e}");
            return ExitCode::FAILURE;
        }
    };
    let objective = match objective_of(flags) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("{e}");
            return ExitCode::FAILURE;
        }
    };
    let deadline_ms = match flags.get("deadline-ms") {
        Some(v) => match v.parse::<u64>() {
            Ok(ms) if ms >= 1 => Some(ms),
            _ => {
                eprintln!("bad --deadline-ms {v:?}: want milliseconds >= 1");
                return ExitCode::FAILURE;
            }
        },
        None => None,
    };
    let job = Job { net, batch, objective, solver, dp: dp_of(flags), deadline_ms };
    println!(
        "scheduling {} (batch {batch}) on {} with {}...",
        job.net.name,
        arch.name,
        solver.label()
    );
    let session = SessionCache::new(budget);
    // Warm tier (single-user layout): `<dir>/session.snap` holds the
    // evaluation/argmin memos, `<dir>/store/` the content-addressed
    // schedules. Both are optional accelerators — any load failure is
    // reported and the run proceeds cold.
    let cache_dir = flags.get("cache-dir").map(PathBuf::from);
    let store = cache_dir.as_ref().and_then(|dir| {
        match load_session(&session, &dir.join("session.snap"), Some(&arch)) {
            Ok(snap) => {
                if snap.eval_entries + snap.intra_entries + snap.skipped > 0 {
                    println!(
                        "session snapshot: {} evaluations, {} argmins restored, {} skipped",
                        snap.eval_entries, snap.intra_entries, snap.skipped
                    );
                }
            }
            Err(e) => eprintln!("warm tier: cannot load session snapshot: {e}"),
        }
        ScheduleStore::open(&dir.join("store"))
            .inspect_err(|e| eprintln!("warm tier: cannot open schedule store: {e}"))
            .ok()
    });
    let r = match coordinator::run_job_persistent(&arch, &job, &session, store.as_ref()) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("scheduling failed: {e}");
            return ExitCode::FAILURE;
        }
    };
    print_cache_stats("evaluation cache", &r.cache);
    if let Some(st) = &store {
        println!(
            "schedule store: {} lookups, {} hits, {} writes, {} skipped",
            st.lookups(),
            st.hits(),
            st.writes(),
            st.skipped()
        );
    }
    if let Some(dir) = &cache_dir {
        if let Err(e) = save_session(&session, &dir.join("session.snap")) {
            eprintln!("warm tier: cannot save session snapshot: {e}");
        }
    }
    if let Some(d) = &r.degraded {
        println!(
            "note: best-effort schedule — {} tripped after {:.1} ms, \
             search stopped at the current incumbent",
            d.reason, d.elapsed_ms
        );
    }

    println!(
        "energy {} | latency {} cycles ({:.3} ms) | solved in {}",
        eng(r.eval.energy.total(), "pJ"),
        eng(r.eval.latency_cycles, ""),
        r.eval.latency_s(&arch) * 1e3,
        fmt_duration(r.solve_s)
    );
    let b = &r.eval.energy;
    println!(
        "breakdown: alu {} | regf {} | bus {} | gbuf {} | noc {} | dram {}",
        eng(b.alu_pj, "pJ"),
        eng(b.regf_pj, "pJ"),
        eng(b.bus_pj, "pJ"),
        eng(b.gbuf_pj, "pJ"),
        eng(b.noc_pj, "pJ"),
        eng(b.dram_pj, "pJ"),
    );
    for (si, (seg, schemes)) in r.schedule.segments.iter().enumerate() {
        let names: Vec<&str> =
            seg.layers.iter().map(|&i| job.net.layers[i].name.as_str()).collect();
        println!(
            "segment {si}: [{}] {} rounds={} regions={:?}",
            names.join(", "),
            if seg.spatial { "pipelined" } else { "time-shared" },
            seg.rounds,
            seg.regions
        );
        if emit {
            for (pos, s) in schemes.iter().enumerate() {
                println!("{}", emit_layer(names[pos], s));
            }
        }
    }
    ExitCode::SUCCESS
}

fn cmd_compare(flags: &HashMap<String, String>) -> ExitCode {
    let arch = arch_of(flags);
    let Some((net, batch)) = net_of(flags) else {
        eprintln!("unknown network");
        return ExitCode::FAILURE;
    };
    let solvers: Vec<SolverKind> = flags
        .get("solvers")
        .map(|s| s.as_str())
        .unwrap_or("k,r,m")
        .split(',')
        .filter_map(SolverKind::parse)
        .collect();
    let obj = match objective_of(flags) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("{e}");
            return ExitCode::FAILURE;
        }
    };
    // Job-level parallelism already saturates the host here; keep each
    // job's intra-layer sweep sequential so the pools don't multiply
    // (`--threads` caps the outer job pool).
    let threads = flags
        .get("threads")
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(coordinator::default_threads);
    let budget = match budget_of(flags) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("{e}");
            return ExitCode::FAILURE;
        }
    };
    let jobs: Vec<Job> = solvers
        .iter()
        .map(|&solver| Job {
            net: net.clone(),
            batch,
            objective: obj,
            solver,
            dp: DpConfig::default(),
            deadline_ms: None,
        })
        .collect();
    // One scheduling session for the whole comparison: solvers exploring
    // overlapping candidate spaces (B ⊂ S, R/M ⊂ B) reuse each other's
    // detailed-model evaluations.
    let session = SessionCache::new(budget);
    let results: Vec<_> = coordinator::run_jobs_with(&arch, &jobs, threads, &session)
        .into_iter()
        .collect::<Result<_, _>>()
        .unwrap_or_else(|e| {
            eprintln!("scheduling failed: {e}");
            std::process::exit(1);
        });
    let base = results[0].eval.energy.total();
    let mut t = Table::new(
        &format!("{} batch={batch} on {}", net.name, arch.name),
        &["solver", "energy", "normalized", "latency cycles", "solve time"],
    );
    for (s, r) in solvers.iter().zip(&results) {
        t.row(vec![
            s.label(),
            eng(r.eval.energy.total(), "pJ"),
            format!("{:.3}", r.eval.energy.total() / base),
            eng(r.eval.latency_cycles, ""),
            fmt_duration(r.solve_s),
        ]);
    }
    println!("{}", t.render());
    print_cache_stats("session cache", &session.stats());
    ExitCode::SUCCESS
}

fn cmd_validate(args: &[String]) -> ExitCode {
    let Some(path) = args.iter().find(|a| !a.starts_with("--")) else {
        eprintln!("validate: missing directive file");
        return ExitCode::FAILURE;
    };
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("cannot read {path}: {e}");
            return ExitCode::FAILURE;
        }
    };
    match kapla::directives::parse::parse(&text) {
        Ok(progs) => {
            for p in &progs {
                println!("{} {}:", p.kind, p.name);
                for lvl in &p.levels {
                    println!(
                        "  {}: {} words resident, {}x parallel",
                        lvl.level,
                        p.resident_words(&lvl.level).unwrap_or(0),
                        p.parallelism(&lvl.level).unwrap_or(1)
                    );
                }
            }
            ExitCode::SUCCESS
        }
        Err(e) => {
            eprintln!("parse error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn cmd_info() -> ExitCode {
    let mut t =
        Table::new("hardware presets", &["name", "nodes", "PEs/node", "REGF", "GBUF", "dataflow"]);
    for a in [presets::multi_node_eyeriss(), presets::bench_multi_node(), presets::edge_tpu()] {
        t.row(vec![
            a.name.into(),
            format!("{}x{}", a.nodes.0, a.nodes.1),
            format!("{}x{}", a.pes.0, a.pes.1),
            format!("{} B", a.regf.bytes),
            format!("{} kB", a.gbuf.bytes / 1024),
            format!("{:?}", a.pe_dataflow),
        ]);
    }
    println!("{}", t.render());

    let mut t = Table::new("network zoo", &["name", "layers", "MACs (batch 1)", "weights"]);
    for net in workloads::all_networks() {
        t.row(vec![
            net.name.clone(),
            net.len().to_string(),
            eng(net.total_macs(1) as f64, ""),
            eng(net.total_weights() as f64, ""),
        ]);
    }
    println!("{}", t.render());
    ExitCode::SUCCESS
}
