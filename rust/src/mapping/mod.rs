//! PE-level unit mappings (paper §III-A "PE mapping", §III-C).
//!
//! The lowest-level REGF dataflow is fixed by the hardware template: the
//! Eyeriss-like row-stationary scheme [8] or the TPU-like weight-stationary
//! systolic flow [25]. Each template is an [`ArrayMapping`] implementation
//! — the single place that knows how the PE array absorbs spatial dims —
//! and everything above (partitioning, the staged evaluator, emission)
//! talks to it through the trait. A `UnitMap` captures everything the
//! upper levels need to know about the PE array:
//!
//! * the *unit tensors* — the per-group granules the bottom-up solver
//!   starts from (paper §IV-C);
//! * the per-node *totals* of each temporal loop group that remain after
//!   the array absorbs its spatial dims;
//! * tensor word-count functions at node scope (for GBUF residency and
//!   traffic) and per-PE REGF footprint functions (for REGF validity);
//! * the spatial utilization of the array after folding.

pub mod row_stationary;
pub mod systolic;

pub use row_stationary::RowStationary;
pub use systolic::Systolic;

use crate::arch::{ArchConfig, PeDataflow};
use crate::directives::Qty;
use crate::workloads::{Layer, LayerKind};

/// Per-node view of a layer after node-level partitioning.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LayerShape {
    pub kind: LayerKind,
    /// Per-node batch.
    pub n: u64,
    pub c: u64,
    pub k: u64,
    pub xo: u64,
    pub yo: u64,
    pub r: u64,
    pub s: u64,
    pub stride: u64,
}

impl LayerShape {
    /// Whole-layer shape for batch `n` (no partitioning).
    pub fn full(layer: &Layer, n: u64) -> LayerShape {
        LayerShape {
            kind: layer.kind,
            n: layer.batch(n),
            c: layer.c,
            k: layer.k,
            xo: layer.xo,
            yo: layer.yo,
            r: layer.r,
            s: layer.s,
            stride: layer.stride,
        }
    }

    /// Input fmap width; back-activation layers invert the stride (their
    /// input is the forward output fmap), matching `Layer::xi`.
    pub fn xi(&self) -> u64 {
        match self.kind {
            LayerKind::ConvBwAct | LayerKind::DWConvBwAct => {
                self.xo.saturating_sub(self.r) / self.stride + 1
            }
            _ => (self.xo - 1) * self.stride + self.r,
        }
    }

    /// Input fmap height (see `xi`).
    pub fn yi(&self) -> u64 {
        match self.kind {
            LayerKind::ConvBwAct | LayerKind::DWConvBwAct => {
                self.yo.saturating_sub(self.s) / self.stride + 1
            }
            _ => (self.yo - 1) * self.stride + self.s,
        }
    }

    /// MACs for this (per-node) shape.
    pub fn macs(&self) -> u64 {
        match self.kind {
            LayerKind::Conv | LayerKind::Fc | LayerKind::ConvBwWeight => {
                self.n * self.k * self.c * self.xo * self.yo * self.r * self.s
            }
            // Transposed conv: one reduction per dY (= input fmap) pixel.
            LayerKind::ConvBwAct => {
                self.n * self.k * self.c * self.xi() * self.yi() * self.r * self.s
            }
            LayerKind::DWConv | LayerKind::Pool => {
                self.n * self.k * self.xo * self.yo * self.r * self.s
            }
            LayerKind::DWConvBwAct => self.n * self.k * self.xi() * self.yi() * self.r * self.s,
            LayerKind::Eltwise => self.n * self.k * self.xo * self.yo,
        }
    }

    fn has_weights(&self) -> bool {
        matches!(
            self.kind,
            LayerKind::Conv
                | LayerKind::Fc
                | LayerKind::DWConv
                | LayerKind::ConvBwWeight
                | LayerKind::ConvBwAct
                | LayerKind::DWConvBwAct
        )
    }
}

/// Effective C-group extent of a shape: depthwise/pool/eltwise layers carry
/// their channels in the K group, so their C group is trivial.
fn chan_c(shape: LayerShape) -> u64 {
    if chan_in_k(shape.kind) {
        1
    } else {
        shape.c
    }
}

/// Whether a kind tracks its channels in the K loop group (see
/// `directives::tensor_groups`): one "filter" per channel, no cross-channel
/// reduction.
fn chan_in_k(kind: LayerKind) -> bool {
    matches!(
        kind,
        LayerKind::DWConv | LayerKind::DWConvBwAct | LayerKind::Pool | LayerKind::Eltwise
    )
}

/// A PE-array mapping template (paper §III-A): everything the hardware's
/// fixed REGF dataflow determines, behind one seam. Implementations are
/// stateless statics; `UnitMap` carries the per-layer quantities and
/// delegates back here, so the rest of the stack — `partition`, the staged
/// evaluator (`directives::PartAccess`/`GbufAccess`), `solvers::space`,
/// `directives::emit`, the sim — never matches on `PeDataflow`.
pub trait ArrayMapping: std::fmt::Debug + Sync {
    /// Human-readable template name (bench tables, JSON rows).
    fn name(&self) -> &'static str;

    /// Build the unit mapping for a per-node shape: unit-tensor granules,
    /// remaining temporal totals, and spatial utilization after folding.
    fn build(&'static self, arch: &ArchConfig, shape: LayerShape) -> UnitMap;

    /// Words of the input fmap covering quantity block `q` at node scope.
    fn ifm_node_words(&self, u: &UnitMap, q: Qty) -> u64;

    /// Words of the output fmap for quantity block `q` at node scope.
    fn ofm_node_words(&self, u: &UnitMap, q: Qty) -> u64;

    /// Words of the weight-role tensor for quantity block `q` (0 if
    /// unweighted). For the back-weight pass this is the streamed dY.
    fn wgt_node_words(&self, u: &UnitMap, q: Qty) -> u64;

    /// Per-PE REGF footprint in words when the REGF-resident block is `q`.
    fn regf_pe_words(&self, u: &UnitMap, q: Qty) -> u64;

    /// GBUF-resident fmap rows `(ifm_rows, ofm_rows)`: full planes under
    /// row-stationary, one streaming stripe under systolic.
    fn gbuf_fmap_rows(&self, shape: &LayerShape) -> (u64, u64);

    /// Emit the REGF-level tensors, PE-array stacks and PE-internal
    /// updates fixed by this template (the body under `REGF:`).
    fn emit_regf(&self, out: &mut String, name: &str, s: &crate::directives::LayerScheme);

    /// Directive-comment label of the B loop group for `kind` under this
    /// template (what one B step iterates over).
    fn batch_dim_label(&self, kind: LayerKind) -> &'static str;
}

/// Select the array-mapping template for an arch's fixed PE dataflow.
///
/// This is the single `PeDataflow` dispatch point for the mapping /
/// partition / directives / sim layers; everything downstream carries the
/// returned trait object.
pub fn array_mapping(df: PeDataflow) -> &'static dyn ArrayMapping {
    match df {
        PeDataflow::RowStationary => &RowStationary,
        PeDataflow::Systolic => &Systolic,
    }
}

/// The PE-array mapping of one layer on one node.
#[derive(Debug, Clone, Copy)]
pub struct UnitMap {
    /// The hardware template that built this map (and serves its word
    /// counts, footprints and emission).
    pub mapping: &'static dyn ArrayMapping,
    /// Per-node layer shape this map was built for.
    pub shape: LayerShape,
    /// PE array dims (cols, rows).
    pub array: (u64, u64),
    /// Temporal loop-group totals per node that remain above the PE array.
    /// B counts images (row-stationary) or output rows (systolic);
    /// C and K count channels.
    pub totals: Qty,
    /// Unit tensor granules per group (the starting block of the bottom-up
    /// solver). Blocks are grown in multiples of these.
    pub granule: Qty,
    /// Fraction of PEs doing useful work (spatial folding efficiency).
    pub utilization: f64,
    /// Row-stationary only: the 1D-conv window chunk held per PE. Filter
    /// rows longer than the REGF allows are folded temporally in chunks
    /// with psum accumulation (Eyeriss handles large filters the same
    /// way); training back-weight layers have filter rows of 27+ taps.
    pub rs_chunk: u64,
}

impl UnitMap {
    /// Build the unit mapping for a per-node shape under the arch's fixed
    /// PE dataflow.
    pub fn build(arch: &ArchConfig, shape: LayerShape) -> UnitMap {
        array_mapping(arch.pe_dataflow).build(arch, shape)
    }

    /// Words of the input fmap covering quantity block `q` at node scope.
    pub fn ifm_node_words(&self, q: Qty) -> u64 {
        self.mapping.ifm_node_words(self, q)
    }

    /// Words of the output fmap for quantity block `q` at node scope.
    pub fn ofm_node_words(&self, q: Qty) -> u64 {
        self.mapping.ofm_node_words(self, q)
    }

    /// Words of the weight-role tensor for quantity block `q` (0 if
    /// unweighted). For the back-weight pass this is the streamed dY.
    pub fn wgt_node_words(&self, q: Qty) -> u64 {
        self.mapping.wgt_node_words(self, q)
    }

    /// Total words of all three tensors for block `q` at node scope.
    pub fn node_words(&self, q: Qty) -> u64 {
        self.ifm_node_words(q) + self.ofm_node_words(q) + self.wgt_node_words(q)
    }

    /// Per-PE REGF footprint in words when the REGF-resident block is `q`.
    pub fn regf_pe_words(&self, q: Qty) -> u64 {
        self.mapping.regf_pe_words(self, q)
    }

    /// Clamp a desired block to the per-node totals and align it to granule
    /// multiples (rounding down, min one granule).
    pub fn align_block(&self, q: Qty) -> Qty {
        let mut out = Qty::UNIT;
        for g in crate::directives::Grp::ALL {
            let gran = self.granule.get(g);
            let tot = self.totals.get(g);
            let v = q.get(g).min(tot);
            let aligned = (v / gran).max(1) * gran;
            out.set(g, aligned.min(tot.max(gran)));
        }
        out
    }

    /// MACs per node for this layer shape.
    pub fn node_macs(&self) -> u64 {
        self.shape.macs()
    }

    /// Compute cycles for the whole per-node workload, given the array size
    /// and utilization (roofline compute term).
    pub fn compute_cycles(&self) -> f64 {
        let peak = (self.array.0 * self.array.1) as f64;
        self.shape.macs() as f64 / (peak * self.utilization.max(1e-6))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::workloads::Layer;

    fn conv_shape() -> LayerShape {
        LayerShape::full(&Layer::conv("c", 16, 32, 14, 3, 1), 4)
    }

    #[test]
    fn rs_totals_are_nck() {
        let arch = presets::multi_node_eyeriss();
        let m = UnitMap::build(&arch, conv_shape());
        assert_eq!(m.totals, Qty::new(4, 16, 32));
        assert_eq!(m.granule, Qty::UNIT);
    }

    #[test]
    fn rs_utilization_folding() {
        let arch = presets::multi_node_eyeriss(); // 8x8 array
        // s=3 uses 3 of 8 rows; yo=14 folds over 8 cols: 2 passes covering
        // 14 columns-worth -> util = (3*14)/(2*64)
        let m = UnitMap::build(&arch, conv_shape());
        let expect = (3.0 * 14.0) / (2.0 * 64.0);
        assert!((m.utilization - expect).abs() < 1e-12, "{}", m.utilization);
    }

    #[test]
    fn rs_word_functions() {
        let arch = presets::multi_node_eyeriss();
        let m = UnitMap::build(&arch, conv_shape());
        let q = Qty::new(2, 4, 8);
        assert_eq!(m.ifm_node_words(q), 2 * 4 * 16 * 16);
        assert_eq!(m.ofm_node_words(q), 2 * 8 * 14 * 14);
        assert_eq!(m.wgt_node_words(q), 4 * 8 * 9);
        assert_eq!(m.node_words(q), m.ifm_node_words(q) + m.ofm_node_words(q) + m.wgt_node_words(q));
    }

    #[test]
    fn rs_regf_footprint_grows_monotonically() {
        let arch = presets::multi_node_eyeriss();
        let m = UnitMap::build(&arch, conv_shape());
        let small = m.regf_pe_words(Qty::UNIT);
        let big = m.regf_pe_words(Qty::new(1, 2, 3));
        assert!(small < big);
        // unit footprint: ifm r + wgt r + psum 1 = 3+3+1
        assert_eq!(small, 7);
    }

    #[test]
    fn systolic_granules_pack_reduction() {
        let arch = presets::edge_tpu(); // 16x16 array
        let l = Layer::conv("c", 64, 64, 28, 3, 1);
        let m = UnitMap::build(&arch, LayerShape::full(&l, 1));
        // r*s = 9; 16 rows fit 1 channel (9 <= 16 < 18)
        assert_eq!(m.granule.c, 1);
        assert_eq!(m.granule.k, 16);
        // B counts output rows: n * yo = 28
        assert_eq!(m.totals.b, 28);
    }

    #[test]
    fn systolic_fc_uses_full_rows() {
        let arch = presets::edge_tpu();
        let l = Layer::fc("f", 1024, 256);
        let m = UnitMap::build(&arch, LayerShape::full(&l, 1));
        // r*s = 1: 16 channels per row-fill
        assert_eq!(m.granule.c, 16);
        assert_eq!(m.totals, Qty::new(1, 1024, 256));
        assert!((m.utilization - 1.0).abs() < 1e-12);
    }

    #[test]
    fn systolic_regf_holds_weight_share() {
        let arch = presets::edge_tpu();
        let l = Layer::fc("f", 1024, 256);
        let m = UnitMap::build(&arch, LayerShape::full(&l, 1));
        // block of (c=256, k=64): welems = 16384 over 256 PEs = 64 each,
        // double buffered = 128 + 4 streaming.
        let q = Qty::new(1, 256, 64);
        assert_eq!(m.regf_pe_words(q), 2 * 64 + 4);
    }

    #[test]
    fn align_block_respects_granule_and_totals() {
        let arch = presets::edge_tpu();
        let l = Layer::fc("f", 100, 40);
        let m = UnitMap::build(&arch, LayerShape::full(&l, 2));
        let a = m.align_block(Qty::new(9, 37, 1000));
        assert_eq!(a.b, 2); // clamped to totals
        assert_eq!(a.c % m.granule.c, 0); // granule multiple
        assert!(a.k <= 40);
    }

    #[test]
    fn dwconv_ifm_tracks_k() {
        let arch = presets::multi_node_eyeriss();
        let l = Layer::dwconv("dw", 32, 14, 3, 1);
        let m = UnitMap::build(&arch, LayerShape::full(&l, 1));
        let q = Qty::new(1, 1, 8);
        // ifm words follow K (channels), not the trivial C group.
        assert_eq!(m.ifm_node_words(q), 8 * 16 * 16);
        assert_eq!(m.wgt_node_words(q), 8 * 9);
    }

    #[test]
    fn eltwise_has_no_weights() {
        let arch = presets::multi_node_eyeriss();
        let l = Layer::eltwise("e", 64, 28);
        let m = UnitMap::build(&arch, LayerShape::full(&l, 2));
        assert_eq!(m.wgt_node_words(Qty::new(2, 1, 64)), 0);
    }

    #[test]
    fn compute_cycles_scale_with_macs() {
        let arch = presets::multi_node_eyeriss();
        let m = UnitMap::build(&arch, conv_shape());
        let c = m.compute_cycles();
        assert!(c > 0.0);
        // cycles * active PEs ~= macs
        let active = 64.0 * m.utilization;
        let rel = (c * active - m.shape.macs() as f64).abs() / (m.shape.macs() as f64);
        assert!(rel < 1e-9);
    }

    #[test]
    fn selection_point_matches_arch() {
        assert_eq!(array_mapping(PeDataflow::RowStationary).name(), "row-stationary");
        assert_eq!(array_mapping(PeDataflow::Systolic).name(), "systolic");
        let arch = presets::multi_node_eyeriss();
        let m = UnitMap::build(&arch, conv_shape());
        assert_eq!(m.mapping.name(), array_mapping(arch.pe_dataflow).name());
    }

    #[test]
    fn bwact_shape_mirrors_forward_volumes() {
        // conv: 16 -> 32 channels, 14x14 out, 3x3, stride 1.
        let fwd = LayerShape::full(&Layer::conv("c", 16, 32, 14, 3, 1), 4);
        let bd = LayerShape {
            kind: LayerKind::ConvBwAct,
            n: 4,
            c: 32,
            k: 16,
            xo: fwd.xi(),
            yo: fwd.yi(),
            r: 3,
            s: 3,
            stride: 1,
        };
        assert_eq!((bd.xi(), bd.yi()), (fwd.xo, fwd.yo));
        assert_eq!(bd.macs(), fwd.macs());
        for arch in [presets::multi_node_eyeriss(), presets::edge_tpu()] {
            let mf = UnitMap::build(&arch, fwd);
            let mb = UnitMap::build(&arch, bd);
            // At full blocks, the bd input fmap is the fwd output fmap
            // (row-for-row under either template) and weights transpose.
            let qf = Qty::new(4, 16, 32);
            let qb = Qty::new(4, 32, 16);
            assert_eq!(mb.wgt_node_words(qb), mf.wgt_node_words(qf));
            assert_eq!(
                mb.ifm_node_words(qb) / (4 * 32),
                mb.shape.xi() * if mb.rs_chunk > 0 { mb.shape.yi() } else { mb.shape.s }
            );
        }
    }

    #[test]
    fn dwconv_bwact_tracks_k_like_dwconv() {
        let arch = presets::multi_node_eyeriss();
        let fwd = Layer::dwconv("dw", 32, 14, 3, 1);
        let bd = LayerShape {
            kind: LayerKind::DWConvBwAct,
            n: 1,
            c: 32,
            k: 32,
            xo: fwd.xi(),
            yo: fwd.yi(),
            r: 3,
            s: 3,
            stride: 1,
        };
        let m = UnitMap::build(&arch, bd);
        // Channels ride K: trivial C group, per-channel filters.
        assert_eq!(m.totals.c, 1);
        let q = Qty::new(1, 1, 8);
        assert_eq!(m.wgt_node_words(q), 8 * 9);
        assert_eq!(m.ifm_node_words(q), 8 * bd.xi() * bd.yi());
    }
}
