//! PE-level unit mappings (paper §III-A "PE mapping", §III-C).
//!
//! The lowest-level REGF dataflow is fixed by the hardware template: the
//! Eyeriss-like row-stationary scheme [8] or the TPU-like weight-stationary
//! systolic flow [25]. A `UnitMap` captures everything the upper levels need
//! to know about the PE array:
//!
//! * the *unit tensors* — the per-group granules the bottom-up solver
//!   starts from (paper §IV-C);
//! * the per-node *totals* of each temporal loop group that remain after
//!   the array absorbs its spatial dims;
//! * tensor word-count functions at node scope (for GBUF residency and
//!   traffic) and per-PE REGF footprint functions (for REGF validity);
//! * the spatial utilization of the array after folding.

use crate::arch::{ArchConfig, PeDataflow};
use crate::directives::Qty;
use crate::workloads::{Layer, LayerKind};

/// Per-node view of a layer after node-level partitioning.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LayerShape {
    pub kind: LayerKind,
    /// Per-node batch.
    pub n: u64,
    pub c: u64,
    pub k: u64,
    pub xo: u64,
    pub yo: u64,
    pub r: u64,
    pub s: u64,
    pub stride: u64,
}

impl LayerShape {
    /// Whole-layer shape for batch `n` (no partitioning).
    pub fn full(layer: &Layer, n: u64) -> LayerShape {
        LayerShape {
            kind: layer.kind,
            n: layer.batch(n),
            c: layer.c,
            k: layer.k,
            xo: layer.xo,
            yo: layer.yo,
            r: layer.r,
            s: layer.s,
            stride: layer.stride,
        }
    }

    pub fn xi(&self) -> u64 {
        (self.xo - 1) * self.stride + self.r
    }

    pub fn yi(&self) -> u64 {
        (self.yo - 1) * self.stride + self.s
    }

    /// MACs for this (per-node) shape.
    pub fn macs(&self) -> u64 {
        match self.kind {
            LayerKind::Conv | LayerKind::Fc | LayerKind::ConvBwWeight => {
                self.n * self.k * self.c * self.xo * self.yo * self.r * self.s
            }
            LayerKind::DWConv | LayerKind::Pool => self.n * self.k * self.xo * self.yo * self.r * self.s,
            LayerKind::Eltwise => self.n * self.k * self.xo * self.yo,
        }
    }

    fn has_weights(&self) -> bool {
        matches!(
            self.kind,
            LayerKind::Conv | LayerKind::Fc | LayerKind::DWConv | LayerKind::ConvBwWeight
        )
    }
}

/// Effective C-group extent of a shape: depthwise/pool/eltwise layers carry
/// their channels in the K group, so their C group is trivial.
fn chan_c(shape: LayerShape) -> u64 {
    match shape.kind {
        LayerKind::DWConv | LayerKind::Pool | LayerKind::Eltwise => 1,
        _ => shape.c,
    }
}

/// The PE-array mapping of one layer on one node.
#[derive(Debug, Clone, Copy)]
pub struct UnitMap {
    pub dataflow: PeDataflow,
    /// Per-node layer shape this map was built for.
    pub shape: LayerShape,
    /// PE array dims (cols, rows).
    pub array: (u64, u64),
    /// Temporal loop-group totals per node that remain above the PE array.
    /// B counts images (row-stationary) or output rows (systolic);
    /// C and K count channels.
    pub totals: Qty,
    /// Unit tensor granules per group (the starting block of the bottom-up
    /// solver). Blocks are grown in multiples of these.
    pub granule: Qty,
    /// Fraction of PEs doing useful work (spatial folding efficiency).
    pub utilization: f64,
    /// Row-stationary only: the 1D-conv window chunk held per PE. Filter
    /// rows longer than the REGF allows are folded temporally in chunks
    /// with psum accumulation (Eyeriss handles large filters the same
    /// way); training back-weight layers have filter rows of 27+ taps.
    pub rs_chunk: u64,
}

impl UnitMap {
    /// Build the unit mapping for a per-node shape under the arch's fixed
    /// PE dataflow.
    pub fn build(arch: &ArchConfig, shape: LayerShape) -> UnitMap {
        let array = arch.pes; // (x = cols, y = rows)
        match arch.pe_dataflow {
            PeDataflow::RowStationary => Self::row_stationary(array, shape, arch.regf_words()),
            PeDataflow::Systolic => Self::systolic(array, shape),
        }
    }

    /// Eyeriss row stationary [8]: filter rows (S) across array rows, output
    /// rows (Yo) across array columns, 1D convolution inside each PE. The
    /// whole 2D conv plane of one (n, c, k) triple is one unit pass; fmap
    /// and filter dims are fully absorbed, so the temporal groups above the
    /// array are exactly (N, C, K).
    fn row_stationary(array: (u64, u64), shape: LayerShape, regf_words: u64) -> UnitMap {
        // Largest per-PE window chunk the REGF can hold at the unit block
        // (ifm chunk + wgt chunk + 1 psum <= capacity).
        let rs_chunk = shape.r.min(((regf_words.saturating_sub(1)) / 2).max(1));
        let (cols, rows) = array;
        let used_rows = shape.s.min(rows);
        let used_cols = shape.yo.min(cols);
        // Folding: larger S or Yo time-multiplexes onto the same PEs
        // (Listing 1 line 9, "folding"); utilization counts the active
        // fraction of the array during a unit pass.
        let fold_s = crate::util::ceil_div(shape.s, rows);
        let fold_y = crate::util::ceil_div(shape.yo, cols);
        let full_passes = fold_s * fold_y;
        let active = {
            // average active PEs over folded passes
            let total_work = shape.s * shape.yo;
            total_work as f64 / (full_passes as f64 * (rows * cols) as f64)
        };
        UnitMap {
            dataflow: PeDataflow::RowStationary,
            shape,
            array,
            totals: Qty::new(shape.n, chan_c(shape), shape.k),
            granule: Qty::UNIT,
            utilization: active.min(1.0) * (used_rows * used_cols > 0) as u64 as f64,
            rs_chunk,
        }
    }

    /// TPU-like weight-stationary systolic array [25]: the C*R*S reduction
    /// spreads across array rows and K across columns; output pixels stream
    /// through. One unit pass computes one output *row* (Xo pixels) for the
    /// resident (C-slice, K-slice) weight tile, so the B group counts
    /// n * yo output rows.
    fn systolic(array: (u64, u64), shape: LayerShape) -> UnitMap {
        let (cols, rows) = array;
        let red = shape.r * shape.s; // reduction elems per channel
        let tot_c = chan_c(shape);
        // Channels per weight-tile row-fill: how many C channels fit down
        // the rows at once.
        let c_gran = (rows / red).max(1).min(tot_c);
        let k_gran = cols.min(shape.k);
        let used_rows = (tot_c.min(c_gran) * red).min(rows);
        let used_cols = k_gran;
        let utilization = (used_rows * used_cols) as f64 / (rows * cols) as f64;
        UnitMap {
            dataflow: PeDataflow::Systolic,
            shape,
            array,
            totals: Qty::new(shape.n * shape.yo, tot_c, shape.k),
            granule: Qty::new(1, c_gran, k_gran),
            utilization,
            rs_chunk: 0,
        }
    }

    /// Words of the input fmap covering quantity block `q` at node scope.
    pub fn ifm_node_words(&self, q: Qty) -> u64 {
        let s = &self.shape;
        let chan = match s.kind {
            // DW/pool/eltwise track channels in K (see directives::tensor_groups).
            LayerKind::DWConv | LayerKind::Pool | LayerKind::Eltwise => q.k,
            _ => q.c,
        };
        match self.dataflow {
            // b counts images; a block holds full (xi x yi) planes.
            PeDataflow::RowStationary => q.b * chan * s.xi() * s.yi(),
            // b counts output rows; each needs an (xi x s) input stripe.
            PeDataflow::Systolic => q.b * chan * s.xi() * s.s,
        }
    }

    /// Words of the output fmap for quantity block `q` at node scope.
    pub fn ofm_node_words(&self, q: Qty) -> u64 {
        let s = &self.shape;
        if s.kind == LayerKind::ConvBwWeight {
            // Output is dW (C x K x R x S), batch-invariant.
            return q.c * q.k * s.r * s.s;
        }
        match self.dataflow {
            PeDataflow::RowStationary => q.b * q.k * s.xo * s.yo,
            PeDataflow::Systolic => q.b * q.k * s.xo,
        }
    }

    /// Words of the weight-role tensor for quantity block `q` (0 if
    /// unweighted). For the back-weight pass this is the streamed dY.
    pub fn wgt_node_words(&self, q: Qty) -> u64 {
        let s = &self.shape;
        if !s.has_weights() {
            return 0;
        }
        match s.kind {
            LayerKind::DWConv => q.k * s.r * s.s,
            LayerKind::ConvBwWeight => match self.dataflow {
                PeDataflow::RowStationary => q.b * q.k * s.xo * s.yo,
                PeDataflow::Systolic => q.b * q.k * s.xo,
            },
            _ => q.c * q.k * s.r * s.s,
        }
    }

    /// Total words of all three tensors for block `q` at node scope.
    pub fn node_words(&self, q: Qty) -> u64 {
        self.ifm_node_words(q) + self.ofm_node_words(q) + self.wgt_node_words(q)
    }

    /// Per-PE REGF footprint in words when the REGF-resident block is `q`.
    pub fn regf_pe_words(&self, q: Qty) -> u64 {
        let s = &self.shape;
        match self.dataflow {
            PeDataflow::RowStationary => {
                // Per PE: ifm sliding window + filter-row chunk (rows
                // longer than the REGF fold temporally in `rs_chunk`-tap
                // chunks, accumulating psums) + psum accumulator.
                let w = self.rs_chunk.min(s.r).max(1);
                let chan_i = match s.kind {
                    LayerKind::DWConv | LayerKind::Pool | LayerKind::Eltwise => q.k,
                    _ => q.c,
                };
                let wgt = if s.has_weights() {
                    match s.kind {
                        LayerKind::DWConv => q.k * w,
                        LayerKind::ConvBwWeight => q.b * q.k * w,
                        _ => q.c * q.k * w,
                    }
                } else {
                    0
                };
                let psum = if s.kind == LayerKind::ConvBwWeight { q.c * q.k } else { q.b * q.k };
                q.b * chan_i * w + wgt + psum
            }
            PeDataflow::Systolic => {
                // Per PE: its share of the resident weight tile (double
                // buffered) + streaming input/psum registers.
                let (cols, rows) = self.array;
                let wgt_share = if s.has_weights() {
                    let welems = match s.kind {
                        LayerKind::DWConv => q.k * s.r * s.s,
                        LayerKind::ConvBwWeight => q.b * q.k * s.xo,
                        _ => q.c * q.k * s.r * s.s,
                    };
                    2 * crate::util::ceil_div(welems, rows * cols)
                } else {
                    0
                };
                wgt_share + 4
            }
        }
    }

    /// Clamp a desired block to the per-node totals and align it to granule
    /// multiples (rounding down, min one granule).
    pub fn align_block(&self, q: Qty) -> Qty {
        let mut out = Qty::UNIT;
        for g in crate::directives::Grp::ALL {
            let gran = self.granule.get(g);
            let tot = self.totals.get(g);
            let v = q.get(g).min(tot);
            let aligned = (v / gran).max(1) * gran;
            out.set(g, aligned.min(tot.max(gran)));
        }
        out
    }

    /// MACs per node for this layer shape.
    pub fn node_macs(&self) -> u64 {
        self.shape.macs()
    }

    /// Compute cycles for the whole per-node workload, given the array size
    /// and utilization (roofline compute term).
    pub fn compute_cycles(&self) -> f64 {
        let peak = (self.array.0 * self.array.1) as f64;
        self.shape.macs() as f64 / (peak * self.utilization.max(1e-6))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::workloads::Layer;

    fn conv_shape() -> LayerShape {
        LayerShape::full(&Layer::conv("c", 16, 32, 14, 3, 1), 4)
    }

    #[test]
    fn rs_totals_are_nck() {
        let arch = presets::multi_node_eyeriss();
        let m = UnitMap::build(&arch, conv_shape());
        assert_eq!(m.totals, Qty::new(4, 16, 32));
        assert_eq!(m.granule, Qty::UNIT);
    }

    #[test]
    fn rs_utilization_folding() {
        let arch = presets::multi_node_eyeriss(); // 8x8 array
        // s=3 uses 3 of 8 rows; yo=14 folds over 8 cols: 2 passes covering
        // 14 columns-worth -> util = (3*14)/(2*64)
        let m = UnitMap::build(&arch, conv_shape());
        let expect = (3.0 * 14.0) / (2.0 * 64.0);
        assert!((m.utilization - expect).abs() < 1e-12, "{}", m.utilization);
    }

    #[test]
    fn rs_word_functions() {
        let arch = presets::multi_node_eyeriss();
        let m = UnitMap::build(&arch, conv_shape());
        let q = Qty::new(2, 4, 8);
        assert_eq!(m.ifm_node_words(q), 2 * 4 * 16 * 16);
        assert_eq!(m.ofm_node_words(q), 2 * 8 * 14 * 14);
        assert_eq!(m.wgt_node_words(q), 4 * 8 * 9);
        assert_eq!(m.node_words(q), m.ifm_node_words(q) + m.ofm_node_words(q) + m.wgt_node_words(q));
    }

    #[test]
    fn rs_regf_footprint_grows_monotonically() {
        let arch = presets::multi_node_eyeriss();
        let m = UnitMap::build(&arch, conv_shape());
        let small = m.regf_pe_words(Qty::UNIT);
        let big = m.regf_pe_words(Qty::new(1, 2, 3));
        assert!(small < big);
        // unit footprint: ifm r + wgt r + psum 1 = 3+3+1
        assert_eq!(small, 7);
    }

    #[test]
    fn systolic_granules_pack_reduction() {
        let arch = presets::edge_tpu(); // 16x16 array
        let l = Layer::conv("c", 64, 64, 28, 3, 1);
        let m = UnitMap::build(&arch, LayerShape::full(&l, 1));
        // r*s = 9; 16 rows fit 1 channel (9 <= 16 < 18)
        assert_eq!(m.granule.c, 1);
        assert_eq!(m.granule.k, 16);
        // B counts output rows: n * yo = 28
        assert_eq!(m.totals.b, 28);
    }

    #[test]
    fn systolic_fc_uses_full_rows() {
        let arch = presets::edge_tpu();
        let l = Layer::fc("f", 1024, 256);
        let m = UnitMap::build(&arch, LayerShape::full(&l, 1));
        // r*s = 1: 16 channels per row-fill
        assert_eq!(m.granule.c, 16);
        assert_eq!(m.totals, Qty::new(1, 1024, 256));
        assert!((m.utilization - 1.0).abs() < 1e-12);
    }

    #[test]
    fn systolic_regf_holds_weight_share() {
        let arch = presets::edge_tpu();
        let l = Layer::fc("f", 1024, 256);
        let m = UnitMap::build(&arch, LayerShape::full(&l, 1));
        // block of (c=256, k=64): welems = 16384 over 256 PEs = 64 each,
        // double buffered = 128 + 4 streaming.
        let q = Qty::new(1, 256, 64);
        assert_eq!(m.regf_pe_words(q), 2 * 64 + 4);
    }

    #[test]
    fn align_block_respects_granule_and_totals() {
        let arch = presets::edge_tpu();
        let l = Layer::fc("f", 100, 40);
        let m = UnitMap::build(&arch, LayerShape::full(&l, 2));
        let a = m.align_block(Qty::new(9, 37, 1000));
        assert_eq!(a.b, 2); // clamped to totals
        assert_eq!(a.c % m.granule.c, 0); // granule multiple
        assert!(a.k <= 40);
    }

    #[test]
    fn dwconv_ifm_tracks_k() {
        let arch = presets::multi_node_eyeriss();
        let l = Layer::dwconv("dw", 32, 14, 3, 1);
        let m = UnitMap::build(&arch, LayerShape::full(&l, 1));
        let q = Qty::new(1, 1, 8);
        // ifm words follow K (channels), not the trivial C group.
        assert_eq!(m.ifm_node_words(q), 8 * 16 * 16);
        assert_eq!(m.wgt_node_words(q), 8 * 9);
    }

    #[test]
    fn eltwise_has_no_weights() {
        let arch = presets::multi_node_eyeriss();
        let l = Layer::eltwise("e", 64, 28);
        let m = UnitMap::build(&arch, LayerShape::full(&l, 2));
        assert_eq!(m.wgt_node_words(Qty::new(2, 1, 64)), 0);
    }

    #[test]
    fn compute_cycles_scale_with_macs() {
        let arch = presets::multi_node_eyeriss();
        let m = UnitMap::build(&arch, conv_shape());
        let c = m.compute_cycles();
        assert!(c > 0.0);
        // cycles * active PEs ~= macs
        let active = 64.0 * m.utilization;
        let rel = (c * active - m.shape.macs() as f64).abs() / (m.shape.macs() as f64);
        assert!(rel < 1e-9);
    }
}
