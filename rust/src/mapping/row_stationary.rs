//! Eyeriss-like row-stationary array mapping [8] (paper §III-A, §III-C).
//!
//! Filter rows (S) spread across array rows, output rows (Yo) across array
//! columns, and each PE runs a 1D convolution over one filter row. The
//! whole 2D conv plane of one (n, c, k) triple is one unit pass; fmap and
//! filter dims are fully absorbed, so the temporal groups above the array
//! are exactly (N, C, K).

use super::{chan_c, chan_in_k, ArrayMapping, LayerShape, UnitMap};
use crate::arch::ArchConfig;
use crate::directives::emit::{chan_view, tensor_line};
use crate::directives::{LayerScheme, Qty};
use crate::workloads::LayerKind;
use std::fmt::Write as _;

/// The row-stationary template. Stateless: every per-layer quantity lives
/// in the `UnitMap` it builds.
#[derive(Debug, Clone, Copy)]
pub struct RowStationary;

impl ArrayMapping for RowStationary {
    fn name(&self) -> &'static str {
        "row-stationary"
    }

    fn build(&'static self, arch: &ArchConfig, shape: LayerShape) -> UnitMap {
        let array = arch.pes; // (x = cols, y = rows)
        // Largest per-PE window chunk the REGF can hold at the unit block
        // (ifm chunk + wgt chunk + 1 psum <= capacity). Filter rows longer
        // than the REGF allows fold temporally in chunks with psum
        // accumulation (Eyeriss handles large filters the same way);
        // training back-weight layers have filter rows of 27+ taps.
        let rs_chunk = shape.r.min(((arch.regf_words().saturating_sub(1)) / 2).max(1));
        let (cols, rows) = array;
        let used_rows = shape.s.min(rows);
        let used_cols = shape.yo.min(cols);
        // Folding: larger S or Yo time-multiplexes onto the same PEs
        // (Listing 1 line 9, "folding"); utilization counts the active
        // fraction of the array during a unit pass.
        let fold_s = crate::util::ceil_div(shape.s, rows);
        let fold_y = crate::util::ceil_div(shape.yo, cols);
        let full_passes = fold_s * fold_y;
        let active = {
            // average active PEs over folded passes
            let total_work = shape.s * shape.yo;
            total_work as f64 / (full_passes as f64 * (rows * cols) as f64)
        };
        UnitMap {
            mapping: self,
            shape,
            array,
            totals: Qty::new(shape.n, chan_c(shape), shape.k),
            granule: Qty::UNIT,
            utilization: active.min(1.0) * (used_rows * used_cols > 0) as u64 as f64,
            rs_chunk,
        }
    }

    fn ifm_node_words(&self, u: &UnitMap, q: Qty) -> u64 {
        let s = &u.shape;
        let chan = if chan_in_k(s.kind) { q.k } else { q.c };
        // b counts images; a block holds full (xi x yi) planes.
        q.b * chan * s.xi() * s.yi()
    }

    fn ofm_node_words(&self, u: &UnitMap, q: Qty) -> u64 {
        let s = &u.shape;
        if s.kind == LayerKind::ConvBwWeight {
            // Output is dW (C x K x R x S), batch-invariant.
            return q.c * q.k * s.r * s.s;
        }
        q.b * q.k * s.xo * s.yo
    }

    fn wgt_node_words(&self, u: &UnitMap, q: Qty) -> u64 {
        let s = &u.shape;
        if !s.has_weights() {
            return 0;
        }
        match s.kind {
            LayerKind::DWConv | LayerKind::DWConvBwAct => q.k * s.r * s.s,
            LayerKind::ConvBwWeight => q.b * q.k * s.xo * s.yo,
            _ => q.c * q.k * s.r * s.s,
        }
    }

    fn regf_pe_words(&self, u: &UnitMap, q: Qty) -> u64 {
        let s = &u.shape;
        // Per PE: ifm sliding window + filter-row chunk (rows longer than
        // the REGF fold temporally in `rs_chunk`-tap chunks, accumulating
        // psums) + psum accumulator.
        let w = u.rs_chunk.min(s.r).max(1);
        let chan_i = if chan_in_k(s.kind) { q.k } else { q.c };
        let wgt = if s.has_weights() {
            match s.kind {
                LayerKind::DWConv | LayerKind::DWConvBwAct => q.k * w,
                LayerKind::ConvBwWeight => q.b * q.k * w,
                _ => q.c * q.k * w,
            }
        } else {
            0
        };
        let psum = if s.kind == LayerKind::ConvBwWeight { q.c * q.k } else { q.b * q.k };
        q.b * chan_i * w + wgt + psum
    }

    fn gbuf_fmap_rows(&self, shape: &LayerShape) -> (u64, u64) {
        // Full fmap planes are GBUF-resident per batch image.
        (shape.yi(), shape.yo)
    }

    fn emit_regf(&self, out: &mut String, name: &str, s: &LayerScheme) {
        let sh = &s.unit.shape;
        let q = s.regf.qty;
        let (ci, ki) = chan_view(s, q);
        let emit = tensor_line;
        emit(out, &format!("{name}_i"), &[("N", q.b), ("C", ci), ("Xi", sh.r), ("Yi", 1)], 1);
        if s.unit.wgt_node_words(Qty::UNIT) > 0 {
            let w = s.unit.rs_chunk.min(sh.r).max(1);
            match sh.kind {
                // One filter per channel: the C axis of the wgt tensor is
                // trivial (channels ride the K group).
                LayerKind::DWConv | LayerKind::DWConvBwAct => {
                    emit(out, &format!("{name}_w"), &[("C", 1), ("K", ki), ("R", sh.r), ("S", 1)], 1)
                }
                // The streamed "filter" is dY: batch x K output rows of
                // `w` taps each.
                LayerKind::ConvBwWeight => {
                    emit(out, &format!("{name}_w"), &[("N", q.b), ("K", ki), ("Xo", w), ("Yo", 1)], 1)
                }
                _ => emit(out, &format!("{name}_w"), &[("C", ci), ("K", ki), ("R", sh.r), ("S", 1)], 1),
            }
        }
        emit(out, &format!("{name}_o"), &[("N", q.b), ("K", ki), ("Xo", 1), ("Yo", 1)], 1);
        let cols = s.unit.array.0.min(sh.yo);
        let rows = s.unit.array.1.min(sh.s);
        let _ = writeln!(out, "    stack(Yi+=1, Yo+=1, {cols}) % PE columns");
        let _ = writeln!(out, "    stack(S+=1, Yi+=1, {rows}) % PE rows");
        let _ = writeln!(out, "    update(Xi+={}, Xo+=1) % 1D conv", sh.stride);
        if sh.yo > cols {
            let _ = writeln!(out, "    update(Yi+={c}, Yo+={c}) % folding", c = cols);
        }
    }

    fn batch_dim_label(&self, _kind: LayerKind) -> &'static str {
        // The B group always counts images under row-stationary.
        "N"
    }
}
