//! TPU-like weight-stationary systolic array mapping [25] (paper §III-C).
//!
//! The C*R*S reduction spreads across array rows and K across columns;
//! output pixels stream through. One unit pass computes one output *row*
//! (Xo pixels) for the resident (C-slice, K-slice) weight tile, so the B
//! group counts n * yo output rows.

use super::{chan_c, chan_in_k, ArrayMapping, LayerShape, UnitMap};
use crate::arch::ArchConfig;
use crate::directives::emit::{chan_view, tensor_line};
use crate::directives::{LayerScheme, Qty};
use crate::workloads::LayerKind;
use std::fmt::Write as _;

/// The weight-stationary systolic template. Stateless: every per-layer
/// quantity lives in the `UnitMap` it builds.
#[derive(Debug, Clone, Copy)]
pub struct Systolic;

impl ArrayMapping for Systolic {
    fn name(&self) -> &'static str {
        "systolic"
    }

    fn build(&'static self, arch: &ArchConfig, shape: LayerShape) -> UnitMap {
        let array = arch.pes; // (x = cols, y = rows)
        let (cols, rows) = array;
        let red = shape.r * shape.s; // reduction elems per channel
        let tot_c = chan_c(shape);
        // Channels per weight-tile row-fill: how many C channels fit down
        // the rows at once.
        let c_gran = (rows / red).max(1).min(tot_c);
        let k_gran = cols.min(shape.k);
        let used_rows = (tot_c.min(c_gran) * red).min(rows);
        let used_cols = k_gran;
        let utilization = (used_rows * used_cols) as f64 / (rows * cols) as f64;
        UnitMap {
            mapping: self,
            shape,
            array,
            totals: Qty::new(shape.n * shape.yo, tot_c, shape.k),
            granule: Qty::new(1, c_gran, k_gran),
            utilization,
            rs_chunk: 0,
        }
    }

    fn ifm_node_words(&self, u: &UnitMap, q: Qty) -> u64 {
        let s = &u.shape;
        let chan = if chan_in_k(s.kind) { q.k } else { q.c };
        // b counts output rows; each needs an (xi x s) input stripe.
        q.b * chan * s.xi() * s.s
    }

    fn ofm_node_words(&self, u: &UnitMap, q: Qty) -> u64 {
        let s = &u.shape;
        if s.kind == LayerKind::ConvBwWeight {
            // Output is dW (C x K x R x S), batch-invariant.
            return q.c * q.k * s.r * s.s;
        }
        q.b * q.k * s.xo
    }

    fn wgt_node_words(&self, u: &UnitMap, q: Qty) -> u64 {
        let s = &u.shape;
        if !s.has_weights() {
            return 0;
        }
        match s.kind {
            LayerKind::DWConv | LayerKind::DWConvBwAct => q.k * s.r * s.s,
            LayerKind::ConvBwWeight => q.b * q.k * s.xo,
            _ => q.c * q.k * s.r * s.s,
        }
    }

    fn regf_pe_words(&self, u: &UnitMap, q: Qty) -> u64 {
        let s = &u.shape;
        // Per PE: its share of the resident weight tile (double buffered)
        // + streaming input/psum registers.
        let (cols, rows) = u.array;
        let wgt_share = if s.has_weights() {
            let welems = match s.kind {
                LayerKind::DWConv | LayerKind::DWConvBwAct => q.k * s.r * s.s,
                LayerKind::ConvBwWeight => q.b * q.k * s.xo,
                _ => q.c * q.k * s.r * s.s,
            };
            2 * crate::util::ceil_div(welems, rows * cols)
        } else {
            0
        };
        wgt_share + 4
    }

    fn gbuf_fmap_rows(&self, shape: &LayerShape) -> (u64, u64) {
        // Only the input stripe feeding one output row stays GBUF-resident.
        (shape.s, 1)
    }

    fn emit_regf(&self, out: &mut String, name: &str, s: &LayerScheme) {
        let sh = &s.unit.shape;
        let q = s.regf.qty;
        let (ci, ki) = chan_view(s, q);
        tensor_line(out, &format!("{name}_i"), &[("N", q.b), ("C", ci), ("Xi", sh.xi()), ("Yi", sh.s)], 1);
        if s.unit.wgt_node_words(Qty::UNIT) > 0 {
            match sh.kind {
                // One filter per channel: the C axis of the wgt tensor is
                // trivial (channels ride the K group).
                LayerKind::DWConv | LayerKind::DWConvBwAct => {
                    tensor_line(out, &format!("{name}_w"), &[("C", 1), ("K", ki), ("R", sh.r), ("S", sh.s)], 1)
                }
                // The streamed "filter" is dY: batch x K rows of Xo pixels.
                LayerKind::ConvBwWeight => {
                    tensor_line(out, &format!("{name}_w"), &[("N", q.b), ("K", ki), ("Xo", sh.xo), ("Yo", 1)], 1)
                }
                _ => tensor_line(out, &format!("{name}_w"), &[("C", ci), ("K", ki), ("R", sh.r), ("S", sh.s)], 1),
            }
        }
        tensor_line(out, &format!("{name}_o"), &[("N", q.b), ("K", ki), ("Xo", sh.xo), ("Yo", 1)], 1);
        let rows = (s.unit.granule.c * sh.r * sh.s).min(s.unit.array.1);
        let cols = s.unit.granule.k.min(s.unit.array.0);
        let _ = writeln!(out, "    stack(C+=1, {rows}) % systolic rows (reduction)");
        let _ = writeln!(out, "    stack(K+=1, {cols}) % systolic cols");
        let _ = writeln!(out, "    update(Xi+={}, Xo+=1) % pixel stream", sh.stride);
    }

    fn batch_dim_label(&self, kind: LayerKind) -> &'static str {
        match kind {
            // FC fmaps are 1x1: the output-row stream is pure batch.
            LayerKind::Fc => "N",
            _ => "N*Yo",
        }
    }
}
