//! Detailed dataflow simulator — the evaluation oracle (paper §V).
//!
//! The paper evaluates every solver's resulting schedule on the nn-dataflow
//! simulator [16], [17] (validated against cycle-accurate simulation and
//! real Eyeriss measurements). We rebuild the same analytical methodology:
//! energy is assembled from per-level access counts times per-access costs
//! (McPAT-style SRAM table, 1 pJ MAC, 0.61 pJ/bit/hop NoC, LPDDR4 DRAM),
//! and latency from a roofline over compute, DRAM bandwidth, GBUF ports and
//! the NoC, with pipeline fill/drain for spatial inter-layer segments.
//!
//! Note this is deliberately a *different, more detailed* model than
//! KAPLA's fast cost estimator in `cost/` — the same separation the paper
//! maintains (§V "this is a different, much more detailed and accurate
//! cost model compared to that in KAPLA").

pub mod pipeline;

use crate::arch::{energy as earch, ArchConfig};
use crate::directives::scheme::AccessCounts;
use crate::directives::LayerScheme;

/// Energy by hardware component, in pJ (the paper's Fig. 7 breakdown).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnergyBreakdown {
    pub alu_pj: f64,
    pub regf_pj: f64,
    pub bus_pj: f64,
    pub gbuf_pj: f64,
    pub noc_pj: f64,
    pub dram_pj: f64,
}

impl EnergyBreakdown {
    pub fn total(&self) -> f64 {
        self.alu_pj + self.regf_pj + self.bus_pj + self.gbuf_pj + self.noc_pj + self.dram_pj
    }

    pub fn add(&mut self, other: &EnergyBreakdown) {
        self.alu_pj += other.alu_pj;
        self.regf_pj += other.regf_pj;
        self.bus_pj += other.bus_pj;
        self.gbuf_pj += other.gbuf_pj;
        self.noc_pj += other.noc_pj;
        self.dram_pj += other.dram_pj;
    }

    pub fn scale(&self, f: f64) -> EnergyBreakdown {
        EnergyBreakdown {
            alu_pj: self.alu_pj * f,
            regf_pj: self.regf_pj * f,
            bus_pj: self.bus_pj * f,
            gbuf_pj: self.gbuf_pj * f,
            noc_pj: self.noc_pj * f,
            dram_pj: self.dram_pj * f,
        }
    }
}

/// Full evaluation of one layer under one scheme.
#[derive(Debug, Clone, Copy)]
pub struct LayerEval {
    pub energy: EnergyBreakdown,
    /// Latency in cycles (roofline, double-buffered overlap).
    pub latency_cycles: f64,
    pub access: AccessCounts,
    /// PE-array compute cycles (per node, all nodes parallel).
    pub compute_cycles: f64,
    /// DRAM-bandwidth-bound cycles.
    pub dram_cycles: f64,
}

/// Evaluate one layer's scheme on the detailed model.
pub fn evaluate_layer(arch: &ArchConfig, s: &LayerScheme, ifm_on_chip: bool) -> LayerEval {
    let a = s.access_counts(ifm_on_chip);
    let energy = energy_of(arch, &a);

    let nodes = s.part.used_nodes().max(1);
    let compute_cycles = s.unit.compute_cycles();
    let dram_cycles = a.dram_total() as f64 / arch.dram_words_per_cycle();
    let gbuf_cycles = (a.gbuf_total() as f64 / nodes as f64) / arch.gbuf.words_per_cycle;
    let noc_cycles = (a.noc_word_hops / nodes as f64) / arch.noc_words_per_cycle;
    let latency_cycles = compute_cycles.max(dram_cycles).max(gbuf_cycles).max(noc_cycles);

    LayerEval { energy, latency_cycles, access: a, compute_cycles, dram_cycles }
}

/// Assemble component energy from access counts.
pub fn energy_of(arch: &ArchConfig, a: &AccessCounts) -> EnergyBreakdown {
    EnergyBreakdown {
        alu_pj: a.macs as f64 * arch.mac_pj,
        regf_pj: a.regf as f64 * arch.regf.pj_per_word,
        bus_pj: a.gbuf_regf_side as f64 * earch::pe_bus_pj_per_word(),
        gbuf_pj: a.gbuf_total() as f64 * arch.gbuf.pj_per_word,
        noc_pj: a.noc_word_hops * arch.noc_pj_per_word(1.0),
        dram_pj: a.dram_total() as f64 * arch.dram.pj_per_word,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::directives::{Grp, LevelBlock, LoopOrder, Qty};
    use crate::mapping::UnitMap;
    use crate::partition::PartitionScheme;
    use crate::workloads::Layer;

    fn scheme(part: PartitionScheme, layer: &Layer, batch: u64) -> LayerScheme {
        let arch = presets::multi_node_eyeriss();
        let unit = UnitMap::build(&arch, part.node_shape(layer, batch));
        LayerScheme {
            part,
            unit,
            regf: LevelBlock { qty: Qty::new(1, 2, 2), order: LoopOrder([Grp::B, Grp::K, Grp::C]) },
            gbuf: LevelBlock { qty: Qty::new(1, 8, 8), order: LoopOrder([Grp::B, Grp::C, Grp::K]) },
        }
    }

    #[test]
    fn energy_components_positive_and_sum() {
        let arch = presets::multi_node_eyeriss();
        let l = Layer::conv("c", 64, 64, 28, 3, 1);
        let e = evaluate_layer(&arch, &scheme(PartitionScheme::single(), &l, 4), false);
        let b = e.energy;
        for (name, v) in [
            ("alu", b.alu_pj),
            ("regf", b.regf_pj),
            ("bus", b.bus_pj),
            ("gbuf", b.gbuf_pj),
            ("noc", b.noc_pj),
            ("dram", b.dram_pj),
        ] {
            assert!(v > 0.0, "{name} = {v}");
        }
        let total = b.total();
        let sum = b.alu_pj + b.regf_pj + b.bus_pj + b.gbuf_pj + b.noc_pj + b.dram_pj;
        assert!((total - sum).abs() < 1e-6);
    }

    #[test]
    fn alu_energy_is_exactly_macs() {
        let arch = presets::multi_node_eyeriss();
        let l = Layer::conv("c", 16, 16, 14, 3, 1);
        let e = evaluate_layer(&arch, &scheme(PartitionScheme::single(), &l, 2), false);
        assert_eq!(e.energy.alu_pj, l.macs(2) as f64 * arch.mac_pj);
    }

    #[test]
    fn latency_is_roofline_max() {
        let arch = presets::multi_node_eyeriss();
        let l = Layer::conv("c", 64, 64, 28, 3, 1);
        let e = evaluate_layer(&arch, &scheme(PartitionScheme::single(), &l, 4), false);
        assert!(e.latency_cycles >= e.compute_cycles);
        assert!(e.latency_cycles >= e.dram_cycles);
    }

    #[test]
    fn partitioning_speeds_up_compute() {
        let arch = presets::multi_node_eyeriss();
        let l = Layer::conv("c", 64, 128, 28, 3, 1);
        let single = evaluate_layer(&arch, &scheme(PartitionScheme::single(), &l, 16), false);
        let part = PartitionScheme { region: (4, 4), pk: 4, pn: 4, ..PartitionScheme::single() };
        let multi = evaluate_layer(&arch, &scheme(part, &l, 16), false);
        assert!(multi.compute_cycles < single.compute_cycles / 8.0);
    }

    #[test]
    fn pipelined_input_cuts_dram_energy() {
        let arch = presets::multi_node_eyeriss();
        let l = Layer::conv("c", 64, 64, 28, 3, 1);
        let s = scheme(PartitionScheme::single(), &l, 4);
        let off = evaluate_layer(&arch, &s, false);
        let on = evaluate_layer(&arch, &s, true);
        assert!(on.energy.dram_pj < off.energy.dram_pj);
        // On a 1x1 region the forward hop equals the DRAM distribution hop,
        // so NoC energy is unchanged; it must never decrease.
        assert!(on.energy.noc_pj >= off.energy.noc_pj);
    }

    #[test]
    fn breakdown_add_and_scale() {
        let mut a = EnergyBreakdown { alu_pj: 1.0, regf_pj: 2.0, ..Default::default() };
        let b = EnergyBreakdown { alu_pj: 3.0, dram_pj: 4.0, ..Default::default() };
        a.add(&b);
        assert_eq!(a.alu_pj, 4.0);
        assert_eq!(a.dram_pj, 4.0);
        let s = a.scale(0.5);
        assert_eq!(s.alu_pj, 2.0);
        assert!((s.total() - a.total() * 0.5).abs() < 1e-12);
    }
}
