//! Detailed dataflow simulator — the evaluation oracle (paper §V).
//!
//! The paper evaluates every solver's resulting schedule on the nn-dataflow
//! simulator [16], [17] (validated against cycle-accurate simulation and
//! real Eyeriss measurements). We rebuild the same analytical methodology:
//! energy is assembled from per-level access counts times per-access costs
//! (McPAT-style SRAM table, 1 pJ MAC, 0.61 pJ/bit/hop NoC, LPDDR4 DRAM),
//! and latency from a roofline over compute, DRAM bandwidth, GBUF ports and
//! the NoC, with pipeline fill/drain for spatial inter-layer segments.
//!
//! Note this is deliberately a *different, more detailed* model than
//! KAPLA's fast cost estimator in `cost/` — the same separation the paper
//! maintains (§V "this is a different, much more detailed and accurate
//! cost model compared to that in KAPLA").

pub mod pipeline;

use crate::arch::{energy as earch, ArchConfig};
use crate::cost::CostEstimate;
use crate::directives::scheme::AccessCounts;
use crate::directives::{GbufAccess, LayerScheme, LoopOrder, PartAccess, Qty};
use crate::mapping::UnitMap;
use crate::partition::PartitionScheme;

/// Energy by hardware component, in pJ (the paper's Fig. 7 breakdown).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnergyBreakdown {
    pub alu_pj: f64,
    pub regf_pj: f64,
    pub bus_pj: f64,
    pub gbuf_pj: f64,
    pub noc_pj: f64,
    pub dram_pj: f64,
}

impl EnergyBreakdown {
    pub fn total(&self) -> f64 {
        self.alu_pj + self.regf_pj + self.bus_pj + self.gbuf_pj + self.noc_pj + self.dram_pj
    }

    pub fn add(&mut self, other: &EnergyBreakdown) {
        self.alu_pj += other.alu_pj;
        self.regf_pj += other.regf_pj;
        self.bus_pj += other.bus_pj;
        self.gbuf_pj += other.gbuf_pj;
        self.noc_pj += other.noc_pj;
        self.dram_pj += other.dram_pj;
    }

    pub fn scale(&self, f: f64) -> EnergyBreakdown {
        EnergyBreakdown {
            alu_pj: self.alu_pj * f,
            regf_pj: self.regf_pj * f,
            bus_pj: self.bus_pj * f,
            gbuf_pj: self.gbuf_pj * f,
            noc_pj: self.noc_pj * f,
            dram_pj: self.dram_pj * f,
        }
    }
}

/// Full evaluation of one layer under one scheme.
#[derive(Debug, Clone, Copy)]
pub struct LayerEval {
    pub energy: EnergyBreakdown,
    /// Latency in cycles (roofline, double-buffered overlap).
    pub latency_cycles: f64,
    pub access: AccessCounts,
    /// PE-array compute cycles (per node, all nodes parallel).
    pub compute_cycles: f64,
    /// DRAM-bandwidth-bound cycles.
    pub dram_cycles: f64,
}

/// Evaluate one layer's scheme on the detailed model. One-shot wrapper
/// over the staged path: `access_counts` runs the staged calculus end to
/// end and [`eval_from_counts`] is the same assembly [`StagedGbuf::eval`]
/// uses, so this and a [`StagedEval`] walk of the same scheme are
/// bit-identical by construction.
pub fn evaluate_layer(arch: &ArchConfig, s: &LayerScheme, ifm_on_chip: bool) -> LayerEval {
    let a = s.access_counts(ifm_on_chip);
    let nodes = s.part.used_nodes().max(1);
    eval_from_counts(arch, nodes, s.unit.compute_cycles(), a)
}

/// Assemble energy and the latency roofline from finished access counts —
/// shared by the one-shot [`evaluate_layer`] and the staged evaluator.
pub fn eval_from_counts(
    arch: &ArchConfig,
    nodes: u64,
    compute_cycles: f64,
    a: AccessCounts,
) -> LayerEval {
    let energy = energy_of(arch, &a);
    let dram_cycles = a.dram_total() as f64 / arch.dram_words_per_cycle();
    let gbuf_cycles = (a.gbuf_total() as f64 / nodes as f64) / arch.gbuf.words_per_cycle;
    let noc_cycles = (a.noc_word_hops / nodes as f64) / arch.noc_words_per_cycle;
    let latency_cycles = compute_cycles.max(dram_cycles).max(gbuf_cycles).max(noc_cycles);
    LayerEval { energy, latency_cycles, access: a, compute_cycles, dram_cycles }
}

/// Staged detailed evaluation of one `(part, unit)` enumeration prefix
/// (the tentpole of the staged/branch-and-bound search): stage 1 is frozen
/// at construction, [`StagedEval::gbuf`] freezes the DRAM/NoC stage for a
/// `(gbuf block, gbuf order)` prefix, and [`StagedGbuf::eval`] finishes a
/// candidate with only the GBUF<->REGF suffix arithmetic. All three stages
/// are the exact code `evaluate_layer` runs, so every staged result is
/// bit-identical to the one-shot evaluation of the same scheme.
#[derive(Debug, Clone, Copy)]
pub struct StagedEval<'a> {
    arch: &'a ArchConfig,
    part: PartAccess,
    ifm_on_chip: bool,
    /// `used_nodes().max(1)` — the latency divisor of `evaluate_layer`.
    nodes: u64,
    compute_cycles: f64,
}

impl<'a> StagedEval<'a> {
    pub fn new(
        arch: &'a ArchConfig,
        part: PartitionScheme,
        unit: UnitMap,
        ifm_on_chip: bool,
    ) -> StagedEval<'a> {
        StagedEval {
            arch,
            part: PartAccess::new(part, unit),
            ifm_on_chip,
            nodes: part.used_nodes().max(1),
            compute_cycles: unit.compute_cycles(),
        }
    }

    /// Freeze stage 2 for one `(gbuf block, gbuf order)` prefix.
    pub fn gbuf(&self, gq: Qty, go: LoopOrder) -> StagedGbuf<'a> {
        StagedGbuf {
            arch: self.arch,
            nodes: self.nodes,
            compute_cycles: self.compute_cycles,
            g: self.part.gbuf(gq, go, self.ifm_on_chip),
        }
    }

    /// Admissible lower bound on the detailed cost of *every* completion
    /// of the `(part, gbuf block)` prefix — any gbuf/regf order, any REGF
    /// block: the order-independent stage-2 floor composed with the
    /// one-drain-pass stage-3 floor, pushed through the same monotone
    /// energy/latency assembly. `bound <= evaluate` for every realizable
    /// completion extends the estimate-tier admissibility property to
    /// prefixes (`tests/staged_eval_equivalence.rs`), which is what makes
    /// branch-and-bound subtree pruning exact.
    pub fn bound_prefix(&self, gq: Qty) -> CostEstimate {
        let a = self.part.gbuf_floor(gq, self.ifm_on_chip).counts_floor();
        let ev = eval_from_counts(self.arch, self.nodes, self.compute_cycles, a);
        CostEstimate { energy_pj: ev.energy.total(), latency_cycles: ev.latency_cycles }
    }

    /// Admissible lower bound over *every* blocking of this `(part, unit)`
    /// prefix — the partition level of the bound hierarchy, one level above
    /// [`StagedEval::bound_prefix`]. It is the floor chain evaluated at
    /// `gq == totals` (one trip per group, whole tensors resident, single
    /// drain pass); `PartAccess::partition_floor` carries the per-stream
    /// domination argument, and `eval_from_counts` is monotone in every
    /// stream while MACs and compute cycles are constants of the prefix, so
    /// `bound_partition() <= bound_prefix(gq) <= evaluate(completion)` for
    /// every realizable `(gq, go, rq, ro)`. Checking it before the blocking
    /// loops lets the branch-and-bound scan skip whole partitions exactly.
    pub fn bound_partition(&self) -> CostEstimate {
        let a = self.part.partition_floor(self.ifm_on_chip);
        let ev = eval_from_counts(self.arch, self.nodes, self.compute_cycles, a);
        CostEstimate { energy_pj: ev.energy.total(), latency_cycles: ev.latency_cycles }
    }
}

/// Stages 1+2 frozen; only the REGF-level suffix left to evaluate.
#[derive(Debug, Clone, Copy)]
pub struct StagedGbuf<'a> {
    arch: &'a ArchConfig,
    nodes: u64,
    compute_cycles: f64,
    g: GbufAccess,
}

impl StagedGbuf<'_> {
    /// Finish one `(regf block, regf order)` candidate — bit-identical to
    /// `evaluate_layer` on the corresponding full scheme.
    pub fn eval(&self, rq: Qty, ro: LoopOrder) -> LayerEval {
        eval_from_counts(self.arch, self.nodes, self.compute_cycles, self.g.counts(rq, ro))
    }

    /// [`StagedGbuf::eval`] projected to the `CostEstimate` the solvers
    /// score with (exactly what `CostModel::evaluate` reports).
    pub fn cost(&self, rq: Qty, ro: LoopOrder) -> CostEstimate {
        let ev = self.eval(rq, ro);
        CostEstimate { energy_pj: ev.energy.total(), latency_cycles: ev.latency_cycles }
    }
}

/// Assemble component energy from access counts.
pub fn energy_of(arch: &ArchConfig, a: &AccessCounts) -> EnergyBreakdown {
    EnergyBreakdown {
        alu_pj: a.macs as f64 * arch.mac_pj,
        regf_pj: a.regf as f64 * arch.regf.pj_per_word,
        bus_pj: a.gbuf_regf_side as f64 * earch::pe_bus_pj_per_word(),
        gbuf_pj: a.gbuf_total() as f64 * arch.gbuf.pj_per_word,
        noc_pj: a.noc_word_hops * arch.noc_pj_per_word(1.0),
        dram_pj: a.dram_total() as f64 * arch.dram.pj_per_word,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::directives::{Grp, LevelBlock, LoopOrder, Qty};
    use crate::mapping::UnitMap;
    use crate::partition::PartitionScheme;
    use crate::workloads::Layer;

    fn scheme(part: PartitionScheme, layer: &Layer, batch: u64) -> LayerScheme {
        let arch = presets::multi_node_eyeriss();
        let unit = UnitMap::build(&arch, part.node_shape(layer, batch));
        LayerScheme {
            part,
            unit,
            regf: LevelBlock { qty: Qty::new(1, 2, 2), order: LoopOrder([Grp::B, Grp::K, Grp::C]) },
            gbuf: LevelBlock { qty: Qty::new(1, 8, 8), order: LoopOrder([Grp::B, Grp::C, Grp::K]) },
        }
    }

    #[test]
    fn energy_components_positive_and_sum() {
        let arch = presets::multi_node_eyeriss();
        let l = Layer::conv("c", 64, 64, 28, 3, 1);
        let e = evaluate_layer(&arch, &scheme(PartitionScheme::single(), &l, 4), false);
        let b = e.energy;
        for (name, v) in [
            ("alu", b.alu_pj),
            ("regf", b.regf_pj),
            ("bus", b.bus_pj),
            ("gbuf", b.gbuf_pj),
            ("noc", b.noc_pj),
            ("dram", b.dram_pj),
        ] {
            assert!(v > 0.0, "{name} = {v}");
        }
        let total = b.total();
        let sum = b.alu_pj + b.regf_pj + b.bus_pj + b.gbuf_pj + b.noc_pj + b.dram_pj;
        assert!((total - sum).abs() < 1e-6);
    }

    #[test]
    fn alu_energy_is_exactly_macs() {
        let arch = presets::multi_node_eyeriss();
        let l = Layer::conv("c", 16, 16, 14, 3, 1);
        let e = evaluate_layer(&arch, &scheme(PartitionScheme::single(), &l, 2), false);
        assert_eq!(e.energy.alu_pj, l.macs(2) as f64 * arch.mac_pj);
    }

    #[test]
    fn latency_is_roofline_max() {
        let arch = presets::multi_node_eyeriss();
        let l = Layer::conv("c", 64, 64, 28, 3, 1);
        let e = evaluate_layer(&arch, &scheme(PartitionScheme::single(), &l, 4), false);
        assert!(e.latency_cycles >= e.compute_cycles);
        assert!(e.latency_cycles >= e.dram_cycles);
    }

    #[test]
    fn partitioning_speeds_up_compute() {
        let arch = presets::multi_node_eyeriss();
        let l = Layer::conv("c", 64, 128, 28, 3, 1);
        let single = evaluate_layer(&arch, &scheme(PartitionScheme::single(), &l, 16), false);
        let part = PartitionScheme { region: (4, 4), pk: 4, pn: 4, ..PartitionScheme::single() };
        let multi = evaluate_layer(&arch, &scheme(part, &l, 16), false);
        assert!(multi.compute_cycles < single.compute_cycles / 8.0);
    }

    #[test]
    fn pipelined_input_cuts_dram_energy() {
        let arch = presets::multi_node_eyeriss();
        let l = Layer::conv("c", 64, 64, 28, 3, 1);
        let s = scheme(PartitionScheme::single(), &l, 4);
        let off = evaluate_layer(&arch, &s, false);
        let on = evaluate_layer(&arch, &s, true);
        assert!(on.energy.dram_pj < off.energy.dram_pj);
        // On a 1x1 region the forward hop equals the DRAM distribution hop,
        // so NoC energy is unchanged; it must never decrease.
        assert!(on.energy.noc_pj >= off.energy.noc_pj);
    }

    #[test]
    fn staged_eval_matches_one_shot() {
        let arch = presets::multi_node_eyeriss();
        let l = Layer::conv("c", 64, 64, 28, 3, 1);
        let part = PartitionScheme { region: (2, 2), pk: 4, ..PartitionScheme::single() };
        let unit = UnitMap::build(&arch, part.node_shape(&l, 8));
        for ifm_on_chip in [false, true] {
            let staged = StagedEval::new(&arch, part, unit, ifm_on_chip);
            for go in LoopOrder::all() {
                let pre = staged.gbuf(Qty::new(2, 16, 16), go);
                for ro in LoopOrder::all() {
                    let s = LayerScheme {
                        part,
                        unit,
                        regf: LevelBlock { qty: Qty::new(1, 2, 2), order: ro },
                        gbuf: LevelBlock { qty: Qty::new(2, 16, 16), order: go },
                    };
                    let one_shot = evaluate_layer(&arch, &s, ifm_on_chip);
                    let st = pre.eval(Qty::new(1, 2, 2), ro);
                    assert_eq!(st.access, one_shot.access);
                    assert_eq!(st.energy, one_shot.energy);
                    assert_eq!(st.latency_cycles, one_shot.latency_cycles);
                }
            }
        }
    }

    #[test]
    fn prefix_bound_is_admissible() {
        // bound_prefix(gq) never exceeds the detailed evaluation of any
        // completion under that prefix — for energy AND latency.
        let arch = presets::multi_node_eyeriss();
        let l = Layer::conv("c", 32, 64, 14, 3, 1);
        let part = PartitionScheme { region: (2, 2), pn: 2, pk: 2, ..PartitionScheme::single() };
        let unit = UnitMap::build(&arch, part.node_shape(&l, 8));
        let staged = StagedEval::new(&arch, part, unit, false);
        for gq in [Qty::new(1, 2, 2), Qty::new(2, 8, 16), Qty::new(4, 16, 32)] {
            let bound = staged.bound_prefix(gq);
            for go in LoopOrder::all() {
                let pre = staged.gbuf(gq, go);
                for rq in [Qty::new(1, 1, 1), Qty::new(1, 2, 2), gq] {
                    for ro in LoopOrder::all() {
                        let ev = pre.eval(rq, ro);
                        assert!(
                            bound.energy_pj <= ev.energy.total() + 1e-9,
                            "energy bound {} > {}",
                            bound.energy_pj,
                            ev.energy.total()
                        );
                        assert!(bound.latency_cycles <= ev.latency_cycles + 1e-9);
                    }
                }
            }
        }
    }

    #[test]
    fn partition_bound_is_admissible() {
        // bound_partition() never exceeds bound_prefix(gq) for any gbuf
        // block, nor the detailed evaluation of any completion — the
        // partition level of the bound hierarchy, for energy AND latency.
        let arch = presets::multi_node_eyeriss();
        let l = Layer::conv("c", 32, 64, 14, 3, 1);
        let part = PartitionScheme { region: (2, 2), pn: 2, pk: 2, ..PartitionScheme::single() };
        let unit = UnitMap::build(&arch, part.node_shape(&l, 8));
        for ifm_on_chip in [false, true] {
            let staged = StagedEval::new(&arch, part, unit, ifm_on_chip);
            let pb = staged.bound_partition();
            for gq in [Qty::new(1, 2, 2), Qty::new(2, 8, 16), Qty::new(4, 16, 32), unit.totals] {
                let prefix = staged.bound_prefix(gq);
                assert!(pb.energy_pj <= prefix.energy_pj + 1e-9);
                assert!(pb.latency_cycles <= prefix.latency_cycles + 1e-9);
                for go in LoopOrder::all() {
                    let pre = staged.gbuf(gq, go);
                    for rq in [Qty::new(1, 1, 1), Qty::new(1, 2, 2), gq] {
                        for ro in LoopOrder::all() {
                            let ev = pre.eval(rq, ro);
                            assert!(
                                pb.energy_pj <= ev.energy.total() + 1e-9,
                                "energy bound {} > {}",
                                pb.energy_pj,
                                ev.energy.total()
                            );
                            assert!(pb.latency_cycles <= ev.latency_cycles + 1e-9);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn breakdown_add_and_scale() {
        let mut a = EnergyBreakdown { alu_pj: 1.0, regf_pj: 2.0, ..Default::default() };
        let b = EnergyBreakdown { alu_pj: 3.0, dram_pj: 4.0, ..Default::default() };
        a.add(&b);
        assert_eq!(a.alu_pj, 4.0);
        assert_eq!(a.dram_pj, 4.0);
        let s = a.scale(0.5);
        assert_eq!(s.alu_pj, 2.0);
        assert!((s.total() - a.total() * 0.5).abs() < 1e-12);
    }
}
