//! Segment- and network-level evaluation with inter-layer pipelining
//! (paper §III-A inter-layer dataflow; §V simulator).
//!
//! A pipelined segment processes `rounds` batch slices: every layer's
//! intra-layer scheme is built for the per-round batch, intermediate fmaps
//! forward on-chip, and weights stay resident in the GBUFs across rounds
//! (so their DRAM traffic is paid once per segment, not per round). Segment
//! latency includes the pipeline fill/drain of `len - 1` rounds. Segments
//! of a chain time-share the accelerator, so network totals add.

use super::{evaluate_layer, EnergyBreakdown, LayerEval};
use crate::arch::ArchConfig;
use crate::interlayer::{Schedule, Segment};
use crate::directives::LayerScheme;
use crate::workloads::Network;

/// Evaluation result for one segment.
#[derive(Debug, Clone)]
pub struct SegmentEval {
    pub energy: EnergyBreakdown,
    pub latency_cycles: f64,
    pub per_layer: Vec<LayerEval>,
}

/// Evaluation result for a whole schedule.
#[derive(Debug, Clone)]
pub struct NetEval {
    pub energy: EnergyBreakdown,
    pub latency_cycles: f64,
    pub per_segment: Vec<SegmentEval>,
}

impl NetEval {
    pub fn energy_pj(&self) -> f64 {
        self.energy.total()
    }

    /// Wall-clock seconds at the arch frequency.
    pub fn latency_s(&self, arch: &ArchConfig) -> f64 {
        self.latency_cycles / arch.freq_hz
    }
}

/// Evaluate one segment. `schemes[i]` must correspond to `seg.layers[i]`
/// and be built for the segment's per-round batch.
pub fn evaluate_segment(
    arch: &ArchConfig,
    net: &Network,
    seg: &Segment,
    schemes: &[LayerScheme],
) -> SegmentEval {
    assert_eq!(seg.layers.len(), schemes.len(), "scheme per layer required");
    let rounds = seg.rounds.max(1) as f64;
    let mut energy = EnergyBreakdown::default();
    let mut round_latency: f64 = 0.0;
    let mut per_layer = Vec::with_capacity(schemes.len());

    for (pos, (&li, scheme)) in seg.layers.iter().zip(schemes).enumerate() {
        let on_chip_in = seg.ifm_on_chip(net, li);
        let ev = evaluate_layer(arch, scheme, on_chip_in);
        let mut e = ev.energy.scale(rounds);
        // Weights stay resident across rounds: their DRAM (and the NoC
        // distribution share) is paid once, not `rounds` times. The
        // back-weight pass streams dY in the weight slot (changes every
        // round), so it gets no credit; back-activation layers reread the
        // persistent (transposed) forward filters and keep it.
        if rounds > 1.0 && scheme.unit.shape.kind != crate::workloads::LayerKind::ConvBwWeight {
            let wgt_dram = ev.access.dram[2] as f64;
            e.dram_pj -= wgt_dram * arch.dram.pj_per_word * (rounds - 1.0);
            e.noc_pj -=
                wgt_dram * arch.noc_pj_per_word(scheme.part.dram_hops()) * (rounds - 1.0);
        }
        // Outputs consumed entirely inside the segment never reach DRAM;
        // their spill was already counted as NoC by the *consumer*'s
        // forwarded input, so drop the producer-side DRAM write.
        if seg.ofm_on_chip(net, li) {
            let ofm_dram = ev.access.dram[1] as f64 * rounds;
            e.dram_pj -= ofm_dram * arch.dram.pj_per_word;
            e.noc_pj -= ofm_dram * arch.noc_pj_per_word(scheme.part.dram_hops());
            e.noc_pj += ofm_dram * arch.noc_pj_per_word(1.0); // short forward hop
        }
        energy.add(&e);
        round_latency = round_latency.max(ev.latency_cycles);
        let _ = pos;
        per_layer.push(ev);
    }

    let latency_cycles = if seg.spatial {
        // fill/drain: len-1 extra rounds at the bottleneck stage rate.
        round_latency * (seg.rounds as f64 + (seg.len() as f64 - 1.0))
    } else {
        // Single layer (or time-multiplexed): sequential rounds.
        per_layer.iter().map(|e| e.latency_cycles).sum::<f64>() * rounds
    };

    SegmentEval { energy, latency_cycles, per_layer }
}

/// Evaluate a full schedule (segments time-share the accelerator).
pub fn evaluate_schedule(arch: &ArchConfig, net: &Network, sched: &Schedule) -> NetEval {
    let mut energy = EnergyBreakdown::default();
    let mut latency = 0.0;
    let mut per_segment = Vec::with_capacity(sched.segments.len());
    for (seg, schemes) in &sched.segments {
        let ev = evaluate_segment(arch, net, seg, schemes);
        energy.add(&ev.energy);
        latency += ev.latency_cycles;
        per_segment.push(ev);
    }
    NetEval { energy, latency_cycles: latency, per_segment }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::directives::{Grp, LevelBlock, LoopOrder, Qty};
    use crate::interlayer::Segment;
    use crate::mapping::UnitMap;
    use crate::partition::PartitionScheme;
    use crate::workloads::{nets, Layer, Network};

    fn tiny_net() -> Network {
        let mut n = Network::new("t", 8, 28, 28);
        n.chain(Layer::conv("a", 8, 16, 28, 3, 1));
        n.chain(Layer::conv("b", 16, 16, 28, 3, 1));
        n
    }

    fn mk_scheme(arch: &crate::arch::ArchConfig, l: &Layer, region: (u64, u64), batch: u64) -> LayerScheme {
        let part = PartitionScheme { region, ..PartitionScheme::single() };
        let unit = UnitMap::build(arch, part.node_shape(l, batch));
        LayerScheme {
            part,
            unit,
            regf: LevelBlock { qty: Qty::new(1, 1, 2), order: LoopOrder([Grp::B, Grp::K, Grp::C]) },
            gbuf: LevelBlock {
                qty: unit.align_block(Qty::new(1, 8, 8)),
                order: LoopOrder([Grp::B, Grp::C, Grp::K]),
            },
        }
    }

    #[test]
    fn pipelined_segment_saves_energy_vs_sliced() {
        let arch = presets::multi_node_eyeriss();
        let net = tiny_net();
        let batch = 16;

        // Sliced: two single-layer segments, full batch each.
        let sliced = Schedule {
            segments: (0..2)
                .map(|i| {
                    let seg = Segment::single(i, &arch);
                    let sch = mk_scheme(&arch, &net.layers[i], arch.nodes, batch);
                    (seg, vec![sch])
                })
                .collect(),
        };
        // Pipelined: one 2-layer segment, 8 rounds.
        let seg = Segment {
            layers: vec![0, 1],
            regions: vec![(8, 16), (8, 16)],
            spatial: true,
            rounds: 8,
        };
        let rb = seg.round_batch(batch);
        let schemes =
            vec![mk_scheme(&arch, &net.layers[0], (8, 16), rb), mk_scheme(&arch, &net.layers[1], (8, 16), rb)];
        let piped = Schedule { segments: vec![(seg, schemes)] };

        let e_sliced = evaluate_schedule(&arch, &net, &sliced);
        let e_piped = evaluate_schedule(&arch, &net, &piped);
        // The intermediate fmap avoids the DRAM round-trip.
        assert!(
            e_piped.energy.dram_pj < e_sliced.energy.dram_pj,
            "piped {} !< sliced {}",
            e_piped.energy.dram_pj,
            e_sliced.energy.dram_pj
        );
    }

    #[test]
    fn fill_drain_latency_model() {
        let arch = presets::multi_node_eyeriss();
        let net = tiny_net();
        let seg = Segment {
            layers: vec![0, 1],
            regions: vec![(8, 16), (8, 16)],
            spatial: true,
            rounds: 4,
        };
        let rb = seg.round_batch(8);
        let schemes =
            vec![mk_scheme(&arch, &net.layers[0], (8, 16), rb), mk_scheme(&arch, &net.layers[1], (8, 16), rb)];
        let ev = evaluate_segment(&arch, &net, &seg, &schemes);
        let bottleneck = ev.per_layer.iter().map(|e| e.latency_cycles).fold(0.0, f64::max);
        assert!((ev.latency_cycles - bottleneck * 5.0).abs() < 1e-6); // 4 rounds + 1 fill
    }

    #[test]
    fn schedule_totals_add_across_segments() {
        let arch = presets::multi_node_eyeriss();
        let net = tiny_net();
        let mk = |i: usize| {
            let seg = Segment::single(i, &arch);
            let sch = mk_scheme(&arch, &net.layers[i], arch.nodes, 4);
            (seg, vec![sch])
        };
        let s0 = Schedule { segments: vec![mk(0)] };
        let s1 = Schedule { segments: vec![mk(1)] };
        let both = Schedule { segments: vec![mk(0), mk(1)] };
        let e0 = evaluate_schedule(&arch, &net, &s0);
        let e1 = evaluate_schedule(&arch, &net, &s1);
        let eb = evaluate_schedule(&arch, &net, &both);
        assert!((eb.energy_pj() - e0.energy_pj() - e1.energy_pj()).abs() < 1e-6);
        assert!((eb.latency_cycles - e0.latency_cycles - e1.latency_cycles).abs() < 1e-6);
    }

    #[test]
    fn weights_resident_across_rounds() {
        // Same segment with more rounds must not multiply weight DRAM
        // energy.
        let arch = presets::multi_node_eyeriss();
        let net = tiny_net();
        let batch = 16;
        let eval_rounds = |rounds: u64| {
            let seg = Segment {
                layers: vec![0, 1],
                regions: vec![(8, 16), (8, 16)],
                spatial: true,
                rounds,
            };
            let rb = seg.round_batch(batch);
            let schemes = vec![
                mk_scheme(&arch, &net.layers[0], (8, 16), rb),
                mk_scheme(&arch, &net.layers[1], (8, 16), rb),
            ];
            evaluate_segment(&arch, &net, &seg, &schemes)
        };
        let e1 = eval_rounds(1);
        let e8 = eval_rounds(8);
        // DRAM energy should not blow up 8x (weights counted once; fmap
        // traffic is the same data split into rounds).
        assert!(e8.energy.dram_pj < e1.energy.dram_pj * 3.0);
    }

    #[test]
    fn works_on_real_network_slice() {
        let arch = presets::multi_node_eyeriss();
        let net = nets::alexnet();
        let seg = Segment::single(0, &arch);
        let sch = mk_scheme(&arch, &net.layers[0], arch.nodes, 4);
        let ev = evaluate_segment(&arch, &net, &seg, &[sch]);
        assert!(ev.energy.total() > 0.0);
        assert!(ev.latency_cycles > 0.0);
    }
}
