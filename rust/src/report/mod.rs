//! Table/figure emission: aligned text tables for the terminal plus CSV
//! files under `reports/` so the paper's tables and figures can be
//! regenerated and post-processed.

pub mod benchkit;

use std::fmt::Write as _;
use std::path::Path;

/// A simple column-aligned table builder.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "## {}", self.title);
        let line = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, "{:<w$}  ", c, w = width[i]);
            }
            out.pop();
            out.pop();
            out.push('\n');
        };
        line(&mut out, &self.header);
        let total: usize = width.iter().sum::<usize>() + 2 * (ncol - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    /// CSV rendering (quoted only when needed).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Write the CSV under `reports/<name>.csv` and return the rendered
    /// text table.
    pub fn save_and_render(&self, name: &str) -> String {
        let dir = Path::new("reports");
        let _ = std::fs::create_dir_all(dir);
        let _ = std::fs::write(dir.join(format!("{name}.csv")), self.to_csv());
        self.render()
    }
}

/// Format a ratio as the paper does (normalized energy, "1.022" etc).
pub fn ratio(x: f64) -> String {
    format!("{x:.3}")
}

/// Format a percentage overhead ("+2.2%").
pub fn pct(x: f64) -> String {
    format!("{:+.1}%", x * 100.0)
}

/// Format engineering values (1.25e9 -> "1.25 GJ"-style with unit).
pub fn eng(x: f64, unit: &str) -> String {
    let (v, p) = if x.abs() >= 1e12 {
        (x / 1e12, "T")
    } else if x.abs() >= 1e9 {
        (x / 1e9, "G")
    } else if x.abs() >= 1e6 {
        (x / 1e6, "M")
    } else if x.abs() >= 1e3 {
        (x / 1e3, "k")
    } else {
        (x, "")
    };
    format!("{v:.2} {p}{unit}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["net", "K", "B"]);
        t.row(vec!["alexnet".into(), "1.022".into(), "1.000".into()]);
        t.row(vec!["mlp".into(), "1.100".into(), "1.000".into()]);
        let r = t.render();
        assert!(r.contains("## Demo"));
        let lines: Vec<&str> = r.lines().collect();
        // header + rule + 2 rows + title
        assert_eq!(lines.len(), 5);
        // columns aligned: "K" column starts at same offset in both rows
        let off = lines[3].find("1.022").unwrap();
        assert_eq!(lines[4].find("1.100").unwrap(), off);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["v,1".into(), "plain".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"v,1\",plain"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(ratio(1.0223), "1.022");
        assert_eq!(pct(0.022), "+2.2%");
        assert_eq!(eng(1.25e9, "pJ"), "1.25 GpJ");
        assert_eq!(eng(512.0, "B"), "512.00 B");
    }
}
