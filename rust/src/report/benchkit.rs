//! Shared infrastructure for the paper-table benchmark harnesses
//! (`rust/benches/*.rs`, all `harness = false`).
//!
//! Scaling policy (DESIGN.md Substitutions): the paper's exhaustive
//! baseline runs for *hours to days* on the 16x16-node config by design.
//! Benches therefore default to the scaled 4x4-node config and a subset of
//! networks whose exhaustive search completes in minutes; set
//! `KAPLA_FULL=1` to run the full zoo (and `KAPLA_NETS=a,b,..` to choose
//! networks explicitly). The *shape* of the results — who wins, by what
//! factor — is preserved; EXPERIMENTS.md records both.

use crate::arch::{presets, ArchConfig, PeDataflow};
use crate::coordinator::{run_job, Job, SolverKind};
use crate::interlayer::dp::DpConfig;
use crate::mapping::array_mapping;
use crate::solvers::{Objective, SolveResult};
use crate::util::json::Json;
use crate::workloads::{self, Network};

/// Full-scale mode toggle.
pub fn full_scale() -> bool {
    std::env::var("KAPLA_FULL").map(|v| v == "1").unwrap_or(false)
}

/// The benchmark architecture: paper config under KAPLA_FULL, scaled 4x4
/// otherwise.
pub fn bench_arch() -> ArchConfig {
    if full_scale() {
        presets::multi_node_eyeriss()
    } else {
        presets::bench_multi_node()
    }
}

/// Networks to benchmark. Default: the subset whose exhaustive baseline
/// finishes in CI-scale time; KAPLA_FULL or KAPLA_NETS widens it.
pub fn bench_nets(default: &[&str]) -> Vec<Network> {
    let names: Vec<String> = match std::env::var("KAPLA_NETS") {
        Ok(s) => s.split(',').map(|x| x.trim().to_string()).collect(),
        Err(_) if full_scale() => {
            ["alexnet", "mobilenet", "vggnet", "googlenet", "resnet", "mlp", "lstm"]
                .iter()
                .map(|s| s.to_string())
                .collect()
        }
        Err(_) => default.iter().map(|s| s.to_string()).collect(),
    };
    names
        .iter()
        .map(|n| workloads::by_name(n).unwrap_or_else(|| panic!("unknown network {n}")))
        .collect()
}

/// Batch size used by the multi-node experiments. The paper uses 64; the
/// CI-scale default is 16 so the exhaustive baseline finishes in minutes
/// (KAPLA_FULL=1 restores 64, KAPLA_BATCH overrides).
pub fn bench_batch() -> u64 {
    std::env::var("KAPLA_BATCH")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if full_scale() { 64 } else { 16 })
}

/// The DP knobs for benches: paper defaults, with a rounds cap that keeps
/// the scaled exhaustive space tractable. Intra-layer solves use the full
/// worker pool, matching the paper's "8 parallel processes" methodology
/// (results are identical to the sequential path by construction).
pub fn bench_dp() -> DpConfig {
    DpConfig {
        max_rounds: if full_scale() { 64 } else { 8 },
        solve_threads: crate::util::available_threads(),
        ..DpConfig::default()
    }
}

/// The five paper solvers in presentation order (B S R M K).
pub fn paper_solvers(random_p: f64) -> Vec<SolverKind> {
    vec![
        SolverKind::Baseline,
        SolverKind::DirectiveExhaustive,
        SolverKind::Random { p: random_p, seed: 0xBEEF },
        SolverKind::Ml { seed: 0x5EED, rounds: 12, batch: 48 },
        SolverKind::Kapla,
    ]
}

/// Both PE-array mapping templates, for benches that sweep the array axis
/// (fig7/fig8 run every training graph under each).
pub fn array_mappings() -> [PeDataflow; 2] {
    [PeDataflow::RowStationary, PeDataflow::Systolic]
}

/// `base` with its PE-array template swapped (everything else identical,
/// so mapping columns are an apples-to-apples sweep).
pub fn with_mapping(base: &ArchConfig, df: PeDataflow) -> ArchConfig {
    let mut a = base.clone();
    a.pe_dataflow = df;
    a
}

/// Label of an arch's array-mapping template for table/JSON rows.
pub fn mapping_label(arch: &ArchConfig) -> &'static str {
    array_mapping(arch.pe_dataflow).name()
}

/// Assert the structural invariants the training sweeps rely on: every
/// weighted forward layer has @bd/@bw/@wu successors in the training
/// graph, and the backward MAC counts conserve the forward count exactly.
pub fn check_training_graph(fwd: &Network, t: &Network, batch: u64) {
    for l in &fwd.layers {
        if !l.has_weights() {
            continue;
        }
        let bd = t
            .layers
            .iter()
            .find(|x| x.name == format!("{}@bd", l.name))
            .unwrap_or_else(|| panic!("{}: missing {}@bd", t.name, l.name));
        let bw = t
            .layers
            .iter()
            .find(|x| x.name == format!("{}@bw", l.name))
            .unwrap_or_else(|| panic!("{}: missing {}@bw", t.name, l.name));
        assert!(
            t.layers.iter().any(|x| x.name == format!("{}@wu", l.name)),
            "{}: missing {}@wu",
            t.name,
            l.name
        );
        assert_eq!(bd.macs(batch), l.macs(batch), "{}: {}@bd macs", t.name, l.name);
        assert_eq!(bw.macs(batch), l.macs(batch), "{}: {}@bw macs", t.name, l.name);
    }
}

/// Run one (net, solver) cell.
pub fn run_cell(
    arch: &ArchConfig,
    net: &Network,
    batch: u64,
    obj: Objective,
    solver: SolverKind,
) -> SolveResult {
    let job = Job { net: net.clone(), batch, objective: obj, solver, dp: bench_dp(), deadline_ms: None };
    run_job(arch, &job)
        .unwrap_or_else(|e| panic!("bench cell {}/{}: {e}", net.name, solver.label()))
}

/// Machine-readable record of one solve: identity, quality, solve time,
/// and the evaluation-cache counters (so warm-session reuse shows up in
/// the uploaded bench artifacts). The solver field carries the *label*
/// (letter + non-default knobs, `SolverKind::label`) so rows from a
/// `random:p=0.3,seed=7` sweep stay distinguishable. Solves that ran the
/// staged branch-and-bound enumeration (the exhaustive B/S families) add
/// a `bnb` object — visited/pruned prefixes, schemes visited/skipped,
/// prune rate and average bound tightness — feeding the Table VI-style
/// pruning reports.
pub fn result_json(net: &str, solver: SolverKind, r: &SolveResult) -> Json {
    let mut o = Json::obj();
    o.set("net", net.into())
        .set("solver", solver.label().into())
        .set("energy_pj", r.eval.energy.total().into())
        .set("latency_cycles", r.eval.latency_cycles.into())
        .set("solve_s", r.solve_s.into())
        .set("cache", r.cache.to_json());
    if let Some(d) = &r.degraded {
        let mut dj = Json::obj();
        dj.set("reason", d.reason.into())
            .set("elapsed_ms", d.elapsed_ms.into())
            .set("best_effort", d.best_effort.into());
        o.set("degraded", dj);
    }
    if let Some(b) = &r.bnb {
        o.set("bnb", b.to_json());
    }
    if let Some(p) = &r.prune {
        o.set("prune", p.to_json());
    }
    o
}

/// Write a JSON report under `reports/<name>.json` (pretty, so diffs in
/// uploaded artifacts stay readable).
pub fn save_json(name: &str, json: &Json) {
    let _ = std::fs::create_dir_all("reports");
    let _ = std::fs::write(format!("reports/{name}.json"), json.to_string_pretty());
}

/// Append a section to EXPERIMENTS-bench.log (raw capture for
/// EXPERIMENTS.md curation).
pub fn log_section(name: &str, body: &str) {
    use std::io::Write as _;
    let _ = std::fs::create_dir_all("reports");
    if let Ok(mut f) =
        std::fs::OpenOptions::new().create(true).append(true).open("reports/bench.log")
    {
        let _ = writeln!(f, "==== {name} ====\n{body}\n");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_nets_resolve() {
        let nets = bench_nets(&["alexnet", "mlp"]);
        assert_eq!(nets.len(), 2);
        assert_eq!(nets[0].name, "alexnet");
    }

    #[test]
    fn solvers_in_paper_order() {
        let s = paper_solvers(0.1);
        let letters: Vec<&str> = s.iter().map(|x| x.letter()).collect();
        assert_eq!(letters, vec!["B", "S", "R", "M", "K"]);
    }

    #[test]
    fn bench_arch_is_scaled_by_default() {
        if !full_scale() {
            assert_eq!(bench_arch().nodes, (4, 4));
        }
    }

    #[test]
    fn result_json_labels_knobbed_solvers() {
        let arch = presets::bench_multi_node();
        let net = workloads::by_name("mlp").unwrap();
        let job = Job {
            net: net.clone(),
            batch: 4,
            objective: Objective::Energy,
            solver: SolverKind::Random { p: 0.3, seed: 7 },
            dp: DpConfig { max_rounds: 4, ..DpConfig::default() },
            deadline_ms: None,
        };
        let r = run_job(&arch, &job).unwrap();
        let j = result_json(&net.name, job.solver, &r);
        assert_eq!(j.get("solver").unwrap().as_str(), Some("R:p=0.3,seed=7"));
    }

    #[test]
    fn result_json_carries_cache_stats() {
        let arch = presets::bench_multi_node();
        let net = workloads::by_name("mlp").unwrap();
        let job = Job {
            net: net.clone(),
            batch: 4,
            objective: Objective::Energy,
            solver: SolverKind::Kapla,
            dp: DpConfig { max_rounds: 4, ..DpConfig::default() },
            deadline_ms: None,
        };
        let r = run_job(&arch, &job).unwrap();
        let j = result_json(&net.name, job.solver, &r);
        assert_eq!(j.get("solver").unwrap().as_str(), Some("K"));
        assert!(j.get("energy_pj").unwrap().as_f64().unwrap() > 0.0);
        let cache = j.get("cache").unwrap();
        assert!(cache.get("lookups").unwrap().as_f64().unwrap() > 0.0);
        assert!(cache.get("hit_rate").unwrap().as_f64().is_some());
    }
}
