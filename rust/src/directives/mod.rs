//! Tensor-centric dataflow directives (paper §III-B).
//!
//! The representation treats the *tensors buffered at each memory level* as
//! first-class citizens. A scheme is described per level by
//!
//! * `tensor{..}(dim=size, ..[, shr])` — the (sub)tensor resident in each
//!   buffer instance at this level;
//! * `stack(dim+=shift, .., repl)` — spatial parallelization across `repl`
//!   sibling buffers;
//! * `update(dim+=step, ..)` — ordered temporal iteration that advances all
//!   resident tensors.
//!
//! From these, *data sizes per buffer* (validity) and *access volumes
//! across buffers* (efficiency) fall out by inspection — the property that
//! makes the representation pragmatic for solvers (§III-B "Advantages").
//!
//! This module holds the core calculus shared by the fast cost model and
//! the detailed simulator: loop groups, loop orders, and the refetch-factor
//! rule that converts `update` nests into access counts.

pub mod emit;
pub mod parse;
pub mod scheme;

pub use scheme::{GbufAccess, LayerScheme, LevelBlock, PartAccess};

/// Tensor dimensions (paper Table I).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dim {
    N,
    C,
    K,
    Xo,
    Yo,
    Xi,
    Yi,
    R,
    S,
}

impl Dim {
    pub fn name(&self) -> &'static str {
        match self {
            Dim::N => "N",
            Dim::C => "C",
            Dim::K => "K",
            Dim::Xo => "Xo",
            Dim::Yo => "Yo",
            Dim::Xi => "Xi",
            Dim::Yi => "Yi",
            Dim::R => "R",
            Dim::S => "S",
        }
    }
}

/// The three tensors of a CONV/FC layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TensorKind {
    Ifm,
    Ofm,
    Wgt,
}

impl TensorKind {
    pub const ALL: [TensorKind; 3] = [TensorKind::Ifm, TensorKind::Ofm, TensorKind::Wgt];

    /// The temporal loop group this tensor is *invariant* to ("miss group"):
    /// ifm has no K, ofm no C, wgt no B.
    pub fn miss_group(&self) -> Grp {
        match self {
            TensorKind::Ifm => Grp::K,
            TensorKind::Ofm => Grp::C,
            TensorKind::Wgt => Grp::B,
        }
    }

    /// The two groups the tensor depends on.
    pub fn member_groups(&self) -> [Grp; 2] {
        match self {
            TensorKind::Ifm => [Grp::B, Grp::C],
            TensorKind::Ofm => [Grp::B, Grp::K],
            TensorKind::Wgt => [Grp::C, Grp::K],
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TensorKind::Ifm => "ifm",
            TensorKind::Ofm => "ofm",
            TensorKind::Wgt => "wgt",
        }
    }
}

/// Temporal loop groups used for blocking across the memory hierarchy:
/// B = batch-like trips (N, plus fmap rows for streaming mappings),
/// C = input channels, K = output channels (paper §III-A: loop blocking
/// over the nested dims; fmap X/Y are absorbed by the PE mapping and node
/// partitioning, as in nn-dataflow [16], [17]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Grp {
    B,
    C,
    K,
}

impl Grp {
    pub const ALL: [Grp; 3] = [Grp::B, Grp::C, Grp::K];

    pub fn name(&self) -> &'static str {
        match self {
            Grp::B => "B",
            Grp::C => "C",
            Grp::K => "K",
        }
    }
}

/// A per-group quantity (sizes, trip counts, blocking factors).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Qty {
    pub b: u64,
    pub c: u64,
    pub k: u64,
}

impl Qty {
    pub const UNIT: Qty = Qty { b: 1, c: 1, k: 1 };

    pub fn new(b: u64, c: u64, k: u64) -> Qty {
        Qty { b, c, k }
    }

    pub fn get(&self, g: Grp) -> u64 {
        match g {
            Grp::B => self.b,
            Grp::C => self.c,
            Grp::K => self.k,
        }
    }

    pub fn set(&mut self, g: Grp, v: u64) {
        match g {
            Grp::B => self.b = v,
            Grp::C => self.c = v,
            Grp::K => self.k = v,
        }
    }

    pub fn product(&self) -> u64 {
        self.b * self.c * self.k
    }

    /// Per-group ceiling trips of `self` blocks covering `total`.
    pub fn trips_over(&self, total: Qty) -> Qty {
        Qty {
            b: crate::util::ceil_div(total.b, self.b),
            c: crate::util::ceil_div(total.c, self.c),
            k: crate::util::ceil_div(total.k, self.k),
        }
    }

    /// Component-wise min.
    pub fn min(&self, other: Qty) -> Qty {
        Qty { b: self.b.min(other.b), c: self.c.min(other.c), k: self.k.min(other.k) }
    }

    /// True if every component of self is <= the other's.
    pub fn fits_in(&self, other: Qty) -> bool {
        self.b <= other.b && self.c <= other.c && self.k <= other.k
    }
}

/// A loop order at one memory level: permutation of the three groups,
/// outermost first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LoopOrder(pub [Grp; 3]);

impl LoopOrder {
    /// All 6 permutations.
    pub fn all() -> [LoopOrder; 6] {
        use Grp::*;
        [
            LoopOrder([B, C, K]),
            LoopOrder([B, K, C]),
            LoopOrder([C, B, K]),
            LoopOrder([C, K, B]),
            LoopOrder([K, B, C]),
            LoopOrder([K, C, B]),
        ]
    }

    pub fn innermost(&self) -> Grp {
        self.0[2]
    }

    pub fn outermost(&self) -> Grp {
        self.0[0]
    }

    pub fn name(&self) -> String {
        format!("{}{}{}", self.0[0].name(), self.0[1].name(), self.0[2].name())
    }
}

/// Member/miss groups of a tensor for a given layer kind. CONV/FC follow
/// `TensorKind::member_groups`; depthwise/pool/eltwise layers carry their
/// channels in the K group with a trivial C group, so their input fmap
/// follows (B, K) instead of (B, C).
pub fn tensor_groups(
    tensor: TensorKind,
    kind: crate::workloads::LayerKind,
) -> ([Grp; 2], Grp) {
    use crate::workloads::LayerKind::*;
    match (kind, tensor) {
        (DWConv | DWConvBwAct | Pool | Eltwise, TensorKind::Ifm) => ([Grp::B, Grp::K], Grp::C),
        // Back-weight pass: "wgt" is the streamed dY (varies with batch),
        // "ofm" is dW, accumulated over the batch (misses B).
        (ConvBwWeight, TensorKind::Wgt) => ([Grp::B, Grp::K], Grp::C),
        (ConvBwWeight, TensorKind::Ofm) => ([Grp::C, Grp::K], Grp::B),
        // Back-activation pass: a conv with swapped channel roles. Its
        // input fmap is dY (follows B, C; misses K), its output is dX
        // (follows B, K; accumulated over the C group = forward K), and
        // its weights are the transposed forward filters (miss B) — the
        // forward-conv defaults, listed explicitly because the *roles*
        // differ even though the group assignment coincides.
        (ConvBwAct, TensorKind::Ifm) => ([Grp::B, Grp::C], Grp::K),
        (ConvBwAct, TensorKind::Ofm) => ([Grp::B, Grp::K], Grp::C),
        (ConvBwAct, TensorKind::Wgt) => ([Grp::C, Grp::K], Grp::B),
        _ => (tensor.member_groups(), tensor.miss_group()),
    }
}

/// The accumulation (revisit) group of the output tensor: the group the
/// ofm is invariant to (C for forward convs, B for the back-weight pass).
pub fn ofm_accum_group(kind: crate::workloads::LayerKind) -> Grp {
    tensor_groups(TensorKind::Ofm, kind).1
}

/// `ofm_revisits` generalized over the accumulation group.
pub fn ofm_revisits_for(trips: Qty, order: LoopOrder, accum: Grp) -> u64 {
    if order.innermost() == accum {
        1
    } else {
        trips.get(accum)
    }
}

/// Generalized refetch rule over explicit member/miss groups.
pub fn refetch_factor_groups(trips: Qty, order: LoopOrder, members: [Grp; 2], miss: Grp) -> u64 {
    let m = trips.get(members[0]) * trips.get(members[1]);
    let miss_f = if order.innermost() == miss || trips.get(miss) == 1 { 1 } else { trips.get(miss) };
    m * miss_f
}

/// How many times a tensor's lower-level block must be (re)fetched from this
/// level, given this level's per-group trip counts and loop order.
///
/// Derivation (paper §III-B "Calculating ... data movement statistics"):
/// the tensor's block index advances whenever a loop over one of its member
/// groups advances; a loop over its miss group forces a refetch of the same
/// blocks unless it is the innermost loop (in which case the resident block
/// is reused across its iterations).
pub fn refetch_factor(trips: Qty, order: LoopOrder, tensor: TensorKind) -> u64 {
    refetch_factor_groups(trips, order, tensor.member_groups(), tensor.miss_group())
}

/// Number of times each *unique* output block is revisited for partial-sum
/// accumulation: the C-group trips unless C is innermost.
pub fn ofm_revisits(trips: Qty, order: LoopOrder) -> u64 {
    if order.innermost() == Grp::C {
        1
    } else {
        trips.c
    }
}

/// Read+write access amplification for the output tensor given `v`
/// accumulation revisits: each revisit writes the block and all but the
/// first also read the partial sums back (2v - 1).
pub fn ofm_rw_factor(v: u64) -> u64 {
    2 * v - 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qty_accessors() {
        let mut q = Qty::new(2, 3, 4);
        assert_eq!(q.get(Grp::B), 2);
        assert_eq!(q.product(), 24);
        q.set(Grp::C, 5);
        assert_eq!(q.c, 5);
        assert_eq!(Qty::UNIT.product(), 1);
    }

    #[test]
    fn trips_over_uses_ceiling() {
        let blk = Qty::new(2, 3, 4);
        let tot = Qty::new(5, 9, 4);
        assert_eq!(blk.trips_over(tot), Qty::new(3, 3, 1));
    }

    #[test]
    fn all_orders_are_permutations() {
        let orders = LoopOrder::all();
        assert_eq!(orders.len(), 6);
        for o in orders {
            let mut seen = [false; 3];
            for g in o.0 {
                seen[g as usize] = true;
            }
            assert!(seen.iter().all(|&s| s));
        }
        for i in 0..6 {
            for j in i + 1..6 {
                assert_ne!(orders[i].0, orders[j].0);
            }
        }
    }

    #[test]
    fn refetch_miss_innermost_reuses() {
        // ifm misses K; with K innermost the ifm block is reused across K.
        let trips = Qty::new(4, 3, 5);
        let o = LoopOrder([Grp::B, Grp::C, Grp::K]);
        assert_eq!(refetch_factor(trips, o, TensorKind::Ifm), 4 * 3);
    }

    #[test]
    fn refetch_miss_outer_forces_reload() {
        let trips = Qty::new(4, 3, 5);
        // K outermost: every k iteration re-walks all ifm blocks.
        let o = LoopOrder([Grp::K, Grp::B, Grp::C]);
        assert_eq!(refetch_factor(trips, o, TensorKind::Ifm), 4 * 3 * 5);
        // K in the middle: same.
        let o = LoopOrder([Grp::B, Grp::K, Grp::C]);
        assert_eq!(refetch_factor(trips, o, TensorKind::Ifm), 4 * 3 * 5);
    }

    #[test]
    fn refetch_single_trip_miss_is_free() {
        let trips = Qty::new(4, 3, 1);
        for o in LoopOrder::all() {
            assert_eq!(refetch_factor(trips, o, TensorKind::Ifm), 12, "order {}", o.name());
        }
    }

    #[test]
    fn wgt_misses_batch() {
        let trips = Qty::new(7, 2, 3);
        let inner_b = LoopOrder([Grp::C, Grp::K, Grp::B]);
        assert_eq!(refetch_factor(trips, inner_b, TensorKind::Wgt), 6);
        let outer_b = LoopOrder([Grp::B, Grp::C, Grp::K]);
        assert_eq!(refetch_factor(trips, outer_b, TensorKind::Wgt), 42);
    }

    #[test]
    fn ofm_revisit_rule() {
        let trips = Qty::new(2, 6, 3);
        assert_eq!(ofm_revisits(trips, LoopOrder([Grp::B, Grp::K, Grp::C])), 1);
        assert_eq!(ofm_revisits(trips, LoopOrder([Grp::C, Grp::B, Grp::K])), 6);
        assert_eq!(ofm_rw_factor(1), 1);
        assert_eq!(ofm_rw_factor(6), 11);
    }

    #[test]
    fn refetch_lower_bound_is_member_product() {
        // Property: refetch factor is always >= product of member trips and
        // <= product of all trips.
        let mut rng = crate::util::SplitMix64::new(3);
        for _ in 0..500 {
            let trips = Qty::new(1 + rng.below(16), 1 + rng.below(16), 1 + rng.below(16));
            for o in LoopOrder::all() {
                for t in TensorKind::ALL {
                    let f = refetch_factor(trips, o, t);
                    let [g1, g2] = t.member_groups();
                    let members = trips.get(g1) * trips.get(g2);
                    assert!(f >= members);
                    assert!(f <= trips.product());
                }
            }
        }
    }

    #[test]
    fn best_order_minimizes_most_accessed_tensor() {
        // With huge C trips, orders ending in C minimize ofm refetches.
        let trips = Qty::new(2, 64, 2);
        let best = LoopOrder::all()
            .into_iter()
            .min_by_key(|o| ofm_rw_factor(ofm_revisits(trips, *o)))
            .unwrap();
        assert_eq!(best.innermost(), Grp::C);
    }
}
