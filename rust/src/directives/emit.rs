//! Emission of the Listing-1-style directive text from a `LayerScheme`.
//!
//! The emitted program is the paper's user-facing representation: per
//! memory level, the resident `tensor`s, the spatial `stack`s and the
//! temporal `update`s, constructed from the inside out. The REGF body is
//! fixed by the hardware template and emitted by the scheme's
//! [`crate::mapping::ArrayMapping`]; this module owns the level framing,
//! the GBUF tensors/stacks and the update nests. `parse.rs` reads the
//! same format back; round-trip equality is tested.

use super::scheme::LayerScheme;
use super::{Grp, Qty};
use crate::workloads::LayerKind;
use std::fmt::Write as _;

/// Emit the full directive program of one layer.
pub fn emit_layer(name: &str, s: &LayerScheme) -> String {
    let mut out = String::new();
    let kind = match s.unit.shape.kind {
        LayerKind::Conv => "CONV",
        LayerKind::DWConv => "DWCONV",
        LayerKind::Fc => "FC",
        LayerKind::Pool => "POOL",
        LayerKind::Eltwise => "ELTWISE",
        LayerKind::ConvBwWeight => "CONVBW",
        LayerKind::ConvBwAct => "CONVBD",
        LayerKind::DWConvBwAct => "DWCONVBD",
    };
    let _ = writeln!(out, "{kind} {name}:");
    emit_regf(&mut out, name, s);
    emit_gbuf(&mut out, name, s);
    out
}

pub(crate) fn tensor_line(
    out: &mut String,
    tag: &str,
    dims: &[(&str, u64)],
    shr: u64,
) {
    let body: Vec<String> = dims.iter().map(|(d, v)| format!("{d}={v}")).collect();
    if shr > 1 {
        let _ = writeln!(out, "    tensor{{{tag}}}({}, shr={shr})", body.join(", "));
    } else {
        let _ = writeln!(out, "    tensor{{{tag}}}({})", body.join(", "));
    }
}

fn update_line(out: &mut String, steps: &[(Grp, u64)], comment: &str) {
    let body: Vec<String> =
        steps.iter().map(|(g, v)| format!("{}+={v}", g.name())).collect();
    let _ = writeln!(out, "    update({}) % {comment}", body.join(", "));
}

/// REGF-level directives: the per-PE unit tensors and PE-array stacks fixed
/// by the hardware template, then the REGF-level update nest.
fn emit_regf(out: &mut String, name: &str, s: &LayerScheme) {
    let _ = writeln!(out, "  REGF:");
    s.unit.mapping.emit_regf(out, name, s);
    emit_updates(out, s.regf_trips(), s.regf.order, s.regf.qty, s);
}

/// GBUF-level directives: per-node tensors (with shr), the node-level
/// partition stacks, and the DRAM-iterating update nest.
fn emit_gbuf(out: &mut String, name: &str, s: &LayerScheme) {
    let _ = writeln!(out, "  GBUF:");
    let sh = &s.unit.shape;
    let q = s.gbuf.qty;
    let (ci, ki) = chan_view(s, q);
    let (ifm_y, ofm_y) = s.unit.mapping.gbuf_fmap_rows(sh);
    tensor_line(
        out,
        &format!("{name}_i"),
        &[("N", q.b), ("C", ci), ("Xi", sh.xi()), ("Yi", ifm_y)],
        s.part.ifm_shr(),
    );
    if s.unit.wgt_node_words(Qty::UNIT) > 0 {
        let wdims: [(&str, u64); 4] = match sh.kind {
            // One filter per channel: trivial C axis, channels in K.
            LayerKind::DWConv | LayerKind::DWConvBwAct => {
                [("C", 1), ("K", ki), ("R", sh.r), ("S", sh.s)]
            }
            // The weight-role tensor is the streamed dY: batch x K rows of
            // Xo pixels (ofm_y rows resident, like the output fmap).
            LayerKind::ConvBwWeight => [("N", q.b), ("K", ki), ("Xo", sh.xo), ("Yo", ofm_y)],
            _ => [("C", ci), ("K", ki), ("R", sh.r), ("S", sh.s)],
        };
        tensor_line(out, &format!("{name}_w"), &wdims, s.part.wgt_shr());
    }
    let odims: [(&str, u64); 4] = match sh.kind {
        // The back-weight output is dW (C x K x R x S), batch-invariant.
        LayerKind::ConvBwWeight => [("C", ci), ("K", ki), ("R", sh.r), ("S", sh.s)],
        _ => [("N", q.b), ("K", ki), ("Xo", sh.xo), ("Yo", ofm_y)],
    };
    tensor_line(out, &format!("{name}_o"), &odims, 1);
    // Node-level stacks, one per partitioned dim (declared order applies
    // recursively, paper §III-B).
    let p = &s.part;
    for (dim, shift, repl) in [
        ("K", ki, p.pk),
        ("N", q.b, p.pn),
        ("C", ci, p.pc),
        ("Xo", sh.xo, p.px),
        ("Yo", ofm_y, p.py),
    ] {
        if repl > 1 {
            let _ = writeln!(out, "    stack({dim}+={shift}, {repl}) % node parallel");
        }
    }
    emit_updates(out, s.gbuf_trips(), s.gbuf.order, s.gbuf.qty, s);
}

/// One `update` per loop group with trips > 1, outermost first in loop
/// order; the step equals the resident block quantity per group.
fn emit_updates(out: &mut String, trips: Qty, order: super::LoopOrder, block: Qty, s: &LayerScheme) {
    for g in order.0.iter().rev() {
        // innermost emitted first: directives list updates inside-out
        if trips.get(*g) > 1 {
            let step = block.get(*g);
            let dim = group_dim_name(*g, s);
            update_line(out, &[(*g, step)], &format!("{} loop x{}", dim, trips.get(*g)));
        }
    }
}

/// What one step of loop group `g` iterates over, for directive comments.
/// The B label comes from the array mapping (images vs output rows); the K
/// group carries the fused channel axis for depthwise-family kinds.
fn group_dim_name(g: Grp, s: &LayerScheme) -> &'static str {
    let kind = s.unit.shape.kind;
    match g {
        Grp::B => s.unit.mapping.batch_dim_label(kind),
        Grp::C => "C",
        Grp::K => match kind {
            LayerKind::DWConv
            | LayerKind::DWConvBwAct
            | LayerKind::Pool
            | LayerKind::Eltwise => "C=K",
            _ => "K",
        },
    }
}

/// Channel view of a block: DW-family layers carry channels in K.
pub(crate) fn chan_view(s: &LayerScheme, q: Qty) -> (u64, u64) {
    match s.unit.shape.kind {
        LayerKind::DWConv | LayerKind::DWConvBwAct | LayerKind::Pool | LayerKind::Eltwise => {
            (q.k, q.k)
        }
        _ => (q.c, q.k),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::directives::{LevelBlock, LoopOrder};
    use crate::mapping::UnitMap;
    use crate::partition::PartitionScheme;
    use crate::workloads::Layer;

    fn sample() -> LayerScheme {
        let arch = presets::multi_node_eyeriss();
        let l = Layer::conv("conv2", 96, 256, 27, 5, 1);
        let part = PartitionScheme {
            region: (4, 4),
            pk: 4,
            pn: 4,
            share_ifm: true,
            ..PartitionScheme::single()
        };
        let unit = UnitMap::build(&arch, part.node_shape(&l, 64));
        LayerScheme {
            part,
            unit,
            regf: LevelBlock { qty: Qty::new(1, 2, 3), order: LoopOrder([Grp::B, Grp::C, Grp::K]) },
            gbuf: LevelBlock { qty: Qty::new(4, 24, 16), order: LoopOrder([Grp::C, Grp::B, Grp::K]) },
        }
    }

    #[test]
    fn emits_both_levels() {
        let text = emit_layer("conv2", &sample());
        assert!(text.contains("CONV conv2:"));
        assert!(text.contains("REGF:"));
        assert!(text.contains("GBUF:"));
    }

    #[test]
    fn emits_sharing_factor() {
        let text = emit_layer("conv2", &sample());
        assert!(text.contains("shr=4"), "{text}");
    }

    #[test]
    fn emits_node_stacks() {
        let text = emit_layer("conv2", &sample());
        let stacks: Vec<&str> = text.lines().filter(|l| l.contains("node parallel")).collect();
        assert_eq!(stacks.len(), 2, "{text}"); // pk and pn
        assert!(stacks[0].contains("K+="));
        assert!(stacks[1].contains("N+="));
    }

    #[test]
    fn emits_rowstationary_pe_stacks() {
        let text = emit_layer("conv2", &sample());
        assert!(text.contains("PE columns"));
        assert!(text.contains("PE rows"));
        assert!(text.contains("1D conv"));
    }

    #[test]
    fn systolic_emission_differs() {
        let arch = presets::edge_tpu();
        let l = Layer::fc("fc6", 1024, 512);
        let part = PartitionScheme::single();
        let unit = UnitMap::build(&arch, part.node_shape(&l, 1));
        let s = LayerScheme {
            part,
            unit,
            regf: LevelBlock { qty: Qty::new(1, 16, 16), order: LoopOrder([Grp::B, Grp::C, Grp::K]) },
            gbuf: LevelBlock { qty: Qty::new(1, 256, 64), order: LoopOrder([Grp::B, Grp::C, Grp::K]) },
        };
        let text = emit_layer("fc6", &s);
        assert!(text.contains("systolic rows"));
        assert!(text.contains("systolic cols"));
        assert!(text.contains("FC fc6:"));
    }

    #[test]
    fn update_lines_reflect_trips() {
        let s = sample();
        let text = emit_layer("conv2", &s);
        // gbuf trips: b: ceil(16/4)=4, c: ceil(96/24)=4, k: ceil(64/16)=4
        assert!(text.contains("x4"), "{text}");
    }

    #[test]
    fn dwconv_wgt_tensor_has_trivial_c_axis() {
        let arch = presets::multi_node_eyeriss();
        let l = Layer::dwconv("dw3", 64, 28, 3, 1);
        let part = PartitionScheme::single();
        let unit = UnitMap::build(&arch, part.node_shape(&l, 4));
        let s = LayerScheme {
            part,
            unit,
            regf: LevelBlock { qty: Qty::UNIT, order: LoopOrder([Grp::B, Grp::C, Grp::K]) },
            gbuf: LevelBlock { qty: Qty::new(4, 1, 64), order: LoopOrder([Grp::B, Grp::C, Grp::K]) },
        };
        let text = emit_layer("dw3", &s);
        // GBUF wgt words are K*R*S: the emitted dims must multiply to that,
        // not K^2*R*S (the C axis is trivial for depthwise filters).
        assert!(text.contains("tensor{dw3_w}(C=1, K=64, R=3, S=3)"), "{text}");
        // Fused channel axis labels as C=K in loop comments.
        assert!(text.contains("C=K loop"), "{text}");
    }

    #[test]
    fn conv_bw_weight_streams_dy_as_weights() {
        let arch = presets::multi_node_eyeriss();
        let mut l = Layer::conv("c3@bw", 16, 32, 14, 3, 1);
        l.kind = LayerKind::ConvBwWeight;
        let part = PartitionScheme::single();
        let unit = UnitMap::build(&arch, part.node_shape(&l, 4));
        let s = LayerScheme {
            part,
            unit,
            regf: LevelBlock { qty: Qty::UNIT, order: LoopOrder([Grp::B, Grp::C, Grp::K]) },
            gbuf: LevelBlock { qty: Qty::new(4, 16, 32), order: LoopOrder([Grp::B, Grp::C, Grp::K]) },
        };
        let text = emit_layer("c3@bw", &s);
        assert!(text.contains("CONVBW c3@bw:"));
        // Weight-role tensor is dY (N,K,Xo,Yo); output is dW (C,K,R,S).
        assert!(text.contains("tensor{c3@bw_w}(N=4, K=32, Xo=14, Yo=14)"), "{text}");
        assert!(text.contains("tensor{c3@bw_o}(C=16, K=32, R=3, S=3)"), "{text}");
    }

    #[test]
    fn conv_bw_act_emission_round_dims() {
        let arch = presets::edge_tpu();
        let mut l = Layer::conv("c1@bd", 32, 16, 16, 3, 1);
        l.kind = LayerKind::ConvBwAct;
        let part = PartitionScheme::single();
        let unit = UnitMap::build(&arch, part.node_shape(&l, 2));
        let s = LayerScheme {
            part,
            unit,
            regf: LevelBlock { qty: unit.granule, order: LoopOrder([Grp::B, Grp::C, Grp::K]) },
            gbuf: LevelBlock { qty: Qty::new(2, 32, 16), order: LoopOrder([Grp::B, Grp::C, Grp::K]) },
        };
        let text = emit_layer("c1@bd", &s);
        assert!(text.contains("CONVBD c1@bd:"), "{text}");
        // Transposed filters keep the (C,K,R,S) weight tensor.
        assert!(text.contains("tensor{c1@bd_w}(C=32, K=16, R=3, S=3)"), "{text}");
    }
}
