//! Emission of the Listing-1-style directive text from a `LayerScheme`.
//!
//! The emitted program is the paper's user-facing representation: per
//! memory level, the resident `tensor`s, the spatial `stack`s and the
//! temporal `update`s, constructed from the inside out. `parse.rs` reads
//! the same format back; round-trip equality is tested.

use super::scheme::LayerScheme;
use super::{Grp, Qty};
use crate::arch::PeDataflow;
use crate::workloads::LayerKind;
use std::fmt::Write as _;

/// Emit the full directive program of one layer.
pub fn emit_layer(name: &str, s: &LayerScheme) -> String {
    let mut out = String::new();
    let kind = match s.unit.shape.kind {
        LayerKind::Conv => "CONV",
        LayerKind::DWConv => "DWCONV",
        LayerKind::Fc => "FC",
        LayerKind::Pool => "POOL",
        LayerKind::Eltwise => "ELTWISE",
        LayerKind::ConvBwWeight => "CONVBW",
    };
    let _ = writeln!(out, "{kind} {name}:");
    emit_regf(&mut out, name, s);
    emit_gbuf(&mut out, name, s);
    out
}

fn tensor_line(
    out: &mut String,
    tag: &str,
    dims: &[(&str, u64)],
    shr: u64,
) {
    let body: Vec<String> = dims.iter().map(|(d, v)| format!("{d}={v}")).collect();
    if shr > 1 {
        let _ = writeln!(out, "    tensor{{{tag}}}({}, shr={shr})", body.join(", "));
    } else {
        let _ = writeln!(out, "    tensor{{{tag}}}({})", body.join(", "));
    }
}

fn update_line(out: &mut String, steps: &[(Grp, u64)], comment: &str) {
    let body: Vec<String> =
        steps.iter().map(|(g, v)| format!("{}+={v}", g.name())).collect();
    let _ = writeln!(out, "    update({}) % {comment}", body.join(", "));
}

/// REGF-level directives: per-PE unit tensors, the PE-array stacks fixed by
/// the hardware dataflow, and the REGF-level update nest.
fn emit_regf(out: &mut String, name: &str, s: &LayerScheme) {
    let _ = writeln!(out, "  REGF:");
    let sh = &s.unit.shape;
    let q = s.regf.qty;
    let (ci, ki) = chan_view(s, q);
    match s.unit.dataflow {
        PeDataflow::RowStationary => {
            tensor_line(out, &format!("{name}_i"), &[("N", q.b), ("C", ci), ("Xi", sh.r), ("Yi", 1)], 1);
            if s.unit.wgt_node_words(Qty::UNIT) > 0 {
                tensor_line(out, &format!("{name}_w"), &[("C", ci), ("K", ki), ("R", sh.r), ("S", 1)], 1);
            }
            tensor_line(out, &format!("{name}_o"), &[("N", q.b), ("K", ki), ("Xo", 1), ("Yo", 1)], 1);
            let cols = s.unit.array.0.min(sh.yo);
            let rows = s.unit.array.1.min(sh.s);
            let _ = writeln!(out, "    stack(Yi+=1, Yo+=1, {cols}) % PE columns");
            let _ = writeln!(out, "    stack(S+=1, Yi+=1, {rows}) % PE rows");
            let _ = writeln!(out, "    update(Xi+={}, Xo+=1) % 1D conv", sh.stride);
            if sh.yo > cols {
                let _ = writeln!(out, "    update(Yi+={c}, Yo+={c}) % folding", c = cols);
            }
        }
        PeDataflow::Systolic => {
            tensor_line(out, &format!("{name}_i"), &[("N", q.b), ("C", ci), ("Xi", sh.xi()), ("Yi", sh.s)], 1);
            if s.unit.wgt_node_words(Qty::UNIT) > 0 {
                tensor_line(out, &format!("{name}_w"), &[("C", ci), ("K", ki), ("R", sh.r), ("S", sh.s)], 1);
            }
            tensor_line(out, &format!("{name}_o"), &[("N", q.b), ("K", ki), ("Xo", sh.xo), ("Yo", 1)], 1);
            let rows = (s.unit.granule.c * sh.r * sh.s).min(s.unit.array.1);
            let cols = s.unit.granule.k.min(s.unit.array.0);
            let _ = writeln!(out, "    stack(C+=1, {rows}) % systolic rows (reduction)");
            let _ = writeln!(out, "    stack(K+=1, {cols}) % systolic cols");
            let _ = writeln!(out, "    update(Xi+={}, Xo+=1) % pixel stream", sh.stride);
        }
    }
    emit_updates(out, s.regf_trips(), s.regf.order, s.regf.qty, s);
}

/// GBUF-level directives: per-node tensors (with shr), the node-level
/// partition stacks, and the DRAM-iterating update nest.
fn emit_gbuf(out: &mut String, name: &str, s: &LayerScheme) {
    let _ = writeln!(out, "  GBUF:");
    let sh = &s.unit.shape;
    let q = s.gbuf.qty;
    let (ci, ki) = chan_view(s, q);
    let (ifm_y, ofm_y) = match s.unit.dataflow {
        PeDataflow::RowStationary => (sh.yi(), sh.yo),
        PeDataflow::Systolic => (sh.s, 1),
    };
    tensor_line(
        out,
        &format!("{name}_i"),
        &[("N", q.b), ("C", ci), ("Xi", sh.xi()), ("Yi", ifm_y)],
        s.part.ifm_shr(),
    );
    if s.unit.wgt_node_words(Qty::UNIT) > 0 {
        tensor_line(
            out,
            &format!("{name}_w"),
            &[("C", ci), ("K", ki), ("R", sh.r), ("S", sh.s)],
            s.part.wgt_shr(),
        );
    }
    tensor_line(out, &format!("{name}_o"), &[("N", q.b), ("K", ki), ("Xo", sh.xo), ("Yo", ofm_y)], 1);
    // Node-level stacks, one per partitioned dim (declared order applies
    // recursively, paper §III-B).
    let p = &s.part;
    for (dim, shift, repl) in [
        ("K", ki, p.pk),
        ("N", q.b, p.pn),
        ("C", ci, p.pc),
        ("Xo", sh.xo, p.px),
        ("Yo", ofm_y, p.py),
    ] {
        if repl > 1 {
            let _ = writeln!(out, "    stack({dim}+={shift}, {repl}) % node parallel");
        }
    }
    emit_updates(out, s.gbuf_trips(), s.gbuf.order, s.gbuf.qty, s);
}

/// One `update` per loop group with trips > 1, outermost first in loop
/// order; the step equals the resident block quantity per group.
fn emit_updates(out: &mut String, trips: Qty, order: super::LoopOrder, block: Qty, s: &LayerScheme) {
    for g in order.0.iter().rev() {
        // innermost emitted first: directives list updates inside-out
        if trips.get(*g) > 1 {
            let step = block.get(*g);
            let dim = group_dim_name(*g, s);
            update_line(out, &[(*g, step)], &format!("{} loop x{}", dim, trips.get(*g)));
        }
    }
}

fn group_dim_name(g: Grp, s: &LayerScheme) -> &'static str {
    match (g, s.unit.dataflow) {
        (Grp::B, PeDataflow::RowStationary) => "N",
        (Grp::B, PeDataflow::Systolic) => "N*Yo",
        (Grp::C, _) => "C",
        (Grp::K, _) => "K",
    }
}

/// Channel view of a block: DW-family layers carry channels in K.
fn chan_view(s: &LayerScheme, q: Qty) -> (u64, u64) {
    match s.unit.shape.kind {
        LayerKind::DWConv | LayerKind::Pool | LayerKind::Eltwise => (q.k, q.k),
        _ => (q.c, q.k),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::directives::{LevelBlock, LoopOrder};
    use crate::mapping::UnitMap;
    use crate::partition::PartitionScheme;
    use crate::workloads::Layer;

    fn sample() -> LayerScheme {
        let arch = presets::multi_node_eyeriss();
        let l = Layer::conv("conv2", 96, 256, 27, 5, 1);
        let part = PartitionScheme {
            region: (4, 4),
            pk: 4,
            pn: 4,
            share_ifm: true,
            ..PartitionScheme::single()
        };
        let unit = UnitMap::build(&arch, part.node_shape(&l, 64));
        LayerScheme {
            part,
            unit,
            regf: LevelBlock { qty: Qty::new(1, 2, 3), order: LoopOrder([Grp::B, Grp::C, Grp::K]) },
            gbuf: LevelBlock { qty: Qty::new(4, 24, 16), order: LoopOrder([Grp::C, Grp::B, Grp::K]) },
        }
    }

    #[test]
    fn emits_both_levels() {
        let text = emit_layer("conv2", &sample());
        assert!(text.contains("CONV conv2:"));
        assert!(text.contains("REGF:"));
        assert!(text.contains("GBUF:"));
    }

    #[test]
    fn emits_sharing_factor() {
        let text = emit_layer("conv2", &sample());
        assert!(text.contains("shr=4"), "{text}");
    }

    #[test]
    fn emits_node_stacks() {
        let text = emit_layer("conv2", &sample());
        let stacks: Vec<&str> = text.lines().filter(|l| l.contains("node parallel")).collect();
        assert_eq!(stacks.len(), 2, "{text}"); // pk and pn
        assert!(stacks[0].contains("K+="));
        assert!(stacks[1].contains("N+="));
    }

    #[test]
    fn emits_rowstationary_pe_stacks() {
        let text = emit_layer("conv2", &sample());
        assert!(text.contains("PE columns"));
        assert!(text.contains("PE rows"));
        assert!(text.contains("1D conv"));
    }

    #[test]
    fn systolic_emission_differs() {
        let arch = presets::edge_tpu();
        let l = Layer::fc("fc6", 1024, 512);
        let part = PartitionScheme::single();
        let unit = UnitMap::build(&arch, part.node_shape(&l, 1));
        let s = LayerScheme {
            part,
            unit,
            regf: LevelBlock { qty: Qty::new(1, 16, 16), order: LoopOrder([Grp::B, Grp::C, Grp::K]) },
            gbuf: LevelBlock { qty: Qty::new(1, 256, 64), order: LoopOrder([Grp::B, Grp::C, Grp::K]) },
        };
        let text = emit_layer("fc6", &s);
        assert!(text.contains("systolic rows"));
        assert!(text.contains("systolic cols"));
        assert!(text.contains("FC fc6:"));
    }

    #[test]
    fn update_lines_reflect_trips() {
        let s = sample();
        let text = emit_layer("conv2", &s);
        // gbuf trips: b: ceil(16/4)=4, c: ceil(96/24)=4, k: ceil(64/16)=4
        assert!(text.contains("x4"), "{text}");
    }
}
