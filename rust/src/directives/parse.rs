//! Parser for the directive text format emitted by `emit.rs`.
//!
//! The parsed form is a lightweight syntax tree; it exists so the emitted
//! representation is a real interchange format (round-trip tested), and so
//! the CLI can validate externally-authored directive programs the way the
//! paper's Listing 1 presents them.

/// One parsed directive line.
#[derive(Debug, Clone, PartialEq)]
pub enum Directive {
    /// tensor{tag}(dim=size, ..[, shr=n])
    Tensor { tag: String, dims: Vec<(String, u64)>, shr: u64 },
    /// stack(dim+=shift, .., repl)
    Stack { shifts: Vec<(String, u64)>, repl: u64 },
    /// update(dim+=step, ..)
    Update { steps: Vec<(String, u64)> },
}

/// A memory level section: name (REGF/GBUF/...) plus its directives.
#[derive(Debug, Clone, PartialEq)]
pub struct LevelSection {
    pub level: String,
    pub directives: Vec<Directive>,
}

/// A parsed layer program.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerProgram {
    pub kind: String,
    pub name: String,
    pub levels: Vec<LevelSection>,
}

impl LayerProgram {
    /// Total words declared resident at a level (sum of tensor sizes with
    /// shr divisors applied) — the validity statistic the representation
    /// exposes "by inspection" (paper §III-B Advantages).
    pub fn resident_words(&self, level: &str) -> Option<u64> {
        let sec = self.levels.iter().find(|s| s.level == level)?;
        let mut total = 0u64;
        for d in &sec.directives {
            if let Directive::Tensor { dims, shr, .. } = d {
                let size: u64 = dims.iter().map(|(_, v)| *v).product();
                total += size.div_ceil(*shr);
            }
        }
        Some(total)
    }

    /// Total spatial replication at a level (product of stack repls).
    pub fn parallelism(&self, level: &str) -> Option<u64> {
        let sec = self.levels.iter().find(|s| s.level == level)?;
        Some(
            sec.directives
                .iter()
                .filter_map(|d| match d {
                    Directive::Stack { repl, .. } => Some(*repl),
                    _ => None,
                })
                .product(),
        )
    }
}

/// Parse a directive program (one or more layers).
pub fn parse(text: &str) -> Result<Vec<LayerProgram>, String> {
    let mut layers: Vec<LayerProgram> = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        let err = |m: &str| format!("line {}: {m}: {raw}", lineno + 1);
        if let Some(rest) = line.strip_suffix(':') {
            let rest = rest.trim();
            if rest.chars().all(|c| c.is_ascii_uppercase() || c.is_ascii_digit()) && !rest.contains(' ')
            {
                // memory level header
                let layer = layers.last_mut().ok_or_else(|| err("level before layer"))?;
                layer.levels.push(LevelSection { level: rest.to_string(), directives: Vec::new() });
            } else {
                // layer header: "KIND name"
                let mut it = rest.split_whitespace();
                let kind = it.next().ok_or_else(|| err("missing kind"))?.to_string();
                let name = it.next().ok_or_else(|| err("missing layer name"))?.to_string();
                layers.push(LayerProgram { kind, name, levels: Vec::new() });
            }
            continue;
        }
        let layer = layers.last_mut().ok_or_else(|| err("directive before layer"))?;
        let level = layer.levels.last_mut().ok_or_else(|| err("directive before level"))?;
        level.directives.push(parse_directive(&line).map_err(|m| err(&m))?);
    }
    Ok(layers)
}

fn strip_comment(line: &str) -> &str {
    match line.find('%') {
        Some(i) => &line[..i],
        None => line,
    }
}

fn parse_directive(line: &str) -> Result<Directive, String> {
    if let Some(rest) = line.strip_prefix("tensor") {
        let (tag, args) = split_tag_args(rest)?;
        let mut dims = Vec::new();
        let mut shr = 1;
        for part in args {
            let (k, v) = split_kv(&part, '=')?;
            if k == "shr" {
                shr = v;
            } else {
                dims.push((k, v));
            }
        }
        Ok(Directive::Tensor { tag, dims, shr })
    } else if let Some(rest) = line.strip_prefix("stack") {
        let args = paren_args(rest)?;
        let mut shifts = Vec::new();
        let mut repl = None;
        for part in &args {
            if part.contains("+=") {
                let (k, v) = split_kv2(part)?;
                shifts.push((k, v));
            } else {
                repl = Some(part.trim().parse::<u64>().map_err(|e| e.to_string())?);
            }
        }
        Ok(Directive::Stack { shifts, repl: repl.ok_or("stack missing repl")? })
    } else if let Some(rest) = line.strip_prefix("update") {
        let args = paren_args(rest)?;
        let mut steps = Vec::new();
        for part in &args {
            let (k, v) = split_kv2(part)?;
            steps.push((k, v));
        }
        Ok(Directive::Update { steps })
    } else {
        Err(format!("unknown directive: {line}"))
    }
}

fn split_tag_args(rest: &str) -> Result<(String, Vec<String>), String> {
    let rest = rest.trim();
    let rest = rest.strip_prefix('{').ok_or("expected '{'")?;
    let close = rest.find('}').ok_or("expected '}'")?;
    let tag = rest[..close].to_string();
    let args = paren_args(&rest[close + 1..])?;
    Ok((tag, args))
}

fn paren_args(rest: &str) -> Result<Vec<String>, String> {
    let rest = rest.trim();
    let rest = rest.strip_prefix('(').ok_or("expected '('")?;
    let close = rest.rfind(')').ok_or("expected ')'")?;
    Ok(rest[..close].split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect())
}

fn split_kv(part: &str, sep: char) -> Result<(String, u64), String> {
    let mut it = part.splitn(2, sep);
    let k = it.next().ok_or("missing key")?.trim().to_string();
    let v = it.next().ok_or("missing value")?.trim().parse::<u64>().map_err(|e| e.to_string())?;
    Ok((k, v))
}

fn split_kv2(part: &str) -> Result<(String, u64), String> {
    let mut it = part.splitn(2, "+=");
    let k = it.next().ok_or("missing key")?.trim().to_string();
    let v = it.next().ok_or("missing value")?.trim().parse::<u64>().map_err(|e| e.to_string())?;
    Ok((k, v))
}

#[cfg(test)]
mod tests {
    use super::*;

    const LISTING: &str = r#"
CONV conv1:
  REGF:
    tensor{i0}(N=1, C=2, Xi=5, Yi=1)
    tensor{w1}(C=2, K=3, R=5, S=1)
    tensor{o1}(N=1, K=3, Xo=1, Yo=1)
    stack(Yi+=1, Yo+=1, 8) % PE columns
    stack(S+=1, Yi+=1, 5) % PE rows
    update(Xi+=1, Xo+=1) % 1D conv
    update(N+=1)
    update(C+=2)
    update(K+=3)
  GBUF:
    tensor{i0}(N=4, C=4, Xi=19, Yi=19, shr=4)
    tensor{w1}(C=4, K=6, R=5, S=5)
    tensor{o1}(N=4, K=6, Xo=15, Yo=15)
    stack(K+=6, 4) % output node parallel
    stack(N+=4, 16) % batch node parallel
    update(C+=4)
    update(K+=24)
    update(N+=64)
"#;

    #[test]
    fn parses_paper_listing() {
        let progs = parse(LISTING).unwrap();
        assert_eq!(progs.len(), 1);
        let p = &progs[0];
        assert_eq!(p.kind, "CONV");
        assert_eq!(p.name, "conv1");
        assert_eq!(p.levels.len(), 2);
        assert_eq!(p.levels[0].level, "REGF");
        assert_eq!(p.levels[1].level, "GBUF");
    }

    #[test]
    fn tensor_sizes_by_inspection() {
        let progs = parse(LISTING).unwrap();
        let p = &progs[0];
        // REGF: 1*2*5*1 + 2*3*5*1 + 1*3*1*1 = 10 + 30 + 3 = 43 words
        assert_eq!(p.resident_words("REGF"), Some(43));
        // GBUF: ifm shared by 4: ceil(4*4*19*19/4)=1444; w: 4*6*25=600;
        // o: 4*6*225=5400
        assert_eq!(p.resident_words("GBUF"), Some(1444 + 600 + 5400));
    }

    #[test]
    fn parallelism_by_inspection() {
        let progs = parse(LISTING).unwrap();
        let p = &progs[0];
        assert_eq!(p.parallelism("REGF"), Some(40)); // 8 x 5 PEs
        assert_eq!(p.parallelism("GBUF"), Some(64)); // 4 x 16 nodes
    }

    #[test]
    fn stack_shifts_parsed() {
        let progs = parse(LISTING).unwrap();
        let regf = &progs[0].levels[0];
        let stack = regf
            .directives
            .iter()
            .find_map(|d| match d {
                Directive::Stack { shifts, repl } if *repl == 5 => Some(shifts.clone()),
                _ => None,
            })
            .unwrap();
        assert_eq!(stack, vec![("S".to_string(), 1), ("Yi".to_string(), 1)]);
    }

    #[test]
    fn errors_are_located() {
        let err = parse("CONV x:\n  REGF:\n    bogus(1)\n").unwrap_err();
        assert!(err.contains("line 3"), "{err}");
        let err = parse("    update(N+=1)\n").unwrap_err();
        assert!(err.contains("before layer"), "{err}");
    }

    #[test]
    fn roundtrip_with_emitter() {
        use crate::arch::presets;
        use crate::directives::{Grp, LevelBlock, LoopOrder, Qty};
        use crate::mapping::UnitMap;
        use crate::partition::PartitionScheme;
        use crate::workloads::Layer;

        let arch = presets::multi_node_eyeriss();
        let l = Layer::conv("conv2", 96, 256, 27, 5, 1);
        let part = PartitionScheme { region: (4, 4), pk: 4, pn: 4, ..PartitionScheme::single() };
        let unit = UnitMap::build(&arch, part.node_shape(&l, 64));
        let s = crate::directives::LayerScheme {
            part,
            unit,
            regf: LevelBlock { qty: Qty::new(1, 2, 3), order: LoopOrder([Grp::B, Grp::C, Grp::K]) },
            gbuf: LevelBlock { qty: Qty::new(4, 24, 16), order: LoopOrder([Grp::C, Grp::B, Grp::K]) },
        };
        let text = crate::directives::emit::emit_layer("conv2", &s);
        let progs = parse(&text).unwrap();
        assert_eq!(progs.len(), 1);
        assert_eq!(progs[0].name, "conv2");
        // Node parallelism visible by inspection equals the partition's.
        assert_eq!(progs[0].parallelism("GBUF"), Some(16));
        // GBUF resident words match the scheme's own accounting.
        assert_eq!(progs[0].resident_words("GBUF"), Some(s.gbuf_words_per_node()));
    }
}
