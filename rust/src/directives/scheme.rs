//! A complete intra-layer dataflow scheme and its directive-level
//! statistics: buffered data sizes (validity) and access volumes across the
//! memory hierarchy (efficiency). Paper §III-B.
//!
//! A `LayerScheme` composes, from the inside out (the directives'
//! construction order):
//!
//! * the PE-level unit mapping (`mapping::UnitMap`, fixed by hardware);
//! * the REGF-level block: how many unit tensors are cached per PE array,
//!   plus the REGF loop order (`update` nest between GBUF and REGF);
//! * the GBUF-level block and loop order (`update` nest between DRAM and
//!   GBUF);
//! * the node-level partition (`partition::PartitionScheme`, the GBUF-level
//!   `stack` directives).

use crate::arch::ArchConfig;
use crate::directives::{ofm_accum_group, ofm_revisits_for, ofm_rw_factor, refetch_factor_groups, tensor_groups, Grp, LoopOrder, Qty, TensorKind};
use crate::mapping::UnitMap;
use crate::partition::PartitionScheme;

/// Temporal blocking at one memory level: the resident block quantities and
/// the loop order iterating blocks at this level.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LevelBlock {
    pub qty: Qty,
    pub order: LoopOrder,
}

/// A full intra-layer scheme for one layer on one node region.
#[derive(Debug, Clone, Copy)]
pub struct LayerScheme {
    pub part: PartitionScheme,
    pub unit: UnitMap,
    pub regf: LevelBlock,
    pub gbuf: LevelBlock,
}

/// Access volumes implied by a scheme (whole layer, all nodes), in words.
/// These are the statistics the paper's directives expose "by inspection".
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct AccessCounts {
    /// DRAM traffic per tensor [ifm, ofm, wgt].
    pub dram: [u64; 3],
    /// GBUF port traffic per tensor [ifm, ofm, wgt] (fills + drains to both
    /// sides of the buffer).
    pub gbuf: [u64; 3],
    /// REGF-side share of the GBUF traffic (rides the intra-node PE bus).
    pub gbuf_regf_side: u64,
    /// REGF traffic (operand reads/writes at the PEs + refills).
    pub regf: u64,
    /// NoC traffic in word-hops (DRAM distribution, rotation, reduction).
    pub noc_word_hops: f64,
    /// Total MAC operations.
    pub macs: u64,
}

impl AccessCounts {
    pub fn dram_total(&self) -> u64 {
        self.dram.iter().sum()
    }
    pub fn gbuf_total(&self) -> u64 {
        self.gbuf.iter().sum()
    }
}

impl LayerScheme {
    /// GBUF words resident per node (with buffer-sharing divisors applied).
    pub fn gbuf_words_per_node(&self) -> u64 {
        let q = self.gbuf.qty;
        let ifm = self.unit.ifm_node_words(q).div_ceil(self.part.ifm_shr());
        let wgt = self.unit.wgt_node_words(q).div_ceil(self.part.wgt_shr());
        let ofm = self.unit.ofm_node_words(q);
        ifm + wgt + ofm
    }

    /// REGF words resident per PE.
    pub fn regf_words_per_pe(&self) -> u64 {
        self.unit.regf_pe_words(self.regf.qty)
    }

    /// Validity check: every tensor fits its buffer, and block nesting is
    /// consistent (paper: "quickly determine whether a scheme satisfies all
    /// constraints").
    pub fn validate(&self, arch: &ArchConfig) -> Result<(), String> {
        let granule = self.unit.granule;
        let totals = self.unit.totals;
        if !granule.fits_in(self.regf.qty) {
            return Err(format!("REGF block {:?} below granule {granule:?}", self.regf.qty));
        }
        if !self.regf.qty.fits_in(self.gbuf.qty) {
            return Err(format!(
                "REGF block {:?} exceeds GBUF block {:?}",
                self.regf.qty, self.gbuf.qty
            ));
        }
        if !self.gbuf.qty.fits_in(totals) {
            return Err(format!("GBUF block {:?} exceeds totals {totals:?}", self.gbuf.qty));
        }
        let rw = self.regf_words_per_pe();
        if rw > arch.regf_words() {
            return Err(format!("REGF overflow: {rw} > {} words", arch.regf_words()));
        }
        let gw = self.gbuf_words_per_node();
        if gw > arch.gbuf_words() {
            return Err(format!("GBUF overflow: {gw} > {} words", arch.gbuf_words()));
        }
        Ok(())
    }

    /// GBUF-level trip counts (DRAM-iterating loops).
    pub fn gbuf_trips(&self) -> Qty {
        self.gbuf.qty.trips_over(self.unit.totals)
    }

    /// REGF-level trip counts (GBUF-iterating loops).
    pub fn regf_trips(&self) -> Qty {
        self.regf.qty.trips_over(self.gbuf.qty)
    }

    /// Compute the access counts implied by the directives. `ifm_on_chip`
    /// marks layers whose input is forwarded from a producer in the same
    /// pipelined segment (traffic moves from DRAM to the NoC).
    ///
    /// One-shot wrapper over the staged calculus below: the enumeration hot
    /// path ([`crate::solvers::space::visit_schemes_staged`]) reuses the
    /// [`PartAccess`] and [`GbufAccess`] prefixes across thousands of
    /// candidates, and because this wrapper runs the very same stages the
    /// two paths are bit-identical by construction
    /// (`tests/staged_eval_equivalence.rs`).
    pub fn access_counts(&self, ifm_on_chip: bool) -> AccessCounts {
        PartAccess::new(self.part, self.unit)
            .gbuf(self.gbuf.qty, self.gbuf.order, ifm_on_chip)
            .counts(self.regf.qty, self.regf.order)
    }
}

/// Stage 1 of the staged access-count calculus: everything determined by
/// the `(part, unit)` enumeration prefix alone — node counts, the kind's
/// tensor/group splits, sharing and reduction divisors, hop distances and
/// the MAC total. Computed once per partition and shared by every blocking
/// candidate underneath it.
#[derive(Debug, Clone, Copy)]
pub struct PartAccess {
    unit: UnitMap,
    nodes: u64,
    i_mem: [Grp; 2],
    i_miss: Grp,
    w_mem: [Grp; 2],
    w_miss: Grp,
    o_mem: [Grp; 2],
    accum: Grp,
    ifm_shr: u64,
    wgt_shr: u64,
    red: u64,
    neighbor_hops: f64,
    dram_distr_hops: f64,
    macs: u64,
}

impl PartAccess {
    pub fn new(part: PartitionScheme, unit: UnitMap) -> PartAccess {
        let kind = unit.shape.kind;
        let (i_mem, i_miss) = tensor_groups(TensorKind::Ifm, kind);
        let (w_mem, w_miss) = tensor_groups(TensorKind::Wgt, kind);
        let (o_mem, _) = tensor_groups(TensorKind::Ofm, kind);
        let nodes = part.used_nodes();
        PartAccess {
            unit,
            nodes,
            i_mem,
            i_miss,
            w_mem,
            w_miss,
            o_mem,
            accum: ofm_accum_group(kind),
            // Replicated tensors: every replica group fetches the same
            // data. With buffer sharing, DRAM sees one copy; the rest
            // moves as NoC rotation among the shr sibling buffers.
            ifm_shr: part.ifm_shr(),
            wgt_shr: part.wgt_shr_for(kind),
            // Cross-node partial-sum reduction: only one reduced copy
            // reaches DRAM (pc for forward convs; batch/fmap parallel
            // nodes for the back-weight pass, whose output reduces over B).
            red: part.ofm_reduction_for(kind),
            neighbor_hops: part.neighbor_hops(),
            dram_distr_hops: part.dram_hops(),
            macs: unit.node_macs() * nodes,
        }
    }

    /// Stage 2: all DRAM and NoC terms plus the per-node GBUF fill streams
    /// for one `(gbuf block, gbuf order)` prefix — none of which depend on
    /// the REGF-level choices iterated underneath.
    pub fn gbuf(&self, gq: Qty, go: LoopOrder, ifm_on_chip: bool) -> GbufAccess {
        let tg = gq.trips_over(self.unit.totals);
        let ifm_per_node =
            self.unit.ifm_node_words(gq) * refetch_factor_groups(tg, go, self.i_mem, self.i_miss);
        let wgt_per_node =
            self.unit.wgt_node_words(gq) * refetch_factor_groups(tg, go, self.w_mem, self.w_miss);
        let ofm_unique =
            self.unit.ofm_node_words(gq) * tg.get(self.o_mem[0]) * tg.get(self.o_mem[1]);
        let v = ofm_revisits_for(tg, go, self.accum);
        let ofm_per_node = ofm_unique * ofm_rw_factor(v);
        self.finish_gbuf(gq, tg, ifm_per_node, wgt_per_node, ofm_unique, ofm_per_node, ifm_on_chip)
    }

    /// Order-independent floor of stage 2: the per-node streams with every
    /// miss-group refetch dropped (refetch factor >= the member-trip
    /// product for any loop order) and a single accumulation visit
    /// (`ofm_rw_factor(v) >= 1`). Every DRAM/NoC/GBUF-fill quantity of
    /// [`PartAccess::gbuf`] is monotone in these streams, so the result
    /// lower-bounds the real stage 2 for *every* gbuf order — the
    /// admissible prefix bound behind branch-and-bound pruning.
    pub fn gbuf_floor(&self, gq: Qty, ifm_on_chip: bool) -> GbufAccess {
        let tg = gq.trips_over(self.unit.totals);
        let ifm_min = self.unit.ifm_node_words(gq) * tg.get(self.i_mem[0]) * tg.get(self.i_mem[1]);
        let wgt_min = self.unit.wgt_node_words(gq) * tg.get(self.w_mem[0]) * tg.get(self.w_mem[1]);
        let ofm_unique =
            self.unit.ofm_node_words(gq) * tg.get(self.o_mem[0]) * tg.get(self.o_mem[1]);
        self.finish_gbuf(gq, tg, ifm_min, wgt_min, ofm_unique, ofm_unique, ifm_on_chip)
    }

    /// Partition-level floor: the stage-2/stage-3 floor chain evaluated at
    /// `gq == unit.totals` — a gq/go-independent lower bound over *every*
    /// blocking of this `(part, unit)` prefix. Admissibility: each
    /// per-node stream is a product of member-group tensor words and
    /// ceil-div trip counts, and `gq.g * trips_over(g) >= totals.g` for
    /// every group, so the stream at any `gq` dominates the stream at the
    /// totals (one trip, the whole tensor resident); likewise
    /// `gbuf_iters = tg.product() >= 1` keeps every stage-3 drain term
    /// above the single-pass floor. `gq == totals` may overflow the GBUF —
    /// irrelevant: a relaxation's floor still lower-bounds the feasible
    /// subset. Monotone assembly (`finish_gbuf`/`assemble` have
    /// nonnegative coefficients in every stream) then gives
    /// `partition_floor <= gbuf_floor(gq).counts_floor() <= counts(..)`
    /// for every `(gq, go, rq, ro)` completion.
    pub fn partition_floor(&self, ifm_on_chip: bool) -> AccessCounts {
        self.gbuf_floor(self.unit.totals, ifm_on_chip).counts_floor()
    }

    #[allow(clippy::too_many_arguments)]
    fn finish_gbuf(
        &self,
        gq: Qty,
        tg: Qty,
        ifm_per_node: u64,
        wgt_per_node: u64,
        ofm_unique: u64,
        ofm_per_node: u64,
        ifm_on_chip: bool,
    ) -> GbufAccess {
        let nodes = self.nodes;
        let mut dram_ifm = ifm_per_node * nodes / self.ifm_shr;
        let dram_wgt = wgt_per_node * nodes / self.wgt_shr;
        let dram_ofm = ofm_per_node * nodes / self.red;

        let mut noc = 0.0;
        // Rotation traffic for shared tensors: each node still *consumes*
        // its full per-node access stream; the (shr-1)/shr remote fraction
        // rides the NoC ring.
        if self.ifm_shr > 1 {
            noc += (ifm_per_node * nodes) as f64 * (self.ifm_shr - 1) as f64 / self.ifm_shr as f64
                * self.neighbor_hops;
        }
        if self.wgt_shr > 1 {
            noc += (wgt_per_node * nodes) as f64 * (self.wgt_shr - 1) as f64 / self.wgt_shr as f64
                * self.neighbor_hops;
        }
        if self.red > 1 {
            noc += (ofm_unique * nodes) as f64 * (self.red - 1) as f64 / self.red as f64
                * self.neighbor_hops;
        }
        // DRAM words travel the mesh to/from edge memory controllers.
        if ifm_on_chip {
            // Producer forwards through the NoC instead of DRAM (layer
            // pipelining): same volume, neighbour-region distance.
            noc += dram_ifm as f64 * self.neighbor_hops;
            dram_ifm = 0;
        } else {
            noc += dram_ifm as f64 * self.dram_distr_hops;
        }
        noc += (dram_wgt + dram_ofm) as f64 * self.dram_distr_hops;

        GbufAccess {
            base: *self,
            gq,
            gbuf_iters: tg.product(),
            dram: [dram_ifm, dram_ofm, dram_wgt],
            noc,
            ifm_per_node,
            wgt_per_node,
            ofm_per_node,
        }
    }
}

/// Stages 1+2 of the access-count calculus, frozen for one
/// `(part, gbuf block, gbuf order)` prefix. The remaining per-candidate
/// work ([`GbufAccess::counts`]) is only the GBUF<->REGF suffix — the
/// cheap arithmetic the innermost `(regf block, regf order)` loops touch.
#[derive(Debug, Clone, Copy)]
pub struct GbufAccess {
    base: PartAccess,
    gq: Qty,
    gbuf_iters: u64,
    dram: [u64; 3],
    noc: f64,
    ifm_per_node: u64,
    wgt_per_node: u64,
    ofm_per_node: u64,
}

impl GbufAccess {
    /// Stage 3: finish the counts for one REGF-level `(block, order)`.
    pub fn counts(&self, rq: Qty, ro: LoopOrder) -> AccessCounts {
        let b = &self.base;
        let tr = rq.trips_over(self.gq);
        // --- GBUF <-> REGF, per node ------------------------------------
        let ifm_g = b.unit.ifm_node_words(rq)
            * refetch_factor_groups(tr, ro, b.i_mem, b.i_miss)
            * self.gbuf_iters;
        let wgt_g = b.unit.wgt_node_words(rq)
            * refetch_factor_groups(tr, ro, b.w_mem, b.w_miss)
            * self.gbuf_iters;
        let vr = ofm_revisits_for(tr, ro, b.accum);
        let ofm_g = b.unit.ofm_node_words(rq)
            * tr.get(b.o_mem[0])
            * tr.get(b.o_mem[1])
            * ofm_rw_factor(vr)
            * self.gbuf_iters;
        self.assemble(ifm_g, wgt_g, ofm_g)
    }

    /// Floor of stage 3 over every REGF-level completion: one drain pass
    /// over the resident gbuf block per gbuf iteration (reached exactly at
    /// `rq == gq`; any smaller block only adds refetches). Composed with
    /// [`PartAccess::gbuf_floor`] this bounds the whole `(rq, ro)` subtree.
    pub fn counts_floor(&self) -> AccessCounts {
        let b = &self.base;
        let ifm_g = b.unit.ifm_node_words(self.gq) * self.gbuf_iters;
        let wgt_g = b.unit.wgt_node_words(self.gq) * self.gbuf_iters;
        let ofm_g = b.unit.ofm_node_words(self.gq) * self.gbuf_iters;
        self.assemble(ifm_g, wgt_g, ofm_g)
    }

    fn assemble(&self, ifm_g: u64, wgt_g: u64, ofm_g: u64) -> AccessCounts {
        let nodes = self.base.nodes;
        // GBUF port sees both the DRAM-side fills and the REGF-side drains.
        let gbuf_ifm = (ifm_g + self.ifm_per_node) * nodes;
        let gbuf_wgt = (wgt_g + self.wgt_per_node) * nodes;
        let gbuf_ofm = (ofm_g + self.ofm_per_node) * nodes;

        // --- REGF traffic ------------------------------------------------
        let macs = self.base.macs;
        // Per MAC: ifm read, wgt read, psum read + write; plus refills.
        let regf = 4 * macs + (ifm_g + wgt_g + ofm_g) * nodes;

        AccessCounts {
            dram: self.dram,
            gbuf: [gbuf_ifm, gbuf_ofm, gbuf_wgt],
            gbuf_regf_side: (ifm_g + wgt_g + ofm_g) * nodes,
            regf,
            noc_word_hops: self.noc,
            macs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::mapping::LayerShape;
    use crate::workloads::Layer;
    use crate::directives::Grp;

    fn scheme(layer: &Layer, batch: u64) -> LayerScheme {
        let arch = presets::multi_node_eyeriss();
        let part = PartitionScheme::single();
        let unit = UnitMap::build(&arch, part.node_shape(layer, batch));
        LayerScheme {
            part,
            unit,
            regf: LevelBlock { qty: Qty::new(1, 2, 2), order: LoopOrder([Grp::B, Grp::K, Grp::C]) },
            gbuf: LevelBlock { qty: Qty::new(1, 8, 8), order: LoopOrder([Grp::B, Grp::C, Grp::K]) },
        }
    }

    #[test]
    fn valid_scheme_passes() {
        let arch = presets::multi_node_eyeriss();
        let l = Layer::conv("c", 16, 32, 14, 3, 1);
        scheme(&l, 4).validate(&arch).unwrap();
    }

    #[test]
    fn regf_overflow_detected() {
        let arch = presets::multi_node_eyeriss();
        let l = Layer::conv("c", 16, 32, 14, 3, 1);
        let mut s = scheme(&l, 4);
        s.regf.qty = Qty::new(1, 8, 8);
        s.gbuf.qty = Qty::new(1, 8, 8);
        let err = s.validate(&arch).unwrap_err();
        assert!(err.contains("REGF overflow"), "{err}");
    }

    #[test]
    fn gbuf_overflow_detected() {
        let arch = presets::multi_node_eyeriss();
        let l = Layer::conv("c", 512, 512, 56, 3, 1);
        let mut s = scheme(&l, 8);
        s.gbuf.qty = Qty::new(8, 512, 512);
        let err = s.validate(&arch).unwrap_err();
        assert!(err.contains("GBUF overflow"), "{err}");
    }

    #[test]
    fn nesting_violation_detected() {
        let arch = presets::multi_node_eyeriss();
        let l = Layer::conv("c", 16, 32, 14, 3, 1);
        let mut s = scheme(&l, 4);
        s.regf.qty = Qty::new(4, 16, 32);
        s.gbuf.qty = Qty::new(1, 8, 8);
        assert!(s.validate(&arch).is_err());
    }

    #[test]
    fn dram_traffic_at_least_compulsory() {
        // DRAM traffic >= one pass over each tensor (compulsory misses).
        let l = Layer::conv("c", 16, 32, 14, 3, 1);
        let s = scheme(&l, 4);
        let a = s.access_counts(false);
        let shape = LayerShape::full(&l, 4);
        assert!(a.dram[0] >= 4 * 16 * shape.xi() * shape.yi());
        assert!(a.dram[1] >= 4 * 32 * 14 * 14);
        assert!(a.dram[2] >= 32 * 16 * 9);
    }

    #[test]
    fn bigger_gbuf_block_reduces_dram_traffic() {
        let l = Layer::conv("c", 64, 64, 28, 3, 1);
        let mut s1 = scheme(&l, 8);
        s1.gbuf.qty = Qty::new(1, 8, 8);
        let mut s2 = scheme(&l, 8);
        s2.gbuf.qty = Qty::new(2, 32, 32);
        let d1 = s1.access_counts(false).dram_total();
        let d2 = s2.access_counts(false).dram_total();
        assert!(d2 < d1, "{d2} !< {d1}");
    }

    #[test]
    fn pipelined_ifm_moves_to_noc() {
        let l = Layer::conv("c", 16, 32, 14, 3, 1);
        let s = scheme(&l, 4);
        let off = s.access_counts(false);
        let on = s.access_counts(true);
        assert_eq!(on.dram[0], 0);
        assert!(on.dram_total() < off.dram_total());
        // NoC picks up the forwarded volume but at shorter distance.
        assert!(on.noc_word_hops > 0.0);
    }

    #[test]
    fn macs_invariant_to_blocking() {
        let l = Layer::conv("c", 32, 32, 28, 3, 1);
        let mut s1 = scheme(&l, 4);
        let mut s2 = scheme(&l, 4);
        s1.gbuf.qty = Qty::new(1, 4, 4);
        s2.gbuf.qty = Qty::new(4, 32, 32);
        assert_eq!(s1.access_counts(false).macs, s2.access_counts(false).macs);
        assert_eq!(s1.access_counts(false).macs, l.macs(4));
    }

    #[test]
    fn buffer_sharing_cuts_dram_adds_noc() {
        let arch = presets::multi_node_eyeriss();
        let l = Layer::conv("c", 64, 64, 28, 3, 1);
        let batch = 8;
        let mk = |share: bool| {
            let part = PartitionScheme {
                region: (2, 2),
                pk: 4,
                share_ifm: share,
                ..PartitionScheme::single()
            };
            let unit = UnitMap::build(&arch, part.node_shape(&l, batch));
            LayerScheme {
                part,
                unit,
                regf: LevelBlock { qty: Qty::new(1, 2, 2), order: LoopOrder([Grp::B, Grp::K, Grp::C]) },
                gbuf: LevelBlock { qty: Qty::new(2, 16, 16), order: LoopOrder([Grp::B, Grp::C, Grp::K]) },
            }
        };
        let plain = mk(false).access_counts(false);
        let shared = mk(true).access_counts(false);
        assert!(shared.dram[0] < plain.dram[0]);
        assert!(shared.noc_word_hops > plain.noc_word_hops * 0.5);
        // Sharing also shrinks the per-node GBUF footprint.
        assert!(mk(true).gbuf_words_per_node() < mk(false).gbuf_words_per_node());
    }

    #[test]
    fn reduction_partition_reduces_dram_ofm() {
        let arch = presets::multi_node_eyeriss();
        let l = Layer::conv("c", 256, 64, 14, 3, 1);
        let batch = 4;
        let mk = |pc: u64, pk: u64| {
            let part = PartitionScheme { region: (2, 2), pc, pk, ..PartitionScheme::single() };
            let unit = UnitMap::build(&arch, part.node_shape(&l, batch));
            LayerScheme {
                part,
                unit,
                regf: LevelBlock { qty: Qty::new(1, 2, 2), order: LoopOrder([Grp::B, Grp::K, Grp::C]) },
                gbuf: LevelBlock { qty: Qty::new(1, 8, 8), order: LoopOrder([Grp::B, Grp::C, Grp::K]) },
            }
        };
        let with_red = mk(4, 1).access_counts(false);
        // reduction adds NoC traffic
        assert!(with_red.noc_word_hops > 0.0);
        // and its DRAM ofm volume is the reduced single copy
        let no_red = mk(1, 4).access_counts(false);
        assert!(with_red.dram[1] <= no_red.dram[1] * 4);
    }

    #[test]
    fn partition_floor_dominated_by_every_blocking() {
        // The gq-independent partition floor lower-bounds every stream of
        // every (gq, go, rq, ro) completion — the per-component property
        // the cost-level admissibility of `StagedEval::bound_partition`
        // rests on (energy/latency assembly is monotone in each stream).
        let arch = presets::multi_node_eyeriss();
        let l = Layer::conv("c", 64, 64, 28, 3, 1);
        let part = PartitionScheme { region: (2, 2), pk: 2, pn: 2, ..PartitionScheme::single() };
        let unit = UnitMap::build(&arch, part.node_shape(&l, 8));
        let pa = PartAccess::new(part, unit);
        for ifm_on_chip in [false, true] {
            let floor = pa.partition_floor(ifm_on_chip);
            for gq in [Qty::new(1, 2, 2), Qty::new(2, 8, 16), unit.totals] {
                for go in LoopOrder::all() {
                    let g = pa.gbuf(gq, go, ifm_on_chip);
                    for rq in [Qty::new(1, 1, 1), Qty::new(1, 2, 2), gq] {
                        for ro in LoopOrder::all() {
                            let c = g.counts(rq, ro);
                            for t in 0..3 {
                                assert!(floor.dram[t] <= c.dram[t], "dram[{t}]");
                                assert!(floor.gbuf[t] <= c.gbuf[t], "gbuf[{t}]");
                            }
                            assert!(floor.gbuf_regf_side <= c.gbuf_regf_side);
                            assert!(floor.regf <= c.regf);
                            assert!(floor.noc_word_hops <= c.noc_word_hops + 1e-9);
                            assert_eq!(floor.macs, c.macs);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn gbuf_sees_both_sides() {
        let l = Layer::conv("c", 16, 32, 14, 3, 1);
        let s = scheme(&l, 4);
        let a = s.access_counts(false);
        // GBUF traffic >= DRAM traffic (everything passes through) and
        // >= the REGF-side drain volume alone.
        assert!(a.gbuf_total() >= a.dram_total());
    }
}
