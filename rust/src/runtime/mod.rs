//! Runtime integration for the AOT-compiled JAX/Pallas artifacts.
//!
//! The PJRT execution path (loading HLO text, compiling through
//! xla_extension, running the batched cost kernel and the surrogate MLP on
//! device) lives in the `pjrt` submodule behind the `pjrt` cargo feature,
//! compiled against the `xla` + `anyhow` path dependencies under
//! `rust/vendor/` — API stubs as shipped (so `cargo check --features
//! pjrt` gates the surface offline), real bindings when vendored in. The
//! default build ships only the artifact/interchange metadata below;
//! every consumer (the ML baseline, the benches) falls back to the
//! bit-compatible native Rust implementations (`solvers::ml::NativeMlp`,
//! `cost::cost_from_features`).
//!
//! Artifacts (see `python/compile/aot.py`):
//! * `cost_batch.hlo.txt` — batched KAPLA cost model (Layer-1 Pallas kernel);
//! * `surrogate_infer.hlo.txt` — surrogate MLP forward;
//! * `surrogate_train.hlo.txt` — surrogate MLP SGD step (fwd+bwd through
//!   the Pallas matmul custom_vjp).
//!
//! The interchange format is HLO *text*: jax >= 0.5 emits protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{BatchCostEvaluator, PjrtSurrogate, Runtime};

use std::path::PathBuf;

use crate::arch::{energy as earch, ArchConfig};

/// Static artifact shapes — keep in sync with `python/compile/model.py`.
pub const COST_BATCH: usize = 256;
pub const INFER_BATCH: usize = 128;
pub const TRAIN_BATCH: usize = 64;

/// Default artifact directory (relative to the repo root / cwd).
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("KAPLA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// True if the AOT artifacts are present (tests skip gracefully if not).
pub fn artifacts_available() -> bool {
    let d = default_artifact_dir();
    ["cost_batch.hlo.txt", "surrogate_infer.hlo.txt", "surrogate_train.hlo.txt"]
        .iter()
        .all(|f| d.join(f).exists())
}

/// True when the PJRT execution path is compiled in.
pub fn pjrt_enabled() -> bool {
    cfg!(feature = "pjrt")
}

/// The arch-parameter vector consumed by the batched cost kernel
/// (layout mirrored in `python/compile/kernels/ref.py::NUM_PARAMS`).
pub fn cost_params(arch: &ArchConfig) -> [f32; 5] {
    [
        arch.mac_pj as f32,
        arch.dram.pj_per_word as f32,
        arch.noc_pj_per_word(1.0) as f32,
        earch::pe_bus_pj_per_word() as f32,
        arch.dram_words_per_cycle() as f32,
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;

    #[test]
    fn cost_params_layout_is_stable() {
        let arch = presets::multi_node_eyeriss();
        let p = cost_params(&arch);
        assert_eq!(p.len(), 5);
        assert!((p[0] - 1.0).abs() < 1e-6); // 1 pJ MAC
        assert!((p[1] - 200.0).abs() < 1e-6); // DRAM pJ/word
        assert!((p[4] - 25.6).abs() < 1e-6); // DRAM words/cycle
    }

    #[test]
    fn artifact_dir_respects_env_shape() {
        // Without the env var the default is the relative `artifacts` dir.
        if std::env::var_os("KAPLA_ARTIFACTS").is_none() {
            assert_eq!(default_artifact_dir(), PathBuf::from("artifacts"));
        }
    }

    #[test]
    fn default_build_reports_pjrt_state() {
        // The dependency-free default build compiles the stub surface only.
        assert_eq!(pjrt_enabled(), cfg!(feature = "pjrt"));
    }
}
