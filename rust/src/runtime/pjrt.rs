//! PJRT-backed execution of the AOT artifacts (feature `pjrt`).
//!
//! This module is only compiled with `--features pjrt`, against the `xla`
//! (xla_extension bindings) and `anyhow` path dependencies under
//! `rust/vendor/`. As shipped those are *API stubs* — this module
//! type-checks (CI gates it with `cargo check --features pjrt`) and every
//! runtime entry returns a clear "not vendored" error; replace the stubs
//! with the real vendored crates to execute the artifacts.

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

use super::{default_artifact_dir, COST_BATCH, INFER_BATCH, TRAIN_BATCH};
use crate::cost::{CostEstimate, NUM_FEATURES, SCHEME_FEATURES};
use crate::solvers::ml::{CostPredictor, NativeMlp, HIDDEN};

fn load_executable(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .with_context(|| format!("loading HLO text {}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client.compile(&comp).with_context(|| format!("compiling {}", path.display()))
}

/// Batched cost evaluation through the AOT kernel.
pub struct BatchCostEvaluator {
    exe: xla::PjRtLoadedExecutable,
}

impl BatchCostEvaluator {
    pub fn load(client: &xla::PjRtClient, dir: &Path) -> Result<BatchCostEvaluator> {
        Ok(BatchCostEvaluator { exe: load_executable(client, &dir.join("cost_batch.hlo.txt"))? })
    }

    /// Evaluate a batch of feature vectors; pads/chunks to the artifact's
    /// static batch size.
    pub fn eval(
        &self,
        feats: &[[f64; NUM_FEATURES]],
        params: [f32; 5],
    ) -> Result<Vec<CostEstimate>> {
        let mut out = Vec::with_capacity(feats.len());
        for chunk in feats.chunks(COST_BATCH) {
            let mut buf = vec![0f32; COST_BATCH * NUM_FEATURES];
            for (r, f) in chunk.iter().enumerate() {
                for (c, &v) in f.iter().enumerate() {
                    buf[r * NUM_FEATURES + c] = v as f32;
                }
            }
            let x = xla::Literal::vec1(&buf).reshape(&[COST_BATCH as i64, NUM_FEATURES as i64])?;
            let p = xla::Literal::vec1(&params);
            let res = self.exe.execute::<xla::Literal>(&[x, p])?[0][0].to_literal_sync()?;
            let tuple = res.to_tuple1()?;
            let vals = tuple.to_vec::<f32>()?; // [COST_BATCH, 2] row major
            for r in 0..chunk.len() {
                out.push(CostEstimate {
                    energy_pj: vals[r * 2] as f64,
                    latency_cycles: vals[r * 2 + 1] as f64,
                });
            }
        }
        Ok(out)
    }
}

/// The surrogate MLP executed through PJRT. Parameter buffers are owned on
/// the Rust side (initialized identically to `NativeMlp`), so the native
/// and PJRT implementations are numerically comparable.
pub struct PjrtSurrogate {
    infer: xla::PjRtLoadedExecutable,
    train: xla::PjRtLoadedExecutable,
    // Parameters in python layout: w1 [F,H] row-major, b1 [H], w2 [H,1], b2 [1].
    w1: Vec<f32>,
    b1: Vec<f32>,
    w2: Vec<f32>,
    b2: Vec<f32>,
}

impl PjrtSurrogate {
    pub fn load(client: &xla::PjRtClient, dir: &Path, seed: u64) -> Result<PjrtSurrogate> {
        let native = NativeMlp::new(seed);
        let mut s = PjrtSurrogate {
            infer: load_executable(client, &dir.join("surrogate_infer.hlo.txt"))?,
            train: load_executable(client, &dir.join("surrogate_train.hlo.txt"))?,
            w1: vec![0.0; SCHEME_FEATURES * HIDDEN],
            b1: vec![0.0; HIDDEN],
            w2: vec![0.0; HIDDEN],
            b2: vec![0.0; 1],
        };
        s.set_params_from_native(&native);
        Ok(s)
    }

    /// Copy parameters from a native MLP (rust layout w1[j*F+i] ->
    /// python layout w1[i*H+j]).
    pub fn set_params_from_native(&mut self, m: &NativeMlp) {
        let f = SCHEME_FEATURES;
        for j in 0..HIDDEN {
            for i in 0..f {
                self.w1[i * HIDDEN + j] = m.w1[j * f + i] as f32;
            }
            self.b1[j] = m.b1[j] as f32;
            self.w2[j] = m.w2[j] as f32;
        }
        self.b2[0] = m.b2 as f32;
    }

    fn param_literals(&self) -> Result<[xla::Literal; 4]> {
        Ok([
            xla::Literal::vec1(&self.w1).reshape(&[SCHEME_FEATURES as i64, HIDDEN as i64])?,
            xla::Literal::vec1(&self.b1),
            xla::Literal::vec1(&self.w2).reshape(&[HIDDEN as i64, 1])?,
            xla::Literal::vec1(&self.b2),
        ])
    }

    fn feats_literal(
        &self,
        feats: &[[f64; SCHEME_FEATURES]],
        rows: usize,
    ) -> Result<xla::Literal> {
        let mut buf = vec![0f32; rows * SCHEME_FEATURES];
        for r in 0..rows {
            // Cyclic padding keeps batch statistics meaningful.
            let src = &feats[r % feats.len()];
            for (c, &v) in src.iter().enumerate() {
                buf[r * SCHEME_FEATURES + c] = v as f32;
            }
        }
        Ok(xla::Literal::vec1(&buf).reshape(&[rows as i64, SCHEME_FEATURES as i64])?)
    }
}

impl CostPredictor for PjrtSurrogate {
    fn predict(&mut self, feats: &[[f64; SCHEME_FEATURES]]) -> Vec<f64> {
        let mut out = Vec::with_capacity(feats.len());
        for chunk in feats.chunks(INFER_BATCH) {
            let run = || -> Result<Vec<f32>> {
                let [w1, b1, w2, b2] = self.param_literals()?;
                let x = self.feats_literal(chunk, INFER_BATCH)?;
                let res = self.infer.execute::<xla::Literal>(&[w1, b1, w2, b2, x])?[0][0]
                    .to_literal_sync()?;
                Ok(res.to_tuple1()?.to_vec::<f32>()?)
            };
            let vals = run().expect("surrogate inference failed");
            out.extend(vals.iter().take(chunk.len()).map(|&v| v as f64));
        }
        out
    }

    fn train_step(&mut self, feats: &[[f64; SCHEME_FEATURES]], targets: &[f64]) -> f64 {
        assert_eq!(feats.len(), targets.len());
        if feats.is_empty() {
            return 0.0;
        }
        let mut run = || -> Result<f64> {
            let [w1, b1, w2, b2] = self.param_literals()?;
            let x = self.feats_literal(feats, TRAIN_BATCH)?;
            let mut ybuf = vec![0f32; TRAIN_BATCH];
            for (r, y) in ybuf.iter_mut().enumerate() {
                *y = targets[r % targets.len()] as f32;
            }
            let y = xla::Literal::vec1(&ybuf);
            let res = self.train.execute::<xla::Literal>(&[w1, b1, w2, b2, x, y])?[0][0]
                .to_literal_sync()?;
            let outs = res.to_tuple()?;
            anyhow::ensure!(outs.len() == 5, "train step returned {} outputs", outs.len());
            let mut it = outs.into_iter();
            self.w1 = it.next().unwrap().to_vec::<f32>()?;
            self.b1 = it.next().unwrap().to_vec::<f32>()?;
            self.w2 = it.next().unwrap().to_vec::<f32>()?;
            self.b2 = it.next().unwrap().to_vec::<f32>()?;
            let loss = it.next().unwrap().to_vec::<f32>()?;
            Ok(loss[0] as f64)
        };
        run().expect("surrogate train step failed")
    }
}

/// Bundle of the PJRT client + artifact directory.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub dir: PathBuf,
}

impl Runtime {
    /// Create a CPU PJRT client over the default artifact directory.
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime { client: xla::PjRtClient::cpu()?, dir: default_artifact_dir() })
    }

    pub fn cost_evaluator(&self) -> Result<BatchCostEvaluator> {
        BatchCostEvaluator::load(&self.client, &self.dir)
    }

    pub fn surrogate(&self, seed: u64) -> Result<PjrtSurrogate> {
        PjrtSurrogate::load(&self.client, &self.dir, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::cost::{cost_from_features, features, LayerCtx};
    use crate::runtime::{artifacts_available, cost_params};
    use crate::workloads::nets;

    fn skip() -> bool {
        if !artifacts_available() {
            eprintln!("skipping runtime test: artifacts/ missing (run `make artifacts`)");
            return true;
        }
        false
    }

    #[test]
    fn cost_kernel_matches_rust_formula() {
        if skip() {
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let eval = rt.cost_evaluator().unwrap();
        let arch = presets::multi_node_eyeriss();
        let net = nets::alexnet();
        let mut feats = Vec::new();
        let mut expect = Vec::new();
        for (i, l) in net.layers.iter().enumerate() {
            let ctx = LayerCtx {
                nodes: 16 + i as u64,
                round_batch: 4,
                rounds: 2,
                ifm_on_chip: i % 2 == 0,
                ofm_on_chip: i % 3 == 0,
                dram_hops: 2.0,
            };
            let f = features(&arch, l, &ctx);
            expect.push(cost_from_features(&arch, &f));
            feats.push(f);
        }
        let got = eval.eval(&feats, cost_params(&arch)).unwrap();
        assert_eq!(got.len(), expect.len());
        for (g, e) in got.iter().zip(&expect) {
            let rel = (g.energy_pj - e.energy_pj).abs() / e.energy_pj.max(1.0);
            assert!(rel < 1e-4, "energy {} vs {}", g.energy_pj, e.energy_pj);
            let rel = (g.latency_cycles - e.latency_cycles).abs() / e.latency_cycles.max(1.0);
            assert!(rel < 1e-4, "latency {} vs {}", g.latency_cycles, e.latency_cycles);
        }
    }

    #[test]
    fn surrogate_parity_with_native() {
        if skip() {
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let mut pjrt = rt.surrogate(42).unwrap();
        let mut native = NativeMlp::new(42);

        let mut rng = crate::util::SplitMix64::new(9);
        let feats: Vec<[f64; SCHEME_FEATURES]> = (0..INFER_BATCH)
            .map(|_| {
                let mut f = [0.0; SCHEME_FEATURES];
                for v in f.iter_mut() {
                    *v = rng.f64() * 4.0 - 2.0;
                }
                f
            })
            .collect();

        let pn = native.predict(&feats);
        let pp = pjrt.predict(&feats);
        for (a, b) in pn.iter().zip(&pp) {
            assert!((a - b).abs() < 1e-3 * (1.0 + a.abs()), "native {a} vs pjrt {b}");
        }
    }

    #[test]
    fn surrogate_train_step_parity() {
        if skip() {
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let mut pjrt = rt.surrogate(7).unwrap();
        let mut native = NativeMlp::new(7);

        let mut rng = crate::util::SplitMix64::new(13);
        let feats: Vec<[f64; SCHEME_FEATURES]> = (0..TRAIN_BATCH)
            .map(|_| {
                let mut f = [0.0; SCHEME_FEATURES];
                for v in f.iter_mut() {
                    *v = rng.f64();
                }
                f
            })
            .collect();
        let targets: Vec<f64> = (0..TRAIN_BATCH).map(|_| rng.f64() * 2.0).collect();

        let ln = native.train_step(&feats, &targets);
        let lp = pjrt.train_step(&feats, &targets);
        assert!((ln - lp).abs() < 1e-3 * (1.0 + ln.abs()), "loss native {ln} vs pjrt {lp}");

        // Predictions after one step still agree.
        let pn = native.predict(&feats);
        let pp = pjrt.predict(&feats);
        for (a, b) in pn.iter().zip(&pp).take(8) {
            assert!((a - b).abs() < 5e-3 * (1.0 + a.abs()), "post-step native {a} vs pjrt {b}");
        }
    }

    #[test]
    fn surrogate_learns_through_pjrt() {
        if skip() {
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let mut s = rt.surrogate(3).unwrap();
        let mut rng = crate::util::SplitMix64::new(5);
        let feats: Vec<[f64; SCHEME_FEATURES]> = (0..TRAIN_BATCH)
            .map(|_| {
                let mut f = [0.0; SCHEME_FEATURES];
                for v in f.iter_mut() {
                    *v = rng.f64();
                }
                f
            })
            .collect();
        let targets: Vec<f64> = feats.iter().map(|f| 2.0 * f[0] + 0.5 * f[3] + 1.0).collect();
        let first = s.train_step(&feats, &targets);
        let mut last = first;
        for _ in 0..200 {
            last = s.train_step(&feats, &targets);
        }
        assert!(last < first * 0.2, "PJRT training loss {first} -> {last}");
    }
}
