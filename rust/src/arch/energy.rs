//! Per-access SRAM energy model (McPAT-substitute, see DESIGN.md
//! Substitutions).
//!
//! The paper models register files and SRAM buffers of different sizes with
//! McPAT 1.3 at 28 nm. We cannot ship McPAT, so we fit the well-published
//! Eyeriss energy hierarchy (normalized to a 1 pJ 16-bit MAC):
//!
//!   REGF (0.5 kB)   ~ 1x MAC
//!   inter-PE bus    ~ 2x
//!   GBUF (100 kB)   ~ 6x
//!   DRAM            ~ 200x
//!
//! and scale SRAM access energy with the square root of capacity (wordline/
//! bitline growth), which matches the McPAT trend across the 32 B – 512 kB
//! range used in the paper's Table V sweep.

/// Reference points for the sqrt-capacity fit.
const REGF_REF_BYTES: f64 = 512.0;
const REGF_REF_PJ: f64 = 1.0;
const GBUF_REF_BYTES: f64 = 100.0 * 1024.0;
const GBUF_REF_PJ: f64 = 6.0;

/// Per-word (16-bit) access energy of a register file of `bytes` capacity.
pub fn regf_pj_per_word(bytes: u64) -> f64 {
    // Floor at 0.03 pJ: even a tiny latch-based file pays wire + mux energy.
    (REGF_REF_PJ * ((bytes as f64) / REGF_REF_BYTES).sqrt()).max(0.03)
}

/// Per-word access energy of an SRAM global buffer of `bytes` capacity.
pub fn gbuf_pj_per_word(bytes: u64) -> f64 {
    (GBUF_REF_PJ * ((bytes as f64) / GBUF_REF_BYTES).sqrt()).max(0.5)
}

/// Per-word DRAM access energy. LPDDR4 at ~28 nm host: the paper models the
/// Micron datasheet; the Eyeriss-normalized figure is ~200x a MAC.
pub fn dram_pj_per_word() -> f64 {
    200.0
}

/// Per-word energy of the intra-node PE-array bus (multicast network).
pub fn pe_bus_pj_per_word() -> f64 {
    2.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eyeriss_reference_points() {
        assert!((regf_pj_per_word(512) - 1.0).abs() < 1e-12);
        assert!((gbuf_pj_per_word(100 * 1024) - 6.0).abs() < 1e-12);
    }

    #[test]
    fn monotone_in_capacity() {
        let caps = [32u64, 64, 128, 512, 4096];
        for w in caps.windows(2) {
            assert!(regf_pj_per_word(w[0]) <= regf_pj_per_word(w[1]));
            assert!(gbuf_pj_per_word(w[0] * 1024) <= gbuf_pj_per_word(w[1] * 1024));
        }
    }

    #[test]
    fn hierarchy_ordering_holds() {
        // REGF < bus < GBUF < DRAM for the paper's large config sizes.
        let regf = regf_pj_per_word(64);
        let gbuf = gbuf_pj_per_word(32 * 1024);
        assert!(regf < pe_bus_pj_per_word());
        assert!(pe_bus_pj_per_word() < gbuf);
        assert!(gbuf < dram_pj_per_word());
    }

    #[test]
    fn sqrt_scaling() {
        let e1 = gbuf_pj_per_word(64 * 1024);
        let e4 = gbuf_pj_per_word(256 * 1024);
        assert!((e4 / e1 - 2.0).abs() < 1e-9);
    }
}
