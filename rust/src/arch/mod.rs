//! Hardware architecture templates (paper §III-C, Fig. 1 and Fig. 4).
//!
//! A scalable NN accelerator is a 2D mesh of *nodes* connected by a NoC and
//! to off-chip DRAM. Each node has a global buffer (GBUF) and a 2D array of
//! PEs, each PE with a register file (REGF). Every memory level carries a
//! capacity, a bandwidth, and a per-word access cost, plus a flag for
//! same-level (neighbour) transfers which enables systolic flows at the PE
//! level and buffer sharing at the node level.

pub mod energy;
pub mod presets;

pub use presets::*;

/// PE-array dataflow the lowest (REGF) level is constrained to
/// (paper §III-C: "most hardware architectures require specific dataflow
/// across the on-chip PEs").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PeDataflow {
    /// Eyeriss-like row stationary: 1D conv rows per PE, filter rows ×
    /// fmap rows across the array, neighbour (same-level) psum transfer.
    RowStationary,
    /// TPU-like weight-stationary systolic array: inputs flow left→right,
    /// partial sums top→bottom; same-level transfers on both axes.
    Systolic,
}

/// One level of the memory hierarchy.
#[derive(Debug, Clone)]
pub struct MemLevel {
    pub name: &'static str,
    /// Capacity in bytes of a single instance of this buffer.
    pub bytes: u64,
    /// Per-word (16-bit) access energy in pJ.
    pub pj_per_word: f64,
    /// Words per cycle an instance can sustain.
    pub words_per_cycle: f64,
    /// Whether hardware supports fetching from a neighbour instance at the
    /// same level (systolic / buffer sharing), paper §III-C.
    pub same_level_transfer: bool,
}

/// Complete hardware configuration (the template of Fig. 4).
#[derive(Debug, Clone)]
pub struct ArchConfig {
    pub name: &'static str,
    /// Node mesh dimensions (nodes_x, nodes_y).
    pub nodes: (u64, u64),
    /// PE array dimensions per node (pes_x, pes_y).
    pub pes: (u64, u64),
    /// Register file per PE.
    pub regf: MemLevel,
    /// Global buffer per node.
    pub gbuf: MemLevel,
    /// Off-chip DRAM.
    pub dram: MemLevel,
    /// Bytes per data word (16-bit => 2).
    pub word_bytes: u64,
    /// Logic frequency in Hz.
    pub freq_hz: f64,
    /// Total DRAM bandwidth in bytes/s (shared by all nodes).
    pub dram_bw_bytes_per_s: f64,
    /// NoC energy per bit per hop in pJ (paper: 0.61 pJ/bit/hop).
    pub noc_pj_per_bit_hop: f64,
    /// NoC link bandwidth in words/cycle per node port.
    pub noc_words_per_cycle: f64,
    /// Energy of one 16-bit MAC in pJ (paper: 1 pJ).
    pub mac_pj: f64,
    /// PE-array dataflow constraint.
    pub pe_dataflow: PeDataflow,
    /// Enable temporal inter-layer dataflow (segment slicing).
    pub temporal_layer_pipe: bool,
    /// Enable spatial inter-layer dataflow (layer pipelining).
    pub spatial_layer_pipe: bool,
}

impl ArchConfig {
    /// Total node count.
    pub fn num_nodes(&self) -> u64 {
        self.nodes.0 * self.nodes.1
    }

    /// PEs per node.
    pub fn pes_per_node(&self) -> u64 {
        self.pes.0 * self.pes.1
    }

    /// Total PE count across the accelerator.
    pub fn total_pes(&self) -> u64 {
        self.num_nodes() * self.pes_per_node()
    }

    /// REGF capacity in 16-bit words.
    pub fn regf_words(&self) -> u64 {
        self.regf.bytes / self.word_bytes
    }

    /// GBUF capacity in words.
    pub fn gbuf_words(&self) -> u64 {
        self.gbuf.bytes / self.word_bytes
    }

    /// Aggregate on-chip SRAM in bytes (sanity metric; the paper's large
    /// config totals 8 MB).
    pub fn total_sram_bytes(&self) -> u64 {
        self.num_nodes() * (self.gbuf.bytes + self.pes_per_node() * self.regf.bytes)
    }

    /// NoC energy to move one word over `hops` mesh hops.
    pub fn noc_pj_per_word(&self, hops: f64) -> f64 {
        self.noc_pj_per_bit_hop * (self.word_bytes * 8) as f64 * hops
    }

    /// DRAM bandwidth expressed in words per cycle (whole chip).
    pub fn dram_words_per_cycle(&self) -> f64 {
        self.dram_bw_bytes_per_s / self.freq_hz / self.word_bytes as f64
    }

    /// Peak MACs/cycle of a node region holding `nodes` nodes.
    pub fn peak_macs_per_cycle(&self, nodes: u64) -> f64 {
        (nodes * self.pes_per_node()) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_large_config_totals() {
        let a = presets::multi_node_eyeriss();
        assert_eq!(a.num_nodes(), 256);
        assert_eq!(a.pes_per_node(), 64);
        assert_eq!(a.total_pes(), 16384);
        // 256 nodes x 32 kB = 8 MB GBUF SRAM (paper: "8 MB on-chip SRAM")
        assert_eq!(a.num_nodes() * a.gbuf.bytes, 8 * 1024 * 1024);
        assert_eq!(a.regf.bytes, 64);
        assert_eq!(a.word_bytes, 2);
    }

    #[test]
    fn edge_config_matches_paper() {
        let a = presets::edge_tpu();
        assert_eq!(a.num_nodes(), 1);
        assert_eq!(a.pes, (16, 16));
        assert_eq!(a.regf.bytes, 512);
        assert_eq!(a.gbuf.bytes, 256 * 1024);
        assert_eq!(a.pe_dataflow, PeDataflow::Systolic);
    }

    #[test]
    fn word_capacities() {
        let a = presets::multi_node_eyeriss();
        assert_eq!(a.regf_words(), 32);
        assert_eq!(a.gbuf_words(), 16 * 1024);
    }

    #[test]
    fn noc_word_energy_scales_with_hops() {
        let a = presets::multi_node_eyeriss();
        let e1 = a.noc_pj_per_word(1.0);
        let e3 = a.noc_pj_per_word(3.0);
        assert!((e3 / e1 - 3.0).abs() < 1e-12);
        // 0.61 pJ/bit * 16 bits = 9.76 pJ per word-hop
        assert!((e1 - 9.76).abs() < 1e-9);
    }

    #[test]
    fn dram_words_per_cycle_reasonable() {
        let a = presets::multi_node_eyeriss();
        // 25.6 GB/s at 500 MHz, 2 B/word => 25.6 words/cycle
        assert!((a.dram_words_per_cycle() - 25.6).abs() < 1e-9);
    }
}
