//! Concrete hardware configurations used in the paper's evaluation (§V) and
//! the Table V sweep, plus the scaled "bench" configs used so that the
//! exhaustive baseline stays feasible in CI (see DESIGN.md Substitutions).

use super::energy;
use super::{ArchConfig, MemLevel, PeDataflow};

fn mem(name: &'static str, bytes: u64, pj: f64, wpc: f64, same_level: bool) -> MemLevel {
    MemLevel { name, bytes, pj_per_word: pj, words_per_cycle: wpc, same_level_transfer: same_level }
}

/// Build a multi-node Eyeriss-like configuration with the given mesh, PE
/// array, and buffer sizes. Used by the Table V hardware sweep.
pub fn eyeriss_like(
    nodes: (u64, u64),
    pes: (u64, u64),
    regf_bytes: u64,
    gbuf_bytes: u64,
) -> ArchConfig {
    ArchConfig {
        name: "eyeriss-like",
        nodes,
        pes,
        regf: mem("REGF", regf_bytes, energy::regf_pj_per_word(regf_bytes), 2.0, true),
        gbuf: mem("GBUF", gbuf_bytes, energy::gbuf_pj_per_word(gbuf_bytes), 8.0, true),
        dram: mem("DRAM", u64::MAX, energy::dram_pj_per_word(), 25.6, false),
        word_bytes: 2,
        freq_hz: 500e6,
        dram_bw_bytes_per_s: 25.6e9,
        noc_pj_per_bit_hop: 0.61,
        noc_words_per_cycle: 4.0,
        mac_pj: 1.0,
        pe_dataflow: PeDataflow::RowStationary,
        temporal_layer_pipe: true,
        spatial_layer_pipe: true,
    }
}

/// The paper's large multi-node accelerator (§V): 16x16 nodes, 8x8 PEs per
/// node, 64 B REGF per PE, 32 kB GBUF per node, row-stationary PE arrays.
pub fn multi_node_eyeriss() -> ArchConfig {
    let mut a = eyeriss_like((16, 16), (8, 8), 64, 32 * 1024);
    a.name = "multi-node-eyeriss-16x16";
    a
}

/// Scaled-down multi-node config for benches/tests where the exhaustive
/// baseline must terminate in seconds rather than hours: 4x4 nodes, same
/// node internals as the paper config.
pub fn bench_multi_node() -> ArchConfig {
    let mut a = eyeriss_like((4, 4), (8, 8), 64, 32 * 1024);
    a.name = "bench-multi-node-4x4";
    a
}

/// The paper's small edge inference device (§V): single node, 16x16 PE
/// systolic array (TPU-like), 512 B registers per PE, 256 kB global buffer.
pub fn edge_tpu() -> ArchConfig {
    ArchConfig {
        name: "edge-tpu-16x16pe",
        nodes: (1, 1),
        pes: (16, 16),
        regf: mem("REGF", 512, energy::regf_pj_per_word(512), 2.0, true),
        gbuf: mem("GBUF", 256 * 1024, energy::gbuf_pj_per_word(256 * 1024), 8.0, false),
        dram: mem("DRAM", u64::MAX, energy::dram_pj_per_word(), 12.8, false),
        word_bytes: 2,
        freq_hz: 500e6,
        dram_bw_bytes_per_s: 12.8e9,
        noc_pj_per_bit_hop: 0.61,
        noc_words_per_cycle: 4.0,
        mac_pj: 1.0,
        pe_dataflow: PeDataflow::Systolic,
        temporal_layer_pipe: true,
        // Single node: no spatial layer pipelining possible.
        spatial_layer_pipe: false,
    }
}

/// The Table V sweep rows: (batch, nodes, pes, gbuf, regf) per the paper.
pub fn table5_configs() -> Vec<(u64, ArchConfig)> {
    let rows: [(u64, (u64, u64), (u64, u64), u64, u64); 5] = [
        (64, (4, 4), (8, 8), 32 * 1024, 32),
        (64, (4, 4), (8, 8), 32 * 1024, 64),
        (64, (4, 4), (8, 8), 32 * 1024, 128),
        (8, (4, 4), (16, 16), 32 * 1024, 32),
        (1, (16, 16), (8, 8), 32 * 1024, 64),
    ];
    rows.iter()
        .map(|&(batch, nodes, pes, gbuf, regf)| (batch, eyeriss_like(nodes, pes, regf, gbuf)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_valid() {
        for a in [multi_node_eyeriss(), bench_multi_node(), edge_tpu()] {
            assert!(a.num_nodes() >= 1);
            assert!(a.pes_per_node() >= 1);
            assert!(a.regf.bytes >= 2, "{}: regf too small", a.name);
            assert!(a.gbuf.bytes > a.regf.bytes);
            assert!(a.gbuf.pj_per_word > a.regf.pj_per_word);
            assert!(a.dram.pj_per_word > a.gbuf.pj_per_word);
        }
    }

    #[test]
    fn table5_has_five_rows_with_paper_params() {
        let rows = table5_configs();
        assert_eq!(rows.len(), 5);
        assert_eq!(rows[0].0, 64);
        assert_eq!(rows[3].1.pes, (16, 16));
        assert_eq!(rows[4].1.nodes, (16, 16));
        assert_eq!(rows[4].0, 1);
    }

    #[test]
    fn edge_has_no_spatial_pipe() {
        assert!(!edge_tpu().spatial_layer_pipe);
        assert!(multi_node_eyeriss().spatial_layer_pipe);
    }
}
