//! Random-search baseline R (paper §V): "evaluates candidates at each
//! level with a given probability" (Timeloop-style [39]). Each design-space
//! level — node partition, GBUF block, GBUF order, REGF block, REGF order —
//! is independently subsampled with probability `p`; the surviving cross
//! product is evaluated exactly. If the sample contains no valid scheme the
//! layer retries with a fresh sample (the paper found p < 0.1 fails to
//! produce valid schemes; the edge config even needs p = 0.85). Plugs into
//! the exact segment-chain DP via [`super::SolveCtx::run`] with
//! `SolverKind::Random`.

use crate::arch::ArchConfig;
use crate::cost::CostModel;
use crate::directives::{LayerScheme, LevelBlock, LoopOrder, Qty};
use crate::mapping::UnitMap;
use crate::partition::enumerate_partitions;
use crate::util::SplitMix64;
use crate::workloads::Layer;

use super::space::qty_candidates;
use super::{ctx_fingerprint, IntraCtx, IntraSolver};

/// Random-sampling intra-layer solver. Each (layer, context) solve draws
/// from its own RNG stream — `seed` folded with `ctx_fingerprint` — so
/// results do not depend on the order contexts are solved in, and the
/// parallel intra-layer sweep reproduces the sequential schedule exactly.
pub struct RandomIntra {
    /// Per-level keep probability.
    pub p: f64,
    /// Retry budget when a sample has no valid scheme.
    pub retries: usize,
    seed: u64,
    /// Cooperative cancellation, polled at the retry/partition yield
    /// points. A trip returns the best sampled scheme so far (or the
    /// minimal fallback) — anytime semantics. Deliberately *not* part of
    /// [`RandomIntra::fingerprint`]: the token never changes what an
    /// untripped solve returns, and tripped (partial) solves are excluded
    /// from the cross-job argmin memo via `IntraSolver::cancel_token`.
    cancel: crate::util::cancel::CancelToken,
}

impl RandomIntra {
    pub fn new(p: f64, seed: u64) -> RandomIntra {
        RandomIntra { p, retries: 8, seed, cancel: crate::util::cancel::CancelToken::none() }
    }

    pub fn with_cancel(mut self, cancel: crate::util::cancel::CancelToken) -> RandomIntra {
        self.cancel = cancel;
        self
    }
}

fn sample<'a, T>(rng: &mut SplitMix64, xs: &'a [T], p: f64) -> Vec<&'a T> {
    let kept: Vec<&T> = xs.iter().filter(|_| rng.chance(p)).collect();
    if kept.is_empty() && !xs.is_empty() {
        // Always keep at least one candidate so a retry can make progress.
        vec![&xs[rng.below(xs.len() as u64) as usize]]
    } else {
        kept
    }
}

impl IntraSolver for RandomIntra {
    fn name(&self) -> &'static str {
        "random(R)"
    }

    /// Every knob that shapes the sampling stream must key the cross-job
    /// argmin memo: two `RandomIntra` values differing in `p`, `seed` or
    /// the retry budget legitimately return different schemes for the same
    /// context and must never alias.
    fn fingerprint(&self) -> u64 {
        crate::util::fnv1a(self.name().bytes().map(u64::from).chain([
            self.p.to_bits(),
            self.retries as u64,
            self.seed,
        ]))
    }

    fn solve(
        &self,
        arch: &ArchConfig,
        layer: &Layer,
        ctx: &IntraCtx,
        model: &dyn CostModel,
    ) -> Option<LayerScheme> {
        let rng = &mut SplitMix64::new(self.seed ^ ctx_fingerprint(layer, ctx));
        let parts = enumerate_partitions(layer, ctx.rb, ctx.region, false);
        let orders = LoopOrder::all();

        'retry: for _ in 0..self.retries.max(1) {
            let mut best: Option<(f64, LayerScheme)> = None;
            for &part in sample(rng, &parts, self.p) {
                // Cancellation yield point: keep the partial best (anytime)
                // or fall through to the minimal fallback below. Purely an
                // early exit — the sampling stream is untouched while the
                // token stays live.
                if self.cancel.is_cancelled() {
                    if best.is_some() {
                        return best.map(|(_, s)| s);
                    }
                    break 'retry;
                }
                let unit = UnitMap::build(arch, part.node_shape(layer, ctx.rb));
                // Staged scoring: the sampled cross product under one
                // partition shares its stage-1/2 prefix evaluations, and
                // enumeration-unique candidates skip the memo hashing. The
                // sampling stream is untouched, so schedules are identical
                // to the one-shot-evaluated path.
                let staged = model.staged(arch, &part, &unit, ctx.ifm_on_chip);
                let gqs: Vec<Qty> = qty_candidates(unit.totals, unit.granule);
                for &gq in sample(rng, &gqs, self.p) {
                    let mut gbuf_evals: [Option<crate::sim::StagedGbuf>; 6] = [None; 6];
                    let rqs: Vec<Qty> = qty_candidates(gq, unit.granule);
                    for &rq in sample(rng, &rqs, self.p) {
                        for &go in sample(rng, &orders, self.p) {
                            let gi = orders.iter().position(|o| *o == go).unwrap();
                            for &ro in sample(rng, &orders, self.p) {
                                let s = LayerScheme {
                                    part,
                                    unit,
                                    regf: LevelBlock { qty: rq, order: ro },
                                    gbuf: LevelBlock { qty: gq, order: go },
                                };
                                if s.validate(arch).is_err() {
                                    continue;
                                }
                                let est = match &staged {
                                    Some(st) => gbuf_evals[gi]
                                        .get_or_insert_with(|| st.gbuf(gq, go))
                                        .cost(rq, ro),
                                    None => model.evaluate(arch, &s, ctx.ifm_on_chip),
                                };
                                let c = ctx.objective.of(&est);
                                if best.as_ref().map(|(b, _)| c < *b).unwrap_or(true) {
                                    best = Some((c, s));
                                }
                            }
                        }
                    }
                }
            }
            if best.is_some() {
                return best.map(|(_, s)| s);
            }
            if self.cancel.is_cancelled() {
                break;
            }
        }
        // Final fallback: deterministic minimal scheme.
        super::space::minimal_scheme(arch, layer, ctx.region, ctx.rb)
    }

    fn cancel_token(&self) -> Option<&crate::util::cancel::CancelToken> {
        self.cancel.active()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::cost::TieredCost;
    use crate::sim::evaluate_layer;
    use crate::solvers::exhaustive::ExhaustiveIntra;
    use crate::solvers::Objective;
    use crate::workloads::nets;

    fn ctx(region: (u64, u64), rb: u64) -> IntraCtx {
        IntraCtx { region, rb, ifm_on_chip: false, objective: Objective::Energy }
    }

    #[test]
    fn random_always_returns_valid() {
        let arch = presets::bench_multi_node();
        let net = nets::alexnet();
        let solver = RandomIntra::new(0.1, 42);
        let model = TieredCost::fresh();
        for l in net.layers.iter().take(6) {
            let s = solver.solve(&arch, l, &ctx((2, 2), 4), &model).unwrap();
            s.validate(&arch).unwrap();
        }
    }

    #[test]
    fn random_no_better_than_exhaustive() {
        let arch = presets::bench_multi_node();
        let l = crate::workloads::Layer::conv("c", 32, 32, 14, 3, 1);
        let c = ctx((2, 2), 4);
        let ex = ExhaustiveIntra::new(false)
            .solve(&arch, &l, &c, &TieredCost::fresh())
            .unwrap();
        let ee = evaluate_layer(&arch, &ex, false).energy.total();
        for seed in [1u64, 2, 3] {
            let r = RandomIntra::new(0.1, seed).solve(&arch, &l, &c, &TieredCost::fresh()).unwrap();
            let er = evaluate_layer(&arch, &r, false).energy.total();
            assert!(er + 1e-9 >= ee, "seed {seed}: random {er} beat exhaustive {ee}");
        }
    }

    #[test]
    fn higher_p_no_worse_on_average() {
        let arch = presets::bench_multi_node();
        let l = crate::workloads::Layer::conv("c", 64, 64, 28, 3, 1);
        let c = ctx((4, 4), 8);
        let avg = |p: f64| {
            let mut tot = 0.0;
            for seed in 0..5u64 {
                let s =
                    RandomIntra::new(p, seed).solve(&arch, &l, &c, &TieredCost::fresh()).unwrap();
                tot += evaluate_layer(&arch, &s, false).energy.total();
            }
            tot / 5.0
        };
        let lo = avg(0.05);
        let hi = avg(0.5);
        assert!(hi <= lo * 1.05, "p=0.5 avg {hi} much worse than p=0.05 avg {lo}");
    }

    #[test]
    fn deterministic_given_seed() {
        let arch = presets::bench_multi_node();
        let l = crate::workloads::Layer::conv("c", 32, 32, 14, 3, 1);
        let c = ctx((2, 2), 4);
        let a = RandomIntra::new(0.2, 7).solve(&arch, &l, &c, &TieredCost::fresh()).unwrap();
        let b = RandomIntra::new(0.2, 7).solve(&arch, &l, &c, &TieredCost::fresh()).unwrap();
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
    }

    #[test]
    fn staged_scoring_bypasses_the_memo() {
        // The sampled candidates are enumeration-unique per solve: the
        // staged path scores them directly, so a session cache behind the
        // model sees no lookups — while the chosen scheme stays identical.
        use crate::cost::CostCache;
        let arch = presets::bench_multi_node();
        let l = crate::workloads::Layer::conv("c", 32, 32, 14, 3, 1);
        let c = ctx((2, 2), 4);
        let cache = CostCache::new();
        let model = TieredCost::over(&cache);
        let a = RandomIntra::new(0.2, 7).solve(&arch, &l, &c, &model).unwrap();
        assert_eq!(cache.lookups(), 0);
        let b = RandomIntra::new(0.2, 7).solve(&arch, &l, &c, &TieredCost::fresh()).unwrap();
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
    }

    #[test]
    fn solve_order_does_not_change_results() {
        // Per-context RNG streams: solving (l1, l2) or (l2, l1) with the
        // same solver instance yields the same schemes — the property the
        // parallel sweep relies on.
        let arch = presets::bench_multi_node();
        let l1 = crate::workloads::Layer::conv("c", 32, 32, 14, 3, 1);
        let l2 = crate::workloads::Layer::conv("c", 16, 64, 28, 3, 1);
        let c = ctx((2, 2), 4);
        let solver = RandomIntra::new(0.2, 11);
        let a1 = solver.solve(&arch, &l1, &c, &TieredCost::fresh()).unwrap();
        let a2 = solver.solve(&arch, &l2, &c, &TieredCost::fresh()).unwrap();
        let b2 = solver.solve(&arch, &l2, &c, &TieredCost::fresh()).unwrap();
        let b1 = solver.solve(&arch, &l1, &c, &TieredCost::fresh()).unwrap();
        assert_eq!(format!("{a1:?}"), format!("{b1:?}"));
        assert_eq!(format!("{a2:?}"), format!("{b2:?}"));
    }
}
