//! Exhaustive baselines (paper §V):
//!
//! * **B** — nn-dataflow [17]: exhaustive search over the nested-loop
//!   intra-layer space (partitions without the extra directive-only
//!   sharing options), globally optimal within its space.
//! * **S** — exhaustive search over *our directive space*, which adds the
//!   buffer-sharing variants (weights as well as ifm). S matches B and
//!   occasionally beats it slightly, demonstrating the directives'
//!   generality (paper Fig. 7 discussion).
//!
//! Both plug into the exact segment-chain DP via
//! [`super::SolveCtx::run`] with `SolverKind::Baseline` /
//! `SolverKind::DirectiveExhaustive`.

use crate::arch::ArchConfig;
use crate::cost::CostModel;
use crate::directives::LayerScheme;
use crate::workloads::Layer;

use super::space::{visit_schemes_staged, BnbCounters, PartOrder, StagedQuery};
use super::{IntraCtx, IntraSolver};

/// Exhaustive intra-layer solver. The scan runs on the staged
/// branch-and-bound enumeration (`space::visit_schemes_staged`): prefix
/// evaluations are shared across the inner loops and subtrees whose
/// admissible lower bound cannot strictly beat the incumbent are skipped —
/// the returned optimum is provably the full scan's first minimum.
#[derive(Debug, Clone, Copy)]
pub struct ExhaustiveIntra<'a> {
    /// Include buffer-sharing variants (S) or not (B).
    pub with_sharing: bool,
    /// Shared pruning counters (`SolveResult::bnb`); `None` skips the
    /// book-keeping, never the pruning.
    pub stats: Option<&'a BnbCounters>,
    /// Check the partition-level admissible floor before enumerating a
    /// partition's blockings (`DpConfig::part_floor`; on by default, `off`
    /// for triage — the argmin is identical either way, so the solver
    /// fingerprint and the cross-job argmin memo are unaffected).
    pub part_floor: bool,
    /// Partition visiting order (`DpConfig::part_order`). Unlike
    /// `part_floor`, the order can move the *first* minimum onto a
    /// different equal-cost scheme, so it IS folded into the solver
    /// fingerprint — memo entries recorded under one order never answer
    /// queries issued under the other.
    pub part_order: PartOrder,
    /// Cooperative cancellation, polled by the staged scan at its
    /// partition/prefix yield points. A trip returns the scan's current
    /// incumbent — or, with no incumbent yet, the always-valid
    /// `minimal_scheme` fallback — so the surrounding DP still assembles
    /// a (degraded) schedule. Not part of the solver fingerprint: a
    /// cancelled scan's partial argmin is never recorded in the cross-job
    /// memo (see `solve_ctx_memoized`), so the memo only ever holds full
    /// scans.
    pub cancel: Option<&'a crate::util::cancel::CancelToken>,
}

impl Default for ExhaustiveIntra<'_> {
    fn default() -> Self {
        ExhaustiveIntra {
            with_sharing: false,
            stats: None,
            part_floor: true,
            part_order: PartOrder::Floor,
            cancel: None,
        }
    }
}

impl ExhaustiveIntra<'_> {
    pub fn new(with_sharing: bool) -> ExhaustiveIntra<'static> {
        ExhaustiveIntra {
            with_sharing,
            stats: None,
            part_floor: true,
            part_order: PartOrder::Floor,
            cancel: None,
        }
    }
}

impl IntraSolver for ExhaustiveIntra<'_> {
    fn name(&self) -> &'static str {
        if self.with_sharing {
            "exhaustive-directives(S)"
        } else {
            "exhaustive-baseline(B)"
        }
    }

    fn solve(
        &self,
        arch: &ArchConfig,
        layer: &Layer,
        ctx: &IntraCtx,
        model: &dyn CostModel,
    ) -> Option<LayerScheme> {
        let mut q = StagedQuery::for_ctx(arch, layer, ctx, self.with_sharing, model)
            .part_floor(self.part_floor)
            .part_order(self.part_order)
            .cancel(self.cancel);
        if let Some(c) = self.stats {
            q = q.counters(c);
        }
        let mut best: Option<(f64, LayerScheme)> = None;
        visit_schemes_staged(&q, |s, est| {
            let c = ctx.objective.of(est);
            if best.as_ref().map(|(b, _)| c < *b).unwrap_or(true) {
                best = Some((c, *s));
            }
            Some(best.as_ref().map(|(b, _)| *b).unwrap_or(f64::INFINITY))
        });
        best.map(|(_, s)| s).or_else(|| {
            // Anytime fallback: a scan cancelled before its first candidate
            // still hands the DP a valid scheme so the solve completes
            // degraded instead of reporting a spurious "unschedulable".
            if self.cancel.is_some_and(|c| c.is_cancelled()) {
                super::space::minimal_scheme(arch, layer, ctx.region, ctx.rb)
            } else {
                None
            }
        })
    }

    fn fingerprint(&self) -> u64 {
        // The default name-only fingerprint would alias Floor- and
        // Enum-order scans in the cross-job argmin memo; the two return
        // equal-*cost* but potentially different schemes, so the order is
        // part of the search policy and must key the memo. `part_floor`
        // stays unfolded: the floor is admissible, so it provably cannot
        // change the first minimum within a fixed order.
        crate::util::fnv1a(
            self.name().bytes().map(u64::from).chain([self.part_order as u64 + 1]),
        )
    }

    fn cancel_token(&self) -> Option<&crate::util::cancel::CancelToken> {
        self.cancel
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::cost::{CostCache, TieredCost};
    use crate::sim::evaluate_layer;
    use crate::solvers::kapla::solve_intra;
    use crate::solvers::Objective;
    use crate::workloads::nets;

    fn ctx(region: (u64, u64), rb: u64) -> IntraCtx {
        IntraCtx { region, rb, ifm_on_chip: false, objective: Objective::Energy }
    }

    #[test]
    fn exhaustive_finds_valid_optimum() {
        let arch = presets::bench_multi_node();
        let l = crate::workloads::Layer::conv("c", 16, 32, 14, 3, 1);
        let s = ExhaustiveIntra::new(false)
            .solve(&arch, &l, &ctx((2, 2), 4), &TieredCost::fresh())
            .unwrap();
        s.validate(&arch).unwrap();
    }

    #[test]
    fn sharing_space_is_superset() {
        // S (with sharing) can never be worse than B on the same layer.
        // The staged enumeration scores candidates directly (no memo
        // hashing), so the shared cache must stay untouched by either scan.
        let arch = presets::bench_multi_node();
        let l = crate::workloads::Layer::conv("c", 32, 64, 28, 3, 1);
        let c = ctx((4, 4), 8);
        let cache = CostCache::new();
        let model = TieredCost::over(&cache);
        let b = ExhaustiveIntra::new(false).solve(&arch, &l, &c, &model).unwrap();
        let s = ExhaustiveIntra::new(true).solve(&arch, &l, &c, &model).unwrap();
        let eb = evaluate_layer(&arch, &b, false).energy.total();
        let es = evaluate_layer(&arch, &s, false).energy.total();
        assert!(es <= eb + 1e-9, "S {es} worse than B {eb}");
        assert_eq!(cache.lookups(), 0, "enumeration-unique candidates must bypass the memo");
    }

    #[test]
    fn bnb_counters_record_pruning() {
        use crate::solvers::space::BnbCounters;
        let arch = presets::bench_multi_node();
        let l = crate::workloads::Layer::conv("c", 64, 64, 28, 3, 1);
        let counters = BnbCounters::new();
        let solver =
            ExhaustiveIntra { with_sharing: true, stats: Some(&counters), ..Default::default() };
        let s = solver.solve(&arch, &l, &ctx((2, 2), 8), &TieredCost::fresh()).unwrap();
        s.validate(&arch).unwrap();
        let st = counters.snapshot();
        assert!(st.schemes_visited > 0);
        assert!(st.bound_evals > 0);
        // The same solver without counters finds the same scheme.
        let plain = ExhaustiveIntra::new(true)
            .solve(&arch, &l, &ctx((2, 2), 8), &TieredCost::fresh())
            .unwrap();
        assert_eq!(format!("{s:?}"), format!("{plain:?}"));
    }

    #[test]
    fn kapla_intra_close_to_exhaustive_optimum() {
        // The headline property at layer granularity: KAPLA's bottom-up
        // descent lands within a few percent of the exhaustive optimum.
        let arch = presets::bench_multi_node();
        let net = nets::alexnet();
        let mut ratios = Vec::new();
        for l in net.layers.iter().filter(|l| l.has_weights()).take(5) {
            let c = ctx((2, 2), 4);
            let ex = ExhaustiveIntra::new(true)
                .solve(&arch, l, &c, &TieredCost::fresh())
                .unwrap();
            let ka = solve_intra(&arch, l, &c).unwrap();
            let ee = evaluate_layer(&arch, &ex, false).energy.total();
            let ek = evaluate_layer(&arch, &ka, false).energy.total();
            ratios.push(ek / ee);
        }
        let worst = ratios.iter().cloned().fold(0.0, f64::max);
        assert!(worst < 1.35, "kapla intra overhead too high: {ratios:?}");
    }

    #[test]
    fn mlp_layer_optimum_contains_weight_reuse() {
        // FC layers are weight-bound; the exhaustive optimum must not
        // refetch weights per batch item at the DRAM level.
        let arch = presets::bench_multi_node();
        let l = crate::workloads::Layer::fc("f", 784, 1500);
        let s = ExhaustiveIntra::new(false)
            .solve(&arch, &l, &ctx((4, 4), 16), &TieredCost::fresh())
            .unwrap();
        let a = s.access_counts(false);
        // weight DRAM traffic within 2x of compulsory
        assert!(
            a.dram[2] <= 2 * l.weight_elems(),
            "wgt dram {} vs {}",
            a.dram[2],
            l.weight_elems()
        );
    }
}
