//! ML-based baseline M (paper §V): AutoTVM-style [6] simulated annealing
//! guided by an online-trained cost surrogate, applied to intra-layer
//! scheduling while inter-layer options are explored exhaustively (through
//! the shared exact DP).
//!
//! The paper's baseline trains an XGBoost ranker; following Mind Mappings
//! [20] (the same baseline family) we substitute an MLP surrogate. The
//! surrogate is a 16-64-1 ReLU MLP over structural "knob" features
//! (`cost::scheme_features`); its forward and SGD-step computations exist
//! twice: a native Rust implementation (reference, always available) and
//! the AOT-compiled JAX/Pallas artifacts executed through PJRT
//! (`runtime::Surrogate`) — bit-compatible by construction and
//! cross-checked in tests.

use crate::arch::ArchConfig;
use crate::cost::{scheme_features, CostModel, SCHEME_FEATURES};
use crate::directives::{LayerScheme, LevelBlock, LoopOrder};
use crate::mapping::UnitMap;
use crate::partition::enumerate_partitions;
use crate::util::SplitMix64;
use crate::workloads::Layer;

use super::space::qty_candidates;
use super::{ctx_fingerprint, IntraCtx, IntraSolver};

/// A trainable cost predictor over scheme features.
pub trait CostPredictor {
    /// Predict (log-)costs for a batch of feature vectors.
    fn predict(&mut self, feats: &[[f64; SCHEME_FEATURES]]) -> Vec<f64>;
    /// One SGD step on (features, log-cost) pairs; returns the batch loss.
    fn train_step(&mut self, feats: &[[f64; SCHEME_FEATURES]], targets: &[f64]) -> f64;
}

/// MLP hyperparameters shared by the native and PJRT implementations and
/// by `python/compile/model.py` (keep in sync!).
pub const HIDDEN: usize = 64;
pub const LEARNING_RATE: f64 = 1e-2;

/// Native-Rust reference implementation of the surrogate MLP
/// (16 -> 64 ReLU -> 1), trained with plain SGD on squared error.
pub struct NativeMlp {
    pub w1: Vec<f64>, // HIDDEN x F
    pub b1: Vec<f64>, // HIDDEN
    pub w2: Vec<f64>, // HIDDEN
    pub b2: f64,
    pub lr: f64,
}

impl NativeMlp {
    /// Deterministic init shared with the PJRT-side parameter buffers.
    pub fn new(seed: u64) -> NativeMlp {
        let mut rng = SplitMix64::new(seed);
        let f = SCHEME_FEATURES;
        let scale1 = (2.0 / f as f64).sqrt();
        let scale2 = (2.0 / HIDDEN as f64).sqrt();
        NativeMlp {
            w1: (0..HIDDEN * f).map(|_| rng.normal() * scale1).collect(),
            b1: vec![0.0; HIDDEN],
            w2: (0..HIDDEN).map(|_| rng.normal() * scale2).collect(),
            b2: 0.0,
            lr: LEARNING_RATE,
        }
    }

    fn forward_one(&self, x: &[f64; SCHEME_FEATURES]) -> (Vec<f64>, f64) {
        let f = SCHEME_FEATURES;
        let mut h = vec![0.0; HIDDEN];
        for j in 0..HIDDEN {
            let mut acc = self.b1[j];
            for (i, &xi) in x.iter().enumerate() {
                acc += self.w1[j * f + i] * xi;
            }
            h[j] = acc.max(0.0);
        }
        let y = h.iter().zip(&self.w2).map(|(a, b)| a * b).sum::<f64>() + self.b2;
        (h, y)
    }
}

impl CostPredictor for NativeMlp {
    fn predict(&mut self, feats: &[[f64; SCHEME_FEATURES]]) -> Vec<f64> {
        feats.iter().map(|x| self.forward_one(x).1).collect()
    }

    fn train_step(&mut self, feats: &[[f64; SCHEME_FEATURES]], targets: &[f64]) -> f64 {
        assert_eq!(feats.len(), targets.len());
        let n = feats.len().max(1) as f64;
        let f = SCHEME_FEATURES;
        let mut gw1 = vec![0.0; HIDDEN * f];
        let mut gb1 = vec![0.0; HIDDEN];
        let mut gw2 = vec![0.0; HIDDEN];
        let mut gb2 = 0.0;
        let mut loss = 0.0;
        for (x, &t) in feats.iter().zip(targets) {
            let (h, y) = self.forward_one(x);
            let e = y - t;
            loss += e * e;
            let g = 2.0 * e / n;
            gb2 += g;
            for j in 0..HIDDEN {
                gw2[j] += g * h[j];
                if h[j] > 0.0 {
                    let gh = g * self.w2[j];
                    gb1[j] += gh;
                    for (i, &xi) in x.iter().enumerate() {
                        gw1[j * f + i] += gh * xi;
                    }
                }
            }
        }
        for (w, g) in self.w1.iter_mut().zip(&gw1) {
            *w -= self.lr * g;
        }
        for (w, g) in self.b1.iter_mut().zip(&gb1) {
            *w -= self.lr * g;
        }
        for (w, g) in self.w2.iter_mut().zip(&gw2) {
            *w -= self.lr * g;
        }
        self.b2 -= self.lr * gb2;
        loss / n
    }
}

/// Simulated-annealing + surrogate intra-layer solver. Each (layer,
/// context) solve gets its own RNG stream *and* its own freshly-initialized
/// surrogate — both derived from `seed` folded with `ctx_fingerprint` — so
/// results do not depend on the order contexts are solved in, and the
/// parallel intra-layer sweep reproduces the sequential schedule exactly
/// (one surrogate per layer context is also what AutoTVM does per task).
pub struct MlIntra<P: CostPredictor> {
    pub rounds: usize,
    pub batch: usize,
    pub evals_per_round: usize,
    seed: u64,
    make_predictor: fn(u64) -> P,
    /// Cooperative cancellation, polled once per annealing round. A trip
    /// returns the best scheme found so far (or the minimal fallback) —
    /// anytime semantics. Not part of [`MlIntra::fingerprint`]: an
    /// untripped token never changes the trajectory, and tripped (partial)
    /// solves never enter the cross-job argmin memo.
    cancel: crate::util::cancel::CancelToken,
}

impl MlIntra<NativeMlp> {
    /// Default configuration with the native surrogate.
    pub fn native(seed: u64, rounds: usize, batch: usize) -> MlIntra<NativeMlp> {
        MlIntra::with_factory(NativeMlp::new, seed, rounds, batch)
    }
}

impl<P: CostPredictor> MlIntra<P> {
    /// Build with a per-context predictor factory (`make(seed)` must be a
    /// deterministic function of its seed).
    pub fn with_factory(
        make_predictor: fn(u64) -> P,
        seed: u64,
        rounds: usize,
        batch: usize,
    ) -> MlIntra<P> {
        MlIntra {
            rounds,
            batch,
            evals_per_round: (batch / 4).max(4),
            seed,
            make_predictor,
            cancel: crate::util::cancel::CancelToken::none(),
        }
    }

    pub fn with_cancel(mut self, cancel: crate::util::cancel::CancelToken) -> MlIntra<P> {
        self.cancel = cancel;
        self
    }
}

/// The mutable candidate space of one layer context.
struct Space {
    parts: Vec<crate::partition::PartitionScheme>,
}

impl Space {
    fn random_scheme(
        &self,
        arch: &ArchConfig,
        layer: &Layer,
        ctx: &IntraCtx,
        rng: &mut SplitMix64,
    ) -> Option<LayerScheme> {
        for _ in 0..32 {
            let part = *rng.choose(&self.parts);
            let unit = UnitMap::build(arch, part.node_shape(layer, ctx.rb));
            let gqs = qty_candidates(unit.totals, unit.granule);
            let gq = *rng.choose(&gqs);
            let rqs = qty_candidates(gq, unit.granule);
            let rq = *rng.choose(&rqs);
            let s = LayerScheme {
                part,
                unit,
                regf: LevelBlock { qty: rq, order: *rng.choose(&LoopOrder::all()) },
                gbuf: LevelBlock { qty: gq, order: *rng.choose(&LoopOrder::all()) },
            };
            if s.validate(arch).is_ok() {
                return Some(s);
            }
        }
        None
    }

    /// Mutate one knob of a scheme.
    fn mutate(
        &self,
        arch: &ArchConfig,
        layer: &Layer,
        ctx: &IntraCtx,
        s: &LayerScheme,
        rng: &mut SplitMix64,
    ) -> Option<LayerScheme> {
        for _ in 0..16 {
            let mut out = *s;
            match rng.below(4) {
                0 => {
                    let part = *rng.choose(&self.parts);
                    out.part = part;
                    out.unit = UnitMap::build(arch, part.node_shape(layer, ctx.rb));
                    out.gbuf.qty = out.unit.align_block(out.gbuf.qty);
                    out.regf.qty = out.unit.align_block(out.regf.qty.min(out.gbuf.qty));
                }
                1 => {
                    let gqs = qty_candidates(out.unit.totals, out.unit.granule);
                    out.gbuf.qty = *rng.choose(&gqs);
                    out.regf.qty = out.regf.qty.min(out.gbuf.qty);
                }
                2 => {
                    let rqs = qty_candidates(out.gbuf.qty, out.unit.granule);
                    out.regf.qty = *rng.choose(&rqs);
                }
                _ => {
                    if rng.chance(0.5) {
                        out.gbuf.order = *rng.choose(&LoopOrder::all());
                    } else {
                        out.regf.order = *rng.choose(&LoopOrder::all());
                    }
                }
            }
            if out.validate(arch).is_ok() {
                return Some(out);
            }
        }
        None
    }
}

impl<P: CostPredictor> IntraSolver for MlIntra<P> {
    fn name(&self) -> &'static str {
        "ml-annealing(M)"
    }

    /// Folds every annealing knob plus the predictor factory identity into
    /// the cross-job argmin memo key. The factory is identified by its
    /// concrete type name and function address — stable within one
    /// process, which is exactly the memo's lifetime — so two `MlIntra`
    /// values with different surrogates (native vs PJRT) never alias.
    fn fingerprint(&self) -> u64 {
        crate::util::fnv1a(
            self.name()
                .bytes()
                .chain(std::any::type_name::<P>().bytes())
                .map(u64::from)
                .chain([
                    self.rounds as u64,
                    self.batch as u64,
                    self.evals_per_round as u64,
                    self.seed,
                    self.make_predictor as usize as u64,
                ]),
        )
    }

    fn solve(
        &self,
        arch: &ArchConfig,
        layer: &Layer,
        ctx: &IntraCtx,
        model: &dyn CostModel,
    ) -> Option<LayerScheme> {
        let fp = ctx_fingerprint(layer, ctx);
        let mut rng = SplitMix64::new(self.seed ^ fp);
        let mut predictor = (self.make_predictor)(self.seed ^ 0x5eed ^ fp);
        let space = Space { parts: enumerate_partitions(layer, ctx.rb, ctx.region, false) };
        if space.parts.is_empty() {
            return super::space::minimal_scheme(arch, layer, ctx.region, ctx.rb);
        }

        // Staged scoring: one `StagedEval` per distinct partition seen in
        // this solve (mutations change the blocking far more often than
        // the partition), so proposals are scored with the cheap staged
        // suffix instead of a full memo-hashed evaluation. Values are
        // bit-identical to `model.evaluate`, so the annealing trajectory —
        // and the schedule — is unchanged. A `None` entry records a
        // backend without a staged shortcut; those keep the evaluate path.
        let mut staged_memo: std::collections::HashMap<
            crate::partition::PartitionScheme,
            Option<crate::sim::StagedEval<'_>>,
        > = std::collections::HashMap::new();
        let mut real_cost = |s: &LayerScheme| -> f64 {
            let staged = staged_memo
                .entry(s.part)
                .or_insert_with(|| model.staged(arch, &s.part, &s.unit, ctx.ifm_on_chip));
            let est = match staged {
                Some(st) => st.gbuf(s.gbuf.qty, s.gbuf.order).cost(s.regf.qty, s.regf.order),
                None => model.evaluate(arch, s, ctx.ifm_on_chip),
            };
            ctx.objective.of(&est)
        };

        // Seed population.
        let mut pop: Vec<LayerScheme> = (0..self.evals_per_round)
            .filter_map(|_| space.random_scheme(arch, layer, ctx, &mut rng))
            .collect();
        if pop.is_empty() {
            return super::space::minimal_scheme(arch, layer, ctx.region, ctx.rb);
        }
        let mut best: Option<(f64, LayerScheme)> = None;
        let mut dataset: Vec<([f64; SCHEME_FEATURES], f64)> = Vec::new();
        for s in &pop {
            let c = real_cost(s);
            dataset.push((scheme_features(s), c.max(1.0).ln()));
            if best.as_ref().map(|(b, _)| c < *b).unwrap_or(true) {
                best = Some((c, *s));
            }
        }

        let mut temp: f64 = 1.0;
        for _round in 0..self.rounds {
            // Cancellation yield point (once per annealing round): keep the
            // incumbent and stop proposing. Purely an early exit — the RNG
            // and annealing trajectory are untouched while the token stays
            // live.
            if self.cancel.is_cancelled() {
                break;
            }
            // Propose a batch of mutations.
            let mut proposals: Vec<LayerScheme> = Vec::with_capacity(self.batch);
            while proposals.len() < self.batch {
                let parent = pop[rng.below(pop.len() as u64) as usize];
                match space.mutate(arch, layer, ctx, &parent, &mut rng) {
                    Some(m) => proposals.push(m),
                    None => break,
                }
            }
            if proposals.is_empty() {
                break;
            }
            // Rank by surrogate prediction; evaluate the top few for real.
            let feats: Vec<[f64; SCHEME_FEATURES]> =
                proposals.iter().map(scheme_features).collect();
            let preds = predictor.predict(&feats);
            let mut idx: Vec<usize> = (0..proposals.len()).collect();
            idx.sort_by(|&a, &b| preds[a].partial_cmp(&preds[b]).unwrap());

            let mut next_pop = Vec::with_capacity(self.evals_per_round);
            for &i in idx.iter().take(self.evals_per_round) {
                let c = real_cost(&proposals[i]);
                dataset.push((feats[i], c.max(1.0).ln()));
                let (bc, _) = best.as_ref().copied().unwrap();
                let accept = c < bc || rng.chance((-(c / bc).ln().max(0.0) / temp).exp());
                if c < bc {
                    best = Some((c, proposals[i]));
                }
                if accept {
                    next_pop.push(proposals[i]);
                }
            }
            if !next_pop.is_empty() {
                pop = next_pop;
            }
            temp *= 0.85;

            // Online-train the surrogate on everything seen so far (one
            // epoch over a bounded replay window).
            let window = dataset.len().min(512);
            let start = dataset.len() - window;
            let fs: Vec<[f64; SCHEME_FEATURES]> =
                dataset[start..].iter().map(|(f, _)| *f).collect();
            let ts: Vec<f64> = dataset[start..].iter().map(|(_, t)| *t).collect();
            predictor.train_step(&fs, &ts);
        }

        best.map(|(_, s)| s).or_else(|| super::space::minimal_scheme(arch, layer, ctx.region, ctx.rb))
    }

    fn cancel_token(&self) -> Option<&crate::util::cancel::CancelToken> {
        self.cancel.active()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::cost::TieredCost;
    use crate::sim::evaluate_layer;
    use crate::solvers::exhaustive::ExhaustiveIntra;
    use crate::solvers::Objective;

    fn ctx(region: (u64, u64), rb: u64) -> IntraCtx {
        IntraCtx { region, rb, ifm_on_chip: false, objective: Objective::Energy }
    }

    #[test]
    fn native_mlp_learns_linear_target() {
        let mut mlp = NativeMlp::new(3);
        let mut rng = SplitMix64::new(4);
        let gen = |rng: &mut SplitMix64| {
            let mut x = [0.0; SCHEME_FEATURES];
            for v in x.iter_mut() {
                *v = rng.f64();
            }
            let t = 2.0 * x[0] + 0.5 * x[3] + 1.0;
            (x, t)
        };
        let data: Vec<_> = (0..256).map(|_| gen(&mut rng)).collect();
        let fs: Vec<_> = data.iter().map(|(f, _)| *f).collect();
        let ts: Vec<_> = data.iter().map(|(_, t)| *t).collect();
        let first = mlp.train_step(&fs, &ts);
        let mut last = first;
        for _ in 0..400 {
            last = mlp.train_step(&fs, &ts);
        }
        assert!(last < first * 0.1, "loss {first} -> {last}");
    }

    #[test]
    fn ml_solver_finds_valid_scheme() {
        let arch = presets::bench_multi_node();
        let l = crate::workloads::Layer::conv("c", 32, 32, 14, 3, 1);
        let intra = MlIntra::native(11, 8, 32);
        let s = intra.solve(&arch, &l, &ctx((2, 2), 4), &TieredCost::fresh()).unwrap();
        s.validate(&arch).unwrap();
    }

    #[test]
    fn ml_between_random_worstcase_and_exhaustive() {
        let arch = presets::bench_multi_node();
        let l = crate::workloads::Layer::conv("c", 64, 64, 28, 3, 1);
        let c = ctx((4, 4), 8);
        let ex =
            ExhaustiveIntra::new(false).solve(&arch, &l, &c, &TieredCost::fresh()).unwrap();
        let ee = evaluate_layer(&arch, &ex, false).energy.total();
        let m = MlIntra::native(5, 16, 64).solve(&arch, &l, &c, &TieredCost::fresh()).unwrap();
        let em = evaluate_layer(&arch, &m, false).energy.total();
        assert!(em + 1e-9 >= ee);
        assert!(em <= ee * 2.5, "ML {em} vs optimal {ee}");
    }

    #[test]
    fn deterministic_given_seed() {
        let arch = presets::bench_multi_node();
        let l = crate::workloads::Layer::conv("c", 32, 32, 14, 3, 1);
        let c = ctx((2, 2), 4);
        let a = MlIntra::native(9, 6, 16).solve(&arch, &l, &c, &TieredCost::fresh()).unwrap();
        let b = MlIntra::native(9, 6, 16).solve(&arch, &l, &c, &TieredCost::fresh()).unwrap();
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
    }

    #[test]
    fn solve_order_does_not_change_results() {
        let arch = presets::bench_multi_node();
        let l1 = crate::workloads::Layer::conv("c", 32, 32, 14, 3, 1);
        let l2 = crate::workloads::Layer::fc("f", 256, 128);
        let c = ctx((2, 2), 4);
        let intra = MlIntra::native(13, 4, 16);
        let a1 = intra.solve(&arch, &l1, &c, &TieredCost::fresh()).unwrap();
        let _ = intra.solve(&arch, &l2, &c, &TieredCost::fresh());
        let b1 = intra.solve(&arch, &l1, &c, &TieredCost::fresh()).unwrap();
        assert_eq!(format!("{a1:?}"), format!("{b1:?}"));
    }
}
