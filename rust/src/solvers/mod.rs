//! Dataflow solvers (paper §IV and §V "Baseline solvers"):
//!
//! * `kapla` — the paper's solver: decoupled inter-layer pruning + DP
//!   prioritization, intra-layer bottom-up cost descent (K).
//! * `exhaustive` — nn-dataflow-style exhaustive baseline (B), and the
//!   directive-space exhaustive variant with buffer-sharing options (S).
//! * `random` — Timeloop-style random sampling at each level (R).
//! * `ml` — AutoTVM-style simulated annealing guided by a learned cost
//!   surrogate (M).
//!
//! All baselines share the *exact* dynamic program over segment chains with
//! simulator-evaluated segment costs; they differ in how each layer's
//! intra-layer scheme is found. KAPLA instead runs the fast estimated DP
//! first and only solves intra-layer schemes for the top-k_S chains.

pub mod exhaustive;
pub mod kapla;
pub mod ml;
pub mod random;
pub mod space;

use std::collections::{HashMap, HashSet};

use crate::arch::ArchConfig;
use crate::cost::{CacheStats, CostCache, EvalCache};
use crate::directives::LayerScheme;
use crate::interlayer::dp::DpConfig;
use crate::interlayer::prune::conservative_valid;
use crate::interlayer::{candidate_spans, enumerate_segment_schemes, Schedule, Segment};
use crate::sim::pipeline::{evaluate_schedule, evaluate_segment, NetEval};
use crate::workloads::{Layer, Network};

/// Optimization objective (the paper evaluates energy, Fig. 7/9/10, and
/// performance, Fig. 8).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Objective {
    Energy,
    Latency,
}

impl Objective {
    /// Parse the CLI/service spelling — the one place the mapping lives,
    /// shared by `--objective`, the service positional and the
    /// `objective=` knob.
    pub fn parse(s: &str) -> Option<Objective> {
        match s {
            "energy" => Some(Objective::Energy),
            "latency" => Some(Objective::Latency),
            _ => None,
        }
    }

    /// The canonical spelling, round-tripping [`Objective::parse`].
    pub fn name(&self) -> &'static str {
        match self {
            Objective::Energy => "energy",
            Objective::Latency => "latency",
        }
    }
}

/// Context handed to an intra-layer solver for one layer of one segment.
#[derive(Debug, Clone, Copy)]
pub struct IntraCtx {
    /// Node region allocated to the layer.
    pub region: (u64, u64),
    /// Per-round batch.
    pub rb: u64,
    /// Input forwarded on-chip.
    pub ifm_on_chip: bool,
    pub objective: Objective,
}

/// An intra-layer solver: find a (near-)optimal `LayerScheme` for one layer
/// in the given context, or `None` if no valid scheme exists.
///
/// Solvers are *pure* per call — all candidate evaluations go through the
/// shared [`EvalCache`] (the per-run [`CostCache`] or a cross-job
/// `cost::SessionCache`) and any internal randomness is derived from the
/// solver's seed plus [`ctx_fingerprint`] — so independent contexts can be
/// solved concurrently, and sessions shared across jobs, with results
/// identical to a solitary sequential run.
pub trait IntraSolver: Sync {
    fn name(&self) -> &'static str;
    fn solve(
        &self,
        arch: &ArchConfig,
        layer: &Layer,
        ctx: &IntraCtx,
        cost: &dyn EvalCache,
    ) -> Option<LayerScheme>;
}

/// Deterministic fingerprint of one (layer, context) solve. The stochastic
/// solvers (R, M) fold this into their seeds so each context gets its own
/// reproducible stream: solving order — and therefore parallelism — cannot
/// change any result.
pub fn ctx_fingerprint(layer: &Layer, ctx: &IntraCtx) -> u64 {
    crate::util::fnv1a([
        layer.kind as u64,
        layer.c,
        layer.k,
        layer.xo,
        layer.yo,
        layer.r,
        layer.s,
        layer.stride,
        layer.no_batch as u64,
        ctx.region.0,
        ctx.region.1,
        ctx.rb,
        ctx.ifm_on_chip as u64,
        matches!(ctx.objective, Objective::Latency) as u64,
    ])
}

/// Result of scheduling a whole network.
pub struct SolveResult {
    pub schedule: Schedule,
    pub eval: NetEval,
    /// Wall-clock seconds spent solving.
    pub solve_s: f64,
    /// Evaluation-cache counters at job completion. For a solitary job
    /// this covers exactly that run; for a shared scheduling session the
    /// counters are session-cumulative, so deltas between consecutive
    /// results expose cross-job reuse.
    pub cache: CacheStats,
}

impl SolveResult {
    pub fn objective_value(&self, obj: Objective) -> f64 {
        match obj {
            Objective::Energy => self.eval.energy.total(),
            Objective::Latency => self.eval.latency_cycles,
        }
    }
}

fn seg_objective(ev: &crate::sim::pipeline::SegmentEval, obj: Objective) -> f64 {
    match obj {
        Objective::Energy => ev.energy.total(),
        Objective::Latency => ev.latency_cycles,
    }
}

/// Key of one intra-layer solve: (layer index, region, round batch,
/// input-forwarded-on-chip).
pub(crate) type IntraKey = (usize, (u64, u64), u64, bool);
pub(crate) type IntraCache = HashMap<IntraKey, Option<LayerScheme>>;

/// Solve every layer of a segment with the given intra-layer solver,
/// memoizing per (layer, region, round-batch, forwarding) context.
pub(crate) fn solve_segment_layers(
    arch: &ArchConfig,
    net: &Network,
    batch: u64,
    seg: &Segment,
    intra: &dyn IntraSolver,
    obj: Objective,
    cache: &mut IntraCache,
    cost: &dyn EvalCache,
) -> Option<Vec<LayerScheme>> {
    let rb = seg.round_batch(batch);
    let mut out = Vec::with_capacity(seg.len());
    for (pos, &li) in seg.layers.iter().enumerate() {
        let on_chip = seg.ifm_on_chip(net, li);
        let key = (li, seg.regions[pos], rb, on_chip);
        let entry = cache.entry(key).or_insert_with(|| {
            let ctx =
                IntraCtx { region: seg.regions[pos], rb, ifm_on_chip: on_chip, objective: obj };
            intra.solve(arch, &net.layers[li], &ctx, cost)
        });
        match entry {
            Some(s) => out.push(*s),
            None => return None,
        }
    }
    Some(out)
}

/// Collect the distinct intra-layer solve contexts of a set of candidate
/// segments, in first-seen order (deterministic).
pub(crate) fn collect_intra_keys<'a>(
    net: &Network,
    batch: u64,
    segs: impl Iterator<Item = &'a Segment>,
) -> Vec<IntraKey> {
    let mut keys = Vec::new();
    let mut seen: HashSet<IntraKey> = HashSet::new();
    for seg in segs {
        let rb = seg.round_batch(batch);
        for (pos, &li) in seg.layers.iter().enumerate() {
            let key = (li, seg.regions[pos], rb, seg.ifm_on_chip(net, li));
            if seen.insert(key) {
                keys.push(key);
            }
        }
    }
    keys
}

/// Solve a batch of independent intra-layer contexts across the scoped
/// worker pool and deposit the results in `cache`. Because every solver is
/// pure per context (see [`IntraSolver`]), the filled cache — and thus the
/// schedule later assembled from it — is identical for any thread count.
pub(crate) fn presolve_contexts(
    arch: &ArchConfig,
    net: &Network,
    keys: Vec<IntraKey>,
    intra: &dyn IntraSolver,
    obj: Objective,
    threads: usize,
    cache: &mut IntraCache,
    cost: &dyn EvalCache,
) {
    let solved = crate::util::par_map(&keys, threads, |&(li, region, rb, on_chip)| {
        let ctx = IntraCtx { region, rb, ifm_on_chip: on_chip, objective: obj };
        intra.solve(arch, &net.layers[li], &ctx, cost)
    });
    for (key, s) in keys.into_iter().zip(solved) {
        cache.insert(key, s);
    }
}

/// Exact dynamic program over segment chains: every candidate segment is
/// fully intra-solved and simulator-evaluated (this is what makes the
/// exhaustive/random/ML baselines slow and exact). Conservative validity
/// pruning is safe for optimality and applied for all solvers, mirroring
/// nn-dataflow's own buffering checks.
///
/// With `cfg.solve_threads > 1` the intra-layer solves — the dominant cost
/// by orders of magnitude — run first, sharded across a scoped worker pool:
/// the candidate segments (and hence solve contexts) do not depend on DP
/// state, only the chain costs do, so the sequential DP afterwards is pure
/// cache assembly and the result is identical to the single-threaded run.
pub fn exact_dp_schedule(
    arch: &ArchConfig,
    net: &Network,
    batch: u64,
    obj: Objective,
    cfg: &DpConfig,
    intra: &dyn IntraSolver,
) -> SolveResult {
    exact_dp_schedule_with(arch, net, batch, obj, cfg, intra, &CostCache::new())
}

/// [`exact_dp_schedule`] against a caller-supplied evaluation cache — the
/// entry point scheduling sessions use to reuse detailed-model evaluations
/// across jobs (the cache key carries the arch fingerprint, so one session
/// can serve jobs on different hardware configs without aliasing).
pub fn exact_dp_schedule_with(
    arch: &ArchConfig,
    net: &Network,
    batch: u64,
    obj: Objective,
    cfg: &DpConfig,
    intra: &dyn IntraSolver,
    cost: &dyn EvalCache,
) -> SolveResult {
    let timer = crate::util::Timer::start();
    let n = net.len();
    struct Node {
        cost: f64,
        seg: Segment,
        schemes: Vec<LayerScheme>,
        parent: Option<usize>, // layer index of previous chain node
    }
    let mut table: Vec<Option<Node>> = (0..n).map(|_| None).collect();
    let mut cache: IntraCache = HashMap::new();

    // Enumerate every candidate segment once, grouped per (end layer,
    // span start). The enumeration is DP-state-independent, so the same
    // list feeds both the parallel pre-solve and the DP proper. Holding
    // all spans' candidates at once costs O(total segments) small structs
    // (~100 MB at the most extreme full-scale settings, trivial at CI
    // scale) and buys a single loop shape for both thread modes.
    let mut spans_by_end: Vec<Vec<(usize, Vec<Segment>)>> = Vec::with_capacity(n);
    for i in 0..n {
        let mut per_span = Vec::new();
        for span in candidate_spans(i, cfg.max_seg_len) {
            let segs: Vec<Segment> = enumerate_segment_schemes(net, arch, batch, &span, cfg.max_rounds)
                .into_iter()
                .filter(|seg| conservative_valid(arch, net, batch, seg))
                .collect();
            per_span.push((span[0], segs));
        }
        spans_by_end.push(per_span);
    }

    if cfg.solve_threads > 1 {
        let keys = collect_intra_keys(
            net,
            batch,
            spans_by_end.iter().flatten().flat_map(|(_, segs)| segs.iter()),
        );
        presolve_contexts(arch, net, keys, intra, obj, cfg.solve_threads, &mut cache, cost);
    }

    for i in 0..n {
        for (start, segs) in &spans_by_end[i] {
            let start = *start;
            let prev_cost = if start == 0 {
                0.0
            } else {
                match &table[start - 1] {
                    Some(nd) => nd.cost,
                    None => continue,
                }
            };
            for seg in segs {
                let Some(schemes) =
                    solve_segment_layers(arch, net, batch, seg, intra, obj, &mut cache, cost)
                else {
                    continue;
                };
                let ev = evaluate_segment(arch, net, seg, &schemes);
                let cost = prev_cost + seg_objective(&ev, obj);
                let better = table[i].as_ref().map(|nd| cost < nd.cost).unwrap_or(true);
                if better {
                    table[i] = Some(Node {
                        cost,
                        seg: seg.clone(),
                        schemes,
                        parent: if start == 0 { None } else { Some(start - 1) },
                    });
                }
            }
        }
        assert!(
            table[i].is_some(),
            "no valid schedule ends at layer {i} ({})",
            net.layers[i].name
        );
    }

    // Reconstruct.
    let mut segments = Vec::new();
    let mut cur = Some(n - 1);
    while let Some(i) = cur {
        let nd = table[i].as_ref().unwrap();
        segments.push((nd.seg.clone(), nd.schemes.clone()));
        cur = nd.parent;
    }
    segments.reverse();
    let schedule = Schedule { segments };
    let eval = evaluate_schedule(arch, net, &schedule);
    SolveResult { schedule, eval, solve_s: timer.elapsed_s(), cache: cost.stats() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::workloads::{nets, Layer, Network};

    /// Minimal intra solver for tests: smallest valid scheme.
    pub(crate) struct Minimal;
    impl IntraSolver for Minimal {
        fn name(&self) -> &'static str {
            "minimal"
        }
        fn solve(
            &self,
            arch: &ArchConfig,
            layer: &Layer,
            ctx: &IntraCtx,
            _cost: &dyn EvalCache,
        ) -> Option<LayerScheme> {
            space::minimal_scheme(arch, layer, ctx.region, ctx.rb)
        }
    }

    fn small_net() -> Network {
        let mut n = Network::new("s", 8, 28, 28);
        n.chain(Layer::conv("a", 8, 16, 28, 3, 1));
        n.chain(Layer::conv("b", 16, 16, 28, 3, 1));
        n.chain(Layer::fc("c", 16 * 28 * 28, 64));
        n
    }

    #[test]
    fn exact_dp_produces_full_coverage() {
        let arch = presets::bench_multi_node();
        let net = small_net();
        let r =
            exact_dp_schedule(&arch, &net, 4, Objective::Energy, &DpConfig::default(), &Minimal);
        assert_eq!(r.schedule.num_layers(), net.len());
        assert!(r.eval.energy.total() > 0.0);
        let mut seen = Vec::new();
        for (seg, schemes) in &r.schedule.segments {
            assert_eq!(seg.len(), schemes.len());
            seen.extend(seg.layers.iter().copied());
        }
        assert_eq!(seen, (0..net.len()).collect::<Vec<_>>());
    }

    #[test]
    fn exact_dp_objective_latency_differs() {
        let arch = presets::bench_multi_node();
        let net = small_net();
        let re =
            exact_dp_schedule(&arch, &net, 4, Objective::Energy, &DpConfig::default(), &Minimal);
        let rl =
            exact_dp_schedule(&arch, &net, 4, Objective::Latency, &DpConfig::default(), &Minimal);
        // Latency-optimized schedule can't have worse latency than the
        // energy-optimized one (same space, different objective).
        assert!(rl.eval.latency_cycles <= re.eval.latency_cycles + 1e-6);
    }

    #[test]
    fn works_on_mlp_at_edge() {
        let arch = presets::edge_tpu();
        let net = nets::mlp();
        let r =
            exact_dp_schedule(&arch, &net, 1, Objective::Energy, &DpConfig::default(), &Minimal);
        assert_eq!(r.schedule.num_layers(), net.len());
        for (seg, _) in &r.schedule.segments {
            assert_eq!(seg.len(), 1); // single node: no pipelining
        }
    }

    #[test]
    fn parallel_dp_matches_sequential_exactly() {
        let arch = presets::bench_multi_node();
        let net = small_net();
        let seq_cfg = DpConfig { solve_threads: 1, ..DpConfig::default() };
        let par_cfg = DpConfig { solve_threads: 4, ..DpConfig::default() };
        let seq = exact_dp_schedule(&arch, &net, 4, Objective::Energy, &seq_cfg, &Minimal);
        let par = exact_dp_schedule(&arch, &net, 4, Objective::Energy, &par_cfg, &Minimal);
        assert_eq!(seq.eval.energy.total(), par.eval.energy.total());
        assert_eq!(seq.eval.latency_cycles, par.eval.latency_cycles);
        assert_eq!(format!("{:?}", seq.schedule), format!("{:?}", par.schedule));
    }

    #[test]
    fn ctx_fingerprint_distinguishes_contexts() {
        let a = Layer::conv("a", 8, 16, 28, 3, 1);
        let b = Layer::conv("b", 8, 16, 28, 3, 1); // same dims, same stream
        let ctx = |rb| IntraCtx {
            region: (2, 2),
            rb,
            ifm_on_chip: false,
            objective: Objective::Energy,
        };
        assert_eq!(ctx_fingerprint(&a, &ctx(4)), ctx_fingerprint(&b, &ctx(4)));
        assert_ne!(ctx_fingerprint(&a, &ctx(4)), ctx_fingerprint(&a, &ctx(8)));
        let mut lat = ctx(4);
        lat.objective = Objective::Latency;
        assert_ne!(ctx_fingerprint(&a, &ctx(4)), ctx_fingerprint(&a, &lat));
    }
}
