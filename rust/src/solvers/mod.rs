//! Dataflow solvers (paper §IV and §V "Baseline solvers"):
//!
//! * `kapla` — the paper's solver: decoupled inter-layer pruning + DP
//!   prioritization, intra-layer bottom-up cost descent (K).
//! * `exhaustive` — nn-dataflow-style exhaustive baseline (B), and the
//!   directive-space exhaustive variant with buffer-sharing options (S).
//! * `random` — Timeloop-style random sampling at each level (R).
//! * `ml` — AutoTVM-style simulated annealing guided by a learned cost
//!   surrogate (M).
//!
//! All baselines share the *exact* dynamic program over segment chains with
//! simulator-evaluated segment costs; they differ in how each layer's
//! intra-layer scheme is found. KAPLA instead runs the fast estimated DP
//! first and only solves intra-layer schemes for the top-k_S chains.
//!
//! The one entry point is the [`SolveCtx`] engine (`engine` module): it
//! owns the arch, DP knobs, objective and the tiered [`CostModel`], and
//! dispatches a [`SolverKind`] through `SolveCtx::run`. The per-family
//! `*_schedule` free functions this module used to export are gone —
//! coordinator, service, CLI, benches and tests all go through the engine.
//!
//! Exact pruning rests on a three-level hierarchy of admissible floors,
//! coarsest first: the *partition* floor (`CostModel::bound_partition`,
//! one check skips every blocking of a `PartitionScheme`), the *prefix*
//! bound (`CostModel::bound_prefix`, skips all completions of a
//! `(part, gbuf)` prefix), and the *span* floor in the inter-layer
//! planner (skips whole candidate spans against the chain incumbent).
//! Each floor lower-bounds everything beneath it, so pruning never moves
//! any argmin. [`SolverKind`] variants are plain unit tags compared with
//! `==`, so the `part_floor` toggle is *not* part of the solver label; it
//! surfaces through the [`BnbStats`] counters (`bnb` JSON object)
//! instead.

pub mod engine;
pub mod exhaustive;
pub mod kapla;
pub mod ml;
pub mod random;
pub mod space;

pub use engine::SolveCtx;
pub use space::{BnbCounters, BnbStats, PartOrder};

use std::collections::{HashMap, HashSet};

use crate::arch::ArchConfig;
use crate::cost::{CacheStats, CostEstimate, CostModel};
use crate::directives::LayerScheme;
use crate::interlayer::prune::PruneStats;
use crate::interlayer::{Schedule, Segment};
use crate::sim::pipeline::NetEval;
use crate::workloads::{Layer, Network};

/// Optimization objective (the paper evaluates energy, Fig. 7/9/10, and
/// performance, Fig. 8).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Objective {
    Energy,
    Latency,
}

impl Objective {
    /// Parse the CLI/service spelling — the one place the mapping lives,
    /// shared by `--objective`, the service positional and the
    /// `objective=` knob.
    pub fn parse(s: &str) -> Option<Objective> {
        match s {
            "energy" => Some(Objective::Energy),
            "latency" => Some(Objective::Latency),
            _ => None,
        }
    }

    /// The canonical spelling, round-tripping [`Objective::parse`].
    pub fn name(&self) -> &'static str {
        match self {
            Objective::Energy => "energy",
            Objective::Latency => "latency",
        }
    }

    /// Scalar value of a cost-model estimate under this objective — the
    /// one projection every solver scores candidates with.
    pub fn of(&self, est: &CostEstimate) -> f64 {
        match self {
            Objective::Energy => est.energy_pj,
            Objective::Latency => est.latency_cycles,
        }
    }
}

/// The five evaluated solvers (paper §V letters). Stochastic members carry
/// their knobs so a `SolverKind` value fully determines the search.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SolverKind {
    /// B — nn-dataflow exhaustive baseline.
    Baseline,
    /// S — exhaustive over the directive space.
    DirectiveExhaustive,
    /// R — random sampling with keep-probability `p`.
    Random { p: f64, seed: u64 },
    /// M — simulated annealing + surrogate.
    Ml { seed: u64, rounds: usize, batch: usize },
    /// K — KAPLA.
    Kapla,
}

/// Default knobs of the stochastic solvers — shared by [`SolverKind::parse`]
/// (what you get when a knob is omitted) and [`SolverKind::label`] (which
/// only prints knobs that differ from these).
pub const DEFAULT_RANDOM_P: f64 = 0.1;
pub const DEFAULT_RANDOM_SEED: u64 = 0xDA7AF10;
pub const DEFAULT_ML_SEED: u64 = 0x5EED;
pub const DEFAULT_ML_ROUNDS: usize = 16;
pub const DEFAULT_ML_BATCH: usize = 64;

impl SolverKind {
    pub fn letter(&self) -> &'static str {
        match self {
            SolverKind::Baseline => "B",
            SolverKind::DirectiveExhaustive => "S",
            SolverKind::Random { .. } => "R",
            SolverKind::Ml { .. } => "M",
            SolverKind::Kapla => "K",
        }
    }

    /// The letter plus any non-default knobs, so report rows from a
    /// `random:p=0.3,seed=7` sweep are distinguishable from each other
    /// (bare `letter()` collapses them all to `R`). Round-trips through
    /// [`SolverKind::parse`].
    pub fn label(&self) -> String {
        let mut knobs: Vec<String> = Vec::new();
        match self {
            SolverKind::Random { p, seed } => {
                if *p != DEFAULT_RANDOM_P {
                    knobs.push(format!("p={p}"));
                }
                if *seed != DEFAULT_RANDOM_SEED {
                    knobs.push(format!("seed={seed}"));
                }
            }
            SolverKind::Ml { seed, rounds, batch } => {
                if *rounds != DEFAULT_ML_ROUNDS {
                    knobs.push(format!("rounds={rounds}"));
                }
                if *batch != DEFAULT_ML_BATCH {
                    knobs.push(format!("batch={batch}"));
                }
                if *seed != DEFAULT_ML_SEED {
                    knobs.push(format!("seed={seed}"));
                }
            }
            _ => {}
        }
        if knobs.is_empty() {
            self.letter().to_string()
        } else {
            format!("{}:{}", self.letter(), knobs.join(","))
        }
    }

    /// Parse a CLI/service name. Stochastic solvers take knobs after a
    /// `:` — either the legacy bare number (`"random:0.1"`, `"ml:16"`) or
    /// comma-separated `key=value` pairs (`"random:p=0.2,seed=9"`,
    /// `"ml:rounds=8,batch=32,seed=5"`). Unknown names, unknown keys and
    /// unparseable values all return `None`, so front ends can reject a
    /// malformed request instead of silently falling back to defaults.
    pub fn parse(s: &str) -> Option<SolverKind> {
        let lower = s.to_ascii_lowercase();
        let (name, arg) = match lower.split_once(':') {
            Some((n, a)) => (n, Some(a)),
            None => (lower.as_str(), None),
        };
        match name {
            "k" | "kapla" => Some(SolverKind::Kapla),
            "b" | "baseline" | "nn-dataflow" => Some(SolverKind::Baseline),
            "s" | "exhaustive" => Some(SolverKind::DirectiveExhaustive),
            "r" | "random" => {
                let (mut p, mut seed) = (DEFAULT_RANDOM_P, DEFAULT_RANDOM_SEED);
                for part in arg.into_iter().flat_map(|a| a.split(',')) {
                    match part.split_once('=') {
                        Some(("p", v)) => p = v.parse().ok()?,
                        Some(("seed", v)) => seed = v.parse().ok()?,
                        Some(_) => return None,
                        None => p = part.parse().ok()?,
                    }
                }
                // A keep-probability outside (0, 1] is degenerate: p <= 0
                // samples nothing (the solver would reject every scheme and
                // "find" no schedule), p > 1 is meaningless, and NaN fails
                // both comparisons. Reject rather than run a useless solve.
                if !(p > 0.0 && p <= 1.0) {
                    return None;
                }
                Some(SolverKind::Random { p, seed })
            }
            "m" | "ml" => {
                let (mut seed, mut rounds, mut batch) =
                    (DEFAULT_ML_SEED, DEFAULT_ML_ROUNDS, DEFAULT_ML_BATCH);
                for part in arg.into_iter().flat_map(|a| a.split(',')) {
                    match part.split_once('=') {
                        Some(("rounds", v)) => rounds = v.parse().ok()?,
                        Some(("batch", v)) => batch = v.parse().ok()?,
                        Some(("seed", v)) => seed = v.parse().ok()?,
                        Some(_) => return None,
                        None => rounds = part.parse().ok()?,
                    }
                }
                // Zero rounds or a zero candidate batch trains on nothing —
                // the same degenerate-count class the DP knobs already
                // reject (`threads=0`, `ks=0`, ...).
                if rounds == 0 || batch == 0 {
                    return None;
                }
                Some(SolverKind::Ml { seed, rounds, batch })
            }
            _ => None,
        }
    }
}

/// A structured scheduling failure. Degenerate net/arch combinations used
/// to panic deep inside the DP (killing a long-running serve loop on one
/// bad request); every solver path now surfaces them through
/// `SolveCtx::run`, and the service maps them to `{"ok":false,...}`
/// responses.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SolveError {
    /// The inter-layer DP found no valid segment chain ending at `layer`.
    NoChain { layer: usize, layer_name: String },
    /// No intra-layer scheme realizes `layer` on this hardware — even the
    /// minimal unit-block mapping overflows the buffers.
    Unschedulable { layer: usize, layer_name: String },
    /// The solve was cancelled (deadline or manual trip) before *any*
    /// schedule existed to degrade to. A solve holding an incumbent never
    /// takes this path — it returns the incumbent with
    /// [`SolveResult::degraded`] set instead (anytime semantics).
    Deadline { elapsed_ms: u64 },
}

impl std::fmt::Display for SolveError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SolveError::NoChain { layer, layer_name } => {
                write!(f, "no valid segment chain ends at layer {layer} ({layer_name})")
            }
            SolveError::Unschedulable { layer, layer_name } => write!(
                f,
                "no valid schedule ends at layer {layer} ({layer_name}): no intra-layer \
                 scheme fits the hardware"
            ),
            SolveError::Deadline { elapsed_ms } => {
                write!(f, "deadline exceeded after {elapsed_ms} ms before any schedule was found")
            }
        }
    }
}

impl std::error::Error for SolveError {}

/// Context handed to an intra-layer solver for one layer of one segment.
#[derive(Debug, Clone, Copy)]
pub struct IntraCtx {
    /// Node region allocated to the layer.
    pub region: (u64, u64),
    /// Per-round batch.
    pub rb: u64,
    /// Input forwarded on-chip.
    pub ifm_on_chip: bool,
    pub objective: Objective,
}

/// An intra-layer solver: find a (near-)optimal `LayerScheme` for one layer
/// in the given context, or `None` if no valid scheme exists.
///
/// Solvers are *pure* per call — all candidate scoring draws from the
/// detailed tier of the shared [`CostModel`], either per candidate through
/// `evaluate` (cache-backed, so a per-run memo or a cross-job
/// `cost::SessionCache` serves repeats — the KAPLA descent's revisit-heavy
/// probes) or through the bit-identical staged evaluator for
/// enumeration-unique candidates (`CostModel::staged`, the B/S/R/M hot
/// loops) — and any internal randomness is derived from the solver's seed
/// plus [`ctx_fingerprint`] — so independent contexts can be solved
/// concurrently, and sessions shared across jobs, with results identical
/// to a solitary sequential run.
pub trait IntraSolver: Sync {
    fn name(&self) -> &'static str;
    fn solve(
        &self,
        arch: &ArchConfig,
        layer: &Layer,
        ctx: &IntraCtx,
        model: &dyn CostModel,
    ) -> Option<LayerScheme>;

    /// Deterministic identity of this solver's *search space and policy*:
    /// two solver values with equal fingerprints must return identical
    /// schemes for identical `(arch, layer, ctx)` inputs. It keys the
    /// cross-job intra-argmin memo (`cost::IntraKey`), so stochastic
    /// solvers MUST override it to fold every knob that changes their
    /// candidate stream (seed, probabilities, budgets); the default covers
    /// solvers fully described by their `name()` (KAPLA's descent, the
    /// exhaustive scans — B and S carry distinct names).
    fn fingerprint(&self) -> u64 {
        crate::util::fnv1a(self.name().bytes().map(u64::from))
    }

    /// The cancellation token this solver polls mid-scan, if it carries
    /// one. The memoization layer consults it to keep cancelled (partial)
    /// scans out of the cross-job argmin memo; the default covers solvers
    /// without cancellation support.
    fn cancel_token(&self) -> Option<&crate::util::cancel::CancelToken> {
        None
    }
}

/// Deterministic fingerprint of one (layer, context) solve. The stochastic
/// solvers (R, M) fold this into their seeds so each context gets its own
/// reproducible stream: solving order — and therefore parallelism — cannot
/// change any result.
pub fn ctx_fingerprint(layer: &Layer, ctx: &IntraCtx) -> u64 {
    crate::util::fnv1a([
        layer.kind as u64,
        layer.c,
        layer.k,
        layer.xo,
        layer.yo,
        layer.r,
        layer.s,
        layer.stride,
        layer.no_batch as u64,
        ctx.region.0,
        ctx.region.1,
        ctx.rb,
        ctx.ifm_on_chip as u64,
        matches!(ctx.objective, Objective::Latency) as u64,
    ])
}

/// How a solve fell short of its full search: the anytime marker stamped
/// on results whose scans were cut off by a [`CancelToken`] trip
/// (deadline or manual cancel). The schedule is still *valid* — every
/// scheme fits the hardware and the evaluation is exact — it is just the
/// best found before the trip rather than the search's full answer.
///
/// [`CancelToken`]: crate::util::cancel::CancelToken
#[derive(Debug, Clone, PartialEq)]
pub struct Degraded {
    /// `"deadline"` or `"cancelled"` — the latched trip reason.
    pub reason: &'static str,
    /// Milliseconds from token arming to result assembly.
    pub elapsed_ms: f64,
    /// Always `true`: kept explicit so the JSON surface is self-describing.
    pub best_effort: bool,
}

/// Result of scheduling a whole network.
pub struct SolveResult {
    pub schedule: Schedule,
    pub eval: NetEval,
    /// Wall-clock seconds spent solving.
    pub solve_s: f64,
    /// Evaluation-cache counters at job completion. For a solitary job
    /// this covers exactly that run; for a shared scheduling session the
    /// counters are session-cumulative, so deltas between consecutive
    /// results expose cross-job reuse.
    pub cache: CacheStats,
    /// Inter-layer pruning statistics (Table VI). Populated by the KAPLA
    /// decoupled path; the exact-DP baselines don't rank-prune, so they
    /// report `None`.
    pub prune: Option<PruneStats>,
    /// Intra-layer branch-and-bound statistics of the staged enumeration
    /// (visited/pruned prefixes, bound tightness — Table VI companion).
    /// Populated by the exhaustive B/S solvers; the other families don't
    /// subtree-prune, so they report `None`.
    pub bnb: Option<BnbStats>,
    /// `Some` when a cancellation trip cut the search short and this
    /// result is the best-effort incumbent; `None` for a full solve.
    pub degraded: Option<Degraded>,
}

impl SolveResult {
    pub fn objective_value(&self, obj: Objective) -> f64 {
        match obj {
            Objective::Energy => self.eval.energy.total(),
            Objective::Latency => self.eval.latency_cycles,
        }
    }
}

pub(crate) fn seg_objective(ev: &crate::sim::pipeline::SegmentEval, obj: Objective) -> f64 {
    match obj {
        Objective::Energy => ev.energy.total(),
        Objective::Latency => ev.latency_cycles,
    }
}

/// Key of one intra-layer solve within a run: (layer index, region, round
/// batch, input-forwarded-on-chip).
pub(crate) type IntraSolveKey = (usize, (u64, u64), u64, bool);
pub(crate) type IntraCache = HashMap<IntraSolveKey, Option<LayerScheme>>;

/// One intra-layer solve, short-circuited by the cross-job argmin memo:
/// when the model's session has already recorded this exact
/// `(arch, layer, ctx, solver)` scan — keyed by `cost::IntraKey` over
/// [`ctx_fingerprint`] and [`IntraSolver::fingerprint`] — the recorded
/// argmin is replayed and the scan never runs. Solvers are pure per
/// context, so replaying changes *when* searches run, never what any
/// schedule looks like (the golden battery and
/// `tests/planner_equivalence.rs` pin cold == warm byte-identically).
pub(crate) fn solve_ctx_memoized(
    arch: &ArchConfig,
    layer: &Layer,
    ctx: &IntraCtx,
    intra: &dyn IntraSolver,
    model: &dyn CostModel,
) -> Option<LayerScheme> {
    let key = crate::cost::IntraKey::of(arch, ctx_fingerprint(layer, ctx), intra.fingerprint());
    if let Some(recorded) = model.intra_argmin(&key) {
        return recorded;
    }
    let s = intra.solve(arch, layer, ctx, model);
    // A scan cut short by a cancellation trip covers only a prefix of the
    // candidate stream; recording its argmin would poison warm sessions
    // with degraded schemes long after the deadline pressure is gone.
    if !intra.cancel_token().is_some_and(|c| c.is_cancelled()) {
        model.record_intra_argmin(key, s);
    }
    s
}

/// Solve every layer of a segment with the given intra-layer solver,
/// memoizing per (layer, region, round-batch, forwarding) context within
/// the run and through the cross-job argmin memo across runs.
pub(crate) fn solve_segment_layers(
    arch: &ArchConfig,
    net: &Network,
    batch: u64,
    seg: &Segment,
    intra: &dyn IntraSolver,
    obj: Objective,
    cache: &mut IntraCache,
    model: &dyn CostModel,
) -> Option<Vec<LayerScheme>> {
    let rb = seg.round_batch(batch);
    let mut out = Vec::with_capacity(seg.len());
    for (pos, &li) in seg.layers.iter().enumerate() {
        let on_chip = seg.ifm_on_chip(net, li);
        let key = (li, seg.regions[pos], rb, on_chip);
        let entry = cache.entry(key).or_insert_with(|| {
            let ctx =
                IntraCtx { region: seg.regions[pos], rb, ifm_on_chip: on_chip, objective: obj };
            solve_ctx_memoized(arch, &net.layers[li], &ctx, intra, model)
        });
        match entry {
            Some(s) => out.push(*s),
            None => return None,
        }
    }
    Some(out)
}

/// Collect the distinct intra-layer solve contexts of a set of candidate
/// segments, in first-seen order (deterministic).
pub(crate) fn collect_intra_keys<'a>(
    net: &Network,
    batch: u64,
    segs: impl Iterator<Item = &'a Segment>,
) -> Vec<IntraSolveKey> {
    let mut keys = Vec::new();
    let mut seen: HashSet<IntraSolveKey> = HashSet::new();
    for seg in segs {
        let rb = seg.round_batch(batch);
        for (pos, &li) in seg.layers.iter().enumerate() {
            let key = (li, seg.regions[pos], rb, seg.ifm_on_chip(net, li));
            if seen.insert(key) {
                keys.push(key);
            }
        }
    }
    keys
}

/// Solve a batch of independent intra-layer contexts across the scoped
/// worker pool and deposit the results in `cache`. Because every solver is
/// pure per context (see [`IntraSolver`]), the filled cache — and thus the
/// schedule later assembled from it — is identical for any thread count.
pub(crate) fn presolve_contexts(
    arch: &ArchConfig,
    net: &Network,
    keys: Vec<IntraSolveKey>,
    intra: &dyn IntraSolver,
    obj: Objective,
    threads: usize,
    cache: &mut IntraCache,
    model: &dyn CostModel,
) {
    let solved = crate::util::par_map(&keys, threads, |&(li, region, rb, on_chip)| {
        let ctx = IntraCtx { region, rb, ifm_on_chip: on_chip, objective: obj };
        solve_ctx_memoized(arch, &net.layers[li], &ctx, intra, model)
    });
    for (key, s) in keys.into_iter().zip(solved) {
        cache.insert(key, s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::Layer;

    #[test]
    fn ctx_fingerprint_distinguishes_contexts() {
        let a = Layer::conv("a", 8, 16, 28, 3, 1);
        let b = Layer::conv("b", 8, 16, 28, 3, 1); // same dims, same stream
        let ctx = |rb| IntraCtx {
            region: (2, 2),
            rb,
            ifm_on_chip: false,
            objective: Objective::Energy,
        };
        assert_eq!(ctx_fingerprint(&a, &ctx(4)), ctx_fingerprint(&b, &ctx(4)));
        assert_ne!(ctx_fingerprint(&a, &ctx(4)), ctx_fingerprint(&a, &ctx(8)));
        let mut lat = ctx(4);
        lat.objective = Objective::Latency;
        assert_ne!(ctx_fingerprint(&a, &ctx(4)), ctx_fingerprint(&a, &lat));
    }

    #[test]
    fn solver_kind_parsing() {
        assert_eq!(SolverKind::parse("kapla"), Some(SolverKind::Kapla));
        assert_eq!(SolverKind::parse("K"), Some(SolverKind::Kapla));
        assert_eq!(SolverKind::parse("b"), Some(SolverKind::Baseline));
        assert!(
            matches!(SolverKind::parse("random:0.5"), Some(SolverKind::Random { p, .. }) if p == 0.5)
        );
        assert!(matches!(SolverKind::parse("ml:4"), Some(SolverKind::Ml { rounds: 4, .. })));
        assert_eq!(SolverKind::parse("nope"), None);
    }

    #[test]
    fn solver_kind_key_value_knobs() {
        assert_eq!(
            SolverKind::parse("random:p=0.25,seed=9"),
            Some(SolverKind::Random { p: 0.25, seed: 9 })
        );
        assert_eq!(
            SolverKind::parse("ml:rounds=8,batch=32,seed=5"),
            Some(SolverKind::Ml { seed: 5, rounds: 8, batch: 32 })
        );
        // Bare-number legacy form still accepted.
        assert!(
            matches!(SolverKind::parse("r:0.3"), Some(SolverKind::Random { p, .. }) if p == 0.3)
        );
        // Malformed knobs are rejected, not silently defaulted.
        assert_eq!(SolverKind::parse("random:q=0.5"), None);
        assert_eq!(SolverKind::parse("random:p=zero"), None);
        assert_eq!(SolverKind::parse("ml:rounds=many"), None);
    }

    #[test]
    fn degenerate_stochastic_knobs_are_rejected() {
        // Values that parse as numbers but make the solver useless: a
        // keep-probability outside (0, 1] (including NaN/inf) or zero
        // rounds/batch. All must come back `None` so front ends surface a
        // structured error.
        for s in [
            "random:p=0",
            "random:0",
            "random:p=-1",
            "random:p=nan",
            "random:p=1.5",
            "r:p=inf",
            "ml:rounds=0",
            "ml:0",
            "ml:batch=0",
            "ml:rounds=8,batch=0",
        ] {
            assert_eq!(SolverKind::parse(s), None, "{s} must be rejected");
        }
        // The boundaries stay legal: p=1 keeps every sample, 1-round/
        // 1-candidate ML is slow but well-defined.
        assert!(matches!(SolverKind::parse("random:p=1"), Some(SolverKind::Random { p, .. }) if p == 1.0));
        assert_eq!(
            SolverKind::parse("ml:rounds=1,batch=1"),
            Some(SolverKind::Ml { seed: DEFAULT_ML_SEED, rounds: 1, batch: 1 })
        );
    }

    #[test]
    fn letters_match_paper() {
        assert_eq!(SolverKind::Kapla.letter(), "K");
        assert_eq!(SolverKind::Baseline.letter(), "B");
        assert_eq!(SolverKind::DirectiveExhaustive.letter(), "S");
        assert_eq!(SolverKind::Random { p: 0.1, seed: 0 }.letter(), "R");
        assert_eq!(SolverKind::Ml { seed: 0, rounds: 1, batch: 1 }.letter(), "M");
    }

    #[test]
    fn labels_fold_in_non_default_knobs_and_roundtrip() {
        // Default knobs collapse to the bare letter.
        assert_eq!(SolverKind::Kapla.label(), "K");
        assert_eq!(
            SolverKind::Random { p: DEFAULT_RANDOM_P, seed: DEFAULT_RANDOM_SEED }.label(),
            "R"
        );
        assert_eq!(
            SolverKind::Ml {
                seed: DEFAULT_ML_SEED,
                rounds: DEFAULT_ML_ROUNDS,
                batch: DEFAULT_ML_BATCH
            }
            .label(),
            "M"
        );
        // Non-default knobs are spelled out, so sweep rows stay distinct.
        let r = SolverKind::Random { p: 0.3, seed: 7 };
        assert_eq!(r.label(), "R:p=0.3,seed=7");
        let m = SolverKind::Ml { seed: 5, rounds: 8, batch: 32 };
        assert_eq!(m.label(), "M:rounds=8,batch=32,seed=5");
        let r_p_only = SolverKind::Random { p: 0.3, seed: DEFAULT_RANDOM_SEED };
        assert_eq!(r_p_only.label(), "R:p=0.3");
        // Labels parse back to the same kind.
        for kind in [SolverKind::Kapla, r, m, r_p_only] {
            assert_eq!(SolverKind::parse(&kind.label()), Some(kind), "{}", kind.label());
        }
    }

    #[test]
    fn objective_projects_estimates() {
        let est = CostEstimate { energy_pj: 3.0, latency_cycles: 7.0 };
        assert_eq!(Objective::Energy.of(&est), 3.0);
        assert_eq!(Objective::Latency.of(&est), 7.0);
    }
}
