//! Dataflow solvers (paper §IV and §V "Baseline solvers"):
//!
//! * `kapla` — the paper's solver: decoupled inter-layer pruning + DP
//!   prioritization, intra-layer bottom-up cost descent (K).
//! * `exhaustive` — nn-dataflow-style exhaustive baseline (B), and the
//!   directive-space exhaustive variant with buffer-sharing options (S).
//! * `random` — Timeloop-style random sampling at each level (R).
//! * `ml` — AutoTVM-style simulated annealing guided by a learned cost
//!   surrogate (M).
//!
//! All baselines share the *exact* dynamic program over segment chains with
//! simulator-evaluated segment costs; they differ in how each layer's
//! intra-layer scheme is found. KAPLA instead runs the fast estimated DP
//! first and only solves intra-layer schemes for the top-k_S chains.

pub mod exhaustive;
pub mod kapla;
pub mod ml;
pub mod random;
pub mod space;

use std::collections::HashMap;

use crate::arch::ArchConfig;
use crate::directives::LayerScheme;
use crate::interlayer::dp::DpConfig;
use crate::interlayer::prune::conservative_valid;
use crate::interlayer::{candidate_spans, enumerate_segment_schemes, Schedule, Segment};
use crate::sim::pipeline::{evaluate_schedule, evaluate_segment, NetEval};
use crate::workloads::Network;

/// Optimization objective (the paper evaluates energy, Fig. 7/9/10, and
/// performance, Fig. 8).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Objective {
    Energy,
    Latency,
}

/// Context handed to an intra-layer solver for one layer of one segment.
#[derive(Debug, Clone, Copy)]
pub struct IntraCtx {
    /// Node region allocated to the layer.
    pub region: (u64, u64),
    /// Per-round batch.
    pub rb: u64,
    /// Input forwarded on-chip.
    pub ifm_on_chip: bool,
    pub objective: Objective,
}

/// An intra-layer solver: find a (near-)optimal `LayerScheme` for one layer
/// in the given context, or `None` if no valid scheme exists.
pub trait IntraSolver: Sync {
    fn name(&self) -> &'static str;
    fn solve(
        &self,
        arch: &ArchConfig,
        layer: &crate::workloads::Layer,
        ctx: &IntraCtx,
    ) -> Option<LayerScheme>;
}

/// Result of scheduling a whole network.
pub struct SolveResult {
    pub schedule: Schedule,
    pub eval: NetEval,
    /// Wall-clock seconds spent solving.
    pub solve_s: f64,
}

impl SolveResult {
    pub fn objective_value(&self, obj: Objective) -> f64 {
        match obj {
            Objective::Energy => self.eval.energy.total(),
            Objective::Latency => self.eval.latency_cycles,
        }
    }
}

fn seg_objective(ev: &crate::sim::pipeline::SegmentEval, obj: Objective) -> f64 {
    match obj {
        Objective::Energy => ev.energy.total(),
        Objective::Latency => ev.latency_cycles,
    }
}

pub(crate) type IntraCache = HashMap<(usize, (u64, u64), u64, bool), Option<LayerScheme>>;

/// Solve every layer of a segment with the given intra-layer solver,
/// memoizing per (layer, region, round-batch, forwarding) context.
pub(crate) fn solve_segment_layers(
    arch: &ArchConfig,
    net: &Network,
    batch: u64,
    seg: &Segment,
    intra: &dyn IntraSolver,
    obj: Objective,
    cache: &mut IntraCache,
) -> Option<Vec<LayerScheme>> {
    let rb = seg.round_batch(batch);
    let mut out = Vec::with_capacity(seg.len());
    for (pos, &li) in seg.layers.iter().enumerate() {
        let on_chip = seg.ifm_on_chip(net, li);
        let key = (li, seg.regions[pos], rb, on_chip);
        let entry = cache.entry(key).or_insert_with(|| {
            let ctx =
                IntraCtx { region: seg.regions[pos], rb, ifm_on_chip: on_chip, objective: obj };
            intra.solve(arch, &net.layers[li], &ctx)
        });
        match entry {
            Some(s) => out.push(*s),
            None => return None,
        }
    }
    Some(out)
}

/// Exact dynamic program over segment chains: every candidate segment is
/// fully intra-solved and simulator-evaluated (this is what makes the
/// exhaustive/random/ML baselines slow and exact). Conservative validity
/// pruning is safe for optimality and applied for all solvers, mirroring
/// nn-dataflow's own buffering checks.
pub fn exact_dp_schedule(
    arch: &ArchConfig,
    net: &Network,
    batch: u64,
    obj: Objective,
    cfg: &DpConfig,
    intra: &dyn IntraSolver,
) -> SolveResult {
    let timer = crate::util::Timer::start();
    let n = net.len();
    struct Node {
        cost: f64,
        seg: Segment,
        schemes: Vec<LayerScheme>,
        parent: Option<usize>, // layer index of previous chain node
    }
    let mut table: Vec<Option<Node>> = (0..n).map(|_| None).collect();
    let mut cache: IntraCache = HashMap::new();

    for i in 0..n {
        for span in candidate_spans(i, cfg.max_seg_len) {
            let start = span[0];
            let prev_cost = if start == 0 {
                0.0
            } else {
                match &table[start - 1] {
                    Some(nd) => nd.cost,
                    None => continue,
                }
            };
            for seg in enumerate_segment_schemes(net, arch, batch, &span, cfg.max_rounds) {
                if !conservative_valid(arch, net, batch, &seg) {
                    continue;
                }
                let Some(schemes) =
                    solve_segment_layers(arch, net, batch, &seg, intra, obj, &mut cache)
                else {
                    continue;
                };
                let ev = evaluate_segment(arch, net, &seg, &schemes);
                let cost = prev_cost + seg_objective(&ev, obj);
                let better = table[i].as_ref().map(|nd| cost < nd.cost).unwrap_or(true);
                if better {
                    table[i] = Some(Node {
                        cost,
                        seg,
                        schemes,
                        parent: if start == 0 { None } else { Some(start - 1) },
                    });
                }
            }
        }
        assert!(
            table[i].is_some(),
            "no valid schedule ends at layer {i} ({})",
            net.layers[i].name
        );
    }

    // Reconstruct.
    let mut segments = Vec::new();
    let mut cur = Some(n - 1);
    while let Some(i) = cur {
        let nd = table[i].as_ref().unwrap();
        segments.push((nd.seg.clone(), nd.schemes.clone()));
        cur = nd.parent;
    }
    segments.reverse();
    let schedule = Schedule { segments };
    let eval = evaluate_schedule(arch, net, &schedule);
    SolveResult { schedule, eval, solve_s: timer.elapsed_s() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::workloads::{nets, Layer, Network};

    /// Minimal intra solver for tests: smallest valid scheme.
    pub(crate) struct Minimal;
    impl IntraSolver for Minimal {
        fn name(&self) -> &'static str {
            "minimal"
        }
        fn solve(
            &self,
            arch: &ArchConfig,
            layer: &Layer,
            ctx: &IntraCtx,
        ) -> Option<LayerScheme> {
            space::minimal_scheme(arch, layer, ctx.region, ctx.rb)
        }
    }

    fn small_net() -> Network {
        let mut n = Network::new("s", 8, 28, 28);
        n.chain(Layer::conv("a", 8, 16, 28, 3, 1));
        n.chain(Layer::conv("b", 16, 16, 28, 3, 1));
        n.chain(Layer::fc("c", 16 * 28 * 28, 64));
        n
    }

    #[test]
    fn exact_dp_produces_full_coverage() {
        let arch = presets::bench_multi_node();
        let net = small_net();
        let r =
            exact_dp_schedule(&arch, &net, 4, Objective::Energy, &DpConfig::default(), &Minimal);
        assert_eq!(r.schedule.num_layers(), net.len());
        assert!(r.eval.energy.total() > 0.0);
        let mut seen = Vec::new();
        for (seg, schemes) in &r.schedule.segments {
            assert_eq!(seg.len(), schemes.len());
            seen.extend(seg.layers.iter().copied());
        }
        assert_eq!(seen, (0..net.len()).collect::<Vec<_>>());
    }

    #[test]
    fn exact_dp_objective_latency_differs() {
        let arch = presets::bench_multi_node();
        let net = small_net();
        let re =
            exact_dp_schedule(&arch, &net, 4, Objective::Energy, &DpConfig::default(), &Minimal);
        let rl =
            exact_dp_schedule(&arch, &net, 4, Objective::Latency, &DpConfig::default(), &Minimal);
        // Latency-optimized schedule can't have worse latency than the
        // energy-optimized one (same space, different objective).
        assert!(rl.eval.latency_cycles <= re.eval.latency_cycles + 1e-6);
    }

    #[test]
    fn works_on_mlp_at_edge() {
        let arch = presets::edge_tpu();
        let net = nets::mlp();
        let r =
            exact_dp_schedule(&arch, &net, 1, Objective::Energy, &DpConfig::default(), &Minimal);
        assert_eq!(r.schedule.num_layers(), net.len());
        for (seg, _) in &r.schedule.segments {
            assert_eq!(seg.len(), 1); // single node: no pipelining
        }
    }
}
