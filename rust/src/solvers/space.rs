//! Intra-layer design-space enumeration shared by the exhaustive, random
//! and ML solvers (paper §III-A "loop blocking and reordering" plus node
//! partitioning; KAPLA itself avoids this enumeration via bottom-up cost
//! descent).

use crate::arch::ArchConfig;
use crate::directives::{LevelBlock, LayerScheme, LoopOrder, Qty};
use crate::mapping::UnitMap;
use crate::partition::{enumerate_partitions, PartitionScheme};
use crate::util::divisors;
use crate::workloads::Layer;

/// Candidate resident-block quantities for one group: granule multiples
/// whose unit counts divide the total unit count (the divisor-chain
/// blocking space of [39], [58]).
pub fn block_candidates(total: u64, granule: u64) -> Vec<u64> {
    let units = crate::util::ceil_div(total, granule);
    divisors(units).into_iter().map(|d| (d * granule).min(total)).collect()
}

/// All block quantities (triples) for a level, given per-group totals and
/// granules.
pub fn qty_candidates(totals: Qty, granule: Qty) -> Vec<Qty> {
    let bs = block_candidates(totals.b, granule.b);
    let cs = block_candidates(totals.c, granule.c);
    let ks = block_candidates(totals.k, granule.k);
    let mut out = Vec::with_capacity(bs.len() * cs.len() * ks.len());
    for &b in &bs {
        for &c in &cs {
            for &k in &ks {
                out.push(Qty::new(b, c, k));
            }
        }
    }
    out
}

/// Visit every valid intra-layer scheme of `layer` on `region` at batch
/// `rb`. The caller's visitor returns `true` to continue enumeration.
/// `with_sharing` widens the partition space with buffer-sharing variants
/// (the extra expressiveness of the directive space, solver "S").
pub fn visit_schemes(
    arch: &ArchConfig,
    layer: &Layer,
    region: (u64, u64),
    rb: u64,
    with_sharing: bool,
    mut visit: impl FnMut(&LayerScheme) -> bool,
) {
    let parts = enumerate_partitions(layer, rb, region, with_sharing);
    for part in parts {
        let unit = UnitMap::build(arch, part.node_shape(layer, rb));
        'gbuf: for gq in qty_candidates(unit.totals, unit.granule) {
            // Capacity pre-check before spawning the inner loops.
            let probe = LayerScheme {
                part,
                unit,
                regf: LevelBlock { qty: unit.granule, order: LoopOrder::all()[0] },
                gbuf: LevelBlock { qty: gq, order: LoopOrder::all()[0] },
            };
            if probe.gbuf_words_per_node() > arch.gbuf_words() {
                continue 'gbuf;
            }
            for rq in qty_candidates(gq, unit.granule) {
                let probe2 = LayerScheme {
                    regf: LevelBlock { qty: rq, order: LoopOrder::all()[0] },
                    ..probe
                };
                if probe2.regf_words_per_pe() > arch.regf_words() {
                    continue;
                }
                for go in LoopOrder::all() {
                    for ro in LoopOrder::all() {
                        let s = LayerScheme {
                            part,
                            unit,
                            regf: LevelBlock { qty: rq, order: ro },
                            gbuf: LevelBlock { qty: gq, order: go },
                        };
                        if s.validate(arch).is_ok() && !visit(&s) {
                            return;
                        }
                    }
                }
            }
        }
    }
}

/// Count the schemes `visit_schemes` would enumerate (used by the search
/// speed analysis and Table VI style reporting).
pub fn count_schemes(
    arch: &ArchConfig,
    layer: &Layer,
    region: (u64, u64),
    rb: u64,
    with_sharing: bool,
) -> u64 {
    let mut n = 0u64;
    visit_schemes(arch, layer, region, rb, with_sharing, |_| {
        n += 1;
        true
    });
    n
}

/// A fallback scheme that is always valid if one exists at all: the
/// smallest blocks everywhere, on the best-effort partition. Returns `None`
/// when even the unit tensors overflow the buffers.
pub fn minimal_scheme(
    arch: &ArchConfig,
    layer: &Layer,
    region: (u64, u64),
    rb: u64,
) -> Option<LayerScheme> {
    let mut best: Option<LayerScheme> = None;
    for part in enumerate_partitions(layer, rb, region, true) {
        let unit = UnitMap::build(arch, part.node_shape(layer, rb));
        let s = LayerScheme {
            part,
            unit,
            regf: LevelBlock { qty: unit.granule, order: LoopOrder::all()[0] },
            gbuf: LevelBlock { qty: unit.granule, order: LoopOrder::all()[0] },
        };
        if s.validate(arch).is_ok() {
            best = Some(s);
            break;
        }
    }
    best.or_else(|| {
        // Fall back to a single-node mapping (region underuse).
        let part = PartitionScheme { region, ..PartitionScheme::single() };
        let unit = UnitMap::build(arch, part.node_shape(layer, rb));
        let s = LayerScheme {
            part,
            unit,
            regf: LevelBlock { qty: unit.granule, order: LoopOrder::all()[0] },
            gbuf: LevelBlock { qty: unit.granule, order: LoopOrder::all()[0] },
        };
        s.validate(arch).ok().map(|_| s)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;

    #[test]
    fn block_candidates_cover_range() {
        let c = block_candidates(12, 1);
        assert_eq!(c, vec![1, 2, 3, 4, 6, 12]);
        let c = block_candidates(32, 8);
        assert_eq!(c, vec![8, 16, 32]);
        // non-dividing granule clamps to total
        let c = block_candidates(10, 4);
        assert!(c.contains(&10));
        assert!(c.iter().all(|&x| x <= 10));
    }

    #[test]
    fn qty_candidates_cartesian() {
        let q = qty_candidates(Qty::new(2, 4, 1), Qty::UNIT);
        assert_eq!(q.len(), 2 * 3 * 1);
    }

    #[test]
    fn visit_yields_only_valid() {
        let arch = presets::bench_multi_node();
        let l = Layer::conv("c", 16, 32, 14, 3, 1);
        let mut n = 0;
        visit_schemes(&arch, &l, (2, 2), 4, false, |s| {
            s.validate(&arch).unwrap();
            n += 1;
            true
        });
        assert!(n > 100, "space too small: {n}");
    }

    #[test]
    fn sharing_widens_space() {
        let arch = presets::bench_multi_node();
        let l = Layer::conv("c", 16, 32, 14, 3, 1);
        let plain = count_schemes(&arch, &l, (2, 2), 4, false);
        let wide = count_schemes(&arch, &l, (2, 2), 4, true);
        assert!(wide > plain, "{wide} !> {plain}");
    }

    #[test]
    fn early_stop_respected() {
        let arch = presets::bench_multi_node();
        let l = Layer::conv("c", 16, 32, 14, 3, 1);
        let mut n = 0;
        visit_schemes(&arch, &l, (2, 2), 4, false, |_| {
            n += 1;
            n < 10
        });
        assert_eq!(n, 10);
    }

    #[test]
    fn minimal_scheme_exists_for_all_nets() {
        let arch = presets::multi_node_eyeriss();
        for net in crate::workloads::all_networks() {
            for l in &net.layers {
                assert!(
                    minimal_scheme(&arch, l, (4, 4), 4).is_some(),
                    "{}: {}",
                    net.name,
                    l.name
                );
            }
        }
    }

    #[test]
    fn minimal_scheme_on_edge_device() {
        let arch = presets::edge_tpu();
        for net in crate::workloads::all_networks() {
            for l in &net.layers {
                assert!(
                    minimal_scheme(&arch, l, (1, 1), 1).is_some(),
                    "{}: {}",
                    net.name,
                    l.name
                );
            }
        }
    }
}
