//! Intra-layer design-space enumeration shared by the exhaustive, random
//! and ML solvers (paper §III-A "loop blocking and reordering" plus node
//! partitioning; KAPLA itself avoids this enumeration via bottom-up cost
//! descent).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::arch::ArchConfig;
use crate::cost::{CostEstimate, CostModel};
use crate::directives::{LevelBlock, LayerScheme, LoopOrder, Qty};
use crate::mapping::UnitMap;
use crate::partition::{enumerate_partitions, PartitionScheme};
use crate::util::divisors;
use crate::workloads::Layer;

use super::{IntraCtx, Objective};

/// Candidate resident-block quantities for one group: granule multiples
/// whose unit counts divide the total unit count (the divisor-chain
/// blocking space of [39], [58]). Only the largest divisor can reach the
/// `min(total)` clamp (any other divisor `d` of `units` has
/// `d <= units/2`, so `d * granule < total`), so duplicates should be
/// impossible; the `dedup` is a cheap guard that pins that invariant —
/// no candidate quantity is ever enumerated (and evaluated) twice, even
/// if the clamp rule changes.
pub fn block_candidates(total: u64, granule: u64) -> Vec<u64> {
    let units = crate::util::ceil_div(total, granule);
    let mut out: Vec<u64> =
        divisors(units).into_iter().map(|d| (d * granule).min(total)).collect();
    out.dedup();
    out
}

/// All block quantities (triples) for a level, given per-group totals and
/// granules.
pub fn qty_candidates(totals: Qty, granule: Qty) -> Vec<Qty> {
    let bs = block_candidates(totals.b, granule.b);
    let cs = block_candidates(totals.c, granule.c);
    let ks = block_candidates(totals.k, granule.k);
    let mut out = Vec::with_capacity(bs.len() * cs.len() * ks.len());
    for &b in &bs {
        for &c in &cs {
            for &k in &ks {
                out.push(Qty::new(b, c, k));
            }
        }
    }
    out
}

/// Visit every valid intra-layer scheme of `layer` on `region` at batch
/// `rb`. The caller's visitor returns `true` to continue enumeration.
/// `with_sharing` widens the partition space with buffer-sharing variants
/// (the extra expressiveness of the directive space, solver "S").
pub fn visit_schemes(
    arch: &ArchConfig,
    layer: &Layer,
    region: (u64, u64),
    rb: u64,
    with_sharing: bool,
    mut visit: impl FnMut(&LayerScheme) -> bool,
) {
    let parts = enumerate_partitions(layer, rb, region, with_sharing);
    for part in parts {
        let unit = UnitMap::build(arch, part.node_shape(layer, rb));
        'gbuf: for gq in qty_candidates(unit.totals, unit.granule) {
            // Capacity pre-check before spawning the inner loops.
            let probe = LayerScheme {
                part,
                unit,
                regf: LevelBlock { qty: unit.granule, order: LoopOrder::all()[0] },
                gbuf: LevelBlock { qty: gq, order: LoopOrder::all()[0] },
            };
            if probe.gbuf_words_per_node() > arch.gbuf_words() {
                continue 'gbuf;
            }
            for rq in qty_candidates(gq, unit.granule) {
                let probe2 = LayerScheme {
                    regf: LevelBlock { qty: rq, order: LoopOrder::all()[0] },
                    ..probe
                };
                if probe2.regf_words_per_pe() > arch.regf_words() {
                    continue;
                }
                for go in LoopOrder::all() {
                    for ro in LoopOrder::all() {
                        let s = LayerScheme {
                            part,
                            unit,
                            regf: LevelBlock { qty: rq, order: ro },
                            gbuf: LevelBlock { qty: gq, order: go },
                        };
                        if s.validate(arch).is_ok() && !visit(&s) {
                            return;
                        }
                    }
                }
            }
        }
    }
}

/// Count the schemes `visit_schemes` would enumerate (used by the search
/// speed analysis and Table VI style reporting).
pub fn count_schemes(
    arch: &ArchConfig,
    layer: &Layer,
    region: (u64, u64),
    rb: u64,
    with_sharing: bool,
) -> u64 {
    let mut n = 0u64;
    visit_schemes(arch, layer, region, rb, with_sharing, |_| {
        n += 1;
        true
    });
    n
}

/// Thread-safe branch-and-bound counters, shared by every intra-layer
/// solve of one scheduling run (the staged enumeration bumps them from all
/// worker threads; plain relaxed adds, so the totals are deterministic for
/// any thread count).
#[derive(Debug, Default)]
pub struct BnbCounters {
    /// Partitions whose blocking space was actually enumerated.
    parts_visited: AtomicU64,
    /// Partitions skipped whole: the gq-independent partition floor
    /// (`CostModel::bound_partition`) already met the incumbent.
    parts_pruned: AtomicU64,
    /// Gbuf-level prefixes whose subtree was actually enumerated.
    prefixes_visited: AtomicU64,
    /// Gbuf-level prefixes skipped because their admissible lower bound
    /// already met the incumbent.
    prefixes_pruned: AtomicU64,
    /// Prefix lower bounds computed.
    bound_evals: AtomicU64,
    /// Candidates scored on the detailed tier.
    schemes_visited: AtomicU64,
    /// Upper estimate of candidates skipped by pruned prefixes (the
    /// pre-validation subtree size: REGF block candidates x 36 orders).
    schemes_skipped: AtomicU64,
    /// Sum of `1000 * bound / incumbent` over bound evaluations (ratio
    /// clamped to 8.0), for the average bound-tightness report.
    tightness_permille: AtomicU64,
}

impl BnbCounters {
    pub fn new() -> BnbCounters {
        BnbCounters::default()
    }

    fn add(&self, c: &AtomicU64, v: u64) {
        c.fetch_add(v, Ordering::Relaxed);
    }

    /// Plain-value snapshot for reporting. `part_floor` defaults to true
    /// (the scan's default); callers that ran with the floor disabled stamp
    /// the flag before publishing the stats.
    pub fn snapshot(&self) -> BnbStats {
        BnbStats {
            part_floor: true,
            parts_visited: self.parts_visited.load(Ordering::Relaxed),
            parts_pruned: self.parts_pruned.load(Ordering::Relaxed),
            prefixes_visited: self.prefixes_visited.load(Ordering::Relaxed),
            prefixes_pruned: self.prefixes_pruned.load(Ordering::Relaxed),
            bound_evals: self.bound_evals.load(Ordering::Relaxed),
            schemes_visited: self.schemes_visited.load(Ordering::Relaxed),
            schemes_skipped: self.schemes_skipped.load(Ordering::Relaxed),
            tightness_permille: self.tightness_permille.load(Ordering::Relaxed),
        }
    }
}

/// Branch-and-bound statistics of one solve (Table VI-style reporting —
/// `SolveResult::bnb`, bench/service JSON).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct BnbStats {
    /// Whether the partition-level floor was enabled for the run these
    /// stats describe (`DpConfig::part_floor` / the `part_floor=` knob).
    /// `SolverKind` variants are field-less unit tags compared with `==`
    /// throughout, so the knob is surfaced here — in the `bnb` object of
    /// bench and service JSON — rather than folded into the solver label.
    pub part_floor: bool,
    pub parts_visited: u64,
    pub parts_pruned: u64,
    pub prefixes_visited: u64,
    pub prefixes_pruned: u64,
    pub bound_evals: u64,
    pub schemes_visited: u64,
    pub schemes_skipped: u64,
    pub(crate) tightness_permille: u64,
}

impl BnbStats {
    /// Fraction of bounded prefixes whose whole subtree was skipped.
    pub fn prune_rate(&self) -> f64 {
        let total = self.prefixes_visited + self.prefixes_pruned;
        if total == 0 {
            0.0
        } else {
            self.prefixes_pruned as f64 / total as f64
        }
    }

    /// Mean `bound / incumbent` over the prefixes where a bound was
    /// checked (1.0 and above means the prefix pruned; the closer the
    /// unpruned rest sits to 1.0, the tighter the bound).
    pub fn avg_bound_tightness(&self) -> f64 {
        if self.bound_evals == 0 {
            0.0
        } else {
            self.tightness_permille as f64 / 1000.0 / self.bound_evals as f64
        }
    }

    /// JSON object shared by bench reports and service responses.
    pub fn to_json(&self) -> crate::util::json::Json {
        let mut o = crate::util::json::Json::obj();
        o.set("part_floor", self.part_floor.into())
            .set("parts_visited", self.parts_visited.into())
            .set("parts_pruned", self.parts_pruned.into())
            .set("prefixes_visited", self.prefixes_visited.into())
            .set("prefixes_pruned", self.prefixes_pruned.into())
            .set("bound_evals", self.bound_evals.into())
            .set("schemes_visited", self.schemes_visited.into())
            .set("schemes_skipped", self.schemes_skipped.into())
            .set("prune_rate", self.prune_rate().into())
            .set("avg_bound_tightness", self.avg_bound_tightness().into());
        o
    }
}

/// Partition visiting order of the staged scans (ROADMAP item 3's
/// ordering-heuristic successor).
///
/// `Floor` visits partitions in ascending `CostModel::bound_partition`
/// order, so cheap partitions are scored first and the incumbent tightens
/// sooner — strictly more partition- and prefix-level pruning from the
/// same admissible bounds. Still exact: every partition that could hold a
/// strictly better scheme is still enumerated, so the argmin *value* is
/// untouched. What can change is the first-minimum *identity* among
/// equal-cost optima (callers keep the first strict minimum they see), so
/// order-sensitive consumers gate on cost, not bytes, and
/// `ExhaustiveIntra::fingerprint` folds the order so memoized argmins
/// never alias across orders.
///
/// `Enum` is the raw `enumerate_partitions` order — the historical
/// behavior that `visit_schemes` shares, kept for byte-order equivalence
/// tests and triage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartOrder {
    Floor,
    Enum,
}

impl PartOrder {
    pub fn name(&self) -> &'static str {
        match self {
            PartOrder::Floor => "floor",
            PartOrder::Enum => "enum",
        }
    }

    pub fn parse(s: &str) -> Result<PartOrder, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "floor" => Ok(PartOrder::Floor),
            "enum" => Ok(PartOrder::Enum),
            other => Err(format!("bad part_order {other:?}: expected floor|enum")),
        }
    }
}

/// One staged enumeration query: the layer context plus the cost model
/// whose detailed tier scores (and, when it opts in via
/// `CostModel::staged`, bounds) the candidates.
pub struct StagedQuery<'a> {
    pub arch: &'a ArchConfig,
    pub layer: &'a Layer,
    pub region: (u64, u64),
    pub rb: u64,
    pub with_sharing: bool,
    pub ifm_on_chip: bool,
    pub objective: Objective,
    pub model: &'a dyn CostModel,
    pub counters: Option<&'a BnbCounters>,
    /// Check the gq-independent partition floor (`CostModel::bound_partition`)
    /// before enumerating a partition's blockings (default on; `off` is a
    /// debugging/triage mode — the argmin is identical either way).
    pub part_floor: bool,
    /// Partition visiting order. [`StagedQuery::for_ctx`] defaults to
    /// [`PartOrder::Enum`] (the `visit_schemes` order the equivalence
    /// tests pin); the engine threads `DpConfig::part_order`, whose
    /// default is [`PartOrder::Floor`].
    pub part_order: PartOrder,
    /// Cooperative cancellation: polled at the partition and gbuf-prefix
    /// yield points; a trip abandons the remaining scan (the caller keeps
    /// whatever incumbent its visitor accumulated — anytime semantics).
    /// `None` (the default) costs one branch per yield point.
    pub cancel: Option<&'a crate::util::cancel::CancelToken>,
}

impl<'a> StagedQuery<'a> {
    pub fn for_ctx(
        arch: &'a ArchConfig,
        layer: &'a Layer,
        ctx: &IntraCtx,
        with_sharing: bool,
        model: &'a dyn CostModel,
    ) -> StagedQuery<'a> {
        StagedQuery {
            arch,
            layer,
            region: ctx.region,
            rb: ctx.rb,
            with_sharing,
            ifm_on_chip: ctx.ifm_on_chip,
            objective: ctx.objective,
            model,
            counters: None,
            part_floor: true,
            part_order: PartOrder::Enum,
            cancel: None,
        }
    }

    pub fn counters(mut self, counters: &'a BnbCounters) -> StagedQuery<'a> {
        self.counters = Some(counters);
        self
    }

    pub fn part_floor(mut self, on: bool) -> StagedQuery<'a> {
        self.part_floor = on;
        self
    }

    pub fn part_order(mut self, order: PartOrder) -> StagedQuery<'a> {
        self.part_order = order;
        self
    }

    pub fn cancel(mut self, tok: Option<&'a crate::util::cancel::CancelToken>) -> StagedQuery<'a> {
        self.cancel = tok;
        self
    }
}

/// Pre-validation size of one gbuf prefix's subtree: REGF block candidates
/// times the 36 loop-order pairs (the book-keeping value behind
/// `BnbStats::schemes_skipped`).
fn subtree_candidates(gq: Qty, granule: Qty) -> u64 {
    let b = block_candidates(gq.b, granule.b).len() as u64;
    let c = block_candidates(gq.c, granule.c).len() as u64;
    let k = block_candidates(gq.k, granule.k).len() as u64;
    b * c * k * 36
}

/// Staged, incrementally-evaluated, branch-and-bound variant of
/// [`visit_schemes`] — the enumeration hot path of the exhaustive
/// baselines.
///
/// Candidates are visited in *exactly* the order of [`visit_schemes`], and
/// the estimate handed to the visitor equals `model.evaluate` on the same
/// scheme bit for bit (staged stage-3 suffix arithmetic when the model
/// opts in via `CostModel::staged`, a plain `evaluate` call otherwise). The
/// visitor returns `Some(incumbent)` — the best cost it has accepted so
/// far, `f64::INFINITY` for none — to continue, or `None` to stop.
///
/// Contract: the incumbent MUST be `q.objective.of(..)` of an estimate
/// this visitor was handed (the two sides of the pruning comparison must
/// be in the same units and the incumbent must be achieved, not
/// aspirational) — returning a value in other units, or below every
/// real candidate, would prune subtrees unsoundly.
/// Two bound levels guard the scan (the intra-layer half of the
/// partition → prefix → span hierarchy). At every partition the
/// gq-independent `CostModel::bound_partition` floor is checked first
/// (when `q.part_floor` is on): `bound >= incumbent` proves no blocking of
/// the partition can strictly beat the incumbent, so the whole partition
/// is skipped before `qty_candidates` ever runs. At every surviving
/// `(part, gbuf block)` prefix the admissible `CostModel::bound_prefix`
/// lower bound is checked the same way and skips the subtree. Both prunes
/// never change the first-minimum argmin an exhaustive scan would return —
/// byte-identical optima, orders of magnitude fewer evaluations
/// (`tests/staged_eval_equivalence.rs` pins the equality).
pub fn visit_schemes_staged(
    q: &StagedQuery<'_>,
    mut visit: impl FnMut(&LayerScheme, &CostEstimate) -> Option<f64>,
) {
    let orders = LoopOrder::all();
    // Stage every partition's unit map, staged evaluator and (admissible,
    // gq-independent) partition floor up front — the same per-partition
    // work the loop below used to do inline, hoisted so the visiting order
    // becomes a free choice.
    let enumerated = enumerate_partitions(q.layer, q.rb, q.region, q.with_sharing);
    let mut parts = Vec::with_capacity(enumerated.len());
    for part in enumerated {
        // Cancellation yield point: staging builds unit maps and staged
        // access calculi, so a tripped token stops paying for them.
        if q.cancel.is_some_and(|c| c.is_cancelled()) {
            return;
        }
        let unit = UnitMap::build(q.arch, part.node_shape(q.layer, q.rb));
        let staged = q.model.staged(q.arch, &part, &unit, q.ifm_on_chip);
        let floor = staged
            .as_ref()
            .map(|st| q.objective.of(&q.model.bound_partition(st)))
            .unwrap_or(f64::INFINITY);
        parts.push((part, unit, staged, floor));
    }
    // Floor order: ascending partition floor, so likely-cheap partitions
    // tighten the incumbent before expensive ones are bounded against it.
    // The sort is stable (ties and floor-less partitions keep enumeration
    // order; the latter carry an INFINITY placeholder and sort last, where
    // they are still *visited* — a placeholder is not an admissible bound,
    // so it must never prune).
    if q.part_order == PartOrder::Floor {
        parts.sort_by(|a, b| a.3.total_cmp(&b.3));
    }
    let mut incumbent = f64::INFINITY;
    for (part, unit, staged, floor) in &parts {
        let (part, unit) = (*part, *unit);
        // Cancellation yield point (partition granularity): a tripped token
        // abandons the rest of the scan. Purely an early exit — iteration
        // order and scoring are untouched when the token stays live, so
        // untripped runs are byte-identical to a build without the check.
        if q.cancel.is_some_and(|c| c.is_cancelled()) {
            return;
        }
        // Partition-level branch-and-bound: the gq-independent floor over
        // every blocking of this partition, checked before the blocking
        // loops spawn. Admissible (bound_partition <= bound_prefix <=
        // evaluate for every completion), so skipping cannot change the
        // first-minimum argmin. Checked per partition (no sorted early
        // break): the incumbent only tightens mid-scan, and the INFINITY
        // placeholders of floor-less partitions sit past any break point.
        if q.part_floor && incumbent.is_finite() && staged.is_some() && *floor >= incumbent {
            if let Some(c) = q.counters {
                c.add(&c.parts_pruned, 1);
            }
            continue;
        }
        if let Some(c) = q.counters {
            c.add(&c.parts_visited, 1);
        }
        'gbuf: for gq in qty_candidates(unit.totals, unit.granule) {
            // Cancellation yield point (gbuf-prefix granularity): bounds
            // the post-trip latency to one prefix subtree even inside a
            // partition with a huge blocking space.
            if q.cancel.is_some_and(|c| c.is_cancelled()) {
                return;
            }
            // Capacity pre-check before spawning the inner loops.
            let probe = LayerScheme {
                part,
                unit,
                regf: LevelBlock { qty: unit.granule, order: orders[0] },
                gbuf: LevelBlock { qty: gq, order: orders[0] },
            };
            if probe.gbuf_words_per_node() > q.arch.gbuf_words() {
                continue 'gbuf;
            }
            // Branch-and-bound: an admissible prefix bound at or above the
            // incumbent proves the subtree cannot strictly improve on it.
            if let Some(st) = &staged {
                if incumbent.is_finite() {
                    let bound = q.model.bound_prefix(st, gq);
                    let b = q.objective.of(&bound);
                    if let Some(c) = q.counters {
                        c.add(&c.bound_evals, 1);
                        let ratio = (b / incumbent).clamp(0.0, 8.0);
                        c.add(&c.tightness_permille, (ratio * 1000.0) as u64);
                    }
                    if b >= incumbent {
                        if let Some(c) = q.counters {
                            c.add(&c.prefixes_pruned, 1);
                            c.add(&c.schemes_skipped, subtree_candidates(gq, unit.granule));
                        }
                        continue 'gbuf;
                    }
                }
            }
            if let Some(c) = q.counters {
                c.add(&c.prefixes_visited, 1);
            }
            // The six gbuf-order stage-2 evaluations of this prefix,
            // computed lazily and reused across every REGF-level candidate.
            let mut gbuf_evals: [Option<crate::sim::StagedGbuf>; 6] = [None; 6];
            for rq in qty_candidates(gq, unit.granule) {
                let probe2 = LayerScheme {
                    regf: LevelBlock { qty: rq, order: orders[0] },
                    ..probe
                };
                if probe2.regf_words_per_pe() > q.arch.regf_words() {
                    continue;
                }
                for (gi, &go) in orders.iter().enumerate() {
                    for ro in orders {
                        let s = LayerScheme {
                            part,
                            unit,
                            regf: LevelBlock { qty: rq, order: ro },
                            gbuf: LevelBlock { qty: gq, order: go },
                        };
                        if s.validate(q.arch).is_err() {
                            continue;
                        }
                        let est = match &staged {
                            Some(st) => gbuf_evals[gi]
                                .get_or_insert_with(|| st.gbuf(gq, go))
                                .cost(rq, ro),
                            None => q.model.evaluate(q.arch, &s, q.ifm_on_chip),
                        };
                        if let Some(c) = q.counters {
                            c.add(&c.schemes_visited, 1);
                        }
                        match visit(&s, &est) {
                            Some(inc) => incumbent = inc,
                            None => return,
                        }
                    }
                }
            }
        }
    }
}

/// A fallback scheme that is always valid if one exists at all: the
/// smallest blocks everywhere, on the best-effort partition. Returns `None`
/// when even the unit tensors overflow the buffers.
pub fn minimal_scheme(
    arch: &ArchConfig,
    layer: &Layer,
    region: (u64, u64),
    rb: u64,
) -> Option<LayerScheme> {
    let mut best: Option<LayerScheme> = None;
    for part in enumerate_partitions(layer, rb, region, true) {
        let unit = UnitMap::build(arch, part.node_shape(layer, rb));
        let s = LayerScheme {
            part,
            unit,
            regf: LevelBlock { qty: unit.granule, order: LoopOrder::all()[0] },
            gbuf: LevelBlock { qty: unit.granule, order: LoopOrder::all()[0] },
        };
        if s.validate(arch).is_ok() {
            best = Some(s);
            break;
        }
    }
    best.or_else(|| {
        // Fall back to a single-node mapping (region underuse).
        let part = PartitionScheme { region, ..PartitionScheme::single() };
        let unit = UnitMap::build(arch, part.node_shape(layer, rb));
        let s = LayerScheme {
            part,
            unit,
            regf: LevelBlock { qty: unit.granule, order: LoopOrder::all()[0] },
            gbuf: LevelBlock { qty: unit.granule, order: LoopOrder::all()[0] },
        };
        s.validate(arch).ok().map(|_| s)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;

    #[test]
    fn block_candidates_cover_range() {
        let c = block_candidates(12, 1);
        assert_eq!(c, vec![1, 2, 3, 4, 6, 12]);
        let c = block_candidates(32, 8);
        assert_eq!(c, vec![8, 16, 32]);
        // non-dividing granule clamps to total
        let c = block_candidates(10, 4);
        assert!(c.contains(&10));
        assert!(c.iter().all(|&x| x <= 10));
    }

    #[test]
    fn block_candidates_never_repeat() {
        // Strictly-increasing output pins the no-duplicates invariant the
        // enumeration (and the R sampler's RNG-stream stability) relies
        // on, for any (total, granule) — whether guaranteed by the clamp
        // analysis or, defensively, by the dedup.
        for total in 1..=96u64 {
            for granule in 1..=total {
                let c = block_candidates(total, granule);
                assert!(!c.is_empty(), "({total}, {granule})");
                assert!(
                    c.windows(2).all(|w| w[0] < w[1]),
                    "duplicates or disorder for ({total}, {granule}): {c:?}"
                );
                assert_eq!(*c.last().unwrap(), total);
            }
        }
    }

    #[test]
    fn qty_candidates_cartesian() {
        let q = qty_candidates(Qty::new(2, 4, 1), Qty::UNIT);
        assert_eq!(q.len(), 2 * 3 * 1);
    }

    #[test]
    fn visit_yields_only_valid() {
        let arch = presets::bench_multi_node();
        let l = Layer::conv("c", 16, 32, 14, 3, 1);
        let mut n = 0;
        visit_schemes(&arch, &l, (2, 2), 4, false, |s| {
            s.validate(&arch).unwrap();
            n += 1;
            true
        });
        assert!(n > 100, "space too small: {n}");
    }

    #[test]
    fn sharing_widens_space() {
        let arch = presets::bench_multi_node();
        let l = Layer::conv("c", 16, 32, 14, 3, 1);
        let plain = count_schemes(&arch, &l, (2, 2), 4, false);
        let wide = count_schemes(&arch, &l, (2, 2), 4, true);
        assert!(wide > plain, "{wide} !> {plain}");
    }

    #[test]
    fn early_stop_respected() {
        let arch = presets::bench_multi_node();
        let l = Layer::conv("c", 16, 32, 14, 3, 1);
        let mut n = 0;
        visit_schemes(&arch, &l, (2, 2), 4, false, |_| {
            n += 1;
            n < 10
        });
        assert_eq!(n, 10);
    }

    #[test]
    fn staged_visit_matches_naive_order_and_values() {
        // Without pruning (incumbent pinned at infinity), the staged
        // visitor must walk the exact candidate sequence of visit_schemes
        // and hand out estimates bit-identical to the one-shot evaluation.
        use crate::cost::TieredCost;
        let arch = presets::bench_multi_node();
        let l = Layer::conv("c", 16, 32, 14, 3, 1);
        let mut naive: Vec<(String, f64)> = Vec::new();
        visit_schemes(&arch, &l, (2, 2), 4, true, |s| {
            naive.push((format!("{s:?}"), crate::sim::evaluate_layer(&arch, s, false).energy.total()));
            true
        });
        let model = TieredCost::fresh();
        let ctx = IntraCtx {
            region: (2, 2),
            rb: 4,
            ifm_on_chip: false,
            objective: Objective::Energy,
        };
        let q = StagedQuery::for_ctx(&arch, &l, &ctx, true, &model);
        let mut staged: Vec<(String, f64)> = Vec::new();
        visit_schemes_staged(&q, |s, est| {
            staged.push((format!("{s:?}"), est.energy_pj));
            Some(f64::INFINITY)
        });
        assert_eq!(naive.len(), staged.len());
        for (n, s) in naive.iter().zip(&staged) {
            assert_eq!(n.0, s.0, "candidate order diverged");
            assert_eq!(n.1, s.1, "staged estimate diverged on {}", n.0);
        }
    }

    #[test]
    fn bnb_pruning_preserves_the_argmin() {
        use crate::cost::TieredCost;
        let arch = presets::bench_multi_node();
        let ctx = IntraCtx {
            region: (2, 2),
            rb: 4,
            ifm_on_chip: false,
            objective: Objective::Energy,
        };
        for l in [Layer::conv("c", 32, 64, 28, 3, 1), Layer::fc("f", 256, 512)] {
            let mut full: Option<(f64, LayerScheme)> = None;
            visit_schemes(&arch, &l, ctx.region, ctx.rb, true, |s| {
                let e = crate::sim::evaluate_layer(&arch, s, false).energy.total();
                if full.as_ref().map(|(b, _)| e < *b).unwrap_or(true) {
                    full = Some((e, *s));
                }
                true
            });
            let model = TieredCost::fresh();
            let counters = BnbCounters::new();
            let q = StagedQuery::for_ctx(&arch, &l, &ctx, true, &model).counters(&counters);
            let mut pruned: Option<(f64, LayerScheme)> = None;
            visit_schemes_staged(&q, |s, est| {
                let c = est.energy_pj;
                if pruned.as_ref().map(|(b, _)| c < *b).unwrap_or(true) {
                    pruned = Some((c, *s));
                }
                Some(pruned.as_ref().map(|(b, _)| *b).unwrap_or(f64::INFINITY))
            });
            let (fe, fs) = full.unwrap();
            let (pe, ps) = pruned.unwrap();
            assert_eq!(fe, pe, "{}: optimum value changed", l.name);
            assert_eq!(format!("{fs:?}"), format!("{ps:?}"), "{}: optimum scheme changed", l.name);
            let st = counters.snapshot();
            assert!(st.schemes_visited > 0);
            assert!(
                st.prefixes_pruned > 0,
                "{}: expected some subtree pruning (visited {}, bounds {})",
                l.name,
                st.prefixes_visited,
                st.bound_evals
            );
            assert!(
                st.parts_pruned > 0,
                "{}: expected some whole-partition pruning (parts visited {})",
                l.name,
                st.parts_visited
            );

            // With the partition floor disabled the scan walks every
            // partition — and still lands on the exact same argmin.
            let off_counters = BnbCounters::new();
            let qo = StagedQuery::for_ctx(&arch, &l, &ctx, true, &model)
                .counters(&off_counters)
                .part_floor(false);
            let mut off: Option<(f64, LayerScheme)> = None;
            visit_schemes_staged(&qo, |s, est| {
                let c = est.energy_pj;
                if off.as_ref().map(|(b, _)| c < *b).unwrap_or(true) {
                    off = Some((c, *s));
                }
                Some(off.as_ref().map(|(b, _)| *b).unwrap_or(f64::INFINITY))
            });
            let (oe, os) = off.unwrap();
            assert_eq!(fe, oe, "{}: part_floor=off changed the optimum", l.name);
            assert_eq!(format!("{fs:?}"), format!("{os:?}"), "{}: part_floor=off scheme", l.name);
            let ost = off_counters.snapshot();
            assert_eq!(ost.parts_pruned, 0);
            assert!(ost.parts_visited >= st.parts_visited + st.parts_pruned);
        }
    }

    #[test]
    fn part_order_floor_preserves_argmin_value() {
        // Floor ordering re-sorts partitions by their admissible floor, so
        // the *first* minimum can land on a different (equal-cost) scheme —
        // the pin is therefore on the optimum value and coverage, not on
        // candidate bytes. Floor order must also never prune more than it
        // is entitled to: every partition is either visited or pruned, and
        // the totals match enumeration order.
        use crate::cost::TieredCost;
        let arch = presets::bench_multi_node();
        let ctx = IntraCtx {
            region: (2, 2),
            rb: 4,
            ifm_on_chip: false,
            objective: Objective::Energy,
        };
        for l in [Layer::conv("c", 32, 64, 28, 3, 1), Layer::fc("f", 256, 512)] {
            let model = TieredCost::fresh();
            let mut best = [f64::INFINITY; 2];
            let mut totals = [0u64; 2];
            let mut pruned_cnt = [0u64; 2];
            for (i, order) in [PartOrder::Enum, PartOrder::Floor].into_iter().enumerate() {
                let counters = BnbCounters::new();
                let q = StagedQuery::for_ctx(&arch, &l, &ctx, true, &model)
                    .counters(&counters)
                    .part_order(order);
                let mut inc = f64::INFINITY;
                visit_schemes_staged(&q, |_, est| {
                    if est.energy_pj < inc {
                        inc = est.energy_pj;
                    }
                    Some(inc)
                });
                best[i] = inc;
                let st = counters.snapshot();
                totals[i] = st.parts_visited + st.parts_pruned;
                pruned_cnt[i] = st.parts_pruned;
            }
            assert!(best[0].is_finite(), "{}: no scheme found", l.name);
            assert_eq!(best[0], best[1], "{}: part_order changed the optimum", l.name);
            assert_eq!(totals[0], totals[1], "{}: partition coverage diverged", l.name);
            // The whole point of floor ordering: the incumbent tightens
            // sooner, so at least as many partitions get bounded away.
            assert!(
                pruned_cnt[1] >= pruned_cnt[0],
                "{}: floor order pruned fewer partitions ({} < {})",
                l.name,
                pruned_cnt[1],
                pruned_cnt[0]
            );
        }
    }

    #[test]
    fn minimal_scheme_exists_for_all_nets() {
        let arch = presets::multi_node_eyeriss();
        for net in crate::workloads::all_networks() {
            for l in &net.layers {
                assert!(
                    minimal_scheme(&arch, l, (4, 4), 4).is_some(),
                    "{}: {}",
                    net.name,
                    l.name
                );
            }
        }
    }

    #[test]
    fn minimal_scheme_on_edge_device() {
        let arch = presets::edge_tpu();
        for net in crate::workloads::all_networks() {
            for l in &net.layers {
                assert!(
                    minimal_scheme(&arch, l, (1, 1), 1).is_some(),
                    "{}: {}",
                    net.name,
                    l.name
                );
            }
        }
    }
}
