//! The solver engine: one entry point for every solver family.
//!
//! [`SolveCtx`] owns the pieces every scheduling run needs — the hardware
//! config, the DP knobs (including the scoped worker-pool width), the
//! objective, and the tiered [`CostModel`] both search phases draw from —
//! and exposes one generic [`SolveCtx::run`] that dispatches a
//! [`SolverKind`]. RNG-stream derivation is owned here too: the engine
//! builds each stochastic intra-layer solver from its kind's seed, and the
//! solvers fold `ctx_fingerprint` into that seed per context, so schedules
//! are byte-identical for any thread count or cache state.
//!
//! Two internal paths implement the paper's split:
//!
//! * `exact_dp` — the exact segment-chain DP with fully intra-solved,
//!   simulator-evaluated segments (baselines B/S/R/M, paper §V);
//! * `kapla` — the decoupled fast path (paper §IV-B): estimate-tier
//!   pruning + DP prioritization first, detailed intra-layer solving only
//!   for the top-k_S chains.

use std::collections::HashMap;

use crate::arch::ArchConfig;
use crate::cost::{CostModel, EvalCache, TieredCost};
use crate::directives::LayerScheme;
use crate::interlayer::dp::{best_chains_cancellable, DpConfig};
use crate::interlayer::prune::conservative_valid;
use crate::interlayer::{candidate_spans, enumerate_segment_schemes, Schedule, Segment};
use crate::sim::pipeline::{evaluate_schedule, evaluate_segment};
use crate::workloads::Network;

use super::exhaustive::ExhaustiveIntra;
use super::kapla::KaplaIntra;
use super::ml::MlIntra;
use super::random::RandomIntra;
use super::{
    collect_intra_keys, presolve_contexts, seg_objective, solve_segment_layers, Degraded,
    IntraCache, IntraSolver, Objective, SolveError, SolveResult, SolverKind,
};
use crate::util::cancel::CancelToken;

enum Model<'a> {
    /// The default tiered model over a private or shared evaluation cache.
    Tiered(TieredCost<'a>),
    /// A caller-supplied model (e.g. a batched-backend implementation).
    External(&'a dyn CostModel),
}

/// The engine object behind every solver entry. Construct with
/// [`SolveCtx::new`], adjust with the builder methods, then call
/// [`SolveCtx::run`] per scheduling job:
///
/// ```
/// use kapla::arch::presets;
/// use kapla::solvers::{SolveCtx, SolverKind};
/// use kapla::workloads::nets;
///
/// let arch = presets::bench_multi_node();
/// let r = SolveCtx::new(&arch).run(&nets::mlp(), 8, SolverKind::Kapla).unwrap();
/// assert_eq!(r.schedule.num_layers(), nets::mlp().len());
/// ```
pub struct SolveCtx<'a> {
    arch: &'a ArchConfig,
    objective: Objective,
    dp: DpConfig,
    model: Model<'a>,
    cancel: CancelToken,
}

impl<'a> SolveCtx<'a> {
    /// An engine over `arch` with default DP knobs, the energy objective
    /// and a private, fresh evaluation cache.
    pub fn new(arch: &'a ArchConfig) -> SolveCtx<'a> {
        SolveCtx {
            arch,
            objective: Objective::Energy,
            dp: DpConfig::default(),
            model: Model::Tiered(TieredCost::fresh()),
            cancel: CancelToken::none(),
        }
    }

    /// Set the optimization objective.
    pub fn objective(mut self, obj: Objective) -> Self {
        self.objective = obj;
        self
    }

    /// Set the DP knobs (k_S, segment length, rounds cap, worker threads).
    pub fn dp(mut self, dp: DpConfig) -> Self {
        self.dp = dp;
        self
    }

    /// Attach a cooperative cancellation token (deadline or manual). The
    /// engine threads it into every cancellable solver and the inter-layer
    /// planner; on a trip the run returns its best incumbent as a
    /// [`SolveResult`] with [`SolveResult::degraded`] set (anytime
    /// semantics) rather than an error. An untripped token never changes
    /// any result — pinned by `tests/deadline_anytime.rs`.
    pub fn cancel(mut self, tok: CancelToken) -> Self {
        self.cancel = tok;
        self
    }

    /// The degraded marker for the current token state, stamped onto
    /// results after the solve finishes. Conservative by design: a
    /// deadline that expires between the last yield point and this check
    /// still marks the (complete) result `best_effort` — callers may
    /// treat `degraded` as "the budget was exhausted", never the reverse.
    fn degraded_mark(&self) -> Option<Degraded> {
        let tok = self.cancel.active()?;
        if tok.is_cancelled() {
            Some(Degraded {
                reason: tok.reason().unwrap_or("cancelled"),
                elapsed_ms: tok.elapsed_ms(),
                best_effort: true,
            })
        } else {
            None
        }
    }

    /// Run the detailed tier through a shared evaluation cache — the hook
    /// scheduling sessions use to reuse detailed-model evaluations across
    /// jobs (the cache key carries the arch fingerprint, so one session
    /// can serve jobs on different hardware configs without aliasing).
    ///
    /// Mutually exclusive with [`SolveCtx::model`]: each of the two
    /// replaces the engine's whole cost model, so the *last* call wins.
    /// A custom model that wants session reuse should compose the cache
    /// itself (as [`TieredCost::over`] does) and be passed via `model`.
    pub fn session(mut self, cache: &'a dyn EvalCache) -> Self {
        self.model = Model::Tiered(TieredCost::over(cache));
        self
    }

    /// Replace the whole cost model — both tiers — with a caller-supplied
    /// implementation (a batched-kernel backend, a recording proxy, ...).
    ///
    /// Mutually exclusive with [`SolveCtx::session`] — the last call wins
    /// (a later `.session(...)` would silently discard this backend, so
    /// configure exactly one of the two).
    pub fn model(mut self, model: &'a dyn CostModel) -> Self {
        self.model = Model::External(model);
        self
    }

    /// The cost model this engine scores candidates with.
    pub fn cost_model(&self) -> &dyn CostModel {
        match &self.model {
            Model::Tiered(m) => m,
            Model::External(m) => *m,
        }
    }

    /// Solve one network under the given solver kind. Schedules are
    /// byte-identical for any `dp.solve_threads` and any session/budget
    /// state (the golden battery in `tests/parallel_determinism.rs`).
    /// Degenerate net/arch combinations return a structured [`SolveError`]
    /// instead of panicking (front ends surface it; the service maps it to
    /// an error response).
    pub fn run(
        &self,
        net: &Network,
        batch: u64,
        kind: SolverKind,
    ) -> Result<SolveResult, SolveError> {
        match kind {
            SolverKind::Kapla => self.kapla(net, batch),
            SolverKind::Baseline | SolverKind::DirectiveExhaustive => {
                // The exhaustive scans run on the staged branch-and-bound
                // enumeration; aggregate its pruning counters across every
                // intra-layer solve of the run into `SolveResult::bnb`.
                // Warm sessions may replay recorded argmins, in which case
                // the skipped scans legitimately report zero visits.
                let counters = super::space::BnbCounters::new();
                let intra = ExhaustiveIntra {
                    with_sharing: kind == SolverKind::DirectiveExhaustive,
                    stats: Some(&counters),
                    part_floor: self.dp.part_floor,
                    part_order: self.dp.part_order,
                    cancel: self.cancel.active(),
                };
                let mut r = self.exact_dp(net, batch, &intra)?;
                let mut st = counters.snapshot();
                st.part_floor = self.dp.part_floor;
                r.bnb = Some(st);
                Ok(r)
            }
            SolverKind::Random { p, seed } => self.exact_dp(
                net,
                batch,
                &RandomIntra::new(p, seed).with_cancel(self.cancel.clone()),
            ),
            SolverKind::Ml { seed, rounds, batch: sa_batch } => self.exact_dp(
                net,
                batch,
                &MlIntra::native(seed, rounds, sa_batch).with_cancel(self.cancel.clone()),
            ),
        }
    }

    /// Exact dynamic program over segment chains: every candidate segment
    /// is fully intra-solved and simulator-evaluated (this is what makes
    /// the exhaustive/random/ML baselines slow and exact). Conservative
    /// validity pruning is safe for optimality and applied for all
    /// solvers, mirroring nn-dataflow's own buffering checks.
    ///
    /// With `dp.solve_threads > 1` the intra-layer solves — the dominant
    /// cost by orders of magnitude — run first, sharded across a scoped
    /// worker pool: the candidate segments (and hence solve contexts) do
    /// not depend on DP state, only the chain costs do, so the sequential
    /// DP afterwards is pure cache assembly and the result is identical to
    /// the single-threaded run.
    pub fn exact_dp(
        &self,
        net: &Network,
        batch: u64,
        intra: &dyn IntraSolver,
    ) -> Result<SolveResult, SolveError> {
        let timer = crate::util::Timer::start();
        let (arch, obj, cfg) = (self.arch, self.objective, &self.dp);
        let model = self.cost_model();
        let n = net.len();
        struct Node {
            cost: f64,
            seg: Segment,
            schemes: Vec<LayerScheme>,
            parent: Option<usize>, // layer index of previous chain node
        }
        let mut table: Vec<Option<Node>> = (0..n).map(|_| None).collect();
        let mut cache: IntraCache = HashMap::new();

        // Enumerate every candidate segment once, grouped per (end layer,
        // span start). The enumeration is DP-state-independent, so the
        // same list feeds both the parallel pre-solve and the DP proper.
        // Holding all spans' candidates at once costs O(total segments)
        // small structs (~100 MB at the most extreme full-scale settings,
        // trivial at CI scale) and buys a single loop shape for both
        // thread modes.
        let mut spans_by_end: Vec<Vec<(usize, Vec<Segment>)>> = Vec::with_capacity(n);
        for i in 0..n {
            let mut per_span = Vec::new();
            for span in candidate_spans(i, cfg.max_seg_len) {
                let segs: Vec<Segment> =
                    enumerate_segment_schemes(net, arch, batch, &span, cfg.max_rounds)
                        .into_iter()
                        .filter(|seg| conservative_valid(arch, net, batch, seg))
                        .collect();
                per_span.push((span[0], segs));
            }
            spans_by_end.push(per_span);
        }

        if cfg.solve_threads > 1 {
            let keys = collect_intra_keys(
                net,
                batch,
                spans_by_end.iter().flatten().flat_map(|(_, segs)| segs.iter()),
            );
            presolve_contexts(arch, net, keys, intra, obj, cfg.solve_threads, &mut cache, model);
        }

        for i in 0..n {
            for (start, segs) in &spans_by_end[i] {
                let start = *start;
                let prev_cost = if start == 0 {
                    0.0
                } else {
                    match &table[start - 1] {
                        Some(nd) => nd.cost,
                        None => continue,
                    }
                };
                for seg in segs {
                    let Some(schemes) =
                        solve_segment_layers(arch, net, batch, seg, intra, obj, &mut cache, model)
                    else {
                        continue;
                    };
                    let ev = evaluate_segment(arch, net, seg, &schemes);
                    let cost = prev_cost + seg_objective(&ev, obj);
                    let better = table[i].as_ref().map(|nd| cost < nd.cost).unwrap_or(true);
                    if better {
                        table[i] = Some(Node {
                            cost,
                            seg: seg.clone(),
                            schemes,
                            parent: if start == 0 { None } else { Some(start - 1) },
                        });
                    }
                }
            }
            if table[i].is_none() {
                return Err(SolveError::Unschedulable {
                    layer: i,
                    layer_name: net.layers[i].name.clone(),
                });
            }
        }

        // Reconstruct.
        let mut segments = Vec::new();
        let mut cur = Some(n - 1);
        while let Some(i) = cur {
            let nd = table[i].as_ref().unwrap();
            segments.push((nd.seg.clone(), nd.schemes.clone()));
            cur = nd.parent;
        }
        segments.reverse();
        let schedule = Schedule { segments };
        let eval = evaluate_schedule(arch, net, &schedule);
        Ok(SolveResult {
            schedule,
            eval,
            solve_s: timer.elapsed_s(),
            cache: model.stats(),
            prune: None,
            bnb: None,
            degraded: self.degraded_mark(),
        })
    }

    /// Full KAPLA network scheduling (paper §IV): estimate-tier inter-layer
    /// DP, then intra-layer solving of the top-k_S chains, final pick on
    /// the detailed tier. `SolveResult::prune` carries the pruning stats.
    ///
    /// With `dp.solve_threads > 1` the distinct per-layer solve contexts of
    /// all top-k_S chains are solved first across the scoped worker pool;
    /// the chain assembly afterwards only reads the memo, so the schedule
    /// is identical to the sequential run for any thread count.
    pub fn kapla(&self, net: &Network, batch: u64) -> Result<SolveResult, SolveError> {
        let timer = crate::util::Timer::start();
        let (arch, obj, cfg) = (self.arch, self.objective, &self.dp);
        let model = self.cost_model();
        // A deadline trip mid-DP means the planner's partial table holds no
        // complete chain to return — degrade to the all-singleton fallback
        // below (KaplaIntra descent is fast and always terminates), so the
        // caller still gets a valid best-effort schedule, not an error.
        let (chains, stats) = match best_chains_cancellable(
            arch,
            net,
            batch,
            cfg,
            model,
            self.cancel.active(),
        ) {
            Ok(r) => r,
            Err(SolveError::Deadline { .. }) => (Vec::new(), Default::default()),
            Err(e) => return Err(e),
        };
        let intra = KaplaIntra;
        let mut cache: IntraCache = HashMap::new();

        if cfg.solve_threads > 1 {
            let keys =
                collect_intra_keys(net, batch, chains.iter().flat_map(|c| c.segments.iter()));
            presolve_contexts(arch, net, keys, &intra, obj, cfg.solve_threads, &mut cache, model);
        }

        let mut best: Option<(f64, Schedule)> = None;
        for chain in &chains {
            let mut segments = Vec::with_capacity(chain.segments.len());
            let mut ok = true;
            for seg in &chain.segments {
                match solve_segment_layers(arch, net, batch, seg, &intra, obj, &mut cache, model) {
                    Some(schemes) => segments.push((seg.clone(), schemes)),
                    None => {
                        ok = false;
                        break;
                    }
                }
            }
            if !ok {
                continue;
            }
            let sched = Schedule { segments };
            let ev = evaluate_schedule(arch, net, &sched);
            let c = match obj {
                Objective::Energy => ev.energy.total(),
                Objective::Latency => ev.latency_cycles,
            };
            if best.as_ref().map(|(b, _)| c < *b).unwrap_or(true) {
                best = Some((c, sched));
            }
        }

        // Fallback: all-singleton chain (realizable whenever the network
        // is schedulable at all; a layer that defeats even this returns a
        // structured error instead of panicking the caller).
        let schedule = match best {
            Some((_, s)) => s,
            None => {
                let mut segments = Vec::new();
                for i in 0..net.len() {
                    let seg = Segment::single(i, arch);
                    let Some(schemes) = solve_segment_layers(
                        arch, net, batch, &seg, &intra, obj, &mut cache, model,
                    ) else {
                        return Err(SolveError::Unschedulable {
                            layer: i,
                            layer_name: net.layers[i].name.clone(),
                        });
                    };
                    segments.push((seg, schemes));
                }
                Schedule { segments }
            }
        };
        let eval = evaluate_schedule(arch, net, &schedule);
        Ok(SolveResult {
            schedule,
            eval,
            solve_s: timer.elapsed_s(),
            cache: model.stats(),
            prune: Some(stats),
            bnb: None,
            degraded: self.degraded_mark(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::cost::{CostEstimate, SessionCache};
    use crate::workloads::{nets, Layer, Network};

    /// Minimal intra solver for tests: smallest valid scheme.
    struct Minimal;
    impl IntraSolver for Minimal {
        fn name(&self) -> &'static str {
            "minimal"
        }
        fn solve(
            &self,
            arch: &ArchConfig,
            layer: &Layer,
            ctx: &super::super::IntraCtx,
            _model: &dyn CostModel,
        ) -> Option<LayerScheme> {
            super::super::space::minimal_scheme(arch, layer, ctx.region, ctx.rb)
        }
    }

    fn small_net() -> Network {
        let mut n = Network::new("s", 8, 28, 28);
        n.chain(Layer::conv("a", 8, 16, 28, 3, 1));
        n.chain(Layer::conv("b", 16, 16, 28, 3, 1));
        n.chain(Layer::fc("c", 16 * 28 * 28, 64));
        n
    }

    #[test]
    fn exact_dp_produces_full_coverage() {
        let arch = presets::bench_multi_node();
        let net = small_net();
        let r = SolveCtx::new(&arch).exact_dp(&net, 4, &Minimal).unwrap();
        assert_eq!(r.schedule.num_layers(), net.len());
        assert!(r.eval.energy.total() > 0.0);
        assert!(r.prune.is_none());
        let mut seen = Vec::new();
        for (seg, schemes) in &r.schedule.segments {
            assert_eq!(seg.len(), schemes.len());
            seen.extend(seg.layers.iter().copied());
        }
        assert_eq!(seen, (0..net.len()).collect::<Vec<_>>());
    }

    #[test]
    fn exact_dp_objective_latency_differs() {
        let arch = presets::bench_multi_node();
        let net = small_net();
        let re = SolveCtx::new(&arch).exact_dp(&net, 4, &Minimal).unwrap();
        let rl = SolveCtx::new(&arch)
            .objective(Objective::Latency)
            .exact_dp(&net, 4, &Minimal)
            .unwrap();
        // Latency-optimized schedule can't have worse latency than the
        // energy-optimized one (same space, different objective).
        assert!(rl.eval.latency_cycles <= re.eval.latency_cycles + 1e-6);
    }

    #[test]
    fn works_on_mlp_at_edge() {
        let arch = presets::edge_tpu();
        let net = nets::mlp();
        let r = SolveCtx::new(&arch).exact_dp(&net, 1, &Minimal).unwrap();
        assert_eq!(r.schedule.num_layers(), net.len());
        for (seg, _) in &r.schedule.segments {
            assert_eq!(seg.len(), 1); // single node: no pipelining
        }
    }

    #[test]
    fn parallel_dp_matches_sequential_exactly() {
        let arch = presets::bench_multi_node();
        let net = small_net();
        let seq = SolveCtx::new(&arch)
            .dp(DpConfig { solve_threads: 1, ..DpConfig::default() })
            .exact_dp(&net, 4, &Minimal)
            .unwrap();
        let par = SolveCtx::new(&arch)
            .dp(DpConfig { solve_threads: 4, ..DpConfig::default() })
            .exact_dp(&net, 4, &Minimal)
            .unwrap();
        assert_eq!(seq.eval.energy.total(), par.eval.energy.total());
        assert_eq!(seq.eval.latency_cycles, par.eval.latency_cycles);
        assert_eq!(format!("{:?}", seq.schedule), format!("{:?}", par.schedule));
    }

    #[test]
    fn run_dispatches_every_solver_kind() {
        let arch = presets::bench_multi_node();
        let net = nets::mlp();
        let ctx = SolveCtx::new(&arch).dp(DpConfig { max_rounds: 8, ..DpConfig::default() });
        for kind in [
            SolverKind::Baseline,
            SolverKind::DirectiveExhaustive,
            SolverKind::Random { p: 0.15, seed: 1 },
            SolverKind::Ml { seed: 1, rounds: 4, batch: 16 },
            SolverKind::Kapla,
        ] {
            let r = ctx.run(&net, 8, kind).unwrap();
            assert_eq!(r.schedule.num_layers(), net.len(), "{kind:?}");
            assert!(r.eval.energy.total() > 0.0, "{kind:?}");
            assert_eq!(r.prune.is_some(), kind == SolverKind::Kapla, "{kind:?}");
            // The exhaustive scans report their branch-and-bound counters.
            let exhaustive =
                matches!(kind, SolverKind::Baseline | SolverKind::DirectiveExhaustive);
            assert_eq!(r.bnb.is_some(), exhaustive, "{kind:?}");
            if let Some(b) = r.bnb {
                assert!(b.schemes_visited > 0, "{kind:?}");
            }
        }
    }

    #[test]
    fn session_engine_matches_solitary_engine() {
        let arch = presets::bench_multi_node();
        let net = nets::mlp();
        let dp = DpConfig { max_rounds: 8, ..DpConfig::default() };
        let solo = SolveCtx::new(&arch).dp(dp).run(&net, 8, SolverKind::Kapla).unwrap();
        let session = SessionCache::unbounded();
        let a =
            SolveCtx::new(&arch).dp(dp).session(&session).run(&net, 8, SolverKind::Kapla).unwrap();
        let b =
            SolveCtx::new(&arch).dp(dp).session(&session).run(&net, 8, SolverKind::Kapla).unwrap();
        for r in [&a, &b] {
            assert_eq!(format!("{:?}", r.schedule), format!("{:?}", solo.schedule));
            assert_eq!(r.eval.energy.total(), solo.eval.energy.total());
        }
        // Warm repeat replayed every recorded intra-layer argmin — the
        // scans (and their per-candidate evaluations) never ran at all.
        assert!(b.cache.intra_hits > a.cache.intra_hits);
        assert_eq!(b.cache.lookups, a.cache.lookups);
        assert_eq!(b.cache.entries, a.cache.entries);
    }

    #[test]
    fn degenerate_net_returns_structured_error_not_panic() {
        // A row-stationary unit block holds a full per-node input plane,
        // so a conv with an 8192x8192 output plane (~4M-word ifm even
        // under the deepest 4x4 spatial split, vs a 16K-word GBUF) admits
        // no valid scheme at all. The engine must report that as a
        // SolveError (the service maps it to an error response) instead
        // of panicking a long-running caller.
        let arch = presets::bench_multi_node();
        let mut net = Network::new("degenerate", 8, 8192, 8192);
        net.chain(Layer::conv("galaxy", 8, 8, 8192, 3, 1));
        let err = SolveCtx::new(&arch)
            .run(&net, 1, SolverKind::Baseline)
            .err()
            .expect("a full-plane 8192^2 conv cannot schedule on 16K-word GBUFs");
        match &err {
            SolveError::Unschedulable { layer, layer_name } => {
                assert_eq!(*layer, 0);
                assert_eq!(layer_name, "galaxy");
            }
            other => panic!("expected Unschedulable, got {other:?}"),
        }
        assert!(err.to_string().contains("galaxy"));
        // The KAPLA path reports the same failure through its fallback.
        let err = SolveCtx::new(&arch).run(&net, 1, SolverKind::Kapla).err().expect("kapla");
        assert!(matches!(err, SolveError::Unschedulable { .. }));
    }

    #[test]
    fn external_model_is_consulted() {
        // A custom CostModel (here: the default tiers plus a call counter)
        // plugs into the engine via `.model(...)` — the drop-in hook for a
        // batched backend.
        use std::sync::atomic::{AtomicU64, Ordering};
        struct Counting {
            inner: TieredCost<'static>,
            calls: AtomicU64,
        }
        impl CostModel for Counting {
            fn evaluate(
                &self,
                arch: &ArchConfig,
                s: &LayerScheme,
                ifm_on_chip: bool,
            ) -> CostEstimate {
                self.calls.fetch_add(1, Ordering::Relaxed);
                self.inner.evaluate(arch, s, ifm_on_chip)
            }
            fn stats(&self) -> crate::cost::CacheStats {
                self.inner.stats()
            }
        }
        let arch = presets::bench_multi_node();
        let net = nets::mlp();
        let counting = Counting { inner: TieredCost::fresh(), calls: AtomicU64::new(0) };
        let dp = DpConfig { max_rounds: 8, ..DpConfig::default() };
        let r =
            SolveCtx::new(&arch).dp(dp).model(&counting).run(&net, 8, SolverKind::Kapla).unwrap();
        let baseline = SolveCtx::new(&arch).dp(dp).run(&net, 8, SolverKind::Kapla).unwrap();
        assert!(counting.calls.load(Ordering::Relaxed) > 0, "model must be consulted");
        assert_eq!(format!("{:?}", r.schedule), format!("{:?}", baseline.schedule));
    }
}
