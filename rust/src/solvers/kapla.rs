//! The KAPLA intra-layer solver (paper §IV).
//!
//! *Bottom-up cost descending* (Algorithm 1). Starting from the PE
//! mapping's unit tensors, each memory level is solved in turn — a greedy
//! *stacking* pass chooses node-parallel dims (hill-climbing over
//! partition moves), then a *caching* pass enlarges the resident block one
//! divisor step at a time, always growing a dimension that relieves the
//! currently most-accessed tensor, until the buffer capacity is used up.
//! Validity holds *by construction* at every step, eliminating the
//! capacity-check churn of top-down factorization.
//!
//! Every probe and final sweep scores candidates through the detailed tier
//! of the shared [`CostModel`]; the network-level flow (estimate-tier DP,
//! top-k_S realization) lives in [`super::SolveCtx::kapla`].

use crate::arch::ArchConfig;
use crate::cost::{CostModel, TieredCost};
use crate::directives::{
    refetch_factor_groups, tensor_groups, Grp, LayerScheme, LevelBlock, LoopOrder, Qty, TensorKind,
};
use crate::mapping::UnitMap;
use crate::partition::PartitionScheme;
use crate::util::next_divisor;
use crate::workloads::Layer;

use super::{IntraCtx, IntraSolver};

/// The KAPLA intra-layer solver.
#[derive(Debug, Clone, Copy, Default)]
pub struct KaplaIntra;

impl IntraSolver for KaplaIntra {
    fn name(&self) -> &'static str {
        "kapla"
    }

    fn solve(
        &self,
        arch: &ArchConfig,
        layer: &Layer,
        ctx: &IntraCtx,
        model: &dyn CostModel,
    ) -> Option<LayerScheme> {
        solve_intra_cached(arch, layer, ctx, model)
    }
}

/// Bottom-up solve of one layer in one context (convenience wrapper: each
/// call gets a private tiered model with a fresh evaluation memo).
pub fn solve_intra(arch: &ArchConfig, layer: &Layer, ctx: &IntraCtx) -> Option<LayerScheme> {
    solve_intra_cached(arch, layer, ctx, &TieredCost::fresh())
}

/// Bottom-up solve of one layer in one context, scoring through the
/// detailed tier of the shared cost `model` (cache-backed: per-run memo or
/// a cross-job `cost::SessionCache`). The stacking pass probes each
/// partition with the default loop orders and the final sweep re-scores
/// the same schemes, so even a single solve hits the cache; across
/// overlapping segment contexts — and across session jobs — the reuse
/// compounds.
pub fn solve_intra_cached(
    arch: &ArchConfig,
    layer: &Layer,
    ctx: &IntraCtx,
    model: &dyn CostModel,
) -> Option<LayerScheme> {
    let mut best: Option<(f64, LayerScheme)> = None;
    for part in stacking_candidates(arch, layer, ctx, model) {
        let unit = UnitMap::build(arch, part.node_shape(layer, ctx.rb));
        // Level 1: REGF caching per order. The REGF block must stay
        // GBUF-feasible too (the next level's block contains it).
        for ro in LoopOrder::all() {
            let rq = descend(&unit, unit.granule, unit.totals, ro, |q| {
                unit.regf_pe_words(q) <= arch.regf_words() && gbuf_fits(arch, &unit, &part, q)
            });
            if unit.regf_pe_words(rq) > arch.regf_words() || !gbuf_fits(arch, &unit, &part, rq) {
                continue; // even the unit tensors overflow the buffers
            }
            // Level 2: GBUF caching per order, starting from the REGF block.
            for go in LoopOrder::all() {
                let gq = descend(&unit, rq, unit.totals, go, |q| gbuf_fits(arch, &unit, &part, q));
                let s = LayerScheme {
                    part,
                    unit,
                    regf: LevelBlock { qty: rq, order: ro },
                    gbuf: LevelBlock { qty: gq, order: go },
                };
                if s.validate(arch).is_err() {
                    continue;
                }
                let est = model.evaluate(arch, &s, ctx.ifm_on_chip);
                let c = ctx.objective.of(&est);
                if best.as_ref().map(|(b, _)| c < *b).unwrap_or(true) {
                    best = Some((c, s));
                }
            }
        }
    }
    best.map(|(_, s)| s)
}

fn gbuf_fits(arch: &ArchConfig, unit: &UnitMap, part: &PartitionScheme, q: Qty) -> bool {
    let ifm = unit.ifm_node_words(q).div_ceil(part.ifm_shr());
    let wgt = unit.wgt_node_words(q).div_ceil(part.wgt_shr());
    ifm + wgt + unit.ofm_node_words(q) <= arch.gbuf_words()
}

/// Total next-level access volume (words) of all three tensors under block
/// `q` — the cost the caching pass descends.
fn level_accesses(unit: &UnitMap, q: Qty, totals: Qty, order: LoopOrder) -> u64 {
    let kind = unit.shape.kind;
    let trips = q.trips_over(totals);
    TensorKind::ALL
        .iter()
        .map(|&t| {
            let (mem, miss) = tensor_groups(t, kind);
            let words = match t {
                TensorKind::Ifm => unit.ifm_node_words(q),
                TensorKind::Ofm => unit.ofm_node_words(q),
                TensorKind::Wgt => unit.wgt_node_words(q),
            };
            words * refetch_factor_groups(trips, order, mem, miss)
        })
        .sum()
}

/// The greedy caching pass of Algorithm 1: enlarge `q` one divisor step at
/// a time along the dimension whose growth most reduces the total access
/// volume to the next level (the paper picks the dim helping the
/// most-accessed tensor; evaluating all three one-step candidates and
/// keeping the best descent is the same cost-descending rule with exact
/// tie-breaking). Stops when the buffer capacity is exhausted or no step
/// descends. Runs in O(steps x 3) with pure arithmetic.
fn descend(
    unit: &UnitMap,
    start: Qty,
    totals: Qty,
    order: LoopOrder,
    fits: impl Fn(Qty) -> bool,
) -> Qty {
    let mut q = start;
    let mut cur = level_accesses(unit, q, totals, order);
    loop {
        let mut best: Option<(u64, Qty)> = None;
        for g in Grp::ALL {
            if let Some(next) = grow(q, g, totals, unit.granule) {
                if !fits(next) {
                    continue;
                }
                let acc = level_accesses(unit, next, totals, order);
                if best.as_ref().map(|(b, _)| acc < *b).unwrap_or(true) {
                    best = Some((acc, next));
                }
            }
        }
        match best {
            // Accept equal-cost growth too: filling spare capacity never
            // hurts and can unlock further descent (ceil-trip plateaus).
            Some((acc, next)) if acc <= cur => {
                q = next;
                cur = acc;
            }
            _ => break,
        }
    }
    q
}

/// Enlarge group `g` of `q` to its next blocked size (next divisor of the
/// granule-unit count), or `None` if already at the total.
fn grow(q: Qty, g: Grp, totals: Qty, granule: Qty) -> Option<Qty> {
    let gran = granule.get(g);
    let units_total = crate::util::ceil_div(totals.get(g), gran);
    let units_cur = crate::util::ceil_div(q.get(g), gran);
    let next_units = next_divisor(units_total, units_cur)?;
    let mut out = q;
    out.set(g, (next_units * gran).min(totals.get(g)));
    if out == q {
        None
    } else {
        Some(out)
    }
}

/// The stacking pass: greedy hill-climbing over node-partition moves from
/// several seeds (pure batch / output / fmap splits and the unit
/// partition), scored by a one-shot descend + evaluate probe. Returns the
/// distinct partitions encountered on the best paths.
fn stacking_candidates(
    arch: &ArchConfig,
    layer: &Layer,
    ctx: &IntraCtx,
    model: &dyn CostModel,
) -> Vec<PartitionScheme> {
    let region = ctx.region;
    let area = region.0 * region.1;
    let mut seen: Vec<PartitionScheme> = Vec::new();
    let mut keep: Vec<PartitionScheme> = Vec::new();

    let seeds = seed_partitions(layer, ctx.rb, region);
    for seed in seeds {
        let mut cur = seed;
        let mut cur_cost = probe_cost(arch, layer, ctx, &cur, model);
        if !seen.contains(&cur) {
            seen.push(cur);
        }
        loop {
            let mut improved = false;
            for next in partition_moves(&cur, layer, ctx.rb, area) {
                let c = probe_cost(arch, layer, ctx, &next, model);
                if c < cur_cost {
                    cur = next;
                    cur_cost = c;
                    improved = true;
                }
            }
            if !seen.contains(&cur) {
                seen.push(cur);
            }
            if !improved {
                break;
            }
        }
        if !keep.contains(&cur) {
            keep.push(cur);
        }
    }
    // Also keep the plain unit partition as a safety net.
    let unitp = PartitionScheme { region, ..PartitionScheme::single() };
    if !keep.contains(&unitp) {
        keep.push(unitp);
    }
    keep
}

/// Starting points for the hill climb: split fully along each single dim
/// that can absorb the region, plus the trivial partition.
fn seed_partitions(layer: &Layer, rb: u64, region: (u64, u64)) -> Vec<PartitionScheme> {
    let area = region.0 * region.1;
    let base = PartitionScheme { region, ..PartitionScheme::single() };
    let mut seeds = vec![base];
    for (setter, cap) in [
        ((|p: &mut PartitionScheme, v: u64| p.pn = v) as fn(&mut PartitionScheme, u64), rb),
        (|p, v| p.pk = v, layer.k),
        (|p, v| p.pc = v, layer.c),
        (|p, v| p.py = v, layer.yo),
    ] {
        let mut p = base;
        let f = largest_pow2_divisor(area).min(cap.next_power_of_two() / 2).max(1);
        setter(&mut p, f);
        if p.is_valid(layer, rb) && !seeds.contains(&p) {
            seeds.push(p);
        }
    }
    seeds
}

fn largest_pow2_divisor(n: u64) -> u64 {
    n & n.wrapping_neg()
}

/// Neighbour moves: double one partition dim (if it still fits the region
/// and the layer), halve one (to escape over-splits), toggle sharing.
fn partition_moves(
    cur: &PartitionScheme,
    layer: &Layer,
    rb: u64,
    area: u64,
) -> Vec<PartitionScheme> {
    let mut out = Vec::new();
    type Fld = (fn(&PartitionScheme) -> u64, fn(&mut PartitionScheme, u64));
    let fields: [Fld; 5] = [
        (|p| p.pn, |p, v| p.pn = v),
        (|p| p.pk, |p, v| p.pk = v),
        (|p| p.pc, |p, v| p.pc = v),
        (|p| p.px, |p, v| p.px = v),
        (|p| p.py, |p, v| p.py = v),
    ];
    for (get, set) in fields {
        let v = get(cur);
        if cur.used_nodes() / v * (v * 2) <= area {
            let mut p = *cur;
            set(&mut p, v * 2);
            if p.is_valid(layer, rb) {
                out.push(p);
            }
        }
        if v > 1 && v % 2 == 0 {
            let mut p = *cur;
            set(&mut p, v / 2);
            if p.is_valid(layer, rb) {
                out.push(p);
            }
        }
    }
    for (flag, cond) in [(0, cur.pk > 1), (1, cur.wgt_replication() > 1 && layer.has_weights())] {
        if cond {
            let mut p = *cur;
            if flag == 0 {
                p.share_ifm = !p.share_ifm;
            } else {
                p.share_wgt = !p.share_wgt;
            }
            if p.is_valid(layer, rb) {
                out.push(p);
            }
        }
    }
    out
}

/// One-shot probe: default orders, full descend, detailed-tier eval
/// (memoized — the hill climb re-probes partitions along its paths and the
/// final sweep re-scores the same schemes). Infinity when no valid scheme
/// exists under this partition.
fn probe_cost(
    arch: &ArchConfig,
    layer: &Layer,
    ctx: &IntraCtx,
    part: &PartitionScheme,
    model: &dyn CostModel,
) -> f64 {
    let unit = UnitMap::build(arch, part.node_shape(layer, ctx.rb));
    let ro = LoopOrder([Grp::B, Grp::K, Grp::C]);
    let go = LoopOrder([Grp::B, Grp::C, Grp::K]);
    let rq = descend(&unit, unit.granule, unit.totals, ro, |q| {
        unit.regf_pe_words(q) <= arch.regf_words() && gbuf_fits(arch, &unit, part, q)
    });
    if unit.regf_pe_words(rq) > arch.regf_words() || !gbuf_fits(arch, &unit, part, rq) {
        return f64::INFINITY;
    }
    let gq = descend(&unit, rq, unit.totals, go, |q| gbuf_fits(arch, &unit, part, q));
    let s = LayerScheme {
        part: *part,
        unit,
        regf: LevelBlock { qty: rq, order: ro },
        gbuf: LevelBlock { qty: gq, order: go },
    };
    if s.validate(arch).is_err() {
        return f64::INFINITY;
    }
    let est = model.evaluate(arch, &s, ctx.ifm_on_chip);
    ctx.objective.of(&est)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::cost::CostCache;
    use crate::interlayer::dp::DpConfig;
    use crate::sim::evaluate_layer;
    use crate::solvers::{Objective, SolveCtx, SolverKind};
    use crate::workloads::nets;

    fn ctx(region: (u64, u64), rb: u64) -> IntraCtx {
        IntraCtx { region, rb, ifm_on_chip: false, objective: Objective::Energy }
    }

    #[test]
    fn intra_solves_every_alexnet_layer() {
        let arch = presets::multi_node_eyeriss();
        let net = nets::alexnet();
        for l in &net.layers {
            let s =
                solve_intra(&arch, l, &ctx((16, 16), 64)).unwrap_or_else(|| panic!("{}", l.name));
            s.validate(&arch).unwrap();
        }
    }

    #[test]
    fn intra_beats_minimal_scheme() {
        let arch = presets::multi_node_eyeriss();
        let net = nets::alexnet();
        let l = &net.layers[2]; // conv2, heavy
        let c = ctx((8, 8), 16);
        let kapla = solve_intra(&arch, l, &c).unwrap();
        let min = super::super::space::minimal_scheme(&arch, l, c.region, c.rb).unwrap();
        let ek = evaluate_layer(&arch, &kapla, false).energy.total();
        let em = evaluate_layer(&arch, &min, false).energy.total();
        assert!(ek < em, "kapla {ek} !< minimal {em}");
    }

    #[test]
    fn descend_respects_capacity_by_construction() {
        let arch = presets::multi_node_eyeriss();
        let net = nets::vggnet();
        for l in net.layers.iter().take(6) {
            if let Some(s) = solve_intra(&arch, l, &ctx((4, 4), 8)) {
                assert!(s.regf_words_per_pe() <= arch.regf_words());
                assert!(s.gbuf_words_per_node() <= arch.gbuf_words());
            }
        }
    }

    #[test]
    fn grow_walks_divisor_chain() {
        let tot = Qty::new(12, 1, 1);
        let mut q = Qty::UNIT;
        let mut sizes = vec![1u64];
        while let Some(n) = grow(q, Grp::B, tot, Qty::UNIT) {
            q = n;
            sizes.push(q.b);
        }
        assert_eq!(sizes, vec![1, 2, 3, 4, 6, 12]);
    }

    #[test]
    fn edge_systolic_solvable() {
        let arch = presets::edge_tpu();
        let net = nets::mobilenet();
        for l in &net.layers {
            let s = solve_intra(&arch, l, &ctx((1, 1), 1)).unwrap_or_else(|| panic!("{}", l.name));
            s.validate(&arch).unwrap();
        }
    }

    #[test]
    fn solve_intra_reuses_cached_evaluations() {
        let arch = presets::multi_node_eyeriss();
        let net = nets::alexnet();
        let cache = CostCache::new();
        let model = TieredCost::over(&cache);
        let c = ctx((8, 8), 16);
        let a = solve_intra_cached(&arch, &net.layers[2], &c, &model).unwrap();
        assert!(cache.hits() > 0, "probe/final sweep must share evaluations");
        let (h1, l1) = (cache.hits(), cache.lookups());
        let b = solve_intra_cached(&arch, &net.layers[2], &c, &model).unwrap();
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
        // A repeated identical solve answers every evaluation from the memo.
        assert_eq!(cache.hits() - h1, cache.lookups() - l1);
    }

    #[test]
    fn parallel_kapla_schedule_matches_sequential() {
        let arch = presets::bench_multi_node();
        let net = nets::mlp();
        let seq = SolveCtx::new(&arch)
            .dp(DpConfig { solve_threads: 1, ..DpConfig::default() })
            .run(&net, 16, SolverKind::Kapla)
            .unwrap();
        let par = SolveCtx::new(&arch)
            .dp(DpConfig { solve_threads: 4, ..DpConfig::default() })
            .run(&net, 16, SolverKind::Kapla)
            .unwrap();
        assert_eq!(seq.eval.energy.total(), par.eval.energy.total());
        assert_eq!(format!("{:?}", seq.schedule), format!("{:?}", par.schedule));
    }

    #[test]
    fn full_schedule_mlp() {
        let arch = presets::bench_multi_node();
        let net = nets::mlp();
        let r = SolveCtx::new(&arch).run(&net, 16, SolverKind::Kapla).unwrap();
        assert_eq!(r.schedule.num_layers(), net.len());
        assert!(r.eval.energy.total() > 0.0);
        assert!(r.prune.expect("kapla reports prune stats").total > 0);
    }

    #[test]
    fn latency_objective_not_slower() {
        let arch = presets::bench_multi_node();
        let net = nets::mlp();
        let re = SolveCtx::new(&arch).run(&net, 16, SolverKind::Kapla).unwrap();
        let rl = SolveCtx::new(&arch)
            .objective(Objective::Latency)
            .run(&net, 16, SolverKind::Kapla)
            .unwrap();
        assert!(rl.eval.latency_cycles <= re.eval.latency_cycles * 1.25);
    }
}
