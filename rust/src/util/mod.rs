//! Small self-contained utilities: deterministic PRNG, integer factorization
//! helpers used by the blocking-factor enumerators, lightweight statistics,
//! and a JSON writer for report emission.
//!
//! The vendored crate set does not include `rand`, `serde` or `proptest`, so
//! the pieces we need are implemented here (deterministic and tested).

pub mod json;
pub mod prng;
pub mod stats;

pub use prng::SplitMix64;

/// All divisors of `n`, ascending. `n >= 1`.
pub fn divisors(n: u64) -> Vec<u64> {
    assert!(n >= 1, "divisors of zero requested");
    let mut lo = Vec::new();
    let mut hi = Vec::new();
    let mut d = 1u64;
    while d * d <= n {
        if n % d == 0 {
            lo.push(d);
            if d != n / d {
                hi.push(n / d);
            }
        }
        d += 1;
    }
    hi.reverse();
    lo.extend(hi);
    lo
}

/// All ordered pairs `(a, b)` with `a * b == n`.
pub fn factor_pairs(n: u64) -> Vec<(u64, u64)> {
    divisors(n).into_iter().map(|a| (a, n / a)).collect()
}

/// All ordered triples `(a, b, c)` with `a * b * c == n`.
pub fn factor_triples(n: u64) -> Vec<(u64, u64, u64)> {
    let mut out = Vec::new();
    for a in divisors(n) {
        let m = n / a;
        for b in divisors(m) {
            out.push((a, b, m / b));
        }
    }
    out
}

/// Ceiling division for u64.
#[inline]
pub fn ceil_div(a: u64, b: u64) -> u64 {
    assert!(b > 0, "ceil_div by zero");
    a.div_ceil(b)
}

/// The smallest divisor of `total` that is strictly greater than `cur`,
/// or `None` if `cur >= total`. Used by the caching pass to enlarge a
/// dimension to its "next smallest blocked size" (paper §IV-C).
pub fn next_divisor(total: u64, cur: u64) -> Option<u64> {
    if cur >= total {
        return None;
    }
    divisors(total).into_iter().find(|&d| d > cur)
}

/// Round `x` up to a multiple of `m`.
#[inline]
pub fn round_up(x: u64, m: u64) -> u64 {
    ceil_div(x, m) * m
}

/// Wall-clock timer with millisecond reporting, used by the scheduling-time
/// benches (Table IV).
pub struct Timer {
    start: std::time::Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: std::time::Instant::now() }
    }
    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn divisors_basic() {
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(divisors(13), vec![1, 13]);
        assert_eq!(divisors(36), vec![1, 2, 3, 4, 6, 9, 12, 18, 36]);
    }

    #[test]
    fn divisors_are_sorted_and_divide() {
        for n in 1..500u64 {
            let ds = divisors(n);
            assert!(ds.windows(2).all(|w| w[0] < w[1]), "sorted for {n}");
            assert!(ds.iter().all(|d| n % d == 0), "divide for {n}");
            assert_eq!(*ds.first().unwrap(), 1);
            assert_eq!(*ds.last().unwrap(), n);
        }
    }

    #[test]
    fn factor_pairs_product() {
        for n in 1..200u64 {
            for (a, b) in factor_pairs(n) {
                assert_eq!(a * b, n);
            }
        }
    }

    #[test]
    fn factor_triples_product_and_count() {
        for n in [1u64, 2, 6, 12, 64, 96] {
            let ts = factor_triples(n);
            assert!(ts.iter().all(|&(a, b, c)| a * b * c == n));
            if n == 12 {
                // d_3(12) = 18
                assert_eq!(ts.len(), 18);
            }
        }
    }

    #[test]
    fn next_divisor_walks_the_chain() {
        // chain over 12: 1 -> 2 -> 3 -> 4 -> 6 -> 12 -> None
        let mut cur = 1;
        let mut chain = vec![1u64];
        while let Some(nxt) = next_divisor(12, cur) {
            chain.push(nxt);
            cur = nxt;
        }
        assert_eq!(chain, vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(next_divisor(12, 12), None);
    }

    #[test]
    fn ceil_div_and_round_up() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(round_up(10, 4), 12);
        assert_eq!(round_up(8, 4), 8);
    }
}
