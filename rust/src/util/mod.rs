//! Small self-contained utilities: deterministic PRNG, integer factorization
//! helpers used by the blocking-factor enumerators, lightweight statistics,
//! and a JSON writer for report emission.
//!
//! The vendored crate set does not include `rand`, `serde` or `proptest`, so
//! the pieces we need are implemented here (deterministic and tested).

pub mod cancel;
pub mod json;
pub mod prng;
pub mod queue;
pub mod stats;

pub use prng::SplitMix64;

/// Bound of the small-`n` divisor memo: the enumeration inner loops call
/// `divisors` per level per partition with loop-group extents (channel
/// counts, batches — rarely beyond a few thousand); larger arguments fall
/// back to trial division.
const DIVISOR_MEMO_LIMIT: usize = 4096;

/// Lock-free once-per-argument memo for [`divisors`]. `OnceLock` keeps it
/// thread-safe for the scoped worker pools with no lock on the hot (hit)
/// path, and the fixed bound keeps the resident footprint small.
static DIVISOR_MEMO: [std::sync::OnceLock<Vec<u64>>; DIVISOR_MEMO_LIMIT] =
    [const { std::sync::OnceLock::new() }; DIVISOR_MEMO_LIMIT];

/// All divisors of `n`, ascending. `n >= 1`. Memoized for small `n` (the
/// blocking-factor enumerators re-request the same totals constantly);
/// results are identical to [`divisors_uncached`] by construction, which
/// `perf_hotpath` micro-asserts.
pub fn divisors(n: u64) -> Vec<u64> {
    assert!(n >= 1, "divisors of zero requested");
    if (n as usize) < DIVISOR_MEMO_LIMIT {
        return DIVISOR_MEMO[n as usize].get_or_init(|| divisors_uncached(n)).clone();
    }
    divisors_uncached(n)
}

/// Trial-division reference behind [`divisors`].
pub fn divisors_uncached(n: u64) -> Vec<u64> {
    assert!(n >= 1, "divisors of zero requested");
    let mut lo = Vec::new();
    let mut hi = Vec::new();
    let mut d = 1u64;
    while d * d <= n {
        if n % d == 0 {
            lo.push(d);
            if d != n / d {
                hi.push(n / d);
            }
        }
        d += 1;
    }
    hi.reverse();
    lo.extend(hi);
    lo
}

/// All ordered pairs `(a, b)` with `a * b == n`.
pub fn factor_pairs(n: u64) -> Vec<(u64, u64)> {
    divisors(n).into_iter().map(|a| (a, n / a)).collect()
}

/// All ordered triples `(a, b, c)` with `a * b * c == n`.
pub fn factor_triples(n: u64) -> Vec<(u64, u64, u64)> {
    let mut out = Vec::new();
    for a in divisors(n) {
        let m = n / a;
        for b in divisors(m) {
            out.push((a, b, m / b));
        }
    }
    out
}

/// Ceiling division for u64.
#[inline]
pub fn ceil_div(a: u64, b: u64) -> u64 {
    assert!(b > 0, "ceil_div by zero");
    a.div_ceil(b)
}

/// The smallest divisor of `total` that is strictly greater than `cur`,
/// or `None` if `cur >= total`. Used by the caching pass to enlarge a
/// dimension to its "next smallest blocked size" (paper §IV-C).
pub fn next_divisor(total: u64, cur: u64) -> Option<u64> {
    if cur >= total {
        return None;
    }
    divisors(total).into_iter().find(|&d| d > cur)
}

/// Round `x` up to a multiple of `m`.
#[inline]
pub fn round_up(x: u64, m: u64) -> u64 {
    ceil_div(x, m) * m
}

/// FNV-1a over a stream of u64 words — the crate's one tiny hash for
/// deterministic fingerprints (per-context RNG seeds, arch identity in the
/// evaluation cache). Not collision-hardened; callers feed short,
/// structured field lists, not attacker-controlled data.
pub fn fnv1a(values: impl IntoIterator<Item = u64>) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for v in values {
        h ^= v;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Worker-thread count available on this host, capped at 8 (the paper's
/// Table IV measured 8 parallel processes).
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8).min(8)
}

/// Order-preserving parallel map over a slice on a scoped `std::thread`
/// worker pool (the crate is dependency-free — no rayon). Work is stolen
/// through a shared atomic index; results come back in item order, so for
/// a *pure* `f` the output is byte-identical to the sequential map
/// regardless of `threads` — the determinism invariant the solver stack
/// relies on (tests/parallel_determinism.rs). `threads <= 1` runs inline
/// with no pool at all.
///
/// A panic in `f` is caught on the worker, the remaining workers drain,
/// and the panic is re-raised on the caller with the failing item's index
/// folded into the message (the bare scoped-thread join would otherwise
/// abort with no hint of *which* of thousands of solver contexts died).
/// When several items panic concurrently, the first one recorded wins.
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 {
        return items.iter().map(&f).collect();
    }
    type Failure = Option<(usize, Box<dyn std::any::Any + Send>)>;
    let next = std::sync::atomic::AtomicUsize::new(0);
    let failed = std::sync::atomic::AtomicBool::new(false);
    let failure: std::sync::Mutex<Failure> = std::sync::Mutex::new(None);
    let slots: Vec<std::sync::Mutex<Option<R>>> =
        items.iter().map(|_| std::sync::Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                if failed.load(std::sync::atomic::Ordering::Relaxed) {
                    break; // a sibling already panicked: stop early
                }
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&items[i]))) {
                    Ok(r) => *slots[i].lock().unwrap() = Some(r),
                    Err(payload) => {
                        let mut slot = failure.lock().unwrap();
                        if slot.is_none() {
                            *slot = Some((i, payload));
                        }
                        failed.store(true, std::sync::atomic::Ordering::Relaxed);
                        break;
                    }
                }
            });
        }
    });
    if let Some((i, payload)) = failure.into_inner().unwrap() {
        // String payloads (the `panic!("...")` norm) get the item index
        // folded into the message; typed `panic_any` payloads are resumed
        // untouched so upstream downcasts keep working, with the index on
        // stderr.
        let msg = payload
            .downcast_ref::<&'static str>()
            .map(|s| s.to_string())
            .or_else(|| payload.downcast_ref::<String>().cloned());
        match msg {
            Some(m) => panic!("par_map: worker panicked on item {i}: {m}"),
            None => {
                eprintln!("par_map: worker panicked on item {i} (non-string payload)");
                std::panic::resume_unwind(payload);
            }
        }
    }
    slots.into_iter().map(|m| m.into_inner().unwrap().expect("worker missed item")).collect()
}

/// Wall-clock timer with millisecond reporting, used by the scheduling-time
/// benches (Table IV).
pub struct Timer {
    start: std::time::Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: std::time::Instant::now() }
    }
    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn divisors_basic() {
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(divisors(13), vec![1, 13]);
        assert_eq!(divisors(36), vec![1, 2, 3, 4, 6, 9, 12, 18, 36]);
    }

    #[test]
    fn divisors_are_sorted_and_divide() {
        for n in 1..500u64 {
            let ds = divisors(n);
            assert!(ds.windows(2).all(|w| w[0] < w[1]), "sorted for {n}");
            assert!(ds.iter().all(|d| n % d == 0), "divide for {n}");
            assert_eq!(*ds.first().unwrap(), 1);
            assert_eq!(*ds.last().unwrap(), n);
        }
    }

    #[test]
    fn divisors_memo_matches_uncached() {
        // Inside and beyond the memo bound, including repeated queries and
        // the boundary values themselves.
        for n in (1..600u64).chain([4094, 4095, 4096, 4097, 14336, 123456]) {
            assert_eq!(divisors(n), divisors_uncached(n), "n={n}");
            assert_eq!(divisors(n), divisors_uncached(n), "repeat n={n}");
        }
    }

    #[test]
    fn divisors_memo_is_thread_safe() {
        let items: Vec<u64> = (1..256u64).cycle().take(2048).collect();
        let par = par_map(&items, 8, |&n| divisors(n));
        for (n, ds) in items.iter().zip(&par) {
            assert_eq!(*ds, divisors_uncached(*n));
        }
    }

    #[test]
    fn factor_pairs_product() {
        for n in 1..200u64 {
            for (a, b) in factor_pairs(n) {
                assert_eq!(a * b, n);
            }
        }
    }

    #[test]
    fn factor_triples_product_and_count() {
        for n in [1u64, 2, 6, 12, 64, 96] {
            let ts = factor_triples(n);
            assert!(ts.iter().all(|&(a, b, c)| a * b * c == n));
            if n == 12 {
                // d_3(12) = 18
                assert_eq!(ts.len(), 18);
            }
        }
    }

    #[test]
    fn next_divisor_walks_the_chain() {
        // chain over 12: 1 -> 2 -> 3 -> 4 -> 6 -> 12 -> None
        let mut cur = 1;
        let mut chain = vec![1u64];
        while let Some(nxt) = next_divisor(12, cur) {
            chain.push(nxt);
            cur = nxt;
        }
        assert_eq!(chain, vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(next_divisor(12, 12), None);
    }

    #[test]
    fn ceil_div_and_round_up() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(round_up(10, 4), 12);
        assert_eq!(round_up(8, 4), 8);
    }

    #[test]
    fn fnv1a_is_deterministic_and_order_sensitive() {
        assert_eq!(fnv1a([1, 2, 3]), fnv1a([1, 2, 3]));
        assert_ne!(fnv1a([1, 2, 3]), fnv1a([3, 2, 1]));
        assert_ne!(fnv1a([0]), fnv1a([0, 0]));
        // Empty stream yields the offset basis.
        assert_eq!(fnv1a([]), 0xcbf29ce484222325);
    }

    #[test]
    fn par_map_preserves_order_and_values() {
        let items: Vec<u64> = (0..1000).collect();
        let seq = par_map(&items, 1, |&x| x * x + 1);
        for threads in [2usize, 3, 8] {
            let par = par_map(&items, threads, |&x| x * x + 1);
            assert_eq!(par, seq, "threads={threads}");
        }
        assert_eq!(seq[7], 50);
    }

    #[test]
    fn par_map_handles_empty_and_single() {
        let empty: Vec<u64> = Vec::new();
        assert!(par_map(&empty, 4, |&x| x).is_empty());
        assert_eq!(par_map(&[5u64], 4, |&x| x + 1), vec![6]);
    }

    #[test]
    #[should_panic(expected = "worker panicked on item 5")]
    fn par_map_propagates_worker_panic_with_item_index() {
        let items: Vec<u64> = (0..8).collect();
        par_map(&items, 4, |&x| {
            if x == 5 {
                panic!("boom at {x}");
            }
            x
        });
    }

    #[test]
    #[should_panic(expected = "boom at 5")]
    fn par_map_preserves_the_original_panic_message() {
        let items: Vec<u64> = (0..8).collect();
        par_map(&items, 2, |&x| {
            if x == 5 {
                panic!("boom at {x}");
            }
            x
        });
    }

    #[test]
    fn par_map_resumes_typed_panic_payloads_intact() {
        #[derive(Debug, PartialEq)]
        struct Typed(u32);
        let items: Vec<u64> = (0..8).collect();
        let r = std::panic::catch_unwind(|| {
            par_map(&items, 2, |&x| {
                if x == 3 {
                    std::panic::panic_any(Typed(42));
                }
                x
            })
        });
        // The original payload survives the re-raise for upstream
        // downcasts; only string panics get the index folded in.
        let payload = r.unwrap_err();
        assert_eq!(payload.downcast_ref::<Typed>(), Some(&Typed(42)));
    }

    #[test]
    fn available_threads_is_positive_and_capped() {
        let t = available_threads();
        assert!(t >= 1 && t <= 8);
    }
}
