//! Minimal JSON value + writer (no serde in the vendored registry).
//!
//! Used to emit machine-readable reports under `reports/` alongside the
//! aligned-text tables, and by the coordinator service mode to answer
//! scheduling requests.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Numbers are stored as f64; integers round-trip exactly up
/// to 2^53 which comfortably covers every count we emit.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    x.write(out, indent + 1, pretty);
                }
                if !xs.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_shapes() {
        let mut o = Json::obj();
        o.set("name", "alexnet".into())
            .set("energy_pj", 1.25e9.into())
            .set("count", 42u64.into())
            .set("ok", true.into())
            .set("tags", vec!["a", "b"].into());
        let s = o.to_string_compact();
        assert_eq!(
            s,
            "{\"count\":42,\"energy_pj\":1250000000,\"name\":\"alexnet\",\"ok\":true,\"tags\":[\"a\",\"b\"]}"
        );
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.to_string_compact(), "\"a\\\"b\\\\c\\nd\"");
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string_compact(), "3");
        assert_eq!(Json::Num(3.5).to_string_compact(), "3.5");
    }

    #[test]
    fn pretty_is_parsable_shape() {
        let mut o = Json::obj();
        o.set("x", 1u64.into());
        let p = o.to_string_pretty();
        assert!(p.contains("\"x\": 1"));
    }
}
