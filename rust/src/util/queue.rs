//! A bounded multi-producer multi-consumer queue for the service's solve
//! pool (Mutex + Condvar, mirroring `par_map`'s zero-dependency idiom).
//!
//! The shape is dictated by admission control: producers never block —
//! `try_push` fails immediately when the queue is full so the transport
//! can answer `{"ok":false,"error":"overloaded"}` instead of hanging a
//! connection thread — while consumers block in `pop` until work arrives
//! or the queue is closed. `close` is the shutdown edge: queued items are
//! still drained (every admitted request gets a real response), then every
//! blocked consumer wakes up with `None`.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    cap: usize,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> BoundedQueue<T> {
    pub fn new(cap: usize) -> BoundedQueue<T> {
        assert!(cap >= 1, "queue capacity must be >= 1");
        BoundedQueue {
            inner: Mutex::new(Inner { items: VecDeque::with_capacity(cap.min(1024)), closed: false }),
            not_empty: Condvar::new(),
            cap,
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Current depth (racy by nature; used for metrics and retry hints).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    /// Non-blocking admission: `Err` hands the item back when the queue is
    /// full or closed, so the caller can shed load with a structured error.
    pub fn try_push(&self, item: T) -> Result<(), T> {
        let mut g = self.inner.lock().unwrap();
        if g.closed || g.items.len() >= self.cap {
            return Err(item);
        }
        g.items.push_back(item);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Block until an item is available; `None` once the queue is closed
    /// *and* fully drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Stop admitting; wake every blocked consumer once the backlog drains.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn fifo_and_full_rejection() {
        let q = BoundedQueue::new(2);
        assert_eq!(q.capacity(), 2);
        assert!(q.try_push(1).is_ok());
        assert!(q.try_push(2).is_ok());
        assert_eq!(q.try_push(3), Err(3), "full queue hands the item back");
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        assert!(q.try_push(3).is_ok());
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        assert!(q.is_empty());
    }

    #[test]
    fn close_drains_backlog_then_wakes_consumers() {
        let q = BoundedQueue::new(4);
        q.try_push(10).unwrap();
        q.try_push(11).unwrap();
        q.close();
        assert_eq!(q.try_push(12), Err(12), "closed queue admits nothing");
        // Admitted items still come out, then the terminal None.
        assert_eq!(q.pop(), Some(10));
        assert_eq!(q.pop(), Some(11));
        assert_eq!(q.pop(), None);
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn concurrent_producers_and_consumers_conserve_items() {
        const PER_PRODUCER: usize = 200;
        let q = BoundedQueue::new(8);
        let consumed = AtomicUsize::new(0);
        let sum = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for p in 0..3 {
                let q = &q;
                scope.spawn(move || {
                    for i in 0..PER_PRODUCER {
                        let mut item = p * PER_PRODUCER + i;
                        // Producers in this test *want* delivery: spin on
                        // the non-blocking push until admitted.
                        while let Err(back) = q.try_push(item) {
                            item = back;
                            std::thread::yield_now();
                        }
                    }
                });
            }
            for _ in 0..2 {
                let (q, consumed, sum) = (&q, &consumed, &sum);
                scope.spawn(move || {
                    while let Some(item) = q.pop() {
                        consumed.fetch_add(1, Ordering::Relaxed);
                        sum.fetch_add(item, Ordering::Relaxed);
                    }
                });
            }
            // Producers finish first (consumers outpace a depth-8 queue
            // only after close); close once all items are in flight.
            scope.spawn(|| {
                while consumed.load(Ordering::Relaxed) < 3 * PER_PRODUCER {
                    std::thread::yield_now();
                }
                q.close();
            });
        });
        let n = 3 * PER_PRODUCER;
        assert_eq!(consumed.load(Ordering::Relaxed), n);
        assert_eq!(sum.load(Ordering::Relaxed), n * (n - 1) / 2);
    }

    #[test]
    fn close_while_consumers_block_wakes_every_one() {
        // All consumers parked in pop() on an empty queue must wake with
        // None after close(); a missed notify_all would hang this test
        // (caught by the harness timeout rather than a silent pass).
        let q = BoundedQueue::<usize>::new(4);
        let woke = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..6 {
                let (q, woke) = (&q, &woke);
                scope.spawn(move || {
                    assert_eq!(q.pop(), None);
                    woke.fetch_add(1, Ordering::Relaxed);
                });
            }
            // Give the consumers a moment to actually park on the Condvar
            // so close() exercises the wake path, not the fast path.
            std::thread::sleep(std::time::Duration::from_millis(20));
            q.close();
        });
        assert_eq!(woke.load(Ordering::Relaxed), 6);
    }

    /// Property: across seeded random interleavings of push / pop / close,
    /// exactly the admitted items come out — nothing lost between a
    /// successful `try_push` and the post-close drain, nothing duplicated,
    /// and nothing admitted after close. (FIFO order is covered by the
    /// single-threaded test above; with two consumers the shared pop log
    /// can't witness pop order.)
    #[test]
    fn prop_random_interleavings_conserve_admitted_items() {
        use crate::util::SplitMix64;
        use std::sync::Mutex as StdMutex;

        for seed in 0..12u64 {
            let mut rng = SplitMix64::new(0xC0FFEE ^ seed);
            let cap = 1 + rng.below(7) as usize;
            let producers = 1 + rng.below(4) as usize;
            let per_producer = 20 + rng.below(60) as usize;
            // Close somewhere mid-stream so some pushes race the close
            // edge; items are (producer, seq) so order is checkable.
            let close_after = rng.below((producers * per_producer) as u64) as usize;

            let q = BoundedQueue::<(usize, usize)>::new(cap);
            let admitted: Vec<StdMutex<Vec<usize>>> =
                (0..producers).map(|_| StdMutex::new(Vec::new())).collect();
            let popped = StdMutex::new(Vec::new());
            let pushes_done = AtomicUsize::new(0);

            std::thread::scope(|scope| {
                for p in 0..producers {
                    let (q, admitted, pushes_done) = (&q, &admitted, &pushes_done);
                    scope.spawn(move || {
                        for i in 0..per_producer {
                            let mut rejected_after_close = false;
                            loop {
                                match q.try_push((p, i)) {
                                    Ok(()) => {
                                        admitted[p].lock().unwrap().push(i);
                                        break;
                                    }
                                    Err(_) if q.is_closed() => {
                                        rejected_after_close = true;
                                        break;
                                    }
                                    Err(_) => std::thread::yield_now(), // full: retry
                                }
                            }
                            pushes_done.fetch_add(1, Ordering::Relaxed);
                            if rejected_after_close {
                                // Push the counter past close_after for the
                                // rest of this producer's items too.
                                pushes_done
                                    .fetch_add(per_producer - 1 - i, Ordering::Relaxed);
                                break;
                            }
                        }
                    });
                }
                for _ in 0..2 {
                    let (q, popped) = (&q, &popped);
                    scope.spawn(move || {
                        while let Some(item) = q.pop() {
                            popped.lock().unwrap().push(item);
                        }
                    });
                }
                scope.spawn(|| {
                    while pushes_done.load(Ordering::Relaxed) < close_after {
                        std::thread::yield_now();
                    }
                    q.close();
                    // Closed queues admit nothing, ever.
                    assert_eq!(q.try_push((usize::MAX, 0)), Err((usize::MAX, 0)));
                });
            });

            let popped = popped.into_inner().unwrap();
            // Conservation: multiset of popped == multiset of admitted.
            let total_admitted: usize = admitted.iter().map(|a| a.lock().unwrap().len()).sum();
            assert_eq!(popped.len(), total_admitted, "seed {seed}: lost or duplicated items");
            for p in 0..producers {
                let mine: Vec<usize> =
                    popped.iter().filter(|&&(pp, _)| pp == p).map(|&(_, i)| i).collect();
                let mut sorted = mine.clone();
                sorted.sort_unstable();
                assert_eq!(
                    sorted,
                    *admitted[p].lock().unwrap(),
                    "seed {seed}: producer {p} item set mismatch"
                );
            }
        }
    }
}
