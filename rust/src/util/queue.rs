//! A bounded multi-producer multi-consumer queue for the service's solve
//! pool (Mutex + Condvar, mirroring `par_map`'s zero-dependency idiom).
//!
//! The shape is dictated by admission control: producers never block —
//! `try_push` fails immediately when the queue is full so the transport
//! can answer `{"ok":false,"error":"overloaded"}` instead of hanging a
//! connection thread — while consumers block in `pop` until work arrives
//! or the queue is closed. `close` is the shutdown edge: queued items are
//! still drained (every admitted request gets a real response), then every
//! blocked consumer wakes up with `None`.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    cap: usize,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> BoundedQueue<T> {
    pub fn new(cap: usize) -> BoundedQueue<T> {
        assert!(cap >= 1, "queue capacity must be >= 1");
        BoundedQueue {
            inner: Mutex::new(Inner { items: VecDeque::with_capacity(cap.min(1024)), closed: false }),
            not_empty: Condvar::new(),
            cap,
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Current depth (racy by nature; used for metrics and retry hints).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    /// Non-blocking admission: `Err` hands the item back when the queue is
    /// full or closed, so the caller can shed load with a structured error.
    pub fn try_push(&self, item: T) -> Result<(), T> {
        let mut g = self.inner.lock().unwrap();
        if g.closed || g.items.len() >= self.cap {
            return Err(item);
        }
        g.items.push_back(item);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Block until an item is available; `None` once the queue is closed
    /// *and* fully drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Stop admitting; wake every blocked consumer once the backlog drains.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn fifo_and_full_rejection() {
        let q = BoundedQueue::new(2);
        assert_eq!(q.capacity(), 2);
        assert!(q.try_push(1).is_ok());
        assert!(q.try_push(2).is_ok());
        assert_eq!(q.try_push(3), Err(3), "full queue hands the item back");
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        assert!(q.try_push(3).is_ok());
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        assert!(q.is_empty());
    }

    #[test]
    fn close_drains_backlog_then_wakes_consumers() {
        let q = BoundedQueue::new(4);
        q.try_push(10).unwrap();
        q.try_push(11).unwrap();
        q.close();
        assert_eq!(q.try_push(12), Err(12), "closed queue admits nothing");
        // Admitted items still come out, then the terminal None.
        assert_eq!(q.pop(), Some(10));
        assert_eq!(q.pop(), Some(11));
        assert_eq!(q.pop(), None);
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn concurrent_producers_and_consumers_conserve_items() {
        const PER_PRODUCER: usize = 200;
        let q = BoundedQueue::new(8);
        let consumed = AtomicUsize::new(0);
        let sum = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for p in 0..3 {
                let q = &q;
                scope.spawn(move || {
                    for i in 0..PER_PRODUCER {
                        let mut item = p * PER_PRODUCER + i;
                        // Producers in this test *want* delivery: spin on
                        // the non-blocking push until admitted.
                        while let Err(back) = q.try_push(item) {
                            item = back;
                            std::thread::yield_now();
                        }
                    }
                });
            }
            for _ in 0..2 {
                let (q, consumed, sum) = (&q, &consumed, &sum);
                scope.spawn(move || {
                    while let Some(item) = q.pop() {
                        consumed.fetch_add(1, Ordering::Relaxed);
                        sum.fetch_add(item, Ordering::Relaxed);
                    }
                });
            }
            // Producers finish first (consumers outpace a depth-8 queue
            // only after close); close once all items are in flight.
            scope.spawn(|| {
                while consumed.load(Ordering::Relaxed) < 3 * PER_PRODUCER {
                    std::thread::yield_now();
                }
                q.close();
            });
        });
        let n = 3 * PER_PRODUCER;
        assert_eq!(consumed.load(Ordering::Relaxed), n);
        assert_eq!(sum.load(Ordering::Relaxed), n * (n - 1) / 2);
    }
}
