//! Deterministic SplitMix64 PRNG.
//!
//! Used by the random-search baseline (R), the simulated-annealing ML
//! baseline (M), and the property-test sweeps. All consumers take explicit
//! seeds so every table and test in the repository is reproducible.

/// SplitMix64 — tiny, fast, passes BigCrush when used as a stream.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)`. `bound > 0`.
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Lemire-style rejection-free multiply-shift; bias is negligible for
        // the bounds used here (all << 2^32) but we reject to be exact.
        loop {
            let x = self.next_u64();
            let hi = ((x as u128 * bound as u128) >> 64) as u64;
            let lo = (x as u128 * bound as u128) as u64;
            if lo >= bound || lo >= (u64::MAX - bound + 1) % bound {
                return hi;
            }
        }
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Pick a uniformly random element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty(), "choose from empty slice");
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (used to initialize surrogate weights
    /// identically to the python reference).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = SplitMix64::new(7);
        for bound in [1u64, 2, 3, 10, 1000] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn f64_unit_interval_and_roughly_uniform() {
        let mut r = SplitMix64::new(9);
        let n = 10_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_mean_and_var() {
        let mut r = SplitMix64::new(11);
        let n = 20_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
