//! Lightweight summary statistics used by the benchmark harnesses.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean; panics on non-positive values (ratios must be > 0).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty(), "geomean of empty slice");
    let s: f64 = xs
        .iter()
        .map(|&x| {
            assert!(x > 0.0, "geomean needs positive values, got {x}");
            x.ln()
        })
        .sum();
    (s / xs.len() as f64).exp()
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|&x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Median (by sorting a copy).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Format a duration in seconds the way the paper's Table IV does:
/// "32 s", "4.6 min", "8.7 h".
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1.0 {
        format!("{:.0} ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{secs:.1} s")
    } else if secs < 7200.0 {
        format!("{:.1} min", secs / 60.0)
    } else {
        format!("{:.1} h", secs / 3600.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_stddev() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 2.5);
        assert!((stddev(&xs) - 1.118033988).abs() < 1e-6);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
    }

    #[test]
    fn geomean_of_ratios() {
        let xs = [1.0, 4.0];
        assert!((geomean(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn duration_formats() {
        assert_eq!(fmt_duration(0.5), "500 ms");
        assert_eq!(fmt_duration(32.0), "32.0 s");
        assert_eq!(fmt_duration(276.0), "4.6 min");
        assert_eq!(fmt_duration(31320.0), "8.7 h");
    }
}
