//! Lightweight summary statistics used by the benchmark harnesses, plus
//! the fixed-bucket latency histogram backing the service's `metrics`
//! surface.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean; panics on non-positive values (ratios must be > 0).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty(), "geomean of empty slice");
    let s: f64 = xs
        .iter()
        .map(|&x| {
            assert!(x > 0.0, "geomean needs positive values, got {x}");
            x.ln()
        })
        .sum();
    (s / xs.len() as f64).exp()
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|&x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Median (by sorting a copy).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Format a duration in seconds the way the paper's Table IV does:
/// "32 s", "4.6 min", "8.7 h".
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1.0 {
        format!("{:.0} ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{secs:.1} s")
    } else if secs < 7200.0 {
        format!("{:.1} min", secs / 60.0)
    } else {
        format!("{:.1} h", secs / 3600.0)
    }
}

/// Bucket upper bounds (milliseconds) for [`LatencyHistogram`]. Chosen to
/// straddle the solve times the paper's Table IV spans: sub-ms warm-cache
/// replays up to multi-second cold exhaustive scans. One implicit overflow
/// bucket sits past the last bound.
pub const LATENCY_BUCKETS_MS: [f64; 12] =
    [1.0, 2.0, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0, 1000.0, 2500.0, 5000.0];

/// Lock-free fixed-bucket latency histogram: concurrent `record` from the
/// service worker pool, snapshot via `to_json` at any time. Counters only —
/// no allocation after construction, so a recording never contends with a
/// solve.
pub struct LatencyHistogram {
    /// One count per bucket in `LATENCY_BUCKETS_MS`, plus the overflow.
    counts: [AtomicU64; LATENCY_BUCKETS_MS.len() + 1],
    total_us: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> LatencyHistogram {
        LatencyHistogram::new()
    }
}

impl LatencyHistogram {
    pub const fn new() -> LatencyHistogram {
        const ZERO: AtomicU64 = AtomicU64::new(0);
        LatencyHistogram {
            counts: [ZERO; LATENCY_BUCKETS_MS.len() + 1],
            total_us: AtomicU64::new(0),
        }
    }

    /// Record one observation, in seconds (the unit `util::Timer` yields).
    pub fn record(&self, secs: f64) {
        let ms = secs * 1e3;
        let idx = LATENCY_BUCKETS_MS
            .iter()
            .position(|&ub| ms <= ub)
            .unwrap_or(LATENCY_BUCKETS_MS.len());
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.total_us.fetch_add((secs * 1e6).max(0.0) as u64, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    pub fn total_ms(&self) -> f64 {
        self.total_us.load(Ordering::Relaxed) as f64 / 1e3
    }

    pub fn mean_ms(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.total_ms() / n as f64
        }
    }

    /// `{"count":N,"counts":[...],"le_ms":[...],"mean_ms":x}` — `counts`
    /// has one extra trailing entry (observations past the last bound).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("count", self.count().into())
            .set("mean_ms", self.mean_ms().into())
            .set("le_ms", Json::Arr(LATENCY_BUCKETS_MS.iter().map(|&b| b.into()).collect()))
            .set(
                "counts",
                Json::Arr(self.counts.iter().map(|c| c.load(Ordering::Relaxed).into()).collect()),
            );
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_stddev() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 2.5);
        assert!((stddev(&xs) - 1.118033988).abs() < 1e-6);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
    }

    #[test]
    fn geomean_of_ratios() {
        let xs = [1.0, 4.0];
        assert!((geomean(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn duration_formats() {
        assert_eq!(fmt_duration(0.5), "500 ms");
        assert_eq!(fmt_duration(32.0), "32.0 s");
        assert_eq!(fmt_duration(276.0), "4.6 min");
        assert_eq!(fmt_duration(31320.0), "8.7 h");
    }

    #[test]
    fn histogram_buckets_and_overflow() {
        let h = LatencyHistogram::new();
        h.record(0.0005); // 0.5 ms -> bucket 0 (le 1 ms)
        h.record(0.003); // 3 ms -> le 5 ms
        h.record(0.003);
        h.record(9.0); // 9 s -> overflow
        assert_eq!(h.count(), 4);
        assert!((h.mean_ms() - (0.5 + 3.0 + 3.0 + 9000.0) / 4.0).abs() < 0.1);
        let j = h.to_json().to_string_compact();
        assert!(j.contains("\"count\":4"), "{j}");
        // counts carries one more entry than le_ms (the overflow bucket):
        // 0.5 ms in bucket 0, both 3 ms in the le-5 bucket, 9 s overflowed.
        assert!(j.contains("\"counts\":[1,0,2,0,0,0,0,0,0,0,0,0,1]"), "{j}");
        assert!(j.contains("\"le_ms\":[1,2,5,10,25,50,100,250,500,1000,2500,5000]"), "{j}");
    }

    #[test]
    fn histogram_is_concurrency_safe() {
        let h = LatencyHistogram::new();
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let h = &h;
                scope.spawn(move || {
                    for i in 0..250 {
                        h.record(i as f64 * 1e-4); // 0 .. 25 ms
                    }
                });
            }
        });
        assert_eq!(h.count(), 1000);
    }
}
