//! Cooperative cancellation for the solve path (deadline + manual trip).
//!
//! A [`CancelToken`] is carried by `solvers::SolveCtx` and threaded into
//! every long-running loop of the solver stack — the staged intra-layer
//! scans, the inter-layer planner's span stream and its speculative table
//! workers, and the R/M stochastic round loops. Each of those loops polls
//! [`CancelToken::is_cancelled`] at its natural yield points and, on a
//! trip, unwinds *cooperatively*: scans return their current incumbent,
//! the planner abandons the remaining spans, and the engine stamps the
//! result `degraded` instead of erroring (anytime semantics).
//!
//! The contract that keeps the solver determinism pin intact: a token
//! check may only cause an early exit. It never reorders iteration,
//! never changes scoring, and a token that never trips
//! ([`CancelToken::none`], the default) is a branch on an always-`false`
//! bool — so untripped runs stay byte-identical to a build without the
//! checks (pinned by `tests/deadline_anytime.rs` and the golden battery).
//!
//! The hot check is a single relaxed atomic load; the deadline clock is
//! only consulted while the token is still live, and the first trip
//! latches the reason (`"deadline"` vs `"cancelled"`) so later polls and
//! the degraded-result JSON agree on why the solve stopped.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

const LIVE: u8 = 0;
const CANCELLED: u8 = 1;
const DEADLINE: u8 = 2;

/// A cheaply clonable cancellation handle. Clones share one trip flag:
/// cancelling any clone trips them all (that is how the transport-side
/// owner reaches a solve running deep in a worker).
#[derive(Clone, Default)]
pub struct CancelToken {
    /// `None` is the never-trips token — the default for every solve that
    /// has no deadline, costing one `Option` branch per poll.
    inner: Option<Arc<Inner>>,
}

struct Inner {
    state: AtomicU8,
    deadline: Option<Instant>,
    started: Instant,
}

impl CancelToken {
    /// The inert token: never trips, near-zero poll cost.
    pub fn none() -> CancelToken {
        CancelToken { inner: None }
    }

    /// A token with no deadline that only trips via [`CancelToken::cancel`]
    /// (manual cancellation, fault-injection harnesses).
    pub fn manual() -> CancelToken {
        CancelToken::armed(None)
    }

    /// A token that trips once `budget` wall-clock time has elapsed (and
    /// can still be tripped earlier via [`CancelToken::cancel`]).
    pub fn with_deadline(budget: Duration) -> CancelToken {
        CancelToken::armed(Instant::now().checked_add(budget))
    }

    fn armed(deadline: Option<Instant>) -> CancelToken {
        CancelToken {
            inner: Some(Arc::new(Inner {
                state: AtomicU8::new(LIVE),
                deadline,
                started: Instant::now(),
            })),
        }
    }

    /// `Some(self)` when the token can ever trip, `None` for the inert
    /// token — the form the scan structs store so the inert default costs
    /// nothing at the yield points.
    pub fn active(&self) -> Option<&CancelToken> {
        self.inner.as_ref().map(|_| self)
    }

    /// Trip the token manually. First trip wins: a manual cancel after the
    /// deadline already fired does not rewrite the latched reason.
    pub fn cancel(&self) {
        if let Some(i) = &self.inner {
            let _ = i.state.compare_exchange(LIVE, CANCELLED, Ordering::Relaxed, Ordering::Relaxed);
        }
    }

    /// The cooperative poll: relaxed atomic load first, deadline clock only
    /// while still live. The first deadline observation latches the state
    /// so every later poll (and the degraded JSON) sees the same reason.
    #[inline]
    pub fn is_cancelled(&self) -> bool {
        let Some(i) = &self.inner else { return false };
        if i.state.load(Ordering::Relaxed) != LIVE {
            return true;
        }
        if let Some(d) = i.deadline {
            if Instant::now() >= d {
                let _ =
                    i.state.compare_exchange(LIVE, DEADLINE, Ordering::Relaxed, Ordering::Relaxed);
                return true;
            }
        }
        false
    }

    /// Why the token tripped: `"deadline"` or `"cancelled"`, `None` while
    /// live (or for the inert token). Poll [`CancelToken::is_cancelled`]
    /// first if the deadline may have passed without an intervening poll —
    /// the deadline latches lazily.
    pub fn reason(&self) -> Option<&'static str> {
        match self.inner.as_ref()?.state.load(Ordering::Relaxed) {
            CANCELLED => Some("cancelled"),
            DEADLINE => Some("deadline"),
            _ => None,
        }
    }

    /// Milliseconds since the token was armed (0 for the inert token) —
    /// the `elapsed_ms` the degraded result reports.
    pub fn elapsed_ms(&self) -> f64 {
        self.inner.as_ref().map_or(0.0, |i| i.started.elapsed().as_secs_f64() * 1e3)
    }
}

impl std::fmt::Debug for CancelToken {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.inner {
            None => write!(f, "CancelToken::none"),
            Some(i) => write!(
                f,
                "CancelToken {{ state: {}, deadline: {} }}",
                match i.state.load(Ordering::Relaxed) {
                    CANCELLED => "cancelled",
                    DEADLINE => "deadline",
                    _ => "live",
                },
                i.deadline.is_some()
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inert_token_never_trips() {
        let t = CancelToken::none();
        assert!(!t.is_cancelled());
        t.cancel();
        assert!(!t.is_cancelled());
        assert_eq!(t.reason(), None);
        assert!(t.active().is_none());
        assert_eq!(t.elapsed_ms(), 0.0);
    }

    #[test]
    fn manual_cancel_trips_all_clones_with_latched_reason() {
        let t = CancelToken::manual();
        let clone = t.clone();
        assert!(!clone.is_cancelled());
        assert!(t.active().is_some());
        t.cancel();
        assert!(clone.is_cancelled(), "clones share the trip flag");
        assert_eq!(t.reason(), Some("cancelled"));
        assert_eq!(clone.reason(), Some("cancelled"));
    }

    #[test]
    fn zero_deadline_trips_immediately_as_deadline() {
        let t = CancelToken::with_deadline(Duration::from_millis(0));
        assert!(t.is_cancelled());
        assert_eq!(t.reason(), Some("deadline"));
        // A later manual cancel does not rewrite the latched reason.
        t.cancel();
        assert_eq!(t.reason(), Some("deadline"));
        assert!(t.elapsed_ms() >= 0.0);
    }

    #[test]
    fn long_deadline_stays_live() {
        let t = CancelToken::with_deadline(Duration::from_secs(3600));
        assert!(!t.is_cancelled());
        assert_eq!(t.reason(), None);
        // Manual cancel still works under an unexpired deadline.
        t.cancel();
        assert!(t.is_cancelled());
        assert_eq!(t.reason(), Some("cancelled"));
    }
}
