//! Scheduling coordinator: job plumbing over the [`SolveCtx`] engine,
//! parallel batch scheduling, cross-job scheduling sessions, and the
//! request-loop service mode.
//!
//! The paper measures scheduling time "with 8 parallel processes" (Table
//! IV); the coordinator parallelizes scheduling jobs across OS threads
//! (scoped, no external runtime dependency). Beyond per-run memoization,
//! *scheduling sessions* share one bounded `cost::SessionCache` of
//! detailed-model evaluations across jobs — `run_jobs` sweeps (NAS-style
//! traffic re-schedules near-identical layers job after job) and
//! long-lived service connections both reuse it, and the cache key's arch
//! fingerprint guarantees sharing never aliases across hardware configs.
//! The service mode makes the binary a long-running scheduler: one line
//! per request, JSON out — the "real-time interactive compilation" use the
//! paper motivates (NAS, MLaaS). `service` holds the pure line protocol
//! (stdin loop included); `transport` serves it over concurrent TCP /
//! unix-socket connections with per-tenant sessions, bounded-queue
//! admission control, and the `metrics` surface assembled in `metrics`.

pub mod metrics;
pub mod service;
pub mod transport;

use crate::arch::ArchConfig;
use crate::cost::store::{net_fingerprint, ScheduleStore, StoreKey};
use crate::cost::{CacheBudget, EvalCache, SessionCache};
use crate::interlayer::dp::DpConfig;
use crate::solvers::{Objective, PartOrder, SolveCtx, SolveResult};
use crate::workloads::Network;

pub use crate::solvers::{SolveError, SolverKind};

/// Per-request solver knobs parsed from `key=value` tokens — the service
/// line protocol and the CLI share this so clients can set DP parameters
/// per request instead of inheriting hardcoded defaults.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct JobKnobs {
    pub threads: Option<usize>,
    pub objective: Option<Objective>,
    pub ks: Option<usize>,
    pub max_seg_len: Option<usize>,
    pub max_rounds: Option<u64>,
    pub top_per_span: Option<usize>,
    /// Partition-level admissible floor in the staged intra-layer scans
    /// (`part_floor=on|off`; on by default). Exact either way — `off`
    /// exists for triage and for measuring the floor's own benefit.
    pub part_floor: Option<bool>,
    /// Partition visiting order in the staged scans
    /// (`part_order=floor|enum`; floor by default). Exact on the optimum
    /// value either way; the order is part of the content-addressed store
    /// key because ties may resolve to different equal-cost schemes.
    pub part_order: Option<PartOrder>,
    /// Wall-clock budget for the solve (`deadline_ms=`). On expiry the
    /// engine returns its best incumbent marked `degraded` (anytime
    /// semantics) instead of erroring; the service additionally caps the
    /// accepted value.
    pub deadline_ms: Option<u64>,
    /// Persistent warm tier (`persist=on|off`; on by default wherever a
    /// store is configured). `off` forces a cold solve and skips recording
    /// — for triage and for benchmarking the store's own benefit. Inert
    /// when no `--cache-dir` store exists.
    pub persist: Option<bool>,
}

impl JobKnobs {
    /// Consume one token. `Ok(false)`: not a `key=value` token (callers
    /// treat it as positional). `Ok(true)`: recognized and recorded.
    /// `Err`: a malformed knob — unknown key or bad value — that the
    /// request must reject rather than silently default.
    pub fn parse_token(&mut self, tok: &str) -> Result<bool, String> {
        let Some((key, val)) = tok.split_once('=') else {
            return Ok(false);
        };
        fn num<T: std::str::FromStr>(key: &str, val: &str) -> Result<T, String> {
            val.parse().map_err(|_| format!("bad value for knob {key}: {val:?}"))
        }
        // Every count knob must be >= 1: a zero would leave the DP with no
        // candidate spans/chains and panic the solver — a malformed request
        // must never crash a long-running service.
        fn positive<T: std::str::FromStr + PartialOrd + From<u8>>(
            key: &str,
            val: &str,
        ) -> Result<T, String> {
            let v: T = num(key, val)?;
            if v < T::from(1u8) {
                return Err(format!("bad value for knob {key}: must be >= 1"));
            }
            Ok(v)
        }
        match key {
            "threads" => self.threads = Some(positive(key, val)?),
            "objective" => {
                self.objective = Some(
                    Objective::parse(val)
                        .ok_or_else(|| format!("bad value for knob objective: {val:?}"))?,
                );
            }
            "ks" => self.ks = Some(positive(key, val)?),
            "max_seg_len" => self.max_seg_len = Some(positive(key, val)?),
            "max_rounds" => self.max_rounds = Some(positive(key, val)?),
            "top_per_span" => self.top_per_span = Some(positive(key, val)?),
            "part_floor" => {
                self.part_floor = Some(match val {
                    "on" | "true" | "1" => true,
                    "off" | "false" | "0" => false,
                    _ => return Err(format!("bad value for knob part_floor: {val:?}")),
                });
            }
            "part_order" => {
                self.part_order = Some(
                    PartOrder::parse(val)
                        .map_err(|_| format!("bad value for knob part_order: {val:?}"))?,
                );
            }
            "deadline_ms" => self.deadline_ms = Some(positive(key, val)?),
            "persist" => {
                self.persist = Some(match val {
                    "on" | "true" | "1" => true,
                    "off" | "false" | "0" => false,
                    _ => return Err(format!("bad value for knob persist: {val:?}")),
                });
            }
            _ => return Err(format!("unknown knob {key:?}")),
        }
        Ok(true)
    }

    /// Overlay the recorded knobs onto a base `DpConfig`.
    pub fn apply(&self, base: DpConfig) -> DpConfig {
        DpConfig {
            ks: self.ks.unwrap_or(base.ks),
            max_seg_len: self.max_seg_len.unwrap_or(base.max_seg_len),
            max_rounds: self.max_rounds.unwrap_or(base.max_rounds),
            top_per_span: self.top_per_span.unwrap_or(base.top_per_span),
            solve_threads: self.threads.unwrap_or(base.solve_threads),
            parallel_table_min: base.parallel_table_min,
            spec_window: base.spec_window,
            part_floor: self.part_floor.unwrap_or(base.part_floor),
            part_order: self.part_order.unwrap_or(base.part_order),
        }
    }
}

/// One scheduling request.
#[derive(Clone)]
pub struct Job {
    pub net: Network,
    pub batch: u64,
    pub objective: Objective,
    pub solver: SolverKind,
    pub dp: DpConfig,
    /// Optional wall-clock budget. `Some(ms)` arms a deadline token on the
    /// engine: on expiry the solve returns its best incumbent as a
    /// [`SolveResult`] marked degraded, never an error or a hang. `None`
    /// (the default everywhere) is byte-identical to the pre-deadline
    /// engine.
    pub deadline_ms: Option<u64>,
}

impl Job {
    /// The engine configured for this job over `arch` (private fresh
    /// evaluation cache; chain `.session(...)` for cross-job reuse).
    /// `deadline_ms` arms a fresh deadline token per call — the budget
    /// covers one solve, not the `Job` value's lifetime.
    pub fn engine<'a>(&self, arch: &'a ArchConfig) -> SolveCtx<'a> {
        let mut ctx = SolveCtx::new(arch).objective(self.objective).dp(self.dp);
        if let Some(ms) = self.deadline_ms {
            ctx = ctx.cancel(crate::util::cancel::CancelToken::with_deadline(
                std::time::Duration::from_millis(ms),
            ));
        }
        ctx
    }
}

/// Run one scheduling job to completion against a private per-run cache.
/// Within the job, independent per-layer/per-segment intra solves shard
/// across `job.dp.solve_threads` scoped workers and share one evaluation
/// memo; the schedule is byte-identical for any thread count
/// (tests/parallel_determinism.rs). A degenerate net/arch combination
/// returns a structured [`SolveError`] instead of panicking.
pub fn run_job(arch: &ArchConfig, job: &Job) -> Result<SolveResult, SolveError> {
    job.engine(arch).run(&job.net, job.batch, job.solver)
}

/// Run one scheduling job against a caller-supplied evaluation cache —
/// typically a shared `cost::SessionCache` so repeated or near-identical
/// jobs reuse detailed-simulator evaluations *and recorded intra-layer
/// argmins* across the whole session (a warm repeat of an identical job
/// replays its scans outright). Every solver is pure per context, so
/// sharing (with any budget/eviction policy) yields schedules
/// byte-identical to a solitary run.
pub fn run_job_with(
    arch: &ArchConfig,
    job: &Job,
    cost: &dyn EvalCache,
) -> Result<SolveResult, SolveError> {
    job.engine(arch).session(cost).run(&job.net, job.batch, job.solver)
}

/// The content address of a job against `arch` — the key of the on-disk
/// schedule store. Folds everything the (deterministic) solver output
/// depends on: the solver kind with its stochastic knobs, the objective,
/// the batch, and the determinism-relevant DP knobs. Wall-clock-only knobs
/// (threads, speculation window, parallel-table threshold, deadline) are
/// excluded — they change how fast the same schedule is found, not which
/// one. `part_floor` is excluded too (provably argmin-preserving within a
/// fixed order) while `part_order` is folded (it can move ties).
pub fn store_key_for(arch: &ArchConfig, job: &Job) -> StoreKey {
    let solver_vals: Vec<u64> = match job.solver {
        SolverKind::Baseline => vec![0],
        SolverKind::DirectiveExhaustive => vec![1],
        SolverKind::Random { p, seed } => vec![2, p.to_bits(), seed],
        SolverKind::Ml { seed, rounds, batch } => vec![3, seed, rounds as u64, batch as u64],
        SolverKind::Kapla => vec![4],
    };
    let objective = match job.objective {
        Objective::Energy => 0u64,
        Objective::Latency => 1,
    };
    let knobs_fp = crate::util::fnv1a(
        solver_vals
            .into_iter()
            .chain([
                objective,
                job.batch,
                job.dp.ks as u64,
                job.dp.max_seg_len as u64,
                job.dp.max_rounds,
                job.dp.top_per_span as u64,
                job.dp.part_order as u64,
            ]),
    );
    StoreKey {
        net_fp: net_fingerprint(&job.net),
        arch_fp: crate::cost::cache::arch_fingerprint(arch),
        knobs_fp,
    }
}

/// [`run_job_with`] over the persistent warm tier. With a store attached,
/// a job whose content address is already on disk is answered by *replay*:
/// the stored schedule is decoded and re-simulated once
/// (`sim::pipeline::evaluate_schedule` — which bypasses the evaluation
/// memo entirely, so `lookups` stays flat), giving a byte-identical
/// `SolveResult` with zero detailed-evaluation work. A miss solves cold
/// through `cost` and records the result — unless it is degraded (a
/// deadline-cancelled incumbent is not a deterministic function of the
/// request and must never be replayed as if it were).
///
/// The result's `cache` snapshot carries the store counters
/// (`store_lookups`/`store_hits`) overlaid on the session counters.
pub fn run_job_persistent(
    arch: &ArchConfig,
    job: &Job,
    cost: &dyn EvalCache,
    store: Option<&ScheduleStore>,
) -> Result<SolveResult, SolveError> {
    let Some(store) = store else {
        return run_job_with(arch, job, cost);
    };
    let key = store_key_for(arch, job);
    if let Some(stored) = store.lookup(&key) {
        let t = crate::util::Timer::start();
        let eval = crate::sim::pipeline::evaluate_schedule(arch, &job.net, &stored.schedule);
        let mut cache = cost.stats();
        cache.store_lookups = store.lookups();
        cache.store_hits = store.hits();
        return Ok(SolveResult {
            schedule: stored.schedule,
            eval,
            solve_s: t.elapsed_s(),
            cache,
            prune: stored.prune,
            bnb: stored.bnb,
            degraded: None,
        });
    }
    let mut r = run_job_with(arch, job, cost)?;
    if r.degraded.is_none() {
        // A full-fidelity solve is a pure function of the key: safe to
        // publish. Store I/O failure (read-only dir, disk full) must not
        // fail the solve we already have.
        let _ = store.record(&key, &r.schedule, r.prune.as_ref(), r.bnb.as_ref());
    }
    r.cache.store_lookups = store.lookups();
    r.cache.store_hits = store.hits();
    Ok(r)
}

/// Default byte budget of the session `run_jobs` creates: large enough
/// that realistic sweeps hit across jobs without eviction, bounded so a
/// long NAS run cannot grow resident memory without limit (eviction is a
/// perf knob only — schedules are identical for any budget).
pub const DEFAULT_SESSION_BYTES: usize = 256 << 20;

/// Run a batch of jobs over `threads` worker threads (work stealing via a
/// shared atomic index, `util::par_map`). Results come back in job order.
/// The whole batch runs as one scheduling session: a `SessionCache` with a
/// [`DEFAULT_SESSION_BYTES`] budget is shared across the jobs, so sweeps
/// over near-identical networks (NAS-style traffic) reuse each other's
/// evaluations. Use [`run_jobs_with`] to supply a differently-budgeted or
/// longer-lived session.
pub fn run_jobs(
    arch: &ArchConfig,
    jobs: &[Job],
    threads: usize,
) -> Vec<Result<SolveResult, SolveError>> {
    let session = SessionCache::new(CacheBudget::bytes(DEFAULT_SESSION_BYTES));
    run_jobs_with(arch, jobs, threads, &session)
}

/// [`run_jobs`] against a caller-supplied session cache. Results come back
/// in job order, each `Ok` or a per-job [`SolveError`] (one degenerate job
/// does not poison the batch). Each result's `cache` field snapshots the
/// session counters at that job's completion (session-cumulative; with
/// `threads == 1` consecutive deltas isolate per-job reuse exactly).
pub fn run_jobs_with(
    arch: &ArchConfig,
    jobs: &[Job],
    threads: usize,
    cost: &dyn EvalCache,
) -> Vec<Result<SolveResult, SolveError>> {
    crate::util::par_map(jobs, threads, |job| run_job_with(arch, job, cost))
}

/// Default worker-thread count (the paper used 8 parallel processes).
pub fn default_threads() -> usize {
    crate::util::available_threads()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::workloads::nets;

    #[test]
    fn job_knobs_parse_and_apply() {
        let mut k = JobKnobs::default();
        assert_eq!(k.parse_token("positional"), Ok(false));
        assert_eq!(k.parse_token("threads=3"), Ok(true));
        assert_eq!(k.parse_token("objective=latency"), Ok(true));
        assert_eq!(k.parse_token("ks=2"), Ok(true));
        assert_eq!(k.parse_token("max_rounds=16"), Ok(true));
        assert_eq!(k.parse_token("part_floor=off"), Ok(true));
        let dp = k.apply(DpConfig::default());
        assert_eq!(dp.solve_threads, 3);
        assert_eq!(dp.ks, 2);
        assert_eq!(dp.max_rounds, 16);
        assert_eq!(dp.max_seg_len, DpConfig::default().max_seg_len);
        assert!(!dp.part_floor);
        assert_eq!(dp.spec_window, DpConfig::default().spec_window);
        assert_eq!(dp.parallel_table_min, DpConfig::default().parallel_table_min);
        assert_eq!(k.objective, Some(Objective::Latency));

        // deadline_ms: recorded on the knobs (not a DpConfig field), must
        // be a positive integer.
        let mut d = JobKnobs::default();
        assert_eq!(d.parse_token("deadline_ms=250"), Ok(true));
        assert_eq!(d.deadline_ms, Some(250));
        assert!(JobKnobs::default().parse_token("deadline_ms=0").is_err());
        assert!(JobKnobs::default().parse_token("deadline_ms=soon").is_err());

        // part_floor accepts the boolean spellings and defaults to on.
        let mut on = JobKnobs::default();
        assert_eq!(on.parse_token("part_floor=1"), Ok(true));
        assert!(on.apply(DpConfig::default()).part_floor);
        assert!(JobKnobs::default().apply(DpConfig::default()).part_floor);
        assert!(JobKnobs::default().parse_token("part_floor=maybe").is_err());

        // part_order: floor|enum, defaulting to floor through apply().
        let mut po = JobKnobs::default();
        assert_eq!(po.parse_token("part_order=enum"), Ok(true));
        assert_eq!(po.apply(DpConfig::default()).part_order, PartOrder::Enum);
        assert_eq!(JobKnobs::default().apply(DpConfig::default()).part_order, PartOrder::Floor);
        assert!(JobKnobs::default().parse_token("part_order=sorted").is_err());

        // persist: boolean spellings, recorded on the knobs (not a
        // DpConfig field — the service/CLI consult it directly).
        let mut pe = JobKnobs::default();
        assert_eq!(pe.parse_token("persist=off"), Ok(true));
        assert_eq!(pe.persist, Some(false));
        assert_eq!(JobKnobs::default().persist, None);
        assert!(JobKnobs::default().parse_token("persist=maybe").is_err());

        assert!(JobKnobs::default().parse_token("threads=0").is_err());
        assert!(JobKnobs::default().parse_token("threads=two").is_err());
        assert!(JobKnobs::default().parse_token("objective=speed").is_err());
        assert!(JobKnobs::default().parse_token("bogus=1").is_err());
        // Zero count knobs would leave the DP without candidates and panic
        // the solver: reject them all, not just threads.
        for tok in ["ks=0", "max_seg_len=0", "max_rounds=0", "top_per_span=0"] {
            assert!(JobKnobs::default().parse_token(tok).is_err(), "{tok} must be rejected");
        }
    }

    #[test]
    fn shared_session_reuses_across_jobs_without_changing_schedules() {
        let arch = presets::bench_multi_node();
        let job = Job {
            net: nets::mlp(),
            batch: 8,
            objective: Objective::Energy,
            solver: SolverKind::Kapla,
            dp: DpConfig { max_rounds: 8, ..DpConfig::default() },
            deadline_ms: None,
        };
        let solo = run_job(&arch, &job).unwrap();

        let session = SessionCache::unbounded();
        let first = run_job_with(&arch, &job, &session).unwrap();
        let entries_after_first = session.stats().entries;
        let (lookups1, intra_hits1) = (session.stats().lookups, session.stats().intra_hits);
        assert!(session.stats().intra_lookups > 0, "scans must consult the argmin memo");
        let second = run_job_with(&arch, &job, &session).unwrap();
        let st = session.stats();

        // Cross-job reuse: the repeat adds no entries and — because the
        // intra-argmin memo replays every recorded scan — issues no new
        // detailed evaluations at all.
        assert_eq!(st.entries, entries_after_first);
        assert_eq!(st.lookups, lookups1, "warm job must skip the scans entirely");
        assert!(st.intra_hits > intra_hits1, "warm job must replay recorded argmins");
        // ... while the schedules stay byte-identical to the solitary run.
        for r in [&first, &second] {
            assert_eq!(r.eval.energy.total(), solo.eval.energy.total());
            assert_eq!(format!("{:?}", r.schedule), format!("{:?}", solo.schedule));
        }
        // And the per-result snapshot exposes the reuse.
        assert!(second.cache.intra_hits > first.cache.intra_hits);
    }

    #[test]
    fn persistent_store_replays_with_zero_evaluations() {
        let arch = presets::bench_multi_node();
        let job = Job {
            net: nets::mlp(),
            batch: 8,
            objective: Objective::Energy,
            solver: SolverKind::Kapla,
            dp: DpConfig { max_rounds: 8, ..DpConfig::default() },
            deadline_ms: None,
        };
        let dir = std::env::temp_dir()
            .join(format!("kapla-coord-store-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = ScheduleStore::open(&dir).unwrap();
        let s1 = SessionCache::unbounded();
        let cold = run_job_persistent(&arch, &job, &s1, Some(&store)).unwrap();
        assert_eq!(cold.cache.store_lookups, 1);
        assert_eq!(cold.cache.store_hits, 0);

        // "Restart": a fresh session and a fresh handle on the same
        // directory. The warm request must replay the stored schedule
        // byte-identically without a single detailed evaluation.
        let store2 = ScheduleStore::open(&dir).unwrap();
        let s2 = SessionCache::unbounded();
        let warm = run_job_persistent(&arch, &job, &s2, Some(&store2)).unwrap();
        assert_eq!(warm.cache.store_hits, 1);
        assert_eq!(s2.stats().lookups, 0, "replay must issue zero detailed evaluations");
        assert_eq!(s2.stats().intra_lookups, 0, "replay must not even consult the scan memo");
        assert_eq!(format!("{:?}", warm.schedule), format!("{:?}", cold.schedule));
        assert_eq!(warm.eval.energy.total(), cold.eval.energy.total());
        assert!(warm.degraded.is_none());

        // persist=off semantics live in the callers; key stability is what
        // makes the address content-based: same job, same key.
        assert_eq!(store_key_for(&arch, &job), store_key_for(&arch, &job));
        let mut other = job.clone();
        other.batch = 16;
        assert_ne!(store_key_for(&arch, &job), store_key_for(&arch, &other));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn parallel_jobs_match_serial() {
        let arch = presets::bench_multi_node();
        let mk = |solver| Job {
            net: nets::mlp(),
            batch: 8,
            objective: Objective::Energy,
            solver,
            dp: DpConfig { max_rounds: 8, ..DpConfig::default() },
            deadline_ms: None,
        };
        let jobs = vec![
            mk(SolverKind::Kapla),
            mk(SolverKind::Random { p: 0.2, seed: 1 }),
            mk(SolverKind::Kapla),
        ];
        let par: Vec<_> = run_jobs(&arch, &jobs, 3).into_iter().map(|r| r.unwrap()).collect();
        let ser: Vec<_> = jobs.iter().map(|j| run_job(&arch, j).unwrap()).collect();
        assert_eq!(par.len(), 3);
        for (p, s) in par.iter().zip(&ser) {
            assert!((p.eval.energy.total() - s.eval.energy.total()).abs() < 1e-6);
        }
        // KAPLA deterministic: jobs 0 and 2 identical.
        assert!((par[0].eval.energy.total() - par[2].eval.energy.total()).abs() < 1e-6);
    }
}
