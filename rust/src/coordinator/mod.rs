//! Scheduling coordinator: solver registry, parallel batch scheduling, and
//! the request-loop service mode.
//!
//! The paper measures scheduling time "with 8 parallel processes" (Table
//! IV); the coordinator parallelizes scheduling jobs across OS threads
//! (scoped, no external runtime dependency) and reuses solved results via
//! the per-run intra-layer caches inside each solver. The service mode
//! makes the binary a long-running scheduler: one line per request, JSON
//! out — the "real-time interactive compilation" use the paper motivates
//! (NAS, MLaaS).

pub mod service;

use crate::arch::ArchConfig;
use crate::interlayer::dp::DpConfig;
use crate::solvers::exhaustive::{baseline_schedule, directive_exhaustive_schedule};
use crate::solvers::kapla::kapla_schedule;
use crate::solvers::ml::ml_schedule;
use crate::solvers::random::random_schedule;
use crate::solvers::{Objective, SolveResult};
use crate::workloads::Network;

/// The five evaluated solvers (paper §V letters).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SolverKind {
    /// B — nn-dataflow exhaustive baseline.
    Baseline,
    /// S — exhaustive over the directive space.
    DirectiveExhaustive,
    /// R — random sampling with keep-probability `p`.
    Random { p: f64, seed: u64 },
    /// M — simulated annealing + surrogate.
    Ml { seed: u64, rounds: usize, batch: usize },
    /// K — KAPLA.
    Kapla,
}

impl SolverKind {
    pub fn letter(&self) -> &'static str {
        match self {
            SolverKind::Baseline => "B",
            SolverKind::DirectiveExhaustive => "S",
            SolverKind::Random { .. } => "R",
            SolverKind::Ml { .. } => "M",
            SolverKind::Kapla => "K",
        }
    }

    /// Parse a CLI name ("kapla", "b", "random:0.1", "ml", ...).
    pub fn parse(s: &str) -> Option<SolverKind> {
        let lower = s.to_ascii_lowercase();
        let (name, arg) = match lower.split_once(':') {
            Some((n, a)) => (n, Some(a)),
            None => (lower.as_str(), None),
        };
        match name {
            "k" | "kapla" => Some(SolverKind::Kapla),
            "b" | "baseline" | "nn-dataflow" => Some(SolverKind::Baseline),
            "s" | "exhaustive" => Some(SolverKind::DirectiveExhaustive),
            "r" | "random" => {
                let p = arg.and_then(|a| a.parse().ok()).unwrap_or(0.1);
                Some(SolverKind::Random { p, seed: 0xDA7AF10 })
            }
            "m" | "ml" => {
                let rounds = arg.and_then(|a| a.parse().ok()).unwrap_or(16);
                Some(SolverKind::Ml { seed: 0x5EED, rounds, batch: 64 })
            }
            _ => None,
        }
    }
}

/// One scheduling request.
#[derive(Clone)]
pub struct Job {
    pub net: Network,
    pub batch: u64,
    pub objective: Objective,
    pub solver: SolverKind,
    pub dp: DpConfig,
}

/// Run one scheduling job to completion. Within the job, independent
/// per-layer/per-segment intra solves shard across `job.dp.solve_threads`
/// scoped workers and share one `cost::CostCache`; the schedule is
/// byte-identical for any thread count (tests/parallel_determinism.rs).
pub fn run_job(arch: &ArchConfig, job: &Job) -> SolveResult {
    match job.solver {
        SolverKind::Kapla => kapla_schedule(arch, &job.net, job.batch, job.objective, &job.dp).0,
        SolverKind::Baseline => baseline_schedule(arch, &job.net, job.batch, job.objective, &job.dp),
        SolverKind::DirectiveExhaustive => {
            directive_exhaustive_schedule(arch, &job.net, job.batch, job.objective, &job.dp)
        }
        SolverKind::Random { p, seed } => {
            random_schedule(arch, &job.net, job.batch, job.objective, &job.dp, p, seed)
        }
        SolverKind::Ml { seed, rounds, batch } => {
            ml_schedule(arch, &job.net, job.batch, job.objective, &job.dp, seed, rounds, batch)
        }
    }
}

/// Run a batch of jobs over `threads` worker threads (work stealing via a
/// shared atomic index, `util::par_map`). Results come back in job order.
pub fn run_jobs(arch: &ArchConfig, jobs: &[Job], threads: usize) -> Vec<SolveResult> {
    crate::util::par_map(jobs, threads, |job| run_job(arch, job))
}

/// Default worker-thread count (the paper used 8 parallel processes).
pub fn default_threads() -> usize {
    crate::util::available_threads()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::workloads::nets;

    #[test]
    fn solver_kind_parsing() {
        assert_eq!(SolverKind::parse("kapla"), Some(SolverKind::Kapla));
        assert_eq!(SolverKind::parse("K"), Some(SolverKind::Kapla));
        assert_eq!(SolverKind::parse("b"), Some(SolverKind::Baseline));
        assert!(matches!(SolverKind::parse("random:0.5"), Some(SolverKind::Random { p, .. }) if p == 0.5));
        assert!(matches!(SolverKind::parse("ml:4"), Some(SolverKind::Ml { rounds: 4, .. })));
        assert_eq!(SolverKind::parse("nope"), None);
    }

    #[test]
    fn parallel_jobs_match_serial() {
        let arch = presets::bench_multi_node();
        let mk = |solver| Job {
            net: nets::mlp(),
            batch: 8,
            objective: Objective::Energy,
            solver,
            dp: DpConfig { max_rounds: 8, ..DpConfig::default() },
        };
        let jobs =
            vec![mk(SolverKind::Kapla), mk(SolverKind::Random { p: 0.2, seed: 1 }), mk(SolverKind::Kapla)];
        let par = run_jobs(&arch, &jobs, 3);
        let ser: Vec<_> = jobs.iter().map(|j| run_job(&arch, j)).collect();
        assert_eq!(par.len(), 3);
        for (p, s) in par.iter().zip(&ser) {
            assert!((p.eval.energy.total() - s.eval.energy.total()).abs() < 1e-6);
        }
        // KAPLA deterministic: jobs 0 and 2 identical.
        assert!((par[0].eval.energy.total() - par[2].eval.energy.total()).abs() < 1e-6);
    }

    #[test]
    fn letters_match_paper() {
        assert_eq!(SolverKind::Kapla.letter(), "K");
        assert_eq!(SolverKind::Baseline.letter(), "B");
        assert_eq!(SolverKind::DirectiveExhaustive.letter(), "S");
        assert_eq!(SolverKind::Random { p: 0.1, seed: 0 }.letter(), "R");
        assert_eq!(SolverKind::Ml { seed: 0, rounds: 1, batch: 1 }.letter(), "M");
    }
}
