//! Service metrics: request/connection/overload counters and per-solver
//! latency histograms, snapshotted together with the queue state and the
//! per-tenant cache statistics into one JSON object — served by the
//! transport's `metrics` request and the periodic stderr snapshot.
//!
//! Everything here is atomics (`util::stats::LatencyHistogram` is
//! lock-free), so recording from the worker pool never contends with a
//! solve, and a `metrics` request stays cheap enough to answer inline even
//! when the solve queue is saturated — observability must survive exactly
//! the overload conditions it exists to diagnose.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::cost::CacheStats;
use crate::util::json::Json;
use crate::util::stats::LatencyHistogram;

/// Solver letters with a latency-histogram slot, in `SolverKind::letter()`
/// notation (B/S/R/M/K).
const SOLVER_LETTERS: [&str; 5] = ["B", "S", "R", "M", "K"];

#[derive(Default)]
pub struct Metrics {
    /// Connections the listeners accepted (including ones shed at the
    /// connection cap).
    pub connections_accepted: AtomicU64,
    /// Connections currently being served.
    pub connections_active: AtomicU64,
    /// Requests answered through `handle_line` (any verdict).
    pub requests: AtomicU64,
    /// Structured `{"ok":false,...}` responses (malformed requests,
    /// unschedulable nets) — excluding admission-control rejections.
    pub errors: AtomicU64,
    /// Admission-control rejections: solve queue full or connection cap.
    pub overloads: AtomicU64,
    /// Successful responses that carried a `degraded` object — solves
    /// answered with a best-effort incumbent after their deadline tripped
    /// (anytime semantics, still `ok:true`).
    pub degraded: AtomicU64,
    /// Structured deadline errors: requests whose budget expired with no
    /// incumbent at all (counted within `errors` too), including requests
    /// already expired when dequeued.
    pub deadline_errors: AtomicU64,
    solver_latency: [LatencyHistogram; SOLVER_LETTERS.len()],
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Record one completed request: verdict plus wall time, bucketed by
    /// the solver letter echoed in the response (`"R:p=0.3"` folds knobs
    /// after the letter, so only the first byte is keyed). Non-schedule
    /// responses (`stats`) carry no solver and count only as requests.
    pub fn record_response(&self, resp: &Json, secs: f64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        if resp.get("ok") != Some(&Json::Bool(true)) {
            self.errors.fetch_add(1, Ordering::Relaxed);
            // The engine's no-incumbent deadline error and the transport's
            // expired-in-queue rejection share one Display prefix
            // (`SolveError::Deadline`), so one substring keys both.
            if resp
                .get("error")
                .and_then(|e| e.as_str())
                .is_some_and(|e| e.starts_with("deadline exceeded"))
            {
                self.deadline_errors.fetch_add(1, Ordering::Relaxed);
            }
            return;
        }
        if resp.get("degraded").is_some() {
            self.degraded.fetch_add(1, Ordering::Relaxed);
        }
        if let Some(label) = resp.get("solver").and_then(|s| s.as_str()) {
            let letter = label.get(..1).unwrap_or("");
            if let Some(i) = SOLVER_LETTERS.iter().position(|&l| l == letter) {
                self.solver_latency[i].record(secs);
            }
        }
    }

    /// Mean solve latency across every solver histogram, if any request
    /// completed yet — feeds the transport's `retry_after_ms` hint.
    pub fn mean_solve_ms(&self) -> Option<f64> {
        let n: u64 = self.solver_latency.iter().map(|h| h.count()).sum();
        if n == 0 {
            return None;
        }
        let total: f64 = self.solver_latency.iter().map(|h| h.total_ms()).sum();
        Some(total / n as f64)
    }

    /// One deterministic snapshot (keys sorted by `Json::Obj`'s BTreeMap):
    /// queue depth/capacity, the counters, per-solver latency histograms
    /// (only letters that served requests), and per-tenant cache stats.
    pub fn to_json(
        &self,
        queue_depth: usize,
        queue_capacity: usize,
        tenants: &[(String, CacheStats)],
    ) -> Json {
        let mut queue = Json::obj();
        queue.set("depth", queue_depth.into()).set("capacity", queue_capacity.into());
        let mut solvers = Json::obj();
        for (i, letter) in SOLVER_LETTERS.iter().enumerate() {
            if self.solver_latency[i].count() > 0 {
                solvers.set(letter, self.solver_latency[i].to_json());
            }
        }
        let mut tj = Json::obj();
        for (name, stats) in tenants {
            tj.set(name, stats.to_json());
        }
        let mut o = Json::obj();
        o.set("ok", true.into())
            .set("queue", queue)
            .set("connections_accepted", self.connections_accepted.load(Ordering::Relaxed).into())
            .set("connections_active", self.connections_active.load(Ordering::Relaxed).into())
            .set("requests", self.requests.load(Ordering::Relaxed).into())
            .set("errors", self.errors.load(Ordering::Relaxed).into())
            .set("overloads", self.overloads.load(Ordering::Relaxed).into())
            .set("degraded", self.degraded.load(Ordering::Relaxed).into())
            .set("deadline_errors", self.deadline_errors.load(Ordering::Relaxed).into())
            .set("solver_latency_ms", solvers)
            .set("tenants", tj);
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::err_json;

    fn ok_resp(solver: &str) -> Json {
        let mut o = Json::obj();
        o.set("ok", true.into()).set("solver", solver.into());
        o
    }

    #[test]
    fn responses_bucket_by_solver_letter() {
        let m = Metrics::new();
        m.record_response(&ok_resp("K"), 0.004);
        m.record_response(&ok_resp("R:p=0.3,seed=7"), 0.050);
        m.record_response(&err_json("nope"), 0.001);
        assert_eq!(m.requests.load(Ordering::Relaxed), 3);
        assert_eq!(m.errors.load(Ordering::Relaxed), 1);
        let mean = m.mean_solve_ms().unwrap();
        assert!((mean - 27.0).abs() < 1.0, "mean {mean}");
        let j = m.to_json(2, 8, &[]).to_string_compact();
        assert!(j.contains("\"queue\":{\"capacity\":8,\"depth\":2}"), "{j}");
        // Only the letters that served requests appear, knobs folded away.
        assert!(j.contains("\"K\":{\"count\":1"), "{j}");
        assert!(j.contains("\"R\":{\"count\":1"), "{j}");
        assert!(!j.contains("\"B\":"), "{j}");
    }

    #[test]
    fn degraded_and_deadline_responses_are_counted() {
        let m = Metrics::new();
        // ok:true with a degraded object: counted as degraded, not error.
        let mut deg = ok_resp("B");
        let mut d = Json::obj();
        d.set("reason", "deadline".into())
            .set("elapsed_ms", 1.5.into())
            .set("best_effort", true.into());
        deg.set("degraded", d);
        m.record_response(&deg, 0.002);
        // No-incumbent deadline error (engine or expired-in-queue).
        m.record_response(&err_json("deadline exceeded after 3 ms in the solve queue"), 0.0);
        // An unrelated error must not count as a deadline error.
        m.record_response(&err_json("unknown network zzz"), 0.0);
        assert_eq!(m.degraded.load(Ordering::Relaxed), 1);
        assert_eq!(m.deadline_errors.load(Ordering::Relaxed), 1);
        assert_eq!(m.errors.load(Ordering::Relaxed), 2);
        let j = m.to_json(0, 8, &[]).to_string_compact();
        assert!(j.contains("\"degraded\":1"), "{j}");
        assert!(j.contains("\"deadline_errors\":1"), "{j}");
    }

    #[test]
    fn stats_responses_count_but_do_not_bucket() {
        let m = Metrics::new();
        let mut stats = Json::obj();
        stats.set("ok", true.into()).set("cache", Json::obj());
        m.record_response(&stats, 0.001);
        assert_eq!(m.requests.load(Ordering::Relaxed), 1);
        assert_eq!(m.mean_solve_ms(), None);
    }
}
