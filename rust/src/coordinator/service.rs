//! Request-loop service mode: the long-running scheduler front end.
//!
//! Protocol (one request per line on stdin, one JSON response per line on
//! stdout):
//!
//! ```text
//! schedule <network> [batch] [solver] [energy|latency] [train] [key=value ...]
//! stats
//! quit
//! ```
//!
//! Positional fields keep their legacy order; `key=value` knobs may appear
//! anywhere after the network and set per-request solver parameters
//! (`threads=4`, `objective=latency`, `ks=2`, `max_seg_len=3`,
//! `max_rounds=16`, `top_per_span=1`, `part_floor=off`, `part_order=enum`,
//! `deadline_ms=250`, `persist=off`).
//! Malformed requests — unknown
//! network/solver/knob, unparseable value — get a structured
//! `{"ok":false,"error":...}` response instead of silently falling back to
//! defaults.
//!
//! `deadline_ms=` arms a wall-clock budget on the solve: on expiry the
//! engine returns its best incumbent with a `degraded` object
//! (`{"reason":"deadline","elapsed_ms":...,"best_effort":true}`) in the
//! response — anytime semantics, never a hang or a panic. The test-only
//! `chaos=seed:panic_permille:latency_us` knob (gated behind
//! `KAPLA_CHAOS=1`) wraps the cost model in `cost::FaultInjector` for the
//! chaos battery.
//!
//! The connection is a *scheduling session*: every request solves against
//! one shared, budgeted `cost::SessionCache`, so repeated or
//! near-identical requests (the NAS/MLaaS traffic the paper motivates,
//! §II-C) reuse detailed-simulator evaluations across requests. Each
//! response reports the session's cache counters; `stats` reads them
//! without scheduling anything.
//!
//! `handle_line` is deliberately pure (one line in, one JSON value out,
//! no I/O): the stdin loop below and the concurrent network front end in
//! `coordinator::transport` are both thin shells over it. Transport-level
//! concerns — the `tenant=` knob, the `metrics` request, admission
//! control — are stripped or answered in `transport` before a line
//! reaches this module.

use std::io::{BufRead, Write};

use crate::arch::ArchConfig;
use crate::cost::store::ScheduleStore;
use crate::cost::{CacheBudget, EvalCache as _, SessionCache};
use crate::interlayer::dp::DpConfig;
use crate::solvers::Objective;
use crate::util::json::Json;
use crate::workloads;

use super::{run_job_persistent, Job, JobKnobs, SolverKind};

/// Ceiling on the per-request `threads=` knob: schedules are identical for
/// any thread count, so capping at the paper's 8-parallel-process budget
/// only bounds resource use, never results — the one knob that is clamped
/// silently rather than rejected.
pub const MAX_REQUEST_THREADS: usize = 8;

/// Ceilings on the untrusted DP work knobs. Unlike `threads=`, these change
/// the explored schedule space, so an over-limit request is *rejected* with
/// a structured error instead of silently clamped: a single line like
/// `max_seg_len=1000000` would otherwise blow up the span enumeration
/// combinatorially and hang or OOM the long-running serve loop.
pub const MAX_REQUEST_SEG_LEN: usize = 8;
pub const MAX_REQUEST_KS: usize = 64;
pub const MAX_REQUEST_TOP_PER_SPAN: usize = 64;
pub const MAX_REQUEST_ROUNDS: u64 = 4096;

/// Ceiling on the per-request `deadline_ms=` budget (10 minutes). A longer
/// deadline is indistinguishable from no deadline at service scale, and a
/// validated cap keeps the knob composable with queue admission (the
/// transport compares it against wait time before dequeuing).
pub const MAX_REQUEST_DEADLINE_MS: u64 = 600_000;

/// Environment variable gating the `chaos=` fault-injection knob. The knob
/// exists for the chaos battery only: unless the serving process sets
/// `KAPLA_CHAOS=1`, a request carrying `chaos=` is rejected outright.
pub const CHAOS_ENV: &str = "KAPLA_CHAOS";

/// Parsed `chaos=seed:panic_permille:latency_us` knob.
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) struct ChaosKnob {
    pub seed: u64,
    pub panic_permille: u64,
    pub latency_us: u64,
}

impl ChaosKnob {
    fn parse(val: &str) -> Result<ChaosKnob, String> {
        let parts: Vec<&str> = val.split(':').collect();
        let [seed, permille, latency] = parts.as_slice() else {
            return Err(format!("bad chaos knob {val:?}: want seed:panic_permille:latency_us"));
        };
        let num = |name: &str, v: &str| -> Result<u64, String> {
            v.parse().map_err(|_| format!("bad chaos {name}: {v:?}"))
        };
        let k = ChaosKnob {
            seed: num("seed", seed)?,
            panic_permille: num("panic_permille", permille)?,
            latency_us: num("latency_us", latency)?,
        };
        if k.panic_permille > 1000 {
            return Err(format!("bad chaos panic_permille: {} (max 1000)", k.panic_permille));
        }
        // Cap injected latency at 1s per evaluate: chaos must slow solves
        // down, not wedge a worker indefinitely.
        if k.latency_us > 1_000_000 {
            return Err(format!("bad chaos latency_us: {} (max 1000000)", k.latency_us));
        }
        Ok(k)
    }
}

/// Handle a single request line against the connection's scheduling
/// session; `None` means "quit".
pub fn handle_line(arch: &ArchConfig, session: &SessionCache, line: &str) -> Option<Json> {
    handle_line_store(arch, session, None, line)
}

/// [`handle_line`] with the persistent warm tier attached: `schedule`
/// requests consult (and feed) the content-addressed schedule store unless
/// they opt out with `persist=off`, and every reported `cache` object
/// carries the store counters. `store: None` is byte-identical to the
/// store-less service.
pub fn handle_line_store(
    arch: &ArchConfig,
    session: &SessionCache,
    store: Option<&ScheduleStore>,
    line: &str,
) -> Option<Json> {
    let toks: Vec<&str> = line.split_whitespace().collect();
    match toks.as_slice() {
        [] => Some(err_json("empty request")),
        ["quit"] | ["exit"] => None,
        ["stats"] => {
            let mut o = Json::obj();
            o.set("ok", true.into()).set("cache", stats_with_store(session, store).to_json());
            Some(o)
        }
        ["schedule", rest @ ..] => Some(match handle_schedule(arch, session, store, rest) {
            Ok(json) => json,
            Err(msg) => err_json(&msg),
        }),
        _ => Some(err_json(&format!("unknown request: {line}"))),
    }
}

/// Session counters with the store counters overlaid (the session knows
/// nothing about the store; the coordinator owns both).
pub(crate) fn stats_with_store(
    session: &SessionCache,
    store: Option<&ScheduleStore>,
) -> crate::cost::CacheStats {
    let mut st = session.stats();
    if let Some(s) = store {
        st.store_lookups = s.lookups();
        st.store_hits = s.hits();
    }
    st
}

pub(crate) fn err_json(msg: &str) -> Json {
    let mut o = Json::obj();
    o.set("ok", false.into()).set("error", msg.into());
    o
}

fn handle_schedule(
    arch: &ArchConfig,
    session: &SessionCache,
    store: Option<&ScheduleStore>,
    args: &[&str],
) -> Result<Json, String> {
    let (&net_name, rest) = args.split_first().ok_or("schedule: missing network")?;
    let fwd = workloads::by_name(net_name).ok_or_else(|| format!("unknown network {net_name}"))?;

    let mut batch: u64 = 64;
    let mut solver = SolverKind::Kapla;
    let mut objective = Objective::Energy;
    let mut train = false;
    let mut knobs = JobKnobs::default();
    let mut chaos: Option<ChaosKnob> = None;
    let mut pos = 0usize;
    for tok in rest {
        // The chaos knob is service-level (it wraps the cost model, not
        // the DP), carries ':'-separated fields, and is refused unless the
        // process opted in via KAPLA_CHAOS=1 — a public endpoint must not
        // let clients crash or slow workers at will.
        if let Some(val) = tok.strip_prefix("chaos=") {
            if std::env::var(CHAOS_ENV).map(|v| v == "1").unwrap_or(false) {
                chaos = Some(ChaosKnob::parse(val)?);
                continue;
            }
            return Err(format!("chaos knob disabled (set {CHAOS_ENV}=1 to enable)"));
        }
        // Solver tokens may carry their own `key=value` knobs after a ':'
        // ("random:p=0.3,seed=7"), so anything with a ':' is positional.
        if !tok.contains(':') && knobs.parse_token(tok)? {
            continue;
        }
        if *tok == "train" {
            train = true;
            continue;
        }
        match pos {
            // Batch is optional: a non-numeric first positional is tried
            // as the solver (legacy `schedule mlp kapla` form).
            0 => match tok.parse::<u64>() {
                Ok(0) => return Err("bad batch: must be >= 1".to_string()),
                Ok(b) => {
                    batch = b;
                    pos = 1;
                }
                Err(_) => match SolverKind::parse(tok) {
                    Some(k) => {
                        solver = k;
                        pos = 2;
                    }
                    None => return Err(format!("bad batch or unknown solver {tok:?}")),
                },
            },
            1 => {
                solver =
                    SolverKind::parse(tok).ok_or_else(|| format!("unknown solver {tok:?}"))?;
                pos = 2;
            }
            2 => {
                objective =
                    Objective::parse(tok).ok_or_else(|| format!("bad objective {tok:?}"))?;
                pos = 3;
            }
            _ => return Err(format!("unexpected argument {tok:?}")),
        }
    }

    // An untrusted client must not be able to force unbounded solver work.
    for (name, val, max) in [
        ("ks", knobs.ks, MAX_REQUEST_KS),
        ("max_seg_len", knobs.max_seg_len, MAX_REQUEST_SEG_LEN),
        ("top_per_span", knobs.top_per_span, MAX_REQUEST_TOP_PER_SPAN),
    ] {
        if let Some(v) = val {
            if v > max {
                return Err(format!("knob {name} too large: {v} (max {max})"));
            }
        }
    }
    if let Some(r) = knobs.max_rounds {
        if r > MAX_REQUEST_ROUNDS {
            return Err(format!("knob max_rounds too large: {r} (max {MAX_REQUEST_ROUNDS})"));
        }
    }
    if let Some(d) = knobs.deadline_ms {
        if d > MAX_REQUEST_DEADLINE_MS {
            return Err(format!(
                "knob deadline_ms too large: {d} (max {MAX_REQUEST_DEADLINE_MS})"
            ));
        }
    }

    // Service requests are latency-sensitive: saturate the host for the
    // intra-layer sweep unless the request caps it (results are identical
    // for any thread count, so the thread ceiling clamps silently).
    let mut dp =
        knobs.apply(DpConfig { solve_threads: super::default_threads(), ..DpConfig::default() });
    dp.solve_threads = dp.solve_threads.min(MAX_REQUEST_THREADS);
    let objective = knobs.objective.unwrap_or(objective);
    let net = if train { workloads::training_graph(&fwd) } else { fwd };
    let job = Job { net, batch, objective, solver, dp, deadline_ms: knobs.deadline_ms };
    // A degenerate request (net/arch combination no solver can realize)
    // comes back as a structured SolveError — report it like any other
    // malformed request instead of letting a panic kill the serve loop.
    // Under `chaos=` the session's model is wrapped in a FaultInjector;
    // injected panics unwind past this call into the transport worker's
    // catch_unwind (the stdin loop intentionally dies — chaos is opt-in).
    // `persist=off` opts this request out of the warm tier; chaos requests
    // bypass it unconditionally — a fault-injected solve is not a
    // deterministic function of the request and must neither answer from
    // nor feed the store.
    let eff_store = if knobs.persist.unwrap_or(true) { store } else { None };
    let r = match chaos {
        None => run_job_persistent(arch, &job, session, eff_store),
        Some(c) => {
            let tiered = crate::cost::TieredCost::over(session);
            let inj =
                crate::cost::FaultInjector::new(&tiered, c.seed, c.panic_permille, c.latency_us);
            job.engine(arch).model(&inj).run(&job.net, job.batch, job.solver)
        }
    }
    .map_err(|e| e.to_string())?;

    let mut o = Json::obj();
    o.set("ok", true.into())
        .set("network", job.net.name.as_str().into())
        .set("batch", batch.into())
        // The label (letter + non-default solver knobs) so rows from a
        // `random:p=0.3,seed=7` sweep stay distinguishable in logs.
        .set("solver", solver.label().into())
        .set("objective", objective.name().into())
        .set("threads", dp.solve_threads.into())
        .set("energy_pj", r.eval.energy.total().into())
        .set("latency_cycles", r.eval.latency_cycles.into())
        .set("latency_s", r.eval.latency_s(arch).into())
        .set("solve_s", r.solve_s.into())
        .set("segments", r.schedule.segments.len().into())
        .set("cache", r.cache.to_json());
    // A solve whose deadline tripped answers with its best incumbent and
    // says so: anytime semantics, surfaced per response.
    if let Some(d) = &r.degraded {
        let mut dj = Json::obj();
        dj.set("reason", d.reason.into())
            .set("elapsed_ms", d.elapsed_ms.into())
            .set("best_effort", d.best_effort.into());
        o.set("degraded", dj);
    }
    // Exhaustive (B/S) requests ran the staged branch-and-bound scan;
    // surface its pruning counters next to the cache stats.
    if let Some(b) = &r.bnb {
        o.set("bnb", b.to_json());
    }
    // KAPLA requests ran the staged inter-layer planner; surface its
    // span-level pruning counters (Table VI + chain-level B&B).
    if let Some(p) = &r.prune {
        o.set("prune", p.to_json());
    }
    let segs: Vec<Json> = r
        .schedule
        .segments
        .iter()
        .map(|(seg, _)| {
            let mut s = Json::obj();
            s.set(
                "layers",
                Json::Arr(
                    seg.layers
                        .iter()
                        .map(|&i| Json::Str(job.net.layers[i].name.clone()))
                        .collect(),
                ),
            )
            .set("spatial", seg.spatial.into())
            .set("rounds", seg.rounds.into());
            s
        })
        .collect();
    o.set("chain", Json::Arr(segs));
    Ok(o)
}

/// Run the blocking stdin/stdout service loop with the same bounded
/// default budget `run_jobs` batches get: a long-running service must not
/// grow memory monotonically with distinct requests. The budget is purely
/// a resource knob — schedules are byte-identical under any budget — and
/// `--cache-budget` (including `unbounded`) overrides it.
pub fn serve(arch: &ArchConfig) {
    serve_with(arch, CacheBudget::bytes(super::DEFAULT_SESSION_BYTES))
}

/// Run the blocking stdin/stdout service loop; all requests share one
/// `SessionCache` under `budget` (CLI `--cache-budget`).
pub fn serve_with(arch: &ArchConfig, budget: CacheBudget) {
    serve_persistent(arch, budget, None)
}

/// Stdin/stdout loop with an optional warm tier: with a `cache_dir` the
/// single-user layout `<dir>/session.snap` + `<dir>/store/` is loaded
/// before the first request and the snapshot is rewritten on clean exit
/// (`quit` / EOF). A kill mid-run loses only the in-memory memo deltas;
/// the schedule store writes through on every recorded solve.
pub fn serve_persistent(
    arch: &ArchConfig,
    budget: CacheBudget,
    cache_dir: Option<&std::path::Path>,
) {
    let session = SessionCache::new(budget);
    let store = cache_dir.and_then(|dir| {
        if let Err(e) = crate::cost::load_session(&session, &dir.join("session.snap"), Some(arch)) {
            eprintln!("warm tier: cannot load session snapshot: {e}");
        }
        crate::cost::store::ScheduleStore::open(&dir.join("store"))
            .inspect_err(|e| eprintln!("warm tier: cannot open schedule store: {e}"))
            .ok()
    });
    let stdin = std::io::stdin();
    let mut stdout = std::io::stdout();
    eprintln!(
        "kapla service ready (schedule <net> [batch] [solver] [objective] [train] \
         [threads=N] [objective=...] [ks=N] ... | stats | quit)"
    );
    for line in stdin.lock().lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        match handle_line_store(arch, &session, store.as_ref(), &line) {
            Some(resp) => {
                let _ = writeln!(stdout, "{}", resp.to_string_compact());
                let _ = stdout.flush();
            }
            None => break,
        }
    }
    if let Some(dir) = cache_dir {
        if let Err(e) = crate::cost::save_session(&session, &dir.join("session.snap")) {
            eprintln!("warm tier: cannot save session snapshot: {e}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;

    #[test]
    fn quit_ends_loop() {
        let arch = presets::bench_multi_node();
        let s = SessionCache::unbounded();
        assert!(handle_line(&arch, &s, "quit").is_none());
        assert!(handle_line(&arch, &s, "exit").is_none());
    }

    #[test]
    fn bad_requests_report_errors() {
        let arch = presets::bench_multi_node();
        let s = SessionCache::unbounded();
        let r = handle_line(&arch, &s, "bogus").unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        let r = handle_line(&arch, &s, "schedule nonexistent-net").unwrap();
        assert!(r.get("error").unwrap().as_str().unwrap().contains("unknown network"));
    }

    #[test]
    fn schedule_request_roundtrip() {
        let arch = presets::bench_multi_node();
        let s = SessionCache::unbounded();
        let r = handle_line(&arch, &s, "schedule mlp 8 kapla").unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert!(r.get("energy_pj").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(r.get("solver").unwrap().as_str(), Some("K"));
        assert_eq!(r.get("objective").unwrap().as_str(), Some("energy"));
        assert!(r.get("cache").unwrap().get("lookups").unwrap().as_f64().unwrap() > 0.0);
        let out = r.to_string_compact();
        assert!(out.starts_with('{') && out.ends_with('}'));
    }

    #[test]
    fn exhaustive_request_reports_bnb_counters() {
        let arch = presets::bench_multi_node();
        let s = SessionCache::unbounded();
        let r =
            handle_line(&arch, &s, "schedule mlp 4 b max_rounds=4 max_seg_len=2 threads=1").unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        let bnb = r.get("bnb").expect("exhaustive response carries bnb counters");
        assert!(bnb.get("schemes_visited").unwrap().as_f64().unwrap() > 0.0);
        assert!(bnb.get("prune_rate").unwrap().as_f64().is_some());
        // The partition-floor knob is on by default and surfaced in the
        // bnb object (SolverKind labels are unit tags, so the flag rides
        // the counters instead).
        assert_eq!(bnb.get("part_floor"), Some(&Json::Bool(true)));
        assert!(bnb.get("parts_visited").unwrap().as_f64().is_some());
        assert!(bnb.get("parts_pruned").unwrap().as_f64().is_some());
        // `part_floor=off` disables the check — same schedule (the floor
        // is exact), zero partitions pruned, flag reported off.
        let off = handle_line(
            &arch,
            &s,
            "schedule mlp 4 b max_rounds=4 max_seg_len=2 threads=1 part_floor=off",
        )
        .unwrap();
        assert_eq!(off.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(off.get("energy_pj"), r.get("energy_pj"));
        let obnb = off.get("bnb").unwrap();
        assert_eq!(obnb.get("part_floor"), Some(&Json::Bool(false)));
        assert_eq!(obnb.get("parts_pruned").unwrap().as_f64(), Some(0.0));
        // The KAPLA path doesn't subtree-prune: no bnb object.
        let k = handle_line(&arch, &s, "schedule mlp 4 kapla max_rounds=4 threads=1").unwrap();
        assert!(k.get("bnb").is_none());
    }

    #[test]
    fn kapla_request_reports_planner_prune_counters() {
        let arch = presets::bench_multi_node();
        let s = SessionCache::unbounded();
        let r = handle_line(&arch, &s, "schedule mlp 4 kapla max_rounds=4 threads=1").unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        let prune = r.get("prune").expect("kapla response carries planner counters");
        assert!(prune.get("spans_total").unwrap().as_f64().unwrap() > 0.0);
        assert!(prune.get("spans_pruned").unwrap().as_f64().is_some());
        assert!(prune.get("schemes_bound_pruned").unwrap().as_f64().is_some());
        // The exact-DP baselines don't rank-prune: no prune object.
        let b =
            handle_line(&arch, &s, "schedule mlp 4 b max_rounds=4 max_seg_len=2 threads=1")
                .unwrap();
        assert!(b.get("prune").is_none());
    }

    #[test]
    fn training_request() {
        let arch = presets::bench_multi_node();
        let s = SessionCache::unbounded();
        let r = handle_line(&arch, &s, "schedule mlp 8 kapla energy train").unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert!(r.get("network").unwrap().as_str().unwrap().contains("train"));
    }

    #[test]
    fn train_name_and_flag_do_not_double_wrap() {
        let arch = presets::bench_multi_node();
        let s = SessionCache::unbounded();
        // `mlp-train` already names the training graph; the redundant
        // `train` flag used to wrap it a second time (panicking on the
        // backward kinds). Both spellings must yield the same solve.
        let both =
            handle_line(&arch, &s, "schedule mlp-train 4 kapla train threads=1 max_rounds=4")
                .unwrap();
        assert_eq!(both.get("ok"), Some(&Json::Bool(true)), "{}", both.to_string_compact());
        assert_eq!(both.get("network").unwrap().as_str(), Some("mlp-train"));
        let flag = handle_line(&arch, &s, "schedule mlp 4 kapla train threads=1 max_rounds=4")
            .unwrap();
        assert_eq!(flag.get("network").unwrap().as_str(), Some("mlp-train"));
        assert_eq!(both.get("energy_pj"), flag.get("energy_pj"));
        assert_eq!(
            both.get("chain").unwrap().to_string_compact(),
            flag.get("chain").unwrap().to_string_compact()
        );
    }

    #[test]
    fn deadline_knob_validates_caps_and_degrades() {
        let arch = presets::bench_multi_node();
        let s = SessionCache::unbounded();
        // Over the cap: rejected, not clamped (it changes semantics).
        let r = handle_line(&arch, &s, "schedule mlp 4 kapla deadline_ms=600001").unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        assert!(r.get("error").unwrap().as_str().unwrap().contains("deadline_ms too large"));
        // Zero/garbage rejected by the knob parser.
        let r = handle_line(&arch, &s, "schedule mlp 4 kapla deadline_ms=0").unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        // A generous deadline answers byte-identically to no deadline and
        // is NOT marked degraded.
        let free = handle_line(&arch, &s, "schedule mlp 4 kapla threads=1 max_rounds=4").unwrap();
        let capped = handle_line(
            &arch,
            &s,
            "schedule mlp 4 kapla threads=1 max_rounds=4 deadline_ms=600000",
        )
        .unwrap();
        assert_eq!(capped.get("ok"), Some(&Json::Bool(true)));
        assert!(capped.get("degraded").is_none(), "untripped deadline must not degrade");
        assert_eq!(capped.get("energy_pj"), free.get("energy_pj"));
        assert_eq!(
            capped.get("chain").unwrap().to_string_compact(),
            free.get("chain").unwrap().to_string_compact()
        );
        // A 1ms budget on an exhaustive alexnet solve trips immediately:
        // still ok:true, with the anytime incumbent marked degraded.
        let d = handle_line(
            &arch,
            &s,
            "schedule alexnet 8 b threads=1 max_rounds=4 max_seg_len=2 deadline_ms=1",
        )
        .unwrap();
        assert_eq!(d.get("ok"), Some(&Json::Bool(true)), "{}", d.to_string_compact());
        let deg = d.get("degraded").expect("1ms exhaustive alexnet must degrade");
        assert_eq!(deg.get("reason").unwrap().as_str(), Some("deadline"));
        assert_eq!(deg.get("best_effort"), Some(&Json::Bool(true)));
        assert!(deg.get("elapsed_ms").unwrap().as_f64().unwrap() >= 0.5);
        assert!(d.get("energy_pj").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn chaos_knob_is_gated_and_validated() {
        // Pure parser checks (no env involvement).
        assert_eq!(
            ChaosKnob::parse("7:250:1000"),
            Ok(ChaosKnob { seed: 7, panic_permille: 250, latency_us: 1000 })
        );
        assert!(ChaosKnob::parse("7:1001:0").is_err(), "permille over 1000");
        assert!(ChaosKnob::parse("7:0:2000000").is_err(), "latency over 1s");
        assert!(ChaosKnob::parse("7:0").is_err(), "missing field");
        assert!(ChaosKnob::parse("x:0:0").is_err(), "non-numeric seed");

        let arch = presets::bench_multi_node();
        let s = SessionCache::unbounded();
        let r = handle_line(&arch, &s, "schedule mlp 4 kapla threads=1 max_rounds=4 chaos=1:0:0")
            .unwrap();
        if std::env::var(CHAOS_ENV).map(|v| v == "1").unwrap_or(false) {
            // Opted-in process (the chaos battery runs this way): a
            // fault-free injector answers like the plain model.
            assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        } else {
            assert!(
                r.get("error").unwrap().as_str().unwrap().contains("chaos knob disabled"),
                "{}",
                r.to_string_compact()
            );
        }
    }

    #[test]
    fn persist_knob_and_store_counters() {
        let arch = presets::bench_multi_node();
        let dir =
            std::env::temp_dir().join(format!("kapla-service-store-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = ScheduleStore::open(&dir).unwrap();
        let s = SessionCache::unbounded();
        let req = "schedule mlp 4 kapla threads=1 max_rounds=4";
        let cold = handle_line_store(&arch, &s, Some(&store), req).unwrap();
        assert_eq!(cold.get("ok"), Some(&Json::Bool(true)), "{}", cold.to_string_compact());
        let cc = cold.get("cache").unwrap();
        assert_eq!(cc.get("store_lookups").unwrap().as_f64(), Some(1.0));
        assert_eq!(cc.get("store_hits").unwrap().as_f64(), Some(0.0));

        // Fresh session = "restarted process": the repeat answers from the
        // store with zero detailed evaluations and an identical chain.
        let s2 = SessionCache::unbounded();
        let warm = handle_line_store(&arch, &s2, Some(&store), req).unwrap();
        let wc = warm.get("cache").unwrap();
        assert!(wc.get("store_hits").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(wc.get("lookups").unwrap().as_f64(), Some(0.0));
        assert_eq!(warm.get("energy_pj"), cold.get("energy_pj"));
        assert_eq!(
            warm.get("chain").unwrap().to_string_compact(),
            cold.get("chain").unwrap().to_string_compact()
        );

        // persist=off bypasses the store entirely for that request.
        let before = store.lookups();
        let off = handle_line_store(
            &arch,
            &s2,
            Some(&store),
            "schedule mlp 4 kapla threads=1 max_rounds=4 persist=off",
        )
        .unwrap();
        assert_eq!(off.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(store.lookups(), before, "persist=off must not touch the store");
        assert_eq!(off.get("energy_pj"), cold.get("energy_pj"));

        // Malformed persist values are rejected, not defaulted.
        let bad = handle_line_store(&arch, &s2, Some(&store), "schedule mlp persist=maybe")
            .unwrap();
        assert_eq!(bad.get("ok"), Some(&Json::Bool(false)));

        // `stats` overlays the store counters onto the session's.
        let st = handle_line_store(&arch, &s2, Some(&store), "stats").unwrap();
        assert!(
            st.get("cache").unwrap().get("store_lookups").unwrap().as_f64().unwrap() > 0.0
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stats_request_reads_session() {
        let arch = presets::bench_multi_node();
        let s = SessionCache::unbounded();
        let r = handle_line(&arch, &s, "stats").unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(r.get("cache").unwrap().get("lookups").unwrap().as_f64(), Some(0.0));
    }
}
