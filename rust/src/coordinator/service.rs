//! Request-loop service mode: the long-running scheduler front end.
//!
//! Protocol (one request per line on stdin, one JSON response per line on
//! stdout):
//!
//! ```text
//! schedule <network> <batch> <solver> [energy|latency] [train]
//! quit
//! ```
//!
//! This is the deployment shape the paper motivates for NAS and MLaaS
//! use cases (§II-C): dataflow scheduling as an interactive service.

use std::io::{BufRead, Write};

use crate::arch::ArchConfig;
use crate::interlayer::dp::DpConfig;
use crate::solvers::Objective;
use crate::util::json::Json;
use crate::workloads;

use super::{run_job, Job, SolverKind};

/// Handle a single request line; `None` means "quit".
pub fn handle_line(arch: &ArchConfig, line: &str) -> Option<Json> {
    let toks: Vec<&str> = line.split_whitespace().collect();
    match toks.as_slice() {
        [] => Some(err_json("empty request")),
        ["quit"] | ["exit"] => None,
        ["schedule", rest @ ..] => Some(handle_schedule(arch, rest)),
        _ => Some(err_json(&format!("unknown request: {line}"))),
    }
}

fn err_json(msg: &str) -> Json {
    let mut o = Json::obj();
    o.set("ok", false.into()).set("error", msg.into());
    o
}

fn handle_schedule(arch: &ArchConfig, args: &[&str]) -> Json {
    let (&net_name, rest) = match args.split_first() {
        Some(x) => x,
        None => return err_json("schedule: missing network"),
    };
    let Some(fwd) = workloads::by_name(net_name) else {
        return err_json(&format!("unknown network {net_name}"));
    };
    let batch: u64 = rest.first().and_then(|s| s.parse().ok()).unwrap_or(64);
    let solver = rest
        .get(1)
        .and_then(|s| SolverKind::parse(s))
        .unwrap_or(SolverKind::Kapla);
    let objective = match rest.get(2) {
        Some(&"latency") => Objective::Latency,
        _ => Objective::Energy,
    };
    let net = if rest.contains(&"train") { workloads::training_graph(&fwd) } else { fwd };

    // Service requests are latency-sensitive: saturate the host for the
    // intra-layer sweep (results are identical for any thread count).
    let dp = DpConfig { solve_threads: super::default_threads(), ..DpConfig::default() };
    let job = Job { net, batch, objective, solver, dp };
    let r = run_job(arch, &job);

    let mut o = Json::obj();
    o.set("ok", true.into())
        .set("network", job.net.name.as_str().into())
        .set("batch", batch.into())
        .set("solver", solver.letter().into())
        .set("energy_pj", r.eval.energy.total().into())
        .set("latency_cycles", r.eval.latency_cycles.into())
        .set("latency_s", r.eval.latency_s(arch).into())
        .set("solve_s", r.solve_s.into())
        .set("segments", r.schedule.segments.len().into());
    let segs: Vec<Json> = r
        .schedule
        .segments
        .iter()
        .map(|(seg, _)| {
            let mut s = Json::obj();
            s.set(
                "layers",
                Json::Arr(
                    seg.layers
                        .iter()
                        .map(|&i| Json::Str(job.net.layers[i].name.clone()))
                        .collect(),
                ),
            )
            .set("spatial", seg.spatial.into())
            .set("rounds", seg.rounds.into());
            s
        })
        .collect();
    o.set("chain", Json::Arr(segs));
    o
}

/// Run the blocking stdin/stdout service loop.
pub fn serve(arch: &ArchConfig) {
    let stdin = std::io::stdin();
    let mut stdout = std::io::stdout();
    eprintln!("kapla service ready (schedule <net> <batch> <solver> [objective] [train] | quit)");
    for line in stdin.lock().lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        match handle_line(arch, &line) {
            Some(resp) => {
                let _ = writeln!(stdout, "{}", resp.to_string_compact());
                let _ = stdout.flush();
            }
            None => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;

    #[test]
    fn quit_ends_loop() {
        let arch = presets::bench_multi_node();
        assert!(handle_line(&arch, "quit").is_none());
        assert!(handle_line(&arch, "exit").is_none());
    }

    #[test]
    fn bad_requests_report_errors() {
        let arch = presets::bench_multi_node();
        let r = handle_line(&arch, "bogus").unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        let r = handle_line(&arch, "schedule nonexistent-net").unwrap();
        assert!(r.get("error").unwrap().as_str().unwrap().contains("unknown network"));
    }

    #[test]
    fn schedule_request_roundtrip() {
        let arch = presets::bench_multi_node();
        let r = handle_line(&arch, "schedule mlp 8 kapla").unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert!(r.get("energy_pj").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(r.get("solver").unwrap().as_str(), Some("K"));
        let s = r.to_string_compact();
        assert!(s.starts_with('{') && s.ends_with('}'));
    }

    #[test]
    fn training_request() {
        let arch = presets::bench_multi_node();
        let r = handle_line(&arch, "schedule mlp 8 kapla energy train").unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert!(r.get("network").unwrap().as_str().unwrap().contains("train"));
    }
}
