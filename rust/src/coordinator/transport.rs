//! Network transport for the scheduling service: concurrent TCP and
//! unix-socket connections speaking the `service` line protocol,
//! multiplexed onto a bounded solve pool with admission control,
//! per-tenant scheduling sessions, and a `metrics` surface.
//!
//! Layering: `service::handle_line` stays the pure request → response
//! function (one line in, one JSON out, no I/O, no tenancy) — this module
//! only wraps the concurrency shell around it:
//!
//! * **Listener threads** accept connections (non-blocking accept polled
//!   against a stop flag, so shutdown never hangs in `accept`).
//! * **Connection threads** frame lines, strip the transport-level
//!   `tenant=` knob, resolve the request's `SessionCache`, and submit
//!   solve work to the bounded queue. `stats`/`metrics`/`quit` answer
//!   inline so observability survives a saturated queue.
//! * **A worker pool** (`util::queue::BoundedQueue` drained by
//!   `par_map`-style scoped threads) runs the solves. When the queue is
//!   full the connection answers `{"ok":false,"error":"overloaded",
//!   "retry_after_ms":...}` immediately instead of blocking the client.
//!
//! Tenancy: each `tenant=<name>` namespace gets its own `SessionCache`
//! under an independent `CacheBudget`, so one tenant's NAS sweep can
//! neither read another's warm cache (isolation is pinned by
//! `tests/service_transport.rs`) nor evict it (budgets are per-session by
//! construction). Requests without the knob share a per-connection
//! anonymous session — exactly the old stdin-loop behavior.
//!
//! Every solver is pure per (arch, request, session), so concurrency
//! changes *when* requests run, never what a client gets back: a schedule
//! computed over TCP is byte-identical to the same request through the
//! stdin loop.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use crate::arch::ArchConfig;
use crate::cost::store::ScheduleStore;
use crate::cost::{load_session, save_session, CacheBudget, CacheStats, SessionCache};
use crate::util::json::Json;
use crate::util::queue::BoundedQueue;
use crate::util::Timer;

use super::metrics::Metrics;
use super::service;

/// A client line longer than this is judged hostile and the connection is
/// closed (the longest legitimate request is well under 1 KB).
const MAX_LINE_BYTES: usize = 64 * 1024;

/// How long a connection thread blocks in `read` before re-checking the
/// stop flag; bounds shutdown latency for idle connections.
const READ_POLL: Duration = Duration::from_millis(100);

/// Accept-loop poll interval while no connection is pending.
const ACCEPT_POLL: Duration = Duration::from_millis(20);

pub struct ServiceConfig {
    /// Budget for *each* tenant namespace and each anonymous
    /// per-connection session, independently (not a shared pool: an
    /// aggressive tenant must not be able to evict a quiet one).
    pub budget: CacheBudget,
    /// Bounded solve-queue depth; a full queue sheds load.
    pub queue_depth: usize,
    /// Worker threads draining the solve queue.
    pub workers: usize,
    /// Maximum distinct named tenant namespaces (each holds up to
    /// `budget` of cache, so this caps service memory).
    pub max_tenants: usize,
    /// Maximum concurrently served connections; excess connections get a
    /// structured overload response and are closed.
    pub max_connections: usize,
    /// Emit a compact metrics JSON line to stderr at this interval.
    pub metrics_interval: Option<Duration>,
    /// Close a connection that completes no request line for this long —
    /// the slowloris defense (`--idle-timeout`). Measured from the last
    /// *completed* line, so a client dribbling bytes without ever sending
    /// a newline times out like a silent one. The close is structured: an
    /// `{"ok":false,"error":"idle timeout..."}` line precedes the
    /// disconnect. `None` (the default) keeps connections open
    /// indefinitely, the pre-flag behavior.
    pub idle_timeout: Option<Duration>,
    /// Root of the persistent warm tier (`--cache-dir`). When set, each
    /// tenant namespace gets `<dir>/tenants/<name>/` holding its session
    /// snapshot (loaded at tenant creation, saved at graceful shutdown)
    /// and its content-addressed schedule store; anonymous connections
    /// share the `<dir>/anon/store` schedule store (no session snapshot —
    /// anonymous sessions are per-connection and ephemeral by design).
    /// `None` (the default) is the pre-persistence in-memory service.
    pub cache_dir: Option<PathBuf>,
}

impl Default for ServiceConfig {
    fn default() -> ServiceConfig {
        ServiceConfig {
            budget: CacheBudget::bytes(super::DEFAULT_SESSION_BYTES),
            queue_depth: 64,
            workers: crate::util::available_threads(),
            max_tenants: 64,
            max_connections: 256,
            metrics_interval: None,
            idle_timeout: None,
            cache_dir: None,
        }
    }
}

/// Named per-tenant `SessionCache` namespaces, created lazily on first
/// use, each under its own independent budget — plus, when a `cache_dir`
/// is configured, each tenant's slice of the persistent warm tier: its
/// session snapshot (loaded on creation, fingerprint-checked per entry)
/// and its content-addressed schedule store.
pub struct TenantRegistry {
    budget: CacheBudget,
    max_tenants: usize,
    /// Warm-tier root; tenants live under `<dir>/tenants/<name>/`.
    cache_dir: Option<PathBuf>,
    /// Arch the service solves against — the snapshot load filter, so a
    /// cache dir carried across a hardware reconfiguration degrades to a
    /// cold start instead of replaying foreign evaluations.
    arch: Option<ArchConfig>,
    map: Mutex<HashMap<String, (Arc<SessionCache>, Option<Arc<ScheduleStore>>)>>,
}

impl TenantRegistry {
    pub fn new(budget: CacheBudget, max_tenants: usize) -> TenantRegistry {
        TenantRegistry {
            budget,
            max_tenants,
            cache_dir: None,
            arch: None,
            map: Mutex::new(HashMap::new()),
        }
    }

    /// A registry backed by the persistent warm tier rooted at `dir`.
    pub fn persistent(
        budget: CacheBudget,
        max_tenants: usize,
        dir: PathBuf,
        arch: ArchConfig,
    ) -> TenantRegistry {
        TenantRegistry {
            budget,
            max_tenants,
            cache_dir: Some(dir),
            arch: Some(arch),
            map: Mutex::new(HashMap::new()),
        }
    }

    /// Tenant names come from untrusted request lines: short alnum plus
    /// `. _ -` only (they become JSON keys in `metrics` output and, with a
    /// `cache_dir`, directory names — which is why the `.`/`..` path
    /// components are rejected explicitly on top of the charset).
    pub fn valid_name(name: &str) -> bool {
        !name.is_empty()
            && name.len() <= 64
            && name != "."
            && name != ".."
            && name.chars().all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.'))
    }

    /// The tenant's session, created on first use. The namespace count is
    /// capped: a request naming a new tenant past the cap is rejected
    /// (existing tenants keep working — the cap bounds memory, it is not
    /// an eviction policy).
    pub fn session(&self, name: &str) -> Result<Arc<SessionCache>, String> {
        self.warm(name).map(|(s, _)| s)
    }

    /// The tenant's session plus its slice of the warm tier (store handle;
    /// `None` without a `cache_dir`). On first use with persistence, the
    /// tenant's session snapshot is loaded — fingerprint-checked per
    /// entry, anything unrecognized skipped and counted — and its schedule
    /// store opened under `<dir>/tenants/<name>/`.
    pub fn warm(
        &self,
        name: &str,
    ) -> Result<(Arc<SessionCache>, Option<Arc<ScheduleStore>>), String> {
        if !Self::valid_name(name) {
            return Err(format!("bad tenant name {name:?}: use 1-64 chars of [a-zA-Z0-9._-]"));
        }
        let mut map = self.map.lock().unwrap();
        if let Some((s, st)) = map.get(name) {
            return Ok((Arc::clone(s), st.clone()));
        }
        if map.len() >= self.max_tenants {
            return Err(format!(
                "tenant limit reached ({}): tenant {name:?} not admitted",
                self.max_tenants
            ));
        }
        let s = Arc::new(SessionCache::new(self.budget));
        let store = self.cache_dir.as_ref().and_then(|dir| {
            let tenant_dir = dir.join("tenants").join(name);
            // A missing/unreadable snapshot is a clean cold start; partial
            // corruption is skipped per entry inside load_session.
            let _ = load_session(&s, &tenant_dir.join("session.snap"), self.arch.as_ref());
            // A store that cannot be opened (read-only fs) just means this
            // tenant serves without one.
            ScheduleStore::open(&tenant_dir.join("store")).ok().map(Arc::new)
        });
        map.insert(name.to_string(), (Arc::clone(&s), store.clone()));
        Ok((s, store))
    }

    /// Persist every tenant's session snapshot (graceful shutdown). A
    /// tenant whose directory cannot be written is skipped — shutdown must
    /// not fail over a full disk.
    pub fn save_all(&self) {
        let Some(dir) = &self.cache_dir else { return };
        let map = self.map.lock().unwrap();
        for (name, (session, _)) in map.iter() {
            let path = dir.join("tenants").join(name).join("session.snap");
            let _ = save_session(session, &path);
        }
    }

    /// Per-tenant cache-stats snapshot (store counters overlaid),
    /// name-sorted so `metrics` output is deterministic.
    pub fn snapshot(&self) -> Vec<(String, CacheStats)> {
        let map = self.map.lock().unwrap();
        let mut v: Vec<(String, CacheStats)> = map
            .iter()
            .map(|(name, (s, st))| (name.clone(), service::stats_with_store(s, st.as_deref())))
            .collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Split the transport-level `tenant=` knob out of a request line, so
/// `handle_line` (which rejects unknown knobs) sees the plain protocol.
/// A token carrying `:` is a solver spec (`random:p=0.3`), never a tenant
/// knob; repeating the knob is ambiguous and rejected.
pub fn split_tenant(line: &str) -> Result<(Option<&str>, String), String> {
    let mut tenant = None;
    let mut rest: Vec<&str> = Vec::new();
    for tok in line.split_whitespace() {
        match tok.strip_prefix("tenant=") {
            Some(name) if !tok.contains(':') => {
                if tenant.replace(name).is_some() {
                    return Err("repeated tenant= knob".to_string());
                }
            }
            _ => rest.push(tok),
        }
    }
    Ok((tenant, rest.join(" ")))
}

/// One admitted solve: the plain request line, the resolved session, and
/// the channel the connection thread blocks on for the response.
struct SolveRequest {
    line: String,
    session: Arc<SessionCache>,
    /// The request's slice of the persistent warm tier (tenant store, or
    /// the shared anonymous store); `None` when serving without one.
    store: Option<Arc<ScheduleStore>>,
    resp: mpsc::Sender<Json>,
    /// Started at admission, so workers can see how long the request sat
    /// in the queue.
    admitted: Timer,
    /// The request's `deadline_ms=` budget, pre-scanned at admission (the
    /// authoritative parse/validation still happens in `handle_line`).
    /// A request whose budget already expired while queued is answered
    /// with a structured deadline error *before* any solve work starts —
    /// the deadline knob composes with queue admission instead of
    /// spending a worker on a result the client has given up on.
    deadline_ms: Option<u64>,
}

/// Best-effort scan for the `deadline_ms=` knob at admission time. Returns
/// `None` for malformed values — `handle_line` rejects those with a proper
/// parse error, which must win over a spurious queue-expiry answer.
fn scan_deadline_ms(line: &str) -> Option<u64> {
    line.split_whitespace()
        .filter(|tok| !tok.contains(':'))
        .find_map(|tok| tok.strip_prefix("deadline_ms="))
        .and_then(|v| v.parse::<u64>().ok())
        .filter(|&ms| ms > 0)
}

/// Shared state of one running service instance.
struct ServeCtx {
    arch: ArchConfig,
    cfg: ServiceConfig,
    tenants: TenantRegistry,
    /// Schedule store shared by `tenant=`-less requests across all
    /// connections (`<cache_dir>/anon/store`); anonymous *sessions* stay
    /// per-connection and ephemeral.
    anon_store: Option<Arc<ScheduleStore>>,
    queue: BoundedQueue<SolveRequest>,
    metrics: Metrics,
    stop: Arc<AtomicBool>,
}

impl ServeCtx {
    fn metrics_json(&self) -> Json {
        self.metrics.to_json(self.queue.len(), self.queue.capacity(), &self.tenants.snapshot())
    }

    /// Structured backpressure response. The retry hint scales with the
    /// backlog: mean observed solve latency × (queued + 1), clamped to
    /// [25 ms, 10 s]; 100 ms per queued item before any solve completed.
    fn overloaded_json(&self, reason: &str) -> Json {
        self.metrics.overloads.fetch_add(1, Ordering::Relaxed);
        let depth = self.queue.len();
        let per_item_ms = self.metrics.mean_solve_ms().unwrap_or(100.0);
        let retry = (per_item_ms * (depth as f64 + 1.0)).clamp(25.0, 10_000.0);
        let mut o = Json::obj();
        o.set("ok", false.into())
            .set("error", "overloaded".into())
            .set("reason", reason.into())
            .set("queue_depth", depth.into())
            .set("retry_after_ms", retry.into());
        o
    }
}

enum Flow {
    Respond(Json),
    Quit,
}

/// Route one framed request line: resolve tenancy, then either answer
/// inline (`metrics`, `stats`, errors) or go through solve admission.
fn serve_line(req: &str, default_session: &Arc<SessionCache>, ctx: &ServeCtx) -> Flow {
    let (tenant, plain) = match split_tenant(req) {
        Ok(split) => split,
        Err(e) => return Flow::Respond(service::err_json(&e)),
    };
    let (session, store) = match tenant {
        Some(name) => match ctx.tenants.warm(name) {
            Ok(pair) => pair,
            Err(e) => return Flow::Respond(service::err_json(&e)),
        },
        None => (Arc::clone(default_session), ctx.anon_store.clone()),
    };
    match plain.split_whitespace().next().unwrap_or("") {
        // The metrics surface lives above the pure line protocol.
        "metrics" => Flow::Respond(ctx.metrics_json()),
        // Solves are the only expensive requests: they alone pass through
        // admission control.
        "schedule" => {
            let (tx, rx) = mpsc::channel();
            let deadline_ms = scan_deadline_ms(&plain);
            let req = SolveRequest {
                line: plain,
                session,
                store,
                resp: tx,
                admitted: Timer::start(),
                deadline_ms,
            };
            match ctx.queue.try_push(req) {
                Ok(()) => match rx.recv() {
                    Ok(resp) => Flow::Respond(resp),
                    // Workers only drop a pending sender at shutdown.
                    Err(_) => Flow::Respond(service::err_json("service shutting down")),
                },
                Err(_) if ctx.stop.load(Ordering::Relaxed) || ctx.queue.is_closed() => {
                    Flow::Respond(service::err_json("service shutting down"))
                }
                Err(_) => Flow::Respond(ctx.overloaded_json("solve queue full")),
            }
        }
        // Everything else (stats, quit, malformed lines) is cheap: answer
        // inline so error reporting and cache observability survive a
        // saturated solve queue.
        _ => {
            let t = Timer::start();
            match service::handle_line_store(&ctx.arch, &session, store.as_deref(), &plain) {
                Some(resp) => {
                    ctx.metrics.record_response(&resp, t.elapsed_s());
                    Flow::Respond(resp)
                }
                None => Flow::Quit,
            }
        }
    }
}

/// Drain the solve queue until it closes. `handle_line` already maps
/// malformed requests and solver failures to structured errors; the
/// `catch_unwind` is the last line of defense so a latent panic costs one
/// response, never the worker (acceptance: never a hang or panic).
fn worker_loop(ctx: &ServeCtx) {
    while let Some(req) = ctx.queue.pop() {
        let t = Timer::start();
        // Deadline already expired while queued: answer the structured
        // deadline error immediately (same Display prefix as the engine's
        // no-incumbent `SolveError::Deadline`, so metrics key both) and
        // move on to work that can still meet its budget.
        if let Some(ms) = req.deadline_ms {
            let waited_ms = req.admitted.elapsed_s() * 1e3;
            if waited_ms >= ms as f64 {
                let resp = service::err_json(&format!(
                    "deadline exceeded after {:.0} ms in the solve queue (budget {ms} ms)",
                    waited_ms
                ));
                ctx.metrics.record_response(&resp, t.elapsed_s());
                let _ = req.resp.send(resp);
                continue;
            }
        }
        let resp = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            service::handle_line_store(&ctx.arch, &req.session, req.store.as_deref(), &req.line)
        }))
        .unwrap_or_else(|payload| {
            let msg = payload
                .downcast_ref::<&'static str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".to_string());
            Some(service::err_json(&format!("internal error: {msg}")))
        })
        // `quit` never reaches the queue; guard anyway.
        .unwrap_or_else(|| service::err_json("quit is a connection-level request"));
        ctx.metrics.record_response(&resp, t.elapsed_s());
        // The connection may have vanished while the solve ran.
        let _ = req.resp.send(resp);
    }
}

/// Either transport's accepted stream, unified so the connection loop is
/// written once.
enum Stream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(std::os::unix::net::UnixStream),
}

impl Stream {
    fn configure(&self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => {
                s.set_nonblocking(false)?;
                s.set_read_timeout(Some(READ_POLL))?;
                s.set_write_timeout(Some(Duration::from_secs(10)))
            }
            #[cfg(unix)]
            Stream::Unix(s) => {
                s.set_nonblocking(false)?;
                s.set_read_timeout(Some(READ_POLL))?;
                s.set_write_timeout(Some(Duration::from_secs(10)))
            }
        }
    }
}

// `TcpStream`/`UnixStream` implement `Read`/`Write` on shared references,
// so one connection thread can hold a `BufReader` over the stream while
// writing responses through a second shared borrow.
impl Read for &Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => {
                let mut s: &TcpStream = s;
                s.read(buf)
            }
            #[cfg(unix)]
            Stream::Unix(s) => {
                let mut s: &std::os::unix::net::UnixStream = s;
                s.read(buf)
            }
        }
    }
}

impl Write for &Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => {
                let mut s: &TcpStream = s;
                s.write(buf)
            }
            #[cfg(unix)]
            Stream::Unix(s) => {
                let mut s: &std::os::unix::net::UnixStream = s;
                s.write(buf)
            }
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => {
                let mut s: &TcpStream = s;
                s.flush()
            }
            #[cfg(unix)]
            Stream::Unix(s) => {
                let mut s: &std::os::unix::net::UnixStream = s;
                s.flush()
            }
        }
    }
}

fn write_response(mut w: impl Write, resp: &Json) -> std::io::Result<()> {
    let mut line = resp.to_string_compact();
    line.push('\n');
    w.write_all(line.as_bytes())
}

/// Serve one connection: line framing with a read-timeout poll on the
/// stop flag, one anonymous session for `tenant=`-less requests.
fn handle_conn(stream: Stream, ctx: &ServeCtx) {
    if stream.configure().is_err() {
        return;
    }
    let default_session = Arc::new(SessionCache::new(ctx.cfg.budget));
    let mut reader = BufReader::new(&stream);
    let mut line = String::new();
    // When the last *complete* request line arrived. Resetting only on a
    // full line (not on every byte) is what makes the idle timeout a
    // slowloris defense: a client dribbling bytes without a newline ages
    // exactly like a silent one. Detection granularity is the read poll —
    // the check runs when `read_line` returns, so bytes arriving faster
    // than `READ_POLL` keep it from returning and evade the check; the
    // poll interval bounds how slow a dribble must be to get caught.
    let mut last_line = std::time::Instant::now();
    loop {
        if ctx.stop.load(Ordering::Relaxed) {
            break;
        }
        match reader.read_line(&mut line) {
            Ok(0) => break, // EOF (a final unterminated fragment is dropped)
            Ok(_) => {
                last_line = std::time::Instant::now();
                if line.len() > MAX_LINE_BYTES {
                    let _ = write_response(&stream, &service::err_json("request line too long"));
                    break;
                }
                let req = line.trim().to_string();
                line.clear();
                match serve_line(&req, &default_session, ctx) {
                    Flow::Respond(resp) => {
                        if write_response(&stream, &resp).is_err() {
                            break;
                        }
                        // A solve may legitimately outlast the idle limit;
                        // the clock measures client silence, so it restarts
                        // once the response is on the wire.
                        last_line = std::time::Instant::now();
                    }
                    Flow::Quit => break,
                }
            }
            // Timeout while idle (or mid-line — the partial stays buffered
            // in `line`): age the connection, then re-check the stop flag
            // and keep reading.
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) =>
            {
                if line.len() > MAX_LINE_BYTES {
                    let _ = write_response(&stream, &service::err_json("request line too long"));
                    break;
                }
                if let Some(limit) = ctx.cfg.idle_timeout {
                    if last_line.elapsed() >= limit {
                        // Structured close: tell the client why before
                        // dropping the connection.
                        let _ = write_response(
                            &stream,
                            &service::err_json(&format!(
                                "idle timeout: no complete request in {:.0} s, closing connection",
                                limit.as_secs_f64()
                            )),
                        );
                        break;
                    }
                }
            }
            Err(_) => break,
        }
    }
}

/// Either transport's listener.
pub enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(std::os::unix::net::UnixListener),
}

impl Listener {
    fn set_nonblocking(&self) -> std::io::Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(true),
            #[cfg(unix)]
            Listener::Unix(l) => l.set_nonblocking(true),
        }
    }

    fn accept_stream(&self) -> std::io::Result<Stream> {
        match self {
            Listener::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s)),
            #[cfg(unix)]
            Listener::Unix(l) => l.accept().map(|(s, _)| Stream::Unix(s)),
        }
    }
}

/// Bind a listen spec: `"host:port"` for TCP (port 0 picks a free port —
/// see [`ServiceHandle::tcp_addr`]) or `"unix:/path/to.sock"`.
pub fn bind(spec: &str) -> std::io::Result<Listener> {
    match spec.strip_prefix("unix:") {
        Some(path) => {
            #[cfg(unix)]
            {
                // A stale socket file from a dead process refuses to bind.
                let _ = std::fs::remove_file(path);
                std::os::unix::net::UnixListener::bind(path).map(Listener::Unix)
            }
            #[cfg(not(unix))]
            {
                let _ = path;
                Err(std::io::Error::new(
                    std::io::ErrorKind::Unsupported,
                    "unix sockets are not available on this platform",
                ))
            }
        }
        None => TcpListener::bind(spec).map(Listener::Tcp),
    }
}

fn accept_loop<'scope>(
    listener: &Listener,
    ctx: &'scope ServeCtx,
    scope: &'scope std::thread::Scope<'scope, '_>,
) {
    if listener.set_nonblocking().is_err() {
        return;
    }
    while !ctx.stop.load(Ordering::Relaxed) {
        match listener.accept_stream() {
            Ok(stream) => {
                ctx.metrics.connections_accepted.fetch_add(1, Ordering::Relaxed);
                let active = ctx.metrics.connections_active.fetch_add(1, Ordering::Relaxed) + 1;
                if active as usize > ctx.cfg.max_connections {
                    // Connection-level admission control: answer with the
                    // structured overload, then close (drop).
                    if stream.configure().is_ok() {
                        let _ = write_response(&stream, &ctx.overloaded_json("connection limit"));
                    }
                    ctx.metrics.connections_active.fetch_sub(1, Ordering::Relaxed);
                    continue;
                }
                scope.spawn(move || {
                    handle_conn(stream, ctx);
                    ctx.metrics.connections_active.fetch_sub(1, Ordering::Relaxed);
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            // Transient accept errors (e.g. a client resetting mid-
            // handshake) must not kill the listener.
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
}

fn metrics_ticker(ctx: &ServeCtx, interval: Duration) {
    let mut elapsed = Duration::ZERO;
    while !ctx.stop.load(Ordering::Relaxed) {
        std::thread::sleep(ACCEPT_POLL);
        elapsed += ACCEPT_POLL;
        if elapsed >= interval {
            elapsed = Duration::ZERO;
            eprintln!("kapla metrics {}", ctx.metrics_json().to_string_compact());
        }
    }
}

/// Serve until `stop` is set: workers, listeners, connections and the
/// optional metrics ticker all run as scoped threads, so this returns
/// only after every admitted request has been answered.
pub fn run(arch: &ArchConfig, cfg: ServiceConfig, listeners: Vec<Listener>, stop: Arc<AtomicBool>) {
    let queue_depth = cfg.queue_depth.max(1);
    let workers = cfg.workers.max(1);
    let tenants = match &cfg.cache_dir {
        Some(dir) => TenantRegistry::persistent(
            cfg.budget,
            cfg.max_tenants.max(1),
            dir.clone(),
            arch.clone(),
        ),
        None => TenantRegistry::new(cfg.budget, cfg.max_tenants.max(1)),
    };
    let anon_store = cfg
        .cache_dir
        .as_ref()
        .and_then(|dir| ScheduleStore::open(&dir.join("anon").join("store")).ok().map(Arc::new));
    let ctx = ServeCtx {
        arch: arch.clone(),
        tenants,
        anon_store,
        queue: BoundedQueue::new(queue_depth),
        metrics: Metrics::new(),
        stop,
        cfg,
    };
    let ctx = &ctx;
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(move || worker_loop(ctx));
        }
        if let Some(interval) = ctx.cfg.metrics_interval {
            scope.spawn(move || metrics_ticker(ctx, interval));
        }
        for listener in &listeners {
            scope.spawn(move || accept_loop(listener, ctx, scope));
        }
        // Shutdown sequencing: once the stop flag is set, give connection
        // threads one read-poll to observe it (they stop submitting), then
        // close the queue — workers drain the admitted backlog and exit.
        scope.spawn(move || {
            while !ctx.stop.load(Ordering::Relaxed) {
                std::thread::sleep(ACCEPT_POLL);
            }
            std::thread::sleep(READ_POLL + READ_POLL);
            ctx.queue.close();
        });
    });
    // Every worker and connection has exited: persist the tenants' session
    // snapshots so the next process starts warm. (Schedule stores write
    // through on every solve and need no flush; a kill before this point
    // loses at most the in-memory evaluation memos, never store integrity —
    // all disk writes are temp-file+rename.)
    ctx.tenants.save_all();
}

/// A service running in background threads; the handle is how tests and
/// the CLI stop it (or block on it).
pub struct ServiceHandle {
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
    tcp_addr: Option<SocketAddr>,
    label: String,
}

impl ServiceHandle {
    /// The bound TCP address — the real port when the spec asked for :0.
    pub fn tcp_addr(&self) -> Option<SocketAddr> {
        self.tcp_addr
    }

    pub fn label(&self) -> &str {
        &self.label
    }

    /// Block until the service exits (the CLI serve path).
    pub fn join(mut self) {
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }

    /// Signal stop and wait for every in-flight request to be answered.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for ServiceHandle {
    fn drop(&mut self) {
        // Leaked handles (test early-exit paths) still stop the threads;
        // no join here, so dropping never blocks.
        self.stop.store(true, Ordering::Relaxed);
    }
}

/// Bind `spec` synchronously (so the caller sees bind errors and the
/// ephemeral port), then serve it on background threads.
pub fn spawn(arch: &ArchConfig, cfg: ServiceConfig, spec: &str) -> std::io::Result<ServiceHandle> {
    let listener = bind(spec)?;
    let tcp_addr = match &listener {
        Listener::Tcp(l) => Some(l.local_addr()?),
        #[cfg(unix)]
        Listener::Unix(_) => None,
    };
    let label = match tcp_addr {
        Some(addr) => addr.to_string(),
        None => spec.to_string(),
    };
    let stop = Arc::new(AtomicBool::new(false));
    let thread_stop = Arc::clone(&stop);
    let arch = arch.clone();
    let join = std::thread::Builder::new()
        .name("kapla-service".to_string())
        .spawn(move || run(&arch, cfg, vec![listener], thread_stop))?;
    Ok(ServiceHandle { stop, join: Some(join), tcp_addr, label })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tenant_knob_splits_out_of_the_line() {
        let (t, rest) = split_tenant("schedule mlp 8 kapla tenant=acme threads=1").unwrap();
        assert_eq!(t, Some("acme"));
        assert_eq!(rest, "schedule mlp 8 kapla threads=1");

        let (t, rest) = split_tenant("stats").unwrap();
        assert_eq!(t, None);
        assert_eq!(rest, "stats");

        // A ':' marks a solver spec, not a tenant knob — leave it in place
        // for handle_line to reject.
        let (t, rest) = split_tenant("schedule mlp tenant=a:b").unwrap();
        assert_eq!(t, None);
        assert_eq!(rest, "schedule mlp tenant=a:b");

        assert!(split_tenant("stats tenant=a tenant=b").is_err());
    }

    #[test]
    fn deadline_scan_is_tolerant() {
        assert_eq!(scan_deadline_ms("schedule mlp 8 kapla deadline_ms=250"), Some(250));
        assert_eq!(scan_deadline_ms("schedule mlp deadline_ms=1 threads=2"), Some(1));
        // Malformed or zero values are left for handle_line to reject.
        assert_eq!(scan_deadline_ms("schedule mlp deadline_ms=soon"), None);
        assert_eq!(scan_deadline_ms("schedule mlp deadline_ms=0"), None);
        // ':'-bearing tokens are solver specs, never knobs.
        assert_eq!(scan_deadline_ms("schedule mlp custom:deadline_ms=9"), None);
        assert_eq!(scan_deadline_ms("schedule mlp 8 kapla"), None);
    }

    #[test]
    fn tenant_registry_validates_and_caps() {
        let reg = TenantRegistry::new(CacheBudget::entries(64), 2);
        assert!(reg.session("alpha").is_ok());
        // Same name returns the same session (no double-create).
        assert!(reg.session("alpha").is_ok());
        assert!(reg.session("beta-2.x").is_ok());
        assert_eq!(reg.len(), 2);
        let err = reg.session("gamma").unwrap_err();
        assert!(err.contains("tenant limit"), "{err}");
        for bad in ["", "has space", "semi;colon", "sl/ash", &"x".repeat(65)] {
            let err = reg.session(bad).unwrap_err();
            assert!(err.contains("bad tenant name"), "{bad:?}: {err}");
        }
        // Rejections must not consume namespace slots.
        assert_eq!(reg.len(), 2);
        let names: Vec<String> = reg.snapshot().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, ["alpha", "beta-2.x"]);
        // Path components: tenant names become directories under a
        // cache_dir, so the dot traversals are rejected outright.
        assert!(!TenantRegistry::valid_name("."));
        assert!(!TenantRegistry::valid_name(".."));
    }

    #[test]
    fn persistent_registry_restores_tenant_sessions() {
        use crate::arch::presets;
        use crate::cost::EvalCache as _;
        use crate::coordinator::{run_job_persistent, Job};
        use crate::interlayer::dp::DpConfig;
        use crate::solvers::{Objective, SolverKind};

        let dir =
            std::env::temp_dir().join(format!("kapla-transport-warm-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let arch = presets::bench_multi_node();
        let job = Job {
            net: crate::workloads::nets::mlp(),
            batch: 4,
            objective: Objective::Energy,
            solver: SolverKind::Kapla,
            dp: DpConfig { max_rounds: 8, ..DpConfig::default() },
            deadline_ms: None,
        };

        let reg =
            TenantRegistry::persistent(CacheBudget::entries(65536), 4, dir.clone(), arch.clone());
        let (session, store) = reg.warm("acme").unwrap();
        let store = store.expect("persistent registry must open a tenant store");
        let cold = run_job_persistent(&arch, &job, &*session, Some(&*store)).unwrap();
        assert!(session.stats().entries > 0, "cold solve must populate the session");
        reg.save_all();

        // "Restart": a second registry instance over the same directory.
        let reg2 =
            TenantRegistry::persistent(CacheBudget::entries(65536), 4, dir.clone(), arch.clone());
        let (s2, st2) = reg2.warm("acme").unwrap();
        assert!(s2.stats().entries > 0, "snapshot must restore the evaluation memo");
        assert_eq!(s2.stats().load_skipped, 0, "clean snapshot loads without skips");
        let warm = run_job_persistent(&arch, &job, &*s2, st2.as_deref()).unwrap();
        assert!(warm.cache.store_hits > 0, "restarted tenant must hit the schedule store");
        assert_eq!(format!("{:?}", warm.schedule), format!("{:?}", cold.schedule));
        // Isolation: a different tenant starts cold (its own directory).
        let (other, _) = reg2.warm("zeta").unwrap();
        assert_eq!(other.stats().entries, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
