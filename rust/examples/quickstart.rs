//! Quickstart: schedule a single CONV layer on the edge accelerator with
//! KAPLA and print the resulting tensor-centric directive program plus its
//! energy/latency evaluation.
//!
//! Run: `cargo run --release --example quickstart`

use kapla::arch::presets;
use kapla::directives::emit::emit_layer;
use kapla::sim::evaluate_layer;
use kapla::solvers::kapla::solve_intra;
use kapla::solvers::{IntraCtx, Objective};
use kapla::workloads::Layer;

fn main() {
    // A mid-sized CONV layer (ResNet conv3_x shape).
    let layer = Layer::conv("conv3a", 128, 256, 28, 3, 1);
    let arch = presets::edge_tpu();
    println!("arch: {} ({}x{} PEs, {:?})", arch.name, arch.pes.0, arch.pes.1, arch.pe_dataflow);
    println!("layer: {} C={} K={} {}x{} R={}", layer.name, layer.c, layer.k, layer.xo, layer.yo, layer.r);

    let ctx = IntraCtx {
        region: (1, 1),
        rb: 1, // batch-1 edge inference
        ifm_on_chip: false,
        objective: Objective::Energy,
    };
    let scheme = solve_intra(&arch, &layer, &ctx).expect("no valid scheme");
    scheme.validate(&arch).expect("solver must return valid schemes");

    println!("\n--- tensor-centric directives (paper Listing 1 format) ---");
    println!("{}", emit_layer(&layer.name, &scheme));

    let ev = evaluate_layer(&arch, &scheme, false);
    println!("--- evaluation ---");
    println!("energy: {:.3} uJ", ev.energy.total() / 1e6);
    println!(
        "  alu {:.1}% | regf {:.1}% | gbuf {:.1}% | dram {:.1}%",
        100.0 * ev.energy.alu_pj / ev.energy.total(),
        100.0 * ev.energy.regf_pj / ev.energy.total(),
        100.0 * ev.energy.gbuf_pj / ev.energy.total(),
        100.0 * ev.energy.dram_pj / ev.energy.total(),
    );
    println!("latency: {:.0} cycles ({:.3} ms @500MHz)", ev.latency_cycles, ev.latency_cycles / 500e3);
    println!(
        "DRAM traffic: ifm {} + ofm {} + wgt {} words",
        ev.access.dram[0], ev.access.dram[1], ev.access.dram[2]
    );
}
