//! End-to-end driver (the repository's full-system validation run,
//! recorded in EXPERIMENTS.md): schedule *training* of a real CNN on the
//! scaled multi-node accelerator with all solver families, and reproduce
//! the paper's headline metrics — KAPLA within a few percent of the
//! exhaustively-searched optimum at orders-of-magnitude lower scheduling
//! time (paper Fig. 7 + Table IV shape).
//!
//! The run exercises every layer of the stack: workload -> training-graph
//! extension -> inter-layer DP (with conservative pruning) -> bottom-up
//! intra-layer solving -> directive access calculus -> detailed simulator;
//! the ML baseline additionally trains its cost surrogate online through
//! the AOT JAX/Pallas artifacts over PJRT when `artifacts/` is present.
//!
//! Run: `cargo run --release --example e2e_training`
//! (KAPLA_E2E_NET=alexnet|mlp|... and KAPLA_E2E_BATCH to vary.)

use kapla::arch::presets;
use kapla::coordinator::{run_job, Job, SolverKind};
use kapla::interlayer::dp::DpConfig;
use kapla::report::{eng, Table};
use kapla::solvers::Objective;
use kapla::util::stats::fmt_duration;
use kapla::workloads::{by_name, training_graph};

fn main() {
    let net_name = std::env::var("KAPLA_E2E_NET").unwrap_or_else(|_| "alexnet".into());
    let batch: u64 =
        std::env::var("KAPLA_E2E_BATCH").ok().and_then(|s| s.parse().ok()).unwrap_or(16);
    let arch = presets::bench_multi_node();
    let fwd = by_name(&net_name).expect("unknown network");
    let net = training_graph(&fwd);
    println!(
        "end-to-end: {} training graph ({} layers, {} fwd) batch={batch} on {}",
        net.name,
        net.len(),
        fwd.len(),
        arch.name
    );

    let dp = DpConfig { max_rounds: 16, ..DpConfig::default() };
    let solvers = [
        SolverKind::Baseline,
        SolverKind::Kapla,
        SolverKind::Random { p: 0.1, seed: 42 },
        SolverKind::Ml { seed: 42, rounds: 8, batch: 32 },
    ];

    let mut rows = Vec::new();
    let mut base_energy = None;
    let mut base_time = None;
    for solver in solvers {
        println!("running {} ...", solver.letter());
        let job =
            Job { net: net.clone(), batch, objective: Objective::Energy, solver, dp, deadline_ms: None };
        let r = run_job(&arch, &job).expect("schedulable");
        let e = r.eval.energy.total();
        if solver == SolverKind::Baseline {
            base_energy = Some(e);
            base_time = Some(r.solve_s);
        }
        rows.push((solver.letter(), e, r.eval.latency_cycles, r.solve_s));
    }

    let be = base_energy.unwrap();
    let bt = base_time.unwrap();
    let mut t = Table::new(
        &format!("{} training, batch {batch} (paper Fig.7 + Table IV shape)", net.name),
        &["solver", "energy", "vs B", "latency", "solve time", "speedup vs B"],
    );
    for (letter, e, lat, s) in &rows {
        t.row(vec![
            letter.to_string(),
            eng(*e, "pJ"),
            format!("{:.3}x", e / be),
            eng(*lat, "cy"),
            fmt_duration(*s),
            format!("{:.0}x", bt / s.max(1e-9)),
        ]);
    }
    println!("\n{}", t.save_and_render("e2e_training"));

    // Headline checks (paper: K within ~2.2% of B for training; R/M
    // worse). K may come in slightly *below* B because the directive
    // space B does not cover (buffer sharing, partial-region partitions)
    // is available to K — the paper observes the same for solver S.
    let k = rows.iter().find(|r| r.0 == "K").unwrap();
    println!(
        "KAPLA overhead vs exhaustive: {:+.2}% | speedup {:.0}x",
        (k.1 / be - 1.0) * 100.0,
        bt / k.3
    );
    assert!(
        (0.75..=1.25).contains(&(k.1 / be)),
        "KAPLA energy out of expected band: {:.3}x of B",
        k.1 / be
    );
    assert!(k.3 < bt, "KAPLA must be faster than exhaustive");
    println!("e2e training driver: OK");
}
