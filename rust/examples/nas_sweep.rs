//! NAS-style scheduling sweep (paper §II-C motivation: "network
//! architecture search explores a large number of NN structure candidates;
//! many layers must be re-scheduled due to different topologies and/or
//! layer dimensions").
//!
//! Generates 16 width/depth variants of a ResNet-ish backbone and
//! schedules each with KAPLA, showing per-variant energy/latency — the
//! interactive-compilation workload that motivates a fast solver.
//!
//! Run: `cargo run --release --example nas_sweep`

use kapla::arch::presets;
use kapla::coordinator::{run_jobs, Job, SolverKind};
use kapla::interlayer::dp::DpConfig;
use kapla::report::{eng, Table};
use kapla::solvers::Objective;
use kapla::util::Timer;
use kapla::workloads::{Layer, Network};

/// A parameterized ResNet-ish candidate: `width` scales channels, `depth`
/// is the number of blocks per stage.
fn candidate(width: u64, depth: usize) -> Network {
    let name = format!("nas-w{width}-d{depth}");
    let mut n = Network::new(&name, 3, 64, 64);
    n.chain(Layer::conv("stem", 3, 8 * width, 32, 3, 2));
    let mut c = 8 * width;
    let mut xo = 32;
    for stage in 0..3 {
        let k = 8 * width << stage;
        for b in 0..depth {
            let stride = if b == 0 && stage > 0 { 2 } else { 1 };
            if b == 0 && stage > 0 {
                xo /= 2;
            }
            n.chain(Layer::conv(&format!("s{stage}b{b}"), c, k, xo, 3, stride));
            c = k;
        }
    }
    n.chain(Layer::pool("gap", c, 1, xo, xo));
    n.chain(Layer::fc("head", c, 100));
    n
}

fn main() {
    let arch = presets::bench_multi_node();
    let variants: Vec<Network> = (1..=4)
        .flat_map(|w| (1..=4).map(move |d| candidate(w, d)))
        .collect();
    println!("scheduling {} NAS candidates on {} ...", variants.len(), arch.name);

    let jobs: Vec<Job> = variants
        .iter()
        .map(|net| Job {
            net: net.clone(),
            batch: 8,
            objective: Objective::Latency,
            solver: SolverKind::Kapla,
            dp: DpConfig { max_rounds: 8, ..DpConfig::default() },
            deadline_ms: None,
        })
        .collect();

    let t = Timer::start();
    let results: Vec<_> = run_jobs(&arch, &jobs, kapla::coordinator::default_threads())
        .into_iter()
        .map(|r| r.expect("candidate schedulable"))
        .collect();
    let wall = t.elapsed_s();

    let mut table = Table::new(
        "NAS sweep: per-candidate schedule quality",
        &["candidate", "layers", "MACs", "energy", "latency (ms)"],
    );
    let mut best: Option<(f64, &str)> = None;
    for (net, r) in variants.iter().zip(&results) {
        let lat = r.eval.latency_s(&arch) * 1e3;
        if best.map(|(b, _)| lat < b).unwrap_or(true) {
            best = Some((lat, &net.name));
        }
        table.row(vec![
            net.name.clone(),
            net.len().to_string(),
            eng(net.total_macs(8) as f64, ""),
            eng(r.eval.energy.total(), "pJ"),
            format!("{lat:.3}"),
        ]);
    }
    println!("{}", table.save_and_render("nas_sweep"));
    let (blat, bname) = best.unwrap();
    println!(
        "{} candidates scheduled in {wall:.1} s wall ({:.2} s/candidate) — fastest: {bname} ({blat:.3} ms)",
        variants.len(),
        wall / variants.len() as f64
    );
}
