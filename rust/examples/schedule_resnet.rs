//! Schedule full ResNet-50 inference on the paper's 16x16-node Eyeriss-like
//! accelerator with KAPLA, and report the segment chain, energy breakdown
//! and scheduling speed — the paper's flagship "complex NN on a scalable
//! accelerator, solved in seconds" scenario.
//!
//! Run: `cargo run --release --example schedule_resnet`

use kapla::arch::presets;
use kapla::report::eng;
use kapla::solvers::{SolveCtx, SolverKind};
use kapla::util::Timer;
use kapla::workloads::nets;

fn main() {
    let arch = presets::multi_node_eyeriss();
    let net = nets::resnet();
    let batch = 64;
    println!("scheduling {} ({} layers) batch={batch} on {}", net.name, net.len(), arch.name);

    let t = Timer::start();
    let result =
        SolveCtx::new(&arch).run(&net, batch, SolverKind::Kapla).expect("resnet schedules");
    let stats = result.prune.expect("the KAPLA path reports pruning stats");
    println!("\nKAPLA solved in {:.1} s", t.elapsed_s());
    println!(
        "inter-layer pruning: {} candidate schemes -> {} after validity -> {} after Pareto ({:.1}% pruned)",
        stats.total,
        stats.after_validity,
        stats.after_pareto,
        100.0 * (1.0 - stats.after_pareto as f64 / stats.total.max(1) as f64)
    );

    let ev = &result.eval;
    println!("\nenergy  : {}", eng(ev.energy.total(), "pJ"));
    println!("latency : {} cycles = {:.2} ms", eng(ev.latency_cycles, ""), ev.latency_s(&arch) * 1e3);
    let b = &ev.energy;
    for (name, v) in [
        ("alu", b.alu_pj),
        ("regf", b.regf_pj),
        ("bus", b.bus_pj),
        ("gbuf", b.gbuf_pj),
        ("noc", b.noc_pj),
        ("dram", b.dram_pj),
    ] {
        println!("  {name:5} {:>12} ({:.1}%)", eng(v, "pJ"), 100.0 * v / b.total());
    }

    println!("\nsegment chain ({} segments):", result.schedule.segments.len());
    let mut pipelined = 0;
    for (si, (seg, _)) in result.schedule.segments.iter().enumerate() {
        let names: Vec<&str> = seg.layers.iter().map(|&i| net.layers[i].name.as_str()).collect();
        if seg.spatial {
            pipelined += 1;
        }
        if si < 12 || seg.spatial {
            println!(
                "  {si:>3}: {:<44} {} rounds={}",
                names.join("+"),
                if seg.spatial { "pipelined " } else { "time-shared" },
                seg.rounds
            );
        } else if si == 12 {
            println!("  ... ({} more)", result.schedule.segments.len() - 12);
        }
    }
    println!("\n{pipelined} pipelined segments in the chain");
}
