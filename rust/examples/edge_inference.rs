//! Batch-1 inference on the small TPU-like edge device across the whole
//! network zoo (the paper's Fig. 10 scenario), demonstrating KAPLA's
//! generality across PE-array dataflows (row-stationary vs systolic).
//!
//! Run: `cargo run --release --example edge_inference`

use kapla::arch::presets;
use kapla::coordinator::{run_job, Job, SolverKind};
use kapla::interlayer::dp::DpConfig;
use kapla::report::{eng, Table};
use kapla::solvers::Objective;
use kapla::util::stats::fmt_duration;
use kapla::workloads::all_networks;

fn main() {
    let arch = presets::edge_tpu();
    println!("edge device: {} ({:?} array, {} kB GBUF)", arch.name, arch.pe_dataflow, arch.gbuf.bytes / 1024);

    let mut t = Table::new(
        "batch-1 edge inference (paper Fig. 10 scenario)",
        &["network", "energy", "latency (ms)", "solve time"],
    );
    for net in all_networks() {
        let job = Job {
            net: net.clone(),
            batch: 1,
            objective: Objective::Energy,
            solver: SolverKind::Kapla,
            dp: DpConfig::default(),
            deadline_ms: None,
        };
        let r = run_job(&arch, &job).expect("schedulable");
        t.row(vec![
            net.name.clone(),
            eng(r.eval.energy.total(), "pJ"),
            format!("{:.3}", r.eval.latency_s(&arch) * 1e3),
            fmt_duration(r.solve_s),
        ]);
    }
    println!("{}", t.save_and_render("edge_inference"));
}
