//! Property battery for the staged inter-layer planner: chain-level
//! branch-and-bound and the cross-job intra-argmin memo are
//! *optimizations*, never semantic changes. This file pins
//!
//! 1. `best_chains` (lazy + bound-pruned) against a verbatim reference
//!    copy of the pre-refactor eager pipeline (materialize every span's
//!    schemes, `prune_and_rank`, stable sort-and-truncate DP) — chains
//!    byte-identical, on two nets;
//! 2. every solver's *final schedule* byte-identical across pruned/full
//!    planning, partition-floor on/off, cold/warm sessions (the argmin
//!    memo replaying scans), and 1-vs-4 worker threads (the speculative
//!    span pipeline), on two nets x both objectives;
//! 3. the acceptance counters: nonzero span-level prune counters and
//!    nonzero warm-session memo hits on a zoo net.

use kapla::arch::presets;
use kapla::coordinator::{run_job, run_job_with, Job, SolverKind};
use kapla::cost::{CostModel, SessionCache, TieredCost};
use kapla::interlayer::dp::{best_chains, DpConfig};
use kapla::interlayer::planner::Planner;
use kapla::interlayer::prune::prune_and_rank;
use kapla::interlayer::{candidate_spans, enumerate_segment_schemes, Segment};
use kapla::solvers::Objective;
use kapla::workloads::{nets, Layer, Network};

// ---------------------------------------------------------------------------
// Reference: the pre-refactor eager inter-layer DP, kept verbatim (modulo
// the NaN-safe comparator) so the staged planner has a frozen behavioral
// oracle that does not share code with it.

struct RefNode {
    cost: f64,
    seg: Segment,
    parent: Option<(usize, usize)>,
}

fn reference_best_chains(
    arch: &kapla::arch::ArchConfig,
    net: &Network,
    batch: u64,
    cfg: &DpConfig,
    model: &dyn CostModel,
) -> Vec<(f64, Vec<Segment>)> {
    let n = net.len();
    let mut table: Vec<Vec<RefNode>> = Vec::with_capacity(n);
    for i in 0..n {
        let mut cands: Vec<RefNode> = Vec::new();
        for span in candidate_spans(i, cfg.max_seg_len) {
            let start = span[0];
            let schemes = enumerate_segment_schemes(net, arch, batch, &span, cfg.max_rounds);
            let (mut ranked, _) = prune_and_rank(arch, net, batch, schemes, model);
            ranked.truncate(cfg.top_per_span);
            for r in ranked {
                if start == 0 {
                    cands.push(RefNode { cost: r.est.score(), seg: r.seg, parent: None });
                } else {
                    for (rank, prev) in table[start - 1].iter().enumerate() {
                        cands.push(RefNode {
                            cost: r.est.score() + prev.cost,
                            seg: r.seg.clone(),
                            parent: Some((start - 1, rank)),
                        });
                    }
                }
            }
        }
        cands.sort_by(|a, b| a.cost.total_cmp(&b.cost));
        cands.truncate(cfg.ks.max(1));
        assert!(!cands.is_empty(), "reference: no chain ends at layer {i}");
        table.push(cands);
    }
    let last = n - 1;
    let mut out = Vec::new();
    for rank in 0..table[last].len() {
        let mut segments = Vec::new();
        let mut cur = Some((last, rank));
        while let Some((li, r)) = cur {
            segments.push(table[li][r].seg.clone());
            cur = table[li][r].parent;
        }
        segments.reverse();
        out.push((table[last][rank].cost, segments));
    }
    out
}

fn chains_snapshot(chains: &[(f64, Vec<Segment>)]) -> String {
    chains.iter().map(|(c, segs)| format!("{c:?} {segs:?}\n")).collect()
}

#[test]
fn planner_matches_the_reference_eager_pipeline() {
    let arch = presets::multi_node_eyeriss();
    let model = TieredCost::fresh();
    for net in [nets::mlp(), nets::alexnet()] {
        for cfg in [
            DpConfig::default(),
            DpConfig { ks: 1, top_per_span: 1, ..DpConfig::default() },
            DpConfig { max_seg_len: 3, max_rounds: 16, ..DpConfig::default() },
        ] {
            let want = reference_best_chains(&arch, &net, 64, &cfg, &model);
            let (got, stats) = best_chains(&arch, &net, 64, &cfg, &model).unwrap();
            let got: Vec<(f64, Vec<Segment>)> =
                got.into_iter().map(|c| (c.cost, c.segments)).collect();
            assert_eq!(
                chains_snapshot(&want),
                chains_snapshot(&got),
                "{} {cfg:?}: planner diverged from the eager reference",
                net.name
            );
            assert!(stats.spans_total > 0);

            // Full (bound off) mode matches too, and never prunes.
            let (full, fstats) = Planner::new(&arch, &net, 64, &cfg, &model)
                .bound_prune(false)
                .chains()
                .unwrap();
            let full: Vec<(f64, Vec<Segment>)> =
                full.into_iter().map(|c| (c.cost, c.segments)).collect();
            assert_eq!(chains_snapshot(&want), chains_snapshot(&full));
            assert_eq!(fstats.spans_pruned + fstats.schemes_bound_pruned, 0);
        }
    }
}

// ---------------------------------------------------------------------------
// Solver-level battery: schedules byte-identical across pruned/full
// planning, cold/warm sessions and thread counts.

fn tiny_net() -> Network {
    let mut n = Network::new("tiny", 8, 28, 28);
    n.chain(Layer::conv("c1", 8, 16, 28, 3, 1));
    n.chain(Layer::pool("p1", 16, 14, 2, 2));
    n.chain(Layer::conv("c2", 16, 32, 14, 3, 1));
    n.chain(Layer::fc("f1", 32 * 14 * 14, 64));
    n
}

fn snapshot(r: &kapla::solvers::SolveResult) -> String {
    format!(
        "{:?} {:?} {:?}",
        r.eval.energy.total(),
        r.eval.latency_cycles,
        r.schedule
    )
}

#[test]
fn schedules_identical_across_memo_threads_and_sessions() {
    let arch = presets::bench_multi_node();
    for net in [nets::mlp(), tiny_net()] {
        for objective in [Objective::Energy, Objective::Latency] {
            for solver in [SolverKind::Kapla, SolverKind::Baseline] {
                let job = |threads: usize, part_floor: bool| Job {
                    net: net.clone(),
                    batch: 4,
                    objective,
                    solver,
                    dp: DpConfig {
                        max_rounds: 4,
                        max_seg_len: 3,
                        solve_threads: threads,
                        part_floor,
                        ..DpConfig::default()
                    },
                    deadline_ms: None,
                };
                let tag = format!("{}/{objective:?}/{}", net.name, solver.letter());
                // Cold solitary run: the golden reference.
                let cold = run_job(&arch, &job(1, true)).unwrap();
                // 1-vs-4 worker threads (4 threads exercises the planner's
                // speculative span pipeline, on by default).
                let par = run_job(&arch, &job(4, true)).unwrap();
                assert_eq!(snapshot(&cold), snapshot(&par), "{tag}: threads diverged");
                // Partition-level floor off, at both thread counts: the
                // floor is exact, so schedules must not move.
                for threads in [1usize, 4] {
                    let off = run_job(&arch, &job(threads, false)).unwrap();
                    assert_eq!(
                        snapshot(&cold),
                        snapshot(&off),
                        "{tag}: part_floor=off diverged at {threads} threads"
                    );
                    if let Some(bnb) = &off.bnb {
                        assert!(!bnb.part_floor, "{tag}: off-run must report the flag off");
                        assert_eq!(bnb.parts_pruned, 0, "{tag}: disabled floor still pruned");
                    }
                }
                if solver == SolverKind::Baseline {
                    let bnb = cold.bnb.as_ref().expect("exhaustive runs report bnb");
                    assert!(bnb.part_floor, "{tag}: default must report the flag on");
                    assert!(bnb.parts_visited > 0, "{tag}: scan visited no partitions");
                }
                // Cold session, then a warm repeat replaying the recorded
                // argmins.
                let session = SessionCache::unbounded();
                let s1 = run_job_with(&arch, &job(1, true), &session).unwrap();
                let s2 = run_job_with(&arch, &job(1, true), &session).unwrap();
                assert_eq!(snapshot(&cold), snapshot(&s1), "{tag}: session diverged");
                assert_eq!(snapshot(&cold), snapshot(&s2), "{tag}: warm session diverged");
                assert!(
                    s2.cache.intra_hits > s1.cache.intra_hits,
                    "{tag}: warm run must replay recorded argmins"
                );
                assert_eq!(
                    s2.cache.lookups, s1.cache.lookups,
                    "{tag}: warm run must not re-run any scan"
                );
            }
        }
    }
}

#[test]
fn span_prune_counters_fire_on_a_zoo_net() {
    // Acceptance: `SolveResult` reports nonzero span-level prune counters
    // for at least one zoo net. k_S = 1 gives the tightest incumbent, so
    // the chain-level bound provably has something to cut on AlexNet's
    // pipelined spans.
    let arch = presets::multi_node_eyeriss();
    let job = Job {
        net: nets::alexnet(),
        batch: 64,
        objective: Objective::Energy,
        solver: SolverKind::Kapla,
        dp: DpConfig { ks: 1, top_per_span: 1, ..DpConfig::default() },
        deadline_ms: None,
    };
    let r = run_job(&arch, &job).unwrap();
    let prune = r.prune.expect("kapla path reports planner stats");
    assert!(prune.spans_total > 0);
    assert!(
        prune.spans_pruned + prune.schemes_bound_pruned > 0,
        "expected span-level pruning on alexnet with k_S=1: {prune:?}"
    );
    // ... and pruning never changed the result vs the unpruned planner.
    let model = TieredCost::fresh();
    let full = Planner::new(&arch, &job.net, 64, &job.dp, &model)
        .bound_prune(false)
        .chains()
        .unwrap()
        .0;
    let pruned = best_chains(&arch, &job.net, 64, &job.dp, &model).unwrap().0;
    assert_eq!(
        format!("{:?}", full.iter().map(|c| (c.cost, &c.segments)).collect::<Vec<_>>()),
        format!("{:?}", pruned.iter().map(|c| (c.cost, &c.segments)).collect::<Vec<_>>()),
    );
}

#[test]
fn warm_session_reports_memo_hits_on_a_zoo_net() {
    // Acceptance: memo hits on warm sessions for at least one zoo net.
    let arch = presets::bench_multi_node();
    let job = Job {
        net: nets::mlp(),
        batch: 8,
        objective: Objective::Energy,
        solver: SolverKind::Kapla,
        dp: DpConfig { max_rounds: 8, ..DpConfig::default() },
        deadline_ms: None,
    };
    let session = SessionCache::unbounded();
    let cold = run_job_with(&arch, &job, &session).unwrap();
    assert_eq!(cold.cache.intra_hits, 0, "nothing recorded yet");
    assert!(cold.cache.intra_lookups > 0, "scans must consult the memo");
    let warm = run_job_with(&arch, &job, &session).unwrap();
    assert!(warm.cache.intra_hits > 0, "warm session must report memo hits");
    assert_eq!(snapshot(&cold), snapshot(&warm));
    assert!(session.intra_len() > 0);
    assert!(session.intra_hits() > 0);
}
