//! End-to-end service-protocol tests: drive `coordinator::service::
//! handle_line` exactly as a connected client would — request lines in,
//! JSON out — covering knob plumbing (`threads=` / `objective=` / DP
//! knobs), structured rejection of malformed requests, and the
//! cross-request cache-hit accounting of the connection's scheduling
//! session.

use kapla::arch::presets;
use kapla::coordinator::service::handle_line;
use kapla::cost::{CacheBudget, SessionCache};
use kapla::util::json::Json;

/// Fetch a numeric field along a path of object keys.
fn num(j: &Json, path: &[&str]) -> f64 {
    let mut cur = j;
    for key in path {
        cur = cur.get(key).unwrap_or_else(|| panic!("missing {key} in {}", j.to_string_compact()));
    }
    cur.as_f64().unwrap_or_else(|| panic!("non-numeric {path:?}"))
}

fn text<'j>(j: &'j Json, key: &str) -> &'j str {
    j.get(key).and_then(|v| v.as_str()).unwrap_or_else(|| panic!("missing string {key}"))
}

fn ok(j: &Json) -> bool {
    j.get("ok") == Some(&Json::Bool(true))
}

#[test]
fn knobs_plumb_into_the_solve() {
    let arch = presets::bench_multi_node();
    let s = SessionCache::unbounded();
    let r = handle_line(&arch, &s, "schedule mlp 8 kapla threads=2 max_rounds=4").unwrap();
    assert!(ok(&r), "{}", r.to_string_compact());
    assert_eq!(text(&r, "network"), "mlp");
    assert_eq!(text(&r, "solver"), "K");
    assert_eq!(text(&r, "objective"), "energy");
    assert_eq!(num(&r, &["threads"]), 2.0);
    assert_eq!(num(&r, &["batch"]), 8.0);
    assert!(num(&r, &["energy_pj"]) > 0.0);
    assert!(num(&r, &["segments"]) > 0.0);
    assert!(num(&r, &["cache", "lookups"]) > 0.0);

    // objective= knob overrides the positional default and is echoed back.
    let r = handle_line(&arch, &s, "schedule mlp 8 kapla objective=latency threads=1").unwrap();
    assert!(ok(&r));
    assert_eq!(text(&r, "objective"), "latency");
    assert_eq!(num(&r, &["threads"]), 1.0);

    // Positional objective still accepted.
    let r = handle_line(&arch, &s, "schedule mlp 8 kapla latency").unwrap();
    assert!(ok(&r));
    assert_eq!(text(&r, "objective"), "latency");

    // Solver-level key=value knobs ride the solver token, and the echoed
    // solver label folds the non-default knobs back in so sweep responses
    // stay distinguishable.
    let r = handle_line(&arch, &s, "schedule mlp 8 random:p=0.3,seed=7 threads=1").unwrap();
    assert!(ok(&r));
    assert_eq!(text(&r, "solver"), "R:p=0.3,seed=7");

    // Batch is optional: a non-numeric first positional is the solver.
    let r = handle_line(&arch, &s, "schedule mlp kapla threads=1 max_rounds=4").unwrap();
    assert!(ok(&r), "{}", r.to_string_compact());
    assert_eq!(text(&r, "solver"), "K");
    assert_eq!(num(&r, &["batch"]), 64.0, "omitted batch defaults to 64");

    // An untrusted request cannot force unbounded thread fan-out.
    let r = handle_line(&arch, &s, "schedule mlp 8 kapla threads=100000 max_rounds=4").unwrap();
    assert!(ok(&r));
    assert!(num(&r, &["threads"]) <= 8.0, "threads knob must be clamped");
}

#[test]
fn malformed_requests_get_structured_errors() {
    let arch = presets::bench_multi_node();
    let s = SessionCache::unbounded();
    for (line, needle) in [
        ("schedule mlp 8 kapla threads=0", "threads"),
        ("schedule mlp 8 kapla threads=two", "threads"),
        ("schedule mlp 8 kapla max_seg_len=0", "max_seg_len"),
        ("schedule mlp 8 kapla top_per_span=0", "top_per_span"),
        ("schedule mlp 8 kapla max_seg_len=1000000", "too large"),
        ("schedule mlp 8 kapla ks=1000000", "too large"),
        ("schedule mlp 8 kapla max_rounds=99999999", "too large"),
        ("schedule mlp 8 kapla objective=speed", "objective"),
        ("schedule mlp 8 kapla bogus=1", "unknown knob"),
        ("schedule mlp 8 wat", "unknown solver"),
        ("schedule mlp 8 random:q=1", "unknown solver"),
        ("schedule mlp notanumber", "bad batch"),
        ("schedule mlp 0 kapla", "bad batch"),
        ("schedule mlp 8 kapla energy extra", "unexpected argument"),
        ("schedule", "missing network"),
        ("schedule nosuchnet 8", "unknown network"),
    ] {
        let r = handle_line(&arch, &s, line).unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "{line} should be rejected");
        let err = text(&r, "error");
        assert!(err.contains(needle), "{line}: error {err:?} should mention {needle:?}");
    }
    // Nothing was scheduled, so the session saw no evaluations.
    let st = handle_line(&arch, &s, "stats").unwrap();
    assert_eq!(num(&st, &["cache", "lookups"]), 0.0);
}

#[test]
fn cross_request_cache_hits_accumulate() {
    let arch = presets::bench_multi_node();
    let s = SessionCache::unbounded();
    let r1 = handle_line(&arch, &s, "schedule mlp 8 kapla threads=1 max_rounds=4").unwrap();
    assert!(ok(&r1));
    let (lookups1, hits1, entries1) = (
        num(&r1, &["cache", "lookups"]),
        num(&r1, &["cache", "hits"]),
        num(&r1, &["cache", "entries"]),
    );
    assert!(lookups1 > 0.0 && entries1 > 0.0);

    let r2 = handle_line(&arch, &s, "schedule mlp 8 kapla threads=1 max_rounds=4").unwrap();
    assert!(ok(&r2));
    let (lookups2, hits2, entries2) = (
        num(&r2, &["cache", "lookups"]),
        num(&r2, &["cache", "hits"]),
        num(&r2, &["cache", "entries"]),
    );
    // The repeated request adds no entries and — because the session's
    // intra-argmin memo replays every recorded scan — issues no new
    // evaluations at all: pure cross-request reuse.
    assert_eq!(entries2, entries1, "repeat request must add no entries");
    assert_eq!(lookups2, lookups1, "repeat request must skip the scans entirely");
    assert_eq!(hits2, hits1);
    assert!(
        num(&r2, &["cache", "intra_hits"]) > num(&r1, &["cache", "intra_hits"]),
        "repeat request must replay recorded argmins"
    );

    // `stats` reads the same session counters.
    let st = handle_line(&arch, &s, "stats").unwrap();
    assert!(ok(&st));
    assert_eq!(num(&st, &["cache", "lookups"]), lookups2);
    assert_eq!(num(&st, &["cache", "entries"]), entries2);
}

#[test]
fn budgeted_session_serves_identical_schedules() {
    let arch = presets::bench_multi_node();
    let unbounded = SessionCache::unbounded();
    let tiny = SessionCache::new(CacheBudget::entries(32));
    let line = "schedule mlp 8 kapla threads=1 max_rounds=4";
    let a = handle_line(&arch, &unbounded, line).unwrap();
    let b = handle_line(&arch, &tiny, line).unwrap();
    assert!(ok(&a) && ok(&b));
    assert_eq!(num(&a, &["energy_pj"]), num(&b, &["energy_pj"]));
    assert_eq!(num(&a, &["latency_cycles"]), num(&b, &["latency_cycles"]));
    assert_eq!(
        a.get("chain").unwrap().to_string_compact(),
        b.get("chain").unwrap().to_string_compact(),
        "eviction churn must not change the chain"
    );
    // The tiny session actually churned.
    assert!(num(&b, &["cache", "evictions"]) > 0.0);
}
