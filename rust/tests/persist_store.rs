//! Warm-tier robustness: snapshot round-trips, schedule-store replay
//! across a process "restart" (fresh handles over the same directory),
//! and the corruption battery — every damaged input degrades to a cold
//! start (counted in `load_skipped` / the store's `skipped`) with a
//! correct schedule, never a panic and never a stale result.

use kapla::arch::{presets, ArchConfig};
use kapla::coordinator::{run_job_persistent, run_job_with, store_key_for, Job, SolverKind};
use kapla::cost::{
    load_session, save_session, CacheBudget, EvalCache as _, ScheduleStore, SessionCache,
};
use kapla::interlayer::dp::DpConfig;
use kapla::solvers::{Objective, SolveResult};
use kapla::workloads::nets;

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};

fn tmp_dir(tag: &str) -> PathBuf {
    static SEQ: AtomicUsize = AtomicUsize::new(0);
    let d = std::env::temp_dir().join(format!(
        "kapla-persist-{tag}-{}-{}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn arch() -> ArchConfig {
    presets::bench_multi_node()
}

fn job() -> Job {
    Job {
        net: nets::mlp(),
        batch: 4,
        objective: Objective::Energy,
        solver: SolverKind::Kapla,
        dp: DpConfig { max_rounds: 8, solve_threads: 1, ..DpConfig::default() },
        deadline_ms: None,
    }
}

fn assert_same_schedule(a: &SolveResult, b: &SolveResult) {
    assert_eq!(format!("{:?}", a.schedule), format!("{:?}", b.schedule));
    assert_eq!(a.eval.energy.total().to_bits(), b.eval.energy.total().to_bits());
}

/// The single `.sched` file a one-entry store wrote.
fn only_sched_file(dir: &Path) -> PathBuf {
    let mut files: Vec<PathBuf> = std::fs::read_dir(dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().is_some_and(|x| x == "sched"))
        .collect();
    assert_eq!(files.len(), 1, "expected exactly one store file in {dir:?}");
    files.pop().unwrap()
}

#[test]
fn snapshot_round_trip_restores_stats_and_hits() {
    let dir = tmp_dir("roundtrip");
    let arch = arch();
    let job = job();
    let snap = dir.join("session.snap");

    let s1 = SessionCache::new(CacheBudget::UNBOUNDED);
    let cold = run_job_with(&arch, &job, &s1).unwrap();
    let saved = save_session(&s1, &snap).unwrap();
    assert!(saved.eval_entries > 0, "cold solve must leave evaluations to save");
    assert_eq!(saved.skipped, 0);

    // Load into a fresh session: every record must come back, none skipped.
    let s2 = SessionCache::new(CacheBudget::UNBOUNDED);
    let loaded = load_session(&s2, &snap, Some(&arch)).unwrap();
    assert_eq!(loaded.eval_entries, saved.eval_entries);
    assert_eq!(loaded.intra_entries, saved.intra_entries);
    assert_eq!(loaded.skipped, 0);
    assert_eq!(s2.load_skipped(), 0);

    // Re-saving the loaded session keeps the same population (record
    // order may differ — the memo is a map — but the contents round-trip).
    let resaved = save_session(&s2, &dir.join("resave.snap")).unwrap();
    assert_eq!(resaved.eval_entries, saved.eval_entries);
    assert_eq!(resaved.intra_entries, saved.intra_entries);

    // The warm session answers the repeat solve from the memo with a
    // byte-identical schedule.
    let warm = run_job_with(&arch, &job, &s2).unwrap();
    assert_same_schedule(&cold, &warm);
    assert!(warm.cache.hits > 0, "warm session never hit the restored memo");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn store_replay_is_byte_identical_across_restart() {
    let dir = tmp_dir("replay");
    let arch = arch();
    let job = job();

    let store = ScheduleStore::open(&dir.join("store")).unwrap();
    let s1 = SessionCache::new(CacheBudget::UNBOUNDED);
    let cold = run_job_persistent(&arch, &job, &s1, Some(&store)).unwrap();
    assert_eq!(store.hits(), 0);
    assert_eq!(store.writes(), 1);
    drop(store);
    drop(s1);

    // "Restart": fresh handles over the same directory, empty session.
    let store = ScheduleStore::open(&dir.join("store")).unwrap();
    let s2 = SessionCache::new(CacheBudget::UNBOUNDED);
    let warm = run_job_persistent(&arch, &job, &s2, Some(&store)).unwrap();
    assert_eq!(store.hits(), 1);
    assert_eq!(store.skipped(), 0);
    assert_same_schedule(&cold, &warm);
    // The replay bypasses the detailed-evaluation tier entirely.
    let st = s2.stats();
    assert_eq!(st.lookups, 0, "store hit must not touch the evaluation memo");
    assert_eq!(st.intra_lookups, 0);
    assert!(warm.cache.store_hits > 0);

    // Never stale: a different request (other batch) has another key and
    // must miss rather than replay the batch-4 schedule.
    let other = Job { batch: 8, ..job.clone() };
    assert_ne!(store_key_for(&arch, &other), store_key_for(&arch, &job));
    assert!(store.lookup(&store_key_for(&arch, &other)).is_none());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn truncated_snapshot_degrades_to_cold_start() {
    let dir = tmp_dir("trunc");
    let arch = arch();
    let job = job();
    let snap = dir.join("session.snap");

    let s1 = SessionCache::new(CacheBudget::UNBOUNDED);
    let cold = run_job_with(&arch, &job, &s1).unwrap();
    save_session(&s1, &snap).unwrap();
    let full = std::fs::read(&snap).unwrap();
    assert!(full.len() > 32);

    // Cut inside the header, one byte into the first frame, and inside
    // the last frame's checksum — all provably mid-structure: every
    // prefix loads without error, counts at least one skip, and the
    // session still solves to the correct schedule. (A cut at an exact
    // frame boundary is simply a shorter valid snapshot, so those are
    // not in the battery.)
    for cut in [4usize, 13, full.len() - 3] {
        std::fs::write(&snap, &full[..cut]).unwrap();
        let s = SessionCache::new(CacheBudget::UNBOUNDED);
        let st = load_session(&s, &snap, Some(&arch)).unwrap();
        assert!(st.skipped > 0, "truncation at {cut} went unnoticed");
        assert!(s.load_skipped() > 0);
        let r = run_job_with(&arch, &job, &s).unwrap();
        assert_same_schedule(&cold, &r);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn flipped_version_byte_rejects_whole_snapshot() {
    let dir = tmp_dir("version");
    let arch = arch();
    let s1 = SessionCache::new(CacheBudget::UNBOUNDED);
    run_job_with(&arch, &job(), &s1).unwrap();
    let snap = dir.join("session.snap");
    save_session(&s1, &snap).unwrap();

    let mut bytes = std::fs::read(&snap).unwrap();
    bytes[8] ^= 0xFF; // version field, little-endian low byte
    std::fs::write(&snap, &bytes).unwrap();

    let s2 = SessionCache::new(CacheBudget::UNBOUNDED);
    let st = load_session(&s2, &snap, Some(&arch)).unwrap();
    assert_eq!(st.eval_entries, 0, "future-versioned snapshot must not be trusted");
    assert_eq!(st.intra_entries, 0);
    assert_eq!(st.skipped, 1);
    assert_eq!(s2.stats().entries, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn wrong_arch_fingerprint_entries_are_skipped() {
    let dir = tmp_dir("archfp");
    let bench = arch();
    let s1 = SessionCache::new(CacheBudget::UNBOUNDED);
    run_job_with(&bench, &job(), &s1).unwrap();
    let snap = dir.join("session.snap");
    let saved = save_session(&s1, &snap).unwrap();

    // Same bytes, different hardware: every entry is fingerprinted for
    // the bench mesh and must be dropped when loading for the edge TPU.
    let edge = presets::edge_tpu();
    let s2 = SessionCache::new(CacheBudget::UNBOUNDED);
    let st = load_session(&s2, &snap, Some(&edge)).unwrap();
    assert_eq!(st.eval_entries, 0);
    assert_eq!(st.intra_entries, 0);
    assert_eq!(st.skipped, saved.eval_entries + saved.intra_entries);
    assert_eq!(s2.stats().entries, 0);
    assert_eq!(s2.load_skipped(), st.skipped);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_store_file_falls_back_to_cold_solve() {
    let dir = tmp_dir("storecorrupt");
    let arch = arch();
    let job = job();
    let store_dir = dir.join("store");

    let store = ScheduleStore::open(&store_dir).unwrap();
    let s1 = SessionCache::new(CacheBudget::UNBOUNDED);
    let pristine = run_job_persistent(&arch, &job, &s1, Some(&store)).unwrap();

    // Flip one payload byte: the checksum kills the entry, the request
    // re-solves cold (correct result), and the rewrite heals the store.
    let file = only_sched_file(&store_dir);
    let mut bytes = std::fs::read(&file).unwrap();
    let last = bytes.len() - 1;
    bytes[last] ^= 0x01;
    std::fs::write(&file, &bytes).unwrap();

    let store = ScheduleStore::open(&store_dir).unwrap();
    let s2 = SessionCache::new(CacheBudget::UNBOUNDED);
    let healed = run_job_persistent(&arch, &job, &s2, Some(&store)).unwrap();
    assert_eq!(store.hits(), 0, "corrupt entry must never count as a hit");
    assert!(store.skipped() > 0);
    assert_eq!(store.writes(), 1, "cold re-solve must rewrite the entry");
    assert_same_schedule(&pristine, &healed);

    // After the heal the very same handle serves the replay.
    let s3 = SessionCache::new(CacheBudget::UNBOUNDED);
    let replay = run_job_persistent(&arch, &job, &s3, Some(&store)).unwrap();
    assert_eq!(store.hits(), 1);
    assert_same_schedule(&pristine, &replay);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn concurrent_writers_never_corrupt_snapshot_or_store() {
    let dir = tmp_dir("concurrent");
    let arch = arch();
    let job = job();
    let snap = dir.join("session.snap");
    let store_dir = dir.join("store");

    let session = SessionCache::new(CacheBudget::UNBOUNDED);
    let expected = run_job_with(&arch, &job, &session).unwrap();
    let saved = save_session(&session, &snap).unwrap();
    let store = ScheduleStore::open(&store_dir).unwrap();
    let key = store_key_for(&arch, &job);

    // Hammer the same snapshot path and the same store entry from
    // several threads while a reader loads mid-flight. Atomic
    // temp-file+rename publication means every observation is either the
    // old complete file or the new complete file — never a torn one.
    std::thread::scope(|scope| {
        for _ in 0..4 {
            let session = &session;
            let snap = &snap;
            let store = &store;
            let expected = &expected;
            let key = &key;
            scope.spawn(move || {
                for _ in 0..10 {
                    save_session(session, snap).unwrap();
                    store
                        .record(key, &expected.schedule, expected.prune.as_ref(), None)
                        .unwrap();
                }
            });
        }
        let arch = &arch;
        let snap = &snap;
        scope.spawn(move || {
            for _ in 0..20 {
                let probe = SessionCache::new(CacheBudget::UNBOUNDED);
                let st = load_session(&probe, snap, Some(arch)).unwrap();
                assert_eq!(st.skipped, 0, "reader saw a torn snapshot");
            }
        });
    });

    let fresh = SessionCache::new(CacheBudget::UNBOUNDED);
    let st = load_session(&fresh, &snap, Some(&arch)).unwrap();
    assert_eq!(st.skipped, 0);
    assert_eq!(st.eval_entries, saved.eval_entries);
    let stored = store.lookup(&key).expect("store entry readable after the write storm");
    assert_eq!(format!("{:?}", stored.schedule), format!("{:?}", expected.schedule));
    let _ = std::fs::remove_dir_all(&dir);
}
