//! The parallel intra-layer sweep and the cross-job scheduling sessions
//! are *optimizations*, not semantic changes: for every solver family,
//! `run_job` with a worker pool — or against a shared, warm, or budgeted
//! `SessionCache` — must produce byte-identical schedules and energy
//! totals to a solitary sequential run. These tests pin that invariant
//! (including a golden-schedule battery over the full emitted directive
//! programs), plus the cache bookkeeping the speedup comes from.

use kapla::arch::presets;
use kapla::coordinator::{run_job, run_job_with, Job, SolverKind};
use kapla::cost::{CacheBudget, CostCache, EvalCache as _, SessionCache, TieredCost};
use kapla::directives::emit::emit_layer;
use kapla::interlayer::dp::DpConfig;
use kapla::solvers::exhaustive::ExhaustiveIntra;
use kapla::solvers::kapla::{solve_intra_cached, KaplaIntra};
use kapla::solvers::ml::MlIntra;
use kapla::solvers::random::RandomIntra;
use kapla::solvers::{IntraCtx, IntraSolver, Objective, SolveResult};
use kapla::workloads::{nets, Layer, Network};

fn tiny_net() -> Network {
    let mut n = Network::new("tiny", 8, 28, 28);
    n.chain(Layer::conv("c1", 8, 16, 28, 3, 1));
    n.chain(Layer::pool("p1", 16, 14, 2, 2));
    n.chain(Layer::conv("c2", 16, 32, 14, 3, 1));
    n.chain(Layer::fc("f1", 32 * 14 * 14, 64));
    n
}

fn job(solver: SolverKind, threads: usize) -> Job {
    Job {
        net: tiny_net(),
        batch: 8,
        objective: Objective::Energy,
        solver,
        dp: DpConfig { max_rounds: 8, solve_threads: threads, ..DpConfig::default() },
        deadline_ms: None,
    }
}

#[test]
fn parallel_run_job_is_byte_identical_for_every_solver() {
    let arch = presets::bench_multi_node();
    for solver in [
        SolverKind::Baseline,
        SolverKind::DirectiveExhaustive,
        SolverKind::Random { p: 0.15, seed: 1 },
        SolverKind::Ml { seed: 1, rounds: 4, batch: 16 },
        SolverKind::Kapla,
    ] {
        let seq = run_job(&arch, &job(solver, 1)).unwrap();
        let par = run_job(&arch, &job(solver, 4)).unwrap();
        // Exact equality, not tolerance: the parallel path must assemble
        // the same schemes in the same order from the same evaluations.
        assert_eq!(
            seq.eval.energy.total(),
            par.eval.energy.total(),
            "{solver:?}: energy diverged"
        );
        assert_eq!(
            seq.eval.latency_cycles,
            par.eval.latency_cycles,
            "{solver:?}: latency diverged"
        );
        assert_eq!(
            format!("{:?}", seq.schedule),
            format!("{:?}", par.schedule),
            "{solver:?}: schedule diverged"
        );
    }
}

#[test]
fn thread_count_beyond_work_is_harmless() {
    let arch = presets::bench_multi_node();
    let seq = run_job(&arch, &job(SolverKind::Kapla, 1)).unwrap();
    let wide = run_job(&arch, &job(SolverKind::Kapla, 64)).unwrap();
    assert_eq!(seq.eval.energy.total(), wide.eval.energy.total());
    assert_eq!(format!("{:?}", seq.schedule), format!("{:?}", wide.schedule));
}

#[test]
fn cost_cache_hit_rate_sanity() {
    // A shared cache across repeated contexts answers the repeats from the
    // memo: hit rate strictly grows with each repetition and the distinct
    // entry count stays flat.
    let arch = presets::bench_multi_node();
    let net = tiny_net();
    let cache = CostCache::new();
    let ctx = IntraCtx { region: (4, 4), rb: 8, ifm_on_chip: false, objective: Objective::Energy };

    let model = TieredCost::over(&cache);
    let first = solve_intra_cached(&arch, &net.layers[0], &ctx, &model).unwrap();
    let (lookups1, len1) = (cache.lookups(), cache.len());
    assert!(lookups1 > 0);
    assert!(len1 > 0 && len1 <= lookups1 as usize);

    let rate_after_one = cache.hit_rate();
    let second = solve_intra_cached(&arch, &net.layers[0], &ctx, &model).unwrap();
    assert_eq!(format!("{first:?}"), format!("{second:?}"));
    assert_eq!(cache.len(), len1, "identical solve must add no new entries");
    assert!(
        cache.hit_rate() > rate_after_one,
        "hit rate must grow on repetition: {} -> {}",
        rate_after_one,
        cache.hit_rate()
    );
    // The second pass was answered entirely from the memo.
    assert_eq!(cache.hits(), cache.lookups() - len1 as u64);
}

// ---------------------------------------------------------------------------
// Golden-schedule battery: pin the full emitted directive programs + costs
// for all five solvers on two small networks, and require the bytes to be
// identical across cold cache, warm cache, shared session, bounded
// (evicting) session, and 1-vs-N worker threads. A blessed snapshot file
// (tests/golden/*.snap) additionally pins the bytes across commits: the
// battery self-blesses a missing snapshot (commit it!), diffs against a
// present one, and KAPLA_BLESS=1 re-blesses after intentional changes.

fn golden_solvers() -> Vec<SolverKind> {
    vec![
        SolverKind::Baseline,
        SolverKind::DirectiveExhaustive,
        SolverKind::Random { p: 0.15, seed: 1 },
        SolverKind::Ml { seed: 1, rounds: 4, batch: 16 },
        SolverKind::Kapla,
    ]
}

fn golden_nets() -> Vec<(Network, u64)> {
    vec![(nets::mlp(), 4), (tiny_net(), 4)]
}

fn golden_dp(threads: usize) -> DpConfig {
    DpConfig { max_rounds: 4, max_seg_len: 3, solve_threads: threads, ..DpConfig::default() }
}

/// Render one solve as the exact bytes the battery pins: full-precision
/// costs plus every emitted directive program, in schedule order.
fn snapshot_result(net: &Network, solver: SolverKind, r: &SolveResult) -> String {
    let mut out = String::new();
    out.push_str(&format!("=== {} on {} ===\n", solver.letter(), net.name));
    out.push_str(&format!("energy_pj: {:?}\n", r.eval.energy.total()));
    out.push_str(&format!("latency_cycles: {:?}\n", r.eval.latency_cycles));
    for (si, (seg, schemes)) in r.schedule.segments.iter().enumerate() {
        out.push_str(&format!(
            "segment {si}: layers={:?} spatial={} rounds={} regions={:?}\n",
            seg.layers, seg.spatial, seg.rounds, seg.regions
        ));
        for (pos, s) in schemes.iter().enumerate() {
            out.push_str(&emit_layer(&net.layers[seg.layers[pos]].name, s));
        }
    }
    out
}

/// Run the whole battery — every golden solver on every golden net — and
/// concatenate the snapshots. `session: None` gives each job a private
/// cold `CostCache` (the golden reference path).
fn run_battery(session: Option<&SessionCache>, threads: usize) -> String {
    let arch = presets::bench_multi_node();
    let mut out = String::new();
    for (net, batch) in golden_nets() {
        for solver in golden_solvers() {
            let job = Job {
                net: net.clone(),
                batch,
                objective: Objective::Energy,
                solver,
                dp: golden_dp(threads),
                deadline_ms: None,
            };
            let r = match session {
                Some(s) => run_job_with(&arch, &job, s),
                None => run_job(&arch, &job),
            }
            .expect("battery job must schedule");
            out.push_str(&snapshot_result(&net, solver, &r));
        }
    }
    out
}

/// Diff against the blessed snapshot file, self-blessing on first run.
///
/// * `KAPLA_BLESS=1` — force-rewrite the snapshot (after an *intentional*
///   schedule change).
/// * Snapshot present — the run must be byte-identical to it: this is the
///   cross-commit pin (commit `tests/golden/*.snap`; CI fails if a tracked
///   snapshot diverges).
/// * Snapshot missing — write it and note so on stderr: the first run on a
///   machine with a toolchain blesses the battery, and checking the new
///   file in pins it from then on. (This container ships no cargo, so the
///   repo cannot pre-compute the bytes; self-blessing closes that gap.)
fn golden_file_check(name: &str, actual: &str) {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden")
        .join(format!("{name}.snap"));
    let force = std::env::var("KAPLA_BLESS").map(|v| v == "1").unwrap_or(false);
    if !force {
        match std::fs::read_to_string(&path) {
            Ok(want) => {
                assert_eq!(
                    want,
                    actual,
                    "snapshot diverged from blessed {} (KAPLA_BLESS=1 regenerates after \
                     intentional changes)",
                    path.display()
                );
                return;
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {} // self-bless below
            // A present-but-unreadable snapshot must fail, not silently
            // re-bless over a possibly-diverged schedule.
            Err(e) => panic!("cannot read blessed snapshot {}: {e}", path.display()),
        }
    }
    std::fs::create_dir_all(path.parent().unwrap()).unwrap();
    std::fs::write(&path, actual).unwrap();
    if !force {
        eprintln!(
            "golden: no blessed snapshot at {} — wrote one; commit it to pin schedules \
             across commits",
            path.display()
        );
    }
}

#[test]
fn golden_schedules_cold_warm_shared_bounded_and_threads() {
    // Cold: private cache per job — the golden reference.
    let golden = run_battery(None, 1);

    // Shared session across all ten jobs (5 solvers x 2 nets).
    let session = SessionCache::unbounded();
    let shared = run_battery(Some(&session), 1);
    assert_eq!(golden, shared, "shared-session schedules diverged from cold");
    let st1 = session.stats();
    assert!(st1.lookups > 0 && st1.entries > 0);

    // Warm: the same battery again on the now-hot session. Since the
    // intra-argmin memo replays every recorded scan, the warm pass issues
    // no new detailed evaluations at all — the searches never run.
    let warm = run_battery(Some(&session), 1);
    assert_eq!(golden, warm, "warm-cache schedules diverged from cold");
    let st2 = session.stats();
    assert_eq!(st1.entries, st2.entries, "warm pass must add no entries");
    assert_eq!(st2.lookups, st1.lookups, "warm pass must replay scans, not re-run them");
    assert!(st2.intra_hits > st1.intra_hits, "cross-job argmin reuse must actually occur");

    // N worker threads.
    let par = run_battery(None, 4);
    assert_eq!(golden, par, "1-vs-N-thread schedules diverged");

    // Tiny bounded session: eviction churn is a perf knob, never a
    // results one.
    let bounded = SessionCache::new(CacheBudget::entries(64));
    let b = run_battery(Some(&bounded), 1);
    assert_eq!(golden, b, "bounded-session schedules diverged from cold");
    assert!(bounded.len() <= 64);
    assert!(bounded.stats().evictions > 0, "a 64-entry budget must churn");

    golden_file_check("schedules", &golden);
}

#[test]
fn golden_array_mapping_training_battery() {
    // Satellite of the ArrayMapping refactor: the systolic preset run
    // under BOTH array-mapping templates, on two zoo nets, inference and
    // training (full fwd + dX + dW + wu graphs), KAPLA solver. Pins the
    // per-template directive programs across commits, and checks the
    // structural training invariant (backward MACs conserve forward) on
    // top of the byte pin.
    use kapla::arch::PeDataflow;
    use kapla::mapping::array_mapping;
    use kapla::workloads::by_name;

    let base = presets::edge_tpu();
    let mut snap = String::new();
    for df in [PeDataflow::RowStationary, PeDataflow::Systolic] {
        let mut arch = base.clone();
        arch.pe_dataflow = df;
        for name in ["mlp", "mlp-train", "alexnet", "alexnet-train"] {
            let net = by_name(name).expect("zoo net");
            let job = Job {
                net: net.clone(),
                batch: 4,
                objective: Objective::Energy,
                solver: SolverKind::Kapla,
                dp: golden_dp(1),
                deadline_ms: None,
            };
            let r = run_job(&arch, &job).expect("battery job must schedule");
            if let Some(base_name) = name.strip_suffix("-train") {
                let fwd = by_name(base_name).unwrap();
                for l in &fwd.layers {
                    if l.has_weights() {
                        let bd = net
                            .layers
                            .iter()
                            .find(|x| x.name == format!("{}@bd", l.name))
                            .expect("every weighted layer gets a back-activation pass");
                        assert_eq!(bd.macs(4), l.macs(4), "{name}: {} bd macs", l.name);
                    }
                }
            }
            snap.push_str(&format!("### {} / {}\n", array_mapping(df).name(), name));
            snap.push_str(&snapshot_result(&net, SolverKind::Kapla, &r));
        }
    }
    golden_file_check("array_mapping_battery", &snap);
}

#[test]
fn golden_intra_layer_directives_for_all_solvers() {
    // The two small zoo layers: alexnet's conv2 and mlp's fc1, solved by
    // every intra-layer solver family in a fixed context — cold cache vs
    // shared session must emit byte-identical directive programs.
    let arch = presets::bench_multi_node();
    let anet = nets::alexnet();
    let mnet = nets::mlp();
    let layers = [&anet.layers[2], &mnet.layers[0]];
    let ctx = IntraCtx { region: (4, 4), rb: 4, ifm_on_chip: false, objective: Objective::Energy };
    let solvers: Vec<(&str, Box<dyn IntraSolver>)> = vec![
        ("B", Box::new(ExhaustiveIntra::new(false))),
        ("S", Box::new(ExhaustiveIntra::new(true))),
        ("R", Box::new(RandomIntra::new(0.15, 1))),
        ("M", Box::new(MlIntra::native(1, 4, 16))),
        ("K", Box::new(KaplaIntra)),
    ];
    let session = SessionCache::unbounded();
    let shared_model = TieredCost::over(&session);
    let mut snap = String::new();
    for (letter, solver) in &solvers {
        for layer in layers {
            let cold = solver
                .solve(&arch, layer, &ctx, &TieredCost::fresh())
                .unwrap_or_else(|| panic!("{letter}: no scheme for {}", layer.name));
            let shared = solver.solve(&arch, layer, &ctx, &shared_model).unwrap();
            assert_eq!(
                format!("{cold:?}"),
                format!("{shared:?}"),
                "{letter}/{}: session changed the scheme",
                layer.name
            );
            let ev = kapla::sim::evaluate_layer(&arch, &cold, false);
            snap.push_str(&format!(
                "=== {letter} {} ===\nenergy_pj: {:?}\n{}",
                layer.name,
                ev.energy.total(),
                emit_layer(&layer.name, &cold)
            ));
        }
    }
    // Since the staged-enumeration PR, B/S/R/M score their
    // enumeration-unique candidates directly and bypass the memo; the
    // session traffic here comes from KAPLA's revisit-heavy path (its
    // hill-climb probes and final sweep re-score the same schemes —
    // pinned by `solve_intra_reuses_cached_evaluations`).
    assert!(session.hits() > 0, "KAPLA's probe/sweep revisits must share evaluations");
    golden_file_check("intra_directives", &snap);
}
