//! The parallel intra-layer sweep is an *optimization*, not a semantic
//! change: for every solver family, `run_job` with a worker pool must
//! produce byte-identical schedules and energy totals to the sequential
//! path. These tests pin that invariant, plus the cache bookkeeping the
//! speedup comes from.

use kapla::arch::presets;
use kapla::coordinator::{run_job, Job, SolverKind};
use kapla::cost::CostCache;
use kapla::interlayer::dp::DpConfig;
use kapla::solvers::kapla::solve_intra_cached;
use kapla::solvers::{IntraCtx, Objective};
use kapla::workloads::{Layer, Network};

fn tiny_net() -> Network {
    let mut n = Network::new("tiny", 8, 28, 28);
    n.chain(Layer::conv("c1", 8, 16, 28, 3, 1));
    n.chain(Layer::pool("p1", 16, 14, 2, 2));
    n.chain(Layer::conv("c2", 16, 32, 14, 3, 1));
    n.chain(Layer::fc("f1", 32 * 14 * 14, 64));
    n
}

fn job(solver: SolverKind, threads: usize) -> Job {
    Job {
        net: tiny_net(),
        batch: 8,
        objective: Objective::Energy,
        solver,
        dp: DpConfig { max_rounds: 8, solve_threads: threads, ..DpConfig::default() },
    }
}

#[test]
fn parallel_run_job_is_byte_identical_for_every_solver() {
    let arch = presets::bench_multi_node();
    for solver in [
        SolverKind::Baseline,
        SolverKind::DirectiveExhaustive,
        SolverKind::Random { p: 0.15, seed: 1 },
        SolverKind::Ml { seed: 1, rounds: 4, batch: 16 },
        SolverKind::Kapla,
    ] {
        let seq = run_job(&arch, &job(solver, 1));
        let par = run_job(&arch, &job(solver, 4));
        // Exact equality, not tolerance: the parallel path must assemble
        // the same schemes in the same order from the same evaluations.
        assert_eq!(
            seq.eval.energy.total(),
            par.eval.energy.total(),
            "{solver:?}: energy diverged"
        );
        assert_eq!(
            seq.eval.latency_cycles,
            par.eval.latency_cycles,
            "{solver:?}: latency diverged"
        );
        assert_eq!(
            format!("{:?}", seq.schedule),
            format!("{:?}", par.schedule),
            "{solver:?}: schedule diverged"
        );
    }
}

#[test]
fn thread_count_beyond_work_is_harmless() {
    let arch = presets::bench_multi_node();
    let seq = run_job(&arch, &job(SolverKind::Kapla, 1));
    let wide = run_job(&arch, &job(SolverKind::Kapla, 64));
    assert_eq!(seq.eval.energy.total(), wide.eval.energy.total());
    assert_eq!(format!("{:?}", seq.schedule), format!("{:?}", wide.schedule));
}

#[test]
fn cost_cache_hit_rate_sanity() {
    // A shared cache across repeated contexts answers the repeats from the
    // memo: hit rate strictly grows with each repetition and the distinct
    // entry count stays flat.
    let arch = presets::bench_multi_node();
    let net = tiny_net();
    let cache = CostCache::new();
    let ctx = IntraCtx { region: (4, 4), rb: 8, ifm_on_chip: false, objective: Objective::Energy };

    let first = solve_intra_cached(&arch, &net.layers[0], &ctx, &cache).unwrap();
    let (lookups1, len1) = (cache.lookups(), cache.len());
    assert!(lookups1 > 0);
    assert!(len1 > 0 && len1 <= lookups1 as usize);

    let rate_after_one = cache.hit_rate();
    let second = solve_intra_cached(&arch, &net.layers[0], &ctx, &cache).unwrap();
    assert_eq!(format!("{first:?}"), format!("{second:?}"));
    assert_eq!(cache.len(), len1, "identical solve must add no new entries");
    assert!(
        cache.hit_rate() > rate_after_one,
        "hit rate must grow on repetition: {} -> {}",
        rate_after_one,
        cache.hit_rate()
    );
    // The second pass was answered entirely from the memo.
    assert_eq!(cache.hits(), cache.lookups() - len1 as u64);
}
