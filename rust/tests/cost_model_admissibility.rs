//! Estimator-admissibility property tests (seeded SplitMix64 stands in
//! for proptest, which is not in the offline registry).
//!
//! The tiered `cost::CostModel` makes admissibility a *soundness*
//! invariant, not just a heuristic: the inter-layer search prunes and
//! prioritizes on the estimate tier and only realizes the survivors on
//! the detailed tier, so an estimate that ever exceeded the detailed cost
//! of a realizable scheme could prune the true optimum. These tests pin,
//! across seeded random layers and real segment candidates, that
//!
//! * `estimate_layer` (= `cost::layer_lower_bound`) never exceeds the
//!   detailed `evaluate` of any scheme the solvers realize in the same
//!   context, for both energy and latency, and
//! * `estimate_segment` (= `cost::segment_lower_bound`) never exceeds the
//!   detailed `sim::pipeline::evaluate_segment` of the fully-solved
//!   segment.

use kapla::arch::presets;
use kapla::cost::{CostModel, LayerCtx, TieredCost};
use kapla::directives::{LayerScheme, LevelBlock, LoopOrder};
use kapla::interlayer::prune::conservative_valid;
use kapla::interlayer::{candidate_spans, enumerate_segment_schemes};
use kapla::mapping::UnitMap;
use kapla::partition::{enumerate_partitions, PartitionScheme};
use kapla::sim::pipeline::evaluate_segment;
use kapla::solvers::kapla::KaplaIntra;
use kapla::solvers::space::{minimal_scheme, qty_candidates};
use kapla::solvers::{IntraCtx, IntraSolver, Objective};
use kapla::util::SplitMix64;
use kapla::workloads::{nets, training_graph, Layer};

/// Multiplicative slack for float accumulation-order differences between
/// the two tiers; the invariant itself is `estimate <= detailed`.
const SLACK: f64 = 1.001;

/// Random but plausible conv/fc/dw layer (mirrors
/// tests/property_invariants.rs).
fn random_layer(rng: &mut SplitMix64) -> Layer {
    let c = 1 + rng.below(96);
    let k = 1 + rng.below(128);
    let xo = 1 + rng.below(32);
    let r = *rng.choose(&[1u64, 3, 5, 7]);
    match rng.below(4) {
        0 => Layer::fc("f", c, k),
        1 => Layer::dwconv("d", c, xo.max(2), r, 1 + rng.below(2)),
        _ => Layer::conv("c", c, k, xo.max(r), r, 1 + rng.below(2)),
    }
}

/// The estimate context matching a concrete scheme solved on `region` at
/// `rb`: full-region node count (the estimate optimistically assumes all
/// allocated nodes help) and the region's DRAM-distribution hop distance
/// (`PartitionScheme::dram_hops` — the solvers always set a partition's
/// `region` to the allocated region, so this matches every scheme's hops).
fn ctx_for(region: (u64, u64), rb: u64, ifm_on_chip: bool) -> LayerCtx {
    let hops = PartitionScheme { region, ..PartitionScheme::single() }.dram_hops();
    LayerCtx {
        nodes: region.0 * region.1,
        round_batch: rb,
        rounds: 1,
        ifm_on_chip,
        ofm_on_chip: false,
        dram_hops: hops,
    }
}

#[test]
fn layer_estimate_never_exceeds_detailed_evaluation() {
    let arch = presets::bench_multi_node();
    let model = TieredCost::fresh();
    let mut rng = SplitMix64::new(0xAD15_51B1);
    let mut checked = 0usize;
    while checked < 120 {
        let layer = random_layer(&mut rng);
        let region = *rng.choose(&[(2u64, 2u64), (4, 4), (2, 4)]);
        let rb = *rng.choose(&[1u64, 2, 4, 8]);
        let ifm_on = rng.chance(0.5);
        let ictx =
            IntraCtx { region, rb, ifm_on_chip: ifm_on, objective: Objective::Energy };

        // The estimate must lower-bound *every* realizable scheme: check
        // it against two very different ones — KAPLA's descent result and
        // the minimal fallback scheme.
        let mut schemes: Vec<LayerScheme> = Vec::new();
        if let Some(s) = KaplaIntra.solve(&arch, &layer, &ictx, &model) {
            schemes.push(s);
        }
        if let Some(s) = minimal_scheme(&arch, &layer, region, rb) {
            schemes.push(s);
        }
        if schemes.is_empty() {
            continue; // layer does not fit this region/batch at all
        }

        let est = model.estimate_layer(&arch, &layer, &ctx_for(region, rb, ifm_on));
        for s in &schemes {
            let detailed = model.evaluate(&arch, s, ifm_on);
            assert!(
                est.energy_pj <= detailed.energy_pj * SLACK,
                "#{checked} {:?} region={region:?} rb={rb} ifm_on={ifm_on}: \
                 estimate energy {} > detailed {}",
                layer.kind,
                est.energy_pj,
                detailed.energy_pj
            );
            assert!(
                est.latency_cycles <= detailed.latency_cycles * SLACK,
                "#{checked} {:?} region={region:?} rb={rb} ifm_on={ifm_on}: \
                 estimate latency {} > detailed {}",
                layer.kind,
                est.latency_cycles,
                detailed.latency_cycles
            );
        }
        checked += 1;
    }
}

#[test]
fn partition_floor_never_exceeds_any_blocking() {
    // Soundness invariant of the partition-level admissible floor (the
    // lowest tier of the bound hierarchy): for a fixed `(partition, unit)`
    // prefix, `CostModel::bound_partition` lower-bounds the detailed
    // evaluation of EVERY blocking of that partition — in energy and in
    // latency simultaneously, so the partition-level check in
    // `visit_schemes_staged` is exact for both objectives
    // (`Objective::of` reads one of the two fields).
    let mut rng = SplitMix64::new(0xF1_00F2);
    let model = TieredCost::fresh();
    let orders = LoopOrder::all();
    let archs = [
        ("bench_multi_node", presets::bench_multi_node(), (2u64, 2u64), 4u64),
        ("multi_node_eyeriss", presets::multi_node_eyeriss(), (4, 4), 8),
    ];
    let mut checked = 0usize;
    for (name, arch, region, rb) in archs {
        let mut layers_drawn = 0usize;
        while layers_drawn < 12 {
            let layer = random_layer(&mut rng);
            let parts = enumerate_partitions(&layer, rb, region, true);
            if parts.is_empty() {
                continue;
            }
            layers_drawn += 1;
            let part = parts[rng.below(parts.len() as u64) as usize];
            let unit = UnitMap::build(&arch, part.node_shape(&layer, rb));
            for ifm_on_chip in [false, true] {
                let staged = model
                    .staged(&arch, &part, &unit, ifm_on_chip)
                    .expect("tiered model opts into staging");
                let floor = model.bound_partition(&staged);
                let gqs = qty_candidates(unit.totals, unit.granule);
                for _ in 0..6 {
                    let gq = gqs[rng.below(gqs.len() as u64) as usize];
                    let rqs = qty_candidates(gq, unit.granule);
                    let rq = rqs[rng.below(rqs.len() as u64) as usize];
                    let go = orders[rng.below(6) as usize];
                    let ro = orders[rng.below(6) as usize];
                    let s = LayerScheme {
                        part,
                        unit,
                        regf: LevelBlock { qty: rq, order: ro },
                        gbuf: LevelBlock { qty: gq, order: go },
                    };
                    if s.validate(&arch).is_err() {
                        continue;
                    }
                    let ev = model.evaluate(&arch, &s, ifm_on_chip);
                    assert!(
                        floor.energy_pj <= ev.energy_pj + 1e-9,
                        "{name}/{:?}: partition floor energy {} > blocking {}",
                        layer.kind,
                        floor.energy_pj,
                        ev.energy_pj
                    );
                    assert!(
                        floor.latency_cycles <= ev.latency_cycles + 1e-9,
                        "{name}/{:?}: partition floor latency {} > blocking {}",
                        layer.kind,
                        floor.latency_cycles,
                        ev.latency_cycles
                    );
                    checked += 1;
                }
            }
        }
    }
    assert!(checked > 80, "property needs coverage, only {checked} blockings drawn");
}

#[test]
fn segment_estimate_never_exceeds_detailed_evaluation() {
    let arch = presets::bench_multi_node();
    let model = TieredCost::fresh();
    let intra = KaplaIntra;
    let batch = 8u64;
    let mut rng = SplitMix64::new(0x5E6_AD15);
    let mut checked = 0usize;

    for net in [nets::mlp(), nets::alexnet(), training_graph(&nets::mlp())] {
        for end in 0..net.len() {
            for span in candidate_spans(end, 2) {
                let cands = enumerate_segment_schemes(&net, &arch, batch, &span, 8);
                for seg in cands {
                    if !conservative_valid(&arch, &net, batch, &seg) {
                        continue;
                    }
                    // Sample the candidate stream: the full cross product
                    // is large and the invariant is per-candidate.
                    if !rng.chance(0.4) {
                        continue;
                    }
                    let rb = seg.round_batch(batch);
                    let mut schemes = Vec::with_capacity(seg.len());
                    for (pos, &li) in seg.layers.iter().enumerate() {
                        let ictx = IntraCtx {
                            region: seg.regions[pos],
                            rb,
                            ifm_on_chip: seg.ifm_on_chip(&net, li),
                            objective: Objective::Energy,
                        };
                        if let Some(s) = intra.solve(&arch, &net.layers[li], &ictx, &model) {
                            schemes.push(s);
                        }
                    }
                    if schemes.len() != seg.len() {
                        continue; // some layer has no valid scheme here
                    }
                    let est = model.estimate_segment(&arch, &net, batch, &seg);
                    let detailed = evaluate_segment(&arch, &net, &seg, &schemes);
                    assert!(
                        est.energy_pj <= detailed.energy.total() * SLACK,
                        "{} seg {:?} rounds={}: estimate energy {} > detailed {}",
                        net.name,
                        seg.layers,
                        seg.rounds,
                        est.energy_pj,
                        detailed.energy.total()
                    );
                    assert!(
                        est.latency_cycles <= detailed.latency_cycles * SLACK,
                        "{} seg {:?} rounds={}: estimate latency {} > detailed {}",
                        net.name,
                        seg.layers,
                        seg.rounds,
                        est.latency_cycles,
                        detailed.latency_cycles
                    );
                    checked += 1;
                }
            }
        }
    }
    assert!(checked >= 10, "too few segment candidates exercised: {checked}");
}
