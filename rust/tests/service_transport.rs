//! Integration tests for the concurrent network front end
//! (`coordinator::transport`): real TCP/unix-socket connections against an
//! in-process service, pinning the three properties the transport must
//! preserve under concurrency —
//!
//! 1. **determinism**: a schedule computed over N concurrent connections
//!    is byte-identical to the same request through the pure
//!    `handle_line` stdin path;
//! 2. **tenant isolation**: one tenant's warm cache never shows up in
//!    another tenant's responses or stats;
//! 3. **admission control**: a saturated solve queue answers with a
//!    structured overload error — never a hang, never a dropped
//!    connection — and the service keeps serving afterwards.
//!
//! Responses arrive as raw JSON lines (the crate's `util::json` is a
//! writer, not a parser), so assertions work on substrings rendered by
//! the same writer — byte-exact by construction.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use kapla::arch::presets;
use kapla::coordinator::service::handle_line;
use kapla::coordinator::transport::{self, ServiceConfig};
use kapla::cost::{CacheBudget, SessionCache};

/// The workhorse request: small net, capped rounds, one thread — fast and
/// fully deterministic.
const LINE: &str = "schedule mlp 8 kapla threads=1 max_rounds=4";

fn send(conn: &mut TcpStream, line: &str) {
    conn.write_all(line.as_bytes()).unwrap();
    conn.write_all(b"\n").unwrap();
}

fn recv(reader: &mut BufReader<TcpStream>) -> String {
    let mut s = String::new();
    reader.read_line(&mut s).unwrap();
    assert!(s.ends_with('\n'), "truncated response: {s:?}");
    s.trim_end().to_string()
}

fn connect(addr: std::net::SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let conn = TcpStream::connect(addr).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(300))).unwrap();
    let reader = BufReader::new(conn.try_clone().unwrap());
    (conn, reader)
}

/// Extract the raw numeric token after `"key":` (keys are unique enough
/// within one response line for every field asserted here).
fn num_field(line: &str, key: &str) -> f64 {
    let pat = format!("\"{key}\":");
    let i = line.find(&pat).unwrap_or_else(|| panic!("missing {key} in {line}"));
    let rest = &line[i + pat.len()..];
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || matches!(c, '.' | '-' | '+' | 'e' | 'E')))
        .unwrap_or(rest.len());
    rest[..end].parse().unwrap_or_else(|e| panic!("bad number for {key} in {line}: {e}"))
}

#[test]
fn concurrent_clients_get_stdin_identical_schedules() {
    let arch = presets::bench_multi_node();
    let h = transport::spawn(
        &arch,
        ServiceConfig { queue_depth: 16, workers: 2, ..Default::default() },
        "127.0.0.1:0",
    )
    .unwrap();
    let addr = h.tcp_addr().unwrap();

    // Reference: the pure stdin path against a fresh bounded session (the
    // transport gives every tenant the same default budget).
    let reference = {
        let s = SessionCache::new(CacheBudget::bytes(kapla::coordinator::DEFAULT_SESSION_BYTES));
        handle_line(&arch, &s, LINE).unwrap()
    };
    let want_chain = format!("\"chain\":{}", reference.get("chain").unwrap().to_string_compact());
    let want_energy =
        format!("\"energy_pj\":{}", reference.get("energy_pj").unwrap().to_string_compact());

    let responses: Vec<String> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..4)
            .map(|i| {
                scope.spawn(move || {
                    // Two clients per tenant, racing on two workers.
                    let tenant = if i % 2 == 0 { "atenant" } else { "btenant" };
                    let (mut conn, mut reader) = connect(addr);
                    send(&mut conn, &format!("{LINE} tenant={tenant}"));
                    recv(&mut reader)
                })
            })
            .collect();
        handles.into_iter().map(|t| t.join().unwrap()).collect()
    });
    for r in &responses {
        assert!(r.contains("\"ok\":true"), "{r}");
        assert!(r.contains(&want_chain), "transport schedule diverged from stdin loop: {r}");
        assert!(r.contains(&want_energy), "{r}");
    }
    h.shutdown();
}

#[test]
fn tenant_sessions_are_isolated() {
    let arch = presets::bench_multi_node();
    let h = transport::spawn(&arch, ServiceConfig::default(), "127.0.0.1:0").unwrap();
    let (mut conn, mut reader) = connect(h.tcp_addr().unwrap());

    // What one cold request looks like against a fresh session (threads=1
    // makes the counter trace deterministic, not just the schedule).
    let cold_cache = {
        let s = SessionCache::new(CacheBudget::bytes(kapla::coordinator::DEFAULT_SESSION_BYTES));
        let r = handle_line(&arch, &s, LINE).unwrap();
        format!("\"cache\":{}", r.get("cache").unwrap().to_string_compact())
    };

    // Warm tenant `warm` with the identical request twice: the repeat must
    // replay recorded argmins (intra_hits > 0) without new evaluations.
    send(&mut conn, &format!("{LINE} tenant=warm"));
    let first = recv(&mut reader);
    assert!(first.contains("\"ok\":true"), "{first}");
    assert!(first.contains(&cold_cache), "fresh tenant must start cold: {first}");
    send(&mut conn, &format!("{LINE} tenant=warm"));
    let warmed = recv(&mut reader);
    assert!(num_field(&warmed, "intra_hits") > 0.0, "repeat must replay argmins: {warmed}");

    // The same request under a different tenant is stone cold again: its
    // whole counter trace must be byte-identical to a fresh session's —
    // any cross-namespace leak (shared evaluations, replayed argmins,
    // shared eviction pressure) would shift some counter.
    send(&mut conn, &format!("{LINE} tenant=other"));
    let cold = recv(&mut reader);
    assert!(cold.contains("\"ok\":true"), "{cold}");
    assert!(cold.contains(&cold_cache), "cache leak across tenants: {cold}");

    // Per-tenant `stats` agree: the warm tenant shows replays, the other
    // tenant's counters still match one cold request exactly, and a tenant
    // named for the first time has an empty session.
    send(&mut conn, "stats tenant=warm");
    let s_warm = recv(&mut reader);
    assert!(num_field(&s_warm, "intra_hits") > 0.0, "{s_warm}");
    send(&mut conn, "stats tenant=other");
    let s_other = recv(&mut reader);
    assert!(s_other.contains(&cold_cache), "{s_other}");
    send(&mut conn, "stats tenant=fresh");
    let s_fresh = recv(&mut reader);
    assert_eq!(num_field(&s_fresh, "lookups"), 0.0, "{s_fresh}");
    h.shutdown();
}

#[test]
fn anonymous_sessions_are_per_connection() {
    let arch = presets::bench_multi_node();
    let h = transport::spawn(&arch, ServiceConfig::default(), "127.0.0.1:0").unwrap();
    let addr = h.tcp_addr().unwrap();

    // Without a tenant= knob the connection is its own session (the old
    // stdin-loop behavior): warm within, cold across.
    let (mut conn, mut reader) = connect(addr);
    send(&mut conn, LINE);
    recv(&mut reader);
    send(&mut conn, LINE);
    let warmed = recv(&mut reader);
    assert!(num_field(&warmed, "intra_hits") > 0.0, "{warmed}");

    let (mut conn2, mut reader2) = connect(addr);
    send(&mut conn2, LINE);
    let cold = recv(&mut reader2);
    assert_eq!(num_field(&cold, "intra_hits"), 0.0, "{cold}");
    h.shutdown();
}

#[test]
fn saturated_queue_returns_structured_overload() {
    let arch = presets::bench_multi_node();
    // One worker, one queue slot: the third concurrent solve must shed.
    let h = transport::spawn(
        &arch,
        ServiceConfig { queue_depth: 1, workers: 1, ..Default::default() },
        "127.0.0.1:0",
    )
    .unwrap();
    let addr = h.tcp_addr().unwrap();

    // Occupy the worker and the queue slot with two slow solves (alexnet
    // is orders of magnitude more work than the probe request), then
    // burst cheap probes: with the worker busy and the queue full, every
    // probe must get the structured overload response immediately.
    let filler_line = "schedule alexnet 64 kapla threads=1 tenant=filler";
    let mut fillers: Vec<(TcpStream, BufReader<TcpStream>)> = Vec::new();
    for _ in 0..2 {
        let (mut conn, reader) = connect(addr);
        send(&mut conn, filler_line);
        fillers.push((conn, reader));
    }
    // Let the fillers reach the worker and the queue slot.
    std::thread::sleep(Duration::from_millis(200));

    let probes: Vec<String> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..8)
            .map(|_| {
                scope.spawn(move || {
                    let (mut conn, mut reader) = connect(addr);
                    send(&mut conn, LINE);
                    recv(&mut reader)
                })
            })
            .collect();
        handles.into_iter().map(|t| t.join().unwrap()).collect()
    });
    let overloads =
        probes.iter().filter(|r| r.contains("\"error\":\"overloaded\"")).count();
    let oks = probes.iter().filter(|r| r.contains("\"ok\":true")).count();
    assert_eq!(oks + overloads, probes.len(), "unstructured response: {probes:?}");
    assert!(overloads > 0, "1-deep queue under a burst must shed load: {probes:?}");
    for r in probes.iter().filter(|r| r.contains("overloaded")) {
        assert!(r.contains("\"retry_after_ms\":"), "{r}");
        assert!(r.contains("\"reason\":\"solve queue full\""), "{r}");
    }

    // Observability survives saturation: `stats` and `metrics` answer
    // inline even while the fillers still hold the solve queue.
    let (mut conn, mut reader) = connect(addr);
    send(&mut conn, "stats");
    assert!(recv(&mut reader).contains("\"ok\":true"));
    send(&mut conn, "metrics");
    let m = recv(&mut reader);
    assert!(num_field(&m, "overloads") >= overloads as f64, "{m}");

    // Both admitted fillers complete with real schedules (no request that
    // entered the queue is ever dropped)...
    for (_conn, reader) in fillers.iter_mut() {
        let r = recv(reader);
        assert!(r.contains("\"ok\":true"), "admitted solve was dropped: {r}");
    }
    // ...and the service still solves afterwards.
    send(&mut conn, LINE);
    assert!(recv(&mut reader).contains("\"ok\":true"));
    h.shutdown();
}

#[test]
fn tenant_limits_and_metrics_schema() {
    let arch = presets::bench_multi_node();
    let h = transport::spawn(
        &arch,
        ServiceConfig { max_tenants: 2, ..Default::default() },
        "127.0.0.1:0",
    )
    .unwrap();
    let (mut conn, mut reader) = connect(h.tcp_addr().unwrap());

    send(&mut conn, &format!("{LINE} tenant=first"));
    assert!(recv(&mut reader).contains("\"ok\":true"));
    send(&mut conn, "stats tenant=second");
    assert!(recv(&mut reader).contains("\"ok\":true"));
    // The namespace cap rejects the third tenant with a structured error;
    // existing tenants keep working.
    send(&mut conn, "stats tenant=third");
    let r = recv(&mut reader);
    assert!(r.contains("\"ok\":false") && r.contains("tenant limit"), "{r}");
    send(&mut conn, "stats tenant=first");
    assert!(recv(&mut reader).contains("\"ok\":true"));

    // Malformed tenancy is rejected, not guessed at.
    send(&mut conn, "stats tenant=bad/name");
    assert!(recv(&mut reader).contains("bad tenant name"));
    send(&mut conn, "stats tenant=first tenant=second");
    assert!(recv(&mut reader).contains("repeated tenant="));

    // The metrics snapshot carries the queue state, the per-solver
    // latency histogram of the one K solve, and both tenant namespaces.
    send(&mut conn, "metrics");
    let m = recv(&mut reader);
    assert!(m.contains("\"queue\":{\"capacity\":"), "{m}");
    assert!(m.contains("\"solver_latency_ms\":{\"K\":{\"count\":1"), "{m}");
    assert!(m.contains("\"first\":{"), "{m}");
    assert!(m.contains("\"second\":{"), "{m}");
    assert!(num_field(&m, "requests") >= 1.0, "{m}");

    // `quit` closes this connection but not the service.
    send(&mut conn, "quit");
    let mut leftover = String::new();
    assert_eq!(reader.read_line(&mut leftover).unwrap(), 0, "quit must close: {leftover:?}");
    let (mut conn2, mut reader2) = connect(h.tcp_addr().unwrap());
    send(&mut conn2, "stats");
    assert!(recv(&mut reader2).contains("\"ok\":true"));
    h.shutdown();
}

#[test]
fn idle_timeout_closes_stalled_connections_with_structured_error() {
    let arch = presets::bench_multi_node();
    let h = transport::spawn(
        &arch,
        ServiceConfig { idle_timeout: Some(Duration::from_millis(500)), ..Default::default() },
        "127.0.0.1:0",
    )
    .unwrap();
    let addr = h.tcp_addr().unwrap();

    // A connection that keeps completing requests inside the window stays
    // open indefinitely.
    let (mut conn, mut reader) = connect(addr);
    send(&mut conn, "stats");
    assert!(recv(&mut reader).contains("\"ok\":true"));
    std::thread::sleep(Duration::from_millis(200));
    send(&mut conn, "stats");
    assert!(recv(&mut reader).contains("\"ok\":true"));

    // A silent connection gets the structured idle-timeout error, then EOF
    // — never a bare RST, never a hang.
    let (_silent, mut silent_reader) = connect(addr);
    let r = recv(&mut silent_reader);
    assert!(r.contains("\"ok\":false") && r.contains("idle timeout"), "{r}");
    let mut leftover = String::new();
    assert_eq!(silent_reader.read_line(&mut leftover).unwrap(), 0, "expected close: {leftover:?}");

    // The slowloris shape: bytes trickle in but no newline ever completes
    // a request. The idle clock only resets on complete lines, so this
    // connection times out exactly like the silent one.
    let (mut dribbler, mut dribbler_reader) = connect(addr);
    dribbler.write_all(b"sched").unwrap(); // partial line, no '\n'
    let r = recv(&mut dribbler_reader);
    assert!(r.contains("idle timeout"), "dribbled partial line must not hold the slot: {r}");

    // The service itself is unaffected — fresh connections still solve.
    let (mut conn2, mut reader2) = connect(addr);
    send(&mut conn2, LINE);
    assert!(recv(&mut reader2).contains("\"ok\":true"));
    h.shutdown();
}

#[cfg(unix)]
#[test]
fn unix_socket_speaks_the_same_protocol() {
    use std::os::unix::net::UnixStream;

    let arch = presets::bench_multi_node();
    let path = std::env::temp_dir().join(format!("kapla-transport-{}.sock", std::process::id()));
    let spec = format!("unix:{}", path.display());
    let h = transport::spawn(&arch, ServiceConfig::default(), &spec).unwrap();
    assert!(h.tcp_addr().is_none());

    let reference = {
        let s = SessionCache::new(CacheBudget::bytes(kapla::coordinator::DEFAULT_SESSION_BYTES));
        handle_line(&arch, &s, LINE).unwrap()
    };
    let want_chain = format!("\"chain\":{}", reference.get("chain").unwrap().to_string_compact());

    let conn = UnixStream::connect(&path).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(300))).unwrap();
    let mut writer = conn.try_clone().unwrap();
    let mut reader = BufReader::new(conn);
    writer.write_all(format!("{LINE} tenant=ux\n").as_bytes()).unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    assert!(resp.contains("\"ok\":true"), "{resp}");
    assert!(resp.contains(&want_chain), "unix transport diverged: {resp}");

    h.shutdown();
    let _ = std::fs::remove_file(&path);
}
